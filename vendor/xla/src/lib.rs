//! API-compatible offline stub of the `xla` (xla_extension) crate.
//!
//! The real PJRT bindings link the XLA C++ runtime, which is not
//! available in this build environment. This stub keeps the whole crate
//! compiling with the same call signatures `dopinf::runtime` uses, with
//! a precise degradation contract:
//!
//! * [`Literal`] is a complete pure-Rust implementation (shape + bytes),
//!   so host-side literal round-trips behave exactly like upstream.
//! * [`PjRtClient::cpu`] succeeds (cheap handle), but
//!   [`HloModuleProto::from_text_file`] and [`PjRtClient::compile`]
//!   return errors — `runtime::Engine` already treats any PJRT failure
//!   as "fall back to native linalg", so the system stays fully
//!   functional, just without the Pallas-kernel fast path.
//!
//! Swap this path dependency for the real `xla` crate (and rebuild the
//! artifacts with `python/compile/aot.py`) to re-enable PJRT execution.

use std::fmt;
use std::path::Path;

/// Error type matching the upstream crate's `Display`-able error.
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn stub_err<T>(what: &str) -> Result<T> {
    Err(XlaError(format!(
        "{what}: PJRT unavailable (offline xla stub — native fallback expected)"
    )))
}

/// Element dtypes (only what the f64 pipeline uses).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F64,
}

impl ElementType {
    fn size_bytes(self) -> usize {
        match self {
            ElementType::F32 => 4,
            ElementType::F64 => 8,
        }
    }
}

/// Conversion trait backing [`Literal::to_vec`].
pub trait NativeType: Sized {
    const ELEMENT: ElementType;
    fn from_le_bytes(bytes: &[u8]) -> Self;
}

impl NativeType for f64 {
    const ELEMENT: ElementType = ElementType::F64;
    fn from_le_bytes(bytes: &[u8]) -> f64 {
        f64::from_le_bytes(bytes.try_into().expect("8-byte chunk"))
    }
}

impl NativeType for f32 {
    const ELEMENT: ElementType = ElementType::F32;
    fn from_le_bytes(bytes: &[u8]) -> f32 {
        f32::from_le_bytes(bytes.try_into().expect("4-byte chunk"))
    }
}

/// Host-side typed array: fully functional in the stub.
#[derive(Clone, Debug)]
pub struct Literal {
    element_type: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    /// Build a literal from raw little-endian bytes and a shape.
    pub fn create_from_shape_and_untyped_data(
        element_type: ElementType,
        dims: &[usize],
        untyped_data: &[u8],
    ) -> Result<Literal> {
        let count: usize = dims.iter().product();
        let want = count * element_type.size_bytes();
        if untyped_data.len() != want {
            return Err(XlaError(format!(
                "literal data has {} bytes, shape {:?} needs {}",
                untyped_data.len(),
                dims,
                want
            )));
        }
        Ok(Literal { element_type, dims: dims.to_vec(), bytes: untyped_data.to_vec() })
    }

    /// Copy out as a typed vector (dtype-checked).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.element_type != T::ELEMENT {
            return Err(XlaError(format!(
                "literal is {:?}, requested {:?}",
                self.element_type,
                T::ELEMENT
            )));
        }
        let sz = self.element_type.size_bytes();
        Ok(self.bytes.chunks_exact(sz).map(T::from_le_bytes).collect())
    }

    /// Shape dimensions.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Decompose a tuple literal. The stub never produces real tuples
    /// (nothing executes); a plain literal decomposes to itself, which
    /// matches how `runtime::exec` consumes single-output entry points.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Ok(vec![self])
    }
}

/// Parsed HLO module handle. Parsing requires the XLA runtime, so the
/// stub constructor always errors.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        stub_err("HloModuleProto::from_text_file")
    }
}

/// Computation wrapper (never holds a real graph in the stub).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer returned by an execution (unreachable in the stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle (unreachable in the stub: `compile` errs).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle. `cpu()` succeeds so process-wide runtime
/// initialization (and tests of it) behave as on the real crate; the
/// failure surfaces at compile time per-artifact, where the engine's
/// native fallback takes over.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient(()))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub_err("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f64() {
        let data = [1.0f64, -2.5, 3.25];
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F64, &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<f64>().unwrap(), data);
        assert_eq!(lit.dims(), &[3]);
    }

    #[test]
    fn literal_rejects_bad_sizes_and_dtypes() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F64, &[2], &[0u8; 9])
            .is_err());
        let lit = Literal::create_from_shape_and_untyped_data(ElementType::F64, &[1], &[0u8; 8])
            .unwrap();
        assert!(lit.to_vec::<f32>().is_err());
    }

    #[test]
    fn client_initializes_but_compile_fails() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation(());
        assert!(client.compile(&comp).is_err());
        assert!(HloModuleProto::from_text_file("/nope.hlo.txt").is_err());
    }
}

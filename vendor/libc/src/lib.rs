//! Offline vendored subset of the `libc` crate: exactly the symbols
//! dopinf needs on Linux (the only target this repo builds for) —
//! `CLOCK_THREAD_CPUTIME_ID` reads for `dopinf::util::timer` (see
//! DESIGN notes in `rust/src/comm/mod.rs` on the per-thread virtual
//! clocks), `signal(SIGINT, …)` for the `serve` subcommand's
//! graceful drain, and `kill(pid, SIGKILL)` for the process-transport
//! fault-injection tests (`tests/integration_proc.rs`).

#![allow(non_camel_case_types)]

pub type c_int = i32;
pub type c_long = i64;
pub type time_t = i64;

/// Per-thread CPU-time clock id (Linux, all architectures).
pub const CLOCK_THREAD_CPUTIME_ID: c_int = 3;

#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct timespec {
    pub tv_sec: time_t,
    pub tv_nsec: c_long,
}

/// Interrupt signal (ctrl-C); number 2 on Linux, all architectures.
pub const SIGINT: c_int = 2;

/// Uncatchable kill; number 9 on Linux, all architectures. Used by the
/// fault-injection tests to drop a worker rank mid-collective.
pub const SIGKILL: c_int = 9;

/// Process id, as `kill(2)` takes it (i32 on Linux, all architectures).
pub type pid_t = i32;

/// A signal handler address, as `signal(2)` takes it. Handlers must be
/// `extern "C"` and async-signal-safe (the serve CLI's only stores to
/// an `AtomicBool`).
pub type sighandler_t = usize;

extern "C" {
    pub fn clock_gettime(clockid: c_int, tp: *mut timespec) -> c_int;
    pub fn signal(signum: c_int, handler: sighandler_t) -> sighandler_t;
    pub fn kill(pid: pid_t, sig: c_int) -> c_int;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_cputime_clock_readable() {
        let mut ts = timespec { tv_sec: 0, tv_nsec: 0 };
        let rc = unsafe { clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut ts) };
        assert_eq!(rc, 0);
        assert!(ts.tv_sec >= 0 && ts.tv_nsec >= 0);
    }
}

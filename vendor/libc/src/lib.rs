//! Offline vendored subset of the `libc` crate: exactly the symbols
//! `dopinf::util::timer` needs to read `CLOCK_THREAD_CPUTIME_ID` on
//! Linux (the only target this repo builds for — see DESIGN notes in
//! `rust/src/comm/mod.rs` on the per-thread virtual clocks).

#![allow(non_camel_case_types)]

pub type c_int = i32;
pub type c_long = i64;
pub type time_t = i64;

/// Per-thread CPU-time clock id (Linux, all architectures).
pub const CLOCK_THREAD_CPUTIME_ID: c_int = 3;

#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct timespec {
    pub tv_sec: time_t,
    pub tv_nsec: c_long,
}

extern "C" {
    pub fn clock_gettime(clockid: c_int, tp: *mut timespec) -> c_int;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_cputime_clock_readable() {
        let mut ts = timespec { tv_sec: 0, tv_nsec: 0 };
        let rc = unsafe { clock_gettime(CLOCK_THREAD_CPUTIME_ID, &mut ts) };
        assert_eq!(rc, 0);
        assert!(ts.tv_sec >= 0 && ts.tv_nsec >= 0);
    }
}

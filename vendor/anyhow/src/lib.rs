//! Offline vendored subset of the `anyhow` error-handling API.
//!
//! The build environment has no network crate registry, so this crate
//! reimplements exactly the surface `dopinf` uses: [`Error`] (a context
//! chain around an optional typed source), [`Result`], the [`Context`]
//! extension trait for `Result` and `Option`, downcasting back to the
//! typed source ([`Error::downcast_ref`]), and the `anyhow!` / `bail!` /
//! `ensure!` macros. Semantics match upstream anyhow where exercised:
//! `{}` displays the outermost message, `{:#}` joins the whole chain
//! with `": "`, `Debug` renders a "Caused by" list, and `downcast_ref`
//! recovers the original error value a `?` conversion wrapped.

use std::error::Error as StdError;
use std::fmt;

/// A dynamic error carrying a context chain (outermost first) and,
/// when built from a typed `std::error::Error`, the original value for
/// [`Error::downcast_ref`].
pub struct Error {
    chain: Vec<String>,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()], source: None }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// A reference to the typed error this `Error` was converted from,
    /// if it was `E` (upstream `anyhow::Error::downcast_ref`). Context
    /// wrapping does not hide the source.
    pub fn downcast_ref<E: StdError + 'static>(&self) -> Option<&E> {
        self.source.as_deref().and_then(|s| s.downcast_ref::<E>())
    }

    /// Whether the typed source this `Error` was converted from is `E`.
    pub fn is<E: StdError + 'static>(&self) -> bool {
        self.downcast_ref::<E>().is_some()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain, source: Some(Box::new(e)) }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error variant of a `Result` or to `None`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)))
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = io_err().into();
        let e = e.context("reading dataset");
        assert_eq!(format!("{e}"), "reading dataset");
        assert_eq!(format!("{e:#}"), "reading dataset: missing file");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("nothing here").unwrap_err();
        assert_eq!(format!("{e}"), "nothing here");
        assert_eq!(Some(3u8).context("unused").unwrap(), 3);
    }

    #[test]
    fn result_with_context_lazy() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 2: missing file");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too big: {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too big: 101");
        let e = anyhow!("plain {}", "msg");
        assert_eq!(format!("{e}"), "plain msg");
    }

    #[test]
    fn downcast_ref_recovers_the_typed_source() {
        let e: Error = io_err().into();
        let e = e.context("reading dataset");
        let io = e.downcast_ref::<std::io::Error>().expect("source survives context");
        assert_eq!(io.kind(), std::io::ErrorKind::NotFound);
        assert!(e.is::<std::io::Error>());
        assert!(!e.is::<std::fmt::Error>());
        assert!(Error::msg("plain").downcast_ref::<std::io::Error>().is_none());
    }

    #[test]
    fn debug_renders_cause_chain() {
        let e = Error::msg("inner").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by") && dbg.contains("inner"));
    }
}

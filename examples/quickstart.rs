//! Quickstart: learn a predictive ROM from synthetic data in seconds.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the whole public API once: generate a low-rank traveling-wave
//! dataset, run the distributed dOpInf pipeline (p = 4 ranks), inspect
//! the spectrum, and check the ROM's *prediction* beyond the training
//! horizon against the analytic truth.

use std::sync::Arc;

use dopinf::comm::CostModel;
use dopinf::coordinator::config::{DOpInfConfig, DataSource};
use dopinf::coordinator::pipeline::run_distributed;
use dopinf::opinf::serial::OpInfConfig;
use dopinf::rom::RegGrid;
use dopinf::sim::synth::{generate, SynthSpec};

fn main() -> anyhow::Result<()> {
    // --- 1. a dataset: 2 state variables × 4096 spatial DoF, 100
    //        training snapshots of quasi-periodic dynamics -------------
    let spec = SynthSpec { nx: 4096, ns: 2, nt: 100, modes: 4, ..Default::default() };
    let nt_p = 200; // predict twice the training horizon
    let train = generate(&spec, 0);
    println!("dataset: {} rows x {} snapshots", train.rows(), train.cols());

    // --- 2. configure dOpInf (paper defaults, coarse reg grid) --------
    let opinf = OpInfConfig {
        // the paper's NS example uses 0.9996; this synthetic field has
        // slowly-decaying mode amplitudes, so keep (almost) all of them
        energy_target: 0.999_999,
        ns: 2,
        r_override: None,
        scaling: false,
        grid: RegGrid::coarse(),
        // the paper's NS case uses 1.2; periodic synthetic dynamics can
        // legitimately exceed the training max by ~30% when the training
        // window misses a peak, so allow a little more headroom
        max_growth: 1.5,
        nt_p,
    };
    let mut cfg = DOpInfConfig::new(4, opinf);
    cfg.cost_model = CostModel::shared_memory();
    cfg.probes = vec![(0, 100), (1, 2048)]; // two probe rows to lift

    // --- 3. run the distributed pipeline -------------------------------
    let source = DataSource::InMemory(Arc::new(train));
    let result = run_distributed(&cfg, &source)?;

    println!("reduced dimension r = {} (energy target 99.9999%)", result.r);
    println!(
        "top singular-value decay: {:?}",
        result
            .eigs
            .iter()
            .take(6)
            .map(|l| format!("{:.2e}", l.max(0.0).sqrt()))
            .collect::<Vec<_>>()
    );
    println!(
        "optimal regularization (beta1, beta2) = ({:.3e}, {:.3e}), training error {:.3e}",
        result.opt_pair.0, result.opt_pair.1, result.train_err
    );
    let b = result.timing.breakdown();
    println!(
        "virtual time {:.4}s = load {:.4} + compute {:.4} + comm {:.4} + learn {:.4} + post {:.4}",
        b.total, b.load, b.compute, b.comm, b.learn, b.post
    );

    // --- 4. validate the prediction beyond training --------------------
    let full = generate(&SynthSpec { nt: nt_p, ..spec }, 0);
    let mut worst = 0.0f64;
    for probe in &result.probes {
        let row = probe.var * 4096 + probe.row;
        for t in 100..nt_p {
            worst = worst.max((probe.values[t] - full[(row, t)]).abs());
        }
    }
    println!("max probe prediction error beyond training: {worst:.3e}");
    anyhow::ensure!(worst < 0.05, "prediction degraded: {worst}");
    println!("quickstart OK — the ROM extrapolates.");
    Ok(())
}

//! END-TO-END driver: the paper's full 2D Navier–Stokes cylinder
//! workload (Sec. II.B + IV), all layers composed.
//!
//! ```bash
//! make artifacts                      # once: AOT-compile the kernels
//! cargo run --release --example cylinder_rom
//! ```
//!
//! 1. Simulates vortex shedding past a cylinder (from-scratch MAC-grid
//!    projection solver) over [0, 10] s, sampling 1200 snapshots from
//!    t = 4 s (the paper's downsampled layout: 600 train + 600 predict).
//! 2. Trains the distributed dOpInf ROM (p = 8) on the first 600
//!    snapshots through the PJRT artifacts when available.
//! 3. Predicts the full [4, 10] s horizon and reports probe errors at
//!    the paper's three probe locations (Fig. 3) + timing breakdown.
//!
//! The dataset is cached in `data/cylinder.snapd` (~130 MB); delete it
//! to re-simulate. Grid/steps scale with env:
//!   DOPINF_GRID=256x48 DOPINF_PROCS=8 cargo run --release --example cylinder_rom

use std::path::PathBuf;
use std::sync::Arc;

use dopinf::coordinator::config::{DOpInfConfig, DataSource};
use dopinf::coordinator::pipeline::run_distributed;
use dopinf::io::snapd::SnapReader;
use dopinf::opinf::serial::OpInfConfig;
use dopinf::rom::RegGrid;
use dopinf::sim::driver::{run_to_dataset, SimConfig};
use dopinf::util::csvout::CsvWriter;
use dopinf::util::json::Json;
use dopinf::util::timer::WallTimer;

fn env_or(name: &str, default: &str) -> String {
    std::env::var(name).unwrap_or_else(|_| default.to_string())
}

fn main() -> anyhow::Result<()> {
    let grid = env_or("DOPINF_GRID", "192x36");
    let (nx, ny) = {
        let (a, b) = grid.split_once('x').expect("DOPINF_GRID like 192x36");
        (a.parse::<usize>()?, b.parse::<usize>()?)
    };
    let p: usize = env_or("DOPINF_PROCS", "8").parse()?;
    let data_path = PathBuf::from(env_or("DOPINF_DATA", &format!("data/cylinder_{grid}.snapd")));

    // ---------- 1. high-fidelity data (cached) --------------------------
    if !data_path.exists() {
        println!("simulating cylinder flow on {nx}x{ny} (one-time, cached at {data_path:?})...");
        let t = WallTimer::start();
        let cfg = SimConfig::cylinder(nx, ny);
        let info = run_to_dataset(&cfg, &data_path)?;
        println!(
            "  simulated {} steps -> {} snapshots in {:.1}s",
            info.steps,
            info.n_samples,
            t.elapsed()
        );
    } else {
        println!("using cached dataset {data_path:?}");
    }
    let reader = SnapReader::open(&data_path)?;
    let nt_total = reader.var_info("u_x")?.cols;
    let cells = reader.var_info("u_x")?.rows;
    let probe_rows: Vec<usize> = reader
        .meta()
        .get("probe_rows")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_usize).collect())
        .unwrap_or_default();
    let nt_train = nt_total / 2;
    println!("dataset: {cells} cells/var, {nt_total} snapshots, training on first {nt_train}");

    // ---------- 2. distributed dOpInf training --------------------------
    // paper hyperparameters: 99.96% energy, 8x8 grid, growth bound 1.2
    let opinf = OpInfConfig {
        ns: 2,
        energy_target: 0.9996,
        r_override: None,
        scaling: false,
        grid: RegGrid::paper_default(),
        max_growth: 1.2,
        nt_p: nt_total,
    };
    let mut cfg = DOpInfConfig::new(p, opinf);
    let artifacts = PathBuf::from(env_or("DOPINF_ARTIFACTS", "artifacts"));
    if artifacts.join("manifest.json").exists() {
        cfg.artifacts_dir = Some(artifacts);
    } else {
        println!("(no artifacts found; running on the native engine)");
    }
    for &row in &probe_rows {
        cfg.probes.push((0, row));
        cfg.probes.push((1, row));
    }

    // training source: first nt_train snapshots
    let mut stacked = reader.read_all("u_x")?.slice_cols(0, nt_train);
    stacked = stacked.vstack(&reader.read_all("u_y")?.slice_cols(0, nt_train));
    let source = DataSource::InMemory(Arc::new(stacked));

    println!("training dOpInf ROM with p = {p} ranks...");
    let t = WallTimer::start();
    let result = run_distributed(&cfg, &source)?;
    println!("  trained in {:.1}s wall", t.elapsed());
    println!("  r = {} at 99.96% retained energy", result.r);
    println!(
        "  optimal (beta1, beta2) = ({:.3e}, {:.3e}) on rank {}",
        result.opt_pair.0, result.opt_pair.1, result.winner_rank
    );
    println!("  training error = {:.3e}", result.train_err);
    println!(
        "  ROM rollout: {:.4}s for {} steps (the paper reports ~0.03s)",
        result.rom_time, nt_total
    );
    let b = result.timing.breakdown();
    println!(
        "  virtual time {:.3}s = load {:.3} + compute {:.3} + comm {:.3} + learn {:.3} + post {:.3}",
        b.total, b.load, b.compute, b.comm, b.learn, b.post
    );

    // ---------- 3. probe-level validation (Fig. 3) ----------------------
    std::fs::create_dir_all("results")?;
    let mut csv = CsvWriter::create(
        "results/cylinder_probes.csv",
        &["probe", "var", "t_index", "reference", "rom"],
    )?;
    println!("probe errors over the FULL horizon (train + prediction):");
    let mut worst_rel = 0.0f64;
    for (k, pred) in result.probes.iter().enumerate() {
        let var_name = if pred.var == 0 { "u_x" } else { "u_y" };
        let truth = reader.read_row(var_name, pred.row)?;
        let mut num = 0.0;
        let mut den = 0.0;
        for t in 0..nt_total {
            let d = pred.values[t] - truth[t];
            num += d * d;
            den += truth[t] * truth[t];
            csv.row(&[k as f64, pred.var as f64, t as f64, truth[t], pred.values[t]])?;
        }
        let rel = (num / den.max(1e-30)).sqrt();
        worst_rel = worst_rel.max(rel);
        println!("  probe row {:>6} {}: rel l2 error {:.3e}", pred.row, var_name, rel);
    }
    csv.finish()?;
    println!("wrote results/cylinder_probes.csv");
    anyhow::ensure!(worst_rel < 0.5, "probe reconstruction degraded: {worst_rel}");
    println!("cylinder end-to-end OK (worst probe rel error {worst_rel:.3e})");
    Ok(())
}

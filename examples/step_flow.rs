//! Backward-facing step scenario (the abstract's "flow over a step").
//!
//! ```bash
//! cargo run --release --example step_flow
//! ```
//!
//! Same pipeline as `cylinder_rom`, different geometry: recirculating
//! flow behind a step. Demonstrates that the library is workload-
//! agnostic — geometry, probes, and ROM settings are all configuration.

use std::path::PathBuf;
use std::sync::Arc;

use dopinf::coordinator::config::{DOpInfConfig, DataSource};
use dopinf::coordinator::pipeline::run_distributed;
use dopinf::io::snapd::SnapReader;
use dopinf::opinf::serial::OpInfConfig;
use dopinf::rom::RegGrid;
use dopinf::sim::driver::{run_to_dataset, SimConfig};
use dopinf::util::json::Json;
use dopinf::util::timer::WallTimer;

fn main() -> anyhow::Result<()> {
    let (nx, ny) = (128, 32);
    let data_path = PathBuf::from("data/step_128x32.snapd");

    if !data_path.exists() {
        println!("simulating backward-facing step flow on {nx}x{ny}...");
        let t = WallTimer::start();
        let mut cfg = SimConfig::step(nx, ny);
        cfg.t_sample = 2.0;
        cfg.t_end = 6.0;
        cfg.sample_every = 0.02;
        let info = run_to_dataset(&cfg, &data_path)?;
        println!("  {} steps -> {} snapshots in {:.1}s", info.steps, info.n_samples, t.elapsed());
    } else {
        println!("using cached dataset {data_path:?}");
    }

    let reader = SnapReader::open(&data_path)?;
    let nt_total = reader.var_info("u_x")?.cols;
    let nt_train = (nt_total * 2) / 3;
    let probe_rows: Vec<usize> = reader
        .meta()
        .get("probe_rows")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_usize).collect())
        .unwrap_or_default();

    let opinf = OpInfConfig {
        ns: 2,
        energy_target: 0.9999,
        r_override: None,
        scaling: true, // exercise the max-abs scaling path
        grid: RegGrid::paper_default(),
        max_growth: 1.5,
        nt_p: nt_total,
    };
    let mut cfg = DOpInfConfig::new(4, opinf);
    for &row in &probe_rows {
        cfg.probes.push((0, row));
    }

    let mut stacked = reader.read_all("u_x")?.slice_cols(0, nt_train);
    stacked = stacked.vstack(&reader.read_all("u_y")?.slice_cols(0, nt_train));
    let source = DataSource::InMemory(Arc::new(stacked));

    println!("training on {nt_train}/{nt_total} snapshots, p = 4, max-abs scaling ON...");
    let result = run_distributed(&cfg, &source)?;
    println!("  r = {}", result.r);
    println!(
        "  optimal (beta1, beta2) = ({:.3e}, {:.3e}), training error {:.3e}",
        result.opt_pair.0, result.opt_pair.1, result.train_err
    );

    let mut worst = 0.0f64;
    for pred in &result.probes {
        let truth = reader.read_row("u_x", pred.row)?;
        let mut num = 0.0;
        let mut den = 0.0;
        for t in 0..nt_total {
            let d = pred.values[t] - truth[t];
            num += d * d;
            den += truth[t] * truth[t];
        }
        let rel = (num / den.max(1e-30)).sqrt();
        worst = worst.max(rel);
        println!("  probe row {:>6} u_x: rel l2 error {:.3e}", pred.row, rel);
    }
    anyhow::ensure!(worst < 0.5, "probe error {worst}");
    println!("step-flow example OK");
    Ok(())
}

//! Strong-scaling study (paper Fig. 4) + large-p projection.
//!
//! ```bash
//! cargo run --release --example scaling_study
//! ```
//!
//! Runs Steps I–IV at p ∈ {1, 2, 4, 8} on a synthetic dataset shaped
//! like the paper's (600 training snapshots), repeats each measurement,
//! and prints mean ± std virtual CPU time, speedup, and the Fig. 4
//! breakdown. Finishes with the Amdahl+log-p fit projected to p = 2048
//! (the regime of the paper's companion CPC article).

use std::sync::Arc;

use dopinf::comm::CostModel;
use dopinf::coordinator::config::{DOpInfConfig, DataSource};
use dopinf::coordinator::scaling::{strong_scaling, AmdahlFit};
use dopinf::opinf::serial::OpInfConfig;
use dopinf::rom::RegGrid;
use dopinf::sim::synth::{generate, SynthSpec};
use dopinf::util::csvout::CsvWriter;

fn main() -> anyhow::Result<()> {
    let repeats: usize = std::env::var("DOPINF_REPEATS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5);
    let nx: usize = std::env::var("DOPINF_NX")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20_000);

    println!("generating synthetic dataset ({nx} rows/var x 2 vars x 600 snapshots)...");
    let spec = SynthSpec { nx, ns: 2, nt: 600, modes: 5, ..Default::default() };
    let source = DataSource::InMemory(Arc::new(generate(&spec, 0)));

    let opinf = OpInfConfig {
        ns: 2,
        energy_target: 0.9996,
        r_override: None,
        scaling: false,
        grid: RegGrid::paper_default(), // 64 pairs, like the paper
        max_growth: 1.2,
        nt_p: 1200,
    };
    let mut base = DOpInfConfig::new(1, opinf);
    base.cost_model = CostModel::shared_memory();

    println!("strong scaling, {repeats} repeats per p (virtual per-rank clocks):\n");
    let rows = strong_scaling(&base, &source, &[1, 2, 4, 8], repeats)?;
    println!(
        "{:>4} {:>12} {:>10} {:>9}   load/compute/comm/learn/post [s]",
        "p", "mean [s]", "std [s]", "speedup"
    );
    std::fs::create_dir_all("results")?;
    let mut csv = CsvWriter::create(
        "results/scaling_study.csv",
        &["p", "mean_s", "std_s", "speedup", "load", "compute", "comm", "learn", "post"],
    )?;
    for row in &rows {
        let b = &row.breakdown;
        println!(
            "{:>4} {:>12.5} {:>10.5} {:>9.3}   {:.3}/{:.3}/{:.3}/{:.3}/{:.3}",
            row.p, row.mean_s, row.std_s, row.speedup, b.load, b.compute, b.comm, b.learn, b.post
        );
        csv.row(&[
            row.p as f64, row.mean_s, row.std_s, row.speedup, b.load, b.compute, b.comm, b.learn,
            b.post,
        ])?;
    }
    csv.finish()?;

    // Amdahl + log-p projection through (1, 2, 8)
    let fit = AmdahlFit::through([
        (rows[0].p, rows[0].mean_s),
        (rows[1].p, rows[1].mean_s),
        (rows[3].p, rows[3].mean_s),
    ]);
    println!(
        "\nfit: T(p) = {:.4} + {:.4}/p + {:.5}*log2(p)  [serial/parallel/comm seconds]",
        fit.a, fit.b, fit.c
    );
    for p in [16, 64, 256, 2048] {
        println!("  projected speedup at p={p}: {:.2}", fit.speedup(p));
    }
    println!("\n(see results/scaling_study.csv; the Fig. 4 shape — near-ideal to p=4,\n deteriorating at p=8 as the serial fraction and collectives grow — should be visible)");
    Ok(())
}

//! Ensemble UQ end to end: train → save → load → serve.
//!
//! ```bash
//! cargo run --release --example ensemble_uq
//! ```
//!
//! Walks the full online-stage flow the serve/ subsystem adds: train a
//! ROM on synthetic data with the distributed pipeline, package it into
//! a versioned on-disk artifact, load it back (as a serving process
//! would), and evaluate a 256-member perturbed-initial-condition
//! ensemble sharded over 4 workers — the paper's "uncertainty
//! quantification" workload — reporting probe mean/variance bands.

use std::collections::BTreeMap;
use std::sync::Arc;

use dopinf::comm::CostModel;
use dopinf::coordinator::config::{DOpInfConfig, DataSource};
use dopinf::coordinator::pipeline::run_distributed;
use dopinf::opinf::serial::OpInfConfig;
use dopinf::rom::RegGrid;
use dopinf::runtime::Engine;
use dopinf::serve::{serve_ensemble, EnsembleSpec, RegBlocks, RomArtifact};
use dopinf::sim::synth::{generate, SynthSpec};

fn main() -> anyhow::Result<()> {
    // --- 1. train: distributed dOpInf on a synthetic dataset ----------
    let nx = 2048;
    let spec = SynthSpec { nx, ns: 2, nt: 100, modes: 4, ..Default::default() };
    let nt_p = 200;
    let train = generate(&spec, 0);
    println!("training on {} rows x {} snapshots (p = 4 ranks)", train.rows(), train.cols());

    let opinf = OpInfConfig {
        ns: 2,
        energy_target: 0.999_999,
        r_override: None,
        scaling: false,
        grid: RegGrid::coarse(),
        max_growth: 1.5,
        nt_p,
    };
    let mut cfg = DOpInfConfig::new(4, opinf);
    cfg.cost_model = CostModel::shared_memory();
    cfg.probes = vec![(0, 64), (1, 1024)];
    let result = run_distributed(&cfg, &DataSource::InMemory(Arc::new(train)))?;
    println!(
        "trained: r = {}, (beta1, beta2) = ({:.3e}, {:.3e}), train err {:.3e}",
        result.r, result.opt_pair.0, result.opt_pair.1, result.train_err
    );

    // --- 2. save the servable artifact --------------------------------
    let mut meta = BTreeMap::new();
    meta.insert("dataset".to_string(), "synthetic traveling-wave".to_string());
    meta.insert("r".to_string(), result.r.to_string());
    meta.insert("train_err".to_string(), format!("{:.3e}", result.train_err));
    let artifact = RomArtifact {
        ops: result.ops.clone(),
        qhat0: result.qhat0.clone(),
        probes: result.probe_bases.clone(),
        reg: Some(RegBlocks::from_problem(&result.problem)),
        meta,
    };
    let path = std::env::temp_dir().join("dopinf_ensemble_uq").join("synth.rom");
    artifact.save(&path)?;
    println!("saved ROM artifact to {} ({} bytes)", path.display(), artifact.to_bytes().len());

    // --- 3. load it back, as a serving process would -------------------
    let served = RomArtifact::load(&path)?;
    anyhow::ensure!(served.ops.ahat == artifact.ops.ahat, "save -> load must be bitwise");
    anyhow::ensure!(served.probes.len() == 2, "probe bases travel with the model");

    // --- 4. 256-member ensemble, sharded over 4 workers ----------------
    let espec = EnsembleSpec { members: 256, sigma: 0.02, seed: 17, n_steps: nt_p };
    let t = dopinf::util::timer::WallTimer::start();
    let stats = serve_ensemble(&Engine::native(), &served, &espec, 4)?;
    let dt = t.elapsed();
    println!(
        "ensemble: {} member-steps in {:.3} s ({:.3e} member-steps/s), {} diverged",
        espec.members * espec.n_steps,
        dt,
        (espec.members * espec.n_steps) as f64 / dt.max(1e-12),
        stats.n_diverged()
    );

    // --- 5. probe mean/variance output ---------------------------------
    let mut worst_band = 0.0f64;
    for series in &stats.probes {
        let k = espec.n_steps - 1;
        println!(
            "probe var{} row{}: mean {:.5}, std {:.2e}, 90% band [{:.5}, {:.5}]",
            series.var,
            series.row,
            series.mean[k],
            series.variance[k].sqrt(),
            series.q05[k],
            series.q95[k]
        );
        // the deterministic prediction (member 0's anchor) must sit
        // inside the ensemble band at every step
        let pred = result
            .probes
            .iter()
            .find(|p| p.var == series.var && p.row == series.row)
            .expect("probe present in training output");
        for t in 0..espec.n_steps {
            anyhow::ensure!(
                series.q05[t] <= pred.values[t] + 1e-9 && pred.values[t] <= series.q95[t] + 1e-9,
                "deterministic prediction escapes the ensemble band at t={t}"
            );
            worst_band = worst_band.max(series.q95[t] - series.q05[t]);
        }
        anyhow::ensure!(series.count[k] + stats.n_diverged() == espec.members);
    }
    anyhow::ensure!(worst_band > 0.0, "a perturbed ensemble must have spread");
    println!("ensemble_uq OK — widest 90% band: {worst_band:.3e}");
    Ok(())
}

//! PJRT runtime integration: the AOT artifacts (python/compile, `tiny`
//! profile) must reproduce the native linalg results exactly through
//! every entry point — the L1/L2 ⇄ L3 contract.
//!
//! Requires `make artifacts` (the Makefile runs it before tests).

use std::path::PathBuf;

use dopinf::linalg::{matmul, matmul_tn, syrk, Matrix};
use dopinf::rom::quadratic::s_dim;
use dopinf::rom::{solve_discrete, RomOperators};
use dopinf::runtime::Engine;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The PJRT-backed engine, or `None` (test skipped) when the AOT
/// artifacts have not been built: `make artifacts` needs the Python/JAX
/// toolchain, which CI runners and bare checkouts don't have. Native
/// fallback behavior is covered unconditionally in `runtime::exec`'s
/// unit tests; these PJRT-equivalence tests engage wherever the
/// artifacts directory exists.
fn engine() -> Option<Engine> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping PJRT test: no artifacts at {dir:?} (run `make artifacts`)");
        return None;
    }
    let e = Engine::from_artifacts(&dir).expect("engine");
    assert!(e.has_artifacts());
    Some(e)
}

/// tiny profile shapes (python/compile/shapes.py): block_rows=64, nt=24,
/// r_max=6, rollout_steps=32, recon_cols=32.
const NT: usize = 24;
const RMAX: usize = 6;
const STEPS: usize = 32;

#[test]
fn pjrt_gram_matches_native_exact_blocks() {
    let Some(e) = engine() else { return };
    let q = Matrix::randn(128, NT, 1); // exactly 2 blocks of 64
    let got = e.gram(&q);
    let want = syrk(&q);
    assert!(got.max_abs_diff(&want) < 1e-10, "diff {}", got.max_abs_diff(&want));
    assert!(e.stats.pjrt_calls.load(std::sync::atomic::Ordering::Relaxed) >= 2);
}

#[test]
fn pjrt_gram_pads_ragged_tail() {
    let Some(e) = engine() else { return };
    for rows in [1, 63, 65, 100, 200] {
        let q = Matrix::randn(rows, NT, rows as u64);
        let got = e.gram(&q);
        let want = syrk(&q);
        assert!(
            got.max_abs_diff(&want) < 1e-10,
            "rows={rows} diff {}",
            got.max_abs_diff(&want)
        );
    }
}

#[test]
fn pjrt_gram_falls_back_on_other_nt() {
    let Some(e) = engine() else { return };
    let q = Matrix::randn(50, 17, 3); // nt=17 has no artifact
    let got = e.gram(&q);
    assert_eq!(got, syrk(&q));
    assert!(e.stats.native_calls.load(std::sync::atomic::Ordering::Relaxed) >= 1);
}

fn sample_ops(r: usize) -> (RomOperators, Vec<f64>) {
    let mut ops = RomOperators::zeros(r);
    let a = Matrix::randn(r, r, 11);
    for i in 0..r {
        for j in 0..r {
            ops.ahat[(i, j)] = 0.2 * a[(i, j)] / r as f64;
        }
        ops.ahat[(i, i)] += 0.8;
        ops.chat[i] = 0.01 * i as f64;
    }
    let f = Matrix::randn(r, s_dim(r), 12);
    for i in 0..r {
        for k in 0..s_dim(r) {
            ops.fhat[(i, k)] = 0.02 * f[(i, k)];
        }
    }
    let q0: Vec<f64> = (0..r).map(|i| 0.3 - 0.1 * i as f64).collect();
    (ops, q0)
}

#[test]
fn pjrt_rollout_matches_native_at_rmax() {
    let Some(e) = engine() else { return };
    let (ops, q0) = sample_ops(RMAX);
    let (nans_p, got) = e.rollout(&ops, &q0, STEPS);
    let (nans_n, want) = solve_discrete(&ops, &q0, STEPS);
    assert_eq!(nans_p, nans_n);
    assert!(got.max_abs_diff(&want) < 1e-11, "diff {}", got.max_abs_diff(&want));
    // guard against a silent native fallback masking this comparison
    assert!(e.stats.pjrt_calls.load(std::sync::atomic::Ordering::Relaxed) >= 1);
}

#[test]
fn pjrt_rollout_pads_smaller_r() {
    let Some(e) = engine() else { return };
    for r in [1, 3, 5] {
        let (ops, q0) = sample_ops(r);
        let (nans_p, got) = e.rollout(&ops, &q0, STEPS);
        let (nans_n, want) = solve_discrete(&ops, &q0, STEPS);
        assert_eq!(nans_p, nans_n, "r={r}");
        assert!(got.max_abs_diff(&want) < 1e-11, "r={r} diff {}", got.max_abs_diff(&want));
    }
}

#[test]
fn pjrt_rollout_falls_back_on_other_steps() {
    let Some(e) = engine() else { return };
    let (ops, q0) = sample_ops(4);
    let (_, got) = e.rollout(&ops, &q0, 19); // no 19-step artifact
    let (_, want) = solve_discrete(&ops, &q0, 19);
    assert_eq!(got, want);
}

#[test]
fn pjrt_project_matches_native() {
    let Some(e) = engine() else { return };
    let q = Matrix::randn(100, NT, 21);
    let d = syrk(&q);
    for r in [1, 4, RMAX] {
        let tr = Matrix::randn(NT, r, r as u64 + 5);
        let got = e.project(&tr, &d);
        let want = matmul_tn(&tr, &d);
        assert!(got.max_abs_diff(&want) < 1e-10, "r={r} diff {}", got.max_abs_diff(&want));
    }
    assert!(e.stats.pjrt_calls.load(std::sync::atomic::Ordering::Relaxed) >= 3);
}

#[test]
fn pjrt_reconstruct_matches_native() {
    let Some(e) = engine() else { return };
    for (rows, r) in [(64, RMAX), (130, 4), (7, 1)] {
        let vr = Matrix::randn(rows, r, 31);
        let qt = Matrix::randn(r, STEPS, 32); // recon_cols == 32 in tiny
        let got = e.reconstruct(&vr, &qt);
        let want = matmul(&vr, &qt);
        assert!(
            got.max_abs_diff(&want) < 1e-10,
            "rows={rows} r={r} diff {}",
            got.max_abs_diff(&want)
        );
    }
    assert!(e.stats.pjrt_calls.load(std::sync::atomic::Ordering::Relaxed) >= 3);
}

#[test]
fn pjrt_rollout_propagates_nans() {
    let Some(e) = engine() else { return };
    let mut ops = RomOperators::zeros(RMAX);
    ops.fhat[(0, 0)] = 50.0;
    let q0 = vec![100.0; RMAX];
    let (nans, _) = e.rollout(&ops, &q0, STEPS);
    assert!(nans, "divergence must be reported through the PJRT path");
}

#[test]
fn engine_is_shareable_across_threads() {
    let Some(e) = engine() else { return };
    let e = std::sync::Arc::new(e);
    let q = std::sync::Arc::new(Matrix::randn(96, NT, 77));
    let want = syrk(&q);
    std::thread::scope(|s| {
        for _ in 0..4 {
            let e = e.clone();
            let q = q.clone();
            let want = want.clone();
            s.spawn(move || {
                let got = e.gram(&q);
                assert!(got.max_abs_diff(&want) < 1e-10);
            });
        }
    });
}

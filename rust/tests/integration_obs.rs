//! Observability-plane contracts, end to end (ISSUE 6 acceptance):
//!
//! * **Bitwise invisibility** — arming `--trace`/`--metrics` must not
//!   move a single bit of any `DOpInfResult` artifact, across
//!   p ∈ {1, 2, 4} × both transports × T ∈ {1, 4}. The tracer reads
//!   wall clocks but never feeds them back into the virtual `Clock`s
//!   or the numerics, so the outputs are byte-identical by design;
//!   this suite is the regression fence for that design.
//! * **Coverage** — a traced p = 4 run emits a valid Chrome
//!   trace-event document with all five categories on every rank
//!   track and the predicted-vs-actual overlay on every comm event.
//! * **Reconciliation** — the metrics summary's category totals are
//!   the virtual-clock `RunTiming` verbatim.
//! * **Fault path** — an injected mid-run read fault still flushes a
//!   parseable trace holding the originating rank's partial spans,
//!   with no X event missing its `dur` (no collective left open).

use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::Arc;

use dopinf::comm::CostModel;
use dopinf::coordinator::config::{DOpInfConfig, DataSource, FaultSpec, Transport};
use dopinf::coordinator::pipeline::{run_distributed, DOpInfResult};
use dopinf::opinf::serial::OpInfConfig;
use dopinf::rom::RegGrid;
use dopinf::sim::synth::{generate, SynthSpec};
use dopinf::util::json::{parse, Json};

fn obs_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dopinf_it_obs_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn test_setup(nx: usize) -> (DataSource, OpInfConfig) {
    let spec = SynthSpec { nx, ns: 2, nt: 60, modes: 3, ..Default::default() };
    let q = generate(&spec, 0);
    let ocfg = OpInfConfig {
        ns: 2,
        energy_target: 0.999_999,
        r_override: None,
        scaling: false,
        grid: RegGrid::coarse(),
        max_growth: 1.5,
        nt_p: 120,
    };
    (DataSource::InMemory(Arc::new(q)), ocfg)
}

/// Every f64 of every output artifact, not just within tolerance.
fn assert_bitwise_eq(a: &DOpInfResult, b: &DOpInfResult, tag: &str) {
    assert_eq!(a.r, b.r, "{tag}: r");
    assert_eq!(a.eigs, b.eigs, "{tag}: eigs");
    assert_eq!(a.retained_energy, b.retained_energy, "{tag}: energy");
    assert_eq!(a.opt_pair, b.opt_pair, "{tag}: opt_pair");
    assert_eq!(a.train_err, b.train_err, "{tag}: train_err");
    assert_eq!(a.qtilde.data(), b.qtilde.data(), "{tag}: qtilde");
    assert_eq!(a.ops.ahat.data(), b.ops.ahat.data(), "{tag}: ahat");
    assert_eq!(a.ops.fhat.data(), b.ops.fhat.data(), "{tag}: fhat");
    assert_eq!(a.ops.chat, b.ops.chat, "{tag}: chat");
    assert_eq!(a.probes.len(), b.probes.len(), "{tag}: probe count");
    for (pa, pb) in a.probes.iter().zip(&b.probes) {
        assert_eq!(pa.values, pb.values, "{tag}: probe values");
    }
}

#[test]
fn tracing_is_bitwise_invisible_to_results() {
    let dir = obs_dir("invisible");
    let (source, ocfg) = test_setup(61);
    for p in [1usize, 2, 4] {
        for transport in [Transport::Threads, Transport::Sockets] {
            for t in [1usize, 4] {
                let mut cfg = DOpInfConfig::new(p, ocfg.clone());
                cfg.cost_model = CostModel::free();
                cfg.transport = transport;
                cfg.threads_per_rank = t;
                // p × T products exceed this machine's cores; results
                // are T-invariant so only wall time could care
                cfg.allow_oversubscribe = true;
                cfg.probes = vec![(0, 3), (1, 60)];
                let plain = run_distributed(&cfg, &source).unwrap();

                let mut traced_cfg = cfg.clone();
                let tag = format!("p{p}_{transport:?}_t{t}");
                traced_cfg.trace = Some(dir.join(format!("{tag}.trace.json")));
                traced_cfg.metrics = Some(dir.join(format!("{tag}.metrics.json")));
                let traced = run_distributed(&traced_cfg, &source).unwrap();

                assert_bitwise_eq(&plain, &traced, &tag);
                // both exports must exist and hold valid JSON
                for path in [&traced_cfg.trace, &traced_cfg.metrics] {
                    let text = std::fs::read_to_string(path.as_ref().unwrap()).unwrap();
                    assert!(parse(&text).is_ok(), "{tag}: export must be valid JSON");
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Collect the category (`cat`) values of all X events on one rank's
/// track. Comm telemetry appears as `cat: "comm"` events rather than
/// spans, so this is exactly the five-category coverage check.
fn cats_on_rank(events: &[Json], rank: usize) -> HashSet<String> {
    events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
        .filter(|e| e.get("tid").and_then(Json::as_usize) == Some(rank))
        .filter_map(|e| e.get("cat").and_then(Json::as_str).map(str::to_string))
        .collect()
}

#[test]
fn trace_at_p4_covers_all_categories_on_every_rank() {
    let dir = obs_dir("coverage");
    let trace_path = dir.join("trace.json");
    let (source, ocfg) = test_setup(97);
    let mut cfg = DOpInfConfig::new(4, ocfg);
    cfg.cost_model = CostModel::shared_memory();
    cfg.chunk_rows = Some(7);
    cfg.probes = vec![(0, 5), (1, 90)];
    cfg.trace = Some(trace_path.clone());
    run_distributed(&cfg, &source).unwrap();

    let doc = parse(&std::fs::read_to_string(&trace_path).unwrap()).unwrap();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    for rank in 0..4 {
        let cats = cats_on_rank(events, rank);
        for want in ["load", "compute", "comm", "learn", "post"] {
            assert!(cats.contains(want), "rank {rank} missing category {want}: {cats:?}");
        }
    }
    // every X event is closed (has dur) and every comm event carries
    // the predicted-vs-actual overlay
    for e in events {
        if e.get("ph").and_then(Json::as_str) != Some("X") {
            continue;
        }
        assert!(e.get("dur").and_then(Json::as_f64).is_some(), "open span in export");
        if e.get("cat").and_then(Json::as_str) == Some("comm") {
            let args = e.get("args").expect("comm event without args");
            assert!(args.get("bytes").and_then(Json::as_f64).is_some());
            assert!(args.get("predicted_us").and_then(Json::as_f64).unwrap() >= 0.0);
            assert!(args.get("wait_us").and_then(Json::as_f64).unwrap() >= 0.0);
        }
    }
    // the streaming data plane's residency gauge made it through
    assert!(
        events.iter().any(|e| {
            e.get("ph").and_then(Json::as_str) == Some("C")
                && e.get("name").and_then(Json::as_str) == Some("peak_chunk_resident_bytes")
        }),
        "missing peak-residency gauge"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn metrics_categories_reconcile_with_run_timing() {
    let dir = obs_dir("reconcile");
    let metrics_path = dir.join("metrics.json");
    let (source, ocfg) = test_setup(97);
    let mut cfg = DOpInfConfig::new(4, ocfg);
    // a real α–β model so the overlay has nonzero predictions
    cfg.cost_model = CostModel::shared_memory();
    cfg.probes = vec![(0, 5)];
    cfg.metrics = Some(metrics_path.clone());
    let result = run_distributed(&cfg, &source).unwrap();

    let doc = parse(&std::fs::read_to_string(&metrics_path).unwrap()).unwrap();
    assert_eq!(doc.get("schema").and_then(Json::as_str), Some("dopinf-metrics-v1"));
    assert_eq!(doc.get("ranks").and_then(Json::as_usize), Some(4));

    // per-rank rows are the virtual-clock RunTiming verbatim (float
    // tolerance only for the JSON text roundtrip)
    let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * (1.0 + a.abs().max(b.abs()));
    let cats = doc.get("categories").unwrap();
    let per_rank = cats.get("per_rank").unwrap().as_arr().unwrap();
    assert_eq!(per_rank.len(), result.timing.per_rank.len());
    for (row, want) in per_rank.iter().zip(&result.timing.per_rank) {
        for (key, val) in [
            ("total", want.total),
            ("load", want.load),
            ("compute", want.compute),
            ("comm", want.comm),
            ("learn", want.learn),
            ("post", want.post),
        ] {
            let got = row.get(key).and_then(Json::as_f64).unwrap();
            assert!(close(got, val), "rank row {key}: {got} vs {val}");
        }
    }
    // totals are the column sums of those rows
    let totals = cats.get("totals").unwrap();
    let sum = |f: fn(&dopinf::coordinator::timing::RankTiming) -> f64| {
        result.timing.per_rank.iter().map(f).sum::<f64>()
    };
    assert!(close(totals.get("comm").and_then(Json::as_f64).unwrap(), sum(|r| r.comm)));
    assert!(close(totals.get("total").and_then(Json::as_f64).unwrap(), sum(|r| r.total)));

    // the comm table carries the predicted-vs-actual overlay: the
    // pipeline allreduces on every run, with calls, bytes, and a
    // nonzero α–β prediction feeding a finite ratio
    let ar = doc.get("comm").unwrap().get("allreduce").expect("allreduce row");
    assert!(ar.get("calls").and_then(Json::as_usize).unwrap() >= 4);
    assert!(ar.get("bytes").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(ar.get("predicted_s").and_then(Json::as_f64).unwrap() > 0.0);
    assert!(ar.get("ratio").and_then(Json::as_f64).unwrap().is_finite());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn aborted_run_still_flushes_a_parseable_partial_trace() {
    let dir = obs_dir("abort");
    let (source, ocfg) = test_setup(120);
    for p in [2usize, 4] {
        for transport in [Transport::Threads, Transport::Sockets] {
            let fail_rank = 1usize;
            let trace_path = dir.join(format!("abort_p{p}_{transport:?}.trace.json"));
            let mut cfg = DOpInfConfig::new(p, ocfg.clone());
            cfg.cost_model = CostModel::free();
            cfg.transport = transport;
            cfg.chunk_rows = Some(5);
            // bounded waits: a broken abort path fails instead of hanging
            cfg.comm_timeout = Some(60.0);
            cfg.trace = Some(trace_path.clone());
            let faulty = DataSource::Faulty {
                inner: Box::new(source.clone()),
                fault: FaultSpec { rank: fail_rank, after_chunks: 1 },
            };
            let err = run_distributed(&cfg, &faulty).unwrap_err();
            let tag = format!("p={p} {transport:?}");
            assert!(format!("{err:?}").contains("injected read fault"), "{tag}: {err:?}");

            // the partial trace was flushed before the error returned
            let text = std::fs::read_to_string(&trace_path)
                .unwrap_or_else(|e| panic!("{tag}: no trace flushed: {e}"));
            let doc = parse(&text).unwrap_or_else(|e| panic!("{tag}: invalid JSON: {e:?}"));
            let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
            // the originating rank got through one chunk before its
            // fault fired: its partial spans must be present
            let origin_spans = events
                .iter()
                .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
                .filter(|e| e.get("tid").and_then(Json::as_usize) == Some(fail_rank))
                .count();
            assert!(origin_spans >= 1, "{tag}: originating rank has no partial spans");
            // nothing is left open, comm records included: every X
            // event in the export carries a duration
            for e in events {
                if e.get("ph").and_then(Json::as_str) == Some("X") {
                    assert!(
                        e.get("dur").and_then(Json::as_f64).is_some(),
                        "{tag}: open span in aborted-run export"
                    );
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

//! Serving-layer integration: the full train → artifact → load →
//! batched-ensemble flow on real pipeline output, plus the
//! batched-vs-sequential rollout contract at integration scale.

use std::collections::BTreeMap;
use std::sync::Arc;

use dopinf::comm::CostModel;
use dopinf::coordinator::config::{DOpInfConfig, DataSource};
use dopinf::coordinator::pipeline::run_distributed;
use dopinf::linalg::Matrix;
use dopinf::opinf::serial::OpInfConfig;
use dopinf::rom::{solve_discrete, RegGrid};
use dopinf::runtime::Engine;
use dopinf::serve::{
    rollout_batch, run_ensemble, serve_ensemble, EnsembleSpec, RomArtifact, RomServer,
};
use dopinf::sim::synth::{generate, SynthSpec};

fn trained_artifact() -> (RomArtifact, dopinf::DOpInfResult) {
    let spec = SynthSpec { nx: 150, ns: 2, nt: 60, modes: 3, ..Default::default() };
    let q = generate(&spec, 0);
    let ocfg = OpInfConfig {
        ns: 2,
        energy_target: 0.999_999,
        r_override: None,
        scaling: false,
        grid: RegGrid::coarse(),
        max_growth: 1.5,
        nt_p: 120,
    };
    let mut cfg = DOpInfConfig::new(2, ocfg);
    cfg.cost_model = CostModel::free();
    cfg.probes = vec![(0, 10), (1, 140)];
    let result = run_distributed(&cfg, &DataSource::InMemory(Arc::new(q))).unwrap();

    let mut meta = BTreeMap::new();
    meta.insert("dataset".to_string(), "synth-150".to_string());
    let artifact = RomArtifact {
        ops: result.ops.clone(),
        qhat0: result.qhat0.clone(),
        probes: result.probe_bases.clone(),
        reg: Some(dopinf::serve::RegBlocks::from_problem(&result.problem)),
        meta,
    };
    (artifact, result)
}

#[test]
fn train_save_load_serve_end_to_end() {
    let (artifact, result) = trained_artifact();

    // save → load is bitwise on everything that matters
    let dir = std::env::temp_dir().join("dopinf_serve_integration");
    let path = dir.join("model.rom");
    artifact.save(&path).unwrap();
    let served = RomArtifact::load(&path).unwrap();
    assert_eq!(served.ops.ahat, artifact.ops.ahat);
    assert_eq!(served.ops.fhat, artifact.ops.fhat);
    assert_eq!(served.ops.chat, artifact.ops.chat);
    assert_eq!(served.qhat0, artifact.qhat0);
    assert_eq!(served.probes, artifact.probes);
    assert_eq!(served.meta.get("dataset").map(String::as_str), Some("synth-150"));
    // v2: the normal-equation blocks travel with the model, bitwise
    let (want_reg, got_reg) = (artifact.reg.as_ref().unwrap(), served.reg.as_ref().unwrap());
    assert_eq!(got_reg.dtd, want_reg.dtd);
    assert_eq!(got_reg.dtq2, want_reg.dtq2);

    // serve a small ensemble from the loaded artifact
    let spec = EnsembleSpec { members: 32, sigma: 0.01, seed: 3, n_steps: 120 };
    let stats = serve_ensemble(&Engine::native(), &served, &spec, 3).unwrap();
    assert_eq!(stats.members, 32);
    assert_eq!(stats.n_diverged(), 0, "a trained stable ROM must not diverge at sigma=1%");

    // the ensemble tracks the deterministic training-time prediction
    for (series, pred) in stats.probes.iter().zip(&result.probes) {
        assert_eq!((series.var, series.row), (pred.var, pred.row));
        for t in 0..120 {
            let err = (series.mean[t] - pred.values[t]).abs();
            let scale = pred.values[t].abs().max(1.0);
            assert!(err < 0.05 * scale, "t={t}: ensemble mean drifts {err}");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn batched_rollout_matches_sequential_on_trained_model() {
    let (artifact, _) = trained_artifact();
    let engine = Engine::native();
    // perturbed ICs around the trained model's anchor, B = 1..32
    for b in [1usize, 4, 16, 32] {
        let q0s = dopinf::serve::perturbed_initial_conditions(&artifact.qhat0, b, 0.02, b as u64);
        let batch = rollout_batch(&engine, &artifact.ops, &q0s, 120);
        for i in 0..b {
            let (nans, want) = solve_discrete(&artifact.ops, q0s.row(i), 120);
            assert!(!nans, "b={b} member {i}");
            let diff = batch.member_trajectory(i).max_abs_diff(&want);
            assert!(diff < 1e-12, "b={b} member {i}: diff {diff}");
        }
    }
}

#[test]
fn sharded_server_equals_local_ensemble() {
    let (artifact, _) = trained_artifact();
    let engine = Engine::native();
    let spec = EnsembleSpec { members: 40, sigma: 0.03, seed: 12, n_steps: 80 };
    let local = run_ensemble(&engine, &artifact, &spec).unwrap();
    let sharded = serve_ensemble(&engine, &artifact, &spec, 4).unwrap();
    assert_eq!(local.diverged_at, sharded.diverged_at);
    for (a, b) in local.probes.iter().zip(&sharded.probes) {
        assert_eq!(a.mean, b.mean);
        assert_eq!(a.variance, b.variance);
        assert_eq!(a.q05, b.q05);
        assert_eq!(a.q50, b.q50);
        assert_eq!(a.q95, b.q95);
        assert_eq!(a.count, b.count);
    }
}

#[test]
fn request_queue_matches_direct_evaluation() {
    let (artifact, _) = trained_artifact();
    let server = RomServer::start(artifact.clone(), 2);
    let specs: Vec<EnsembleSpec> = (0..4)
        .map(|i| EnsembleSpec { members: 8 + 4 * i, sigma: 0.02, seed: i as u64, n_steps: 50 })
        .collect();
    let tickets: Vec<_> = specs.iter().map(|s| server.submit(s.clone())).collect();
    let engine = Engine::native();
    for (spec, ticket) in specs.iter().zip(tickets) {
        let got = ticket.recv().unwrap().unwrap();
        let want = run_ensemble(&engine, &artifact, spec).unwrap();
        assert_eq!(got.members, want.members);
        for (a, b) in got.probes.iter().zip(&want.probes) {
            assert_eq!(a.mean, b.mean);
            assert_eq!(a.variance, b.variance);
        }
    }
    server.shutdown();
}

#[test]
fn reg_pair_ensemble_from_saved_v2_artifact() {
    // the CLI `ensemble --reg-ensemble` path: train → save v2 → load →
    // reg-pair ensemble from the persisted normal-equation blocks
    let (artifact, result) = trained_artifact();
    let dir = std::env::temp_dir().join("dopinf_serve_regens");
    let path = dir.join("model.rom");
    artifact.save(&path).unwrap();
    let served = RomArtifact::load(&path).unwrap();

    let pairs = RegGrid::coarse().pairs();
    let ens = dopinf::serve::run_reg_ensemble(&served, &pairs, 60).unwrap();
    assert_eq!(ens.pairs_used.len() + ens.skipped.len(), pairs.len());
    assert_eq!(ens.stats.members, ens.pairs_used.len());
    assert!(!ens.pairs_used.is_empty());
    assert_eq!(ens.stats.probes.len(), artifact.probes.len());

    // the training-time optimal pair is among the candidates
    assert!(pairs.contains(&result.opt_pair));
    // every reg model rolls from the same reference IC, so at step 0
    // the ensemble is degenerate: zero variance, quantiles collapsed
    // onto the deterministic training-time prediction
    let series = &ens.stats.probes[0];
    let pred0 = result.probes[0].values[0];
    assert_eq!(series.count[0], ens.stats.members);
    assert!(series.variance[0].abs() < 1e-20, "{}", series.variance[0]);
    assert!((series.mean[0] - pred0).abs() < 1e-9 * pred0.abs().max(1.0));
    assert_eq!(series.q05[0], series.q95[0]);
    // and the sweep genuinely spreads later on
    let k_last = 59;
    assert!(series.count[k_last] >= 1);
    assert!(series.q95[k_last] >= series.q05[k_last]);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupted_artifact_files_fail_loudly() {
    let (artifact, _) = trained_artifact();
    let dir = std::env::temp_dir().join("dopinf_serve_corrupt");
    std::fs::create_dir_all(&dir).unwrap();
    let bytes = artifact.to_bytes();

    // bit flip in the middle
    let mut flipped = bytes.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x10;
    std::fs::write(dir.join("flipped.rom"), &flipped).unwrap();
    assert!(RomArtifact::load(dir.join("flipped.rom")).is_err());

    // truncation
    std::fs::write(dir.join("short.rom"), &bytes[..bytes.len() / 3]).unwrap();
    assert!(RomArtifact::load(dir.join("short.rom")).is_err());

    // not an artifact at all
    std::fs::write(dir.join("junk.rom"), b"hello world, not a rom").unwrap();
    assert!(RomArtifact::load(dir.join("junk.rom")).is_err());

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn batch_is_deterministic_and_composition_independent() {
    // a member's trajectory must not depend on which batch it rides in
    let (artifact, _) = trained_artifact();
    let engine = Engine::native();
    let q0s = dopinf::serve::perturbed_initial_conditions(&artifact.qhat0, 24, 0.05, 99);
    let full = rollout_batch(&engine, &artifact.ops, &q0s, 60);
    let half = rollout_batch(&engine, &artifact.ops, &q0s.slice_rows(0, 12), 60);
    for i in 0..12 {
        assert_eq!(
            full.member_trajectory(i).data(),
            half.member_trajectory(i).data(),
            "member {i} depends on batch composition"
        );
    }
}

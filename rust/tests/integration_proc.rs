//! Process-transport integration suite: real `dopinf worker` OS
//! processes behind the same `Communicator` contract as the in-process
//! backends.
//!
//! Three halves:
//!
//! * **Happy path** — the collective exercise and the full
//!   `run_distributed` pipeline must be **bitwise identical** across
//!   worker processes vs rank threads (the job frame ships the exact
//!   config, every reduction funnels through the same rank-ordered
//!   fold), and a traced process run must ship every worker's spans
//!   back to the parent (one populated track per rank in the exported
//!   Chrome trace).
//! * **Fault injection** — SIGKILL a worker mid-collective at
//!   p ∈ {2, 4}: every surviving rank resolves with
//!   `CommError::Timeout` or `CommError::RemoteAbort` inside the
//!   configured deadline — zero hangs, zero panics. (CI wraps this
//!   binary in a hard `timeout` so a regression back to hanging fails
//!   the job instead of stalling it.)
//! * **Error plumbing** — a worker-rank read fault crosses the process
//!   boundary as the same origin-tagged `DOpInfError::RemoteAbort` the
//!   thread transport produces.
//!
//! Every test needs the built `dopinf` binary (this test executable
//! has no `worker` subcommand), located via `CARGO_BIN_EXE_dopinf` and
//! handed to the launcher through `DOPINF_WORKER_BIN`.

use std::time::{Duration, Instant};

use dopinf::comm::proc::{exercise_rank, run_exercise, ExerciseSpec, WorkerFailure};
use dopinf::comm::{self, Category, CommError, CostModel};
use dopinf::coordinator::config::{DOpInfConfig, DataSource, FaultKind, FaultPass, FaultSpec, Transport};
use dopinf::coordinator::pipeline::run_distributed;
use dopinf::error::DOpInfError;
use dopinf::io::partition::distribute_tutorial;
use dopinf::opinf::serial::OpInfConfig;
use dopinf::rom::RegGrid;
use dopinf::sim::synth::SynthSpec;
use dopinf::util::json::{parse, Json};

/// Point the launcher at the CLI binary Cargo built alongside this
/// test executable. Called by every test; setting the same value twice
/// is harmless (tests share the process environment).
fn arm_worker_binary() {
    std::env::set_var("DOPINF_WORKER_BIN", env!("CARGO_BIN_EXE_dopinf"));
}

fn exercise_spec(prim: &str, rounds: usize, pause_ms: u64) -> ExerciseSpec {
    ExerciseSpec { prim: prim.to_string(), len: 257, rounds, seed: 0xD0F1, pause_ms }
}

// ------------------------------------------------------- happy path

/// The mixed exercise (every primitive per round, rotating roots) over
/// real worker processes must produce the same digest, bit for bit, as
/// the thread transport at p ∈ {2, 4}.
#[test]
fn process_exercise_bitwise_matches_threads() {
    arm_worker_binary();
    for p in [2usize, 4] {
        let spec = exercise_spec("mixed", 3, 0);
        let want = comm::run(p, CostModel::free(), |ctx| exercise_rank(ctx, &spec).unwrap());
        let got = run_exercise(
            p,
            CostModel::free(),
            Some(Duration::from_secs(120)),
            &spec,
            |pids| assert_eq!(pids.len(), p - 1),
        )
        .expect("process launch");
        assert_eq!(got.len(), p);
        for (rank, ((outcome, _clock), reference)) in got.into_iter().zip(&want).enumerate() {
            let digest = outcome.unwrap_or_else(|e| panic!("p={p} rank {rank}: {e:?}"));
            assert_eq!(&digest, reference, "p={p} rank {rank} digest differs");
        }
    }
}

/// Worker virtual clocks cross the join frame: with a non-trivial cost
/// model, every rank of a process group — including the spawned ones —
/// reports a clock that actually advanced, with modeled comm charges.
/// (Clock totals also include measured thread CPU time, so exact
/// cross-run equality is deliberately not asserted.)
#[test]
fn process_clocks_cross_the_join_frame() {
    arm_worker_binary();
    let p = 3;
    let spec = exercise_spec("allreduce", 4, 0);
    let got = run_exercise(
        p,
        CostModel::shared_memory(),
        Some(Duration::from_secs(120)),
        &spec,
        |_| {},
    )
    .expect("process launch");
    assert_eq!(got.len(), p);
    for (rank, (outcome, clock)) in got.iter().enumerate() {
        assert!(outcome.is_ok(), "rank {rank}: {outcome:?}");
        assert!(clock.now() > 0.0, "rank {rank} clock never advanced");
        assert!(
            clock.in_category(Category::Comm) > 0.0,
            "rank {rank} clock is missing the modeled allreduce charges"
        );
    }
}

fn synth_setup(nx: usize, nt: usize) -> (DataSource, OpInfConfig) {
    let spec = SynthSpec { nx, ns: 2, nt, modes: 3, ..Default::default() };
    let ocfg = OpInfConfig {
        ns: 2,
        energy_target: 0.999_999,
        r_override: None,
        scaling: false,
        grid: RegGrid::coarse(),
        max_growth: 1.5,
        nt_p: 2 * nt,
    };
    (DataSource::Synthetic(spec), ocfg)
}

/// The acceptance gate: `run_distributed` over spawned worker
/// processes must produce a bitwise-identical `DOpInfResult` to the
/// thread transport at p = 4 (the job frame ships the exact config;
/// workers re-derive everything else deterministically).
#[test]
fn run_distributed_bitwise_identical_thread_vs_processes_p4() {
    arm_worker_binary();
    let (source, ocfg) = synth_setup(120, 60);
    let mut tcfg = DOpInfConfig::new(4, ocfg);
    tcfg.cost_model = CostModel::free();
    tcfg.probes = vec![(0, 17), (1, 95)];
    tcfg.comm_timeout = Some(120.0);
    let mut pcfg = tcfg.clone();
    pcfg.transport = Transport::Processes;

    let a = run_distributed(&tcfg, &source).unwrap();
    let b = run_distributed(&pcfg, &source).unwrap();

    assert_eq!(a.r, b.r);
    assert_eq!(a.eigs, b.eigs);
    assert_eq!(a.opt_pair, b.opt_pair);
    assert_eq!(a.winner_rank, b.winner_rank);
    assert_eq!(a.train_err.to_bits(), b.train_err.to_bits());
    assert_eq!(a.qtilde.data(), b.qtilde.data());
    assert_eq!(a.qhat0, b.qhat0);
    assert_eq!(a.ops.ahat, b.ops.ahat);
    assert_eq!(a.ops.fhat, b.ops.fhat);
    assert_eq!(a.ops.chat, b.ops.chat);
    for (pa, pb) in a.probes.iter().zip(&b.probes) {
        assert_eq!((pa.var, pa.row), (pb.var, pb.row));
        assert_eq!(pa.values, pb.values);
    }
}

/// A traced process run must ship every worker's spans back through
/// the join frame: the exported Chrome trace contains a populated
/// track (at least one duration event) for every rank, not just the
/// parent's rank 0.
#[test]
fn traced_process_run_exports_every_worker_track() {
    arm_worker_binary();
    let dir = std::env::temp_dir().join(format!("dopinf_proc_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let trace_path = dir.join("trace.json");

    let (source, ocfg) = synth_setup(96, 50);
    let mut cfg = DOpInfConfig::new(4, ocfg);
    cfg.cost_model = CostModel::free();
    cfg.transport = Transport::Processes;
    cfg.comm_timeout = Some(120.0);
    cfg.trace = Some(trace_path.clone());
    run_distributed(&cfg, &source).unwrap();

    let doc = parse(&std::fs::read_to_string(&trace_path).unwrap()).unwrap();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    for rank in 0..4usize {
        let spans = events
            .iter()
            .filter(|e| {
                e.get("tid").and_then(Json::as_usize) == Some(rank)
                    && e.get("ph").and_then(Json::as_str) == Some("X")
            })
            .count();
        assert!(spans > 0, "rank {rank} track is empty — worker trace never crossed the join");
    }
    std::fs::remove_dir_all(&dir).ok();
}

// -------------------------------------------------- fault injection

/// SIGKILL one worker right after spawn, while the group is held
/// mid-exercise by per-round pauses: every surviving rank must resolve
/// with `Timeout` or `RemoteAbort` inside the deadline — never hang.
#[test]
fn sigkilled_worker_never_hangs_the_group() {
    arm_worker_binary();
    for p in [2usize, 4] {
        let deadline = Duration::from_secs(10);
        // pauses keep every rank mid-exercise while the kill lands, so
        // no collective can complete before the failure is visible
        let spec = exercise_spec("mixed", 20, 100);
        let started = Instant::now();
        let results = run_exercise(p, CostModel::free(), Some(deadline), &spec, |pids| {
            assert_eq!(pids.len(), p - 1);
            // drop the highest worker rank mid-collective
            let victim = *pids.last().unwrap();
            let rc = unsafe { libc::kill(victim as libc::pid_t, libc::SIGKILL) };
            assert_eq!(rc, 0, "p={p}: SIGKILL of worker pid {victim} failed");
        })
        .expect("launch itself must succeed");
        let elapsed = started.elapsed();

        assert_eq!(results.len(), p);
        for (rank, (outcome, _clock)) in results.into_iter().enumerate() {
            match outcome {
                Err(WorkerFailure::Comm(
                    CommError::Timeout { .. } | CommError::RemoteAbort { .. },
                )) => {}
                other => panic!(
                    "p={p} rank {rank}: expected Timeout/RemoteAbort after SIGKILL, got {other:?}"
                ),
            }
        }
        // promptness: the deadline plus the reaper grace, with slack
        // for a loaded CI box — far below the exercise's unthrottled
        // runtime had the group hung until the harness timeout
        assert!(
            elapsed < deadline * 3,
            "p={p}: group took {elapsed:?} to resolve a SIGKILLed worker"
        );
    }
}

// ----------------------------------------------------- error plumbing

/// A read fault on a *worker* rank must cross the process boundary and
/// aggregate to the same origin-tagged `RemoteAbort` the thread
/// transport produces.
#[test]
fn worker_read_fault_is_an_origin_tagged_abort() {
    arm_worker_binary();
    let nx = 120;
    let chunk_rows = 7;
    let (source, mut ocfg) = synth_setup(nx, 60);
    // scaling on ⇒ pass 1 ends in an Allreduce(MAX): the failing worker
    // participates in a collective before its fault fires, parking the
    // parent rank in a collective when the abort lands
    ocfg.scaling = true;
    let fail_rank = 1;
    let mut cfg = DOpInfConfig::new(2, ocfg);
    cfg.cost_model = CostModel::free();
    cfg.transport = Transport::Processes;
    cfg.chunk_rows = Some(chunk_rows);
    cfg.comm_timeout = Some(60.0);
    // land the fault mid-pass-2, one chunk into the re-read
    let faulty = DataSource::Faulty {
        inner: Box::new(source),
        fault: FaultSpec {
            rank: fail_rank,
            after_chunks: 1,
            kind: FaultKind::Persistent,
            pass: FaultPass::Two,
        },
    };
    match run_distributed(&cfg, &faulty) {
        Err(DOpInfError::RemoteAbort { origin_rank, message }) => {
            assert_eq!(origin_rank, fail_rank);
            assert!(message.contains("injected read fault"), "{message}");
        }
        other => panic!("expected RemoteAbort from rank {fail_rank}, got {other:?}"),
    }
}

//! Serial ⇄ distributed ⇄ PJRT equivalence — the §III-code-parity row
//! of the DESIGN.md experiment index.
//!
//! The same dataset must yield the same ROM (r, optimal pair, reduced
//! trajectory, probe predictions) through:
//!   * the serial reference (paper's p=1 implementation),
//!   * the distributed pipeline at several p (native engine),
//!   * the distributed pipeline with the PJRT artifact engine.

use std::path::PathBuf;
use std::sync::Arc;

use dopinf::comm::CostModel;
use dopinf::coordinator::config::{DOpInfConfig, DataSource};
use dopinf::coordinator::pipeline::run_distributed;
use dopinf::linalg::Matrix;
use dopinf::opinf::serial::{self, OpInfConfig};
use dopinf::rom::RegGrid;
use dopinf::sim::synth::{generate, SynthSpec};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Synthetic dataset sized to the `tiny` artifact profile
/// (nt=24, rollout_steps=32) so the PJRT path engages end to end.
fn tiny_profile_setup() -> (Matrix, OpInfConfig) {
    // modes=3 -> centered rank 6, so r=5 keeps all used eigenvalues far
    // from the numerical-rank floor (ill-conditioned T_r would amplify
    // benign summation-order differences between p splits)
    let spec = SynthSpec { nx: 130, ns: 2, nt: 24, modes: 3, ..Default::default() };
    let q = generate(&spec, 0);
    let cfg = OpInfConfig {
        ns: 2,
        energy_target: 0.999_999,
        r_override: Some(5), // ≤ tiny r_max = 6
        scaling: false,
        grid: RegGrid::coarse(),
        max_growth: 2.0,
        nt_p: 32, // == tiny rollout_steps
    };
    (q, cfg)
}

#[test]
fn serial_vs_distributed_vs_pjrt() {
    let (q, ocfg) = tiny_profile_setup();
    let source = DataSource::InMemory(Arc::new(q.clone()));
    let serial_res = serial::run(q, &ocfg).unwrap();

    for (p, artifacts) in [(1, false), (2, false), (4, false), (2, true), (4, true)] {
        let mut cfg = DOpInfConfig::new(p, ocfg.clone());
        cfg.cost_model = CostModel::free();
        if artifacts {
            cfg.artifacts_dir = Some(artifacts_dir());
        }
        let dist = run_distributed(&cfg, &source).unwrap();
        let tag = format!("p={p} pjrt={artifacts}");
        assert_eq!(dist.r, serial_res.r, "{tag}");
        assert_eq!(dist.opt_pair, serial_res.opt_pair, "{tag}");
        let qdiff = dist.qtilde.max_abs_diff(&serial_res.qtilde);
        assert!(qdiff < 1e-7, "{tag}: trajectory diff {qdiff}");
        let ediff = (dist.train_err - serial_res.train_err).abs();
        assert!(ediff < 1e-8 + 1e-5 * serial_res.train_err, "{tag}: err diff {ediff}");
    }
}

#[test]
fn probe_predictions_agree_across_p() {
    let (q, ocfg) = tiny_profile_setup();
    let source = DataSource::InMemory(Arc::new(q));
    let probes = vec![(0usize, 3usize), (1, 64), (0, 129)];

    let mut reference: Option<Vec<Vec<f64>>> = None;
    for p in [1, 3, 4] {
        let mut cfg = DOpInfConfig::new(p, ocfg.clone());
        cfg.cost_model = CostModel::free();
        cfg.probes = probes.clone();
        let dist = run_distributed(&cfg, &source).unwrap();
        let values: Vec<Vec<f64>> = dist.probes.iter().map(|pr| pr.values.clone()).collect();
        match &reference {
            None => reference = Some(values),
            Some(want) => {
                for (k, (got, expect)) in values.iter().zip(want).enumerate() {
                    for (t, (a, b)) in got.iter().zip(expect).enumerate() {
                        assert!(
                            (a - b).abs() < 1e-8,
                            "p={p} probe {k} t={t}: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn scaling_toggle_changes_transform_not_quality() {
    let (q, mut ocfg) = tiny_profile_setup();
    let source = DataSource::InMemory(Arc::new(q));
    ocfg.scaling = true;
    let mut cfg = DOpInfConfig::new(2, ocfg);
    cfg.cost_model = CostModel::free();
    let dist = run_distributed(&cfg, &source).unwrap();
    // the scaled pipeline must still produce a valid, accurate ROM
    assert!(dist.train_err < 1e-2, "train err {}", dist.train_err);
    assert_eq!(dist.qtilde.rows(), dist.r);
}

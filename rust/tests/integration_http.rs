//! HTTP serving-tier integration: the coalescing bitwise contract at
//! sweep scale, and the full network path — raw `TcpStream` clients
//! against a live [`HttpServer`] — covering success, every error
//! status, deadlines, backpressure, hot-reload, and graceful drain.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use dopinf::opinf::postprocess::ProbeBasis;
use dopinf::rom::RomOperators;
use dopinf::runtime::Engine;
use dopinf::serve::http::coalesce::run_coalesced;
use dopinf::serve::http::{HttpConfig, HttpServer, ModelRegistry};
use dopinf::serve::{run_ensemble, EnsembleSpec, EnsembleStats, RomArtifact};
use dopinf::util::json::{parse, Json};

fn artifact(r: usize, seed: u64) -> RomArtifact {
    let probes = vec![
        ProbeBasis { var: 0, row: 3, phi: vec![1.0; r], mean: 0.5, scale: 2.0 },
        ProbeBasis {
            var: 1,
            row: 9,
            phi: (0..r).map(|j| 0.15 * (j as f64 - 1.5)).collect(),
            mean: -0.25,
            scale: 1.0,
        },
    ];
    RomArtifact {
        ops: RomOperators::stable_sample(r, seed),
        qhat0: (0..r).map(|j| 0.4 - 0.04 * j as f64).collect(),
        probes,
        reg: None,
        meta: BTreeMap::new(),
    }
}

fn assert_stats_bitwise(a: &EnsembleStats, b: &EnsembleStats) {
    assert_eq!(a.members, b.members);
    assert_eq!(a.n_steps, b.n_steps);
    assert_eq!(a.diverged_at, b.diverged_at);
    assert_eq!(a.probes.len(), b.probes.len());
    for (pa, pb) in a.probes.iter().zip(&b.probes) {
        assert_eq!((pa.var, pa.row), (pb.var, pb.row));
        assert_eq!(pa.mean, pb.mean);
        assert_eq!(pa.variance, pb.variance);
        assert_eq!(pa.q05, pb.q05);
        assert_eq!(pa.q50, pb.q50);
        assert_eq!(pa.q95, pb.q95);
        assert_eq!(pa.count, pb.count);
    }
}

/// The tentpole contract at sweep scale: N coalesced requests are
/// bitwise identical to the same N served sequentially, for
/// N ∈ {1, 3, 8} × members ∈ {1, 64}.
#[test]
fn coalescing_sweep_is_bitwise_identical_to_sequential() {
    let engine = Engine::native();
    let art = artifact(6, 17);
    for &n in &[1usize, 3, 8] {
        for &members in &[1usize, 64] {
            let specs: Vec<EnsembleSpec> = (0..n)
                .map(|i| EnsembleSpec {
                    members,
                    sigma: 0.01 + 0.005 * i as f64,
                    seed: 100 + i as u64,
                    n_steps: 60,
                })
                .collect();
            let fused = run_coalesced(&engine, &art, &specs).unwrap();
            assert_eq!(fused.len(), n);
            for (spec, got) in specs.iter().zip(&fused) {
                let solo = run_ensemble(&engine, &art, spec).unwrap();
                assert_stats_bitwise(got, &solo);
            }
        }
    }
}

// ------------------------------------------------------------ raw client

fn read_response<R: BufRead>(r: &mut R) -> (u16, Vec<(String, String)>, String) {
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("malformed status line {line:?}"))
        .parse()
        .unwrap();
    let mut headers = Vec::new();
    loop {
        let mut h = String::new();
        r.read_line(&mut h).unwrap();
        let t = h.trim_end();
        if t.is_empty() {
            break;
        }
        let (k, v) = t.split_once(':').unwrap();
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    let len: usize = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .map(|(_, v)| v.parse().unwrap())
        .unwrap_or(0);
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).unwrap();
    (status, headers, String::from_utf8(body).unwrap())
}

fn raw(addr: SocketAddr, bytes: &[u8]) -> (u16, Vec<(String, String)>, String) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    s.write_all(bytes).unwrap();
    read_response(&mut BufReader::new(s))
}

fn request(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
    let msg = match body {
        Some(b) => format!(
            "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{b}",
            b.len()
        ),
        None => format!("{method} {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n"),
    };
    let (status, _, resp) = raw(addr, msg.as_bytes());
    (status, resp)
}

fn server(cfg: HttpConfig, models: Vec<(&str, RomArtifact)>) -> HttpServer {
    let mut cfg = cfg;
    cfg.addr = "127.0.0.1:0".to_string();
    HttpServer::start(ModelRegistry::from_artifacts(models), cfg).unwrap()
}

fn json_f64s(doc: &Json, probe: usize, field: &str) -> Vec<f64> {
    doc.get("probes").unwrap().as_arr().unwrap()[probe]
        .get(field)
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect()
}

/// The wire format preserves the computed statistics bit for bit: the
/// emitter's shortest-roundtrip floats parse back to identical values,
/// extending the coalescing contract through HTTP.
#[test]
fn http_roundtrip_preserves_statistics_bitwise() {
    let art = artifact(5, 23);
    let spec = EnsembleSpec { members: 16, sigma: 0.02, seed: 41, n_steps: 50 };
    let solo = run_ensemble(&Engine::native(), &art, &spec).unwrap();

    let srv = server(HttpConfig::default(), vec![("m", artifact(5, 23))]);
    let addr = srv.local_addr();

    let (status, body) = request(
        addr,
        "POST",
        "/v1/ensemble",
        Some(r#"{"members": 16, "sigma": 0.02, "seed": 41, "steps": 50}"#),
    );
    assert_eq!(status, 200, "body: {body}");
    let doc = parse(&body).unwrap();
    assert_eq!(doc.get("model").unwrap().as_str().unwrap(), "m");
    assert_eq!(doc.get("members").unwrap().as_usize().unwrap(), 16);
    assert_eq!(doc.get("steps").unwrap().as_usize().unwrap(), 50);
    assert_eq!(doc.get("diverged").unwrap().as_usize().unwrap(), solo.n_diverged());
    for (i, probe) in solo.probes.iter().enumerate() {
        assert_eq!(json_f64s(&doc, i, "mean"), probe.mean, "probe {i} mean drifts on the wire");
        assert_eq!(json_f64s(&doc, i, "variance"), probe.variance);
        assert_eq!(json_f64s(&doc, i, "q05"), probe.q05);
        assert_eq!(json_f64s(&doc, i, "q50"), probe.q50);
        assert_eq!(json_f64s(&doc, i, "q95"), probe.q95);
    }

    // series: "last" collapses each series to its final scalar
    let (status, body) = request(
        addr,
        "POST",
        "/v1/ensemble",
        Some(r#"{"members": 16, "sigma": 0.02, "seed": 41, "steps": 50, "series": "last"}"#),
    );
    assert_eq!(status, 200);
    let doc = parse(&body).unwrap();
    let p0 = &doc.get("probes").unwrap().as_arr().unwrap()[0];
    assert_eq!(p0.get("mean").unwrap().as_f64().unwrap(), *solo.probes[0].mean.last().unwrap());

    // healthz + models while we're here
    let (status, body) = request(addr, "GET", "/healthz", None);
    assert_eq!(status, 200);
    assert_eq!(parse(&body).unwrap().get("status").unwrap().as_str().unwrap(), "ok");
    let (status, body) = request(addr, "GET", "/v1/models", None);
    assert_eq!(status, 200);
    let models = parse(&body).unwrap();
    let row = &models.get("models").unwrap().as_arr().unwrap()[0];
    assert_eq!(row.get("name").unwrap().as_str().unwrap(), "m");
    assert_eq!(row.get("r").unwrap().as_usize().unwrap(), 5);

    srv.join().unwrap();
}

#[test]
fn http_error_statuses_are_mapped() {
    let cfg = HttpConfig {
        limits: dopinf::serve::http::Limits { max_body: 4096, ..Default::default() },
        ..HttpConfig::default()
    };
    let srv = server(cfg, vec![("m", artifact(4, 7))]);
    let addr = srv.local_addr();

    // unknown route → 404
    let (status, _) = request(addr, "GET", "/nope", None);
    assert_eq!(status, 404);
    // wrong method on a known route → 405 + Allow
    let msg = "GET /v1/ensemble HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n";
    let (status, headers, _) = raw(addr, msg.as_bytes());
    assert_eq!(status, 405);
    assert!(headers.iter().any(|(k, v)| k == "allow" && v == "POST"));
    // malformed JSON → 400
    let (status, _) = request(addr, "POST", "/v1/ensemble", Some("{not json"));
    assert_eq!(status, 400);
    // unknown field → 400 (typos must not silently run defaults)
    let (status, body) = request(addr, "POST", "/v1/ensemble", Some(r#"{"member": 4}"#));
    assert_eq!(status, 400);
    assert!(body.contains("member"), "the reason names the bad field: {body}");
    // unknown model → 404
    let (status, _) =
        request(addr, "POST", "/v1/ensemble", Some(r#"{"model": "ghost", "members": 1}"#));
    assert_eq!(status, 404);
    // reload of a memory-backed model → 400
    let (status, _) = request(addr, "POST", "/v1/models/m/reload", None);
    assert_eq!(status, 400);
    // reload of an unknown model → 404
    let (status, _) = request(addr, "POST", "/v1/models/ghost/reload", None);
    assert_eq!(status, 404);
    // oversized declared body → 413, before the body is read
    let msg = "POST /v1/ensemble HTTP/1.1\r\nHost: t\r\nContent-Length: 999999999\r\n\r\n";
    let (status, _, _) = raw(addr, msg.as_bytes());
    assert_eq!(status, 413);
    // malformed request line → 400
    let (status, _, _) = raw(addr, b"TOTAL GARBAGE\r\n\r\n");
    assert_eq!(status, 400);
    // POST without a Content-Length → 411
    let msg = "POST /v1/ensemble HTTP/1.1\r\nHost: t\r\n\r\n";
    let (status, _, _) = raw(addr, msg.as_bytes());
    assert_eq!(status, 411);

    // after all that abuse, the server still serves
    let (status, _) = request(addr, "POST", "/v1/ensemble", Some(r#"{"members": 2, "steps": 5}"#));
    assert_eq!(status, 200);
    let final_metrics = srv.join().unwrap();
    let http = final_metrics.get("http").unwrap();
    assert!(http.get("responses_4xx").unwrap().as_usize().unwrap() >= 8);
}

/// A stuck evaluation answers 504 at its deadline while the queue keeps
/// serving other requests.
#[test]
fn deadline_maps_to_504_and_queue_stays_serviceable() {
    let cfg = HttpConfig { workers: 1, ..HttpConfig::default() };
    let srv = server(cfg, vec![("m", artifact(6, 3))]);
    let addr = srv.local_addr();

    // the slow request occupies the only worker well past its deadline
    let slow = std::thread::spawn(move || {
        request(
            addr,
            "POST",
            "/v1/ensemble",
            Some(r#"{"members": 8, "steps": 300000, "timeout_ms": 100}"#),
        )
    });
    // a healthy request queued behind it: must complete once the worker
    // frees up, well within its own generous deadline
    let fast = std::thread::spawn(move || {
        request(
            addr,
            "POST",
            "/v1/ensemble",
            Some(r#"{"members": 2, "steps": 10, "timeout_ms": 110000}"#),
        )
    });
    let (slow_status, _) = slow.join().unwrap();
    assert_eq!(slow_status, 504, "the stuck request answers at its deadline");
    let (fast_status, _) = fast.join().unwrap();
    assert_eq!(fast_status, 200, "the queue stays serviceable past a stuck job");

    let final_metrics = srv.join().unwrap();
    assert!(final_metrics.get("http").unwrap().get("deadline_504").unwrap().as_usize().unwrap() >= 1);
}

#[test]
fn queue_full_answers_503_with_retry_after() {
    let cfg = HttpConfig { workers: 1, max_queue: 1, ..HttpConfig::default() };
    let srv = server(cfg, vec![("m", artifact(6, 3))]);
    let addr = srv.local_addr();

    // A occupies the worker, B fills the queue slot of 1
    let occupy = std::thread::spawn(move || {
        request(addr, "POST", "/v1/ensemble", Some(r#"{"members": 8, "steps": 200000}"#))
    });
    std::thread::sleep(Duration::from_millis(300)); // A dequeued
    let queued = std::thread::spawn(move || {
        request(addr, "POST", "/v1/ensemble", Some(r#"{"members": 2, "steps": 10}"#))
    });
    std::thread::sleep(Duration::from_millis(300)); // B parked in the queue

    let msg = format!(
        "POST /v1/ensemble HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        r#"{"members": 1, "steps": 5}"#.len(),
        r#"{"members": 1, "steps": 5}"#
    );
    let (status, headers, _) = raw(addr, msg.as_bytes());
    assert_eq!(status, 503, "a full queue refuses rather than buffering unboundedly");
    assert!(headers.iter().any(|(k, _)| k == "retry-after"));

    assert_eq!(occupy.join().unwrap().0, 200);
    assert_eq!(queued.join().unwrap().0, 200);
    let final_metrics = srv.join().unwrap();
    assert!(final_metrics.get("http").unwrap().get("rejected_503").unwrap().as_usize().unwrap() >= 1);
}

/// Hot-reload swaps the artifact atomically: the in-flight request
/// finishes on the artifact it was admitted against, requests admitted
/// after the swap see the new one — both verified bitwise.
#[test]
fn hot_reload_swaps_without_failing_in_flight_requests() {
    let dir = std::env::temp_dir().join(format!("dopinf_http_reload_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.rom");
    let old_art = artifact(5, 31);
    let new_art = artifact(5, 77); // same r, different operators
    old_art.save(&path).unwrap();

    let cfg = HttpConfig {
        workers: 1,
        addr: "127.0.0.1:0".to_string(),
        ..HttpConfig::default()
    };
    let registry = ModelRegistry::open(&[("m".to_string(), path.clone())]).unwrap();
    let srv = HttpServer::start(registry, cfg).unwrap();
    let addr = srv.local_addr();

    // in-flight slow request, admitted against the old artifact
    let inflight = std::thread::spawn(move || {
        request(
            addr,
            "POST",
            "/v1/ensemble",
            Some(r#"{"members": 8, "sigma": 0.02, "seed": 5, "steps": 150000, "series": "last"}"#),
        )
    });
    std::thread::sleep(Duration::from_millis(200)); // let it be admitted + dequeued

    new_art.save(&path).unwrap();
    let (status, body) = request(addr, "POST", "/v1/models/m/reload", None);
    assert_eq!(status, 200, "reload: {body}");
    let rep = parse(&body).unwrap();
    assert_eq!(rep.get("generation").unwrap().as_usize().unwrap(), 2);

    // the in-flight request completed on the OLD artifact, bitwise
    let (status, body) = inflight.join().unwrap();
    assert_eq!(status, 200, "in-flight request must not fail across a reload: {body}");
    let spec = EnsembleSpec { members: 8, sigma: 0.02, seed: 5, n_steps: 150_000 };
    let old_solo = run_ensemble(&Engine::native(), &old_art, &spec).unwrap();
    let doc = parse(&body).unwrap();
    let got = doc.get("probes").unwrap().as_arr().unwrap()[0].get("mean").unwrap().as_f64();
    assert_eq!(got, Some(*old_solo.probes[0].mean.last().unwrap()));

    // a post-reload request serves the NEW artifact, bitwise
    let (status, body) = request(
        addr,
        "POST",
        "/v1/ensemble",
        Some(r#"{"members": 4, "sigma": 0.01, "seed": 9, "steps": 40, "series": "last"}"#),
    );
    assert_eq!(status, 200);
    let spec = EnsembleSpec { members: 4, sigma: 0.01, seed: 9, n_steps: 40 };
    let new_solo = run_ensemble(&Engine::native(), &new_art, &spec).unwrap();
    let doc = parse(&body).unwrap();
    let got = doc.get("probes").unwrap().as_arr().unwrap()[0].get("mean").unwrap().as_f64();
    assert_eq!(got, Some(*new_solo.probes[0].mean.last().unwrap()));

    srv.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

/// Graceful shutdown: every admitted request is answered, the final
/// metrics snapshot is flushed, and the port stops accepting.
#[test]
fn shutdown_drains_all_in_flight_requests() {
    let dir = std::env::temp_dir().join(format!("dopinf_http_drain_{}", std::process::id()));
    let metrics_path = dir.join("final_metrics.json");
    let cfg = HttpConfig {
        workers: 1,
        admin_shutdown: true,
        metrics_path: Some(metrics_path.clone()),
        ..HttpConfig::default()
    };
    let srv = server(cfg, vec![("m", artifact(6, 3))]);
    let addr = srv.local_addr();

    // three requests: one in-flight on the worker, two parked in the queue
    let clients: Vec<_> = (0..3)
        .map(|i| {
            std::thread::spawn(move || {
                let body = format!(r#"{{"members": 4, "seed": {i}, "steps": 40000}}"#);
                request(addr, "POST", "/v1/ensemble", Some(&body))
            })
        })
        .collect();
    std::thread::sleep(Duration::from_millis(300)); // all three admitted

    let (status, body) = request(addr, "POST", "/admin/shutdown", None);
    assert_eq!(status, 200);
    assert_eq!(parse(&body).unwrap().get("status").unwrap().as_str().unwrap(), "shutting down");

    // every admitted request completes despite the shutdown
    for c in clients {
        let (status, body) = c.join().unwrap();
        assert_eq!(status, 200, "admitted request dropped during drain: {body}");
    }
    let final_metrics = srv.join().unwrap();
    let served = final_metrics
        .get("models")
        .and_then(|m| m.get("m"))
        .and_then(|m| m.get("requests"))
        .and_then(Json::as_usize)
        .unwrap();
    assert_eq!(served, 3, "all three ensemble requests recorded");

    // the final snapshot was flushed and parses
    let flushed = parse(&std::fs::read_to_string(&metrics_path).unwrap()).unwrap();
    assert_eq!(flushed.get("schema").unwrap().as_str().unwrap(), "dopinf-serve-http-v1");
    assert_eq!(
        flushed.get("models").unwrap().get("m").unwrap().get("requests").unwrap().as_usize(),
        Some(3)
    );

    // the listener is gone: connecting now fails, or the socket closes
    // without ever answering
    match TcpStream::connect(addr) {
        Err(_) => {}
        Ok(mut s) => {
            s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
            let _ = s.write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
            let mut buf = Vec::new();
            let n = s.read_to_end(&mut buf).unwrap_or(0);
            assert_eq!(n, 0, "a drained server must not answer new requests");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn keep_alive_serves_pipelined_clients() {
    let srv = server(HttpConfig::default(), vec![("m", artifact(4, 7))]);
    let addr = srv.local_addr();
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let body = r#"{"members": 2, "steps": 8, "series": "last"}"#;
    let one = format!(
        "POST /v1/ensemble HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    // two requests up front on one connection, then read two responses
    s.write_all(format!("{one}{one}").as_bytes()).unwrap();
    let mut r = BufReader::new(s);
    let (s1, _, b1) = read_response(&mut r);
    let (s2, _, b2) = read_response(&mut r);
    assert_eq!((s1, s2), (200, 200));
    assert_eq!(b1, b2, "identical pipelined requests get identical answers");
    drop(r);
    srv.join().unwrap();
}

//! Transport-equivalence property tests (util::propcheck) and the
//! error-propagation suite of the fallible-collectives contract.
//!
//! Two halves:
//!
//! * **Happy path** — every backend combines contributions in rank
//!   order through the shared `fold` kernels, so collective results
//!   must be **bitwise identical** — across the thread, socket, and
//!   hierarchical two-level transports (p ∈ {1, 2, 4, 8} × nodes ∈
//!   {1, 2, 4}), against the rank-ordered reference fold, and (for
//!   partition-invariant collectives like gather) across
//!   p ∈ {1, 2, 4, 7} as well. `run_distributed` must produce a
//!   bitwise-identical `DOpInfResult` on threads vs sockets (p = 4)
//!   and on threads vs hier at every node shape (p = 8). These suites
//!   predate the fallible API redesign and pass unchanged — the
//!   redesign's byte-identity guarantee. (The process transport's
//!   equivalence suite lives in `tests/integration_proc.rs`, which
//!   needs the built `dopinf` binary.)
//! * **Error path** — a mid-pass-2 read fault on any single rank must
//!   resolve *every* rank promptly: siblings wake from their parked
//!   collectives with a rank-tagged `CommError::RemoteAbort`, and
//!   `run_distributed` returns `DOpInfError::RemoteAbort` carrying the
//!   originating rank — zero hangs, zero panics, on both transports at
//!   p ∈ {2, 4}. (CI wraps this test binary in a hard `timeout`, so a
//!   regression back to hanging fails the job instead of stalling it.)

use std::sync::Arc;

use dopinf::comm::{self, fold, CommError, Communicator, CostModel, Op, SelfComm, TwoLevelModel};
use dopinf::coordinator::config::{DOpInfConfig, DataSource, FaultSpec, Transport};
use dopinf::coordinator::pipeline::run_distributed;
use dopinf::error::DOpInfError;
use dopinf::io::partition::{distribute_balanced, distribute_tutorial};
use dopinf::opinf::serial::OpInfConfig;
use dopinf::rom::RegGrid;
use dopinf::sim::synth::{generate, SynthSpec};
use dopinf::util::propcheck::{check, Config};
use dopinf::util::rng::Rng;

const PS: [usize; 4] = [1, 2, 4, 7];

/// Deterministic per-rank payload: depends only on (seed, rank), so
/// every backend run regenerates identical contributions.
fn rank_data(seed: u64, rank: usize, len: usize) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ ((rank as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)));
    (0..len).map(|_| rng.normal() * 8.0 + 0.125).collect()
}

#[test]
fn allreduce_bitwise_identical_across_backends() {
    check(
        Config { cases: 8, seed: 41 },
        |rng| (1 + rng.below(48) as usize, rng.below(1 << 30)),
        |&(len, seed)| {
            for p in PS {
                for op in [Op::Sum, Op::Max, Op::Min] {
                    let parts: Vec<Vec<f64>> = (0..p).map(|r| rank_data(seed, r, len)).collect();
                    let want = fold::reduce_parts(&parts, op);
                    let threads = comm::run(p, CostModel::free(), |ctx| {
                        ctx.allreduce(&rank_data(seed, ctx.rank(), len), op).unwrap()
                    });
                    let sockets = comm::socket::run(p, CostModel::free(), |ctx| {
                        ctx.allreduce(&rank_data(seed, ctx.rank(), len), op).unwrap()
                    })
                    .expect("socket rendezvous");
                    for r in 0..p {
                        if threads[r] != want {
                            return Err(format!("thread backend differs at p={p} rank {r}"));
                        }
                        if sockets[r] != want {
                            return Err(format!("socket backend differs at p={p} rank {r}"));
                        }
                    }
                    if p == 1 {
                        // SelfComm is the p=1 reference: identity
                        let mut ctx = SelfComm::new();
                        let got = ctx.allreduce(&parts[0], op).unwrap();
                        if got != parts[0] {
                            return Err("SelfComm must be the identity".into());
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn gather_reconstructs_the_partitioned_vector_for_every_p() {
    // gather of a balanced partition must reproduce the global vector
    // bit for bit — for every p (partition-invariance) and on both
    // transports, landing on the root alone
    check(
        Config { cases: 8, seed: 77 },
        |rng| (7 + rng.below(200) as usize, rng.below(1 << 30)),
        |&(n, seed)| {
            let global = rank_data(seed, 0, n);
            for p in PS {
                let shards = distribute_balanced(n, p);
                let root = p - 1;
                let run_gather = |results: Vec<Option<Vec<Vec<f64>>>>| -> Result<(), String> {
                    for (rank, out) in results.iter().enumerate() {
                        if rank == root {
                            let got = out.clone().ok_or(format!("p={p}: root got None"))?;
                            if got.concat() != global {
                                return Err(format!("p={p}: gathered vector differs"));
                            }
                        } else if out.is_some() {
                            return Err(format!("p={p}: non-root rank {rank} received data"));
                        }
                    }
                    Ok(())
                };
                run_gather(comm::run(p, CostModel::free(), |ctx| {
                    let sh = shards[ctx.rank()];
                    ctx.gather(root, &global[sh.start..sh.end]).unwrap()
                }))?;
                run_gather(
                    comm::socket::run(p, CostModel::free(), |ctx| {
                        let sh = shards[ctx.rank()];
                        ctx.gather(root, &global[sh.start..sh.end]).unwrap()
                    })
                    .expect("socket rendezvous"),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn reduce_scatter_block_bitwise_thread_vs_socket() {
    check(
        Config { cases: 8, seed: 5 },
        |rng| (1 + rng.below(24) as usize, rng.below(1 << 30)),
        |&(chunk, seed)| {
            for p in PS {
                let len = chunk * p;
                let parts: Vec<Vec<f64>> = (0..p).map(|r| rank_data(seed, r, len)).collect();
                let reduced = fold::reduce_parts(&parts, Op::Sum);
                let threads = comm::run(p, CostModel::free(), |ctx| {
                    ctx.reduce_scatter_block(&rank_data(seed, ctx.rank(), len), Op::Sum).unwrap()
                });
                let sockets = comm::socket::run(p, CostModel::free(), |ctx| {
                    ctx.reduce_scatter_block(&rank_data(seed, ctx.rank(), len), Op::Sum).unwrap()
                })
                .expect("socket rendezvous");
                for r in 0..p {
                    let want = fold::block(&reduced, r, p);
                    if threads[r] != want {
                        return Err(format!("thread backend differs at p={p} rank {r}"));
                    }
                    if sockets[r] != want {
                        return Err(format!("socket backend differs at p={p} rank {r}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn rooted_reduce_bitwise_equals_allreduce_on_root() {
    check(
        Config { cases: 6, seed: 913 },
        |rng| (1 + rng.below(40) as usize, rng.below(1 << 30)),
        |&(len, seed)| {
            for p in PS {
                let root = p / 2;
                let reduced = comm::run(p, CostModel::free(), |ctx| {
                    let mine = rank_data(seed, ctx.rank(), len);
                    (ctx.reduce(root, &mine, Op::Sum).unwrap(), ctx.allreduce(&mine, Op::Sum).unwrap())
                });
                for (rank, (rooted, all)) in reduced.iter().enumerate() {
                    if rank == root {
                        if rooted.as_ref() != Some(all) {
                            return Err(format!("p={p}: reduce != allreduce on root"));
                        }
                    } else if rooted.is_some() {
                        return Err(format!("p={p}: non-root {rank} received reduction"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// The node shapes a hier sweep visits for a given p: every node count
/// in {1, 2, 4} that fits (nodes ≤ p).
fn node_shapes(p: usize) -> impl Iterator<Item = usize> {
    [1usize, 2, 4].into_iter().filter(move |&n| n <= p)
}

/// Hierarchical collectives must be bitwise identical to the flat
/// rank-ordered reference fold — across p ∈ {1, 2, 4, 8} × nodes ∈
/// {1, 2, 4}: the local-fold → leader-tree → local-broadcast schedule
/// ships raw rank-tagged parts so the fold happens once, in rank
/// order, exactly like the flat transports.
#[test]
fn hier_collectives_bitwise_identical_across_node_shapes() {
    check(
        Config { cases: 6, seed: 271 },
        |rng| (1 + rng.below(40) as usize, rng.below(1 << 30)),
        |&(len, seed)| {
            for p in [1usize, 2, 4, 8] {
                for nodes in node_shapes(p) {
                    for op in [Op::Sum, Op::Max, Op::Min] {
                        let parts: Vec<Vec<f64>> =
                            (0..p).map(|r| rank_data(seed, r, len)).collect();
                        let want = fold::reduce_parts(&parts, op);
                        let got = comm::hier::run(p, nodes, TwoLevelModel::free(), |ctx| {
                            ctx.allreduce(&rank_data(seed, ctx.rank(), len), op).unwrap()
                        });
                        for r in 0..p {
                            if got[r] != want {
                                return Err(format!(
                                    "hier differs at p={p} nodes={nodes} rank {r} op={op:?}"
                                ));
                            }
                        }
                    }
                    // reduce_scatter through the two levels: each rank's
                    // block of the rank-ordered reduction
                    let len_rs = len.div_ceil(p).max(1) * p;
                    let parts: Vec<Vec<f64>> =
                        (0..p).map(|r| rank_data(seed, r, len_rs)).collect();
                    let reduced = fold::reduce_parts(&parts, Op::Sum);
                    let got = comm::hier::run(p, nodes, TwoLevelModel::free(), |ctx| {
                        ctx.reduce_scatter_block(&rank_data(seed, ctx.rank(), len_rs), Op::Sum)
                            .unwrap()
                    });
                    for r in 0..p {
                        if got[r] != fold::block(&reduced, r, p) {
                            return Err(format!(
                                "hier reduce_scatter differs at p={p} nodes={nodes} rank {r}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

fn tutorial_config(nx: usize) -> (DataSource, OpInfConfig) {
    let spec = SynthSpec { nx, ns: 2, nt: 60, modes: 3, ..Default::default() };
    let q = generate(&spec, 0);
    let ocfg = OpInfConfig {
        ns: 2,
        energy_target: 0.999_999,
        r_override: None,
        scaling: false,
        grid: RegGrid::coarse(),
        max_growth: 1.5,
        nt_p: 120,
    };
    (DataSource::InMemory(Arc::new(q)), ocfg)
}

/// The acceptance gate: `run_distributed` at p = 4 on the tutorial-style
/// config must produce a bitwise-identical `DOpInfResult` on the thread
/// vs socket transports.
#[test]
fn run_distributed_bitwise_identical_thread_vs_socket_p4() {
    let (source, ocfg) = tutorial_config(180);
    let mut tcfg = DOpInfConfig::new(4, ocfg);
    tcfg.cost_model = CostModel::free();
    tcfg.probes = vec![(0, 17), (1, 95), (0, 179)];
    let mut scfg = tcfg.clone();
    scfg.transport = Transport::Sockets;

    let a = run_distributed(&tcfg, &source).unwrap();
    let b = run_distributed(&scfg, &source).unwrap();

    assert_eq!(a.r, b.r);
    assert_eq!(a.eigs, b.eigs);
    assert_eq!(a.retained_energy, b.retained_energy);
    assert_eq!(a.opt_pair, b.opt_pair);
    assert_eq!(a.winner_rank, b.winner_rank);
    assert_eq!(a.train_err.to_bits(), b.train_err.to_bits());
    assert_eq!(a.qtilde.data(), b.qtilde.data());
    assert_eq!(a.qhat0, b.qhat0);
    assert_eq!(a.ops.ahat, b.ops.ahat);
    assert_eq!(a.ops.fhat, b.ops.fhat);
    assert_eq!(a.ops.chat, b.ops.chat);
    for (pa, pb) in a.probes.iter().zip(&b.probes) {
        assert_eq!((pa.var, pa.row), (pb.var, pb.row));
        assert_eq!(pa.values, pb.values);
    }
    for (ba, bb) in a.probe_bases.iter().zip(&b.probe_bases) {
        assert_eq!(ba.phi, bb.phi);
        assert_eq!(ba.mean.to_bits(), bb.mean.to_bits());
        assert_eq!(ba.scale.to_bits(), bb.scale.to_bits());
    }
}

/// The hier acceptance gate: `run_distributed` over the two-level
/// transport must produce a bitwise-identical `DOpInfResult` to the
/// flat thread transport — at p = 8 across every node shape.
#[test]
fn run_distributed_bitwise_identical_thread_vs_hier_p8() {
    let (source, ocfg) = tutorial_config(180);
    let mut tcfg = DOpInfConfig::new(8, ocfg);
    tcfg.cost_model = CostModel::free();
    tcfg.allow_oversubscribe = true; // 8 rank threads on a small CI box
    tcfg.probes = vec![(0, 17), (1, 95), (0, 179)];
    let a = run_distributed(&tcfg, &source).unwrap();
    for nodes in node_shapes(8) {
        let mut hcfg = tcfg.clone();
        hcfg.transport = Transport::Hier;
        hcfg.nodes = nodes;
        let b = run_distributed(&hcfg, &source).unwrap();
        assert_eq!(a.r, b.r, "nodes={nodes}");
        assert_eq!(a.eigs, b.eigs, "nodes={nodes}");
        assert_eq!(a.retained_energy, b.retained_energy, "nodes={nodes}");
        assert_eq!(a.opt_pair, b.opt_pair, "nodes={nodes}");
        assert_eq!(a.winner_rank, b.winner_rank, "nodes={nodes}");
        assert_eq!(a.train_err.to_bits(), b.train_err.to_bits(), "nodes={nodes}");
        assert_eq!(a.qtilde.data(), b.qtilde.data(), "nodes={nodes}");
        assert_eq!(a.qhat0, b.qhat0, "nodes={nodes}");
        assert_eq!(a.ops.ahat, b.ops.ahat, "nodes={nodes}");
        assert_eq!(a.ops.fhat, b.ops.fhat, "nodes={nodes}");
        assert_eq!(a.ops.chat, b.ops.chat, "nodes={nodes}");
        for (pa, pb) in a.probes.iter().zip(&b.probes) {
            assert_eq!(pa.values, pb.values, "nodes={nodes}");
        }
    }
}

// ------------------------------------------------------ error paths

/// Every rank of a group with one aborting member must return a
/// rank-tagged `RemoteAbort` — observed per rank, on both transports,
/// at p ∈ {2, 4}.
#[test]
fn abort_reaches_every_rank_on_both_transports() {
    for p in [2usize, 4] {
        let fail_rank = p - 1;
        let check_all = |results: Vec<Result<(), CommError>>| {
            assert_eq!(results.len(), p);
            for (rank, r) in results.iter().enumerate() {
                match r {
                    Err(CommError::RemoteAbort { origin_rank, message }) => {
                        assert_eq!(*origin_rank, fail_rank, "p={p} rank {rank}");
                        assert!(message.contains("simulated EIO"), "{message}");
                    }
                    other => panic!("p={p} rank {rank}: expected RemoteAbort, got {other:?}"),
                }
            }
        };
        check_all(comm::run(p, CostModel::free(), |ctx| {
            if ctx.rank() == fail_rank {
                Err(ctx.abort("simulated EIO"))
            } else {
                // two rounds: whichever collective the abort lands in,
                // the rank must come back with an error, promptly
                ctx.allreduce_scalar(1.0, Op::Sum).and_then(|_| ctx.barrier())
            }
        }));
        check_all(
            comm::socket::run(p, CostModel::free(), |ctx| {
                if ctx.rank() == fail_rank {
                    Err(ctx.abort("simulated EIO"))
                } else {
                    ctx.allreduce_scalar(1.0, Op::Sum).and_then(|_| ctx.barrier())
                }
            })
            .expect("socket rendezvous"),
        );
        // two-level topology: the abort must cross node boundaries —
        // out of the failing rank's node board, through the leader
        // layer, into every other node's board
        for nodes in node_shapes(p).filter(|&n| n > 1) {
            check_all(comm::hier::run(p, nodes, TwoLevelModel::free(), |ctx| {
                if ctx.rank() == fail_rank {
                    Err(ctx.abort("simulated EIO"))
                } else {
                    ctx.allreduce_scalar(1.0, Op::Sum).and_then(|_| ctx.barrier())
                }
            }));
        }
    }
}

/// The acceptance criterion of the redesign: a mid-pass-2 read error on
/// any single rank causes `run_distributed` to return an origin-tagged
/// `DOpInfError::RemoteAbort` — zero hangs, zero panics — for
/// p ∈ {2, 4} on both transports.
#[test]
fn read_fault_resolves_run_distributed_on_both_transports() {
    let nx = 120;
    let chunk_rows = 7;
    let (source, mut ocfg) = tutorial_config(nx);
    // scaling on ⇒ pass 1 ends in an Allreduce(MAX): the failing rank
    // participates in a collective *before* its fault fires, the exact
    // "sibling ranks park at the next collective" scenario
    ocfg.scaling = true;
    for p in [2usize, 4] {
        for transport in [Transport::Threads, Transport::Sockets, Transport::Hier] {
            let fail_rank = p / 2;
            // land the fault mid-pass-2: past one full pass of chunks,
            // short of two
            let per = distribute_tutorial(nx, p)[fail_rank].len();
            let chunks_per_pass = (2 * per).div_ceil(chunk_rows);
            let fault = FaultSpec { rank: fail_rank, after_chunks: chunks_per_pass + 1 };

            let mut cfg = DOpInfConfig::new(p, ocfg.clone());
            cfg.cost_model = CostModel::free();
            cfg.transport = transport;
            if transport == Transport::Hier {
                cfg.nodes = 2;
            }
            cfg.chunk_rows = Some(chunk_rows);
            // the suite's own hang-regression guard: every collective
            // wait is bounded, so a broken abort broadcast fails the
            // test instead of freezing it (CI adds a hard job timeout
            // on top)
            cfg.comm_timeout = Some(60.0);
            let faulty =
                DataSource::Faulty { inner: Box::new(source.clone()), fault };

            match run_distributed(&cfg, &faulty) {
                Err(DOpInfError::RemoteAbort { origin_rank, message }) => {
                    assert_eq!(origin_rank, fail_rank, "p={p} {transport:?}");
                    assert!(
                        message.contains("injected read fault"),
                        "p={p} {transport:?}: {message}"
                    );
                }
                other => {
                    panic!("p={p} {transport:?}: expected RemoteAbort, got {other:?}")
                }
            }
        }
    }
}

/// A rank that silently stops participating (no abort, no panic) must
/// resolve as a timeout when a deadline is configured — not a hang.
#[test]
fn silent_rank_resolves_as_timeout_with_deadline() {
    let results = comm::run_with_clocks_timeout(
        3,
        CostModel::free(),
        Some(std::time::Duration::from_millis(200)),
        |ctx| {
            if ctx.rank() == 1 {
                Ok(()) // never enters the collective
            } else {
                ctx.allreduce_scalar(1.0, Op::Sum).map(|_| ())
            }
        },
    );
    assert!(results[1].0.is_ok());
    for rank in [0usize, 2] {
        match &results[rank].0 {
            Err(CommError::Timeout { .. }) => {}
            other => panic!("rank {rank}: expected Timeout, got {other:?}"),
        }
    }
}

/// The happy path of the faulty wrapper: a fault configured past the
/// total chunk count never fires, and the result is bitwise identical
/// to the unwrapped source — fault injection is observability-free.
#[test]
fn unfired_fault_wrapper_is_bitwise_invisible() {
    let (source, ocfg) = tutorial_config(100);
    let mut cfg = DOpInfConfig::new(2, ocfg);
    cfg.cost_model = CostModel::free();
    cfg.chunk_rows = Some(9);
    cfg.probes = vec![(0, 11), (1, 60)];
    let wrapped = DataSource::Faulty {
        inner: Box::new(source.clone()),
        fault: FaultSpec { rank: 0, after_chunks: usize::MAX },
    };
    let plain = run_distributed(&cfg, &source).unwrap();
    let faulty = run_distributed(&cfg, &wrapped).unwrap();
    assert_eq!(plain.r, faulty.r);
    assert_eq!(plain.eigs, faulty.eigs);
    assert_eq!(plain.opt_pair, faulty.opt_pair);
    assert_eq!(plain.qtilde.data(), faulty.qtilde.data());
    for (pa, pb) in plain.probes.iter().zip(&faulty.probes) {
        assert_eq!(pa.values, pb.values);
    }
}

//! Transport-equivalence property tests (util::propcheck).
//!
//! The Communicator contract: every backend combines contributions in
//! rank order through the shared `fold` kernels, so collective results
//! must be **bitwise identical** — across the thread and socket
//! transports at every p, against the rank-ordered reference fold, and
//! (for partition-invariant collectives like gather) across
//! p ∈ {1, 2, 4, 7} as well. The final test closes the loop on the
//! pipeline itself: `run_distributed` at p = 4 must produce a
//! bitwise-identical `DOpInfResult` on threads vs sockets.

use std::sync::Arc;

use dopinf::comm::{self, fold, Communicator, CostModel, Op, SelfComm};
use dopinf::coordinator::config::{DOpInfConfig, DataSource, Transport};
use dopinf::coordinator::pipeline::run_distributed;
use dopinf::io::partition::distribute_balanced;
use dopinf::opinf::serial::OpInfConfig;
use dopinf::rom::RegGrid;
use dopinf::sim::synth::{generate, SynthSpec};
use dopinf::util::propcheck::{check, Config};
use dopinf::util::rng::Rng;

const PS: [usize; 4] = [1, 2, 4, 7];

/// Deterministic per-rank payload: depends only on (seed, rank), so
/// every backend run regenerates identical contributions.
fn rank_data(seed: u64, rank: usize, len: usize) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ ((rank as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)));
    (0..len).map(|_| rng.normal() * 8.0 + 0.125).collect()
}

#[test]
fn allreduce_bitwise_identical_across_backends() {
    check(
        Config { cases: 8, seed: 41 },
        |rng| (1 + rng.below(48) as usize, rng.below(1 << 30)),
        |&(len, seed)| {
            for p in PS {
                for op in [Op::Sum, Op::Max, Op::Min] {
                    let parts: Vec<Vec<f64>> = (0..p).map(|r| rank_data(seed, r, len)).collect();
                    let want = fold::reduce_parts(&parts, op);
                    let threads = comm::run(p, CostModel::free(), |ctx| {
                        ctx.allreduce(&rank_data(seed, ctx.rank(), len), op)
                    });
                    let sockets = comm::socket::run(p, CostModel::free(), |ctx| {
                        ctx.allreduce(&rank_data(seed, ctx.rank(), len), op)
                    });
                    for r in 0..p {
                        if threads[r] != want {
                            return Err(format!("thread backend differs at p={p} rank {r}"));
                        }
                        if sockets[r] != want {
                            return Err(format!("socket backend differs at p={p} rank {r}"));
                        }
                    }
                    if p == 1 {
                        // SelfComm is the p=1 reference: identity
                        let mut ctx = SelfComm::new();
                        let got = ctx.allreduce(&parts[0], op);
                        if got != parts[0] {
                            return Err("SelfComm must be the identity".into());
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn gather_reconstructs_the_partitioned_vector_for_every_p() {
    // gather of a balanced partition must reproduce the global vector
    // bit for bit — for every p (partition-invariance) and on both
    // transports, landing on the root alone
    check(
        Config { cases: 8, seed: 77 },
        |rng| (7 + rng.below(200) as usize, rng.below(1 << 30)),
        |&(n, seed)| {
            let global = rank_data(seed, 0, n);
            for p in PS {
                let shards = distribute_balanced(n, p);
                let root = p - 1;
                let run_gather = |results: Vec<Option<Vec<Vec<f64>>>>| -> Result<(), String> {
                    for (rank, out) in results.iter().enumerate() {
                        if rank == root {
                            let got = out.clone().ok_or(format!("p={p}: root got None"))?;
                            if got.concat() != global {
                                return Err(format!("p={p}: gathered vector differs"));
                            }
                        } else if out.is_some() {
                            return Err(format!("p={p}: non-root rank {rank} received data"));
                        }
                    }
                    Ok(())
                };
                run_gather(comm::run(p, CostModel::free(), |ctx| {
                    let sh = shards[ctx.rank()];
                    ctx.gather(root, &global[sh.start..sh.end])
                }))?;
                run_gather(comm::socket::run(p, CostModel::free(), |ctx| {
                    let sh = shards[ctx.rank()];
                    ctx.gather(root, &global[sh.start..sh.end])
                }))?;
            }
            Ok(())
        },
    );
}

#[test]
fn reduce_scatter_block_bitwise_thread_vs_socket() {
    check(
        Config { cases: 8, seed: 5 },
        |rng| (1 + rng.below(24) as usize, rng.below(1 << 30)),
        |&(chunk, seed)| {
            for p in PS {
                let len = chunk * p;
                let parts: Vec<Vec<f64>> = (0..p).map(|r| rank_data(seed, r, len)).collect();
                let reduced = fold::reduce_parts(&parts, Op::Sum);
                let threads = comm::run(p, CostModel::free(), |ctx| {
                    ctx.reduce_scatter_block(&rank_data(seed, ctx.rank(), len), Op::Sum)
                });
                let sockets = comm::socket::run(p, CostModel::free(), |ctx| {
                    ctx.reduce_scatter_block(&rank_data(seed, ctx.rank(), len), Op::Sum)
                });
                for r in 0..p {
                    let want = fold::block(&reduced, r, p);
                    if threads[r] != want {
                        return Err(format!("thread backend differs at p={p} rank {r}"));
                    }
                    if sockets[r] != want {
                        return Err(format!("socket backend differs at p={p} rank {r}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn rooted_reduce_bitwise_equals_allreduce_on_root() {
    check(
        Config { cases: 6, seed: 913 },
        |rng| (1 + rng.below(40) as usize, rng.below(1 << 30)),
        |&(len, seed)| {
            for p in PS {
                let root = p / 2;
                let reduced = comm::run(p, CostModel::free(), |ctx| {
                    let mine = rank_data(seed, ctx.rank(), len);
                    (ctx.reduce(root, &mine, Op::Sum), ctx.allreduce(&mine, Op::Sum))
                });
                for (rank, (rooted, all)) in reduced.iter().enumerate() {
                    if rank == root {
                        if rooted.as_ref() != Some(all) {
                            return Err(format!("p={p}: reduce != allreduce on root"));
                        }
                    } else if rooted.is_some() {
                        return Err(format!("p={p}: non-root {rank} received reduction"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// The acceptance gate: `run_distributed` at p = 4 on the tutorial-style
/// config must produce a bitwise-identical `DOpInfResult` on the thread
/// vs socket transports.
#[test]
fn run_distributed_bitwise_identical_thread_vs_socket_p4() {
    let spec = SynthSpec { nx: 180, ns: 2, nt: 60, modes: 3, ..Default::default() };
    let q = generate(&spec, 0);
    let ocfg = OpInfConfig {
        ns: 2,
        energy_target: 0.999_999,
        r_override: None,
        scaling: false,
        grid: RegGrid::coarse(),
        max_growth: 1.5,
        nt_p: 120,
    };
    let source = DataSource::InMemory(Arc::new(q));
    let mut tcfg = DOpInfConfig::new(4, ocfg);
    tcfg.cost_model = CostModel::free();
    tcfg.probes = vec![(0, 17), (1, 95), (0, 179)];
    let mut scfg = tcfg.clone();
    scfg.transport = Transport::Sockets;

    let a = run_distributed(&tcfg, &source).unwrap();
    let b = run_distributed(&scfg, &source).unwrap();

    assert_eq!(a.r, b.r);
    assert_eq!(a.eigs, b.eigs);
    assert_eq!(a.retained_energy, b.retained_energy);
    assert_eq!(a.opt_pair, b.opt_pair);
    assert_eq!(a.winner_rank, b.winner_rank);
    assert_eq!(a.train_err.to_bits(), b.train_err.to_bits());
    assert_eq!(a.qtilde.data(), b.qtilde.data());
    assert_eq!(a.qhat0, b.qhat0);
    assert_eq!(a.ops.ahat, b.ops.ahat);
    assert_eq!(a.ops.fhat, b.ops.fhat);
    assert_eq!(a.ops.chat, b.ops.chat);
    for (pa, pb) in a.probes.iter().zip(&b.probes) {
        assert_eq!((pa.var, pa.row), (pb.var, pb.row));
        assert_eq!(pa.values, pb.values);
    }
    for (ba, bb) in a.probe_bases.iter().zip(&b.probe_bases) {
        assert_eq!(ba.phi, bb.phi);
        assert_eq!(ba.mean.to_bits(), bb.mean.to_bits());
        assert_eq!(ba.scale.to_bits(), bb.scale.to_bits());
    }
}

//! End-to-end pipeline over a file-backed dataset: flow solver →
//! SNAPD file → distributed training with probes → prediction quality
//! beyond the training horizon.

use std::sync::Arc;

use dopinf::comm::CostModel;
use dopinf::coordinator::config::{DOpInfConfig, DataSource};
use dopinf::coordinator::pipeline::run_distributed;
use dopinf::io::snapd::SnapReader;
use dopinf::linalg::Matrix;
use dopinf::opinf::serial::OpInfConfig;
use dopinf::rom::RegGrid;
use dopinf::sim::driver::{run_to_dataset, SimConfig};
use dopinf::sim::synth::{generate, SynthSpec};
use dopinf::sim::Geometry;
use dopinf::util::json::Json;

#[test]
fn dataset_file_to_trained_rom() {
    // small channel run: enough to exercise the full file path quickly
    let dir = std::env::temp_dir().join("dopinf_it_pipeline");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("channel.snapd");
    let sim = SimConfig {
        geometry: Geometry::Channel,
        nx: 24,
        ny: 12,
        nu: 0.01,
        u_mean: 1.0,
        t_sample: 0.2,
        t_end: 1.0,
        sample_every: 0.02,
        dt: None,
    };
    let info = run_to_dataset(&sim, &path).unwrap();
    assert!(info.n_samples >= 30);

    let source = DataSource::File {
        path: path.clone(),
        variables: vec!["u_x".into(), "u_y".into()],
    };
    let ocfg = OpInfConfig {
        ns: 2,
        energy_target: 0.9999,
        r_override: None,
        scaling: false,
        grid: RegGrid::coarse(),
        max_growth: 10.0, // steady channel: generous bound
        nt_p: info.n_samples,
    };
    let mut cfg = DOpInfConfig::new(3, ocfg);
    cfg.cost_model = CostModel::free();
    cfg.probes = vec![(0, info.probe_rows[0]), (1, info.probe_rows[0])];
    let result = run_distributed(&cfg, &source).unwrap();

    assert!(result.r >= 1);
    assert!(result.train_err.is_finite());
    assert_eq!(result.probes.len(), 2);
    // channel flow is steady: probe prediction ≈ constant u_x there
    let reader = SnapReader::open(&path).unwrap();
    let truth = reader.read_row("u_x", info.probe_rows[0]).unwrap();
    let pred = &result.probes[0].values;
    let denom = truth.iter().fold(0.0f64, |m, &x| m.max(x.abs())).max(1e-12);
    for (t, (a, b)) in pred.iter().zip(&truth).enumerate() {
        assert!((a - b).abs() / denom < 0.05, "t={t}: {a} vs {b}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn prediction_beyond_training_horizon() {
    // periodic synthetic dynamics: train on the first half, verify the
    // ROM extrapolates over the second half (the paper's target use)
    let spec = SynthSpec { nx: 180, ns: 2, nt: 160, modes: 3, ..Default::default() };
    let full = generate(&spec, 0);
    let train = full.slice_cols(0, 80);

    let ocfg = OpInfConfig {
        ns: 2,
        energy_target: 0.999_999,
        r_override: None,
        scaling: false,
        grid: RegGrid::coarse(),
        max_growth: 1.5,
        nt_p: 160,
    };
    let mut cfg = DOpInfConfig::new(4, ocfg);
    cfg.cost_model = CostModel::free();
    cfg.probes = vec![(0, 17), (1, 95)];
    let source = DataSource::InMemory(Arc::new(train));
    let result = run_distributed(&cfg, &source).unwrap();

    for probe in &result.probes {
        let global_row = probe.var * 180 + probe.row;
        let mut worst = 0.0f64;
        for t in 80..160 {
            let truth = full[(global_row, t)];
            let got = probe.values[t];
            worst = worst.max((got - truth).abs());
        }
        assert!(
            worst < 0.05,
            "probe (var {}, row {}): prediction error {worst} beyond training",
            probe.var,
            probe.row
        );
    }
}

#[test]
fn missing_dataset_fails_cleanly() {
    let source = DataSource::File {
        path: "/does/not/exist.snapd".into(),
        variables: vec!["u_x".into()],
    };
    let ocfg = OpInfConfig {
        ns: 1,
        energy_target: 0.99,
        r_override: None,
        scaling: false,
        grid: RegGrid::coarse(),
        max_growth: 1.2,
        nt_p: 10,
    };
    let cfg = DOpInfConfig::new(2, ocfg);
    assert!(run_distributed(&cfg, &source).is_err());
}

#[test]
fn dataset_metadata_probe_rows_usable() {
    // simulate writes probe_rows metadata that `dopinf train` consumes
    let dir = std::env::temp_dir().join("dopinf_it_meta");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("meta.snapd");
    let sim = SimConfig {
        geometry: Geometry::Channel,
        nx: 16,
        ny: 8,
        nu: 0.02,
        u_mean: 1.0,
        t_sample: 0.0,
        t_end: 0.2,
        sample_every: 0.05,
        dt: None,
    };
    run_to_dataset(&sim, &path).unwrap();
    let reader = SnapReader::open(&path).unwrap();
    let rows: Vec<usize> = reader
        .meta()
        .get("probe_rows")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter_map(Json::as_usize)
        .collect();
    assert_eq!(rows.len(), 3);
    let cells = reader.var_info("u_x").unwrap().rows;
    assert!(rows.iter().all(|&r| r < cells));
    // rows must be readable
    for &r in &rows {
        let _ = reader.read_row("u_x", r).unwrap();
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn large_row_count_stresses_partitioning() {
    // ragged split: 997 rows over 8 ranks, tutorial split gives the last
    // rank extra rows; pipeline must stay exact
    let spec = SynthSpec { nx: 997, ns: 2, nt: 30, modes: 2, ..Default::default() };
    let q = generate(&spec, 0);
    let ocfg = OpInfConfig {
        ns: 2,
        energy_target: 0.999_999,
        r_override: Some(4),
        scaling: false,
        grid: RegGrid::coarse(),
        max_growth: 2.0,
        nt_p: 60,
    };
    let mut c1 = DOpInfConfig::new(1, ocfg.clone());
    c1.cost_model = CostModel::free();
    let mut c8 = DOpInfConfig::new(8, ocfg);
    c8.cost_model = CostModel::free();
    let source = DataSource::InMemory(Arc::new(q));
    let r1 = run_distributed(&c1, &source).unwrap();
    let r8 = run_distributed(&c8, &source).unwrap();
    assert_eq!(r1.opt_pair, r8.opt_pair);
    assert!(r1.qtilde.max_abs_diff(&r8.qtilde) < 1e-7);
    let _ = Matrix::zeros(1, 1);
}

//! End-to-end pipeline over a file-backed dataset: flow solver →
//! SNAPD file → distributed training with probes → prediction quality
//! beyond the training horizon — plus the streaming data plane's
//! bitwise-invariance property tests (chunk size × p × transport).

use std::sync::Arc;

use dopinf::ckpt;
use dopinf::comm::CostModel;
use dopinf::coordinator::config::{
    DOpInfConfig, DataSource, FaultKind, FaultPass, FaultSpec, Transport,
};
use dopinf::coordinator::pipeline::{run_distributed, DOpInfResult};
use dopinf::coordinator::resilient::{run_resilient, SAME_ORIGIN_LIMIT};
use dopinf::io::reader::{clear_fault_trips, fault_trips};
use dopinf::io::snapd::{SnapReader, SnapWriter};
use dopinf::linalg::Matrix;
use dopinf::opinf::serial::OpInfConfig;
use dopinf::opinf::streaming::{project_streamed, GramAccumulator};
use dopinf::rom::RegGrid;
use dopinf::runtime::Engine;
use dopinf::sim::driver::{run_to_dataset, SimConfig};
use dopinf::sim::synth::{generate, SynthSpec};
use dopinf::sim::Geometry;
use dopinf::util::json::Json;
use dopinf::util::propcheck;
use dopinf::util::rng::Rng;

#[test]
fn dataset_file_to_trained_rom() {
    // small channel run: enough to exercise the full file path quickly
    let dir = std::env::temp_dir().join("dopinf_it_pipeline");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("channel.snapd");
    let sim = SimConfig {
        geometry: Geometry::Channel,
        nx: 24,
        ny: 12,
        nu: 0.01,
        u_mean: 1.0,
        t_sample: 0.2,
        t_end: 1.0,
        sample_every: 0.02,
        dt: None,
    };
    let info = run_to_dataset(&sim, &path).unwrap();
    assert!(info.n_samples >= 30);

    let source = DataSource::File {
        path: path.clone(),
        variables: vec!["u_x".into(), "u_y".into()],
        nt_train: None,
    };
    let ocfg = OpInfConfig {
        ns: 2,
        energy_target: 0.9999,
        r_override: None,
        scaling: false,
        grid: RegGrid::coarse(),
        max_growth: 10.0, // steady channel: generous bound
        nt_p: info.n_samples,
    };
    let mut cfg = DOpInfConfig::new(3, ocfg);
    cfg.cost_model = CostModel::free();
    cfg.probes = vec![(0, info.probe_rows[0]), (1, info.probe_rows[0])];
    let result = run_distributed(&cfg, &source).unwrap();

    assert!(result.r >= 1);
    assert!(result.train_err.is_finite());
    assert_eq!(result.probes.len(), 2);
    // channel flow is steady: probe prediction ≈ constant u_x there
    let reader = SnapReader::open(&path).unwrap();
    let truth = reader.read_row("u_x", info.probe_rows[0]).unwrap();
    let pred = &result.probes[0].values;
    let denom = truth.iter().fold(0.0f64, |m, &x| m.max(x.abs())).max(1e-12);
    for (t, (a, b)) in pred.iter().zip(&truth).enumerate() {
        assert!((a - b).abs() / denom < 0.05, "t={t}: {a} vs {b}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn prediction_beyond_training_horizon() {
    // periodic synthetic dynamics: train on the first half, verify the
    // ROM extrapolates over the second half (the paper's target use)
    let spec = SynthSpec { nx: 180, ns: 2, nt: 160, modes: 3, ..Default::default() };
    let full = generate(&spec, 0);
    let train = full.slice_cols(0, 80);

    let ocfg = OpInfConfig {
        ns: 2,
        energy_target: 0.999_999,
        r_override: None,
        scaling: false,
        grid: RegGrid::coarse(),
        max_growth: 1.5,
        nt_p: 160,
    };
    let mut cfg = DOpInfConfig::new(4, ocfg);
    cfg.cost_model = CostModel::free();
    cfg.probes = vec![(0, 17), (1, 95)];
    let source = DataSource::InMemory(Arc::new(train));
    let result = run_distributed(&cfg, &source).unwrap();

    for probe in &result.probes {
        let global_row = probe.var * 180 + probe.row;
        let mut worst = 0.0f64;
        for t in 80..160 {
            let truth = full[(global_row, t)];
            let got = probe.values[t];
            worst = worst.max((got - truth).abs());
        }
        assert!(
            worst < 0.05,
            "probe (var {}, row {}): prediction error {worst} beyond training",
            probe.var,
            probe.row
        );
    }
}

#[test]
fn missing_dataset_fails_cleanly() {
    let source = DataSource::File {
        path: "/does/not/exist.snapd".into(),
        variables: vec!["u_x".into()],
        nt_train: None,
    };
    let ocfg = OpInfConfig {
        ns: 1,
        energy_target: 0.99,
        r_override: None,
        scaling: false,
        grid: RegGrid::coarse(),
        max_growth: 1.2,
        nt_p: 10,
    };
    let cfg = DOpInfConfig::new(2, ocfg);
    assert!(run_distributed(&cfg, &source).is_err());
}

#[test]
fn dataset_metadata_probe_rows_usable() {
    // simulate writes probe_rows metadata that `dopinf train` consumes
    let dir = std::env::temp_dir().join("dopinf_it_meta");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("meta.snapd");
    let sim = SimConfig {
        geometry: Geometry::Channel,
        nx: 16,
        ny: 8,
        nu: 0.02,
        u_mean: 1.0,
        t_sample: 0.0,
        t_end: 0.2,
        sample_every: 0.05,
        dt: None,
    };
    run_to_dataset(&sim, &path).unwrap();
    let reader = SnapReader::open(&path).unwrap();
    let rows: Vec<usize> = reader
        .meta()
        .get("probe_rows")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter_map(Json::as_usize)
        .collect();
    assert_eq!(rows.len(), 3);
    let cells = reader.var_info("u_x").unwrap().rows;
    assert!(rows.iter().all(|&r| r < cells));
    // rows must be readable
    for &r in &rows {
        let _ = reader.read_row("u_x", r).unwrap();
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Assert two distributed results are bitwise identical — every f64 of
/// every output artifact, not just within tolerance.
fn assert_bitwise_eq(a: &DOpInfResult, b: &DOpInfResult, tag: &str) {
    assert_eq!(a.r, b.r, "{tag}: r");
    assert_eq!(a.eigs, b.eigs, "{tag}: eigs");
    assert_eq!(a.retained_energy, b.retained_energy, "{tag}: energy");
    assert_eq!(a.opt_pair, b.opt_pair, "{tag}: opt_pair");
    assert_eq!(a.train_err, b.train_err, "{tag}: train_err");
    assert_eq!(a.winner_rank, b.winner_rank, "{tag}: winner");
    assert_eq!(a.qtilde.data(), b.qtilde.data(), "{tag}: qtilde");
    assert_eq!(a.qhat0, b.qhat0, "{tag}: qhat0");
    assert_eq!(a.ops.ahat.data(), b.ops.ahat.data(), "{tag}: ahat");
    assert_eq!(a.ops.fhat.data(), b.ops.fhat.data(), "{tag}: fhat");
    assert_eq!(a.ops.chat, b.ops.chat, "{tag}: chat");
    assert_eq!(a.probes.len(), b.probes.len(), "{tag}: probe count");
    for (pa, pb) in a.probes.iter().zip(&b.probes) {
        assert_eq!((pa.var, pa.row), (pb.var, pb.row), "{tag}: probe id");
        assert_eq!(pa.values, pb.values, "{tag}: probe values");
    }
    assert_eq!(a.probe_bases.len(), b.probe_bases.len(), "{tag}: probe basis count");
    for (ba, bb) in a.probe_bases.iter().zip(&b.probe_bases) {
        assert_eq!(ba.phi, bb.phi, "{tag}: probe basis phi");
        assert_eq!(ba.mean, bb.mean, "{tag}: probe basis mean");
        assert_eq!(ba.scale, bb.scale, "{tag}: probe basis scale");
    }
}

#[test]
fn streamed_pipeline_bitwise_equals_monolithic() {
    // the core contract of the streaming data plane: chunk_rows ∈
    // {1, 7, 64, whole-block} × p ∈ {1, 2, 4} × {threads, sockets} all
    // produce the identical DOpInfResult, scaling transform included
    let spec = SynthSpec { nx: 61, ns: 2, nt: 24, modes: 3, ..Default::default() };
    let q = generate(&spec, 0);
    let source = DataSource::InMemory(Arc::new(q));
    let ocfg = OpInfConfig {
        ns: 2,
        energy_target: 0.999_999,
        r_override: Some(4),
        scaling: true,
        grid: RegGrid::coarse(),
        max_growth: 2.0,
        nt_p: 48,
    };
    for p in [1usize, 2, 4] {
        for transport in [Transport::Threads, Transport::Sockets] {
            let mut base = DOpInfConfig::new(p, ocfg.clone());
            base.cost_model = CostModel::free();
            base.transport = transport;
            base.probes = vec![(0, 3), (1, 60)];
            base.chunk_rows = None; // monolithic single-chunk reference
            let mono = run_distributed(&base, &source).unwrap();
            for chunk in [1usize, 7, 64] {
                let mut cfg = base.clone();
                cfg.chunk_rows = Some(chunk);
                let streamed = run_distributed(&cfg, &source).unwrap();
                assert_bitwise_eq(
                    &mono,
                    &streamed,
                    &format!("p={p} {transport:?} chunk_rows={chunk}"),
                );
            }
        }
    }
}

#[test]
fn pipeline_bitwise_invariant_across_thread_counts() {
    // the compute-plane contract end to end: threads_per_rank ∈
    // {1, 2, 4} × p ∈ {1, 2, 4} × both transports all produce the
    // identical DOpInfResult — every f64 of every artifact — both
    // monolithic and chunked. Threshold 0 forces the banded kernels
    // even at this test-sized problem; the p×T products exceed small CI
    // machines, which is exactly what allow_oversubscribe is for
    // (results are T-invariant; only wall time would care).
    dopinf::linalg::par::set_par_min_elems(0);
    let spec = SynthSpec { nx: 61, ns: 2, nt: 24, modes: 3, ..Default::default() };
    let q = generate(&spec, 0);
    let source = DataSource::InMemory(Arc::new(q));
    let ocfg = OpInfConfig {
        ns: 2,
        energy_target: 0.999_999,
        r_override: Some(4),
        scaling: true,
        grid: RegGrid::coarse(),
        max_growth: 2.0,
        nt_p: 48,
    };
    for p in [1usize, 2, 4] {
        for transport in [Transport::Threads, Transport::Sockets] {
            let mut base = DOpInfConfig::new(p, ocfg.clone());
            base.cost_model = CostModel::free();
            base.transport = transport;
            base.probes = vec![(0, 3), (1, 60)];
            base.threads_per_rank = 1;
            base.allow_oversubscribe = true;
            let reference = run_distributed(&base, &source).unwrap();
            for t in [2usize, 4] {
                for chunk in [None, Some(7)] {
                    let mut cfg = base.clone();
                    cfg.threads_per_rank = t;
                    cfg.chunk_rows = chunk;
                    let res = run_distributed(&cfg, &source).unwrap();
                    assert_bitwise_eq(
                        &reference,
                        &res,
                        &format!("p={p} {transport:?} T={t} chunk_rows={chunk:?}"),
                    );
                }
            }
        }
    }
}

#[test]
fn pipeline_bitwise_invariant_across_simd_tiers() {
    // the lane-order contract end to end: the AVX2+FMA tier and its
    // scalar mul_add emulation produce the identical DOpInfResult —
    // every f64 of every artifact — across ranks, transports, and
    // compute-plane widths. One reference (p=1, threads transport, T=1,
    // native tier) pins the canonical bits; the sweep crosses
    // p ∈ {1, 2, 4} × both transports × T ∈ {1, 4} × both lane-order
    // tiers. (`off` is deliberately absent: it is the legacy arithmetic
    // and produces different — equally valid — bits.) Threshold 0
    // forces the banded kernels even at this test-sized problem.
    dopinf::linalg::par::set_par_min_elems(0);
    let spec = SynthSpec { nx: 61, ns: 2, nt: 24, modes: 3, ..Default::default() };
    let q = generate(&spec, 0);
    let source = DataSource::InMemory(Arc::new(q));
    let ocfg = OpInfConfig {
        ns: 2,
        energy_target: 0.999_999,
        r_override: Some(4),
        scaling: true,
        grid: RegGrid::coarse(),
        max_growth: 2.0,
        nt_p: 48,
    };
    let mut base = DOpInfConfig::new(1, ocfg.clone());
    base.cost_model = CostModel::free();
    base.probes = vec![(0, 3), (1, 60)];
    base.threads_per_rank = 1;
    base.allow_oversubscribe = true;
    base.simd = Some(dopinf::linalg::SimdTier::Native);
    let reference = run_distributed(&base, &source).unwrap();
    for p in [1usize, 2, 4] {
        for transport in [Transport::Threads, Transport::Sockets] {
            for t in [1usize, 4] {
                for tier in [dopinf::linalg::SimdTier::Native, dopinf::linalg::SimdTier::Scalar] {
                    let mut cfg = DOpInfConfig::new(p, ocfg.clone());
                    cfg.cost_model = CostModel::free();
                    cfg.transport = transport;
                    cfg.probes = vec![(0, 3), (1, 60)];
                    cfg.threads_per_rank = t;
                    cfg.allow_oversubscribe = true;
                    cfg.simd = Some(tier);
                    let res = run_distributed(&cfg, &source).unwrap();
                    assert_bitwise_eq(
                        &reference,
                        &res,
                        &format!("p={p} {transport:?} T={t} simd={tier:?}"),
                    );
                }
            }
        }
    }
}

#[test]
fn streamed_file_ingestion_bitwise_with_column_truncation() {
    // file-backed source with nt_train truncation: the streamed reads
    // must agree bitwise with themselves across chunk sizes, and the
    // truncated source must behave like an in-memory column slice
    let dir = std::env::temp_dir().join("dopinf_it_stream_file");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("stream.snapd");
    let spec = SynthSpec { nx: 40, ns: 2, nt: 30, modes: 3, ..Default::default() };
    let q = generate(&spec, 0);
    let mut w = SnapWriter::create(&path, &[("u_x", 40, 30), ("u_y", 40, 30)], Json::Null)
        .unwrap();
    w.write_variable("u_x", &q.slice_rows(0, 40)).unwrap();
    w.write_variable("u_y", &q.slice_rows(40, 80)).unwrap();
    w.finish().unwrap();

    let file_src = DataSource::File {
        path: path.clone(),
        variables: vec!["u_x".into(), "u_y".into()],
        nt_train: Some(20),
    };
    assert_eq!(file_src.dims(2).unwrap(), (40, 2, 20));
    let mem_src = DataSource::InMemory(Arc::new(q.slice_cols(0, 20)));

    let ocfg = OpInfConfig {
        ns: 2,
        energy_target: 0.999_999,
        r_override: Some(3),
        scaling: false,
        grid: RegGrid::coarse(),
        max_growth: 2.0,
        nt_p: 30,
    };
    let mut cfg = DOpInfConfig::new(3, ocfg);
    cfg.cost_model = CostModel::free();
    cfg.probes = vec![(1, 12)];
    cfg.chunk_rows = None;
    let reference = run_distributed(&cfg, &mem_src).unwrap();
    for chunk in [1usize, 7, 512] {
        let mut c = cfg.clone();
        c.chunk_rows = Some(chunk);
        let res = run_distributed(&c, &file_src).unwrap();
        assert_bitwise_eq(&reference, &res, &format!("file chunk_rows={chunk}"));
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn synthetic_source_streams_bitwise() {
    // row-on-demand generation through the pipeline: any chunking of
    // the synthetic reader matches the in-memory generate() path
    let spec = SynthSpec { nx: 53, ns: 2, nt: 20, modes: 2, ..Default::default() };
    let mem_src = DataSource::InMemory(Arc::new(generate(&spec, 0)));
    let synth_src = DataSource::Synthetic(spec);
    let ocfg = OpInfConfig {
        ns: 2,
        energy_target: 0.999_999,
        r_override: Some(3),
        scaling: true,
        grid: RegGrid::coarse(),
        max_growth: 2.0,
        nt_p: 40,
    };
    let mut cfg = DOpInfConfig::new(2, ocfg);
    cfg.cost_model = CostModel::free();
    cfg.chunk_rows = None;
    let reference = run_distributed(&cfg, &mem_src).unwrap();
    for chunk in [5usize, 53] {
        let mut c = cfg.clone();
        c.chunk_rows = Some(chunk);
        let res = run_distributed(&c, &synth_src).unwrap();
        assert_bitwise_eq(&reference, &res, &format!("synthetic chunk_rows={chunk}"));
    }
}

#[test]
fn accumulators_match_engine_bitwise() {
    // property: GramAccumulator == engine.gram and the streamed
    // projection == engine.project, bitwise, for random matrices under
    // random chunk partitions
    let engine = Engine::native();
    propcheck::check(
        propcheck::Config { cases: 48, ..Default::default() },
        |rng: &mut Rng| {
            let rows = 1 + rng.below(70) as usize;
            let nt = 2 + rng.below(14) as usize;
            let r = 1 + rng.below(6) as usize;
            (rows, nt, r, rng.next_u64())
        },
        |&(rows, nt, r, seed)| {
            let q = Matrix::randn(rows, nt, seed);
            let want_d = engine.gram(&q);
            let mut chunk_rng = Rng::new(seed ^ 0xC0FFEE);
            let mut acc = GramAccumulator::new(nt);
            let mut start = 0;
            while start < rows {
                let end = (start + 1 + chunk_rng.below(9) as usize).min(rows);
                acc.push(&q.slice_rows(start, end));
                start = end;
            }
            let d = acc.finish();
            if d.data() != want_d.data() {
                return Err(format!(
                    "streamed Gram diverges from engine.gram by {:e}",
                    d.max_abs_diff(&want_d)
                ));
            }
            let tr = Matrix::randn(nt, r.min(nt), seed ^ 0x5EED);
            let want_q = engine.project(&tr, &want_d);
            let chunk = 1 + (seed % 6) as usize;
            let got = project_streamed(&tr, &want_d, chunk);
            if got.data() != want_q.data() {
                return Err(format!(
                    "streamed projection diverges from engine.project by {:e}",
                    got.max_abs_diff(&want_q)
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn large_row_count_stresses_partitioning() {
    // ragged split: 997 rows over 8 ranks, tutorial split gives the last
    // rank extra rows; pipeline must stay exact
    let spec = SynthSpec { nx: 997, ns: 2, nt: 30, modes: 2, ..Default::default() };
    let q = generate(&spec, 0);
    let ocfg = OpInfConfig {
        ns: 2,
        energy_target: 0.999_999,
        r_override: Some(4),
        scaling: false,
        grid: RegGrid::coarse(),
        max_growth: 2.0,
        nt_p: 60,
    };
    let mut c1 = DOpInfConfig::new(1, ocfg.clone());
    c1.cost_model = CostModel::free();
    let mut c8 = DOpInfConfig::new(8, ocfg);
    c8.cost_model = CostModel::free();
    let source = DataSource::InMemory(Arc::new(q));
    let r1 = run_distributed(&c1, &source).unwrap();
    let r8 = run_distributed(&c8, &source).unwrap();
    assert_eq!(r1.opt_pair, r8.opt_pair);
    assert!(r1.qtilde.max_abs_diff(&r8.qtilde) < 1e-7);
    let _ = Matrix::zeros(1, 1);
}

// ------------------------------------------------- resilience suite

/// Shared config for the checkpoint/resume property tests. Scaling on
/// matters: pass 1 then ends in an `Allreduce(MAX)` barrier, so by the
/// time any rank enters pass 2 every rank's pass-1 shards are on disk —
/// a mid-pass-2 fault is guaranteed to leave a committable epoch behind.
fn resilience_ocfg() -> OpInfConfig {
    OpInfConfig {
        ns: 2,
        energy_target: 0.999_999,
        r_override: Some(4),
        scaling: true,
        grid: RegGrid::coarse(),
        max_growth: 2.0,
        nt_p: 48,
    }
}

/// A fresh, empty checkpoint directory under the system temp dir.
fn ckpt_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("dopinf_resil_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn resilient_retry_resumes_bitwise_threads() {
    // the acceptance property, in-process: a rank's reader dies
    // mid-pass-2 (its Gram partial is lost), the supervisor retries,
    // every rank resumes from the newest committed manifest — and the
    // final DOpInfResult is bitwise identical to an uninterrupted run,
    // across checkpoint cadence × chunk size × rank count
    let spec = SynthSpec { nx: 61, ns: 2, nt: 24, modes: 3, ..Default::default() };
    let clean_src = DataSource::InMemory(Arc::new(generate(&spec, 0)));
    for p in [2usize, 4] {
        for chunk in [1usize, 7] {
            let mut base = DOpInfConfig::new(p, resilience_ocfg());
            base.cost_model = CostModel::free();
            base.probes = vec![(0, 3), (1, 60)];
            base.chunk_rows = Some(chunk);
            let reference = run_distributed(&base, &clean_src).unwrap();
            for every in [1usize, 3] {
                let tag = format!("p={p} chunk_rows={chunk} every={every}");
                let fault = FaultSpec {
                    rank: p - 1,
                    after_chunks: 1,
                    kind: FaultKind::Transient { fail_count: 1 },
                    pass: FaultPass::Two,
                };
                clear_fault_trips(&fault);
                let faulty =
                    DataSource::Faulty { inner: Box::new(clean_src.clone()), fault };
                let mut cfg = base.clone();
                cfg.checkpoint_dir = Some(ckpt_dir(&format!("t_{p}_{chunk}_{every}")));
                cfg.checkpoint_every = every;
                cfg.max_retries = 2;
                let outcome = run_resilient(&cfg, &faulty)
                    .unwrap_or_else(|e| panic!("{tag}: {e}"));
                assert_eq!(outcome.attempts, 2, "{tag}: one failure, one resumed retry");
                assert_eq!(fault_trips(&fault), 1, "{tag}: the fault fired exactly once");
                assert_bitwise_eq(&reference, &outcome.result, &tag);
                // a successful run leaves the checkpoint dir clean
                let dir = cfg.checkpoint_dir.unwrap();
                let leftovers: Vec<_> = std::fs::read_dir(&dir)
                    .unwrap()
                    .flatten()
                    .filter(|e| {
                        let n = e.file_name().to_string_lossy().to_string();
                        n.starts_with("shard-e") || n.starts_with("manifest-e")
                    })
                    .collect();
                assert!(leftovers.is_empty(), "{tag}: checkpoint artifacts survived success");
                std::fs::remove_dir_all(&dir).ok();
            }
        }
    }
}

#[test]
fn resilient_retry_resumes_bitwise_processes() {
    // the same property over real OS worker processes: rank 0 (the
    // parent — the transient trip registry is process-local, so the
    // healing fault must live there) dies mid-pass-2, the driver
    // respawns the worker group, and the resumed result is bitwise
    // identical to the thread transport's uninterrupted run
    std::env::set_var("DOPINF_WORKER_BIN", env!("CARGO_BIN_EXE_dopinf"));
    let spec = SynthSpec { nx: 61, ns: 2, nt: 24, modes: 3, ..Default::default() };
    let clean_src = DataSource::Synthetic(spec);
    for p in [2usize, 4] {
        let tag = format!("processes p={p}");
        let mut base = DOpInfConfig::new(p, resilience_ocfg());
        base.cost_model = CostModel::free();
        base.probes = vec![(0, 3), (1, 60)];
        base.chunk_rows = Some(7);
        base.comm_timeout = Some(120.0);
        let reference = run_distributed(&base, &clean_src).unwrap();

        let fault = FaultSpec {
            rank: 0,
            after_chunks: 1,
            kind: FaultKind::Transient { fail_count: 1 },
            pass: FaultPass::Two,
        };
        clear_fault_trips(&fault);
        let faulty = DataSource::Faulty { inner: Box::new(clean_src.clone()), fault };
        let mut cfg = base.clone();
        cfg.transport = Transport::Processes;
        cfg.checkpoint_dir = Some(ckpt_dir(&format!("proc_{p}")));
        cfg.checkpoint_every = 2;
        cfg.max_retries = 2;
        let outcome = run_resilient(&cfg, &faulty).unwrap_or_else(|e| panic!("{tag}: {e}"));
        assert_eq!(outcome.attempts, 2, "{tag}");
        assert_bitwise_eq(&reference, &outcome.result, &tag);
        std::fs::remove_dir_all(cfg.checkpoint_dir.unwrap()).ok();
    }
}

#[test]
fn corrupt_checkpoints_degrade_without_corrupting_results() {
    // a corrupt or partial checkpoint may cost progress, never
    // correctness: stage a real interrupted run, then resume against
    // (1) the intact manifest set, (2) a bit-flipped member shard, and
    // (3) truncated manifests — every resume stays bitwise identical
    // to the uninterrupted reference
    let spec = SynthSpec { nx: 61, ns: 2, nt: 24, modes: 3, ..Default::default() };
    let clean_src = DataSource::InMemory(Arc::new(generate(&spec, 0)));
    let p = 2;
    let mut cfg = DOpInfConfig::new(p, resilience_ocfg());
    cfg.cost_model = CostModel::free();
    cfg.probes = vec![(0, 3), (1, 60)];
    cfg.chunk_rows = Some(1);
    cfg.checkpoint_dir = Some(ckpt_dir("corrupt"));
    cfg.checkpoint_every = 1;
    let dir = cfg.checkpoint_dir.clone().unwrap();
    let reference = {
        let mut plain = cfg.clone();
        plain.checkpoint_dir = None;
        run_distributed(&plain, &clean_src).unwrap()
    };

    // stage the wreckage: a persistent mid-pass-2 fault on rank 1
    let faulty = DataSource::Faulty {
        inner: Box::new(clean_src.clone()),
        fault: FaultSpec {
            rank: 1,
            after_chunks: 1,
            kind: FaultKind::Persistent,
            pass: FaultPass::Two,
        },
    };
    run_distributed(&cfg, &faulty).unwrap_err();
    let fp = ckpt::config_fingerprint(&cfg, (61, 2, 24));
    let newest = ckpt::newest_valid_manifest(&dir, p, fp)
        .expect("a mid-pass-2 kill must leave at least one committed epoch");

    // (1) intact resume from the newest manifest
    let mut resumed = cfg.clone();
    resumed.resume_epoch = Some(newest);
    resumed.attempt = 1;
    let got = run_distributed(&resumed, &clean_src).unwrap();
    assert_bitwise_eq(&reference, &got, "intact resume");

    // (2) flip one byte in the newest epoch's rank-0 shard: the
    // manifest for that epoch is invalidated (recorded checksum no
    // longer matches) and resolution falls back to an older one...
    // (re-resolve first: the completed resume above committed newer
    // epochs of its own)
    let newest = ckpt::newest_valid_manifest(&dir, p, fp).unwrap();
    let shard0 = dir.join(format!("shard-e{newest}-r0.ck"));
    let mut bytes = std::fs::read(&shard0).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&shard0, &bytes).unwrap();
    let fallback = ckpt::newest_valid_manifest(&dir, p, fp);
    assert!(
        fallback.map_or(true, |e| e < newest),
        "corrupted member must invalidate the newest manifest ({fallback:?} vs {newest})"
    );
    if let Some(older) = fallback {
        let mut r = cfg.clone();
        r.resume_epoch = Some(older);
        let got = run_distributed(&r, &clean_src).unwrap();
        assert_bitwise_eq(&reference, &got, "fallback resume");
    }
    // ...and even forcing the poisoned epoch is safe: the shard loader
    // rejects the corrupt file, that rank replays from zero, the rest
    // restore — the blast radius is wasted work, not wrong numbers
    let mut forced = cfg.clone();
    forced.resume_epoch = Some(newest);
    let got = run_distributed(&forced, &clean_src).unwrap();
    assert_bitwise_eq(&reference, &got, "forced poisoned-epoch resume");

    // (3) truncate every manifest: resolution finds nothing, the run
    // restarts from zero, and the result is still exact
    for entry in std::fs::read_dir(&dir).unwrap().flatten() {
        let name = entry.file_name().to_string_lossy().to_string();
        if name.starts_with("manifest-e") {
            let b = std::fs::read(entry.path()).unwrap();
            std::fs::write(entry.path(), &b[..b.len() / 2]).unwrap();
        }
    }
    assert_eq!(ckpt::newest_valid_manifest(&dir, p, fp), None, "truncated manifests");
    let mut fresh = cfg.clone();
    fresh.resume_epoch = None;
    let got = run_distributed(&fresh, &clean_src).unwrap();
    assert_bitwise_eq(&reference, &got, "restart after manifest loss");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn persistent_faults_trip_the_circuit_breaker() {
    // supervision must fail fast on faults retrying can't fix: with no
    // retry budget the first failure is final, and with a lavish budget
    // the same-origin circuit breaker cuts an effectively-persistent
    // fault off after SAME_ORIGIN_LIMIT attempts — not max_retries + 1
    let spec = SynthSpec { nx: 61, ns: 2, nt: 24, modes: 3, ..Default::default() };
    let clean_src = DataSource::InMemory(Arc::new(generate(&spec, 0)));
    let fault = FaultSpec {
        rank: 1,
        after_chunks: 1,
        kind: FaultKind::Transient { fail_count: 100 },
        pass: FaultPass::Two,
    };
    let faulty = DataSource::Faulty { inner: Box::new(clean_src), fault };
    let mut cfg = DOpInfConfig::new(2, resilience_ocfg());
    cfg.cost_model = CostModel::free();
    cfg.chunk_rows = Some(7);
    cfg.checkpoint_dir = Some(ckpt_dir("breaker"));
    cfg.checkpoint_every = 2;

    clear_fault_trips(&fault);
    cfg.max_retries = 0;
    let err = run_resilient(&cfg, &faulty).unwrap_err();
    assert_eq!(err.rank(), Some(1), "origin must survive aggregation: {err}");
    assert_eq!(fault_trips(&fault), 1, "no budget ⇒ exactly one attempt");

    clear_fault_trips(&fault);
    cfg.max_retries = 10;
    let err = run_resilient(&cfg, &faulty).unwrap_err();
    assert_eq!(err.rank(), Some(1), "{err}");
    assert_eq!(
        fault_trips(&fault),
        SAME_ORIGIN_LIMIT,
        "the breaker, not the retry budget, must end a same-origin streak"
    );
    std::fs::remove_dir_all(cfg.checkpoint_dir.unwrap()).ok();
}

//! HTTP serving-tier throughput: req/s for concurrent single-member
//! ensemble requests with cross-request coalescing off vs on, measured
//! end-to-end through a live [`HttpServer`] (real sockets, real JSON,
//! real queue — not a kernel microbench).
//!
//! `cargo bench --bench serve_http`
//!
//! The load shape is the coalescer's motivating case: 8 keep-alive
//! clients each streaming B = 1 requests at a single worker. Without
//! coalescing every request pays a full solo rollout; with it the queue
//! fuses waiting requests into one batched GEMM. Acceptance target:
//! coalescing lifts req/s by ≥ 2x at this shape. Machine-readable
//! output: results/serve_http.json. Record runs in EXPERIMENTS.md §Perf.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use dopinf::opinf::postprocess::ProbeBasis;
use dopinf::rom::RomOperators;
use dopinf::serve::http::{HttpConfig, HttpServer, ModelRegistry};
use dopinf::serve::RomArtifact;
use dopinf::util::benchkit::Bench;
use dopinf::util::json::Json;
use dopinf::util::timer::WallTimer;

const CLIENTS: usize = 8;
const REQS_PER_CLIENT: usize = 12;
const STEPS: usize = 4096;
const R: usize = 10;

fn artifact() -> RomArtifact {
    RomArtifact {
        ops: RomOperators::stable_sample(R, 5),
        qhat0: (0..R).map(|j| 0.2 + 0.01 * j as f64).collect(),
        probes: vec![ProbeBasis { var: 0, row: 2, phi: vec![1.0; R], mean: 0.0, scale: 1.0 }],
        reg: None,
        meta: BTreeMap::new(),
    }
}

/// Read one response off a keep-alive connection; return its status.
fn read_status<B: BufRead>(r: &mut B) -> u16 {
    let mut line = String::new();
    r.read_line(&mut line).expect("status line");
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("malformed status line {line:?}"))
        .parse()
        .expect("numeric status");
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        r.read_line(&mut h).expect("header line");
        let t = h.trim_end();
        if t.is_empty() {
            break;
        }
        if let Some((k, v)) = t.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                len = v.trim().parse().expect("content-length value");
            }
        }
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).expect("response body");
    status
}

/// One load sample: `CLIENTS` keep-alive connections each stream
/// `reqs` single-member requests; returns elapsed wall seconds.
fn run_load(addr: SocketAddr, reqs: usize) -> f64 {
    let t = WallTimer::start();
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).expect("connect");
                stream.set_read_timeout(Some(Duration::from_secs(120))).expect("read timeout");
                stream.set_nodelay(true).ok();
                let mut reader = BufReader::new(stream.try_clone().expect("clone"));
                for i in 0..reqs {
                    let body = format!(
                        "{{\"members\":1,\"sigma\":0.01,\"seed\":{},\"steps\":{STEPS},\"series\":\"last\"}}",
                        1000 * c + i
                    );
                    let msg = format!(
                        "POST /v1/ensemble HTTP/1.1\r\nHost: bench\r\nContent-Length: {}\r\n\r\n{body}",
                        body.len()
                    );
                    stream.write_all(msg.as_bytes()).expect("send request");
                    let status = read_status(&mut reader);
                    assert_eq!(status, 200, "client {c} request {i} failed");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread");
    }
    t.elapsed()
}

/// Measure one server configuration: start, warm up, sample, tear down.
/// Returns (mean wall seconds per sample, final metrics snapshot).
fn measure(bench: &mut Bench, coalesce: bool, samples: usize) -> (f64, Json) {
    let cfg = HttpConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 1,
        coalesce,
        ..HttpConfig::default()
    };
    let registry = ModelRegistry::from_artifacts(vec![("bench", artifact())]);
    let server = HttpServer::start(registry, cfg).expect("server start");
    let addr = server.local_addr();

    run_load(addr, 2); // warmup: thread pool + route caches
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        times.push(run_load(addr, REQS_PER_CLIENT));
    }
    let mode = if coalesce { "on " } else { "off" };
    let name = format!(
        "serve http coalesce={mode} {CLIENTS} clients x B=1 x {STEPS}"
    );
    let mean_s = bench.record_samples(&name, &times).mean_s;
    server.request_shutdown();
    let metrics = server.join().expect("clean drain");
    (mean_s, metrics)
}

fn main() {
    let samples = std::env::var("DOPINF_BENCH_SAMPLES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5);
    let mut bench = Bench::new();
    println!("== HTTP serving tier: req/s with and without coalescing ==\n");
    println!(
        "   {CLIENTS} keep-alive clients x {REQS_PER_CLIENT} requests, members=1, \
         r={R} x {STEPS} steps, 1 worker\n"
    );

    let (off_s, _) = measure(&mut bench, false, samples);
    let (on_s, on_metrics) = measure(&mut bench, true, samples);

    let total_reqs = (CLIENTS * REQS_PER_CLIENT) as f64;
    let off_rps = total_reqs / off_s;
    let on_rps = total_reqs / on_s;
    let gain = off_s / on_s;
    println!("\n  -> coalesce=off {off_rps:.1} req/s, coalesce=on {on_rps:.1} req/s");
    let fused = on_metrics
        .get("http")
        .and_then(|h| h.get("coalesced_batches"))
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    println!("  -> batches evaluated with coalescing on: {fused:.0}");

    bench.write_json("results/serve_http.json").expect("write results/serve_http.json");
    println!("wrote results/serve_http.json");
    println!(
        "acceptance: coalescing req/s gain at {CLIENTS}x B=1 {gain:.2}x (target >= 2x){}",
        if gain >= 2.0 { " — OK" } else { " — BELOW TARGET" }
    );
}

//! Ensemble-rollout throughput: batched GEMM kernel vs looping the
//! sequential `solve_discrete` baseline, plus the compute-plane sweep
//! (member bands over T ∈ {1, 2, 4, 8} pool workers — bitwise
//! identical trajectories at every T, so only the clock moves).
//!
//! `cargo bench --bench ensemble_throughput`
//!
//! Reports member-steps/sec. Acceptance targets: the batched kernel is
//! ≥ 3x the sequential loop at B = 64, r = 10 (the serving layer's
//! bread-and-butter shape), and the banded rollout at T = 4 is ≥ 2.5x
//! its own T = 1 time at B = 1024 (one node-sized scheduling quantum,
//! where the per-step barrier cost is amortized). Machine-readable
//! output: results/ensemble_throughput.json. Record runs in
//! EXPERIMENTS.md §Perf.

use dopinf::rom::{solve_discrete, RomOperators};
use dopinf::runtime::Engine;
use dopinf::serve::batch::{rollout_batch, rollout_batch_threaded};
use dopinf::serve::ensemble::perturbed_initial_conditions;
use dopinf::util::benchkit::Bench;

fn main() {
    let mut bench = Bench::new();
    println!("== ensemble rollout throughput (member-steps/s) ==\n");

    let engine = Engine::native();
    let r = 10;
    let n_steps = 1200;
    let ops = RomOperators::stable_sample(r, 5);
    let q0: Vec<f64> = (0..r).map(|i| 0.2 + 0.01 * i as f64).collect();

    let mut speedup_at_64 = 0.0;
    for b in [1usize, 8, 64, 256] {
        let q0s = perturbed_initial_conditions(&q0, b, 0.01, 42);
        let member_steps = b * n_steps;

        let seq = bench
            .run_elems(&format!("sequential loop      B={b:<3} r={r} x {n_steps}"), member_steps, || {
                let mut diverged = 0usize;
                for i in 0..b {
                    let (nans, traj) = solve_discrete(&ops, q0s.row(i), n_steps);
                    diverged += usize::from(nans);
                    std::hint::black_box(traj);
                }
                diverged
            })
            .throughput()
            .expect("elems set");

        let bat = bench
            .run_elems(&format!("batched GEMM kernel  B={b:<3} r={r} x {n_steps}"), member_steps, || {
                std::hint::black_box(rollout_batch(&engine, &ops, &q0s, n_steps))
            })
            .throughput()
            .expect("elems set");

        let speedup = bat / seq;
        println!("  -> batched/sequential speedup at B={b}: {speedup:.2}x\n");
        if b == 64 {
            speedup_at_64 = speedup;
        }
    }

    // ---- compute-plane sweep: member bands over T pool workers --------
    // streaming visitor (no trajectory buffer) — the serving layer's
    // actual calling convention; the acceptance shape is B = 1024
    let mut speedup_t4 = 0.0;
    for b in [256usize, 1024] {
        let q0s = perturbed_initial_conditions(&q0, b, 0.01, 43);
        let member_steps = b * n_steps;
        let mut t1 = f64::NAN;
        for t in [1usize, 2, 4, 8] {
            let rep = bench
                .run_elems(
                    &format!("banded rollout       B={b:<4} r={r} x {n_steps} T={t}"),
                    member_steps,
                    || {
                        std::hint::black_box(rollout_batch_threaded(
                            &engine,
                            &ops,
                            &q0s,
                            n_steps,
                            t,
                            |_, _, _| {},
                        ))
                    },
                )
                .mean_s;
            if t == 1 {
                t1 = rep;
            }
            if t == 4 && b == 1024 {
                speedup_t4 = t1 / rep;
            }
        }
        println!();
    }

    bench
        .write_json("results/ensemble_throughput.json")
        .expect("write results/ensemble_throughput.json");
    println!("wrote results/ensemble_throughput.json");
    println!(
        "acceptance: B=64 batched/sequential {speedup_at_64:.2}x (target >= 3x){}",
        if speedup_at_64 >= 3.0 { " — OK" } else { " — BELOW TARGET" }
    );
    println!(
        "acceptance: B=1024 T=4/T=1 {speedup_t4:.2}x (target >= 2.5x){}",
        if speedup_t4 >= 2.5 { " — OK" } else { " — BELOW TARGET" }
    );
}

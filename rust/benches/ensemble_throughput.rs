//! Ensemble-rollout throughput: batched GEMM kernel vs looping the
//! sequential `solve_discrete` baseline.
//!
//! `cargo bench --bench ensemble_throughput`
//!
//! Reports member-steps/sec. Acceptance target: the batched kernel is
//! ≥ 3x the sequential loop at B = 64, r = 10 (the serving layer's
//! bread-and-butter shape: a paper-sized ROM, one scheduling quantum of
//! ensemble members). Record runs in EXPERIMENTS.md §Perf.

use dopinf::rom::{solve_discrete, RomOperators};
use dopinf::runtime::Engine;
use dopinf::serve::batch::rollout_batch;
use dopinf::serve::ensemble::perturbed_initial_conditions;
use dopinf::util::benchkit::Bench;

fn main() {
    let mut bench = Bench::new();
    println!("== ensemble rollout throughput (member-steps/s) ==\n");

    let engine = Engine::native();
    let r = 10;
    let n_steps = 1200;
    let ops = RomOperators::stable_sample(r, 5);
    let q0: Vec<f64> = (0..r).map(|i| 0.2 + 0.01 * i as f64).collect();

    let mut speedup_at_64 = 0.0;
    for b in [1usize, 8, 64, 256] {
        let q0s = perturbed_initial_conditions(&q0, b, 0.01, 42);
        let member_steps = b * n_steps;

        let seq = bench
            .run_elems(&format!("sequential loop      B={b:<3} r={r} x {n_steps}"), member_steps, || {
                let mut diverged = 0usize;
                for i in 0..b {
                    let (nans, traj) = solve_discrete(&ops, q0s.row(i), n_steps);
                    diverged += usize::from(nans);
                    std::hint::black_box(traj);
                }
                diverged
            })
            .throughput()
            .expect("elems set");

        let bat = bench
            .run_elems(&format!("batched GEMM kernel  B={b:<3} r={r} x {n_steps}"), member_steps, || {
                std::hint::black_box(rollout_batch(&engine, &ops, &q0s, n_steps))
            })
            .throughput()
            .expect("elems set");

        let speedup = bat / seq;
        println!("  -> batched/sequential speedup at B={b}: {speedup:.2}x\n");
        if b == 64 {
            speedup_at_64 = speedup;
        }
    }

    println!(
        "acceptance: B=64 speedup {speedup_at_64:.2}x (target >= 3x){}",
        if speedup_at_64 >= 3.0 { " — OK" } else { " — BELOW TARGET" }
    );
}

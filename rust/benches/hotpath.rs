//! Hot-path microbenches for the perf pass (EXPERIMENTS.md §Perf).
//!
//! `cargo bench --bench hotpath`
//!
//! Covers every compute kernel on the pipeline's critical path, native
//! vs PJRT where both exist:
//!   * Gram product QᵀQ (Step III's dominant cost — L1 kernel territory)
//!   * symmetric eigendecomposition (replicated serial fraction)
//!   * OpInf assembly + one regularized solve (Step IV inner loop)
//!   * ROM rollout (Step IV trial + online phase)
//!   * postprocessing lift (Step V)
//!   * collectives (comm substrate overhead)

use dopinf::comm::{self, Communicator, CostModel, Op};
use dopinf::linalg::{cholesky_solve, eigh, matmul, matmul_tn, syrk, Matrix};
use dopinf::opinf::learn;
use dopinf::rom::quadratic::{qhat_sq_rows, s_dim};
use dopinf::rom::{solve_discrete, RomOperators};
use dopinf::runtime::Engine;
use dopinf::util::benchkit::Bench;

fn main() {
    let mut bench = Bench::new();
    println!("== hot-path microbenches ==\n");

    // ---- Gram product: tall-skinny AtA ---------------------------------
    let nt = 600;
    for rows in [2048usize, 8192] {
        let q = Matrix::randn(rows, nt, rows as u64);
        bench.run_elems(&format!("gram native syrk {rows}x{nt}"), rows * nt, || syrk(&q));
    }
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let engine = Engine::from_artifacts(std::path::Path::new("artifacts")).unwrap();
        for rows in [2048usize, 8192] {
            let q = Matrix::randn(rows, nt, rows as u64);
            bench.run_elems(&format!("gram pjrt kernel {rows}x{nt}"), rows * nt, || {
                engine.gram(&q)
            });
        }
    }

    // ---- eigendecomposition (the replicated serial fraction) ----------
    for n in [100usize, 300, 600] {
        let q = Matrix::randn(n + 50, n, n as u64);
        let d = syrk(&q);
        bench.run(&format!("eigh {n}x{n}"), || eigh(&d));
    }

    // ---- OpInf learning ------------------------------------------------
    let r = 10;
    let qhat = Matrix::randn(r, 600, 9);
    bench.run("opinf assemble (r=10, nt=600)", || learn::assemble(&qhat));
    let problem = learn::assemble(&qhat);
    bench.run("opinf regularized solve (one pair)", || {
        problem.solve(1e-6, 1e-2).unwrap()
    });
    let d = problem.dtd.clone();
    let rhs = problem.dtq2.clone();
    bench.run("cholesky solve 66x66, 10 rhs", || cholesky_solve(&d, &rhs).unwrap());

    // ---- quadratic products --------------------------------------------
    let q1 = Matrix::randn(599, r, 4);
    bench.run_elems("qhat_sq rows (599x10 -> 599x55)", 599 * s_dim(r), || qhat_sq_rows(&q1));

    // ---- rollout ---------------------------------------------------------
    let mut ops = RomOperators::zeros(r);
    for i in 0..r {
        ops.ahat[(i, i)] = 0.9;
    }
    let q0 = vec![0.1; r];
    bench.run_elems("rollout r=10 x 1200 steps", 1200, || solve_discrete(&ops, &q0, 1200));

    // ---- postprocessing lift -------------------------------------------
    let centered = Matrix::randn(8192, nt, 6);
    let tr = Matrix::randn(nt, r, 7);
    let qtilde = Matrix::randn(r, 1200, 8);
    bench.run("lift: V_r = Q T_r (8192x600 @ 600x10)", || matmul(&centered, &tr));
    let vr = matmul(&centered, &tr);
    bench.run("lift: V_r Qtilde (8192x10 @ 10x1200)", || matmul(&vr, &qtilde));
    bench.run("project: T_rT D (600x10_T @ 600x600)", || matmul_tn(&tr, &syrk(&Matrix::randn(700, nt, 3))));

    // ---- collectives -----------------------------------------------------
    for p in [2usize, 4, 8] {
        bench.run(&format!("allreduce 600x600 over p={p} ranks"), || {
            comm::run(p, CostModel::free(), |ctx| {
                let data = vec![ctx.rank() as f64; 600 * 600];
                ctx.allreduce(&data, Op::Sum).unwrap().len()
            })
        });
    }

    println!("\n(record before/after in EXPERIMENTS.md §Perf)");
}

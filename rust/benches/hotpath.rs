//! Hot-path microbenches for the perf pass (EXPERIMENTS.md §Perf).
//!
//! `cargo bench --bench hotpath`
//!
//! Covers every compute kernel on the pipeline's critical path, native
//! vs PJRT where both exist, and sweeps the intra-rank compute plane
//! (`T ∈ {1, 2, 4, 8}` pool workers — results bitwise identical at
//! every T, so only the clock moves):
//!   * Gram product QᵀQ (Step III's dominant cost — L1 kernel territory)
//!   * symmetric eigendecomposition (replicated serial fraction)
//!   * OpInf assembly + one regularized solve (Step IV inner loop)
//!   * ROM rollout (Step IV trial + online phase)
//!   * postprocessing lift (Step V)
//!   * collectives (comm substrate overhead)
//!
//! Machine-readable output: results/hotpath.json (one report object per
//! row via `benchkit::write_json`) — the perf trajectory CI uploads.

use dopinf::comm::{self, Category, Communicator, CostModel, Op};
use dopinf::linalg::{
    cholesky_solve, eigh, matmul, matmul_tn, matmul_tn_with_threads, simd, syrk,
    syrk_with_threads, Matrix, SimdTier,
};
use dopinf::opinf::learn;
use dopinf::rom::quadratic::{qhat_sq_rows, s_dim};
use dopinf::rom::{solve_discrete, RomOperators};
use dopinf::obs::Tracer;
use dopinf::runtime::Engine;
use dopinf::serve::rollout_batch_collect;
use dopinf::util::benchkit::Bench;

/// The pre-compute-plane syrk inner loops, zero-skip branches included,
/// kept verbatim as the measurement baseline for the "drop the dense
/// kernels' zero branches" decision (see `linalg::gemm` docs): inputs
/// post-centering are dense, so the branch never fires on the hot path
/// — this row quantifies what keeping it would cost/save.
fn syrk_zero_skip_reference(a: &Matrix) -> Matrix {
    let (k, n) = (a.rows(), a.cols());
    let mut d = Matrix::zeros(n, n);
    let ad = a.data();
    let dd = d.data_mut();
    let mut kk = 0;
    while kk + 4 <= k {
        let (r0, rest) = ad[kk * n..].split_at(n);
        let (r1, rest) = rest.split_at(n);
        let (r2, rest) = rest.split_at(n);
        let r3 = &rest[..n];
        for i in 0..n {
            let (a0, a1, a2, a3) = (r0[i], r1[i], r2[i], r3[i]);
            if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                continue;
            }
            let drow = &mut dd[i * n + i..(i + 1) * n];
            for (j, dv) in drow.iter_mut().enumerate() {
                let jj = i + j;
                *dv += a0 * r0[jj] + a1 * r1[jj] + a2 * r2[jj] + a3 * r3[jj];
            }
        }
        kk += 4;
    }
    for kk in kk..k {
        let row = &ad[kk * n..(kk + 1) * n];
        for i in 0..n {
            let ai = row[i];
            if ai == 0.0 {
                continue;
            }
            let drow = &mut dd[i * n..(i + 1) * n];
            for j in i..n {
                drow[j] += ai * row[j];
            }
        }
    }
    for i in 0..n {
        for j in (i + 1)..n {
            dd[j * n + i] = dd[i * n + j];
        }
    }
    d
}

fn main() {
    let mut bench = Bench::new();
    println!("== hot-path microbenches ==\n");

    // ---- Gram product: tall-skinny AtA ---------------------------------
    let nt = 600;
    for rows in [2048usize, 8192] {
        let q = Matrix::randn(rows, nt, rows as u64);
        bench.run_elems(&format!("gram native syrk {rows}x{nt}"), rows * nt, || syrk(&q));
    }

    // compute-plane sweep on the acceptance shape (T-invariance means
    // the result bits never move; only the clock does)
    let q8k = Matrix::randn(8192, nt, 8192);
    let mut syrk_t1 = f64::NAN;
    let mut syrk_t4 = f64::NAN;
    for t in [1usize, 2, 4, 8] {
        let rep = bench
            .run_elems(&format!("gram native syrk 8192x{nt} T={t}"), 8192 * nt, || {
                syrk_with_threads(&q8k, t)
            })
            .mean_s;
        if t == 1 {
            syrk_t1 = rep;
        }
        if t == 4 {
            syrk_t4 = rep;
        }
    }
    println!(
        "  -> syrk 8192x{nt} T=4 speedup: {:.2}x (target >= 2.5x)\n",
        syrk_t1 / syrk_t4
    );

    // zero-skip branch baseline (satellite measurement: dense inputs,
    // branch never taken — rows quantify the compare overhead)
    bench.run_elems(&format!("gram syrk zero-skip reference 8192x{nt}"), 8192 * nt, || {
        syrk_zero_skip_reference(&q8k)
    });

    // ---- tracer overhead on the hot path (obs/ contract) ---------------
    // Wraps each syrk call in one span exactly the way the pipeline
    // instruments its phases. The obs/ overhead contract: with the
    // tracer *disabled* (the default), span calls must stay within 1%
    // of the bare kernel; the enabled row bounds the per-span cost when
    // an exporter is armed.
    // The three rows compare the *same* kernel with and without span
    // instrumentation, so the lane-order tier is pinned explicitly —
    // otherwise the contract ratio would float with whatever
    // DOPINF_SIMD happens to be set in the environment between runs.
    let ambient_tier = simd::tier();
    simd::set_tier(SimdTier::Scalar);
    let q2k = Matrix::randn(2048, nt, 777);
    let bare = bench
        .run_elems(&format!("syrk 2048x{nt} tracer bare"), 2048 * nt, || syrk(&q2k))
        .mean_s;
    let mut t_off = Tracer::new(0);
    let off = bench
        .run_elems(&format!("syrk 2048x{nt} tracer off"), 2048 * nt, || {
            let s = t_off.span_start();
            let d = syrk(&q2k);
            t_off.span_end(s, "bench_syrk", Category::Compute);
            d
        })
        .mean_s;
    let mut t_on = Tracer::new(0);
    t_on.set_enabled(true);
    let on = bench
        .run_elems(&format!("syrk 2048x{nt} tracer on"), 2048 * nt, || {
            let s = t_on.span_start();
            let d = syrk(&q2k);
            t_on.span_end(s, "bench_syrk", Category::Compute);
            d
        })
        .mean_s;
    // keep the enabled tracer's buffer from looking dead to the optimizer
    std::hint::black_box(t_on.take());
    simd::set_tier(ambient_tier);
    println!(
        "  -> tracer overhead per syrk: off {:+.2}% (contract <= 1%), on {:+.2}%\n",
        (off / bare - 1.0) * 100.0,
        (on / bare - 1.0) * 100.0
    );

    if std::path::Path::new("artifacts/manifest.json").exists() {
        let engine = Engine::from_artifacts(std::path::Path::new("artifacts")).unwrap();
        for rows in [2048usize, 8192] {
            let q = Matrix::randn(rows, nt, rows as u64);
            bench.run_elems(&format!("gram pjrt kernel {rows}x{nt}"), rows * nt, || {
                engine.gram(&q)
            });
        }
    }

    // ---- eigendecomposition (the replicated serial fraction) ----------
    for n in [100usize, 300, 600] {
        let q = Matrix::randn(n + 50, n, n as u64);
        let d = syrk(&q);
        bench.run(&format!("eigh {n}x{n}"), || eigh(&d));
    }

    // ---- OpInf learning ------------------------------------------------
    let r = 10;
    let qhat = Matrix::randn(r, 600, 9);
    bench.run("opinf assemble (r=10, nt=600)", || learn::assemble(&qhat));
    let problem = learn::assemble(&qhat);
    bench.run("opinf regularized solve (one pair)", || {
        problem.solve(1e-6, 1e-2).unwrap()
    });
    let d = problem.dtd.clone();
    let rhs = problem.dtq2.clone();
    bench.run("cholesky solve 66x66, 10 rhs", || cholesky_solve(&d, &rhs).unwrap());

    // ---- quadratic products --------------------------------------------
    let q1 = Matrix::randn(599, r, 4);
    bench.run_elems("qhat_sq rows (599x10 -> 599x55)", 599 * s_dim(r), || qhat_sq_rows(&q1));

    // ---- rollout ---------------------------------------------------------
    let mut ops = RomOperators::zeros(r);
    for i in 0..r {
        ops.ahat[(i, i)] = 0.9;
    }
    let q0 = vec![0.1; r];
    bench.run_elems("rollout r=10 x 1200 steps", 1200, || solve_discrete(&ops, &q0, 1200));

    // ---- postprocessing lift -------------------------------------------
    let centered = Matrix::randn(8192, nt, 6);
    let tr = Matrix::randn(nt, r, 7);
    let qtilde = Matrix::randn(r, 1200, 8);
    bench.run("lift: V_r = Q T_r (8192x600 @ 600x10)", || matmul(&centered, &tr));
    let vr = matmul(&centered, &tr);
    bench.run("lift: V_r Qtilde (8192x10 @ 10x1200)", || matmul(&vr, &qtilde));
    let d_proj = syrk(&Matrix::randn(700, nt, 3));
    bench.run("project: T_rT D (600x10_T @ 600x600)", || matmul_tn(&tr, &d_proj));
    for t in [1usize, 2, 4, 8] {
        bench.run(&format!("project: T_rT D 600x600 T={t}"), || {
            matmul_tn_with_threads(&tr, &d_proj, t)
        });
    }

    // ---- transpose (tiled; serve/batch's IC staging) -------------------
    let tall = Matrix::randn(65_536, r, 12);
    bench.run_elems("transpose 65536x10 (tiled)", 65_536 * r, || tall.transpose());

    // ---- lane-order dispatch tiers (linalg::simd) ----------------------
    // native (AVX2+FMA intrinsics) and scalar (fused mul_add emulation
    // in the identical lane order) are bitwise identical — only the
    // clock separates those rows. `off` is the legacy pre-re-baseline
    // arithmetic, kept as the perf/accuracy baseline. Each row pins its
    // tier explicitly (the knob is process-wide). On a machine without
    // AVX2+FMA the `native` rows silently measure the scalar tier.
    let engine = Engine::native();
    let ops_s = RomOperators::stable_sample(r, 42);
    let q0s = Matrix::randn(512, r, 13);
    let mut syrk_native = f64::NAN;
    let mut syrk_off = f64::NAN;
    for tier in [SimdTier::Native, SimdTier::Scalar, SimdTier::Off] {
        simd::set_tier(tier);
        let name = tier.name();
        let t = bench
            .run_elems(&format!("gram syrk-simd {name} 8192x{nt} T=1"), 8192 * nt, || {
                syrk_with_threads(&q8k, 1)
            })
            .mean_s;
        match tier {
            SimdTier::Native => syrk_native = t,
            SimdTier::Off => syrk_off = t,
            SimdTier::Scalar => {}
        }
        bench.run(&format!("project: tn-simd {name} 600x600 T=1"), || {
            matmul_tn_with_threads(&tr, &d_proj, 1)
        });
        bench.run_elems(&format!("rollout-simd {name} B=512 r=10 x 400 steps"), 512 * 400, || {
            rollout_batch_collect(&engine, &ops_s, &q0s, 400, 1)
        });
    }
    simd::set_tier(ambient_tier);
    println!(
        "  -> syrk 8192x{nt} T=1 simd-native vs simd-off speedup: {:.2}x (target >= 3x)\n",
        syrk_off / syrk_native
    );

    // ---- collectives -----------------------------------------------------
    for p in [2usize, 4, 8] {
        bench.run(&format!("allreduce 600x600 over p={p} ranks"), || {
            comm::run(p, CostModel::free(), |ctx| {
                let data = vec![ctx.rank() as f64; 600 * 600];
                ctx.allreduce(&data, Op::Sum).unwrap().len()
            })
        });
    }

    bench.write_json("results/hotpath.json").expect("write results/hotpath.json");
    println!("\nwrote results/hotpath.json");
    println!("(record before/after in EXPERIMENTS.md §Perf)");
}

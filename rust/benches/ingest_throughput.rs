//! Ingestion-path throughput: streamed chunked passes vs the
//! monolithic whole-block read.
//!
//! `cargo bench --bench ingest_throughput`
//!
//! Measures the full two-pass Step I–III data plane (pass 1 stats,
//! pass 2 center/scale + Gram fold) over a SNAPD file at several chunk
//! sizes, plus the pure read path, reporting block rows/s (`elems` =
//! local rows per two-pass ingest). Each row's name carries the
//! estimated peak residency of the data plane at that chunk size
//! (chunk buffer + (nt, nt) Gram accumulator) — the quantity the
//! streaming refactor bounds. JSON lands in
//! `results/ingest_throughput.json` via `util::benchkit`, alongside
//! the comm/ensemble bench trajectories.

use dopinf::coordinator::config::DataSource;
use dopinf::io::RowRange;
use dopinf::opinf::streaming::{apply_chunk_transform, chunk_stats, GramAccumulator};
use dopinf::sim::synth::{SynthField, SynthSpec};
use dopinf::io::snapd::SnapWriter;
use dopinf::linalg::Matrix;
use dopinf::util::benchkit::Bench;
use dopinf::util::json::Json;
use std::path::PathBuf;

/// Dataset shape: 2 × 8192 spatial rows × 128 snapshots = 16 MiB.
const NX: usize = 8192;
const NS: usize = 2;
const NT: usize = 128;

fn write_dataset() -> PathBuf {
    let dir = std::env::temp_dir().join("dopinf_ingest_bench");
    std::fs::create_dir_all(&dir).expect("temp dir");
    let path = dir.join("ingest.snapd");
    let spec = SynthSpec { nx: NX, ns: NS, nt: NT, modes: 4, ..Default::default() };
    let field = SynthField::new(&spec);
    let mut w = SnapWriter::create(
        &path,
        &[("u_x", NX, NT), ("u_y", NX, NT)],
        Json::Null,
    )
    .expect("create dataset");
    // written the memory-bounded way too: 1024-row generated chunks
    for (var, name) in [(0usize, "u_x"), (1, "u_y")] {
        let mut start = 0;
        while start < NX {
            let end = (start + 1024).min(NX);
            let mut chunk = Matrix::zeros(end - start, NT);
            for row in start..end {
                field.fill_row(var, row, 0, chunk.row_mut(row - start));
            }
            w.write_rows(name, &chunk).expect("write chunk");
            start = end;
        }
    }
    w.finish().expect("finish dataset");
    path
}

/// One full two-pass ingest (stats, then transform + Gram fold);
/// returns a checksum so nothing is optimized away.
fn two_pass_ingest(source: &DataSource, chunk_rows: usize) -> f64 {
    let range = RowRange { start: 0, end: NX };
    let mut reader = source.block_reader(0, range, NX, NS, chunk_rows).expect("reader");
    let mut means = Vec::with_capacity(NS * NX);
    let mut maxabs = vec![0.0f64; NS];
    while let Some(chunk) = reader.next_chunk().expect("pass 1 chunk") {
        chunk_stats(&chunk.data, chunk.start_row, NX, &mut means, &mut maxabs);
    }
    reader.reset().expect("reset");
    let mut gram = GramAccumulator::new(NT);
    while let Some(mut chunk) = reader.next_chunk().expect("pass 2 chunk") {
        apply_chunk_transform(&mut chunk.data, chunk.start_row, NX, &means, Some(&maxabs));
        gram.push(&chunk.data);
    }
    let d = gram.finish();
    d[(0, 0)] + d[(NT - 1, NT - 1)]
}

/// Pure read path (no transforms): chunk drain only.
fn read_only(source: &DataSource, chunk_rows: usize) -> f64 {
    let range = RowRange { start: 0, end: NX };
    let mut reader = source.block_reader(0, range, NX, NS, chunk_rows).expect("reader");
    let mut acc = 0.0;
    while let Some(chunk) = reader.next_chunk().expect("chunk") {
        acc += chunk.data.row(0)[0];
    }
    acc
}

fn resident_kib(chunk_rows: usize) -> usize {
    // chunk buffer + Gram accumulator (+ the O(rows) means vector)
    (chunk_rows.min(NS * NX) * NT * 8 + NT * NT * 8 + NS * NX * 8) / 1024
}

fn main() {
    let path = write_dataset();
    let local_rows = NS * NX;
    let source = DataSource::File {
        path: path.clone(),
        variables: vec!["u_x".to_string(), "u_y".to_string()],
        nt_train: None,
    };
    println!(
        "== ingest throughput: {NS}x{NX} rows x {NT} snapshots ({} MiB on disk) ==\n",
        local_rows * NT * 8 / (1 << 20)
    );

    let mut bench = Bench::new();
    for chunk_rows in [local_rows, 4096, 1024, 256, 64] {
        let label = if chunk_rows == local_rows {
            "monolithic".to_string()
        } else {
            format!("chunk={chunk_rows}")
        };
        bench.run_elems(
            &format!("read-only   {label:<12} resident~{}KiB", resident_kib(chunk_rows)),
            local_rows,
            || read_only(&source, chunk_rows),
        );
        bench.run_elems(
            &format!("two-pass    {label:<12} resident~{}KiB", resident_kib(chunk_rows)),
            local_rows,
            || two_pass_ingest(&source, chunk_rows),
        );
    }

    // synthetic source: the generator bound, no storage at all
    let spec = SynthSpec { nx: NX, ns: NS, nt: NT, modes: 4, ..Default::default() };
    let synth = DataSource::Synthetic(spec);
    bench.run_elems(
        &format!("two-pass    synthetic    resident~{}KiB", resident_kib(1024)),
        local_rows,
        || two_pass_ingest(&synth, 1024),
    );

    bench
        .write_json("results/ingest_throughput.json")
        .expect("write bench json");
    println!("\nwrote results/ingest_throughput.json (elem = block row per two-pass ingest)");
    std::fs::remove_file(&path).ok();
}

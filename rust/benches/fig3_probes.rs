//! Fig. 3 regeneration: ROM velocity predictions at the paper's three
//! probe locations over the full target horizon (training + prediction),
//! compared against the reference solution.
//!
//! `cargo bench --bench fig3_probes`
//!
//! Acceptance is shape: the ROM tracks the reference at all probes,
//! including beyond the training horizon (the right-hand, unhashed part
//! of the paper's panels). Series → results/fig3_probe_*.csv.

use std::sync::Arc;

use dopinf::comm::CostModel;
use dopinf::coordinator::config::{DOpInfConfig, DataSource};
use dopinf::coordinator::pipeline::run_distributed;
use dopinf::io::snapd::SnapReader;
use dopinf::opinf::serial::OpInfConfig;
use dopinf::rom::RegGrid;
use dopinf::sim::synth::{generate, SynthSpec};
use dopinf::util::benchkit::Bench;
use dopinf::util::csvout::CsvWriter;

fn main() {
    // Prefer the real cylinder dataset; otherwise use the synthetic
    // stand-in whose ground truth is analytic.
    let dataset = ["data/cylinder_192x36.snapd", "data/flow.snapd"]
        .iter()
        .find(|p| std::path::Path::new(p).exists())
        .copied();

    println!("== Fig. 3: probe predictions over the target horizon ==");
    let mut bench = Bench::with_samples(1, 0);

    match dataset {
        Some(path) => run_on_dataset(path, &mut bench),
        None => run_on_synthetic(&mut bench),
    }
}

fn run_on_dataset(path: &str, bench: &mut Bench) {
    println!("data: {path}");
    let reader = SnapReader::open(path).unwrap();
    let nt_total = reader.var_info("u_x").unwrap().cols;
    let nt_train = nt_total / 2;
    let probe_rows: Vec<usize> = reader
        .meta()
        .get("probe_rows")
        .and_then(dopinf::util::json::Json::as_arr)
        .map(|a| a.iter().filter_map(dopinf::util::json::Json::as_usize).collect())
        .unwrap_or_default();

    let mut train = reader.read_all("u_x").unwrap().slice_cols(0, nt_train);
    train = train.vstack(&reader.read_all("u_y").unwrap().slice_cols(0, nt_train));

    let opinf = OpInfConfig {
        ns: 2,
        energy_target: 0.9996,
        r_override: None,
        scaling: false,
        grid: RegGrid::paper_default(),
        max_growth: 1.2,
        nt_p: nt_total,
    };
    let mut cfg = DOpInfConfig::new(8, opinf);
    cfg.cost_model = CostModel::shared_memory();
    if std::path::Path::new("artifacts/manifest.json").exists() {
        cfg.artifacts_dir = Some("artifacts".into());
    }
    for &row in &probe_rows {
        cfg.probes.push((0, row));
        cfg.probes.push((1, row));
    }
    let source = DataSource::InMemory(Arc::new(train));

    let mut result = None;
    bench.run("full pipeline + probe lifting (p=8)", || {
        result = Some(run_distributed(&cfg, &source).unwrap());
    });
    let result = result.unwrap();
    println!("r = {}, optimal pair = {:?}", result.r, result.opt_pair);

    for pred in &result.probes {
        let var_name = if pred.var == 0 { "u_x" } else { "u_y" };
        let truth = reader.read_row(var_name, pred.row).unwrap();
        let mut csv = CsvWriter::create(
            format!("results/fig3_probe_row{}_{}.csv", pred.row, var_name),
            &["t_index", "reference", "rom", "in_training"],
        )
        .unwrap();
        let mut train_err = 0.0f64;
        let mut pred_err = 0.0f64;
        let scale = truth.iter().fold(0.0f64, |m, &x| m.max(x.abs())).max(1e-12);
        for t in 0..nt_total {
            csv.row(&[
                t as f64,
                truth[t],
                pred.values[t],
                if t < nt_train { 1.0 } else { 0.0 },
            ])
            .unwrap();
            let e = (pred.values[t] - truth[t]).abs() / scale;
            if t < nt_train {
                train_err = train_err.max(e);
            } else {
                pred_err = pred_err.max(e);
            }
        }
        csv.finish().unwrap();
        println!(
            "probe row {:>6} {}: max rel err train {:.3e} | prediction {:.3e}",
            pred.row, var_name, train_err, pred_err
        );
    }
    println!("wrote results/fig3_probe_*.csv");
}

fn run_on_synthetic(bench: &mut Bench) {
    println!("data: synthetic stand-in (run examples/cylinder_rom for the flow dataset)");
    let nx = 20_000;
    let spec = SynthSpec { nx, ns: 2, nt: 1200, modes: 5, ..Default::default() };
    let full = generate(&spec, 0);
    let train = full.slice_cols(0, 600);

    let opinf = OpInfConfig {
        ns: 2,
        energy_target: 0.999_999,
        r_override: None,
        scaling: false,
        grid: RegGrid::paper_default(),
        max_growth: 1.5,
        nt_p: 1200,
    };
    let mut cfg = DOpInfConfig::new(8, opinf);
    cfg.cost_model = CostModel::shared_memory();
    let probes = [(0usize, nx / 4), (0, nx / 2), (1, 3 * nx / 4)];
    cfg.probes = probes.to_vec();
    let source = DataSource::InMemory(Arc::new(train));

    let mut result = None;
    bench.run("full pipeline + probe lifting (p=8)", || {
        result = Some(run_distributed(&cfg, &source).unwrap());
    });
    let result = result.unwrap();
    println!("r = {}, optimal pair = {:?}", result.r, result.opt_pair);

    for pred in &result.probes {
        let row = pred.var * nx + pred.row;
        let mut csv = CsvWriter::create(
            format!("results/fig3_probe_row{}_var{}.csv", pred.row, pred.var),
            &["t_index", "reference", "rom", "in_training"],
        )
        .unwrap();
        let mut pred_err = 0.0f64;
        for t in 0..1200 {
            csv.row(&[
                t as f64,
                full[(row, t)],
                pred.values[t],
                if t < 600 { 1.0 } else { 0.0 },
            ])
            .unwrap();
            if t >= 600 {
                pred_err = pred_err.max((pred.values[t] - full[(row, t)]).abs());
            }
        }
        csv.finish().unwrap();
        println!(
            "probe (var {}, row {:>6}): max abs prediction error {:.3e}",
            pred.var, pred.row, pred_err
        );
        assert!(pred_err < 0.1, "prediction beyond training degraded");
    }
    println!("wrote results/fig3_probe_*.csv");
}

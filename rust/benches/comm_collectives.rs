//! Collective-primitive microbenchmark across transports.
//!
//! `cargo bench --bench comm_collectives`
//!
//! Measures each [`Communicator`] primitive per backend — thread
//! shared-board, localhost sockets, hierarchical two-level (`hier`,
//! fixed at 2 node groups), and real OS worker processes — at
//! p ∈ {2, 4}, reporting bytes/s (the `elems` column is the payload
//! volume crossing the transport per run) and writing
//! `results/comm_collectives.json` via `util::benchkit`.
//!
//! Each iteration spins the full rank group (thread spawn; TCP
//! rendezvous for sockets; fork+exec+rendezvous for processes) and then
//! runs ROUNDS collective rounds, so fixed setup cost amortizes; the
//! `barrier` row is the near-zero-payload baseline to subtract for
//! per-byte costs. The processes backend drives its rounds through the
//! exercise job (`comm::proc::run_exercise` — the same code path the
//! fault-injection suite exercises), which has no in-place-allreduce
//! variant, so that backend reports 7 primitives instead of 8.

use dopinf::comm::{self, Communicator, CostModel, Op, TwoLevelModel};
use dopinf::comm::proc::{run_exercise, ExerciseSpec};
use dopinf::util::benchkit::Bench;

#[derive(Clone, Copy, Debug)]
enum Backend {
    Threads,
    Sockets,
    Hier,
}

#[derive(Clone, Copy, Debug)]
enum Prim {
    Allreduce,
    AllreduceInplace,
    Broadcast,
    Allgather,
    Gather,
    Reduce,
    ReduceScatter,
    Barrier,
}

const PRIMS: [(Prim, &str); 8] = [
    (Prim::Allreduce, "allreduce"),
    (Prim::AllreduceInplace, "allreduce_inplace"),
    (Prim::Broadcast, "broadcast"),
    (Prim::Allgather, "allgather"),
    (Prim::Gather, "gather"),
    (Prim::Reduce, "reduce"),
    (Prim::ReduceScatter, "reduce_scatter_block"),
    (Prim::Barrier, "barrier"),
];

/// collective rounds per rank-group spin
const ROUNDS: usize = 8;

/// One rank's work: ROUNDS rounds of the primitive over a `len`-element
/// payload. Returns a checksum so nothing is optimized away.
fn collective_pass<C: Communicator>(ctx: &mut C, prim: Prim, len: usize) -> f64 {
    let data = vec![ctx.rank() as f64 + 0.5; len];
    let mut acc = 0.0;
    for _ in 0..ROUNDS {
        // happy-path microbench: collective failures abort the bench
        acc += match prim {
            Prim::Allreduce => ctx.allreduce(&data, Op::Sum).unwrap()[0],
            Prim::AllreduceInplace => {
                let mut d = data.clone();
                ctx.allreduce_inplace(&mut d, Op::Sum).unwrap();
                d[0]
            }
            Prim::Broadcast => {
                let payload = (ctx.rank() == 0).then(|| data.clone());
                ctx.broadcast(0, payload).unwrap()[0]
            }
            Prim::Allgather => ctx.allgather(&data).unwrap()[0][0],
            Prim::Gather => ctx.gather(0, &data).unwrap().map_or(0.0, |parts| parts[0][0]),
            Prim::Reduce => ctx.reduce(0, &data, Op::Sum).unwrap().map_or(0.0, |v| v[0]),
            Prim::ReduceScatter => ctx.reduce_scatter_block(&data, Op::Sum).unwrap()[0],
            Prim::Barrier => {
                ctx.barrier().unwrap();
                0.0
            }
        };
    }
    acc
}

fn payload_bytes(prim: Prim, p: usize, len: usize) -> usize {
    // volume crossing the transport per spin (all rounds)
    let per_round = match prim {
        Prim::Barrier => 0,
        // all-to-all style primitives move p contributions
        Prim::Allgather | Prim::Gather | Prim::Allreduce | Prim::AllreduceInplace
        | Prim::Reduce | Prim::ReduceScatter => len * 8 * p,
        Prim::Broadcast => len * 8,
    };
    per_round * ROUNDS
}

fn main() {
    let mut bench = Bench::new();
    println!("== collective microbenches (bytes/s per primitive per backend) ==\n");

    let len = 1 << 14; // 16k f64 = 128 KiB per rank per round
    let backends = [
        (Backend::Threads, "threads"),
        (Backend::Sockets, "sockets"),
        (Backend::Hier, "hier"),
    ];
    for &(backend, bname) in &backends {
        for p in [2usize, 4] {
            for &(prim, pname) in &PRIMS {
                let name = format!("{pname:<20} {bname} p={p}");
                let bytes = payload_bytes(prim, p, len).max(1);
                bench.run_elems(&name, bytes, || match backend {
                    Backend::Threads => {
                        comm::run(p, CostModel::free(), |ctx| collective_pass(ctx, prim, len))
                    }
                    Backend::Sockets => {
                        comm::socket::run(p, CostModel::free(), |ctx| collective_pass(ctx, prim, len))
                            .expect("socket rendezvous")
                    }
                    // 2 node groups: the smallest shape that exercises
                    // both the intra-node boards and the leader tree
                    Backend::Hier => comm::hier::run(p, 2, TwoLevelModel::free(), |ctx| {
                        collective_pass(ctx, prim, len)
                    }),
                });
            }
        }
    }

    // the processes backend spawns real `dopinf worker` ranks; this
    // bench executable has no `worker` subcommand, so point the
    // launcher at the CLI binary Cargo built alongside us
    std::env::set_var("DOPINF_WORKER_BIN", env!("CARGO_BIN_EXE_dopinf"));
    let proc_prims: [(&str, Prim); 7] = [
        ("allreduce", Prim::Allreduce),
        ("broadcast", Prim::Broadcast),
        ("allgather", Prim::Allgather),
        ("gather", Prim::Gather),
        ("reduce", Prim::Reduce),
        ("reduce_scatter", Prim::ReduceScatter),
        ("barrier", Prim::Barrier),
    ];
    for p in [2usize, 4] {
        for &(pname, prim) in &proc_prims {
            let name = format!("{pname:<20} processes p={p}");
            let bytes = payload_bytes(prim, p, len).max(1);
            let spec = ExerciseSpec {
                prim: pname.to_string(),
                len,
                rounds: ROUNDS,
                seed: 42,
                pause_ms: 0,
            };
            bench.run_elems(&name, bytes, || {
                let results = run_exercise(
                    p,
                    CostModel::free(),
                    Some(std::time::Duration::from_secs(120)),
                    &spec,
                    |_| {},
                )
                .expect("process launch");
                // consume every rank's digest so nothing is optimized away
                results
                    .into_iter()
                    .map(|(outcome, _)| {
                        outcome.expect("worker outcome").first().copied().unwrap_or(0.0)
                    })
                    .sum::<f64>()
            });
        }
    }

    bench.write_json("results/comm_collectives.json").expect("write bench json");
    println!("\nwrote results/comm_collectives.json (elem = byte crossing the transport)");
}

//! Collective-primitive microbenchmark across transports.
//!
//! `cargo bench --bench comm_collectives`
//!
//! Measures each [`Communicator`] primitive per backend (thread
//! shared-board vs localhost sockets) at p ∈ {2, 4}, reporting bytes/s
//! (the `elems` column is the payload volume crossing the transport
//! per run) and writing `results/comm_collectives.json` via
//! `util::benchkit` — the seed of the perf trajectory for future
//! transports.
//!
//! Each iteration spins the full rank group (thread spawn, and for the
//! socket backend the TCP rendezvous) and then runs ROUNDS collective
//! rounds, so fixed setup cost amortizes; the `barrier` row is the
//! near-zero-payload baseline to subtract for per-byte costs.

use dopinf::comm::{self, Communicator, CostModel, Op};
use dopinf::util::benchkit::Bench;

#[derive(Clone, Copy, Debug)]
enum Backend {
    Threads,
    Sockets,
}

#[derive(Clone, Copy, Debug)]
enum Prim {
    Allreduce,
    AllreduceInplace,
    Broadcast,
    Allgather,
    Gather,
    Reduce,
    ReduceScatter,
    Barrier,
}

const PRIMS: [(Prim, &str); 8] = [
    (Prim::Allreduce, "allreduce"),
    (Prim::AllreduceInplace, "allreduce_inplace"),
    (Prim::Broadcast, "broadcast"),
    (Prim::Allgather, "allgather"),
    (Prim::Gather, "gather"),
    (Prim::Reduce, "reduce"),
    (Prim::ReduceScatter, "reduce_scatter_block"),
    (Prim::Barrier, "barrier"),
];

/// collective rounds per rank-group spin
const ROUNDS: usize = 8;

/// One rank's work: ROUNDS rounds of the primitive over a `len`-element
/// payload. Returns a checksum so nothing is optimized away.
fn collective_pass<C: Communicator>(ctx: &mut C, prim: Prim, len: usize) -> f64 {
    let data = vec![ctx.rank() as f64 + 0.5; len];
    let mut acc = 0.0;
    for _ in 0..ROUNDS {
        // happy-path microbench: collective failures abort the bench
        acc += match prim {
            Prim::Allreduce => ctx.allreduce(&data, Op::Sum).unwrap()[0],
            Prim::AllreduceInplace => {
                let mut d = data.clone();
                ctx.allreduce_inplace(&mut d, Op::Sum).unwrap();
                d[0]
            }
            Prim::Broadcast => {
                let payload = (ctx.rank() == 0).then(|| data.clone());
                ctx.broadcast(0, payload).unwrap()[0]
            }
            Prim::Allgather => ctx.allgather(&data).unwrap()[0][0],
            Prim::Gather => ctx.gather(0, &data).unwrap().map_or(0.0, |parts| parts[0][0]),
            Prim::Reduce => ctx.reduce(0, &data, Op::Sum).unwrap().map_or(0.0, |v| v[0]),
            Prim::ReduceScatter => ctx.reduce_scatter_block(&data, Op::Sum).unwrap()[0],
            Prim::Barrier => {
                ctx.barrier().unwrap();
                0.0
            }
        };
    }
    acc
}

fn payload_bytes(prim: Prim, p: usize, len: usize) -> usize {
    // volume crossing the transport per spin (all rounds)
    let per_round = match prim {
        Prim::Barrier => 0,
        // all-to-all style primitives move p contributions
        Prim::Allgather | Prim::Gather | Prim::Allreduce | Prim::AllreduceInplace
        | Prim::Reduce | Prim::ReduceScatter => len * 8 * p,
        Prim::Broadcast => len * 8,
    };
    per_round * ROUNDS
}

fn main() {
    let mut bench = Bench::new();
    println!("== collective microbenches (bytes/s per primitive per backend) ==\n");

    let len = 1 << 14; // 16k f64 = 128 KiB per rank per round
    for &(backend, bname) in &[(Backend::Threads, "threads"), (Backend::Sockets, "sockets")] {
        for p in [2usize, 4] {
            for &(prim, pname) in &PRIMS {
                let name = format!("{pname:<20} {bname} p={p}");
                let bytes = payload_bytes(prim, p, len).max(1);
                bench.run_elems(&name, bytes, || match backend {
                    Backend::Threads => {
                        comm::run(p, CostModel::free(), |ctx| collective_pass(ctx, prim, len))
                    }
                    Backend::Sockets => {
                        comm::socket::run(p, CostModel::free(), |ctx| collective_pass(ctx, prim, len))
                            .expect("socket rendezvous")
                    }
                });
            }
        }
    }

    bench.write_json("results/comm_collectives.json").expect("write bench json");
    println!("\nwrote results/comm_collectives.json (elem = byte crossing the transport)");
}

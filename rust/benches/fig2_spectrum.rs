//! Fig. 2 regeneration: normalized singular values (left panel) and
//! retained energy (right panel) of the training data, plus timing of
//! the distributed dimensionality-reduction stage that produces them.
//!
//! `cargo bench --bench fig2_spectrum`
//!
//! Paper reference: singular values decay fast; r = 10 POD modes attain
//! the 99.96% energy threshold on the cylinder data. Acceptance is
//! *shape* (fast decay, small r at threshold), not absolute values —
//! our solver/grid differ from the FEniCS setup (DESIGN.md §3).
//!
//! Series are written to results/fig2_{singular_values,energy}.csv.

use std::sync::Arc;

use dopinf::comm::CostModel;
use dopinf::coordinator::config::{DOpInfConfig, DataSource};
use dopinf::coordinator::pipeline::run_distributed;
use dopinf::io::snapd::SnapReader;
use dopinf::linalg::Matrix;
use dopinf::opinf::serial::OpInfConfig;
use dopinf::rom::RegGrid;
use dopinf::sim::synth::{generate, SynthSpec};
use dopinf::util::benchkit::Bench;
use dopinf::util::csvout::CsvWriter;

/// Cylinder dataset when available (built by examples/cylinder_rom or
/// `dopinf simulate`), otherwise the 600-snapshot synthetic stand-in.
fn load_training() -> (Matrix, String) {
    for candidate in ["data/cylinder_192x36.snapd", "data/flow.snapd"] {
        if let Ok(reader) = SnapReader::open(candidate) {
            let nt = reader.var_info("u_x").unwrap().cols;
            let nt_train = nt / 2;
            let mut q = reader.read_all("u_x").unwrap().slice_cols(0, nt_train);
            q = q.vstack(&reader.read_all("u_y").unwrap().slice_cols(0, nt_train));
            return (q, format!("cylinder dataset {candidate} (train half)"));
        }
    }
    let spec = SynthSpec { nx: 20_000, ns: 2, nt: 600, modes: 5, ..Default::default() };
    (generate(&spec, 0), "synthetic 600-snapshot stand-in".to_string())
}

fn main() {
    let (q, desc) = load_training();
    println!("== Fig. 2: singular-value spectrum & retained energy ==");
    println!("data: {desc} ({} x {})", q.rows(), q.cols());

    let opinf = OpInfConfig {
        ns: 2,
        energy_target: 0.9996,
        r_override: None,
        scaling: false,
        grid: RegGrid { beta1: vec![1e-8], beta2: vec![1e1] }, // spectrum only
        max_growth: 1e9,
        nt_p: q.cols(),
    };
    let mut cfg = DOpInfConfig::new(4, opinf);
    cfg.cost_model = CostModel::shared_memory();
    let source = DataSource::InMemory(Arc::new(q));

    let mut bench = Bench::new();
    let mut result = None;
    bench.run("steps I-III (p=4, gram+eigh+project)", || {
        result = Some(run_distributed(&cfg, &source).unwrap());
    });
    let result = result.unwrap();

    let r_star = result
        .retained_energy
        .iter()
        .position(|&e| e > 0.9996)
        .map(|p| p + 1)
        .unwrap_or(result.eigs.len());
    println!("\nselected r at 99.96% retained energy: {r_star} (paper: 10)");

    let sigma1 = result.eigs[0].max(0.0).sqrt();
    let mut sv_csv = CsvWriter::create(
        "results/fig2_singular_values.csv",
        &["k", "normalized_sigma"],
    )
    .unwrap();
    let mut en_csv =
        CsvWriter::create("results/fig2_energy.csv", &["r", "retained_energy"]).unwrap();
    println!("\n k   sigma_k/sigma_1    retained energy");
    for (k, (eig, energy)) in result.eigs.iter().zip(&result.retained_energy).enumerate() {
        let ns = eig.max(0.0).sqrt() / sigma1;
        sv_csv.row(&[(k + 1) as f64, ns]).unwrap();
        en_csv.row(&[(k + 1) as f64, *energy]).unwrap();
        if k < 20 {
            println!("{:>2}   {:<16.6e}  {:.8}", k + 1, ns, energy);
        }
    }
    sv_csv.finish().unwrap();
    en_csv.finish().unwrap();

    // paper shape checks
    assert!(r_star <= 40, "spectrum decays too slowly: r* = {r_star}");
    let decade = result.eigs[r_star.min(result.eigs.len() - 1)].max(1e-300)
        / result.eigs[0].max(1e-300);
    println!("\neigenvalue drop through r*: {decade:.2e} (fast decay expected)");
    println!("wrote results/fig2_singular_values.csv, results/fig2_energy.csv");
}

//! In-text result regeneration: the dOpInf ROM CPU time.
//!
//! `cargo bench --bench rom_cpu_time`
//!
//! Paper: the trained r = 10 quadratic ROM integrates 1200 steps over
//! [4, 10] s in 0.03 ± 0.002 s — orders of magnitude cheaper than the
//! high-fidelity solve. This bench measures our native rollout and the
//! PJRT-artifact rollout at the paper's shape (r = 10 padded to 16,
//! 1200 steps), plus the speed ratio against one high-fidelity solver
//! step, and r-sweeps for the scaling ablation.

use dopinf::linalg::Matrix;
use dopinf::rom::quadratic::s_dim;
use dopinf::rom::{solve_discrete, RomOperators};
use dopinf::runtime::Engine;
use dopinf::sim::solver::FlowSolver;
use dopinf::sim::Grid;
use dopinf::util::benchkit::Bench;
use dopinf::util::csvout::CsvWriter;

fn stable_ops(r: usize, seed: u64) -> (RomOperators, Vec<f64>) {
    let mut ops = RomOperators::zeros(r);
    let a = Matrix::randn(r, r, seed);
    for i in 0..r {
        for j in 0..r {
            ops.ahat[(i, j)] = 0.2 * a[(i, j)] / r as f64;
        }
        ops.ahat[(i, i)] += 0.75;
        ops.chat[i] = 1e-3 * i as f64;
    }
    let f = Matrix::randn(r, s_dim(r), seed + 1);
    for i in 0..r {
        for k in 0..s_dim(r) {
            ops.fhat[(i, k)] = 5e-3 * f[(i, k)];
        }
    }
    (ops, vec![0.1; r])
}

fn main() {
    println!("== ROM CPU time (paper: 0.03 ± 0.002 s for 1200 steps, r = 10) ==\n");
    let mut bench = Bench::new();
    let steps = 1200;

    // the paper's shape
    let (ops, q0) = stable_ops(10, 3);
    let native =
        bench.run_elems("native rollout r=10, 1200 steps", steps, || {
            solve_discrete(&ops, &q0, steps)
        }).clone();

    // PJRT artifact path (cyl profile: r_max=16, 1200 steps)
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let engine = Engine::from_artifacts(std::path::Path::new("artifacts")).unwrap();
        bench.run_elems("pjrt rollout r=10->16, 1200 steps", steps, || {
            engine.rollout(&ops, &q0, steps)
        });
    } else {
        println!("(artifacts not built; skipping the PJRT rollout row)");
    }

    // r sweep — how the paper's \"computationally cheap\" claim scales
    let mut csv = CsvWriter::create("results/rom_cpu_time.csv", &["r", "mean_s", "std_s"]).unwrap();
    for r in [4, 8, 10, 16, 24, 32] {
        let (ops, q0) = stable_ops(r, r as u64);
        let rep = bench
            .run(&format!("native rollout r={r}, 1200 steps"), || {
                solve_discrete(&ops, &q0, steps)
            })
            .clone();
        csv.row(&[r as f64, rep.mean_s, rep.std_s]).unwrap();
    }
    csv.finish().unwrap();

    // ROM vs high-fidelity: one projection-solver step on the cylinder
    // grid vs the entire 1200-step ROM horizon
    let mut solver = FlowSolver::new(Grid::dfg_cylinder(192, 36), 0.001, 1.0);
    let dt = solver.stable_dt();
    let hifi = bench.run("high-fidelity solver: ONE time step (192x36)", || solver.step(dt)).clone();
    let ratio = hifi.mean_s / native.mean_s;
    println!(
        "\none high-fidelity step / full 1200-step ROM horizon = {ratio:.1}x\n\
         (the paper's point: the ROM is orders of magnitude cheaper than the\n\
          high-fidelity solve — theirs needs ~hours on a supercomputer)"
    );
    println!("wrote results/rom_cpu_time.csv");
}

//! Fig. 4 regeneration: strong-scaling speedup (left) and CPU-time
//! breakdown (right) for p ∈ {1, 2, 4, 8}, each measurement repeated
//! (the paper repeats 100×; set DOPINF_BENCH_SAMPLES to match).
//!
//! `cargo bench --bench fig4_scaling`
//!
//! Paper reference CPU times: 8.35/4.35/2.23/1.72 s for p = 1/2/4/8 —
//! near-ideal speedup to p = 4, deteriorating at p = 8 as the serial
//! fraction (replicated eigh + OpInf assembly) and the collectives grow.
//! Acceptance is that *shape*; absolute seconds differ (our substrate,
//! DESIGN.md §3). Timing uses per-rank virtual clocks (thread CPU time
//! + α–β collective model) because this container has one core.
//!
//! Series → results/fig4_speedup.csv, results/fig4_breakdown.csv.

use std::sync::Arc;

use dopinf::comm::{CoreModel, CostModel, TwoLevelModel};
use dopinf::coordinator::config::{DOpInfConfig, DataSource};
use dopinf::coordinator::scaling::{strong_scaling, AmdahlFit};
use dopinf::io::snapd::SnapReader;
use dopinf::linalg::Matrix;
use dopinf::opinf::serial::OpInfConfig;
use dopinf::rom::RegGrid;
use dopinf::sim::synth::{generate, SynthSpec};
use dopinf::util::csvout::CsvWriter;

fn load_training() -> (Matrix, String) {
    // DOPINF_FIG4_DATA=path switches to a real dataset; the default is a
    // synthetic workload with the PAPER'S exact state dimension
    // (nx = 146,339 per velocity variable, n = 292,678, nt = 600) so the
    // serial-vs-parallel fractions match the paper's regime.
    if let Ok(candidate) = std::env::var("DOPINF_FIG4_DATA") {
        let reader = SnapReader::open(&candidate).expect("DOPINF_FIG4_DATA unreadable");
        let nt = reader.var_info("u_x").unwrap().cols;
        let nt_train = nt / 2;
        let mut q = reader.read_all("u_x").unwrap().slice_cols(0, nt_train);
        q = q.vstack(&reader.read_all("u_y").unwrap().slice_cols(0, nt_train));
        return (q, candidate);
    }
    let spec = SynthSpec { nx: 146_339, ns: 2, nt: 600, modes: 5, ..Default::default() };
    (generate(&spec, 0), "synthetic at the paper's state dimension (n = 292,678)".to_string())
}

fn main() {
    let repeats: usize = std::env::var("DOPINF_BENCH_SAMPLES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3); // paper repeats 100x; one-core wall time says no
    let (q, desc) = load_training();
    let nt = q.cols();
    println!("== Fig. 4: strong scaling, p in {{1,2,4,8}}, {repeats} repeats ==");
    println!("data: {desc} ({} x {nt})", q.rows());

    let opinf = OpInfConfig {
        ns: 2,
        energy_target: 0.9996,
        r_override: None,
        scaling: false,
        grid: RegGrid::paper_default(), // 64 pairs like the paper
        max_growth: 1.2,
        nt_p: 2 * nt,
    };
    let mut base = DOpInfConfig::new(1, opinf);
    base.cost_model = CostModel::shared_memory();
    // pin the compute plane serial regardless of DOPINF_THREADS: the
    // measured per-rank breakdown must be T=1 (the CoreModel projection
    // below applies the thread speedup itself — an armed knob would
    // both double-apply it and trip the oversubscription guard at p=8)
    base.threads_per_rank = 1;
    let source = DataSource::InMemory(Arc::new(q));

    let rows = strong_scaling(&base, &source, &[1, 2, 4, 8], repeats).unwrap();

    println!(
        "\n{:>4} {:>12} {:>10} {:>9}   load/compute/comm/learn/post [s]",
        "p", "mean [s]", "std [s]", "speedup"
    );
    let mut speed_csv =
        CsvWriter::create("results/fig4_speedup.csv", &["p", "mean_s", "std_s", "speedup"])
            .unwrap();
    let mut brk_csv = CsvWriter::create(
        "results/fig4_breakdown.csv",
        &["p", "load", "compute", "comm", "learn", "post"],
    )
    .unwrap();
    for row in &rows {
        let b = &row.breakdown;
        println!(
            "{:>4} {:>12.5} {:>10.5} {:>9.3}   {:.4}/{:.4}/{:.4}/{:.4}/{:.4}",
            row.p, row.mean_s, row.std_s, row.speedup, b.load, b.compute, b.comm, b.learn, b.post
        );
        speed_csv.row(&[row.p as f64, row.mean_s, row.std_s, row.speedup]).unwrap();
        brk_csv
            .row(&[row.p as f64, b.load, b.compute, b.comm, b.learn, b.post])
            .unwrap();
    }
    speed_csv.finish().unwrap();
    brk_csv.finish().unwrap();

    // ---- shape assertions (who wins / where the crossover falls) ------
    assert!(rows[1].speedup > 1.3, "p=2 should show real speedup, got {}", rows[1].speedup);
    assert!(
        rows[2].speedup > rows[1].speedup,
        "p=4 should beat p=2 ({} vs {})",
        rows[2].speedup,
        rows[1].speedup
    );
    let eff4 = rows[2].speedup / 4.0;
    let eff8 = rows[3].speedup / 8.0;
    assert!(
        eff8 < eff4,
        "efficiency must deteriorate at p=8 (paper Fig. 4): {eff8:.3} vs {eff4:.3}"
    );
    // comm share grows with p (Fig. 4 right)
    let comm_share =
        |r: &dopinf::coordinator::scaling::ScalingRow| r.breakdown.comm / r.breakdown.total;
    assert!(
        comm_share(&rows[3]) > comm_share(&rows[1]),
        "communication share must grow with p"
    );

    // ---- node-level projection: p ranks × T compute-plane threads ----
    // The measured breakdown is per-rank-serial; the deterministic pool
    // scales only the Compute segment (Load is I/O, Comm is the
    // transport, Learn is already rank-sharded), so the node model is
    // total - compute + compute / speedup(T). This is what the paper's
    // 256-core box actually runs: p × T cores per node.
    let core = CoreModel::node();
    println!(
        "\nnode-level projection (CoreModel: {} cores/rank, serial fraction {:.2}):",
        core.cores_per_rank, core.serial_fraction
    );
    println!("{:>4} {:>10} {:>10} {:>10} {:>10}   total [s] at T threads/rank", "p", "T=1", "T=2", "T=4", "T=8");
    let mut node_csv = CsvWriter::create(
        "results/fig4_node_projection.csv",
        &["p", "t", "projected_s", "speedup_vs_p1_t1"],
    )
    .unwrap();
    // one formula for table, CSV, and shape asserts
    let project = |row: &dopinf::coordinator::scaling::ScalingRow, t: usize| {
        row.breakdown.total - row.breakdown.compute + core.compute_time(row.breakdown.compute, t)
    };
    let base_t1 = rows[0].breakdown.total;
    for row in &rows {
        for t in [1usize, 2, 4, 8] {
            node_csv
                .row(&[row.p as f64, t as f64, project(row, t), base_t1 / project(row, t)])
                .unwrap();
        }
        println!(
            "{:>4} {:>10.4} {:>10.4} {:>10.4} {:>10.4}",
            row.p,
            project(row, 1),
            project(row, 2),
            project(row, 4),
            project(row, 8)
        );
    }
    node_csv.finish().unwrap();
    // shape check: adding threads must help every p, with diminishing
    // returns past the Amdahl knee
    assert!(project(&rows[0], 4) < project(&rows[0], 1), "T must reduce modeled node time");
    // gains shrink with T: the 1→4 saving exceeds the 4→8 saving
    assert!(
        project(&rows[0], 4) - project(&rows[0], 8) < project(&rows[0], 1) - project(&rows[0], 4),
        "returns must diminish with T"
    );

    // ---- two-level projection: nodes × ranks-per-node ----------------
    // What the hierarchical transport (comm::hier) changes on a
    // cluster: collectives run local fold → leader tree → local
    // broadcast, so only the node count pays interconnect hops — the
    // rank fan-in stays on the intra-node terms. Projected here for
    // the pipeline's dominant collective — the Allreduce(SUM) of the
    // (nt, nt) Gram matrix — side by side with a flat model that
    // charges every one of the p ranks an interconnect hop.
    let two = TwoLevelModel::hpc();
    let flat = CostModel::cluster();
    let gram_bytes = nt * nt * 8;
    println!(
        "\ntwo-level comm projection (Gram allreduce, {} MiB; hier vs flat cluster):",
        gram_bytes / (1 << 20)
    );
    println!(
        "{:>6} {:>6} {:>6} {:>12} {:>12} {:>7}",
        "nodes", "rpn", "p", "hier [s]", "flat [s]", "ratio"
    );
    let mut hier_csv = CsvWriter::create(
        "results/fig4_hier_projection.csv",
        &["nodes", "ranks_per_node", "p", "hier_allreduce_s", "flat_allreduce_s", "ratio"],
    )
    .unwrap();
    let mut shapes: Vec<(usize, usize, f64, f64)> = Vec::new();
    for nodes in [1usize, 2, 4, 8, 16] {
        for rpn in [1usize, 2, 4, 8] {
            let p = nodes * rpn;
            let hier_s = two.allreduce(nodes, rpn, gram_bytes);
            let flat_s = flat.allreduce(p, gram_bytes);
            println!(
                "{nodes:>6} {rpn:>6} {p:>6} {hier_s:>12.6} {flat_s:>12.6} {:>7.3}",
                hier_s / flat_s.max(1e-30)
            );
            hier_csv
                .row(&[
                    nodes as f64,
                    rpn as f64,
                    p as f64,
                    hier_s,
                    flat_s,
                    hier_s / flat_s.max(1e-30),
                ])
                .unwrap();
            shapes.push((nodes, rpn, hier_s, flat_s));
        }
    }
    hier_csv.finish().unwrap();
    // shape checks: (a) the interconnect component itself shrinks —
    // a 2-node leader exchange costs less than a flat 16-rank
    // interconnect tree (the point of the leader schedule; whether the
    // *total* wins depends on the intra/inter α–β ratio, which the CSV
    // lets the reader judge); (b, c) cost is monotone in each topology
    // dimension (more nodes → more interconnect hops; more ranks per
    // node → deeper local fold)
    let find = |n: usize, r: usize| shapes.iter().find(|s| s.0 == n && s.1 == r).unwrap();
    assert!(
        two.inter.allreduce(2, gram_bytes) < flat.allreduce(16, gram_bytes),
        "the 2-node leader exchange must cost less than a flat 16-rank interconnect tree"
    );
    assert!(
        find(8, 4).2 > find(2, 4).2,
        "hier cost must grow with the node count at fixed ranks-per-node"
    );
    assert!(
        find(2, 8).2 > find(2, 2).2,
        "hier cost must grow with ranks-per-node at a fixed node count"
    );

    let fit = AmdahlFit::through([
        (rows[0].p, rows[0].mean_s),
        (rows[1].p, rows[1].mean_s),
        (rows[3].p, rows[3].mean_s),
    ]);
    println!(
        "\nAmdahl fit: serial {:.4}s, parallel {:.4}s, comm {:.5}s/log2(p)",
        fit.a, fit.b, fit.c
    );
    println!("projected speedup at p=2048: {:.2} (large-scale regime needs the RDRE-size problem of Ref. [1])", fit.speedup(2048));
    println!("\nwrote results/fig4_speedup.csv, results/fig4_breakdown.csv, results/fig4_hier_projection.csv");
    println!("fig4 shape checks PASSED (near-ideal to p=4, deterioration at p=8, comm share grows)");
}

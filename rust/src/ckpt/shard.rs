//! One rank's checkpoint shard: the full pipeline state at a chunk
//! boundary, serialized with a magic, a format version, and a trailing
//! FNV-1a checksum over everything before it.
//!
//! File layout (all little-endian, via [`crate::util::codec`]):
//!
//! ```text
//! "DOPINFCK" | version u64 | payload | fnv1a(prefix) u64
//! ```
//!
//! Decoding validates the checksum *before* parsing a single payload
//! field, so a torn write or flipped bit surfaces as a typed error and
//! the shard is simply not restored — the resilience contract is that
//! bad checkpoints cost progress, never correctness.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::atomic::write_atomic;
use crate::util::codec as c;

pub const MAGIC: &[u8; 8] = b"DOPINFCK";
pub const VERSION: u64 = 1;

/// Where the captured rank was in the two-pass streaming pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Mid-pass-1: `means` holds `cursor` entries, `local_max` is the
    /// partial fold; the Gram state is untouched.
    PassOne,
    /// Pass 1 complete (means full, `local_max` final); `cursor` rows
    /// of pass 2 are already folded into the Gram partial. `cursor ==
    /// local_rows` is the pass-2 boundary shard, written just before
    /// the Gram allreduce.
    PassTwo,
}

/// One rank's complete checkpointable state. See the module docs of
/// [`crate::ckpt`] for the resume-is-bitwise argument.
#[derive(Clone, Debug, PartialEq)]
pub struct RankShard {
    pub epoch: u64,
    pub rank: usize,
    pub p: usize,
    /// [`crate::ckpt::config_fingerprint`] of the run that wrote this
    pub fingerprint: u64,
    pub phase: Phase,
    /// local rows consumed within the captured pass
    pub cursor: usize,
    /// pass-1 row means accumulated so far (one per consumed row)
    pub means: Vec<f64>,
    /// pass-1 per-variable centered max-abs partials
    pub local_max: Vec<f64>,
    /// Gram side length (snapshot count); 0 until pass 2 starts
    pub nt: usize,
    /// Gram partial: the accumulator's `D` (native path) or the summed
    /// PJRT per-chunk partials (`pjrt == true`)
    pub gram_d: Vec<f64>,
    pub gram_rows_seen: usize,
    /// the ≤3-row carry buffer (empty on the PJRT path)
    pub gram_carry: Vec<f64>,
    /// whether `gram_d` came from the PJRT gram-artifact path — a
    /// restore under the other engine must discard the shard
    pub pjrt: bool,
    /// probe rows captured so far: (local cache key, row if captured)
    pub probes: Vec<(usize, Option<Vec<f64>>)>,
    /// virtual-clock parts at capture (total, per-category split)
    pub clock_total: f64,
    pub clock_split: [f64; 5],
}

impl RankShard {
    /// An empty pass-1-start shard (the restore fallback when no valid
    /// checkpoint exists for this rank).
    pub fn fresh(nvars: usize) -> RankShard {
        RankShard {
            epoch: 0,
            rank: 0,
            p: 0,
            fingerprint: 0,
            phase: Phase::PassOne,
            cursor: 0,
            means: Vec::new(),
            local_max: vec![0.0; nvars],
            nt: 0,
            gram_d: Vec::new(),
            gram_rows_seen: 0,
            gram_carry: Vec::new(),
            pjrt: false,
            probes: Vec::new(),
            clock_total: 0.0,
            clock_split: [0.0; 5],
        }
    }
}

pub fn shard_filename(epoch: u64, rank: usize) -> String {
    format!("shard-e{epoch}-r{rank}.ck")
}

pub fn shard_path(dir: &Path, epoch: u64, rank: usize) -> PathBuf {
    dir.join(shard_filename(epoch, rank))
}

/// Serialize to the checksummed on-disk format.
pub fn encode(s: &RankShard) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    c::write_u64(&mut buf, VERSION).unwrap();
    c::write_u64(&mut buf, s.epoch).unwrap();
    c::write_usize(&mut buf, s.rank).unwrap();
    c::write_usize(&mut buf, s.p).unwrap();
    c::write_u64(&mut buf, s.fingerprint).unwrap();
    c::write_u8(&mut buf, match s.phase {
        Phase::PassOne => 1,
        Phase::PassTwo => 2,
    })
    .unwrap();
    c::write_usize(&mut buf, s.cursor).unwrap();
    c::write_f64s(&mut buf, &s.means).unwrap();
    c::write_f64s(&mut buf, &s.local_max).unwrap();
    c::write_usize(&mut buf, s.nt).unwrap();
    c::write_f64s(&mut buf, &s.gram_d).unwrap();
    c::write_usize(&mut buf, s.gram_rows_seen).unwrap();
    c::write_f64s(&mut buf, &s.gram_carry).unwrap();
    c::write_bool(&mut buf, s.pjrt).unwrap();
    c::write_usize(&mut buf, s.probes.len()).unwrap();
    for (key, row) in &s.probes {
        c::write_usize(&mut buf, *key).unwrap();
        c::write_opt(&mut buf, row.as_ref(), |w, v| c::write_f64s(w, v)).unwrap();
    }
    c::write_f64(&mut buf, s.clock_total).unwrap();
    for v in s.clock_split {
        c::write_f64(&mut buf, v).unwrap();
    }
    let checksum = super::fnv1a(&buf);
    c::write_u64(&mut buf, checksum).unwrap();
    buf
}

/// Parse and validate a shard image: checksum first, then magic and
/// version, then the payload.
pub fn decode(bytes: &[u8]) -> Result<RankShard> {
    anyhow::ensure!(bytes.len() >= MAGIC.len() + 16, "shard truncated ({} bytes)", bytes.len());
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(tail.try_into().unwrap());
    let actual = super::fnv1a(body);
    anyhow::ensure!(stored == actual, "shard checksum mismatch ({stored:#x} != {actual:#x})");
    let (magic, mut r) = body.split_at(MAGIC.len());
    anyhow::ensure!(magic == MAGIC, "not a checkpoint shard (bad magic)");
    let version = c::read_u64(&mut r)?;
    anyhow::ensure!(version == VERSION, "unsupported shard version {version}");
    let epoch = c::read_u64(&mut r)?;
    let rank = c::read_usize(&mut r)?;
    let p = c::read_usize(&mut r)?;
    let fingerprint = c::read_u64(&mut r)?;
    let phase = match c::read_u8(&mut r)? {
        1 => Phase::PassOne,
        2 => Phase::PassTwo,
        other => anyhow::bail!("bad phase byte {other}"),
    };
    let cursor = c::read_usize(&mut r)?;
    let means = c::read_f64s(&mut r)?;
    let local_max = c::read_f64s(&mut r)?;
    let nt = c::read_usize(&mut r)?;
    let gram_d = c::read_f64s(&mut r)?;
    let gram_rows_seen = c::read_usize(&mut r)?;
    let gram_carry = c::read_f64s(&mut r)?;
    let pjrt = c::read_bool(&mut r)?;
    let nprobes = c::read_usize(&mut r)?;
    let mut probes = Vec::with_capacity(nprobes.min(1024));
    for _ in 0..nprobes {
        let key = c::read_usize(&mut r)?;
        let row = c::read_opt(&mut r, |r| c::read_f64s(r))?;
        probes.push((key, row));
    }
    let clock_total = c::read_f64(&mut r)?;
    let mut clock_split = [0.0f64; 5];
    for v in &mut clock_split {
        *v = c::read_f64(&mut r)?;
    }
    anyhow::ensure!(r.is_empty(), "trailing bytes after shard payload");
    Ok(RankShard {
        epoch,
        rank,
        p,
        fingerprint,
        phase,
        cursor,
        means,
        local_max,
        nt,
        gram_d,
        gram_rows_seen,
        gram_carry,
        pjrt,
        probes,
        clock_total,
        clock_split,
    })
}

/// Atomically persist `s` as `dir/shard-e{epoch}-r{rank}.ck`. Returns
/// the byte size written (for the `checkpoint_bytes` gauge and the
/// DiskModel charge).
pub fn save(dir: &Path, s: &RankShard) -> Result<usize> {
    let bytes = encode(s);
    let path = shard_path(dir, s.epoch, s.rank);
    write_atomic(&path, &bytes).with_context(|| format!("writing shard {}", path.display()))?;
    Ok(bytes.len())
}

/// Load + validate one shard, additionally checking it belongs to this
/// (epoch, rank, fingerprint).
pub fn load(dir: &Path, epoch: u64, rank: usize, fingerprint: u64) -> Result<RankShard> {
    let path = shard_path(dir, epoch, rank);
    let bytes =
        std::fs::read(&path).with_context(|| format!("reading shard {}", path.display()))?;
    let s = decode(&bytes).with_context(|| format!("decoding shard {}", path.display()))?;
    anyhow::ensure!(
        s.epoch == epoch && s.rank == rank,
        "shard identity mismatch (file says epoch {} rank {})",
        s.epoch,
        s.rank
    );
    anyhow::ensure!(
        s.fingerprint == fingerprint,
        "shard fingerprint mismatch — checkpoint from a different configuration"
    );
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RankShard {
        RankShard {
            epoch: 3,
            rank: 1,
            p: 4,
            fingerprint: 0xDEAD_BEEF,
            phase: Phase::PassTwo,
            cursor: 17,
            means: vec![0.5, -1.25, 3.0],
            local_max: vec![2.0, 4.5],
            nt: 2,
            gram_d: vec![1.0, 2.0, 3.0, 4.0],
            gram_rows_seen: 16,
            gram_carry: vec![9.0, 8.0],
            pjrt: false,
            probes: vec![(5, Some(vec![1.0, 2.0])), (11, None)],
            clock_total: 1.5,
            clock_split: [0.1, 0.2, 0.3, 0.4, 0.5],
        }
    }

    #[test]
    fn shard_roundtrips_bitwise() {
        let s = sample();
        let got = decode(&encode(&s)).unwrap();
        assert_eq!(got, s);
        // f64 payloads must be bit-exact, not just PartialEq
        assert_eq!(got.means[1].to_bits(), s.means[1].to_bits());
        assert_eq!(got.clock_total.to_bits(), s.clock_total.to_bits());
    }

    #[test]
    fn every_flipped_bit_is_detected() {
        let bytes = encode(&sample());
        // flip one bit at a spread of offsets, including the header,
        // the payload, and the checksum itself
        for at in [0, 8, 20, bytes.len() / 2, bytes.len() - 9, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[at] ^= 0x10;
            assert!(decode(&bad).is_err(), "flipped bit at {at} went undetected");
        }
        // truncation at any point is detected too
        for cut in [0, 7, bytes.len() - 1] {
            assert!(decode(&bytes[..cut]).is_err(), "truncation to {cut} went undetected");
        }
    }

    #[test]
    fn save_load_validates_identity_and_fingerprint() {
        let dir = std::env::temp_dir().join(format!("dopinf_shard_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let s = sample();
        save(&dir, &s).unwrap();
        let got = load(&dir, 3, 1, 0xDEAD_BEEF).unwrap();
        assert_eq!(got, s);
        assert!(load(&dir, 3, 1, 0x1234).is_err(), "wrong fingerprint must be rejected");
        assert!(load(&dir, 4, 1, 0xDEAD_BEEF).is_err(), "missing epoch must error");
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Checkpoint/resume: versioned, checksummed per-rank state shards and
//! the epoch-manifest commit protocol behind
//! [`crate::coordinator::resilient::run_resilient`].
//!
//! ## What a shard captures
//!
//! A [`RankShard`] is *all* of one rank's pipeline state at a chunk
//! boundary: the pass-1 statistics (row means so far, per-variable
//! centered max-abs), the pass-2 fold state
//! ([`crate::opinf::streaming::GramAccumulator`] partial — `D` so far,
//! `rows_seen`, and the ≤3-row carry buffer that keeps the rank-4 row
//! groups aligned), the chunk cursor, the captured probe rows, and the
//! virtual [`crate::comm::Clock`] parts. Shards are written through
//! [`crate::util::atomic`] (temp-file + atomic rename) with a magic,
//! a format version, and a trailing FNV-1a checksum, so a torn or
//! bit-rotted shard is *detected and discarded* — never restored.
//!
//! ## The epoch-manifest commit protocol
//!
//! Epochs are **rank-local version counters**: each rank writes
//! `shard-e{epoch}-r{rank}.ck` at its own trigger points (every
//! `--checkpoint-every N` chunks within a pass, plus the mandatory
//! pass boundaries) and increments its counter. Rank 0 additionally
//! tries to **commit** `manifest-e{j}.ck` for the newest epoch `j` at
//! which *every* rank's shard exists and passes checksum + fingerprint
//! validation; the manifest records each shard file's checksum. A
//! manifest therefore commits only when the whole epoch durably
//! landed, and a later partial overwrite of any member shard
//! invalidates it (the recorded checksum no longer matches), falling
//! back to an older manifest — **a corrupt or partial checkpoint can
//! cost progress, never correctness**.
//!
//! ## Why resume is bitwise identical
//!
//! Epochs need no cross-rank logical alignment because the streaming
//! pass loops contain **no collectives**: the only cross-pass
//! collective is the scales `Allreduce(MAX)`, every rank re-executes
//! it on resume from its stored `local_max` (same inputs ⇒ bitwise
//! same output), and each rank replays its remaining chunks from its
//! own cursor — the exact operation sequence of an uninterrupted run
//! (the carry-buffer alignment argument of `opinf::streaming`). So
//! the core invariant extends: streamed ≡ monolithic ≡ any p ≡ any
//! transport ≡ any T ≡ **resumed-after-kill**. Restored clocks carry
//! the *measured* time of the interrupted attempt forward — results
//! provably cannot depend on them (they never feed the numeric path).

pub mod manifest;
pub mod shard;

pub use manifest::{newest_valid_manifest, try_commit, Manifest};
pub use shard::{Phase, RankShard};

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::coordinator::config::DOpInfConfig;

/// FNV-1a 64-bit — the integrity hash for shards, manifests, and the
/// config fingerprint. Not cryptographic; it guards against torn
/// writes and bit rot, not adversaries.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fingerprint of everything a shard's validity depends on: the rank
/// layout, the data dimensions, the chunking, and every algorithm knob
/// that steers the per-rank operation sequence. A checkpoint taken
/// under any other configuration must never be restored — the cursor
/// arithmetic and the accumulated partials would silently disagree.
pub fn config_fingerprint(cfg: &DOpInfConfig, dims: (usize, usize, usize)) -> u64 {
    use crate::util::codec as c;
    let mut buf = Vec::new();
    let (nx, ns, nt) = dims;
    c::write_usize(&mut buf, cfg.p).unwrap();
    c::write_usize(&mut buf, nx).unwrap();
    c::write_usize(&mut buf, ns).unwrap();
    c::write_usize(&mut buf, nt).unwrap();
    c::write_opt(&mut buf, cfg.chunk_rows.as_ref(), |w, v| c::write_usize(w, *v)).unwrap();
    c::write_bool(&mut buf, cfg.opinf.scaling).unwrap();
    c::write_f64(&mut buf, cfg.opinf.energy_target).unwrap();
    c::write_opt(&mut buf, cfg.opinf.r_override.as_ref(), |w, v| c::write_usize(w, *v)).unwrap();
    c::write_f64s(&mut buf, &cfg.opinf.grid.beta1).unwrap();
    c::write_f64s(&mut buf, &cfg.opinf.grid.beta2).unwrap();
    c::write_f64(&mut buf, cfg.opinf.max_growth).unwrap();
    c::write_usize(&mut buf, cfg.opinf.nt_p).unwrap();
    c::write_usize(&mut buf, cfg.probes.len()).unwrap();
    for &(var, row) in &cfg.probes {
        c::write_usize(&mut buf, var).unwrap();
        c::write_usize(&mut buf, row).unwrap();
    }
    c::write_bool(&mut buf, cfg.artifacts_dir.is_some()).unwrap();
    fnv1a(&buf)
}

/// The pass-1 → pass-2 transition marker (`pass2-r{rank}`): written
/// when a rank enters pass 2 with checkpointing on. Purely a progress
/// signal for harnesses (the CI resilience smoke polls for these to
/// time its SIGKILL mid-pass-2); nothing is ever restored from it.
pub fn mark_pass2(dir: &Path, rank: usize) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    crate::util::atomic::write_atomic(&dir.join(format!("pass2-r{rank}")), b"1")?;
    Ok(())
}

/// Remove every checkpoint artifact (`shard-e*`, `manifest-e*`,
/// `pass2-r*`, orphaned `*.tmp.*` siblings) from `dir`, leaving other
/// files alone. Called by the retry driver after a successful run.
pub fn clean(dir: &Path) -> Result<()> {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(()), // nothing ever written
    };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.starts_with("shard-e")
            || name.starts_with("manifest-e")
            || name.starts_with("pass2-r")
            || name.contains(".tmp.")
        {
            std::fs::remove_file(entry.path()).ok();
        }
    }
    Ok(())
}

/// Per-rank checkpoint writer: owns the rank-local epoch counter, the
/// cadence rule, and (on rank 0) the manifest commit attempts.
pub struct Checkpointer {
    dir: PathBuf,
    every: usize,
    fingerprint: u64,
    rank: usize,
    p: usize,
    next_epoch: u64,
    /// cumulative bytes persisted by this rank (shards + manifests) —
    /// feeds the `checkpoint_bytes` gauge and the DiskModel charges
    bytes_written: u64,
}

impl Checkpointer {
    /// `resume_epoch` is the manifest this attempt restored from (the
    /// rank's next shard gets the epoch after it), or `None` for a
    /// fresh run.
    pub fn new(
        dir: &Path,
        every: usize,
        fingerprint: u64,
        rank: usize,
        p: usize,
        resume_epoch: Option<u64>,
    ) -> Result<Checkpointer> {
        std::fs::create_dir_all(dir)?;
        Ok(Checkpointer {
            dir: dir.to_path_buf(),
            every,
            fingerprint,
            rank,
            p,
            next_epoch: resume_epoch.map_or(0, |e| e + 1),
            bytes_written: 0,
        })
    }

    /// Mid-pass cadence: save after `chunks_done` chunks of the current
    /// pass (an **absolute** within-pass count, so a resumed attempt
    /// triggers at the same positions as the uninterrupted run and
    /// epoch ↔ position stays attempt-invariant).
    pub fn due(&self, chunks_done: usize) -> bool {
        self.every > 0 && chunks_done > 0 && chunks_done % self.every == 0
    }

    /// Persist this rank's shard at the next epoch (atomic rename), and
    /// on rank 0 try to commit the newest complete manifest. Returns
    /// the bytes written by this call.
    pub fn save(&mut self, shard: &mut RankShard) -> Result<usize> {
        shard.epoch = self.next_epoch;
        shard.rank = self.rank;
        shard.p = self.p;
        shard.fingerprint = self.fingerprint;
        let mut bytes = shard::save(&self.dir, shard)?;
        self.next_epoch += 1;
        if self.rank == 0 {
            bytes += self.commit()?;
        }
        self.bytes_written += bytes as u64;
        Ok(bytes)
    }

    /// Rank 0's manifest commit attempt (also called once after the
    /// Gram allreduce, when every rank's pass-2 boundary shard is
    /// guaranteed on disk). Returns manifest bytes written (0 when
    /// nothing new committed).
    pub fn commit(&mut self) -> Result<usize> {
        if self.next_epoch == 0 {
            return Ok(0);
        }
        let bytes = manifest::try_commit(&self.dir, self.p, self.fingerprint, self.next_epoch - 1)?
            .map_or(0, |(_, b)| b);
        self.bytes_written += bytes as u64;
        Ok(bytes)
    }

    pub fn bytes_written(&self) -> u64 {
        self.bytes_written
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // the canonical FNV-1a 64 test vectors
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn fingerprint_tracks_every_knob() {
        use crate::opinf::serial::OpInfConfig;
        use crate::rom::RegGrid;
        let ocfg = OpInfConfig {
            ns: 2,
            energy_target: 0.999,
            r_override: None,
            scaling: false,
            grid: RegGrid::coarse(),
            max_growth: 1.2,
            nt_p: 100,
        };
        let cfg = DOpInfConfig::new(4, ocfg);
        let base = config_fingerprint(&cfg, (100, 2, 50));
        assert_eq!(base, config_fingerprint(&cfg, (100, 2, 50)), "deterministic");

        let mut other = cfg.clone();
        other.p = 2;
        assert_ne!(base, config_fingerprint(&other, (100, 2, 50)), "p");
        let mut other = cfg.clone();
        other.chunk_rows = Some(7);
        assert_ne!(base, config_fingerprint(&other, (100, 2, 50)), "chunk_rows");
        let mut other = cfg.clone();
        other.opinf.scaling = true;
        assert_ne!(base, config_fingerprint(&other, (100, 2, 50)), "scaling");
        let mut other = cfg.clone();
        other.probes = vec![(0, 3)];
        assert_ne!(base, config_fingerprint(&other, (100, 2, 50)), "probes");
        assert_ne!(base, config_fingerprint(&cfg, (101, 2, 50)), "dims");
        // knobs that never steer the rank-local operation sequence —
        // transport, cost model, tracing — must NOT invalidate shards
        let mut other = cfg.clone();
        other.transport = crate::coordinator::config::Transport::Processes;
        other.trace = Some(std::path::PathBuf::from("/tmp/t.json"));
        assert_eq!(base, config_fingerprint(&other, (100, 2, 50)));
    }

    #[test]
    fn clean_removes_only_checkpoint_artifacts() {
        let dir = std::env::temp_dir().join(format!("dopinf_ckpt_clean_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for name in ["shard-e0-r1.ck", "manifest-e0.ck", "pass2-r3", "x.ck.tmp.99", "keep.rom"] {
            std::fs::write(dir.join(name), b"x").unwrap();
        }
        clean(&dir).unwrap();
        assert!(dir.join("keep.rom").exists(), "unrelated files must survive");
        for name in ["shard-e0-r1.ck", "manifest-e0.ck", "pass2-r3", "x.ck.tmp.99"] {
            assert!(!dir.join(name).exists(), "{name} must be removed");
        }
        std::fs::remove_dir_all(&dir).ok();
        clean(&dir).unwrap(); // missing dir is a no-op, not an error
    }
}

//! Epoch manifests: the commit records that make a checkpoint epoch
//! *restorable*.
//!
//! Shards land independently per rank; an epoch only becomes a resume
//! point when rank 0 commits `manifest-e{j}.ck` recording every
//! member shard's filename and FNV-1a checksum. Validation at resume
//! re-hashes each shard file against the recorded checksum, so:
//!
//! * a **partial epoch** (some rank died before writing) never
//!   commits — no manifest, not a candidate;
//! * a **stale overwrite** (a later attempt re-wrote a member shard)
//!   invalidates the old manifest — the recorded checksum no longer
//!   matches — and resume falls back to the next older valid one;
//! * a **corrupt manifest or shard** (torn write, bit rot) fails its
//!   own checksum and is skipped the same way.
//!
//! Fallback bottoms out at "no valid manifest", which the retry driver
//! treats as restart-from-zero: progress lost, correctness never.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::shard::{shard_filename, shard_path};
use crate::util::atomic::write_atomic;
use crate::util::codec as c;

pub const MAGIC: &[u8; 8] = b"DOPINFMF";
pub const VERSION: u64 = 1;

/// One committed epoch: every rank's shard file with its checksum.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Manifest {
    pub epoch: u64,
    pub p: usize,
    pub fingerprint: u64,
    /// `(shard filename, fnv1a of its full file bytes)`, rank order
    pub shards: Vec<(String, u64)>,
}

pub fn manifest_path(dir: &Path, epoch: u64) -> PathBuf {
    dir.join(format!("manifest-e{epoch}.ck"))
}

pub fn encode(m: &Manifest) -> Vec<u8> {
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    c::write_u64(&mut buf, VERSION).unwrap();
    c::write_u64(&mut buf, m.epoch).unwrap();
    c::write_usize(&mut buf, m.p).unwrap();
    c::write_u64(&mut buf, m.fingerprint).unwrap();
    c::write_usize(&mut buf, m.shards.len()).unwrap();
    for (name, sum) in &m.shards {
        c::write_str(&mut buf, name).unwrap();
        c::write_u64(&mut buf, *sum).unwrap();
    }
    let checksum = super::fnv1a(&buf);
    c::write_u64(&mut buf, checksum).unwrap();
    buf
}

pub fn decode(bytes: &[u8]) -> Result<Manifest> {
    anyhow::ensure!(bytes.len() >= MAGIC.len() + 16, "manifest truncated");
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(tail.try_into().unwrap());
    let actual = super::fnv1a(body);
    anyhow::ensure!(stored == actual, "manifest checksum mismatch");
    let (magic, mut r) = body.split_at(MAGIC.len());
    anyhow::ensure!(magic == MAGIC, "not a checkpoint manifest (bad magic)");
    let version = c::read_u64(&mut r)?;
    anyhow::ensure!(version == VERSION, "unsupported manifest version {version}");
    let epoch = c::read_u64(&mut r)?;
    let p = c::read_usize(&mut r)?;
    let fingerprint = c::read_u64(&mut r)?;
    let n = c::read_usize(&mut r)?;
    let mut shards = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        let name = c::read_str(&mut r)?;
        let sum = c::read_u64(&mut r)?;
        shards.push((name, sum));
    }
    anyhow::ensure!(r.is_empty(), "trailing bytes after manifest payload");
    Ok(Manifest { epoch, p, fingerprint, shards })
}

/// Is every shard the manifest recorded still on disk with exactly the
/// recorded bytes?
fn members_intact(dir: &Path, m: &Manifest) -> bool {
    m.shards.len() == m.p
        && m.shards.iter().all(|(name, sum)| {
            std::fs::read(dir.join(name)).map(|b| super::fnv1a(&b) == *sum).unwrap_or(false)
        })
}

/// Checksum-validate epoch `epoch`'s full shard set directly (used
/// before a manifest exists). Returns the per-shard file checksums in
/// rank order, or `None` if any shard is missing/corrupt/foreign.
fn epoch_checksums(dir: &Path, epoch: u64, p: usize, fingerprint: u64) -> Option<Vec<u64>> {
    let mut sums = Vec::with_capacity(p);
    for rank in 0..p {
        let bytes = std::fs::read(shard_path(dir, epoch, rank)).ok()?;
        let s = super::shard::decode(&bytes).ok()?;
        if s.epoch != epoch || s.rank != rank || s.p != p || s.fingerprint != fingerprint {
            return None;
        }
        sums.push(super::fnv1a(&bytes));
    }
    Some(sums)
}

/// Rank 0's commit attempt: scan epochs `upto, upto-1, …, 0` and stop
/// at the first that is restorable — either a still-valid existing
/// manifest (nothing to do) or a complete, checksum-valid shard set
/// (commit it, overwriting any stale manifest file at that epoch).
/// Returns the committed/confirmed epoch and the bytes written by this
/// call (0 when an existing manifest was confirmed).
pub fn try_commit(
    dir: &Path,
    p: usize,
    fingerprint: u64,
    upto: u64,
) -> Result<Option<(u64, usize)>> {
    for epoch in (0..=upto).rev() {
        if let Ok(bytes) = std::fs::read(manifest_path(dir, epoch)) {
            if let Ok(m) = decode(&bytes) {
                if m.epoch == epoch && m.fingerprint == fingerprint && members_intact(dir, &m) {
                    return Ok(Some((epoch, 0)));
                }
            }
        }
        if let Some(sums) = epoch_checksums(dir, epoch, p, fingerprint) {
            let m = Manifest {
                epoch,
                p,
                fingerprint,
                shards: (0..p).map(|r| (shard_filename(epoch, r), sums[r])).collect(),
            };
            let bytes = encode(&m);
            let path = manifest_path(dir, epoch);
            write_atomic(&path, &bytes)
                .with_context(|| format!("committing manifest {}", path.display()))?;
            return Ok(Some((epoch, bytes.len())));
        }
    }
    Ok(None)
}

/// The newest restorable epoch: scan the directory's manifests in
/// descending epoch order and return the first that decodes, matches
/// `(p, fingerprint)`, and whose member shards are all intact. `None`
/// means restart from zero.
pub fn newest_valid_manifest(dir: &Path, p: usize, fingerprint: u64) -> Option<u64> {
    let entries = std::fs::read_dir(dir).ok()?;
    let mut epochs: Vec<u64> = entries
        .flatten()
        .filter_map(|e| {
            let name = e.file_name();
            let name = name.to_string_lossy().into_owned();
            name.strip_prefix("manifest-e")?.strip_suffix(".ck")?.parse().ok()
        })
        .collect();
    epochs.sort_unstable();
    for epoch in epochs.into_iter().rev() {
        let Ok(bytes) = std::fs::read(manifest_path(dir, epoch)) else { continue };
        let Ok(m) = decode(&bytes) else { continue };
        if m.epoch == epoch && m.p == p && m.fingerprint == fingerprint && members_intact(dir, &m)
        {
            return Some(epoch);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt::shard::{save, Phase, RankShard};

    fn shard_at(epoch: u64, rank: usize, p: usize, fp: u64) -> RankShard {
        RankShard {
            epoch,
            rank,
            p,
            fingerprint: fp,
            phase: Phase::PassOne,
            cursor: rank + 1,
            means: vec![rank as f64; 3],
            local_max: vec![1.0, 2.0],
            nt: 0,
            gram_d: Vec::new(),
            gram_rows_seen: 0,
            gram_carry: Vec::new(),
            pjrt: false,
            probes: Vec::new(),
            clock_total: 0.25,
            clock_split: [0.25, 0.0, 0.0, 0.0, 0.0],
        }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dopinf_manifest_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn manifest_roundtrips_and_detects_corruption() {
        let m = Manifest {
            epoch: 7,
            p: 2,
            fingerprint: 99,
            shards: vec![("shard-e7-r0.ck".into(), 1), ("shard-e7-r1.ck".into(), 2)],
        };
        let bytes = encode(&m);
        assert_eq!(decode(&bytes).unwrap(), m);
        let mut bad = bytes.clone();
        bad[12] ^= 1;
        assert!(decode(&bad).is_err());
    }

    #[test]
    fn commit_waits_for_the_full_shard_set() {
        let dir = tmp_dir("partial");
        let fp = 42u64;
        save(&dir, &shard_at(0, 0, 2, fp)).unwrap();
        // rank 1's shard hasn't landed: nothing commits
        assert_eq!(try_commit(&dir, 2, fp, 0).unwrap(), None);
        assert_eq!(newest_valid_manifest(&dir, 2, fp), None);
        save(&dir, &shard_at(0, 1, 2, fp)).unwrap();
        let (epoch, bytes) = try_commit(&dir, 2, fp, 0).unwrap().unwrap();
        assert_eq!(epoch, 0);
        assert!(bytes > 0, "first commit writes the manifest");
        assert_eq!(newest_valid_manifest(&dir, 2, fp), Some(0));
        // re-confirming writes nothing new
        assert_eq!(try_commit(&dir, 2, fp, 0).unwrap(), Some((0, 0)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn newest_complete_epoch_wins_and_corruption_falls_back() {
        let dir = tmp_dir("fallback");
        let fp = 7u64;
        for epoch in 0..3u64 {
            for rank in 0..2 {
                save(&dir, &shard_at(epoch, rank, 2, fp)).unwrap();
            }
            try_commit(&dir, 2, fp, epoch).unwrap();
        }
        assert_eq!(newest_valid_manifest(&dir, 2, fp), Some(2));
        // corrupt a member shard of epoch 2: resume must fall back to 1
        let victim = shard_path(&dir, 2, 1);
        let mut bytes = std::fs::read(&victim).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&victim, &bytes).unwrap();
        assert_eq!(newest_valid_manifest(&dir, 2, fp), Some(1));
        // delete a shard of epoch 1 as well: fall back to 0
        std::fs::remove_file(shard_path(&dir, 1, 0)).unwrap();
        assert_eq!(newest_valid_manifest(&dir, 2, fp), Some(0));
        // a foreign fingerprint sees nothing restorable at all
        assert_eq!(newest_valid_manifest(&dir, 2, fp + 1), None);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_manifest_is_recommitted_after_overwrite() {
        let dir = tmp_dir("stale");
        let fp = 5u64;
        for rank in 0..2 {
            save(&dir, &shard_at(0, rank, 2, fp)).unwrap();
        }
        try_commit(&dir, 2, fp, 0).unwrap();
        // a later attempt overwrites rank 0's shard with different
        // content: the old manifest's recorded checksum goes stale
        let mut s = shard_at(0, 0, 2, fp);
        s.clock_total = 9.75;
        save(&dir, &s).unwrap();
        assert_eq!(newest_valid_manifest(&dir, 2, fp), None, "stale manifest must not validate");
        // the next commit attempt re-commits epoch 0 over the fresh set
        let (epoch, bytes) = try_commit(&dir, 2, fp, 0).unwrap().unwrap();
        assert_eq!((epoch, bytes > 0), (0, true));
        assert_eq!(newest_valid_manifest(&dir, 2, fp), Some(0));
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! CSV writer for figure/bench series (`results/*.csv`).
//!
//! Every bench that regenerates a paper figure emits its series here so
//! plots can be rebuilt outside the harness.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Column-oriented CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    /// Create the file (and parent dirs) and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<CsvWriter> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, cols: header.len() })
    }

    /// Write one row of f64 cells (full precision).
    pub fn row(&mut self, cells: &[f64]) -> std::io::Result<()> {
        assert_eq!(cells.len(), self.cols, "row width != header width");
        let line = cells.iter().map(|c| format!("{c:.17e}")).collect::<Vec<_>>().join(",");
        writeln!(self.out, "{line}")
    }

    /// Write one row of preformatted string cells.
    pub fn row_str(&mut self, cells: &[String]) -> std::io::Result<()> {
        assert_eq!(cells.len(), self.cols, "row width != header width");
        writeln!(self.out, "{}", cells.join(","))
    }

    pub fn finish(mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("dopinf_csv_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&[1.0, 2.5]).unwrap();
        w.row_str(&["x".into(), "y".into()]).unwrap();
        w.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "a,b");
        assert!(lines[1].starts_with("1."));
        assert_eq!(lines[2], "x,y");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn panics_on_bad_width() {
        let dir = std::env::temp_dir().join("dopinf_csv_test2");
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a", "b"]).unwrap();
        let _ = w.row(&[1.0]);
    }
}

//! Panic-payload helpers for the failure-isolation layers (comm abort
//! broadcast, RomServer worker recovery).

use std::any::Any;

/// Best-effort text of a caught panic payload (`&str` and `String`
/// payloads cover `panic!`/`assert!`; anything else is labeled).
pub fn panic_text(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extracts_str_and_string_payloads() {
        let p = std::panic::catch_unwind(|| panic!("static text")).unwrap_err();
        assert_eq!(panic_text(&*p), "static text");
        let n = 7;
        let p = std::panic::catch_unwind(move || panic!("formatted {n}")).unwrap_err();
        assert_eq!(panic_text(&*p), "formatted 7");
        let p = std::panic::catch_unwind(|| std::panic::panic_any(42i32)).unwrap_err();
        assert_eq!(panic_text(&*p), "non-string panic payload");
    }
}

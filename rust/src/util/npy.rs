//! NPY v1.0 writer/reader for f64 arrays.
//!
//! The paper's tutorial saves probe predictions with `np.save`; we keep
//! the same on-disk format so its postprocessing notebooks can load our
//! outputs directly, and so python tests can cross-check Rust results.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Write a little-endian f64 array of arbitrary shape (C order).
pub fn write_f64<P: AsRef<Path>>(path: P, shape: &[usize], data: &[f64]) -> Result<()> {
    let count: usize = shape.iter().product();
    if count != data.len() {
        bail!("shape {:?} has {} elements, data has {}", shape, count, data.len());
    }
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut out = BufWriter::new(File::create(path)?);

    let shape_str = match shape.len() {
        1 => format!("({},)", shape[0]),
        _ => format!("({})", shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")),
    };
    let mut header =
        format!("{{'descr': '<f8', 'fortran_order': False, 'shape': {shape_str}, }}");
    // pad so magic(6)+version(2)+len(2)+header is a multiple of 64, ending in \n
    let unpadded = 10 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');

    out.write_all(b"\x93NUMPY\x01\x00")?;
    out.write_all(&(header.len() as u16).to_le_bytes())?;
    out.write_all(header.as_bytes())?;
    for v in data {
        out.write_all(&v.to_le_bytes())?;
    }
    out.flush()?;
    Ok(())
}

/// Read an NPY file of little-endian f64 (C order). Returns (shape, data).
pub fn read_f64<P: AsRef<Path>>(path: P) -> Result<(Vec<usize>, Vec<f64>)> {
    let mut input = BufReader::new(File::open(&path).with_context(|| {
        format!("open {:?}", path.as_ref())
    })?);
    let mut magic = [0u8; 8];
    input.read_exact(&mut magic)?;
    if &magic[..6] != b"\x93NUMPY" {
        bail!("not an NPY file");
    }
    let mut len_bytes = [0u8; 2];
    input.read_exact(&mut len_bytes)?;
    let header_len = u16::from_le_bytes(len_bytes) as usize;
    let mut header = vec![0u8; header_len];
    input.read_exact(&mut header)?;
    let header = String::from_utf8_lossy(&header);

    if !header.contains("'descr': '<f8'") {
        bail!("only <f8 supported, header: {header}");
    }
    if header.contains("'fortran_order': True") {
        bail!("fortran order not supported");
    }
    let shape_part = header
        .split("'shape':")
        .nth(1)
        .context("no shape in header")?
        .split('(')
        .nth(1)
        .context("bad shape")?
        .split(')')
        .next()
        .context("bad shape")?;
    let shape: Vec<usize> = shape_part
        .split(',')
        .map(|s| s.trim())
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<usize>().context("bad dim"))
        .collect::<Result<_>>()?;

    let count: usize = shape.iter().product();
    let mut bytes = Vec::with_capacity(count * 8);
    input.read_to_end(&mut bytes)?;
    if bytes.len() < count * 8 {
        bail!("truncated NPY: want {} bytes, have {}", count * 8, bytes.len());
    }
    let data = bytes[..count * 8]
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok((shape, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_2d() {
        let dir = std::env::temp_dir().join("dopinf_npy_test");
        let path = dir.join("a.npy");
        let data: Vec<f64> = (0..12).map(|i| i as f64 * 0.5).collect();
        write_f64(&path, &[3, 4], &data).unwrap();
        let (shape, got) = read_f64(&path).unwrap();
        assert_eq!(shape, vec![3, 4]);
        assert_eq!(got, data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn roundtrip_1d() {
        let dir = std::env::temp_dir().join("dopinf_npy_test1d");
        let path = dir.join("b.npy");
        write_f64(&path, &[5], &[1.0, -2.0, 3.5, f64::MIN_POSITIVE, 0.0]).unwrap();
        let (shape, got) = read_f64(&path).unwrap();
        assert_eq!(shape, vec![5]);
        assert_eq!(got[3], f64::MIN_POSITIVE);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shape_mismatch_rejected() {
        let dir = std::env::temp_dir().join("dopinf_npy_test_bad");
        assert!(write_f64(dir.join("c.npy"), &[2, 2], &[1.0]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn numpy_can_read_our_header_format() {
        // Validate the header is byte-exact to numpy's convention:
        // total header block (magic..newline) multiple of 64.
        let dir = std::env::temp_dir().join("dopinf_npy_test_hdr");
        let path = dir.join("d.npy");
        write_f64(&path, &[7], &vec![0.0; 7]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let header_len = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + header_len) % 64, 0);
        assert_eq!(bytes[10 + header_len - 1], b'\n');
        std::fs::remove_dir_all(&dir).ok();
    }
}

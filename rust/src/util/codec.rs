//! Length-prefixed little-endian byte codec over any `Read`/`Write`.
//!
//! The socket transport frames its collective payloads inline
//! (`comm::socket`); this module is the substrate for everything
//! *around* those collectives that must also cross a process boundary:
//! the job description a spawned worker receives (config, data source,
//! trace flag — see `coordinator::launch`) and the join report it
//! ships back (clock parts, trace, per-rank result — see
//! `comm::proc`). Every scalar is little-endian; every variable-length
//! field carries a `u64` byte/element count first, so a reader never
//! guesses at boundaries.

use std::io::{self, Read, Write};

pub fn write_u8(w: &mut (impl Write + ?Sized), v: u8) -> io::Result<()> {
    w.write_all(&[v])
}

pub fn read_u8(r: &mut (impl Read + ?Sized)) -> io::Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

pub fn write_u64(w: &mut (impl Write + ?Sized), v: u64) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

pub fn read_u64(r: &mut (impl Read + ?Sized)) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// `usize` rides the wire as `u64` (ranks on different machines must
/// agree on the width).
pub fn write_usize(w: &mut (impl Write + ?Sized), v: usize) -> io::Result<()> {
    write_u64(w, v as u64)
}

pub fn read_usize(r: &mut (impl Read + ?Sized)) -> io::Result<usize> {
    Ok(read_u64(r)? as usize)
}

pub fn write_f64(w: &mut (impl Write + ?Sized), v: f64) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

pub fn read_f64(r: &mut (impl Read + ?Sized)) -> io::Result<f64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

pub fn write_bool(w: &mut (impl Write + ?Sized), v: bool) -> io::Result<()> {
    write_u8(w, u8::from(v))
}

pub fn read_bool(r: &mut (impl Read + ?Sized)) -> io::Result<bool> {
    match read_u8(r)? {
        0 => Ok(false),
        1 => Ok(true),
        other => Err(corrupt(format!("bool byte {other}"))),
    }
}

/// `len u64 | bytes`.
pub fn write_bytes(w: &mut (impl Write + ?Sized), b: &[u8]) -> io::Result<()> {
    write_u64(w, b.len() as u64)?;
    w.write_all(b)
}

pub fn read_bytes(r: &mut (impl Read + ?Sized)) -> io::Result<Vec<u8>> {
    let len = read_usize(r)?;
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

/// A UTF-8 string as [`write_bytes`].
pub fn write_str(w: &mut (impl Write + ?Sized), s: &str) -> io::Result<()> {
    write_bytes(w, s.as_bytes())
}

pub fn read_str(r: &mut (impl Read + ?Sized)) -> io::Result<String> {
    String::from_utf8(read_bytes(r)?).map_err(|e| corrupt(format!("non-UTF-8 string: {e}")))
}

/// `len u64 | f64 × len`.
pub fn write_f64s(w: &mut (impl Write + ?Sized), v: &[f64]) -> io::Result<()> {
    write_u64(w, v.len() as u64)?;
    let mut raw = Vec::with_capacity(v.len() * 8);
    for x in v {
        raw.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&raw)
}

pub fn read_f64s(r: &mut (impl Read + ?Sized)) -> io::Result<Vec<f64>> {
    let len = read_usize(r)?;
    let mut raw = vec![0u8; len * 8];
    r.read_exact(&mut raw)?;
    Ok(raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
}

/// An `Option<T>` as `present u8 | payload if present`.
pub fn write_opt<T>(
    w: &mut (impl Write + ?Sized),
    v: Option<&T>,
    f: impl FnOnce(&mut dyn Write, &T) -> io::Result<()>,
) -> io::Result<()> {
    match v {
        None => write_u8(w, 0),
        Some(t) => {
            write_u8(w, 1)?;
            f(w, t)
        }
    }
}

pub fn read_opt<T>(
    r: &mut (impl Read + ?Sized),
    f: impl FnOnce(&mut dyn Read) -> io::Result<T>,
) -> io::Result<Option<T>> {
    match read_u8(r)? {
        0 => Ok(None),
        1 => Ok(Some(f(r)?)),
        other => Err(corrupt(format!("option byte {other}"))),
    }
}

pub fn corrupt(detail: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("corrupt frame ({detail})"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn scalars_roundtrip() {
        let mut buf = Vec::new();
        write_u8(&mut buf, 7).unwrap();
        write_u64(&mut buf, u64::MAX - 3).unwrap();
        write_usize(&mut buf, 123_456).unwrap();
        write_f64(&mut buf, -0.1f64).unwrap();
        write_bool(&mut buf, true).unwrap();
        write_bool(&mut buf, false).unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_u8(&mut r).unwrap(), 7);
        assert_eq!(read_u64(&mut r).unwrap(), u64::MAX - 3);
        assert_eq!(read_usize(&mut r).unwrap(), 123_456);
        assert_eq!(read_f64(&mut r).unwrap().to_bits(), (-0.1f64).to_bits());
        assert!(read_bool(&mut r).unwrap());
        assert!(!read_bool(&mut r).unwrap());
    }

    #[test]
    fn strings_and_vectors_roundtrip() {
        let mut buf = Vec::new();
        write_str(&mut buf, "hub 127.0.0.1:4242 — κ").unwrap();
        write_f64s(&mut buf, &[1e16, -1.0, 3.5e-13]).unwrap();
        write_bytes(&mut buf, &[]).unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_str(&mut r).unwrap(), "hub 127.0.0.1:4242 — κ");
        let v = read_f64s(&mut r).unwrap();
        assert_eq!(v.len(), 3);
        assert_eq!(v[0].to_bits(), 1e16f64.to_bits());
        assert_eq!(v[2].to_bits(), 3.5e-13f64.to_bits());
        assert!(read_bytes(&mut r).unwrap().is_empty());
    }

    #[test]
    fn options_roundtrip() {
        let mut buf = Vec::new();
        write_opt(&mut buf, Some(&2.5f64), |w, v| write_f64(w, *v)).unwrap();
        write_opt::<f64>(&mut buf, None, |w, v| write_f64(w, *v)).unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_opt(&mut r, read_f64).unwrap(), Some(2.5));
        assert_eq!(read_opt(&mut r, read_f64).unwrap(), None);
    }

    #[test]
    fn truncated_input_errors_cleanly() {
        let mut buf = Vec::new();
        write_f64s(&mut buf, &[1.0, 2.0, 3.0]).unwrap();
        buf.truncate(buf.len() - 4);
        let mut r = Cursor::new(buf);
        assert!(read_f64s(&mut r).is_err());
        assert!(read_bool(&mut Cursor::new(vec![9u8])).is_err());
    }
}

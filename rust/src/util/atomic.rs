//! Crash-safe file persistence: temp-file + atomic rename.
//!
//! Every durable artifact this crate writes — `.rom` model files,
//! SNAPD datasets, checkpoint shards and manifests — goes through this
//! module so a reader can never observe a torn file. The protocol is
//! the classic one: write the full payload to a same-directory sibling
//! (`<name>.tmp.<pid>`), fsync it, then `rename` onto the final path.
//! On POSIX the rename is atomic within a filesystem, so concurrent
//! readers see either the old complete file or the new complete file,
//! never a prefix. A crash mid-write leaves only an orphaned `.tmp.*`
//! sibling, which later writers ignore and overwrite.

use std::fs::File;
use std::io::{self, Write};
use std::path::{Path, PathBuf};

/// The temp sibling a writer stages into before promoting: same
/// directory (rename must not cross filesystems), suffixed with the
/// writer's pid so concurrent processes never stage into each other.
pub fn temp_sibling(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(format!(".tmp.{}", std::process::id()));
    path.with_file_name(name)
}

/// Promote a fully-written temp file onto its final path. The caller
/// must have flushed (and ideally synced) `tmp` first. On failure the
/// temp file is removed so retries start clean.
pub fn promote(tmp: &Path, path: &Path) -> io::Result<()> {
    std::fs::rename(tmp, path).inspect_err(|_| {
        std::fs::remove_file(tmp).ok();
    })
}

/// Write `bytes` to `path` atomically: stage into [`temp_sibling`],
/// fsync, rename. The final path either keeps its previous content or
/// holds exactly `bytes` — never a truncated mix.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> io::Result<()> {
    let tmp = temp_sibling(path);
    let stage = (|| {
        let mut f = File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()
    })();
    if let Err(e) = stage {
        std::fs::remove_file(&tmp).ok();
        return Err(e);
    }
    promote(&tmp, path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dopinf_atomic_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn write_atomic_replaces_content_and_leaves_no_temp() {
        let path = tmp_dir().join("a.bin");
        write_atomic(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        write_atomic(&path, b"second, longer payload").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second, longer payload");
        assert!(!temp_sibling(&path).exists(), "temp sibling must not survive");
    }

    #[test]
    fn temp_sibling_stays_in_the_same_directory() {
        let path = Path::new("/some/dir/file.rom");
        let t = temp_sibling(path);
        assert_eq!(t.parent(), path.parent());
        let name = t.file_name().unwrap().to_string_lossy().into_owned();
        assert!(name.starts_with("file.rom.tmp."), "{name}");
    }

    #[test]
    fn failed_promote_cleans_the_temp_file() {
        let dir = tmp_dir();
        let tmp = dir.join("stage.tmp.x");
        std::fs::write(&tmp, b"payload").unwrap();
        // the destination's parent does not exist ⇒ rename must fail
        let dest = dir.join("missing_subdir").join("out.bin");
        assert!(promote(&tmp, &dest).is_err());
        assert!(!tmp.exists(), "temp file must be removed on failed promote");
    }
}

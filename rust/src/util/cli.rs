//! Tiny CLI argument parser (substrate; `clap` is not in the vendored set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positionals, with
//! typed getters, defaults, and a generated usage string.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declarative option spec used for usage/help rendering.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments for one (sub)command. Value-options may repeat
/// (`--model a.rom --model b.rom`); [`Args::get`] returns the last
/// occurrence, [`Args::get_all`] every occurrence in order.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    positional: Vec<String>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

impl Args {
    /// Parse raw tokens. `specs` distinguishes value-options from flags.
    pub fn parse(tokens: &[String], specs: &[OptSpec]) -> Result<Args, CliError> {
        let mut args = Args::default();
        let is_flag = |name: &str| {
            specs.iter().any(|s| s.name == name && s.is_flag)
        };
        let known = |name: &str| specs.iter().any(|s| s.name == name);
        let mut it = tokens.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                if !known(&name) {
                    return Err(CliError(format!("unknown option --{name}")));
                }
                if is_flag(&name) {
                    if inline_val.is_some() {
                        return Err(CliError(format!("--{name} takes no value")));
                    }
                    args.flags.push(name);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| CliError(format!("--{name} needs a value")))?,
                    };
                    args.opts.entry(name).or_default().push(val);
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Last value given for `name` (repeating an option overrides).
    pub fn get<'a>(&'a self, name: &str) -> Option<&'a str> {
        self.opts.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// Every value given for `name`, in command-line order.
    pub fn get_all<'a>(&'a self, name: &str) -> &'a [String] {
        self.opts.get(name).map(Vec::as_slice).unwrap_or(&[])
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| CliError(format!("--{name}: cannot parse {v:?}"))),
        }
    }

    /// Parse a comma-separated list of T (e.g. `--procs 1,2,4,8`).
    pub fn get_list<T: std::str::FromStr>(
        &self,
        name: &str,
        default: &[T],
    ) -> Result<Vec<T>, CliError>
    where
        T: Clone,
    {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| {
                    x.trim()
                        .parse::<T>()
                        .map_err(|_| CliError(format!("--{name}: cannot parse {x:?}")))
                })
                .collect(),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Render a usage block from option specs.
pub fn usage(cmd: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "{about}\n\nUsage: dopinf {cmd} [options]\n\nOptions:");
    for s in specs {
        let head = if s.is_flag {
            format!("  --{}", s.name)
        } else {
            format!("  --{} <value>", s.name)
        };
        let default = s.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
        let _ = writeln!(out, "{head:<28}{}{}", s.help, default);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "grid", help: "nx x ny", default: Some("288x54"), is_flag: false },
            OptSpec { name: "procs", help: "ranks", default: Some("4"), is_flag: false },
            OptSpec { name: "verbose", help: "chatty", default: None, is_flag: true },
        ]
    }

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_options_and_flags() {
        let a = Args::parse(&toks(&["--grid", "64x32", "--verbose", "pos1"]), &specs()).unwrap();
        assert_eq!(a.get("grid"), Some("64x32"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn parses_equals_form() {
        let a = Args::parse(&toks(&["--procs=8"]), &specs()).unwrap();
        assert_eq!(a.get_parse::<usize>("procs", 4).unwrap(), 8);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&[], &specs()).unwrap();
        assert_eq!(a.get_or("grid", "288x54"), "288x54");
        assert_eq!(a.get_parse::<usize>("procs", 4).unwrap(), 4);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn rejects_unknown_and_missing_value() {
        assert!(Args::parse(&toks(&["--nope"]), &specs()).is_err());
        assert!(Args::parse(&toks(&["--grid"]), &specs()).is_err());
        assert!(Args::parse(&toks(&["--verbose=1"]), &specs()).is_err());
    }

    #[test]
    fn repeated_options_accumulate() {
        let a =
            Args::parse(&toks(&["--grid", "1x1", "--grid", "2x2", "--grid=3x3"]), &specs()).unwrap();
        assert_eq!(a.get("grid"), Some("3x3")); // last wins for get()
        assert_eq!(a.get_all("grid"), &["1x1", "2x2", "3x3"]);
        assert!(a.get_all("procs").is_empty());
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(&toks(&["--procs", "1,2,4,8"]), &specs()).unwrap();
        assert_eq!(a.get_list::<usize>("procs", &[4]).unwrap(), vec![1, 2, 4, 8]);
        let b = Args::parse(&[], &specs()).unwrap();
        assert_eq!(b.get_list::<usize>("procs", &[1, 2]).unwrap(), vec![1, 2]);
    }

    #[test]
    fn usage_renders() {
        let u = usage("simulate", "Run the flow solver", &specs());
        assert!(u.contains("--grid"));
        assert!(u.contains("[default: 288x54]"));
    }
}

//! Small self-contained substrates the rest of the crate builds on.
//!
//! The offline vendored crate set has no `clap`/`serde_json`/`rand`/
//! `criterion`/`proptest`, so this module provides from-scratch,
//! fully-tested replacements: a splitmix/xorshift RNG, a JSON
//! parser/emitter, a CLI argument parser, NPY/CSV writers, wall+thread
//! CPU timers, a property-test mini-framework, and a bench harness.

pub mod atomic;
pub mod benchkit;
pub mod cli;
pub mod codec;
pub mod csvout;
pub mod json;
pub mod npy;
pub mod panic;
pub mod propcheck;
pub mod rng;
pub mod timer;

//! Mini property-based testing framework (substrate; `proptest` is not in
//! the vendored crate set — DESIGN.md §3).
//!
//! Deterministic: each case derives from a fixed seed + case index, so
//! failures are reproducible by rerunning the test. On failure the case
//! index and generated inputs (via Debug) are reported.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xD0_91_F0 }
    }
}

/// Run `prop` against `cases` generated inputs. `gen` draws one input
/// from the RNG. Panics (failing the enclosing #[test]) on the first
/// falsified case, reporting the case index and input.
pub fn check<T: std::fmt::Debug>(
    config: Config,
    mut generate: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..config.cases {
        let mut rng = Rng::new(config.seed.wrapping_add(case as u64 * 0x9E37));
        let input = generate(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property falsified at case {case}/{}: {msg}\ninput: {input:#?}",
                config.cases
            );
        }
    }
}

/// Shorthand with default config.
pub fn quick<T: std::fmt::Debug>(
    generate: impl FnMut(&mut Rng) -> T,
    prop: impl FnMut(&T) -> Result<(), String>,
) {
    check(Config::default(), generate, prop)
}

/// Assert two floats are close (absolute + relative tolerance), with a
/// useful error message for property bodies.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> Result<(), String> {
    let diff = (a - b).abs();
    let bound = atol + rtol * b.abs().max(a.abs());
    if diff <= bound || (a.is_nan() && b.is_nan()) {
        Ok(())
    } else {
        Err(format!("{a} != {b} (diff {diff:.3e} > bound {bound:.3e})"))
    }
}

/// Assert two slices are elementwise close.
pub fn all_close(a: &[f64], b: &[f64], rtol: f64, atol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        close(x, y, rtol, atol).map_err(|e| format!("at index {i}: {e}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        quick(
            |rng| (rng.uniform(), rng.uniform()),
            |(a, b)| {
                if a + b >= *a {
                    Ok(())
                } else {
                    Err("addition of non-negatives decreased".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property falsified")]
    fn fails_false_property() {
        quick(
            |rng| rng.uniform(),
            |x| if *x < 0.5 { Ok(()) } else { Err("x >= 0.5".into()) },
        );
    }

    #[test]
    fn deterministic_generation() {
        let mut first: Vec<f64> = Vec::new();
        check(
            Config { cases: 5, seed: 9 },
            |rng| rng.uniform(),
            |x| {
                first.push(*x);
                Ok(())
            },
        );
        let mut second: Vec<f64> = Vec::new();
        check(
            Config { cases: 5, seed: 9 },
            |rng| rng.uniform(),
            |x| {
                second.push(*x);
                Ok(())
            },
        );
        assert_eq!(first, second);
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-10, 0.0).is_ok());
        assert!(close(1.0, 1.1, 1e-10, 0.0).is_err());
        assert!(all_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-13], 1e-10, 0.0).is_ok());
        assert!(all_close(&[1.0], &[1.0, 2.0], 1e-10, 0.0).is_err());
    }
}

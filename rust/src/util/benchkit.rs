//! Micro/macro bench harness (substrate; `criterion` is not in the
//! vendored crate set).
//!
//! Benches are plain binaries registered with `harness = false`; each
//! builds a [`Bench`] and reports mean ± std over warmup + measured
//! iterations, plus throughput when element counts are supplied. Paper
//! figures use [`Bench::run_sampled`] with explicit repeat counts (the
//! paper repeats each measurement 100×). [`Bench::write_json`] emits
//! the machine-readable side (one report object per row) so perf
//! trajectories diff across commits.

use crate::util::json::{emit, Json};
use crate::util::timer::{mean_std, WallTimer};

/// One benchmark report row.
#[derive(Clone, Debug)]
pub struct Report {
    pub name: String,
    pub mean_s: f64,
    pub std_s: f64,
    pub samples: usize,
    /// elements processed per iteration (for throughput), if meaningful
    pub elems: Option<usize>,
}

impl Report {
    pub fn throughput(&self) -> Option<f64> {
        self.elems.map(|e| e as f64 / self.mean_s)
    }

    pub fn render(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e9 => format!("  {:.2} Gelem/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:.2} Melem/s", t / 1e6),
            Some(t) => format!("  {t:.2} elem/s"),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12.6} s ± {:>10.6} s  (n={}){tp}",
            self.name, self.mean_s, self.std_s, self.samples
        )
    }

    /// Machine-readable form of one report row.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::Str(self.name.clone())),
            ("mean_s", Json::Num(self.mean_s)),
            ("std_s", Json::Num(self.std_s)),
            ("samples", Json::Num(self.samples as f64)),
        ];
        if let Some(e) = self.elems {
            pairs.push(("elems", Json::Num(e as f64)));
            if let Some(tp) = self.throughput() {
                pairs.push(("throughput_per_s", Json::Num(tp)));
            }
        }
        Json::obj(pairs)
    }
}

/// Bench runner: prints rows as they complete and collects reports.
pub struct Bench {
    pub reports: Vec<Report>,
    warmup: usize,
    samples: usize,
}

impl Bench {
    pub fn new() -> Self {
        // Respect quick runs: DOPINF_BENCH_SAMPLES=10 etc. The default
        // favors one-core CI wall-time; the paper-style 100-repeat runs
        // are opt-in.
        let samples = std::env::var("DOPINF_BENCH_SAMPLES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(5);
        Bench { reports: Vec::new(), warmup: 1, samples }
    }

    pub fn with_samples(samples: usize, warmup: usize) -> Self {
        Bench { reports: Vec::new(), warmup, samples }
    }

    /// Time `f` for the configured warmup+samples; prints and records.
    pub fn run<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> &Report {
        self.run_with_elems(name, None, &mut f)
    }

    /// Like [`run`], also recording per-iteration element counts.
    pub fn run_elems<R>(&mut self, name: &str, elems: usize, mut f: impl FnMut() -> R) -> &Report {
        self.run_with_elems(name, Some(elems), &mut f)
    }

    fn run_with_elems<R>(
        &mut self,
        name: &str,
        elems: Option<usize>,
        f: &mut impl FnMut() -> R,
    ) -> &Report {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = WallTimer::start();
            std::hint::black_box(f());
            times.push(t.elapsed());
        }
        let (mean_s, std_s) = mean_std(&times);
        let report = Report { name: name.to_string(), mean_s, std_s, samples: self.samples, elems };
        println!("{}", report.render());
        self.reports.push(report);
        self.reports.last().unwrap()
    }

    /// Record an externally-measured sample series under `name`.
    pub fn record_samples(&mut self, name: &str, samples: &[f64]) -> &Report {
        let (mean_s, std_s) = mean_std(samples);
        let report = Report {
            name: name.to_string(),
            mean_s,
            std_s,
            samples: samples.len(),
            elems: None,
        };
        println!("{}", report.render());
        self.reports.push(report);
        self.reports.last().unwrap()
    }

    pub fn find(&self, name: &str) -> Option<&Report> {
        self.reports.iter().find(|r| r.name == name)
    }

    /// Write every recorded report as one JSON document to `path`
    /// (parent directories created).
    pub fn write_json<P: AsRef<std::path::Path>>(&self, path: P) -> std::io::Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let doc = Json::obj(vec![(
            "reports",
            Json::Arr(self.reports.iter().map(Report::to_json).collect()),
        )]);
        std::fs::write(path, emit(&doc))
    }
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_records() {
        let mut b = Bench::with_samples(3, 1);
        b.run("noop", || 1 + 1);
        b.run_elems("withelems", 1000, || (0..100).sum::<usize>());
        assert_eq!(b.reports.len(), 2);
        assert!(b.find("noop").is_some());
        assert!(b.find("withelems").unwrap().throughput().unwrap() > 0.0);
        assert!(b.reports[0].mean_s >= 0.0);
    }

    #[test]
    fn record_external_samples() {
        let mut b = Bench::with_samples(1, 0);
        let r = b.record_samples("ext", &[1.0, 2.0, 3.0]).clone();
        assert!((r.mean_s - 2.0).abs() < 1e-12);
        assert_eq!(r.samples, 3);
    }

    #[test]
    fn json_output_roundtrips() {
        let mut b = Bench::with_samples(2, 0);
        b.run_elems("collective x", 4096, || (0..50_000u64).map(|i| i ^ 0x55).sum::<u64>());
        b.run("no elems", || (0..50_000u64).map(|i| i | 0x3).sum::<u64>());
        let path = std::env::temp_dir().join("dopinf_benchkit_test").join("out.json");
        b.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::util::json::parse(&text).unwrap();
        let reports = doc.get("reports").and_then(crate::util::json::Json::as_arr).unwrap();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].get("name").and_then(Json::as_str), Some("collective x"));
        assert_eq!(reports[0].get("elems").and_then(Json::as_usize), Some(4096));
        assert!(reports[0].get("throughput_per_s").and_then(Json::as_f64).unwrap_or(0.0) > 0.0);
        assert!(reports[1].get("elems").is_none());
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn report_render_contains_name() {
        let r = Report { name: "x".into(), mean_s: 0.5, std_s: 0.1, samples: 4, elems: Some(2_000_000) };
        let s = r.render();
        assert!(s.contains('x') && s.contains("Melem/s"));
    }
}

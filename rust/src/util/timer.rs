//! Wall-clock and per-thread CPU timers.
//!
//! The scaling study (paper Fig. 4) measures *per-rank compute time*:
//! since this testbed has a single physical core, rank threads timeshare
//! and wall-clock cannot show strong scaling. [`ThreadCpuTimer`] reads
//! `CLOCK_THREAD_CPUTIME_ID`, which charges each rank only for cycles it
//! actually executed — giving the virtual per-rank clocks described in
//! DESIGN.md §3.

use std::time::Instant;

/// Wall-clock stopwatch.
#[derive(Debug)]
pub struct WallTimer {
    start: Instant,
}

impl WallTimer {
    pub fn start() -> Self {
        WallTimer { start: Instant::now() }
    }
    /// Elapsed seconds since start.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Seconds of CPU time consumed by the *calling thread* so far.
pub fn thread_cpu_time() -> f64 {
    let mut ts = libc::timespec { tv_sec: 0, tv_nsec: 0 };
    // SAFETY: ts is a valid out-pointer; CLOCK_THREAD_CPUTIME_ID is
    // supported on all Linux targets this crate builds for.
    let rc = unsafe { libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts) };
    debug_assert_eq!(rc, 0);
    ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
}

/// Per-thread CPU stopwatch (excludes time the thread spent descheduled).
#[derive(Debug)]
pub struct ThreadCpuTimer {
    start: f64,
}

impl ThreadCpuTimer {
    pub fn start() -> Self {
        ThreadCpuTimer { start: thread_cpu_time() }
    }
    /// CPU seconds this thread burned since start, clamped to zero.
    ///
    /// `CLOCK_THREAD_CPUTIME_ID` is per-CPU state under the hood: after
    /// a migration across cores with imperfectly synchronized TSCs, a
    /// later reading can come out *below* an earlier one by a few ns.
    /// A negative delta would poison every downstream consumer
    /// (`Clock::add` debug-asserts non-negative charges; the virtual
    /// clocks and timing tables silently lose time in release), so the
    /// delta saturates at zero — the same contract
    /// `Instant::duration_since` adopted for wall clocks.
    pub fn elapsed(&self) -> f64 {
        (thread_cpu_time() - self.start).max(0.0)
    }
}

/// Mean and (sample) standard deviation of a series of measurements.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    assert!(!xs.is_empty());
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_timer_monotone() {
        let t = WallTimer::start();
        let a = t.elapsed();
        let b = t.elapsed();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn thread_cpu_advances_under_load() {
        let t = ThreadCpuTimer::start();
        // burn some cycles
        let mut acc = 0u64;
        for i in 0..2_000_000u64 {
            acc = acc.wrapping_add(i * i);
        }
        std::hint::black_box(acc);
        assert!(t.elapsed() > 0.0);
    }

    #[test]
    fn thread_cpu_excludes_sleep() {
        let t = ThreadCpuTimer::start();
        std::thread::sleep(std::time::Duration::from_millis(50));
        // CPU time during sleep should be ~0, certainly far below wall 50ms
        assert!(t.elapsed() < 0.02, "cpu={}", t.elapsed());
    }

    #[test]
    fn thread_cpu_clamps_nonmonotonic_readings_to_zero() {
        // simulate a cross-core migration where the new core's clock is
        // behind: a timer whose start is in the "future" must report
        // 0.0, never a negative delta
        let t = ThreadCpuTimer { start: thread_cpu_time() + 1e9 };
        assert_eq!(t.elapsed(), 0.0);
    }

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.138089935299395).abs() < 1e-12);
        let (m1, s1) = mean_std(&[3.5]);
        assert_eq!((m1, s1), (3.5, 0.0));
    }
}

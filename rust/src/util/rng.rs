//! Deterministic pseudo-random numbers (xoshiro256**, seeded via splitmix64).
//!
//! Used by the synthetic workloads, property tests, and benches; the
//! vendored crate set has no `rand`, and determinism across runs is a
//! feature for reproducing the paper's figures bit-for-bit.

/// xoshiro256** generator — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed (any value, including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double mantissa resolution
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (n > 0), unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }
}

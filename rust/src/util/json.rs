//! Minimal JSON parser + emitter (RFC 8259 subset sufficient for
//! `artifacts/manifest.json`, config files, and results output).
//!
//! Substrate note (DESIGN.md §3): `serde_json` is not in the vendored
//! crate set, so this is a from-scratch recursive-descent implementation.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so emission is
/// deterministic — results files diff cleanly across runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `None` for non-objects/missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, ParseError> {
        Err(ParseError { offset: self.pos, message: msg.to_string() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            self.err(&format!("expected '{}'", b as char))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            self.err(&format!("expected '{lit}'"))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => self.err("unexpected character"),
            None => self.err("unexpected end of input"),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or(ParseError {
                                offset: self.pos,
                                message: "bad \\u escape".into(),
                            })?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or(ParseError {
                                    offset: self.pos,
                                    message: "bad hex digit".into(),
                                })?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy the remaining continuation bytes
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| ParseError {
                                offset: start,
                                message: "invalid utf-8".into(),
                            })?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or(ParseError { offset: start, message: "invalid number".into() })
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a JSON document (trailing whitespace allowed, trailing garbage not).
pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn emit_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                emit_into(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                emit_into(val, out);
            }
            out.push('}');
        }
    }
}

/// Serialize a JSON value compactly.
pub fn emit(v: &Json) -> String {
    let mut out = String::new();
    emit_into(v, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let doc = r#"{"entries":[{"name":"gram","shape":[64,24],"ok":true}],"v":1}"#;
        let j = parse(doc).unwrap();
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("name").unwrap().as_str().unwrap(), "gram");
        assert_eq!(e.get("shape").unwrap().as_arr().unwrap()[1].as_usize().unwrap(), 24);
        assert_eq!(j.get("v").unwrap().as_f64().unwrap(), 1.0);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let j = parse(r#""a\n\t\"\\ bA é""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\t\"\\ bA é");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,null,{"b":"x\"y"}],"c":false}"#;
        let j = parse(doc).unwrap();
        let out = emit(&j);
        assert_eq!(parse(&out).unwrap(), j);
    }

    #[test]
    fn integers_emit_without_decimal() {
        assert_eq!(emit(&Json::Num(600.0)), "600");
        assert_eq!(emit(&Json::Num(0.5)), "0.5");
    }

    #[test]
    fn real_manifest_shape() {
        // mirror of what aot.py writes
        let doc = r#"{
          "version": 1, "dtype": "float64",
          "entries": [
            {"name": "gram", "profile": "tiny", "file": "tiny/gram.hlo.txt",
             "inputs": [{"shape": [64, 24], "dtype": "float64"}],
             "outputs": [{"shape": [24, 24], "dtype": "float64"}],
             "meta": {"block_rows": 64, "nt": 24, "r_max": 6,
                      "s_max": 21, "rollout_steps": 32, "recon_cols": 32,
                      "gram_tile": 16}}
          ]}"#;
        let j = parse(doc).unwrap();
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("meta").unwrap().get("nt").unwrap().as_usize().unwrap(), 24);
    }
}

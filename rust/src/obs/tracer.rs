//! Per-rank span recorder: lock-free within a rank, merged at join.
//!
//! One [`Tracer`] rides each [`crate::comm::Communicator`] backend, so
//! recording a span or a collective record is a plain `Vec::push` on
//! rank-local state — no atomics, no locks, no channels. The runner
//! merges the per-rank [`RankTrace`]s after join, exactly like it
//! merges the virtual [`crate::comm::Clock`]s.
//!
//! Two contracts the rest of the crate relies on:
//!
//! * **Off is free.** The tracer is default-off; every probe point
//!   checks one `bool` before touching a clock, and disabled probes
//!   read no `Instant`, allocate nothing, and return unit or `0.0`.
//!   The `hotpath` bench carries a tracer-off row next to the bare
//!   kernel to keep this honest (acceptance: ≤ 1% regression).
//! * **On observes, never perturbs.** Wall-clock readings never feed
//!   the virtual clocks or any numeric path, so results are bitwise
//!   identical with tracing enabled — `integration_obs` asserts this
//!   across p × transport × T.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::comm::Category;

/// One closed span on a rank's timeline.
#[derive(Clone, Debug)]
pub struct Span {
    /// stable label ("pass1", "chunk_read", ...)
    pub label: &'static str,
    /// the virtual-clock category the spanned work bills to
    pub category: Category,
    /// wall seconds since the rank's trace origin
    pub start_s: f64,
    /// wall duration (seconds)
    pub dur_s: f64,
}

/// One collective call: measured wall time next to its α–β prediction.
#[derive(Clone, Debug)]
pub struct CommRecord {
    /// primitive name ("allreduce", "broadcast", ...)
    pub primitive: &'static str,
    /// which hop of the topology the call crossed: `"flat"` for the
    /// single-level transports, `"intra"` for a node-local board hop,
    /// `"inter"` for a leader-tree hop of the hierarchical transport
    pub link: &'static str,
    /// payload bytes, using the same convention the cost model is fed
    pub bytes: usize,
    /// `comm::costmodel` α–β prediction (seconds)
    pub predicted_s: f64,
    /// measured wall time of the whole call (seconds)
    pub measured_s: f64,
    /// portion of the call spent waiting for peers (seconds)
    pub wait_s: f64,
    /// wall seconds since the rank's trace origin
    pub start_s: f64,
}

/// Token from [`Tracer::span_start`]: `None` when tracing is off, so
/// the matching [`Tracer::span_end`] is a no-op without re-checking.
#[derive(Clone, Copy, Debug)]
pub struct SpanStart(Option<Instant>);

/// Token from [`Tracer::comm_start`]; same disabled-is-`None` shape.
#[derive(Clone, Copy, Debug)]
pub struct CommStart(Option<Instant>);

/// Per-rank recorder for spans, collective records, and gauges.
#[derive(Debug)]
pub struct Tracer {
    enabled: bool,
    rank: usize,
    origin: Instant,
    spans: Vec<Span>,
    comm: Vec<CommRecord>,
    gauges: BTreeMap<&'static str, f64>,
}

impl Tracer {
    /// A disabled tracer for `rank`; every backend constructs one.
    pub fn new(rank: usize) -> Tracer {
        Tracer {
            enabled: false,
            rank,
            origin: Instant::now(),
            spans: Vec::new(),
            comm: Vec::new(),
            gauges: BTreeMap::new(),
        }
    }

    pub fn set_enabled(&mut self, on: bool) {
        self.enabled = on;
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Open a span. Reads the clock only when enabled.
    #[inline]
    pub fn span_start(&self) -> SpanStart {
        SpanStart(self.enabled.then(Instant::now))
    }

    /// Close a span opened with [`span_start`](Self::span_start).
    pub fn span_end(&mut self, start: SpanStart, label: &'static str, category: Category) {
        if let Some(t0) = start.0 {
            self.spans.push(Span {
                label,
                category,
                start_s: t0.duration_since(self.origin).as_secs_f64(),
                dur_s: t0.elapsed().as_secs_f64(),
            });
        }
    }

    /// Open a collective record. Reads the clock only when enabled.
    #[inline]
    pub fn comm_start(&self) -> CommStart {
        CommStart(self.enabled.then(Instant::now))
    }

    /// Wall seconds since `start` (0.0 when tracing is off) — used by
    /// the transports to split wait time out of a collective.
    pub fn elapsed_since(&self, start: CommStart) -> f64 {
        start.0.map_or(0.0, |t0| t0.elapsed().as_secs_f64())
    }

    /// Close a collective record opened with
    /// [`comm_start`](Self::comm_start); `measured_s` is taken here so
    /// every exit path of a collective closes its record. Records the
    /// `"flat"` link — the single-level transports' hop kind.
    pub fn comm_record(
        &mut self,
        start: CommStart,
        primitive: &'static str,
        bytes: usize,
        predicted_s: f64,
        wait_s: f64,
    ) {
        self.comm_record_link(start, primitive, "flat", bytes, predicted_s, wait_s);
    }

    /// [`comm_record`](Self::comm_record) with an explicit link tag —
    /// the hierarchical transport tags node-local hops `"intra"` and
    /// leader-tree hops `"inter"`.
    pub fn comm_record_link(
        &mut self,
        start: CommStart,
        primitive: &'static str,
        link: &'static str,
        bytes: usize,
        predicted_s: f64,
        wait_s: f64,
    ) {
        if let Some(t0) = start.0 {
            self.comm.push(CommRecord {
                primitive,
                link,
                bytes,
                predicted_s,
                measured_s: t0.elapsed().as_secs_f64(),
                wait_s,
                start_s: t0.duration_since(self.origin).as_secs_f64(),
            });
        }
    }

    /// Record a running-maximum gauge (e.g. peak resident chunk bytes).
    pub fn gauge_max(&mut self, name: &'static str, value: f64) {
        if self.enabled {
            let slot = self.gauges.entry(name).or_insert(value);
            if value > *slot {
                *slot = value;
            }
        }
    }

    /// Move the recorded data out (the tracer stays usable but empty).
    pub fn take(&mut self) -> RankTrace {
        RankTrace {
            rank: self.rank,
            enabled: self.enabled,
            spans: std::mem::take(&mut self.spans),
            comm: std::mem::take(&mut self.comm),
            gauges: std::mem::take(&mut self.gauges),
        }
    }
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::new(0)
    }
}

/// One rank's recorded trace, moved out of the rank at join time.
#[derive(Clone, Debug)]
pub struct RankTrace {
    pub rank: usize,
    /// whether the rank recorded at all (exporters skip disabled ranks)
    pub enabled: bool,
    pub spans: Vec<Span>,
    pub comm: Vec<CommRecord>,
    pub gauges: BTreeMap<&'static str, f64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_records_nothing() {
        let mut t = Tracer::new(3);
        assert!(!t.is_enabled());
        let s = t.span_start();
        t.span_end(s, "pass1", Category::Load);
        let c = t.comm_start();
        assert_eq!(t.elapsed_since(c), 0.0);
        t.comm_record(c, "allreduce", 64, 1.0e-6, 0.0);
        t.gauge_max("peak", 42.0);
        let trace = t.take();
        assert_eq!(trace.rank, 3);
        assert!(!trace.enabled);
        assert!(trace.spans.is_empty());
        assert!(trace.comm.is_empty());
        assert!(trace.gauges.is_empty());
    }

    #[test]
    fn enabled_records_spans_and_comm() {
        let mut t = Tracer::new(1);
        t.set_enabled(true);
        let s = t.span_start();
        std::hint::black_box((0..1000u64).sum::<u64>());
        t.span_end(s, "pass2", Category::Compute);
        let c = t.comm_start();
        let wait = t.elapsed_since(c);
        t.comm_record(c, "broadcast", 128, 2.5e-6, wait);
        let trace = t.take();
        assert!(trace.enabled);
        assert_eq!(trace.spans.len(), 1);
        assert_eq!(trace.spans[0].label, "pass2");
        assert!(trace.spans[0].dur_s >= 0.0);
        assert!(trace.spans[0].start_s >= 0.0);
        assert_eq!(trace.comm.len(), 1);
        let r = &trace.comm[0];
        assert_eq!(r.primitive, "broadcast");
        assert_eq!(r.link, "flat");
        assert_eq!(r.bytes, 128);
        assert!((r.predicted_s - 2.5e-6).abs() < 1e-18);
        assert!(r.measured_s >= r.wait_s);
        // take() drains: a second take is empty
        assert!(t.take().spans.is_empty());
    }

    #[test]
    fn link_tags_survive_into_the_trace() {
        let mut t = Tracer::new(2);
        t.set_enabled(true);
        let c = t.comm_start();
        t.comm_record_link(c, "allreduce", "intra", 64, 1e-6, 0.0);
        let c = t.comm_start();
        t.comm_record_link(c, "allreduce", "inter", 64, 2e-6, 0.0);
        let trace = t.take();
        assert_eq!(trace.comm[0].link, "intra");
        assert_eq!(trace.comm[1].link, "inter");
    }

    #[test]
    fn gauge_keeps_the_maximum() {
        let mut t = Tracer::new(0);
        t.set_enabled(true);
        t.gauge_max("peak_bytes", 100.0);
        t.gauge_max("peak_bytes", 40.0);
        t.gauge_max("peak_bytes", 250.0);
        let trace = t.take();
        assert_eq!(trace.gauges.get("peak_bytes"), Some(&250.0));
    }
}

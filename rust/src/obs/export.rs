//! Trace and metrics exporters, built on [`crate::util::json`].
//!
//! * [`chrome_trace`] — Chrome trace-event JSON (the `{"traceEvents":
//!   [...]}` object form), loadable in `chrome://tracing` or Perfetto.
//!   One track (`tid`) per rank; spans and collectives are `"ph":"X"`
//!   complete events (µs units), gauges are `"ph":"C"` counters.
//!   Timestamps are relative to each rank's own trace origin, so
//!   within-rank ordering is exact while cross-rank alignment is
//!   approximate (ranks start their tracers within the spawn window).
//! * [`metrics_summary`] — a structured summary document (schema
//!   `dopinf-metrics-v1`): per-category virtual-clock totals copied
//!   verbatim from [`RunTiming`] (so they reconcile with the Fig. 4
//!   tables by construction), a per-primitive comm table with the
//!   measured-vs-α–β-predicted ratio, span aggregates, gauges, and the
//!   serve-tier histograms when serving.

use std::collections::BTreeMap;
use std::io;
use std::path::Path;

use crate::coordinator::timing::{RankTiming, RunTiming};
use crate::util::json::{emit, Json};

use super::hist::ServeMetrics;
use super::tracer::RankTrace;

/// Build the Chrome trace-event document for the given rank traces.
pub fn chrome_trace(traces: &[RankTrace]) -> Json {
    let mut events = Vec::new();
    for t in traces {
        events.push(Json::obj(vec![
            ("ph", Json::Str("M".into())),
            ("pid", Json::Num(0.0)),
            ("tid", Json::Num(t.rank as f64)),
            ("name", Json::Str("thread_name".into())),
            ("args", Json::obj(vec![("name", Json::Str(format!("rank {}", t.rank)))])),
        ]));
        for s in &t.spans {
            events.push(Json::obj(vec![
                ("ph", Json::Str("X".into())),
                ("pid", Json::Num(0.0)),
                ("tid", Json::Num(t.rank as f64)),
                ("ts", Json::Num(s.start_s * 1e6)),
                ("dur", Json::Num(s.dur_s * 1e6)),
                ("name", Json::Str(s.label.to_string())),
                ("cat", Json::Str(s.category.name().to_string())),
            ]));
        }
        for c in &t.comm {
            events.push(Json::obj(vec![
                ("ph", Json::Str("X".into())),
                ("pid", Json::Num(0.0)),
                ("tid", Json::Num(t.rank as f64)),
                ("ts", Json::Num(c.start_s * 1e6)),
                ("dur", Json::Num(c.measured_s * 1e6)),
                ("name", Json::Str(c.primitive.to_string())),
                ("cat", Json::Str("comm".into())),
                (
                    "args",
                    Json::obj(vec![
                        ("bytes", Json::Num(c.bytes as f64)),
                        ("predicted_us", Json::Num(c.predicted_s * 1e6)),
                        ("wait_us", Json::Num(c.wait_s * 1e6)),
                        ("link", Json::Str(c.link.to_string())),
                    ]),
                ),
            ]));
        }
        for (name, value) in &t.gauges {
            events.push(Json::obj(vec![
                ("ph", Json::Str("C".into())),
                ("pid", Json::Num(0.0)),
                ("tid", Json::Num(t.rank as f64)),
                ("ts", Json::Num(0.0)),
                ("name", Json::Str(name.to_string())),
                ("args", Json::obj(vec![("value", Json::Num(*value))])),
            ]));
        }
    }
    Json::obj(vec![
        ("displayTimeUnit", Json::Str("ms".into())),
        ("traceEvents", Json::Arr(events)),
    ])
}

fn rank_timing_json(r: &RankTiming) -> Json {
    Json::obj(vec![
        ("rank", Json::Num(r.rank as f64)),
        ("total", Json::Num(r.total)),
        ("load", Json::Num(r.load)),
        ("compute", Json::Num(r.compute)),
        ("comm", Json::Num(r.comm)),
        ("learn", Json::Num(r.learn)),
        ("post", Json::Num(r.post)),
    ])
}

/// Build the structured metrics summary. `serve` is `None` for
/// training runs; the serve tier passes its histogram snapshot.
pub fn metrics_summary(
    traces: &[RankTrace],
    timing: &RunTiming,
    serve: Option<&ServeMetrics>,
) -> Json {
    // Category totals come from the virtual clocks, not the wall-clock
    // spans: the contract is that these reconcile exactly with the
    // RunTiming the caller already reports.
    let sum = |f: fn(&RankTiming) -> f64| timing.per_rank.iter().map(f).sum::<f64>();
    let totals = Json::obj(vec![
        ("total", Json::Num(sum(|r| r.total))),
        ("load", Json::Num(sum(|r| r.load))),
        ("compute", Json::Num(sum(|r| r.compute))),
        ("comm", Json::Num(sum(|r| r.comm))),
        ("learn", Json::Num(sum(|r| r.learn))),
        ("post", Json::Num(sum(|r| r.post))),
    ]);
    let per_rank: Vec<Json> = timing.per_rank.iter().map(rank_timing_json).collect();

    #[derive(Default)]
    struct CommAgg {
        calls: u64,
        bytes: u64,
        measured: f64,
        wait: f64,
        predicted: f64,
        links: BTreeMap<&'static str, u64>,
    }
    let mut comm: BTreeMap<&'static str, CommAgg> = BTreeMap::new();
    for t in traces {
        for c in &t.comm {
            let a = comm.entry(c.primitive).or_default();
            a.calls += 1;
            a.bytes += c.bytes as u64;
            a.measured += c.measured_s;
            a.wait += c.wait_s;
            a.predicted += c.predicted_s;
            *a.links.entry(c.link).or_insert(0) += 1;
        }
    }
    let comm_json = Json::Obj(
        comm.iter()
            .map(|(k, a)| {
                let ratio = if a.predicted > 0.0 {
                    Json::Num(a.measured / a.predicted)
                } else {
                    Json::Null
                };
                let links = Json::Obj(
                    a.links
                        .iter()
                        .map(|(l, n)| (l.to_string(), Json::Num(*n as f64)))
                        .collect(),
                );
                (
                    k.to_string(),
                    Json::obj(vec![
                        ("calls", Json::Num(a.calls as f64)),
                        ("bytes", Json::Num(a.bytes as f64)),
                        ("measured_s", Json::Num(a.measured)),
                        ("wait_s", Json::Num(a.wait)),
                        ("predicted_s", Json::Num(a.predicted)),
                        ("ratio", ratio),
                        // per-link call counts: how many of the calls
                        // crossed flat / intra-node / inter-node hops
                        ("links", links),
                    ]),
                )
            })
            .collect(),
    );

    let mut phases: BTreeMap<&'static str, (u64, f64)> = BTreeMap::new();
    for t in traces {
        for s in &t.spans {
            let e = phases.entry(s.label).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += s.dur_s;
        }
    }
    let phases_json = Json::Obj(
        phases
            .iter()
            .map(|(k, (calls, total))| {
                (
                    k.to_string(),
                    Json::obj(vec![
                        ("calls", Json::Num(*calls as f64)),
                        ("total_s", Json::Num(*total)),
                    ]),
                )
            })
            .collect(),
    );

    let mut gauges: BTreeMap<&'static str, f64> = BTreeMap::new();
    for t in traces {
        for (&name, &value) in &t.gauges {
            let slot = gauges.entry(name).or_insert(value);
            if value > *slot {
                *slot = value;
            }
        }
    }
    let gauges_json =
        Json::Obj(gauges.iter().map(|(k, v)| (k.to_string(), Json::Num(*v))).collect());

    Json::obj(vec![
        ("schema", Json::Str("dopinf-metrics-v1".into())),
        ("ranks", Json::Num(timing.per_rank.len() as f64)),
        ("categories", Json::obj(vec![("totals", totals), ("per_rank", Json::Arr(per_rank))])),
        ("comm", comm_json),
        ("phases", phases_json),
        ("gauges", gauges_json),
        ("serve", serve.map_or(Json::Null, ServeMetrics::to_json)),
    ])
}

fn write_doc(path: &Path, doc: &Json) -> io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, emit(doc))
}

/// Write the Chrome trace-event document to `path` (parents created).
pub fn write_chrome_trace(path: &Path, traces: &[RankTrace]) -> io::Result<()> {
    write_doc(path, &chrome_trace(traces))
}

/// Write the metrics summary document to `path` (parents created).
pub fn write_metrics(
    path: &Path,
    traces: &[RankTrace],
    timing: &RunTiming,
    serve: Option<&ServeMetrics>,
) -> io::Result<()> {
    write_doc(path, &metrics_summary(traces, timing, serve))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Category;
    use crate::obs::tracer::{CommRecord, Span};
    use crate::util::json::parse;

    fn fake_trace(rank: usize) -> RankTrace {
        RankTrace {
            rank,
            enabled: true,
            spans: vec![
                Span { label: "pass1", category: Category::Load, start_s: 0.0, dur_s: 0.5 },
                Span { label: "pass2", category: Category::Compute, start_s: 0.5, dur_s: 0.25 },
            ],
            comm: vec![CommRecord {
                primitive: "allreduce",
                link: "flat",
                bytes: 800,
                predicted_s: 1e-5,
                measured_s: 2e-5,
                wait_s: 5e-6,
                start_s: 0.75,
            }],
            gauges: [("peak_bytes", 1000.0 + rank as f64)].into_iter().collect(),
        }
    }

    fn fake_timing(p: usize) -> RunTiming {
        RunTiming::new(
            (0..p)
                .map(|rank| RankTiming {
                    rank,
                    total: 1.0,
                    load: 0.5,
                    compute: 0.25,
                    comm: 0.15,
                    learn: 0.05,
                    post: 0.05,
                })
                .collect(),
        )
    }

    #[test]
    fn chrome_trace_roundtrips_and_has_tracks() {
        let traces = vec![fake_trace(0), fake_trace(1)];
        let doc = chrome_trace(&traces);
        let parsed = parse(&emit(&doc)).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // per rank: 1 metadata + 2 spans + 1 comm + 1 gauge
        assert_eq!(events.len(), 10);
        // every X event carries a dur (no open spans in the export)
        for e in events {
            if e.get("ph").and_then(Json::as_str) == Some("X") {
                assert!(e.get("dur").and_then(Json::as_f64).is_some());
                assert!(e.get("ts").and_then(Json::as_f64).unwrap() >= 0.0);
            }
        }
        // both rank tracks present
        for tid in [0, 1] {
            assert!(events
                .iter()
                .any(|e| e.get("tid").and_then(Json::as_usize) == Some(tid)));
        }
        // comm args carry the overlay fields
        let comm = events
            .iter()
            .find(|e| e.get("cat").and_then(Json::as_str) == Some("comm"))
            .unwrap();
        let args = comm.get("args").unwrap();
        assert_eq!(args.get("bytes").and_then(Json::as_usize), Some(800));
        assert!(args.get("predicted_us").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(args.get("wait_us").and_then(Json::as_f64).is_some());
        assert_eq!(args.get("link").and_then(Json::as_str), Some("flat"));
    }

    #[test]
    fn metrics_reconcile_with_run_timing() {
        let traces = vec![fake_trace(0), fake_trace(1)];
        let timing = fake_timing(2);
        let doc = metrics_summary(&traces, &timing, None);
        let parsed = parse(&emit(&doc)).unwrap();
        assert_eq!(parsed.get("schema").and_then(Json::as_str), Some("dopinf-metrics-v1"));
        assert_eq!(parsed.get("ranks").and_then(Json::as_usize), Some(2));
        let per_rank = parsed.get("categories").unwrap().get("per_rank").unwrap().as_arr().unwrap();
        assert_eq!(per_rank.len(), 2);
        for (row, want) in per_rank.iter().zip(&timing.per_rank) {
            assert_eq!(row.get("load").and_then(Json::as_f64), Some(want.load));
            assert_eq!(row.get("comm").and_then(Json::as_f64), Some(want.comm));
            assert_eq!(row.get("total").and_then(Json::as_f64), Some(want.total));
        }
        let ar = parsed.get("comm").unwrap().get("allreduce").unwrap();
        assert_eq!(ar.get("calls").and_then(Json::as_usize), Some(2));
        assert_eq!(ar.get("bytes").and_then(Json::as_usize), Some(1600));
        // ratio = measured/predicted = 2.0 for the fake records
        assert!((ar.get("ratio").and_then(Json::as_f64).unwrap() - 2.0).abs() < 1e-12);
        // both fake records crossed the flat link
        assert_eq!(
            ar.get("links").unwrap().get("flat").and_then(Json::as_usize),
            Some(2)
        );
        // phases aggregated across ranks
        let p1 = parsed.get("phases").unwrap().get("pass1").unwrap();
        assert_eq!(p1.get("calls").and_then(Json::as_usize), Some(2));
        // gauge is the max across ranks
        assert_eq!(
            parsed.get("gauges").unwrap().get("peak_bytes").and_then(Json::as_f64),
            Some(1001.0)
        );
        assert_eq!(parsed.get("serve"), Some(&Json::Null));
    }

    #[test]
    fn zero_predicted_cost_reports_null_ratio() {
        let mut t = fake_trace(0);
        t.comm[0].predicted_s = 0.0;
        let doc = metrics_summary(&[t], &fake_timing(1), None);
        let ar = doc.get("comm").unwrap().get("allreduce").unwrap();
        assert_eq!(ar.get("ratio"), Some(&Json::Null));
    }

    #[test]
    fn serve_section_included_when_present() {
        let mut m = ServeMetrics::new();
        m.record_request(4, 1e-4, 3e-3);
        let doc = metrics_summary(&[], &fake_timing(1), Some(&m));
        assert_eq!(
            doc.get("serve").unwrap().get("requests").and_then(Json::as_usize),
            Some(1)
        );
    }

    #[test]
    fn writers_create_parent_dirs() {
        let dir = std::env::temp_dir().join(format!("dopinf_obs_export_{}", std::process::id()));
        let trace_path = dir.join("nested").join("trace.json");
        let metrics_path = dir.join("nested").join("metrics.json");
        let traces = vec![fake_trace(0)];
        write_chrome_trace(&trace_path, &traces).unwrap();
        write_metrics(&metrics_path, &traces, &fake_timing(1), None).unwrap();
        for p in [&trace_path, &metrics_path] {
            let text = std::fs::read_to_string(p).unwrap();
            assert!(parse(&text).is_ok(), "{p:?} must hold valid JSON");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

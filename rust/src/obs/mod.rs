//! Run-wide tracing & metrics plane: per-rank spans, per-collective
//! telemetry, and predicted-vs-actual cost-model overlays.
//!
//! The paper's evaluation (Fig. 4) is a per-rank time breakdown; this
//! module is the runtime counterpart — a timeline a human can read and
//! a machine-checkable summary — built with zero external dependencies
//! on top of [`crate::util::json`].
//!
//! # Span model
//!
//! Each rank owns one [`Tracer`] (a field of its
//! [`crate::comm::Communicator`] backend), so recording is lock-free
//! within a rank: a span is an `Instant` pair pushed onto a rank-local
//! `Vec`, a collective record additionally carries payload bytes, the
//! wait/transfer split, and the `comm::costmodel` α–β prediction.
//! Ranks never share tracer state; the runner collects the per-rank
//! [`RankTrace`]s at join, exactly as it collects the virtual clocks —
//! including from *failed* ranks, so abort/timeout runs still flush
//! partial traces.
//!
//! # Exporters
//!
//! [`write_chrome_trace`] emits Chrome trace-event JSON (one track per
//! rank; load in `chrome://tracing` or Perfetto), and [`write_metrics`]
//! emits a `dopinf-metrics-v1` summary whose per-category totals are
//! copied from the virtual clocks (so they reconcile with the Fig. 4
//! tables exactly) and whose comm table reports the per-primitive
//! measured-vs-predicted ratio — continuously validating the α–β model
//! against real transports. Enabled from the CLI with
//! `train --trace FILE --metrics FILE`.
//!
//! # Overhead contract
//!
//! * **Off** (the default): every probe point is one `bool` branch; no
//!   clock reads, no allocation. The `hotpath` bench pins this at ≤ 1%
//!   on the syrk kernel.
//! * **On**: wall-clock readings never enter the virtual clocks or any
//!   numeric path, so results are bitwise identical with tracing
//!   enabled (asserted by `integration_obs` across p × transport × T).

pub mod export;
pub mod hist;
pub mod tracer;

pub use export::{chrome_trace, metrics_summary, write_chrome_trace, write_metrics};
pub use hist::{Histogram, ServeMetrics};
pub use tracer::{CommRecord, CommStart, RankTrace, Span, SpanStart, Tracer};

//! Fixed log-spaced histograms and the serve-tier metrics they feed.
//!
//! The serve tier is latency-sensitive: a mean hides tail behavior, so
//! [`crate::serve::RomServer`] records queue wait, request latency, and
//! batch size into [`Histogram`]s with fixed power-of-two buckets. The
//! fixed layout keeps recording allocation-free and makes histograms
//! from different runs directly comparable (same bucket edges always).

use crate::util::json::Json;

/// Number of finite buckets; one overflow bucket is appended.
pub const BUCKETS: usize = 32;

/// Log-spaced histogram: bucket `i` holds values in
/// `(base·2^(i-1), base·2^i]` (bucket 0 is `[0, base]`), plus an
/// overflow bucket past `base·2^(BUCKETS-1)`.
#[derive(Clone, Debug)]
pub struct Histogram {
    base: f64,
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    /// `base` is the upper edge of the first bucket (e.g. `1e-6` for
    /// seconds-scale latencies, `1.0` for counts).
    pub fn new(base: f64) -> Histogram {
        assert!(base > 0.0, "histogram base must be positive");
        Histogram {
            base,
            counts: vec![0; BUCKETS + 1],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: 0.0,
        }
    }

    /// Record one observation (negative values clamp to zero).
    pub fn record(&mut self, value: f64) {
        let v = value.max(0.0);
        self.count += 1;
        self.sum += v;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
        let mut idx = 0;
        let mut edge = self.base;
        while v > edge && idx < BUCKETS {
            edge *= 2.0;
            idx += 1;
        }
        self.counts[idx] += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Mean of the recorded values (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Upper edge of the bucket holding the `q`-quantile observation
    /// (0.0 when empty; the overflow bucket reports the exact max).
    /// With log-spaced buckets this is an upper bound within 2× of the
    /// true quantile — the resolution serving dashboards need for
    /// p50/p99 without keeping raw samples.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return if i < BUCKETS { self.base * 2f64.powi(i as i32) } else { self.max };
            }
        }
        self.max
    }

    /// Structured form: count/sum/min/max plus the non-empty buckets as
    /// `{le, count}` rows (`le` is the bucket's upper edge; the
    /// overflow bucket reports `"inf"`).
    pub fn to_json(&self) -> Json {
        let mut buckets = Vec::new();
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let le = if i < BUCKETS {
                Json::Num(self.base * 2f64.powi(i as i32))
            } else {
                Json::Str("inf".to_string())
            };
            buckets.push(Json::obj(vec![("le", le), ("count", Json::Num(c as f64))]));
        }
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("sum", Json::Num(self.sum)),
            ("min", Json::Num(if self.count == 0 { 0.0 } else { self.min })),
            ("max", Json::Num(self.max)),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

/// Aggregated serve-tier metrics: one instance per [`crate::serve::RomServer`],
/// shared by its workers and snapshotted via `RomServer::metrics`.
#[derive(Clone, Debug)]
pub struct ServeMetrics {
    /// requests completed (success or failure)
    pub requests: u64,
    /// seconds a job sat queued before a worker dequeued it
    pub queue_wait: Histogram,
    /// seconds from dequeue to reply (the ensemble run itself)
    pub latency: Histogram,
    /// ensemble members per request (the "batch size" of the shard run)
    pub batch_members: Histogram,
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics {
            requests: 0,
            queue_wait: Histogram::new(1e-6),
            latency: Histogram::new(1e-6),
            batch_members: Histogram::new(1.0),
        }
    }

    /// Record one completed request.
    pub fn record_request(&mut self, members: usize, queue_wait_s: f64, latency_s: f64) {
        self.requests += 1;
        self.queue_wait.record(queue_wait_s);
        self.latency.record(latency_s);
        self.batch_members.record(members as f64);
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("queue_wait_s", self.queue_wait.to_json()),
            ("latency_s", self.latency.to_json()),
            ("batch_members", self.batch_members.to_json()),
        ])
    }
}

impl Default for ServeMetrics {
    fn default() -> Self {
        ServeMetrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{emit, parse};

    #[test]
    fn buckets_are_log_spaced() {
        let mut h = Histogram::new(1.0);
        h.record(0.5); // bucket 0: [0, 1]
        h.record(1.0); // bucket 0 (inclusive upper edge)
        h.record(1.5); // bucket 1: (1, 2]
        h.record(100.0); // bucket 7: (64, 128]
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 103.0).abs() < 1e-12);
        let j = h.to_json();
        let buckets = j.get("buckets").unwrap().as_arr().unwrap();
        let row = |le: f64| {
            buckets
                .iter()
                .find(|b| b.get("le").and_then(Json::as_f64) == Some(le))
                .and_then(|b| b.get("count"))
                .and_then(Json::as_usize)
        };
        assert_eq!(row(1.0), Some(2));
        assert_eq!(row(2.0), Some(1));
        assert_eq!(row(128.0), Some(1));
    }

    #[test]
    fn overflow_and_negatives() {
        let mut h = Histogram::new(1e-6);
        h.record(-5.0); // clamps to 0 → bucket 0
        h.record(1e12); // past the last finite edge → overflow
        let j = h.to_json();
        let buckets = j.get("buckets").unwrap().as_arr().unwrap();
        assert!(buckets.iter().any(|b| b.get("le").and_then(Json::as_str) == Some("inf")));
        assert_eq!(j.get("min").and_then(Json::as_f64), Some(0.0));
        // the document is valid JSON even with the overflow sentinel
        assert!(parse(&emit(&j)).is_ok());
    }

    #[test]
    fn empty_histogram_is_well_formed() {
        let h = Histogram::new(1.0);
        assert_eq!(h.mean(), 0.0);
        let j = h.to_json();
        assert_eq!(j.get("count").and_then(Json::as_usize), Some(0));
        assert_eq!(j.get("min").and_then(Json::as_f64), Some(0.0));
        assert!(j.get("buckets").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn quantiles_report_bucket_upper_edges() {
        let mut h = Histogram::new(1.0);
        for _ in 0..90 {
            h.record(0.5); // bucket 0, edge 1.0
        }
        for _ in 0..9 {
            h.record(3.0); // bucket 2, edge 4.0
        }
        h.record(1e15); // overflow
        assert_eq!(h.quantile(0.5), 1.0);
        assert_eq!(h.quantile(0.9), 1.0);
        assert_eq!(h.quantile(0.95), 4.0);
        assert_eq!(h.quantile(0.99), 4.0);
        assert_eq!(h.quantile(1.0), 1e15); // overflow reports the max
        assert_eq!(Histogram::new(1.0).quantile(0.5), 0.0);
    }

    #[test]
    fn serve_metrics_records_all_three() {
        let mut m = ServeMetrics::new();
        m.record_request(8, 1e-4, 2e-3);
        m.record_request(2, 5e-5, 1e-3);
        assert_eq!(m.requests, 2);
        assert_eq!(m.queue_wait.count(), 2);
        assert!((m.batch_members.sum() - 10.0).abs() < 1e-12);
        let j = m.to_json();
        assert_eq!(j.get("requests").and_then(Json::as_usize), Some(2));
        assert!(j.get("latency_s").unwrap().get("count").is_some());
    }
}

//! Step IV: discrete Operator Inference least squares (paper Eq. 12).
//!
//! Given the reduced trajectory Q̂ (r, nt), assemble the data matrix
//! `D̂ = [Q̂₁ᵀ | Q̂₁ᵀ ⊗' Q̂₁ᵀ | 1]` (nt-1, r+s+1) once, precompute the
//! normal-equation blocks `D̂ᵀD̂` and `D̂ᵀQ̂₂ᵀ`, then solve the
//! β-regularized system per candidate pair — each solve is a cheap
//! (r+s+1)² Cholesky because only the diagonal changes (tutorial lines
//! 230–262).

use crate::linalg::{cholesky_solve, matmul_tn, syrk, Matrix};
use crate::rom::quadratic::{qhat_sq_rows, s_dim};
use crate::rom::RomOperators;

use anyhow::Result;

/// Precomputed, pair-independent pieces of the OpInf problem.
#[derive(Clone, Debug)]
pub struct OpInfProblem {
    pub r: usize,
    /// d = r + s + 1
    pub d: usize,
    /// D̂ᵀD̂, (d, d)
    pub dtd: Matrix,
    /// D̂ᵀ Q̂₂ᵀ, (d, r)
    pub dtq2: Matrix,
    /// reduced training trajectory, rows = time (nt, r)
    pub qhat_t: Matrix,
    /// reduced initial condition (first training state)
    pub qhat0: Vec<f64>,
}

/// Assemble the learning problem from the reduced trajectory
/// `qhat` (r, nt) — tutorial lines 214–233.
pub fn assemble(qhat: &Matrix) -> OpInfProblem {
    let (r, nt) = (qhat.rows(), qhat.cols());
    assert!(nt >= 2, "need at least two snapshots");
    let qhat_t = qhat.transpose(); // (nt, r), rows = time
    let q1 = qhat_t.slice_rows(0, nt - 1); // (nt-1, r)
    let q2 = qhat_t.slice_rows(1, nt); // (nt-1, r)
    let q1_sq = qhat_sq_rows(&q1); // (nt-1, s)
    let ones = Matrix::from_vec(nt - 1, 1, vec![1.0; nt - 1]);
    let dhat = q1.hstack(&q1_sq).hstack(&ones); // (nt-1, d)

    OpInfProblem {
        r,
        d: r + s_dim(r) + 1,
        dtd: syrk(&dhat),
        dtq2: matmul_tn(&dhat, &q2),
        qhat0: q1.row(0).to_vec(),
        qhat_t,
    }
}

impl OpInfProblem {
    /// Rebuild a solvable problem from persisted normal-equation blocks
    /// (the serving-side entry: v2 `.rom` artifacts carry `D̂ᵀD̂` and
    /// `D̂ᵀQ̂₂ᵀ`). The training trajectory is not available in that
    /// setting, so `qhat_t` is empty — [`OpInfProblem::solve`] works,
    /// training-error search does not.
    pub fn from_blocks(dtd: Matrix, dtq2: Matrix, qhat0: Vec<f64>) -> OpInfProblem {
        let d = dtd.rows();
        let r = dtq2.cols();
        assert_eq!(dtd.cols(), d, "dtd must be square");
        assert_eq!(dtq2.rows(), d, "dtq2 rows must match dtd");
        assert_eq!(d, r + s_dim(r) + 1, "block dims inconsistent: d = {d} vs r = {r}");
        assert_eq!(qhat0.len(), r, "qhat0 length != r");
        OpInfProblem { r, d, dtd, dtq2, qhat_t: Matrix::zeros(0, r), qhat0 }
    }

    /// Solve the (β₁, β₂)-regularized normal equations: β₁ on the linear
    /// and constant blocks, β₂ on the quadratic block (tutorial lines
    /// 253–262; note the tutorial adds β to the diagonal, i.e. Tikhonov
    /// with Γ² = β — we match that convention exactly).
    pub fn solve(&self, beta1: f64, beta2: f64) -> Result<RomOperators> {
        let (r, d) = (self.r, self.d);
        let s = s_dim(r);
        let mut reg = self.dtd.clone();
        for i in 0..r {
            reg[(i, i)] += beta1;
        }
        for i in r..r + s {
            reg[(i, i)] += beta2;
        }
        reg[(d - 1, d - 1)] += beta1;
        let ohat_t = cholesky_solve(&reg, &self.dtq2)?; // (d, r)
        Ok(RomOperators::from_stacked(&ohat_t.transpose()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rom::rollout::solve_discrete;

    /// Build a trajectory from known operators, learn them back, verify.
    fn roundtrip(r: usize, nt: usize, seed: u64) -> (RomOperators, RomOperators) {
        let mut truth = RomOperators::zeros(r);
        // stable-ish linear part + small quadratic part
        let a = Matrix::randn(r, r, seed);
        for i in 0..r {
            for j in 0..r {
                truth.ahat[(i, j)] = 0.3 * a[(i, j)] / r as f64;
            }
            truth.ahat[(i, i)] += 0.7;
        }
        let f = Matrix::randn(r, s_dim(r), seed + 1);
        for i in 0..r {
            for k in 0..s_dim(r) {
                truth.fhat[(i, k)] = 0.02 * f[(i, k)];
            }
            truth.chat[i] = 0.01 * (i as f64 + 1.0);
        }
        let q0: Vec<f64> = (0..r).map(|i| 0.5 + 0.1 * i as f64).collect();
        let (nans, traj) = solve_discrete(&truth, &q0, nt);
        assert!(!nans);
        let problem = assemble(&traj.transpose());
        let learned = problem.solve(1e-12, 1e-12).unwrap();
        (truth, learned)
    }

    #[test]
    fn recovers_generating_dynamics() {
        // Operator entries are only identifiable up to the excitation of
        // the training trajectory; the well-posed statement is that the
        // learned model reproduces the generating trajectory.
        let (truth, learned) = roundtrip(3, 120, 5);
        let q0: Vec<f64> = (0..3).map(|i| 0.5 + 0.1 * i as f64).collect();
        let (_, want) = solve_discrete(&truth, &q0, 120);
        let (nans, got) = solve_discrete(&learned, &q0, 120);
        assert!(!nans);
        assert!(got.max_abs_diff(&want) < 1e-6, "trajectory mismatch {}", got.max_abs_diff(&want));
    }

    #[test]
    fn learned_model_reproduces_training_data() {
        let (_, learned) = roundtrip(4, 100, 9);
        // re-simulate from the learned model: training fit must be tight
        let q0: Vec<f64> = (0..4).map(|i| 0.5 + 0.1 * i as f64).collect();
        let (nans, _) = solve_discrete(&learned, &q0, 100);
        assert!(!nans);
    }

    #[test]
    fn assemble_shapes() {
        let qhat = Matrix::randn(5, 30, 2); // (r=5, nt=30)
        let p = assemble(&qhat);
        assert_eq!(p.r, 5);
        assert_eq!(p.d, 5 + 15 + 1);
        assert_eq!((p.dtd.rows(), p.dtd.cols()), (21, 21));
        assert_eq!((p.dtq2.rows(), p.dtq2.cols()), (21, 5));
        assert_eq!(p.qhat0.len(), 5);
        assert_eq!((p.qhat_t.rows(), p.qhat_t.cols()), (30, 5));
        // qhat0 is the first snapshot
        assert_eq!(p.qhat0, qhat.col(0));
    }

    #[test]
    fn heavier_regularization_shrinks_operators() {
        let qhat = Matrix::randn(4, 60, 3);
        let p = assemble(&qhat);
        let light = p.solve(1e-10, 1e-10).unwrap();
        let heavy = p.solve(1e4, 1e4).unwrap();
        let (la, lf, _) = light.norms();
        let (ha, hf, _) = heavy.norms();
        assert!(ha < la);
        assert!(hf < lf);
    }

    #[test]
    fn beta2_targets_quadratic_block_only() {
        let qhat = Matrix::randn(3, 50, 4);
        let p = assemble(&qhat);
        let base = p.solve(1e-8, 1e-8).unwrap();
        let quad_reg = p.solve(1e-8, 1e6).unwrap();
        let (_, f_base, _) = base.norms();
        let (_, f_quad, _) = quad_reg.norms();
        assert!(f_quad < 1e-3 * f_base, "quadratic block not suppressed");
    }

    #[test]
    fn from_blocks_solves_identically() {
        let qhat = Matrix::randn(4, 80, 11);
        let full = assemble(&qhat);
        let rebuilt =
            OpInfProblem::from_blocks(full.dtd.clone(), full.dtq2.clone(), full.qhat0.clone());
        assert_eq!(rebuilt.r, full.r);
        assert_eq!(rebuilt.d, full.d);
        let a = full.solve(1e-6, 1e-3).unwrap();
        let b = rebuilt.solve(1e-6, 1e-3).unwrap();
        // identical inputs → bitwise-identical operators
        assert_eq!(a.ahat, b.ahat);
        assert_eq!(a.fhat, b.fhat);
        assert_eq!(a.chat, b.chat);
    }

    #[test]
    #[should_panic(expected = "block dims inconsistent")]
    fn from_blocks_rejects_mismatched_dims() {
        OpInfProblem::from_blocks(Matrix::zeros(7, 7), Matrix::zeros(7, 3), vec![0.0; 3]);
    }

    #[test]
    fn singular_data_still_solvable_with_regularization() {
        // constant trajectory => D̂ᵀD̂ singular; β makes it SPD
        let qhat = Matrix::from_vec(2, 10, vec![1.0; 20]);
        let p = assemble(&qhat);
        assert!(p.solve(1e-6, 1e-6).is_ok());
    }
}

//! Step V: postprocessing the reduced solution (paper Sec. III.F).
//!
//! Maps the reduced trajectory Q̃ (r, nt_p) back to original coordinates
//! at selected rows: each rank computes its POD-basis slice on the fly
//! via `V_{r,i} = Q_i T_r` (Eq. 7 — still never materializing the full
//! basis), lifts `V_{r,i} Q̃`, and un-centers with the stored temporal
//! means. For probe outputs only the probe rows are lifted (tutorial
//! lines 323–355).

use crate::linalg::{matmul, Matrix};

/// One spatial row's POD-basis slice plus its un-centering transform —
/// everything needed to evaluate that row of the full-order field from
/// *any* reduced trajectory, long after the training data is gone.
///
/// This is the serving-side contract of Step V: the pipeline extracts a
/// `ProbeBasis` per probe during training, `serve::model` persists them
/// in the ROM artifact, and the ensemble engine evaluates
/// `φ · q̃(t) · scale + mean` per member per step.
#[derive(Clone, Debug, PartialEq)]
pub struct ProbeBasis {
    /// state-variable index of the probe
    pub var: usize,
    /// global spatial row (within the variable) of the probe
    pub row: usize,
    /// φ = rowᵀ T_r — this row of the POD basis V_r (length r)
    pub phi: Vec<f64>,
    /// the row's temporal mean from centering
    pub mean: f64,
    /// the row's variable scaling factor (1.0 if unscaled)
    pub scale: f64,
}

impl ProbeBasis {
    /// Evaluate this probe at one reduced state `q` (length r).
    #[inline]
    pub fn eval(&self, q: &[f64]) -> f64 {
        debug_assert_eq!(q.len(), self.phi.len());
        let mut acc = 0.0;
        for (p, v) in self.phi.iter().zip(q) {
            acc += p * v;
        }
        acc * self.scale + self.mean
    }
}

/// φ = rowᵀ T_r — this row of the POD basis (tutorial line 344).
pub fn probe_basis_row(centered_row: &[f64], tr: &Matrix) -> Vec<f64> {
    let (nt, r) = (tr.rows(), tr.cols());
    assert_eq!(centered_row.len(), nt);
    let mut phi = vec![0.0; r];
    for (j, p) in phi.iter_mut().enumerate() {
        let mut acc = 0.0;
        for (k, &q) in centered_row.iter().enumerate() {
            acc += q * tr[(k, j)];
        }
        *p = acc;
    }
    phi
}

/// Lift the reduced trajectory at one local row: returns the predicted
/// signal over the horizon.
///
/// * `centered_row` — this rank's (centered, scaled) training row (nt,)
/// * `tr`           — T_r (nt, r)
/// * `qtilde`       — reduced trajectory (r, nt_p)
/// * `mean`         — the row's temporal mean from centering
/// * `scale`        — the row's variable scaling factor (1.0 if unscaled)
pub fn lift_row(
    centered_row: &[f64],
    tr: &Matrix,
    qtilde: &Matrix,
    mean: f64,
    scale: f64,
) -> Vec<f64> {
    let phi = probe_basis_row(centered_row, tr);
    lift_from_phi(&phi, qtilde, mean, scale)
}

/// The second half of [`lift_row`]: prediction = φ Q̃ · scale + mean
/// (tutorial line 351 + un-scaling), for callers that already hold φ.
pub fn lift_from_phi(phi: &[f64], qtilde: &Matrix, mean: f64, scale: f64) -> Vec<f64> {
    let r = phi.len();
    assert_eq!(qtilde.rows(), r);
    let nt_p = qtilde.cols();
    let mut out = vec![0.0; nt_p];
    for (t, o) in out.iter_mut().enumerate() {
        let mut acc = 0.0;
        for j in 0..r {
            acc += phi[j] * qtilde[(j, t)];
        }
        *o = acc * scale + mean;
    }
    out
}

/// Lift a whole local block: `V_{r,i} Q̃` then un-transform. Returns the
/// (local_rows, nt_p) reconstruction in original coordinates. `means`
/// and `scales` are per-row.
pub fn lift_block(
    centered_block: &Matrix,
    tr: &Matrix,
    qtilde: &Matrix,
    means: &[f64],
    scales: &[f64],
) -> Matrix {
    let rows = centered_block.rows();
    assert_eq!(means.len(), rows);
    assert_eq!(scales.len(), rows);
    let vr = matmul(centered_block, tr); // (rows, r)
    let mut lifted = matmul(&vr, qtilde); // (rows, nt_p)
    for i in 0..rows {
        let row = lifted.row_mut(i);
        for v in row.iter_mut() {
            *v = *v * scales[i] + means[i];
        }
    }
    lifted
}

/// Relative ℓ² reconstruction error per time instant:
/// `‖approx_t − ref_t‖ / ‖ref_t‖` columns of two (rows, nt) matrices.
pub fn relative_errors(reference: &Matrix, approx: &Matrix) -> Vec<f64> {
    assert_eq!(reference.rows(), approx.rows());
    assert_eq!(reference.cols(), approx.cols());
    (0..reference.cols())
        .map(|t| {
            let mut num = 0.0;
            let mut den = 0.0;
            for i in 0..reference.rows() {
                let d = approx[(i, t)] - reference[(i, t)];
                num += d * d;
                den += reference[(i, t)] * reference[(i, t)];
            }
            if den > 0.0 {
                (num / den).sqrt()
            } else {
                num.sqrt()
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul_tn, syrk};
    use crate::opinf::podgram::{project, GramSpectrum};

    /// Projecting training data and lifting it back must reproduce the
    /// data when it is exactly rank-r (full POD round trip).
    #[test]
    fn roundtrip_on_low_rank_data() {
        let rank = 4;
        let m = 60;
        let nt = 25;
        let a = Matrix::randn(m, rank, 1);
        let b = Matrix::randn(rank, nt, 2);
        let q = matmul(&a, &b);

        let d = syrk(&q);
        let spec = GramSpectrum::from_gram(&d);
        let tr = spec.tr(rank);
        let qhat = project(&tr, &d); // (r, nt)

        let means = vec![0.0; m];
        let scales = vec![1.0; m];
        let lifted = lift_block(&q, &tr, &qhat, &means, &scales);
        assert!(lifted.max_abs_diff(&q) < 1e-8);
    }

    #[test]
    fn lift_row_matches_lift_block() {
        let q = Matrix::randn(30, 12, 3);
        let d = syrk(&q);
        let spec = GramSpectrum::from_gram(&d);
        let tr = spec.tr(5);
        let qhat = project(&tr, &d);
        let means: Vec<f64> = (0..30).map(|i| i as f64 * 0.1).collect();
        let scales: Vec<f64> = (0..30).map(|i| 1.0 + 0.01 * i as f64).collect();
        let block = lift_block(&q, &tr, &qhat, &means, &scales);
        for i in [0, 7, 29] {
            let row = lift_row(q.row(i), &tr, &qhat, means[i], scales[i]);
            for (t, &v) in row.iter().enumerate() {
                assert!((v - block[(i, t)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn mean_and_scale_restored() {
        // constant reduced solution of zero => output equals the mean
        let tr = Matrix::randn(10, 3, 4);
        let qtilde = Matrix::zeros(3, 6);
        let row = vec![0.5; 10];
        let out = lift_row(&row, &tr, &qtilde, 7.25, 2.0);
        assert!(out.iter().all(|&v| (v - 7.25).abs() < 1e-14));
    }

    #[test]
    fn probe_basis_eval_matches_lift_row() {
        let q = Matrix::randn(20, 9, 11);
        let d = syrk(&q);
        let spec = GramSpectrum::from_gram(&d);
        let tr = spec.tr(4);
        let qtilde = project(&tr, &d);
        let basis = ProbeBasis {
            var: 0,
            row: 3,
            phi: probe_basis_row(q.row(3), &tr),
            mean: 0.75,
            scale: 1.5,
        };
        let lifted = lift_row(q.row(3), &tr, &qtilde, 0.75, 1.5);
        for t in 0..qtilde.cols() {
            let state = qtilde.col(t);
            assert!((basis.eval(&state) - lifted[t]).abs() < 1e-12, "t={t}");
        }
    }

    #[test]
    fn relative_errors_zero_for_identical() {
        let a = Matrix::randn(8, 5, 6);
        let errs = relative_errors(&a, &a);
        assert!(errs.iter().all(|&e| e == 0.0));
    }

    #[test]
    fn relative_errors_detect_mismatch() {
        let a = Matrix::randn(8, 5, 7);
        let mut b = a.clone();
        b.scale(1.1);
        let errs = relative_errors(&a, &b);
        for e in errs {
            assert!((e - 0.1).abs() < 1e-12);
        }
    }

    #[test]
    fn projection_consistency_with_matmul_tn() {
        // lift of the projected data equals V_r V_rᵀ Q (orthogonal proj)
        let q = Matrix::randn(40, 10, 8);
        let d = syrk(&q);
        let spec = GramSpectrum::from_gram(&d);
        let r = 3;
        let tr = spec.tr(r);
        let qhat = project(&tr, &d);
        let lifted = lift_block(&q, &tr, &qhat, &vec![0.0; 40], &vec![1.0; 40]);
        let vr = matmul(&q, &tr);
        let want = matmul(&vr, &matmul_tn(&vr, &q));
        assert!(lifted.max_abs_diff(&want) < 1e-9);
    }
}

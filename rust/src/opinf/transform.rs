//! Step II: training-data transformations (paper Sec. III.C).
//!
//! Centering by the temporal mean is purely row-local, which is exactly
//! why dOpInf splits the snapshot matrix by spatial rows (Remark 3).
//! Max-abs scaling needs one global reduction per variable: the local
//! max-abs values computed here are combined by the coordinator with an
//! `Allreduce(MAX)` and applied via [`apply_scaling`].

use crate::linalg::Matrix;

/// Center each row by its temporal mean in place; returns the means
/// (needed later to un-center probe predictions, tutorial line 347).
pub fn center_rows(q: &mut Matrix) -> Vec<f64> {
    let (rows, cols) = (q.rows(), q.cols());
    assert!(cols > 0);
    let mut means = Vec::with_capacity(rows);
    for i in 0..rows {
        let row = q.row_mut(i);
        let mean = row.iter().sum::<f64>() / cols as f64;
        for v in row.iter_mut() {
            *v -= mean;
        }
        means.push(mean);
    }
    means
}

/// Local per-variable max-abs over this rank's rows of each variable.
/// `var_ranges[v] = (row_start, row_end)` within the local block.
pub fn local_maxabs(q: &Matrix, var_ranges: &[(usize, usize)]) -> Vec<f64> {
    var_ranges
        .iter()
        .map(|&(start, end)| {
            let mut m = 0.0f64;
            for i in start..end {
                for &v in q.row(i) {
                    m = m.max(v.abs());
                }
            }
            m
        })
        .collect()
}

/// The effective scaling divisor for a raw per-variable max-abs: zero
/// (a constant variable) acts as 1. The single definition of this
/// convention — the monolithic and streaming transforms, the pipeline's
/// probe un-scaling, and the serial path all route through it, so the
/// scale baked into `.rom` probe bases can never drift from the scale
/// applied to the training data.
pub fn effective_scale(s: f64) -> f64 {
    if s > 0.0 {
        s
    } else {
        1.0
    }
}

/// Scale each variable's rows by its (global) scaling parameter:
/// `q[rows_of_var] /= scale[var]` (tutorial's scaling snippet). Zero
/// scales are treated as 1 (constant variable).
pub fn apply_scaling(q: &mut Matrix, var_ranges: &[(usize, usize)], scales: &[f64]) {
    assert_eq!(var_ranges.len(), scales.len());
    for (&(start, end), &s) in var_ranges.iter().zip(scales) {
        let s = effective_scale(s);
        for i in start..end {
            for v in q.row_mut(i) {
                *v /= s;
            }
        }
    }
}

/// Split a local block of `ns` equally-sized stacked variables into
/// per-variable row ranges (the tutorial's `j*nx_i .. (j+1)*nx_i`).
pub fn variable_ranges(local_rows: usize, ns: usize) -> Vec<(usize, usize)> {
    assert_eq!(local_rows % ns, 0, "block must hold all variables equally");
    let per = local_rows / ns;
    (0..ns).map(|v| (v * per, (v + 1) * per)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn centering_zeroes_row_means() {
        let mut q = Matrix::randn(10, 7, 1);
        let means = center_rows(&mut q);
        assert_eq!(means.len(), 10);
        for i in 0..10 {
            let m: f64 = q.row(i).iter().sum::<f64>() / 7.0;
            assert!(m.abs() < 1e-13);
        }
    }

    #[test]
    fn centering_returns_original_means() {
        let mut q = Matrix::from_rows(&[&[1.0, 3.0], &[10.0, 10.0]]);
        let means = center_rows(&mut q);
        assert_eq!(means, vec![2.0, 10.0]);
        assert_eq!(q.row(0), &[-1.0, 1.0]);
        assert_eq!(q.row(1), &[0.0, 0.0]);
    }

    #[test]
    fn maxabs_per_variable() {
        let q = Matrix::from_rows(&[&[1.0, -2.0], &[0.5, 0.1], &[-7.0, 3.0], &[0.0, 0.0]]);
        let ranges = variable_ranges(4, 2);
        let m = local_maxabs(&q, &ranges);
        assert_eq!(m, vec![2.0, 7.0]);
    }

    #[test]
    fn scaling_bounds_to_unit_interval() {
        let mut q = Matrix::from_rows(&[&[4.0, -8.0], &[1.0, 2.0]]);
        let ranges = variable_ranges(2, 2);
        let scales = local_maxabs(&q, &ranges);
        apply_scaling(&mut q, &ranges, &scales);
        for v in q.data() {
            assert!(v.abs() <= 1.0 + 1e-15);
        }
        assert_eq!(q.row(0), &[0.5, -1.0]);
    }

    #[test]
    fn zero_scale_is_noop() {
        let mut q = Matrix::from_rows(&[&[0.0, 0.0]]);
        apply_scaling(&mut q, &[(0, 1)], &[0.0]);
        assert_eq!(q.row(0), &[0.0, 0.0]);
    }

    #[test]
    fn variable_ranges_split_evenly() {
        assert_eq!(variable_ranges(6, 2), vec![(0, 3), (3, 6)]);
        assert_eq!(variable_ranges(6, 3), vec![(0, 2), (2, 4), (4, 6)]);
    }

    #[test]
    #[should_panic(expected = "equally")]
    fn variable_ranges_reject_ragged() {
        variable_ranges(7, 2);
    }
}

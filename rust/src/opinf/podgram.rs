//! Step III: Gram-matrix dimensionality reduction (paper Eqs. 5–8).
//!
//! The heart of dOpInf's scalability: the rank-r POD *representation* of
//! the data is computed from two small nt×nt matrices without ever
//! forming the m×r POD basis —
//!
//! ```text
//!   D = Σᵢ QᵢᵀQᵢ          (local SYRK + Allreduce)
//!   D W = W Σ²            (replicated nt×nt eigendecomposition)
//!   T_r = U_r Λ_r^{-1/2}
//!   Q̂  = T_rᵀ D          (Eq. 8)
//! ```

use crate::linalg::{eigh, matmul_tn, Matrix};

/// Spectral summary of the global Gram matrix.
#[derive(Clone, Debug)]
pub struct GramSpectrum {
    /// eigenvalues of D sorted **descending** (= squared singular values
    /// of the snapshot matrix, Eq. 6)
    pub eigs: Vec<f64>,
    /// eigenvectors as columns, matching `eigs` order
    pub eigv: Matrix,
}

impl GramSpectrum {
    /// Eigendecompose the (symmetric PSD) global Gram matrix and sort
    /// descending — tutorial lines 83–87.
    pub fn from_gram(d_global: &Matrix) -> GramSpectrum {
        let e = eigh(d_global);
        let n = e.values.len();
        // ascending -> descending
        let eigs: Vec<f64> = e.values.iter().rev().copied().collect();
        let mut eigv = Matrix::zeros(n, n);
        for j in 0..n {
            for i in 0..n {
                eigv[(i, j)] = e.vectors[(i, n - 1 - j)];
            }
        }
        GramSpectrum { eigs, eigv }
    }

    /// Cumulative retained-energy curve `Σ_{k≤r} λ_k / Σ_k λ_k`
    /// (Fig. 2 right panel; Eq. 9 with λ = σ²).
    pub fn retained_energy(&self) -> Vec<f64> {
        let total: f64 = self.eigs.iter().sum();
        let mut acc = 0.0;
        self.eigs
            .iter()
            .map(|&l| {
                acc += l;
                if total > 0.0 {
                    acc / total
                } else {
                    1.0
                }
            })
            .collect()
    }

    /// Normalized singular values `σ_k / σ_1` (Fig. 2 left panel).
    pub fn normalized_singular_values(&self) -> Vec<f64> {
        let s1 = self.eigs.first().copied().unwrap_or(0.0).max(0.0).sqrt();
        self.eigs
            .iter()
            .map(|&l| if s1 > 0.0 { l.max(0.0).sqrt() / s1 } else { 0.0 })
            .collect()
    }

    /// Smallest r whose retained energy exceeds `target` — tutorial
    /// line 95 (`np.argmax(ret_energy > target) + 1`).
    pub fn choose_r(&self, target: f64) -> usize {
        let energy = self.retained_energy();
        energy
            .iter()
            .position(|&e| e > target)
            .map(|p| p + 1)
            .unwrap_or(self.eigs.len())
    }

    /// `T_r = U_r Λ_r^{-1/2}` (nt, r) — tutorial line 98. Guards tiny /
    /// negative (roundoff) eigenvalues.
    pub fn tr(&self, r: usize) -> Matrix {
        let nt = self.eigs.len();
        assert!(r >= 1 && r <= nt, "invalid reduced dimension {r}");
        let mut tr = Matrix::zeros(nt, r);
        for j in 0..r {
            let lam = self.eigs[j];
            assert!(lam > 0.0, "eigenvalue {j} is {lam}; r too large for data rank");
            let inv_sqrt = 1.0 / lam.sqrt();
            for i in 0..nt {
                tr[(i, j)] = self.eigv[(i, j)] * inv_sqrt;
            }
        }
        tr
    }
}

/// `Q̂ = T_rᵀ D` (r, nt) — tutorial line 100.
pub fn project(tr: &Matrix, d_global: &Matrix) -> Matrix {
    matmul_tn(tr, d_global)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, syrk};

    fn low_rank_snapshots(m: usize, nt: usize, rank: usize, seed: u64) -> Matrix {
        let a = Matrix::randn(m, rank, seed);
        let b = Matrix::randn(rank, nt, seed + 1);
        matmul(&a, &b)
    }

    #[test]
    fn eigs_sorted_descending() {
        let q = Matrix::randn(60, 12, 1);
        let spec = GramSpectrum::from_gram(&syrk(&q));
        for w in spec.eigs.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
    }

    #[test]
    fn energy_curve_monotone_to_one() {
        let q = Matrix::randn(50, 10, 2);
        let spec = GramSpectrum::from_gram(&syrk(&q));
        let e = spec.retained_energy();
        assert!(e.windows(2).all(|w| w[1] >= w[0] - 1e-15));
        assert!((e.last().unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn choose_r_detects_exact_rank() {
        let q = low_rank_snapshots(80, 20, 4, 3);
        let spec = GramSpectrum::from_gram(&syrk(&q));
        assert_eq!(spec.choose_r(0.999_999_9), 4);
    }

    #[test]
    fn projection_matches_pod_projection() {
        // Q̂ = T_rᵀD must equal V_rᵀQ with V_r = Q T_r (Eq. 7/8)
        let q = Matrix::randn(70, 15, 4);
        let d = syrk(&q);
        let spec = GramSpectrum::from_gram(&d);
        let r = 6;
        let tr = spec.tr(r);
        let qhat = project(&tr, &d);
        let vr = matmul(&q, &tr); // (m, r)
        let want = matmul_tn(&vr, &q); // V_rᵀ Q
        assert!(qhat.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn pod_basis_is_orthonormal() {
        // V_r = Q T_r has orthonormal columns (property of the method of
        // snapshots) — validates T_r's Λ^{-1/2} normalization
        let q = Matrix::randn(90, 12, 5);
        let d = syrk(&q);
        let spec = GramSpectrum::from_gram(&d);
        let tr = spec.tr(5);
        let vr = matmul(&q, &tr);
        let vtv = matmul_tn(&vr, &vr);
        assert!(vtv.max_abs_diff(&Matrix::eye(5)) < 1e-10);
    }

    #[test]
    fn normalized_svs_start_at_one() {
        let q = Matrix::randn(40, 8, 6);
        let spec = GramSpectrum::from_gram(&syrk(&q));
        let ns = spec.normalized_singular_values();
        assert!((ns[0] - 1.0).abs() < 1e-14);
        assert!(ns.iter().all(|&v| (0.0..=1.0 + 1e-12).contains(&v)));
    }

    #[test]
    #[should_panic(expected = "r too large")]
    fn tr_rejects_rank_deficient_r() {
        let q = low_rank_snapshots(40, 10, 2, 7);
        let spec = GramSpectrum::from_gram(&syrk(&q));
        let _ = spec.tr(9); // rank is 2, eigenvalue 9 ~ 0
    }
}

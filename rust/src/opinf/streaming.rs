//! Extension: streamed Gram accumulation (paper §I cites incremental /
//! streaming POD [15, 16] as the complementary approach).
//!
//! `D = QᵀQ` is a sum over *row* blocks (the distributed identity,
//! Eq. 5) but equally accumulates over *column* (snapshot-batch) outer
//! products of rows — enabling datasets whose row blocks do not fit in
//! memory: stream `nb` snapshot rows at a time from disk and accumulate.
//! This gives the same D bitwise (same rank-ordered summation) as the
//! in-memory path.

use crate::linalg::{syrk, Matrix};

/// Accumulates `D = Σ_b Q_bᵀ Q_b` over row batches of a tall matrix.
#[derive(Clone, Debug)]
pub struct GramAccumulator {
    nt: usize,
    d: Matrix,
    rows_seen: usize,
}

impl GramAccumulator {
    pub fn new(nt: usize) -> GramAccumulator {
        GramAccumulator { nt, d: Matrix::zeros(nt, nt), rows_seen: 0 }
    }

    /// Fold one batch of rows (any row count, same nt columns).
    pub fn push(&mut self, batch: &Matrix) {
        assert_eq!(batch.cols(), self.nt, "batch column count");
        self.d.axpy(1.0, &syrk(batch));
        self.rows_seen += batch.rows();
    }

    pub fn rows_seen(&self) -> usize {
        self.rows_seen
    }

    /// The accumulated Gram matrix.
    pub fn finish(self) -> Matrix {
        self.d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_monolithic_gram() {
        let q = Matrix::randn(97, 12, 3);
        let mut acc = GramAccumulator::new(12);
        let mut start = 0;
        for size in [10, 30, 1, 56] {
            acc.push(&q.slice_rows(start, start + size));
            start += size;
        }
        assert_eq!(start, 97);
        assert_eq!(acc.rows_seen(), 97);
        let d = acc.finish();
        assert!(d.max_abs_diff(&syrk(&q)) < 1e-12);
    }

    #[test]
    fn empty_batches_are_noops() {
        let mut acc = GramAccumulator::new(5);
        acc.push(&Matrix::zeros(0, 5));
        assert_eq!(acc.rows_seen(), 0);
        let d = acc.finish();
        assert_eq!(d, Matrix::zeros(5, 5));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn rejects_wrong_width() {
        let mut acc = GramAccumulator::new(4);
        acc.push(&Matrix::zeros(3, 5));
    }
}

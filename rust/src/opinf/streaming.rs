//! The streaming Step II–III engine: the **primary** training data
//! plane (paper Sec. III, plus the streaming-POD line of work cited in
//! §I [15, 16]).
//!
//! dOpInf exists because the snapshot data is "too large to process on
//! a single computer" — so the per-rank pipeline must not materialize
//! its full `(n_x/p, n_t)` block either. Every pass over the training
//! data streams row chunks from a [`crate::io::BlockReader`] through
//! the kernels in this module:
//!
//! ```text
//! pass 1  chunk ─▶ chunk_stats        row means + centered max-abs
//!                                     (Allreduce(MAX) joins the scales)
//! pass 2  chunk ─▶ apply_chunk_transform  center + scale in the chunk
//!               ─▶ GramAccumulator    D_local = Σ_b Q_bᵀ Q_b
//!                                     (Allreduce(SUM) joins D)
//!         spectrum ─▶ ProjectionAccumulator  Q̂ = T_rᵀ D, streamed
//! ```
//!
//! Per-rank residency is O(chunk_rows · n_t) for the data plus the
//! unavoidable (n_t, n_t) Gram accumulator — independent of n_x.
//!
//! ## The bitwise contract
//!
//! Streamed results are **bitwise identical** to the monolithic path
//! for every chunk size, because each accumulator runs the *exact same
//! sequence of floating-point operations* as its monolithic kernel:
//!
//! * [`GramAccumulator`] replays [`crate::linalg::syrk`]'s fused rank-4
//!   row groups. A carry buffer keeps the groups aligned to the
//!   absolute row index across chunk boundaries, and the `rows mod 4`
//!   remainder is flushed through the same single-row step at
//!   [`GramAccumulator::finish`] — exactly where `syrk` handles it.
//! * [`ProjectionAccumulator`] replays [`crate::linalg::matmul_tn`]'s
//!   purely row-sequential rank-1 updates, which are chunk-invariant
//!   with no alignment bookkeeping at all.
//! * [`chunk_stats`] / [`apply_chunk_transform`] are row-local, so they
//!   reproduce [`super::transform::center_rows`] /
//!   [`super::transform::local_maxabs`] /
//!   [`super::transform::apply_scaling`] element for element.
//!
//! Combined with the rank-ordered `comm::fold` reduction kernel, the
//! whole distributed pipeline is bitwise invariant in (chunk size, p,
//! transport) — property-tested in `tests/integration_pipeline.rs`.
//!
//! The shared kernels are the canonical lane-order kernels
//! ([`crate::linalg::simd`]): replaying them means replaying the same
//! FMA lane arithmetic, so the invariant extends to the SIMD dispatch
//! tier too (native ≡ scalar-emulation, at any chunk size — including
//! chunk boundaries that fall mid-lane-group, tested below). The ≤3-row
//! carry buffer aligns the rank-4 *row groups* (the k-direction); the
//! 4-wide *lanes* run along the output columns and never interact with
//! chunking at all.
//!
//! Since the compute-plane change the per-chunk work also fans out over
//! [`crate::linalg::par`] worker threads: the accumulators replay their
//! kernels over contiguous **output-row bands** (rows of D, rows of C)
//! and the transform over chunk-row bands, which leaves every element's
//! floating-point operation sequence untouched — so the invariant
//! extends to (chunk size, p, transport, **T**). Thread counts come
//! from `DOpInfConfig.threads_per_rank` via the process knob (or the
//! `with_threads` constructors, used by the property tests).

use crate::linalg::par;
use crate::linalg::{syrk_mirror, syrk_step1, syrk_step4_band, tn_step1_band, Matrix};

/// Accumulates `D = Σ_b Q_bᵀ Q_b` over row chunks of a tall matrix,
/// bitwise identical to `syrk` of the vertically stacked chunks.
#[derive(Clone, Debug)]
pub struct GramAccumulator {
    nt: usize,
    /// compute-plane width for the per-chunk fold (results are bitwise
    /// identical for every value)
    threads: usize,
    d: Matrix,
    rows_seen: usize,
    /// 0–3 buffered rows so the fused rank-4 groups stay aligned to the
    /// absolute row index regardless of chunk boundaries — the
    /// invariant behind the bitwise chunk-independence guarantee.
    carry: Vec<f64>,
}

impl GramAccumulator {
    pub fn new(nt: usize) -> GramAccumulator {
        GramAccumulator::with_threads(nt, par::threads())
    }

    /// Accumulator with an explicit compute-plane width (tests/benches;
    /// [`GramAccumulator::new`] reads the process knob).
    pub fn with_threads(nt: usize, threads: usize) -> GramAccumulator {
        GramAccumulator {
            nt,
            threads: threads.max(1),
            d: Matrix::zeros(nt, nt),
            rows_seen: 0,
            carry: Vec::with_capacity(4 * nt),
        }
    }

    /// Fold one chunk of rows (any row count, same nt columns).
    pub fn push(&mut self, batch: &Matrix) {
        assert_eq!(batch.cols(), self.nt, "batch column count");
        let n = self.nt;
        let rows = batch.rows();
        let bd = batch.data();
        self.rows_seen += rows;

        // top the carry up to a full rank-4 group first
        let mut next = 0;
        while !self.carry.is_empty() && self.carry.len() < 4 * n && next < rows {
            self.carry.extend_from_slice(&bd[next * n..(next + 1) * n]);
            next += 1;
        }
        // this push's aligned rank-4 group sequence — the completed
        // carry group first, then whole groups straight from the chunk
        // — is what syrk would run monolithically; banding D's rows
        // replays it once per band without touching any element's
        // operation order
        let carry_full = self.carry.len() == 4 * n;
        let chunk_groups = (rows - next) / 4;
        let tail = next + 4 * chunk_groups;
        let ngroups = usize::from(carry_full) + chunk_groups;
        if ngroups > 0 {
            let work = ngroups.saturating_mul(2 * n).saturating_mul(n);
            let nb = par::effective_bands(self.threads, n, work);
            let dd = self.d.data_mut();
            let carry_group: Option<[&[f64]; 4]> = if carry_full {
                let (r0, rest) = self.carry.split_at(n);
                let (r1, rest) = rest.split_at(n);
                let (r2, r3) = rest.split_at(n);
                Some([r0, r1, r2, r3])
            } else {
                None
            };
            if nb <= 1 {
                // serial: replay straight through, no staging allocation
                // (the common case with small chunks — chunk_rows = 7)
                if let Some(g) = &carry_group {
                    syrk_step4_band(dd, n, 0..n, g[0], g[1], g[2], g[3]);
                }
                let mut at = next;
                while at + 4 <= rows {
                    let (r0, rest) = bd[at * n..].split_at(n);
                    let (r1, rest) = rest.split_at(n);
                    let (r2, rest) = rest.split_at(n);
                    syrk_step4_band(dd, n, 0..n, r0, r1, r2, &rest[..n]);
                    at += 4;
                }
            } else {
                let mut groups: Vec<[&[f64]; 4]> = Vec::with_capacity(ngroups);
                if let Some(g) = carry_group {
                    groups.push(g);
                }
                let mut at = next;
                while at + 4 <= rows {
                    let (r0, rest) = bd[at * n..].split_at(n);
                    let (r1, rest) = rest.split_at(n);
                    let (r2, rest) = rest.split_at(n);
                    groups.push([r0, r1, r2, &rest[..n]]);
                    at += 4;
                }
                par::for_each_band(dd, n, n, nb, |band, dd_band| {
                    for g in &groups {
                        syrk_step4_band(dd_band, n, band.clone(), g[0], g[1], g[2], g[3]);
                    }
                });
            }
        }
        if carry_full {
            self.carry.clear();
        }
        // buffer the tail (< 4 rows) for the next chunk
        self.carry.extend_from_slice(&bd[tail * n..rows * n]);
    }

    pub fn rows_seen(&self) -> usize {
        self.rows_seen
    }

    /// Snapshot the full fold state for a checkpoint shard:
    /// `(d_lower_triangle_so_far, rows_seen, carry)`. Together with the
    /// chunk cursor this is *all* the pass-2 state — re-hydrating via
    /// [`GramAccumulator::from_parts`] and replaying the remaining
    /// chunks runs the exact same operation sequence as an
    /// uninterrupted fold, so the resumed Gram is bitwise identical.
    pub fn to_parts(&self) -> (Vec<f64>, usize, Vec<f64>) {
        (self.d.data().to_vec(), self.rows_seen, self.carry.clone())
    }

    /// Re-hydrate an accumulator from [`GramAccumulator::to_parts`]
    /// state. The compute-plane width is re-read from the process knob
    /// (it never affects the bits).
    pub fn from_parts(nt: usize, d: Vec<f64>, rows_seen: usize, carry: Vec<f64>) -> GramAccumulator {
        assert_eq!(d.len(), nt * nt, "Gram checkpoint shape");
        assert!(carry.len() % nt.max(1) == 0 && carry.len() < 4 * nt.max(1), "carry shape");
        GramAccumulator {
            nt,
            threads: par::threads().max(1),
            d: Matrix::from_vec(nt, nt, d),
            rows_seen,
            carry,
        }
    }

    /// The accumulated Gram matrix: flush the `rows mod 4` remainder
    /// through the single-row step and mirror the upper triangle —
    /// exactly `syrk`'s epilogue.
    pub fn finish(mut self) -> Matrix {
        let n = self.nt;
        let dd = self.d.data_mut();
        for row in self.carry.chunks_exact(n) {
            syrk_step1(dd, n, row);
        }
        syrk_mirror(dd, n);
        self.d
    }
}

/// Accumulates `C = Aᵀ B = Σ_k a_kᵀ ⊗ b_k` over paired row chunks of
/// two matrices sharing their tall dimension — bitwise identical to
/// `matmul_tn(A, B)` for every chunking, because `matmul_tn` itself is
/// a pure row-sequential rank-1 accumulation.
///
/// In the pipeline this carries the Step III projection
/// `Q̂ = T_rᵀ D` (Eq. 8) streamed over rows of the replicated Gram —
/// the identity `Q̂ = Σ_b (Q_b T_r)ᵀ Q_b` shows the same quantity is a
/// sum over data chunks, but the `T_rᵀ D` form needs only the (n_t,
/// n_t) matrices already resident, so nothing block-sized survives
/// Step III.
#[derive(Clone, Debug)]
pub struct ProjectionAccumulator {
    m: usize,
    n: usize,
    /// compute-plane width for the per-chunk fold (results are bitwise
    /// identical for every value)
    threads: usize,
    c: Matrix,
    rows_seen: usize,
}

impl ProjectionAccumulator {
    /// Accumulator for an `(m, n)` product `AᵀB` with `A: (k, m)`,
    /// `B: (k, n)` streamed in row chunks.
    pub fn new(m: usize, n: usize) -> ProjectionAccumulator {
        ProjectionAccumulator::with_threads(m, n, par::threads())
    }

    /// Accumulator with an explicit compute-plane width (tests/benches;
    /// [`ProjectionAccumulator::new`] reads the process knob).
    pub fn with_threads(m: usize, n: usize, threads: usize) -> ProjectionAccumulator {
        ProjectionAccumulator {
            m,
            n,
            threads: threads.max(1),
            c: Matrix::zeros(m, n),
            rows_seen: 0,
        }
    }

    /// Fold one paired chunk: `a` and `b` hold the same rows
    /// `[seen, seen + chunk)` of their full matrices. The rank-1 update
    /// sequence is row-sequential per output element, so banding C's
    /// rows across the compute plane leaves every element's operation
    /// order — and therefore the bits — unchanged.
    pub fn push(&mut self, a: &Matrix, b: &Matrix) {
        assert_eq!(a.rows(), b.rows(), "paired chunk row count");
        assert_eq!(a.cols(), self.m, "left chunk column count");
        assert_eq!(b.cols(), self.n, "right chunk column count");
        let rows = a.rows();
        let (m, n) = (self.m, self.n);
        let (ad, bd) = (a.data(), b.data());
        let cd = self.c.data_mut();
        let work = rows.saturating_mul(m).saturating_mul(n);
        let nb = par::effective_bands(self.threads, m, work);
        par::for_each_band(cd, n, m, nb, |band, c_band| {
            for kk in 0..rows {
                tn_step1_band(
                    c_band,
                    n,
                    band.clone(),
                    &ad[kk * m..(kk + 1) * m],
                    &bd[kk * n..(kk + 1) * n],
                );
            }
        });
        self.rows_seen += rows;
    }

    pub fn rows_seen(&self) -> usize {
        self.rows_seen
    }

    pub fn finish(self) -> Matrix {
        self.c
    }
}

/// `Q̂ = T_rᵀ D` streamed over `chunk_rows`-row blocks of both inputs
/// (paper Eq. 8). Bitwise identical to the native
/// `runtime::Engine::project` path for every chunk size.
pub fn project_streamed(tr: &Matrix, d: &Matrix, chunk_rows: usize) -> Matrix {
    assert!(chunk_rows >= 1, "chunk_rows must be >= 1");
    assert_eq!(tr.rows(), d.rows(), "T_r and D row counts differ");
    let mut acc = ProjectionAccumulator::new(tr.cols(), d.cols());
    let mut start = 0;
    while start < tr.rows() {
        let end = (start + chunk_rows).min(tr.rows());
        acc.push(&tr.slice_rows(start, end), &d.slice_rows(start, end));
        start = end;
    }
    acc.finish()
}

/// Pass-1 per-chunk statistics: append each row's temporal mean to
/// `means` (rows arrive in local var-major order, so `means[i]` ends up
/// the mean of local row `i`) and fold each row's *centered* max-abs
/// into its variable's `maxabs` slot. Bitwise identical to
/// `center_rows` + `local_maxabs` on the monolithic block.
///
/// `start_row` is the chunk's first local row index; `rows_per_var` is
/// the rank's per-variable row count (`|range|`).
pub fn chunk_stats(
    chunk: &Matrix,
    start_row: usize,
    rows_per_var: usize,
    means: &mut Vec<f64>,
    maxabs: &mut [f64],
) {
    let cols = chunk.cols();
    assert!(cols > 0, "chunks must carry at least one snapshot");
    assert!(rows_per_var > 0, "empty per-variable row range");
    for i in 0..chunk.rows() {
        let row = chunk.row(i);
        let mean = row.iter().sum::<f64>() / cols as f64;
        // hard error, not debug-only: an out-of-order BlockReader would
        // otherwise mis-attribute every subsequent row's mean and
        // silently corrupt the ROM
        assert_eq!(means.len(), start_row + i, "rows must stream in order");
        means.push(mean);
        let m = &mut maxabs[(start_row + i) / rows_per_var];
        for &v in row {
            *m = m.max((v - mean).abs());
        }
    }
}

/// Pass-2 per-chunk transform: center each row by its pass-1 mean and,
/// when `scales` is given, divide by its variable's global max-abs
/// (zero scales act as 1, like `apply_scaling`). The elementwise
/// operations match `center_rows` + `apply_scaling` exactly, so the
/// transformed chunk is bitwise identical to the corresponding rows of
/// the monolithically transformed block. Row-local, so the chunk rows
/// fan out over the compute plane (process knob) without any effect on
/// the bits.
pub fn apply_chunk_transform(
    chunk: &mut Matrix,
    start_row: usize,
    rows_per_var: usize,
    means: &[f64],
    scales: Option<&[f64]>,
) {
    apply_chunk_transform_with_threads(chunk, start_row, rows_per_var, means, scales, par::threads())
}

/// [`apply_chunk_transform`] with an explicit compute-plane width
/// (tests/benches).
pub fn apply_chunk_transform_with_threads(
    chunk: &mut Matrix,
    start_row: usize,
    rows_per_var: usize,
    means: &[f64],
    scales: Option<&[f64]>,
    threads: usize,
) {
    assert!(rows_per_var > 0, "empty per-variable row range");
    let rows = chunk.rows();
    let cols = chunk.cols();
    let work = rows.saturating_mul(cols);
    let nb = par::effective_bands(threads, rows, work);
    let data = chunk.data_mut();
    par::for_each_band(data, cols, rows, nb, |band, band_rows| {
        for i in band.clone() {
            let li = start_row + i;
            let mean = means[li];
            let off = (i - band.start) * cols;
            let row = &mut band_rows[off..off + cols];
            // subtract-then-divide per element, exactly as the
            // monolithic transform: no contraction exists, so the bits
            // are identical in every SIMD tier (the kernel only
            // vectorizes the walk)
            let s = scales
                .map(|sc| super::transform::effective_scale(sc[li / rows_per_var]));
            crate::linalg::simd::center_scale(row, mean, s);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul_tn, syrk};
    use crate::opinf::transform::{apply_scaling, center_rows, local_maxabs, variable_ranges};
    use crate::util::rng::Rng;

    #[test]
    fn gram_matches_monolithic_bitwise() {
        let q = Matrix::randn(97, 12, 3);
        let mut acc = GramAccumulator::new(12);
        let mut start = 0;
        for size in [10, 30, 1, 56] {
            acc.push(&q.slice_rows(start, start + size));
            start += size;
        }
        assert_eq!(start, 97);
        assert_eq!(acc.rows_seen(), 97);
        let d = acc.finish();
        assert_eq!(d.data(), syrk(&q).data(), "chunked Gram must be bitwise syrk");
    }

    #[test]
    fn gram_bitwise_for_any_chunking() {
        // random partitions, including single rows and rank-4-misaligned
        // splits, must all reproduce syrk exactly
        let mut rng = Rng::new(7);
        for case in 0..20 {
            let rows = 1 + (rng.below(50) as usize);
            let nt = 2 + (rng.below(10) as usize);
            let q = Matrix::randn(rows, nt, 100 + case);
            let want = syrk(&q);
            let mut acc = GramAccumulator::new(nt);
            let mut start = 0;
            while start < rows {
                let take = 1 + rng.below(7) as usize;
                let end = (start + take).min(rows);
                acc.push(&q.slice_rows(start, end));
                start = end;
            }
            let d = acc.finish();
            assert_eq!(d.data(), want.data(), "case {case}: rows={rows} nt={nt}");
        }
    }

    #[test]
    fn gram_resumed_from_parts_is_bitwise_identical() {
        // checkpoint/restore at every possible chunk boundary — the
        // resumed fold must reproduce the uninterrupted fold exactly,
        // carry buffer and all
        let mut rng = Rng::new(91);
        for case in 0..10 {
            let rows = 8 + rng.below(40) as usize;
            let nt = 3 + rng.below(9) as usize;
            let q = Matrix::randn(rows, nt, 7000 + case);
            let chunk = 1 + rng.below(6) as usize;
            let mut boundaries = Vec::new();
            let mut start = 0;
            while start < rows {
                boundaries.push(start);
                start = (start + chunk).min(rows);
            }
            let mut unbroken = GramAccumulator::new(nt);
            for &b in &boundaries {
                unbroken.push(&q.slice_rows(b, (b + chunk).min(rows)));
            }
            let want = unbroken.finish();
            for cut in 1..boundaries.len() {
                let mut acc = GramAccumulator::new(nt);
                for &b in &boundaries[..cut] {
                    acc.push(&q.slice_rows(b, (b + chunk).min(rows)));
                }
                let (d, seen, carry) = acc.to_parts();
                assert_eq!(seen, boundaries[cut]);
                let mut resumed = GramAccumulator::from_parts(nt, d, seen, carry);
                for &b in &boundaries[cut..] {
                    resumed.push(&q.slice_rows(b, (b + chunk).min(rows)));
                }
                assert_eq!(resumed.rows_seen(), rows);
                assert_eq!(
                    resumed.finish().data(),
                    want.data(),
                    "case {case} cut {cut}: resumed Gram differs"
                );
            }
        }
    }

    #[test]
    fn empty_batches_are_noops() {
        let mut acc = GramAccumulator::new(5);
        acc.push(&Matrix::zeros(0, 5));
        assert_eq!(acc.rows_seen(), 0);
        let d = acc.finish();
        assert_eq!(d, Matrix::zeros(5, 5));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn rejects_wrong_width() {
        let mut acc = GramAccumulator::new(4);
        acc.push(&Matrix::zeros(3, 5));
    }

    #[test]
    fn projection_matches_matmul_tn_bitwise() {
        let a = Matrix::randn(41, 6, 1);
        let b = Matrix::randn(41, 9, 2);
        let want = matmul_tn(&a, &b);
        for chunk in [1, 3, 4, 40, 41, 100] {
            let mut acc = ProjectionAccumulator::new(6, 9);
            let mut start = 0;
            while start < 41 {
                let end = (start + chunk).min(41);
                acc.push(&a.slice_rows(start, end), &b.slice_rows(start, end));
                start = end;
            }
            assert_eq!(acc.rows_seen(), 41);
            assert_eq!(acc.finish().data(), want.data(), "chunk={chunk}");
        }
    }

    #[test]
    fn project_streamed_matches_native() {
        let q = Matrix::randn(60, 14, 4);
        let d = syrk(&q);
        let tr = Matrix::randn(14, 5, 5);
        let want = matmul_tn(&tr, &d);
        for chunk in [1, 2, 5, 14, 64] {
            assert_eq!(project_streamed(&tr, &d, chunk).data(), want.data(), "chunk={chunk}");
        }
    }

    #[test]
    #[should_panic(expected = "paired chunk row count")]
    fn projection_rejects_mismatched_pairs() {
        let mut acc = ProjectionAccumulator::new(2, 3);
        acc.push(&Matrix::zeros(4, 2), &Matrix::zeros(3, 3));
    }

    #[test]
    fn accumulators_bitwise_across_simd_tiers_and_seam_chunks() {
        // the remainder-handling seam of the re-baseline: chunk
        // boundaries falling mid-lane-group (chunk_rows ∈ {1,3,5,7},
        // all misaligned with the rank-4 row groups) must replay the
        // exact monolithic lane arithmetic in both lane-order tiers.
        // Native↔Scalar toggles are results-neutral, so flipping the
        // global knob here is safe alongside concurrent tests.
        use crate::linalg::simd::{self, SimdTier};
        for tier in [SimdTier::Native, SimdTier::Scalar] {
            simd::set_tier(tier);
            for rows in [5usize, 8, 13, 29] {
                let nt = 9;
                let q = Matrix::randn(rows, nt, 3000 + rows as u64);
                let b = Matrix::randn(rows, 6, 4000 + rows as u64);
                let want_d = syrk(&q);
                let want_c = matmul_tn(&q, &b);
                for chunk in [1usize, 3, 5, 7] {
                    let mut gram = GramAccumulator::new(nt);
                    let mut proj = ProjectionAccumulator::new(nt, 6);
                    let mut start = 0;
                    while start < rows {
                        let end = (start + chunk).min(rows);
                        gram.push(&q.slice_rows(start, end));
                        proj.push(&q.slice_rows(start, end), &b.slice_rows(start, end));
                        start = end;
                    }
                    assert_eq!(
                        gram.finish().data(),
                        want_d.data(),
                        "gram tier={} rows={rows} chunk={chunk}",
                        tier.name()
                    );
                    assert_eq!(
                        proj.finish().data(),
                        want_c.data(),
                        "proj tier={} rows={rows} chunk={chunk}",
                        tier.name()
                    );
                }
            }
        }
        simd::set_tier(SimdTier::Native);
    }

    #[test]
    fn transform_bitwise_across_simd_tiers() {
        // center_scale carries no contraction, so the transformed chunk
        // must be identical bits in both lane-order tiers (and chunked
        // ≡ monolithic under each)
        use crate::linalg::simd::{self, SimdTier};
        let per = 11;
        let q0 = Matrix::randn(2 * per, 8, 55);
        let mut means = Vec::new();
        let mut maxabs = vec![0.0f64; 2];
        chunk_stats(&q0, 0, per, &mut means, &mut maxabs);
        let mut reference: Option<Matrix> = None;
        for tier in [SimdTier::Native, SimdTier::Scalar] {
            simd::set_tier(tier);
            for chunk in [1usize, 3, 5, 7, 2 * per] {
                let mut rebuilt = Matrix::zeros(0, 8);
                let mut start = 0;
                while start < 2 * per {
                    let end = (start + chunk).min(2 * per);
                    let mut c = q0.slice_rows(start, end);
                    apply_chunk_transform(&mut c, start, per, &means, Some(&maxabs));
                    rebuilt = rebuilt.vstack(&c);
                    start = end;
                }
                match &reference {
                    None => reference = Some(rebuilt),
                    Some(want) => assert_eq!(
                        rebuilt.data(),
                        want.data(),
                        "tier={} chunk={chunk}",
                        tier.name()
                    ),
                }
            }
        }
        simd::set_tier(SimdTier::Native);
    }

    #[test]
    fn parallel_folds_bitwise_equal_serial() {
        // the compute-plane contract at accumulator level: any thread
        // count, any chunking — bit-for-bit the serial syrk/matmul_tn.
        // Threshold 0 forces the banded path for these small inputs.
        crate::linalg::par::set_par_min_elems(0);
        let mut rng = Rng::new(31);
        for case in 0..8 {
            let rows = 5 + rng.below(90) as usize;
            let nt = 2 + rng.below(12) as usize;
            let q = Matrix::randn(rows, nt, 500 + case);
            let want_d = crate::linalg::syrk_with_threads(&q, 1);
            let b = Matrix::randn(rows, 7, 900 + case);
            let want_c = crate::linalg::matmul_tn_with_threads(&q, &b, 1);
            for t in [2usize, 4] {
                let mut gram = GramAccumulator::with_threads(nt, t);
                let mut proj = ProjectionAccumulator::with_threads(nt, 7, t);
                let mut start = 0;
                while start < rows {
                    let end = (start + 1 + rng.below(8) as usize).min(rows);
                    gram.push(&q.slice_rows(start, end));
                    proj.push(&q.slice_rows(start, end), &b.slice_rows(start, end));
                    start = end;
                }
                assert_eq!(gram.finish().data(), want_d.data(), "gram case {case} T={t}");
                assert_eq!(proj.finish().data(), want_c.data(), "proj case {case} T={t}");
            }
        }
    }

    #[test]
    fn parallel_chunk_transform_bitwise() {
        crate::linalg::par::set_par_min_elems(0);
        let ns = 2;
        let per = 17;
        let nt = 9;
        let q0 = Matrix::randn(ns * per, nt, 77);
        let mut means = Vec::new();
        let mut maxabs = vec![0.0f64; ns];
        chunk_stats(&q0, 0, per, &mut means, &mut maxabs);
        let mut want = q0.clone();
        apply_chunk_transform_with_threads(&mut want, 0, per, &means, Some(&maxabs), 1);
        for t in [2usize, 4, 8] {
            let mut got = q0.clone();
            apply_chunk_transform_with_threads(&mut got, 0, per, &means, Some(&maxabs), t);
            assert_eq!(got.data(), want.data(), "T={t}");
        }
    }

    #[test]
    fn chunked_transform_matches_monolithic_bitwise() {
        // monolithic reference: center, maxabs, scale on the full block
        let ns = 3;
        let per = 14;
        let nt = 11;
        let q0 = Matrix::randn(ns * per, nt, 9);
        let mut mono = q0.clone();
        let ranges = variable_ranges(ns * per, ns);
        let want_means = center_rows(&mut mono);
        let want_max = local_maxabs(&mono, &ranges);
        apply_scaling(&mut mono, &ranges, &want_max);

        for chunk in [1, 4, 5, per, ns * per] {
            let mut means = Vec::new();
            let mut maxabs = vec![0.0f64; ns];
            let mut start = 0;
            while start < ns * per {
                let end = (start + chunk).min(ns * per);
                chunk_stats(&q0.slice_rows(start, end), start, per, &mut means, &mut maxabs);
                start = end;
            }
            assert_eq!(means, want_means, "chunk={chunk}");
            assert_eq!(maxabs, want_max, "chunk={chunk}");

            let mut rebuilt = Matrix::zeros(0, nt);
            let mut start = 0;
            while start < ns * per {
                let end = (start + chunk).min(ns * per);
                let mut c = q0.slice_rows(start, end);
                apply_chunk_transform(&mut c, start, per, &means, Some(&maxabs));
                rebuilt = rebuilt.vstack(&c);
                start = end;
            }
            assert_eq!(rebuilt.data(), mono.data(), "chunk={chunk}");
        }
    }
}

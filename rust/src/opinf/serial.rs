//! Serial OpInf reference implementation (the paper's p=1 baseline).
//!
//! Runs the complete pipeline — transform, Gram reduction, grid search,
//! rollout — on one in-memory snapshot matrix with no communicator. The
//! distributed pipeline must match this bitwise on the same data (see
//! `rust/tests/integration_equivalence.rs`); it is also the p=1
//! measurement in the Fig. 4 scaling study, mirroring the paper, which
//! benchmarks its serial implementation for p=1.

use anyhow::{Context, Result};

use super::learn::{self, OpInfProblem};
use super::podgram::GramSpectrum;
use super::transform::{apply_scaling, center_rows, local_maxabs, variable_ranges};
use crate::linalg::Matrix;
use crate::rom::regsearch::{
    growth_ratio, train_error, training_stats, RegGrid, RegSearchOutcome,
};
use crate::runtime::Engine;
use crate::util::timer::WallTimer;

/// Pipeline hyperparameters shared by the serial and distributed paths.
#[derive(Clone, Debug)]
pub struct OpInfConfig {
    /// number of stacked state variables in the snapshot rows
    pub ns: usize,
    /// retained-energy target (paper: 0.9996)
    pub energy_target: f64,
    /// overrides energy-based selection when set
    pub r_override: Option<usize>,
    /// apply max-abs variable scaling (the tutorial shows but skips it)
    pub scaling: bool,
    /// regularization candidate grid
    pub grid: RegGrid,
    /// growth-ratio bound for accepting a candidate (paper: 1.2)
    pub max_growth: f64,
    /// rollout steps over the target horizon (paper: 1200)
    pub nt_p: usize,
}

impl OpInfConfig {
    pub fn paper_default(ns: usize, nt_p: usize) -> OpInfConfig {
        OpInfConfig {
            ns,
            energy_target: 0.9996,
            r_override: None,
            scaling: false,
            grid: RegGrid::paper_default(),
            max_growth: 1.2,
            nt_p,
        }
    }
}

/// Everything the serial pipeline produces.
#[derive(Clone, Debug)]
pub struct SerialResult {
    pub r: usize,
    pub spectrum: GramSpectrum,
    pub tr: Matrix,
    /// reduced training trajectory (r, nt)
    pub qhat: Matrix,
    /// per-row temporal means (centering)
    pub means: Vec<f64>,
    /// per-variable scales (all 1.0 when scaling is off)
    pub scales: Vec<f64>,
    pub opt_pair: (f64, f64),
    pub train_err: f64,
    /// reduced solution over the target horizon (r, nt_p)
    pub qtilde: Matrix,
    /// wall seconds of the winning ROM rollout (the paper's ROM CPU time)
    pub rom_time: f64,
    /// centered (and scaled) training data — kept for Step V lifting
    pub centered: Matrix,
}

/// Search `pairs`, solving + rolling out each candidate; shared by the
/// serial and distributed paths (tutorial lines 246–298). Rollouts go
/// through `engine` (PJRT artifact when the shape matches).
pub fn search_pairs(
    engine: &Engine,
    problem: &OpInfProblem,
    pairs: &[(f64, f64)],
    max_growth: f64,
    nt_p: usize,
) -> RegSearchOutcome {
    let nt = problem.qhat_t.rows();
    let (mean_train, max_diff_train) = training_stats(&problem.qhat_t);
    let mut out = RegSearchOutcome::empty();
    for &(b1, b2) in pairs {
        out.evaluated += 1;
        let ops = match problem.solve(b1, b2) {
            Ok(ops) => ops,
            Err(_) => {
                out.rejected += 1;
                continue;
            }
        };
        let t = WallTimer::start();
        let (contains_nans, traj) = engine.rollout(&ops, &problem.qhat0, nt_p);
        let rom_time = t.elapsed();
        if contains_nans {
            out.rejected += 1;
            continue;
        }
        let err = train_error(&problem.qhat_t.slice_rows(0, nt), &traj.slice_rows(0, nt));
        let growth = growth_ratio(&traj, &mean_train, &max_diff_train);
        if growth < max_growth && err < out.best_err {
            out.best_err = err;
            out.best_pair = Some((b1, b2));
            out.best_trajectory = Some(traj.transpose()); // (r, nt_p)
            out.best_rom_time = rom_time;
        } else if growth >= max_growth {
            out.rejected += 1;
        }
    }
    out
}

/// Run the full serial pipeline on snapshots `q` (n, nt) with the
/// native engine.
pub fn run(q: Matrix, cfg: &OpInfConfig) -> Result<SerialResult> {
    run_with_engine(q, cfg, &Engine::native())
}

/// Run the full serial pipeline on snapshots `q` (n, nt), consumed and
/// transformed in place; heavy products dispatch through `engine`.
pub fn run_with_engine(mut q: Matrix, cfg: &OpInfConfig, engine: &Engine) -> Result<SerialResult> {
    // Step II: transforms
    let means = center_rows(&mut q);
    let var_ranges = variable_ranges(q.rows(), cfg.ns);
    let scales_per_var: Vec<f64> = if cfg.scaling {
        let s = local_maxabs(&q, &var_ranges);
        apply_scaling(&mut q, &var_ranges, &s);
        s.iter().copied().map(super::transform::effective_scale).collect()
    } else {
        vec![1.0; cfg.ns]
    };
    // expand per-variable scales to per-row
    let mut scales = vec![1.0; q.rows()];
    for (v, &(s0, s1)) in var_ranges.iter().enumerate() {
        for item in scales.iter_mut().take(s1).skip(s0) {
            *item = scales_per_var[v];
        }
    }

    // Step III: Gram reduction
    let d_global = engine.gram(&q);
    let spectrum = GramSpectrum::from_gram(&d_global);
    let r = cfg.r_override.unwrap_or_else(|| spectrum.choose_r(cfg.energy_target));
    let tr = spectrum.tr(r);
    let qhat = engine.project(&tr, &d_global); // (r, nt)

    // Step IV: grid search over all pairs
    let problem = learn::assemble(&qhat);
    let outcome = search_pairs(engine, &problem, &cfg.grid.pairs(), cfg.max_growth, cfg.nt_p);
    let opt_pair = outcome
        .best_pair
        .context("no regularization pair satisfied the growth constraint")?;

    Ok(SerialResult {
        r,
        spectrum,
        tr,
        qhat,
        means,
        scales,
        opt_pair,
        train_err: outcome.best_err,
        qtilde: outcome.best_trajectory.unwrap(),
        rom_time: outcome.best_rom_time,
        centered: q,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opinf::postprocess::{lift_block, relative_errors};
    use crate::sim::synth::{generate, SynthSpec};

    fn synth_config() -> (Matrix, OpInfConfig, SynthSpec) {
        let spec = SynthSpec { nx: 200, ns: 2, nt: 80, modes: 3, ..Default::default() };
        let q = generate(&spec, 0);
        let cfg = OpInfConfig {
            ns: 2,
            energy_target: 0.999_999,
            r_override: None,
            scaling: false,
            grid: RegGrid::coarse(),
            max_growth: 1.5,
            nt_p: 160,
        };
        (q, cfg, spec)
    }

    #[test]
    fn serial_pipeline_learns_predictive_rom() {
        let (q, cfg, spec) = synth_config();
        let reference_full = generate(&SynthSpec { nt: 160, ..spec.clone() }, 0);
        let res = run(q, &cfg).unwrap();

        // rank bounded by construction (2·modes = 6 dynamic + residue)
        assert!(res.r <= 8, "r = {}", res.r);
        assert!(res.train_err < 1e-3, "train err {}", res.train_err);
        assert_eq!(res.qtilde.rows(), res.r);
        assert_eq!(res.qtilde.cols(), 160);

        // lift the prediction and compare against the true future: the
        // dynamics are periodic, so extrapolation must hold
        let lifted = lift_block(&res.centered, &res.tr, &res.qtilde, &res.means, &res.scales);
        let errs = relative_errors(&reference_full, &lifted);
        let max_err = errs.iter().fold(0.0f64, |m, &e| m.max(e));
        assert!(max_err < 0.05, "prediction error {max_err}");
    }

    #[test]
    fn scaling_on_gives_similar_quality() {
        let (q, mut cfg, _) = synth_config();
        cfg.scaling = true;
        let res = run(q, &cfg).unwrap();
        assert!(res.train_err < 5e-3, "train err {}", res.train_err);
        assert!(res.scales.iter().any(|&s| s != 1.0));
    }

    #[test]
    fn r_override_respected() {
        let (q, mut cfg, _) = synth_config();
        cfg.r_override = Some(3);
        let res = run(q, &cfg).unwrap();
        assert_eq!(res.r, 3);
        assert_eq!(res.tr.cols(), 3);
    }

    #[test]
    fn search_pairs_filters_unstable() {
        let (q, cfg, _) = synth_config();
        let res = run(q, &cfg).unwrap();
        let problem = learn::assemble(&res.qhat);
        // absurdly small regularization grid where everything explodes
        // may still find finite pairs; just assert accounting consistency
        let outcome = search_pairs(
            &Engine::native(),
            &problem,
            &[(1e-14, 1e-14), (1.0, 1.0)],
            cfg.max_growth,
            cfg.nt_p,
        );
        assert_eq!(outcome.evaluated, 2);
        assert!(outcome.best_err < 1e20);
    }
}

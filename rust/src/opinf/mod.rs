//! The dOpInf algorithm as a library (paper Sec. III).
//!
//! Functions here operate on *local* (per-rank) data blocks plus the few
//! small replicated matrices; the [`crate::coordinator`] wires them to
//! the communicator. This separation lets the serial reference
//! implementation ([`serial`]) share the exact same numerics — the
//! serial-vs-distributed equivalence test is the core correctness signal
//! of the whole pipeline.
//!
//! * [`transform`]   — Step II reference kernels: centering + max-abs
//!   scaling on a resident block (the serial path)
//! * [`streaming`]   — the **primary** Step II–III engine: per-chunk
//!   stats/transform kernels and the Gram/projection accumulators the
//!   distributed pipeline streams its data through, bitwise identical
//!   to the monolithic kernels for every chunking
//! * [`podgram`]     — Step III: Gram-based dimensionality reduction
//!   (Eqs. 5–8: D, eigh, T_r, Q̂ = T_rᵀD — no POD basis formed)
//! * [`learn`]       — Step IV: discrete OpInf least squares (Eq. 12)
//! * [`postprocess`] — Step V: probe lifting via V_{r,i} = Q_i T_r
//! * [`serial`]      — the paper's serial OpInf reference (p = 1 baseline)

pub mod learn;
pub mod podgram;
pub mod postprocess;
pub mod serial;
pub mod streaming;
pub mod transform;

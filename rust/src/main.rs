//! dopinf — distributed Operator Inference CLI.
//!
//! Subcommands:
//!   simulate   run the 2D Navier–Stokes solver and write a dataset
//!   train      run the distributed dOpInf pipeline on a dataset
//!   scaling    strong-scaling study (paper Fig. 4)
//!   probes     print probe row indices for a grid geometry
//!   artifacts  list loaded PJRT artifacts
//!   ensemble   serve a saved ROM: batched ensemble rollout + UQ stats
//!   serve      HTTP serving tier: multi-model, coalescing, hot-reload
//!
//! Examples:
//!   dopinf simulate --geometry cylinder --grid 192x36 --out data/cyl.snapd
//!   dopinf train --data data/cyl.snapd --procs 8 --save-rom models/cyl.rom
//!   dopinf scaling --data data/cyl.snapd --procs-list 1,2,4,8 --repeats 10
//!   dopinf ensemble --model models/cyl.rom --members 256 --steps 1200
//!   dopinf serve --model cyl=models/cyl.rom --port 8080 --workers 2

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use dopinf::coordinator::config::{DOpInfConfig, DataSource, Transport};
use dopinf::coordinator::pipeline::run_distributed;
use dopinf::coordinator::resilient::run_resilient;
use dopinf::coordinator::scaling::strong_scaling;
use dopinf::error::DOpInfError;
use dopinf::io::snapd::SnapReader;
use dopinf::opinf::serial::OpInfConfig;
use dopinf::rom::RegGrid;
use dopinf::runtime::{Engine, Manifest};
use dopinf::serve::{serve_ensemble, EnsembleSpec, HttpConfig, HttpServer, ModelRegistry, RomArtifact};
use dopinf::sim::driver::{run_to_dataset, SimConfig};
use dopinf::sim::synth::SynthSpec;
use dopinf::sim::{Geometry, Grid};
use dopinf::util::cli::{usage, Args, OptSpec};
use dopinf::util::csvout::CsvWriter;
use dopinf::util::json::Json;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            // a distributed-run failure prints the originating rank's
            // story ("run aborted by rank N: …") and exits with a
            // distinct status so a scheduler can tell "the run itself
            // failed mid-flight" from bad usage/setup. Note: a rank
            // abort is not necessarily transient — the message carries
            // the origin rank's error chain for that judgment.
            eprintln!("error: {e:#}");
            match e.downcast_ref::<DOpInfError>() {
                Some(DOpInfError::RemoteAbort { .. } | DOpInfError::Timeout { .. }) => 2,
                _ => 1,
            }
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "simulate" => cmd_simulate(rest),
        "train" => cmd_train(rest),
        "scaling" => cmd_scaling(rest),
        "probes" => cmd_probes(rest),
        "artifacts" => cmd_artifacts(rest),
        "ensemble" => cmd_ensemble(rest),
        "serve" => cmd_serve(rest),
        // hidden: a spawned rank of `--transport processes` (or one
        // started by hand on a remote host — see
        // examples/multinode_quickstart.md). Not in the help text: the
        // launcher composes this command line, operators rarely do.
        "worker" => cmd_worker(rest),
        "--help" | "-h" | "help" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command {other:?}; run `dopinf help`"),
    }
}

fn print_help() {
    println!(
        "dopinf — distributed Operator Inference (AIAA 2025-1170 reproduction)\n\n\
         Commands:\n\
           simulate   run the 2D Navier-Stokes solver, write a SNAPD dataset\n\
           train      run the distributed dOpInf pipeline\n\
           scaling    strong-scaling study (Fig. 4)\n\
           probes     print probe row indices for a geometry/grid\n\
           artifacts  list PJRT artifacts from a manifest\n\
           ensemble   serve a saved ROM: batched ensemble rollout + UQ stats\n\
           serve      HTTP serving tier: multi-model registry, request\n\
                      coalescing, hot-reload, graceful drain on ctrl-C\n\n\
         Run `dopinf <command> --help` for options."
    );
}

fn parse_grid(s: &str) -> Result<(usize, usize)> {
    let (a, b) = s.split_once('x').context("grid must look like 192x36")?;
    Ok((a.parse()?, b.parse()?))
}

fn parse_geometry(s: &str) -> Result<Geometry> {
    Ok(match s {
        "cylinder" => Geometry::Cylinder,
        "step" => Geometry::Step,
        "channel" => Geometry::Channel,
        other => bail!("unknown geometry {other:?} (cylinder|step|channel)"),
    })
}

// ---------------------------------------------------------------- simulate

fn cmd_simulate(tokens: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "geometry", help: "cylinder | step | channel", default: Some("cylinder"), is_flag: false },
        OptSpec { name: "grid", help: "NXxNY cells", default: Some("192x36"), is_flag: false },
        OptSpec { name: "out", help: "output SNAPD path", default: Some("data/flow.snapd"), is_flag: false },
        OptSpec { name: "t-end", help: "simulation end time (s)", default: None, is_flag: false },
        OptSpec { name: "t-sample", help: "sampling start time (s)", default: None, is_flag: false },
        OptSpec { name: "sample-every", help: "seconds between snapshots", default: None, is_flag: false },
        OptSpec { name: "help", help: "show this help", default: None, is_flag: true },
    ];
    let a = Args::parse(tokens, &specs)?;
    if a.flag("help") {
        print!("{}", usage("simulate", "Run the flow solver and write a training dataset", &specs));
        return Ok(());
    }
    let (nx, ny) = parse_grid(a.get_or("grid", "192x36"))?;
    let geometry = parse_geometry(a.get_or("geometry", "cylinder"))?;
    let mut cfg = match geometry {
        Geometry::Step => SimConfig::step(nx, ny),
        _ => SimConfig { geometry, ..SimConfig::cylinder(nx, ny) },
    };
    if let Some(v) = a.get("t-end") {
        cfg.t_end = v.parse()?;
    }
    if let Some(v) = a.get("t-sample") {
        cfg.t_sample = v.parse()?;
    }
    if let Some(v) = a.get("sample-every") {
        cfg.sample_every = v.parse()?;
    }
    let out = a.get_or("out", "data/flow.snapd");
    eprintln!("simulating {geometry:?} on {nx}x{ny} -> {out}");
    let info = run_to_dataset(&cfg, out)?;
    println!(
        "wrote {out}: {} cells x {} snapshots ({} solver steps), probes at rows {:?}",
        info.cells, info.n_samples, info.steps, info.probe_rows
    );
    Ok(())
}

// ------------------------------------------------------------------- train

fn train_specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "data", help: "SNAPD dataset path", default: None, is_flag: false },
        OptSpec { name: "synth", help: "train on generated data instead of a file: NXxNT spatial rows x snapshots of the analytic traveling-wave field (mutually exclusive with --data; trains on all NT columns)", default: None, is_flag: false },
        OptSpec { name: "procs", help: "number of ranks p", default: Some("4"), is_flag: false },
        OptSpec { name: "energy", help: "retained-energy target", default: Some("0.9996"), is_flag: false },
        OptSpec { name: "r", help: "override reduced dimension", default: None, is_flag: false },
        OptSpec { name: "train-frac", help: "fraction of snapshots used for training", default: Some("0.5"), is_flag: false },
        OptSpec { name: "scaling", help: "apply max-abs variable scaling", default: None, is_flag: true },
        OptSpec { name: "artifacts", help: "PJRT artifacts dir (omit for native)", default: None, is_flag: false },
        OptSpec { name: "results", help: "results output dir", default: Some("results"), is_flag: false },
        OptSpec { name: "grid-size", help: "reg grid: coarse | paper", default: Some("paper"), is_flag: false },
        OptSpec { name: "max-growth", help: "growth-ratio bound", default: Some("1.2"), is_flag: false },
        OptSpec { name: "procs-list", help: "(scaling) comma-separated p values", default: Some("1,2,4,8"), is_flag: false },
        OptSpec { name: "repeats", help: "(scaling) measurements per p", default: Some("10"), is_flag: false },
        OptSpec { name: "save-rom", help: "write the trained ROM artifact here (.rom)", default: None, is_flag: false },
        OptSpec { name: "transport", help: "communicator backend: threads | sockets | processes | hier", default: Some("threads"), is_flag: false },
        OptSpec { name: "nodes", help: "(hier) node count: ranks split into `nodes` contiguous balanced groups; collectives run local fold -> leader tree -> local broadcast (results are bitwise identical to the flat transports)", default: None, is_flag: false },
        OptSpec { name: "hosts", help: "(processes) comma-separated host per rank; all-localhost lists auto-spawn, any remote entry switches to manual worker launch (see examples/multinode_quickstart.md)", default: None, is_flag: false },
        OptSpec { name: "comm-timeout", help: "communication deadline in seconds (rendezvous + every collective); a dead rank fails the run instead of hanging it", default: None, is_flag: false },
        OptSpec { name: "chunk-rows", help: "stream ingestion in chunks of N local rows (default: whole block; native-engine results are bitwise identical)", default: None, is_flag: false },
        OptSpec { name: "memory-budget-mb", help: "derive the ingestion chunk size from a per-rank memory budget (MiB)", default: None, is_flag: false },
        OptSpec { name: "threads", help: "compute-plane worker threads per rank (default: DOPINF_THREADS or 1); results are bitwise identical for every value", default: None, is_flag: false },
        OptSpec { name: "oversubscribe", help: "allow procs x threads to exceed the visible cores (timesharing skews per-rank CPU timings)", default: None, is_flag: true },
        OptSpec { name: "trace", help: "write a Chrome trace-event timeline here: one track per rank with phase, data-plane, and per-collective spans (open in Perfetto / chrome://tracing; under `scaling` the last run wins)", default: None, is_flag: false },
        OptSpec { name: "metrics", help: "write a structured metrics summary here: per-category clock totals, the per-primitive comm table with the predicted-vs-measured cost-model ratio, phase aggregates, and gauges", default: None, is_flag: false },
        OptSpec { name: "simd", help: "kernel dispatch tier: off | scalar | native (default: DOPINF_SIMD or native; native and scalar are bitwise identical, off restores the legacy lane order)", default: None, is_flag: false },
        OptSpec { name: "checkpoint-every", help: "persist a checksummed per-rank state shard every N streamed chunks (plus the mandatory pass boundaries; 0 = boundaries only); resumed results are bitwise identical to an uninterrupted run", default: None, is_flag: false },
        OptSpec { name: "checkpoint-dir", help: "checkpoint directory (default: <results>/ckpt once --checkpoint-every or --max-retries is set)", default: None, is_flag: false },
        OptSpec { name: "max-retries", help: "supervised retries after a transient failure (dead rank, timeout, lost worker), resuming from the newest complete checkpoint manifest; contract violations and repeatedly-failing ranks fail fast", default: None, is_flag: false },
        OptSpec { name: "help", help: "show this help", default: None, is_flag: true },
    ]
}

fn parse_transport(s: &str) -> Result<Transport> {
    Ok(match s {
        "threads" => Transport::Threads,
        "sockets" => Transport::Sockets,
        "processes" => Transport::Processes,
        "hier" => Transport::Hier,
        other => bail!("unknown transport {other:?} (threads|sockets|processes|hier)"),
    })
}

fn parse_simd(a: &Args) -> Result<Option<dopinf::linalg::SimdTier>> {
    match a.get("simd") {
        None => Ok(None),
        Some(s) => match dopinf::linalg::simd::parse_tier(s) {
            Some(t) => Ok(Some(t)),
            None => bail!("unknown simd tier {s:?} (off|scalar|native)"),
        },
    }
}

fn parse_reg_grid(s: &str) -> Result<RegGrid> {
    Ok(match s {
        "coarse" => RegGrid::coarse(),
        "paper" => RegGrid::paper_default(),
        other => bail!("unknown regularization grid {other:?} (coarse|paper)"),
    })
}

/// Build the training configuration + data source from CLI options.
fn build_train_setup(a: &Args) -> Result<(DOpInfConfig, DataSource, Vec<usize>, usize)> {
    // dataset: a SNAPD file, or `--synth NXxNT` — the analytic
    // traveling-wave generator, so smoke/trace runs need no file
    let (source, ns, nt_total, nt_train, probe_rows) = match (a.get("data"), a.get("synth")) {
        (Some(_), Some(_)) => bail!("--data and --synth are mutually exclusive"),
        (None, None) => bail!("--data is required (or --synth NXxNT for generated data)"),
        (Some(data), None) => {
            let reader = SnapReader::open(data)?;
            let vars: Vec<String> = reader.variables().iter().map(|s| s.to_string()).collect();
            let ns = vars.len();
            let nt_total = reader.var_info(&vars[0])?.cols;
            let train_frac: f64 = a.get_parse("train-frac", 0.5)?;
            let nt_train = ((nt_total as f64 * train_frac).round() as usize).clamp(2, nt_total);
            // probe rows from metadata (written by `dopinf simulate`)
            let probe_rows: Vec<usize> = reader
                .meta()
                .get("probe_rows")
                .and_then(Json::as_arr)
                .map(|arr| arr.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default();
            // the source itself carries the training-column truncation —
            // the streamed readers slice columns per chunk, so no
            // truncated copy of the dataset is ever staged in memory
            let source = DataSource::File {
                path: PathBuf::from(data),
                variables: vars,
                nt_train: Some(nt_train),
            };
            (source, ns, nt_total, nt_train, probe_rows)
        }
        (None, Some(spec)) => {
            let (nx, nt) = parse_grid(spec).context("--synth must look like NXxNT")?;
            anyhow::ensure!(nx >= 1 && nt >= 2, "--synth needs NX >= 1 and NT >= 2");
            let spec = SynthSpec { nx, nt, ..Default::default() };
            let ns = spec.ns;
            (DataSource::Synthetic(spec), ns, nt, nt, Vec::new())
        }
    };

    let grid = parse_reg_grid(a.get_or("grid-size", "paper"))?;
    let opinf = OpInfConfig {
        ns,
        energy_target: a.get_parse("energy", 0.9996)?,
        r_override: a.get("r").map(|v| v.parse()).transpose()?,
        scaling: a.flag("scaling"),
        grid,
        max_growth: a.get_parse("max-growth", 1.2)?,
        nt_p: nt_total,
    };
    let mut cfg = DOpInfConfig::new(a.get_parse("procs", 4)?, opinf);
    cfg.transport = parse_transport(a.get_or("transport", "threads"))?;
    // hier topology: --nodes groups the ranks; validated against p in
    // the pipeline's setup step (1 <= nodes <= p)
    if let Some(v) = a.get("nodes") {
        anyhow::ensure!(
            cfg.transport == Transport::Hier,
            "--nodes only applies to --transport hier"
        );
        cfg.nodes = v.parse().context("--nodes")?;
    }
    // process placement: one host per rank; validated in the launcher
    // (plan_hosts) against the rank count
    if let Some(v) = a.get("hosts") {
        anyhow::ensure!(
            cfg.transport == Transport::Processes,
            "--hosts only applies to --transport processes"
        );
        cfg.hosts = v.split(',').map(|h| h.trim().to_string()).collect();
    }
    cfg.artifacts_dir = a.get("artifacts").map(PathBuf::from);
    // intra-rank compute plane: p ranks x T worker threads (bitwise
    // identical results at any T — only wall time changes)
    cfg.threads_per_rank = a.get_parse("threads", dopinf::linalg::par::env_threads())?;
    anyhow::ensure!(cfg.threads_per_rank >= 1, "--threads must be >= 1");
    cfg.allow_oversubscribe = a.flag("oversubscribe");
    // lane-order plane: native and scalar are bitwise identical, so the
    // choice never changes results — only `off` (legacy arithmetic) does
    cfg.simd = parse_simd(&a)?;
    if let Some(v) = a.get("comm-timeout") {
        let secs: f64 = v.parse().context("--comm-timeout")?;
        anyhow::ensure!(secs > 0.0, "--comm-timeout must be positive");
        cfg.comm_timeout = Some(secs);
    }
    // streamed ingestion: an explicit chunk size, or one derived from a
    // per-rank memory budget (chunk bytes ≈ rows × nt_total × 8 — the
    // full stored row streams through memory even when training
    // truncates columns)
    match (a.get("chunk-rows"), a.get("memory-budget-mb")) {
        (Some(_), Some(_)) => {
            bail!("--chunk-rows and --memory-budget-mb are mutually exclusive")
        }
        (Some(v), None) => {
            let n: usize = v.parse().context("--chunk-rows")?;
            anyhow::ensure!(n >= 1, "--chunk-rows must be >= 1");
            cfg.chunk_rows = Some(n);
        }
        (None, Some(v)) => {
            let mb: f64 = v.parse().context("--memory-budget-mb")?;
            anyhow::ensure!(mb > 0.0, "--memory-budget-mb must be positive");
            // peak residency per chunk is ~3x the chunk payload: the
            // destination matrix plus the read path's raw-byte and
            // decoded staging buffers live simultaneously
            let rows =
                ((mb * 1024.0 * 1024.0) / (3.0 * 8.0 * nt_total as f64)).floor() as usize;
            cfg.chunk_rows = Some(rows.max(1));
        }
        (None, None) => {}
    }
    // resilience plane (see crate::ckpt): either knob arms
    // checkpointing; the supervised driver engages in cmd_train when
    // any of the three is set
    if let Some(v) = a.get("checkpoint-every") {
        cfg.checkpoint_every = v.parse().context("--checkpoint-every")?;
    }
    if let Some(v) = a.get("max-retries") {
        cfg.max_retries = v.parse().context("--max-retries")?;
    }
    cfg.checkpoint_dir = match a.get("checkpoint-dir") {
        Some(dir) => Some(PathBuf::from(dir)),
        // keep the shards next to the other run outputs by default
        None if a.get("checkpoint-every").is_some() || a.get("max-retries").is_some() => {
            Some(PathBuf::from(a.get_or("results", "results")).join("ckpt"))
        }
        None => None,
    };
    // observability exports (see crate::obs): span recording turns on
    // iff one of these is set — results are bitwise identical either way
    cfg.trace = a.get("trace").map(PathBuf::from);
    cfg.metrics = a.get("metrics").map(PathBuf::from);
    // probes on both velocity variables
    for &row in &probe_rows {
        for var in 0..ns {
            cfg.probes.push((var, row));
        }
    }
    Ok((cfg, source, probe_rows, nt_train))
}

fn cmd_train(tokens: &[String]) -> Result<()> {
    let specs = train_specs();
    let a = Args::parse(tokens, &specs)?;
    if a.flag("help") {
        print!("{}", usage("train", "Run the distributed dOpInf pipeline", &specs));
        return Ok(());
    }
    let (cfg, source, probe_rows, nt_train) = build_train_setup(&a)?;
    eprintln!(
        "training: p={} nt_train={nt_train} nt_p={} energy={} chunk_rows={} artifacts={:?}",
        cfg.p,
        cfg.opinf.nt_p,
        cfg.opinf.energy_target,
        cfg.chunk_rows.map_or("block".to_string(), |n| n.to_string()),
        cfg.artifacts_dir
    );
    // any resilience knob routes through the supervised retry driver;
    // the plain path stays byte-for-byte what it always was
    let result = if cfg.checkpoint_dir.is_some() || cfg.max_retries > 0 {
        let outcome = run_resilient(&cfg, &source)?;
        if outcome.retries() > 0 {
            println!(
                "resilient run: {} attempts ({} retries, resumed from epochs {:?})",
                outcome.attempts,
                outcome.retries(),
                outcome.resumed_from
            );
        }
        outcome.result
    } else {
        run_distributed(&cfg, &source)?
    };

    println!("reduced dimension r = {}", result.r);
    println!(
        "optimal pair (beta1, beta2) = ({:.4e}, {:.4e}) on rank {}",
        result.opt_pair.0, result.opt_pair.1, result.winner_rank
    );
    println!("training error = {:.4e}", result.train_err);
    println!("ROM rollout time = {:.4} s for {} steps", result.rom_time, result.qtilde.cols());
    let b = result.timing.breakdown();
    println!(
        "virtual time = {:.4} s (load {:.4}, compute {:.4}, comm {:.4}, learn {:.4}, post {:.4})",
        b.total, b.load, b.compute, b.comm, b.learn, b.post
    );

    // persist outputs
    let results_dir = PathBuf::from(a.get_or("results", "results"));
    std::fs::create_dir_all(&results_dir)?;
    let mut spectrum = CsvWriter::create(
        results_dir.join("spectrum.csv"),
        &["k", "eigenvalue", "retained_energy"],
    )?;
    for (k, (e, re)) in result.eigs.iter().zip(&result.retained_energy).enumerate() {
        spectrum.row(&[(k + 1) as f64, *e, *re])?;
    }
    spectrum.finish()?;
    for pred in &result.probes {
        let name = format!("dOpInf_probe_row{}_var{}.npy", pred.row, pred.var);
        dopinf::util::npy::write_f64(
            results_dir.join(&name),
            &[pred.values.len()],
            &pred.values,
        )?;
    }
    if !result.probes.is_empty() {
        println!("wrote {} probe predictions for rows {probe_rows:?}", result.probes.len());
    }

    // persist the servable ROM artifact (training → artifact → serving)
    if let Some(rom_path) = a.get("save-rom") {
        let mut meta = std::collections::BTreeMap::new();
        meta.insert("dataset".to_string(), a.get_or("data", "?").to_string());
        meta.insert("r".to_string(), result.r.to_string());
        meta.insert(
            "beta_pair".to_string(),
            format!("({:.6e}, {:.6e})", result.opt_pair.0, result.opt_pair.1),
        );
        meta.insert("train_err".to_string(), format!("{:.6e}", result.train_err));
        meta.insert("procs".to_string(), cfg.p.to_string());
        let artifact = dopinf::serve::RomArtifact {
            ops: result.ops.clone(),
            qhat0: result.qhat0.clone(),
            probes: result.probe_bases.clone(),
            // v2: persist the normal-equation blocks so `ensemble
            // --reg-ensemble` can re-solve reg-pair ensembles later
            reg: Some(dopinf::serve::RegBlocks::from_problem(&result.problem)),
            meta,
        };
        artifact.save(rom_path)?;
        println!(
            "saved ROM artifact to {rom_path} (r={}, {} probes, reg blocks included)",
            result.r,
            artifact.probes.len()
        );
    }
    println!("results in {}", results_dir.display());
    Ok(())
}

// ----------------------------------------------------------------- scaling

fn cmd_scaling(tokens: &[String]) -> Result<()> {
    let specs = train_specs();
    let a = Args::parse(tokens, &specs)?;
    if a.flag("help") {
        print!("{}", usage("scaling", "Strong-scaling study (Fig. 4)", &specs));
        return Ok(());
    }
    let (cfg, source, _, _nt_train) = build_train_setup(&a)?;
    let procs = a.get_list::<usize>("procs-list", &[1, 2, 4, 8])?;
    let repeats = a.get_parse("repeats", 10)?;

    let rows = strong_scaling(&cfg, &source, &procs, repeats)?;
    println!(
        "{:>4} {:>12} {:>12} {:>9}  breakdown (load/compute/comm/learn/post)",
        "p", "mean [s]", "std [s]", "speedup"
    );
    let results_dir = PathBuf::from(a.get_or("results", "results"));
    let mut csv = CsvWriter::create(
        results_dir.join("scaling.csv"),
        &["p", "mean_s", "std_s", "speedup", "load", "compute", "comm", "learn", "post"],
    )?;
    for row in &rows {
        let b = &row.breakdown;
        println!(
            "{:>4} {:>12.5} {:>12.5} {:>9.3}  {:.4}/{:.4}/{:.4}/{:.4}/{:.4}",
            row.p, row.mean_s, row.std_s, row.speedup, b.load, b.compute, b.comm, b.learn, b.post
        );
        csv.row(&[
            row.p as f64, row.mean_s, row.std_s, row.speedup, b.load, b.compute, b.comm, b.learn,
            b.post,
        ])?;
    }
    csv.finish()?;
    println!("wrote {}/scaling.csv", results_dir.display());
    Ok(())
}

// ------------------------------------------------------------------ probes

fn cmd_probes(tokens: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "geometry", help: "cylinder | step | channel", default: Some("cylinder"), is_flag: false },
        OptSpec { name: "grid", help: "NXxNY cells", default: Some("192x36"), is_flag: false },
        OptSpec { name: "at", help: "comma-separated x:y pairs (defaults to the paper's probes)", default: None, is_flag: false },
        OptSpec { name: "help", help: "show this help", default: None, is_flag: true },
    ];
    let a = Args::parse(tokens, &specs)?;
    if a.flag("help") {
        print!("{}", usage("probes", "Map probe locations to dataset rows", &specs));
        return Ok(());
    }
    let (nx, ny) = parse_grid(a.get_or("grid", "192x36"))?;
    let geometry = parse_geometry(a.get_or("geometry", "cylinder"))?;
    let (lx, ly) = match geometry {
        Geometry::Cylinder => (2.2, 0.41),
        Geometry::Step => (4.0, 1.0),
        Geometry::Channel => (2.0, 1.0),
    };
    let grid = Grid::new(geometry, nx, ny, lx, ly);
    let locations: Vec<(f64, f64)> = match a.get("at") {
        Some(spec) => spec
            .split(',')
            .map(|pair| -> Result<(f64, f64)> {
                let (x, y) = pair.split_once(':').context("use x:y")?;
                Ok((x.trim().parse()?, y.trim().parse()?))
            })
            .collect::<Result<_>>()?,
        None => dopinf::io::probes::ProbeSet::paper_fractions()
            .iter()
            .map(|(fx, fy)| (fx * lx, fy * ly))
            .collect(),
    };
    for (x, y) in locations {
        println!("({x:.4}, {y:.4}) -> row {}", grid.probe_index(x, y));
    }
    Ok(())
}

// --------------------------------------------------------------- artifacts

fn cmd_artifacts(tokens: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "dir", help: "artifacts directory", default: Some("artifacts"), is_flag: false },
        OptSpec { name: "help", help: "show this help", default: None, is_flag: true },
    ];
    let a = Args::parse(tokens, &specs)?;
    if a.flag("help") {
        print!("{}", usage("artifacts", "List PJRT artifacts", &specs));
        return Ok(());
    }
    let dir = PathBuf::from(a.get_or("dir", "artifacts"));
    let manifest = Manifest::load(&dir)?;
    if manifest.entries.is_empty() {
        println!("no artifacts in {} (run `make artifacts`)", dir.display());
        return Ok(());
    }
    println!("{:<16} {:<8} {:<28} inputs -> outputs", "entry", "profile", "file");
    for e in &manifest.entries {
        println!(
            "{:<16} {:<8} {:<28} {:?} -> {:?}",
            e.name,
            e.profile,
            e.path.file_name().unwrap_or_default().to_string_lossy(),
            e.inputs,
            e.outputs
        );
    }
    Ok(())
}

// ---------------------------------------------------------------- ensemble

fn cmd_ensemble(tokens: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "model", help: "ROM artifact path (from train --save-rom)", default: None, is_flag: false },
        OptSpec { name: "members", help: "ensemble size B", default: Some("256"), is_flag: false },
        OptSpec { name: "sigma", help: "relative std-dev of IC perturbations", default: Some("0.01"), is_flag: false },
        OptSpec { name: "steps", help: "rollout horizon per member", default: Some("1200"), is_flag: false },
        OptSpec { name: "workers", help: "rank workers to shard members over", default: Some("4"), is_flag: false },
        OptSpec { name: "threads", help: "compute-plane worker threads per rank worker (default: DOPINF_THREADS or 1); results are bitwise identical for every value", default: None, is_flag: false },
        OptSpec { name: "simd", help: "kernel dispatch tier: off | scalar | native (default: DOPINF_SIMD or native; native and scalar are bitwise identical, off restores the legacy lane order)", default: None, is_flag: false },
        OptSpec { name: "oversubscribe", help: "allow workers x threads to exceed the visible cores", default: None, is_flag: true },
        OptSpec { name: "seed", help: "ensemble RNG seed", default: Some("7"), is_flag: false },
        OptSpec { name: "results", help: "results output dir", default: Some("results"), is_flag: false },
        OptSpec { name: "artifacts", help: "PJRT artifacts dir (omit for native)", default: None, is_flag: false },
        OptSpec { name: "reg-ensemble", help: "ensemble over regularization pairs (needs a v2 .rom with reg blocks)", default: None, is_flag: true },
        OptSpec { name: "reg-grid", help: "(reg-ensemble) candidate grid: coarse | paper", default: Some("coarse"), is_flag: false },
        OptSpec { name: "help", help: "show this help", default: None, is_flag: true },
    ];
    let a = Args::parse(tokens, &specs)?;
    if a.flag("help") {
        print!(
            "{}",
            usage("ensemble", "Serve a trained ROM: batched ensemble rollout + UQ statistics", &specs)
        );
        return Ok(());
    }
    let model_path = a.get("model").context("--model is required (train with --save-rom)")?;
    let artifact = RomArtifact::load(model_path)?;
    let n_steps: usize = a.get_parse("steps", 1200)?;
    // arm the compute plane for the batched rollout (bitwise identical
    // results at any value; member bands carry the parallelism). Same
    // oversubscription guard as the training pipeline: rank workers are
    // threads of this process, so workers x threads is the real thread
    // footprint (the reg-ensemble path is single-process: workers = 1).
    let threads: usize = a.get_parse("threads", dopinf::linalg::par::env_threads())?;
    anyhow::ensure!(threads >= 1, "--threads must be >= 1");
    let guard_workers: usize =
        if a.flag("reg-ensemble") { 1 } else { a.get_parse("workers", 4)? };
    if let Err(msg) = dopinf::linalg::par::check_oversubscription(
        guard_workers,
        threads,
        a.flag("oversubscribe"),
    ) {
        bail!("{msg}; lower --workers/--threads or pass --oversubscribe to opt in");
    }
    dopinf::linalg::par::set_threads(threads);
    if let Some(t) = parse_simd(&a)? {
        dopinf::linalg::simd::set_tier(t);
    }
    if !artifact.meta.is_empty() {
        let meta: Vec<String> =
            artifact.meta.iter().map(|(k, v)| format!("{k}={v}")).collect();
        eprintln!("provenance: {}", meta.join(", "));
    }

    let results_dir = PathBuf::from(a.get_or("results", "results"));
    let t = dopinf::util::timer::WallTimer::start();
    let (stats, prefix) = if a.flag("reg-ensemble") {
        // members come from the candidate grid, the rollout is native
        // and single-process — reject flags that would silently do
        // nothing rather than leaving the user guessing
        for flag in ["members", "sigma", "seed", "workers", "artifacts"] {
            anyhow::ensure!(
                a.get(flag).is_none(),
                "--{flag} does not apply to --reg-ensemble (ensemble size = solvable \
                 grid pairs; use --reg-grid to change the candidate set)"
            );
        }
        let pairs = parse_reg_grid(a.get_or("reg-grid", "coarse"))?.pairs();
        eprintln!(
            "serving {model_path}: r={}, {} probes, reg ensemble over {} candidate pairs x {n_steps} steps",
            artifact.r(),
            artifact.probes.len(),
            pairs.len()
        );
        let ens = dopinf::serve::run_reg_ensemble(&artifact, &pairs, n_steps)?;
        println!(
            "reg ensemble: {} of {} pairs solvable ({} skipped)",
            ens.pairs_used.len(),
            pairs.len(),
            ens.skipped.len()
        );
        (ens.stats, "regens")
    } else {
        let spec = EnsembleSpec {
            members: a.get_parse("members", 256)?,
            sigma: a.get_parse("sigma", 0.01)?,
            seed: a.get_parse("seed", 7)?,
            n_steps,
        };
        let workers: usize = a.get_parse("workers", 4)?;
        let engine = match a.get("artifacts") {
            Some(dir) => Engine::from_artifacts(std::path::Path::new(dir))?,
            None => Engine::native(),
        };
        eprintln!(
            "serving {model_path}: r={}, {} probes, B={} members x {} steps over {workers} workers",
            artifact.r(),
            artifact.probes.len(),
            spec.members,
            spec.n_steps
        );
        (serve_ensemble(&engine, &artifact, &spec, workers)?, "ensemble")
    };
    let elapsed = t.elapsed();
    let member_steps = (stats.members * stats.n_steps) as f64;
    println!(
        "rolled {} member-steps in {:.4} s ({:.3e} member-steps/s), {} of {} members diverged",
        stats.members * stats.n_steps,
        elapsed,
        member_steps / elapsed.max(1e-12),
        stats.n_diverged(),
        stats.members
    );

    for series in &stats.probes {
        let k_last = stats.n_steps - 1;
        println!(
            "probe var{} row{}: final mean {:.6e}, variance {:.6e}, [q05, q95] = [{:.6e}, {:.6e}] ({} members)",
            series.var,
            series.row,
            series.mean[k_last],
            series.variance[k_last],
            series.q05[k_last],
            series.q95[k_last],
            series.count[k_last]
        );
        let name = format!("{prefix}_probe_var{}_row{}.csv", series.var, series.row);
        let mut csv = CsvWriter::create(
            results_dir.join(&name),
            &["step", "mean", "variance", "q05", "q50", "q95", "count"],
        )?;
        for k in 0..stats.n_steps {
            csv.row(&[
                k as f64,
                series.mean[k],
                series.variance[k],
                series.q05[k],
                series.q50[k],
                series.q95[k],
                series.count[k] as f64,
            ])?;
        }
        csv.finish()?;
    }
    if !stats.probes.is_empty() {
        println!("wrote {} ensemble series to {}", stats.probes.len(), results_dir.display());
    }
    Ok(())
}

// ---------------------------------------------------------------- serve

/// Set by the SIGINT handler; the serve loop polls it.
static SIGINT_SEEN: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

/// `signal(2)` handler — async-signal-safe: one atomic store, nothing
/// else.
extern "C" fn note_sigint(_signum: i32) {
    SIGINT_SEEN.store(true, std::sync::atomic::Ordering::SeqCst);
}

fn cmd_serve(tokens: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "model", help: "NAME=PATH (repeatable) or a bare PATH (file stem names it)", default: None, is_flag: false },
        OptSpec { name: "bind", help: "address to bind", default: Some("127.0.0.1"), is_flag: false },
        OptSpec { name: "port", help: "port to bind (0 picks an ephemeral port)", default: Some("8080"), is_flag: false },
        OptSpec { name: "workers", help: "evaluation worker threads behind the queue", default: Some("2"), is_flag: false },
        OptSpec { name: "threads", help: "compute-plane threads per evaluation (default: DOPINF_THREADS or 1); results are bitwise identical for every value", default: None, is_flag: false },
        OptSpec { name: "simd", help: "kernel dispatch tier: off | scalar | native (default: DOPINF_SIMD or native; native and scalar are bitwise identical, off restores the legacy lane order)", default: None, is_flag: false },
        OptSpec { name: "oversubscribe", help: "allow workers x threads to exceed the visible cores", default: None, is_flag: true },
        OptSpec { name: "max-queue", help: "pending requests before 503 + Retry-After", default: Some("256"), is_flag: false },
        OptSpec { name: "request-timeout", help: "default per-request deadline in seconds (0 disables)", default: Some("30"), is_flag: false },
        OptSpec { name: "no-coalesce", help: "disable cross-request coalescing (results are bitwise identical either way)", default: None, is_flag: true },
        OptSpec { name: "coalesce-max", help: "total members a fused batch may hold", default: Some("1024"), is_flag: false },
        OptSpec { name: "split-members", help: "members at/above this shard over rank workers", default: Some("8192"), is_flag: false },
        OptSpec { name: "split-workers", help: "most rank workers one split request may use", default: Some("4"), is_flag: false },
        OptSpec { name: "max-connections", help: "concurrent connections before 503", default: Some("64"), is_flag: false },
        OptSpec { name: "max-body-kb", help: "largest accepted request body, KiB", default: Some("1024"), is_flag: false },
        OptSpec { name: "admin-shutdown", help: "enable POST /admin/shutdown (tests/CI; SIGINT is the production path)", default: None, is_flag: true },
        OptSpec { name: "metrics", help: "write a final /metrics snapshot to FILE on shutdown", default: None, is_flag: false },
        OptSpec { name: "help", help: "show this help", default: None, is_flag: true },
    ];
    let a = Args::parse(tokens, &specs)?;
    if a.flag("help") {
        print!(
            "{}",
            usage(
                "serve",
                "HTTP serving tier over saved ROMs: POST /v1/ensemble with \
                 cross-request coalescing (bitwise identical to solo serving), \
                 GET /v1/models, POST /v1/models/{name}/reload (hot-reload), \
                 GET /healthz, GET /metrics. Ctrl-C drains gracefully.",
                &specs
            )
        );
        return Ok(());
    }

    let model_args = a.get_all("model");
    anyhow::ensure!(
        !model_args.is_empty(),
        "--model is required at least once (NAME=PATH, or PATH to use the file stem as the name)"
    );
    let mut model_specs = Vec::new();
    for m in model_args {
        let (name, path) = match m.split_once('=') {
            Some((n, p)) => (n.to_string(), PathBuf::from(p)),
            None => {
                let path = PathBuf::from(m);
                let stem = path
                    .file_stem()
                    .map(|s| s.to_string_lossy().into_owned())
                    .filter(|s| !s.is_empty())
                    .with_context(|| format!("cannot derive a model name from {m:?}; use NAME=PATH"))?;
                (stem, path)
            }
        };
        model_specs.push((name, path));
    }
    let registry = ModelRegistry::open(&model_specs)?;
    let names: Vec<&str> = model_specs.iter().map(|(n, _)| n.as_str()).collect();

    // evaluation workers are threads of this process, so workers x
    // threads is the real thread footprint — same guard as train/ensemble
    let workers: usize = a.get_parse("workers", 2)?;
    anyhow::ensure!(workers >= 1, "--workers must be >= 1");
    let threads: usize = a.get_parse("threads", dopinf::linalg::par::env_threads())?;
    anyhow::ensure!(threads >= 1, "--threads must be >= 1");
    if let Err(msg) =
        dopinf::linalg::par::check_oversubscription(workers, threads, a.flag("oversubscribe"))
    {
        bail!("{msg}; lower --workers/--threads or pass --oversubscribe to opt in");
    }
    dopinf::linalg::par::set_threads(threads);
    if let Some(t) = parse_simd(&a)? {
        dopinf::linalg::simd::set_tier(t);
    }

    let bind = a.get_or("bind", "127.0.0.1");
    let port: u16 = a.get_parse("port", 8080)?;
    let timeout_s: u64 = a.get_parse("request-timeout", 30)?;
    let max_body_kb: usize = a.get_parse("max-body-kb", 1024)?;
    anyhow::ensure!(max_body_kb >= 1, "--max-body-kb must be >= 1");
    let cfg = HttpConfig {
        addr: format!("{bind}:{port}"),
        workers,
        max_queue: a.get_parse("max-queue", 256)?,
        request_timeout: (timeout_s > 0).then(|| std::time::Duration::from_secs(timeout_s)),
        coalesce: !a.flag("no-coalesce"),
        max_coalesce_members: a.get_parse("coalesce-max", 1024)?,
        split_members: a.get_parse("split-members", 8192)?,
        split_workers: a.get_parse("split-workers", 4)?,
        max_connections: a.get_parse("max-connections", 64)?,
        limits: dopinf::serve::http::Limits {
            max_body: max_body_kb * 1024,
            ..Default::default()
        },
        admin_shutdown: a.flag("admin-shutdown"),
        metrics_path: a.get("metrics").map(PathBuf::from),
        ..HttpConfig::default()
    };

    // install the handler before the listener exists so a race-early
    // ctrl-C still drains instead of killing the process
    unsafe {
        libc::signal(libc::SIGINT, note_sigint as libc::sighandler_t);
    }

    let server = HttpServer::start(registry, cfg)?;
    eprintln!(
        "serving {} model(s) [{}] with {workers} worker(s) x {threads} thread(s)",
        names.len(),
        names.join(", ")
    );
    println!("listening on http://{}", server.local_addr());

    while !SIGINT_SEEN.load(std::sync::atomic::Ordering::SeqCst) && !server.shutdown_requested() {
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    eprintln!("shutdown requested; draining in-flight requests...");
    server.request_shutdown();
    let final_metrics = server.join()?;
    let responses = final_metrics
        .get("http")
        .and_then(|h| h.get("responses"))
        .and_then(Json::as_usize)
        .unwrap_or(0);
    let connections = final_metrics
        .get("http")
        .and_then(|h| h.get("connections"))
        .and_then(Json::as_usize)
        .unwrap_or(0);
    println!("drained cleanly: {responses} response(s) over {connections} connection(s)");
    Ok(())
}

// ---------------------------------------------------------------- worker

/// One spawned rank of `--transport processes`: rendezvous with the
/// rank-0 hub, receive the job frame, run it, ship the join report.
/// The command line is normally composed by the launcher
/// (`comm::proc::launch`); on a remote host the operator runs it by
/// hand (examples/multinode_quickstart.md).
fn cmd_worker(tokens: &[String]) -> Result<()> {
    let specs = [
        OptSpec { name: "rank", help: "this worker's rank (1..size-1 when spawned; any non-zero rank when launched by hand)", default: None, is_flag: false },
        OptSpec { name: "size", help: "total rank count p of the group", default: None, is_flag: false },
        OptSpec { name: "hub", help: "rank-0 rendezvous address, host:port", default: None, is_flag: false },
        OptSpec { name: "comm-timeout", help: "communication deadline in seconds (must match the hub's)", default: None, is_flag: false },
        OptSpec { name: "threads", help: "compute-plane worker threads for this rank", default: None, is_flag: false },
        OptSpec { name: "simd", help: "kernel dispatch tier: off | scalar | native", default: None, is_flag: false },
        OptSpec { name: "help", help: "show this help", default: None, is_flag: true },
    ];
    let a = Args::parse(tokens, &specs)?;
    if a.flag("help") {
        print!(
            "{}",
            usage(
                "worker",
                "One rank of a multi-process group (spawned by `train --transport \
                 processes`, or started by hand on a remote host)",
                &specs
            )
        );
        return Ok(());
    }
    let rank: usize = a.get("rank").context("--rank is required")?.parse().context("--rank")?;
    let size: usize = a.get("size").context("--size is required")?.parse().context("--size")?;
    anyhow::ensure!(size >= 2, "--size must be >= 2 (a 1-rank group has no workers)");
    anyhow::ensure!(rank >= 1 && rank < size, "--rank must be in 1..size (rank 0 is the hub)");
    let hub = a.get("hub").context("--hub is required (host:port of rank 0)")?.to_string();
    let timeout = match a.get("comm-timeout") {
        None => None,
        Some(v) => {
            let secs: f64 = v.parse().context("--comm-timeout")?;
            anyhow::ensure!(secs > 0.0, "--comm-timeout must be positive");
            Some(std::time::Duration::from_secs_f64(secs))
        }
    };
    // arm the per-process knobs from argv before any job runs; the job
    // frame carries the rest of the configuration
    if let Some(v) = a.get("threads") {
        let t: usize = v.parse().context("--threads")?;
        anyhow::ensure!(t >= 1, "--threads must be >= 1");
        dopinf::linalg::par::set_threads(t);
    }
    if let Some(t) = parse_simd(&a)? {
        dopinf::linalg::simd::set_tier(t);
    }
    let boot = dopinf::comm::proc::WorkerBoot { rank, size, hub, timeout };
    dopinf::coordinator::launch::worker_main(&boot)
        .map_err(|e| anyhow::Error::from(DOpInfError::from(e)))
}

//! The crate's top-level typed error: what a distributed run can
//! report to its caller.
//!
//! [`crate::run_distributed`] joins every rank and aggregates their
//! failures into one [`DOpInfError`]. The contract that makes
//! single-rank failures survivable at scale: a rank that fails
//! mid-pipeline broadcasts an abort through its
//! [`crate::comm::Communicator`], so *every* rank returns promptly —
//! the originating rank with its own error, the siblings with
//! [`crate::comm::CommError::RemoteAbort`] — and the aggregation
//! recovers the origin. Unlike `MPI_Abort`, nothing kills the process:
//! the error is an ordinary `Result` at the `run_distributed`
//! boundary, so a driver can retry, reschedule, or report.

use std::fmt;

use crate::comm::CommError;

/// Error of one distributed training / serving run.
#[derive(Debug)]
pub enum DOpInfError {
    /// A rank failed mid-pipeline and the abort was broadcast:
    /// `origin_rank` is the rank whose failure started it, `message`
    /// its rank-local error chain.
    RemoteAbort { origin_rank: usize, message: String },
    /// A communication deadline elapsed (`--comm-timeout`): a worker
    /// never connected, or a peer died silently mid-collective.
    Timeout { rank: usize, seconds: f64, message: String },
    /// The communication layer failed in a non-abort way (contract
    /// violation, lost connection, corrupt frame).
    Comm { rank: usize, source: CommError },
    /// A rank failed without a comm-layer classification (shouldn't
    /// normally happen — rank failures are wrapped into aborts — but
    /// kept so no error is ever swallowed).
    Rank { rank: usize, source: anyhow::Error },
    /// The run failed outside the rank pipeline: before any rank
    /// launched (bad config, unreadable dataset, rendezvous bind
    /// failure), or after a successful join when a requested
    /// `--trace`/`--metrics` export could not be written.
    Setup(anyhow::Error),
}

impl DOpInfError {
    /// Aggregate per-rank failures (rank id, rank error) into the run
    /// error, preferring the *originating* rank's story:
    ///
    /// 1. a rank whose `RemoteAbort` names itself (it started the
    ///    abort — its message is the root cause),
    /// 2. any `RemoteAbort` (origin recovered from a sibling),
    /// 3. a `Timeout`, then any other typed comm error,
    /// 4. the first rank error verbatim.
    pub fn from_rank_failures(mut failures: Vec<(usize, anyhow::Error)>) -> DOpInfError {
        assert!(!failures.is_empty(), "no failures to aggregate");
        let comm_of = |e: &anyhow::Error| e.downcast_ref::<CommError>().cloned();
        if let Some((rank, e)) = failures.iter().find(|(rank, e)| {
            matches!(comm_of(e), Some(CommError::RemoteAbort { origin_rank, .. }) if origin_rank == *rank)
        }) {
            let Some(CommError::RemoteAbort { message, .. }) = comm_of(e) else { unreachable!() };
            return DOpInfError::RemoteAbort { origin_rank: *rank, message };
        }
        if let Some(CommError::RemoteAbort { origin_rank, message }) =
            failures.iter().find_map(|(_, e)| match comm_of(e) {
                Some(ce @ CommError::RemoteAbort { .. }) => Some(ce),
                _ => None,
            })
        {
            return DOpInfError::RemoteAbort { origin_rank, message };
        }
        if let Some((rank, seconds, waiting_for)) =
            failures.iter().find_map(|(_, e)| match comm_of(e) {
                Some(CommError::Timeout { rank, seconds, waiting_for }) => {
                    Some((rank, seconds, waiting_for))
                }
                _ => None,
            })
        {
            return DOpInfError::Timeout { rank, seconds, message: waiting_for };
        }
        if let Some((rank, ce)) = failures.iter().find_map(|(rank, e)| comm_of(e).map(|ce| (*rank, ce)))
        {
            return DOpInfError::Comm { rank, source: ce };
        }
        let (rank, source) = failures.swap_remove(0);
        DOpInfError::Rank { rank, source }
    }

    /// The rank this error is attributed to (origin for aborts), if the
    /// failure happened after ranks launched.
    pub fn rank(&self) -> Option<usize> {
        match self {
            DOpInfError::RemoteAbort { origin_rank, .. } => Some(*origin_rank),
            DOpInfError::Timeout { rank, .. }
            | DOpInfError::Comm { rank, .. }
            | DOpInfError::Rank { rank, .. } => Some(*rank),
            DOpInfError::Setup(_) => None,
        }
    }
}

impl fmt::Display for DOpInfError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DOpInfError::RemoteAbort { origin_rank, message } => {
                write!(f, "run aborted by rank {origin_rank}: {message}")
            }
            DOpInfError::Timeout { rank, seconds, message } => {
                write!(
                    f,
                    "communication timed out on rank {rank} after {seconds:.1}s ({message})"
                )
            }
            DOpInfError::Comm { rank, source } => {
                write!(f, "communication failed on rank {rank}: {source}")
            }
            DOpInfError::Rank { rank, source } => write!(f, "rank {rank} failed: {source:#}"),
            DOpInfError::Setup(source) => write!(f, "run setup failed: {source:#}"),
        }
    }
}

impl std::error::Error for DOpInfError {}

impl From<CommError> for DOpInfError {
    /// Lift a pre-launch comm failure (socket rendezvous) into the run
    /// error.
    fn from(e: CommError) -> DOpInfError {
        match e {
            CommError::RemoteAbort { origin_rank, message } => {
                DOpInfError::RemoteAbort { origin_rank, message }
            }
            CommError::Timeout { rank, seconds, waiting_for } => {
                DOpInfError::Timeout { rank, seconds, message: waiting_for }
            }
            other => DOpInfError::Comm { rank: other.rank(), source: other },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn abort_err(origin: usize, msg: &str) -> anyhow::Error {
        anyhow::Error::from(CommError::RemoteAbort {
            origin_rank: origin,
            message: msg.to_string(),
        })
    }

    #[test]
    fn aggregation_prefers_the_originating_rank() {
        // ranks 0 and 2 observed rank 1's abort; rank 1 is the origin
        let failures = vec![
            (0, abort_err(1, "EIO at chunk 3")),
            (1, abort_err(1, "EIO at chunk 3")),
            (2, abort_err(1, "EIO at chunk 3")),
        ];
        match DOpInfError::from_rank_failures(failures) {
            DOpInfError::RemoteAbort { origin_rank, message } => {
                assert_eq!(origin_rank, 1);
                assert!(message.contains("EIO at chunk 3"));
            }
            other => panic!("expected RemoteAbort, got {other:?}"),
        }
    }

    #[test]
    fn aggregation_recovers_origin_from_siblings_alone() {
        // the origin rank's own result is missing (e.g. it panicked);
        // siblings still carry the origin tag
        let failures = vec![(0, abort_err(3, "died")), (2, abort_err(3, "died"))];
        match DOpInfError::from_rank_failures(failures) {
            DOpInfError::RemoteAbort { origin_rank: 3, .. } => {}
            other => panic!("expected RemoteAbort from rank 3, got {other:?}"),
        }
    }

    #[test]
    fn aggregation_surfaces_timeouts() {
        let failures = vec![(
            0,
            anyhow::Error::from(CommError::Timeout {
                rank: 0,
                seconds: 5.0,
                waiting_for: "reply from the rank 0 hub".to_string(),
            }),
        )];
        match DOpInfError::from_rank_failures(failures) {
            DOpInfError::Timeout { rank: 0, seconds, .. } => assert_eq!(seconds, 5.0),
            other => panic!("expected Timeout, got {other:?}"),
        }
    }

    #[test]
    fn aggregation_falls_back_to_the_first_rank_error() {
        let failures = vec![(2, anyhow::anyhow!("plain local failure"))];
        match DOpInfError::from_rank_failures(failures) {
            DOpInfError::Rank { rank: 2, source } => {
                assert!(format!("{source}").contains("plain local failure"));
            }
            other => panic!("expected Rank, got {other:?}"),
        }
    }

    #[test]
    fn display_is_origin_tagged() {
        let e = DOpInfError::RemoteAbort { origin_rank: 5, message: "boom".into() };
        assert_eq!(e.to_string(), "run aborted by rank 5: boom");
        assert_eq!(e.rank(), Some(5));
    }
}

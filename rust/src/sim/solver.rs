//! Chorin-projection incompressible Navier–Stokes solver on the MAC grid.
//!
//! Explicit tentative-velocity step with the Griebel–Dornseifer–
//! Neunhoeffer γ-blended donor-cell advection scheme (the scheme behind
//! NaSt2D, which reliably produces Kármán vortex streets on modest
//! grids), second-order central diffusion, then a pressure projection
//! with the CG solver from [`super::poisson`]. Boundary conditions match
//! the DFG 2D-3 benchmark the paper uses: parabolic inflow, no-slip
//! walls + obstacle, zero-gradient outflow with pinned pressure.
//!
//! Staggering: `u[j][i]` lives at x-face `(i·dx, (j+½)·dy)` with
//! `i ∈ 0..=nx`; `v[j][i]` at y-face `((i+½)·dx, j·dy)` with
//! `j ∈ 0..=ny`; pressure at cell centers.

use super::grid::Grid;
use super::poisson::PoissonSolver;
use crate::linalg::Matrix;

/// Flow state + scheme parameters for one geometry.
pub struct FlowSolver {
    pub grid: Grid,
    /// kinematic viscosity (DFG: Re = Ū·D/ν)
    pub nu: f64,
    /// mean inflow velocity Ū (profile peak is 1.5·Ū)
    pub u_mean: f64,
    /// donor-cell blend (0 = central, 1 = full upwind)
    pub gamma: f64,
    /// x-face velocities, (nx+1) per row, ny rows
    u: Vec<f64>,
    /// y-face velocities, nx per row, ny+1 rows
    v: Vec<f64>,
    /// cell-centered pressure (warm start across steps)
    p: Vec<f64>,
    /// last Poisson iteration count (diagnostics)
    pub last_poisson_iters: usize,
    pub time: f64,
}

impl FlowSolver {
    pub fn new(grid: Grid, nu: f64, u_mean: f64) -> FlowSolver {
        let (nx, ny) = (grid.nx, grid.ny);
        let mut s = FlowSolver {
            grid,
            nu,
            u_mean,
            gamma: 0.8,
            u: vec![0.0; (nx + 1) * ny],
            v: vec![0.0; nx * (ny + 1)],
            p: vec![0.0; nx * ny],
            last_poisson_iters: 0,
            time: 0.0,
        };
        // impulsive start: inflow profile everywhere (fluid columns)
        for j in 0..ny {
            let prof = s.inflow_profile(j);
            for i in 0..=nx {
                s.u[j * (nx + 1) + i] = prof;
            }
        }
        s.enforce_bcs();
        s
    }

    #[inline]
    fn ui(&self, i: usize, j: usize) -> usize {
        j * (self.grid.nx + 1) + i
    }
    #[inline]
    fn vi(&self, i: usize, j: usize) -> usize {
        j * self.grid.nx + i
    }

    /// DFG parabolic inflow at row j: `4·1.5·Ū·y(H−y)/H²`.
    fn inflow_profile(&self, j: usize) -> f64 {
        let h = self.grid.ly;
        let y = (j as f64 + 0.5) * self.grid.dy;
        4.0 * 1.5 * self.u_mean * y * (h - y) / (h * h)
    }

    /// u with wall ghosts: reflect across no-slip top/bottom walls.
    #[inline]
    fn u_at(&self, i: usize, j: isize) -> f64 {
        let ny = self.grid.ny as isize;
        if j < 0 {
            -self.u[self.ui(i, 0)]
        } else if j >= ny {
            -self.u[self.ui(i, (ny - 1) as usize)]
        } else {
            self.u[self.ui(i, j as usize)]
        }
    }

    /// v with inflow/outflow ghosts in x.
    #[inline]
    fn v_at(&self, i: isize, j: usize) -> f64 {
        let nx = self.grid.nx as isize;
        if i < 0 {
            -self.v[self.vi(0, j)] // zero transverse velocity at inflow
        } else if i >= nx {
            self.v[self.vi((nx - 1) as usize, j)] // zero-gradient outflow
        } else {
            self.v[self.vi(i as usize, j)]
        }
    }

    /// Is the x-face (i, j) adjacent to a solid cell (or inside one)?
    fn u_face_solid(&self, i: usize, j: usize) -> bool {
        let g = &self.grid;
        let left_solid = i > 0 && g.is_solid(i - 1, j);
        let right_solid = i < g.nx && g.is_solid(i.min(g.nx - 1), j);
        left_solid || (i < g.nx && right_solid) || (i == g.nx && g.is_solid(g.nx - 1, j))
    }

    fn v_face_solid(&self, i: usize, j: usize) -> bool {
        let g = &self.grid;
        let below_solid = j > 0 && g.is_solid(i, j - 1);
        let above_solid = j < g.ny && g.is_solid(i, j.min(g.ny - 1));
        below_solid || (j < g.ny && above_solid) || (j == g.ny && g.is_solid(i, g.ny - 1))
    }

    /// Apply all boundary conditions in place.
    fn enforce_bcs(&mut self) {
        let (nx, ny) = (self.grid.nx, self.grid.ny);
        for j in 0..ny {
            // inflow
            let prof = self.inflow_profile(j);
            let k = self.ui(0, j);
            self.u[k] = prof;
            // outflow: zero gradient
            let k_out = self.ui(nx, j);
            let k_in = self.ui(nx - 1, j);
            self.u[k_out] = self.u[k_in];
        }
        for i in 0..nx {
            // impermeable walls
            let kb = self.vi(i, 0);
            self.v[kb] = 0.0;
            let kt = self.vi(i, ny);
            self.v[kt] = 0.0;
        }
        // no-slip on solids: zero every face touching a solid cell
        for j in 0..ny {
            for i in 0..=nx {
                if self.u_face_solid(i, j) {
                    let k = self.ui(i, j);
                    self.u[k] = 0.0;
                }
            }
        }
        for j in 0..=ny {
            for i in 0..nx {
                if self.v_face_solid(i, j) {
                    let k = self.vi(i, j);
                    self.v[k] = 0.0;
                }
            }
        }
    }

    /// Largest stable explicit step (CFL + viscous limits, factor 0.4).
    pub fn stable_dt(&self) -> f64 {
        let umax = self
            .u
            .iter()
            .chain(self.v.iter())
            .fold(0.1f64, |m, &x| m.max(x.abs()));
        let (dx, dy) = (self.grid.dx, self.grid.dy);
        let conv = dx.min(dy) / umax;
        let visc = 0.5 / (self.nu * (1.0 / (dx * dx) + 1.0 / (dy * dy)));
        0.4 * conv.min(visc)
    }

    /// Advance one time step of size `dt`.
    pub fn step(&mut self, dt: f64) {
        let g = &self.grid;
        let (nx, ny, dx, dy) = (g.nx, g.ny, g.dx, g.dy);
        let (nu, gamma) = (self.nu, self.gamma);

        // --- tentative velocities (explicit Euler) ---
        let mut u_star = self.u.clone();
        let mut v_star = self.v.clone();

        for j in 0..ny {
            for i in 1..nx {
                if self.u_face_solid(i, j) {
                    continue;
                }
                let k = self.ui(i, j);
                let jj = j as isize;
                let uc = self.u[k];
                let ue = self.u[self.ui(i + 1, j)];
                let uw = self.u[self.ui(i - 1, j)];
                let un = self.u_at(i, jj + 1);
                let us = self.u_at(i, jj - 1);

                // d(u²)/dx with γ-blended donor cell
                let ur = 0.5 * (uc + ue);
                let ul = 0.5 * (uw + uc);
                let du2dx = (ur * ur - ul * ul) / dx
                    + gamma * (ur.abs() * (uc - ue) * 0.5 - ul.abs() * (uw - uc) * 0.5) / dx;

                // d(uv)/dy: v at the face's top/bottom corners
                let vn = 0.5 * (self.v_at(i as isize - 1, j + 1) + self.v_at(i as isize, j + 1));
                let vs = 0.5 * (self.v_at(i as isize - 1, j) + self.v_at(i as isize, j));
                let duvdy = (vn * 0.5 * (uc + un) - vs * 0.5 * (us + uc)) / dy
                    + gamma * (vn.abs() * (uc - un) * 0.5 - vs.abs() * (us - uc) * 0.5) / dy;

                let lap = (ue - 2.0 * uc + uw) / (dx * dx) + (un - 2.0 * uc + us) / (dy * dy);
                u_star[k] = uc + dt * (nu * lap - du2dx - duvdy);
            }
        }

        for j in 1..ny {
            for i in 0..nx {
                if self.v_face_solid(i, j) {
                    continue;
                }
                let k = self.vi(i, j);
                let ii = i as isize;
                let vc = self.v[k];
                let vn = self.v[self.vi(i, j + 1)];
                let vs = self.v[self.vi(i, j - 1)];
                let ve = self.v_at(ii + 1, j);
                let vw = self.v_at(ii - 1, j);

                // d(v²)/dy
                let vt = 0.5 * (vc + vn);
                let vb = 0.5 * (vs + vc);
                let dv2dy = (vt * vt - vb * vb) / dy
                    + gamma * (vt.abs() * (vc - vn) * 0.5 - vb.abs() * (vs - vc) * 0.5) / dy;

                // d(uv)/dx: u at the face's left/right corners
                let ue = 0.5 * (self.u[self.ui(i + 1, j - 1)] + self.u[self.ui(i + 1, j)]);
                let uw = 0.5 * (self.u[self.ui(i, j - 1)] + self.u[self.ui(i, j)]);
                let duvdx = (ue * 0.5 * (vc + ve) - uw * 0.5 * (vw + vc)) / dx
                    + gamma * (ue.abs() * (vc - ve) * 0.5 - uw.abs() * (vw - vc) * 0.5) / dx;

                let lap = (ve - 2.0 * vc + vw) / (dx * dx) + (vn - 2.0 * vc + vs) / (dy * dy);
                v_star[k] = vc + dt * (nu * lap - dv2dy - duvdx);
            }
        }

        self.u = u_star;
        self.v = v_star;
        self.enforce_bcs();

        // --- pressure projection ---
        let solver = PoissonSolver::new(&self.grid);
        let mut rhs = vec![0.0; nx * ny];
        for j in 0..ny {
            for i in 0..nx {
                if self.grid.is_solid(i, j) {
                    continue;
                }
                let div = (self.u[self.ui(i + 1, j)] - self.u[self.ui(i, j)]) / dx
                    + (self.v[self.vi(i, j + 1)] - self.v[self.vi(i, j)]) / dy;
                rhs[self.grid.idx(i, j)] = -div / dt; // A = -∇², so A p = -div/dt
            }
        }
        self.last_poisson_iters = solver.solve(&rhs, &mut self.p);

        // --- velocity correction ---
        for j in 0..ny {
            for i in 1..nx {
                if self.u_face_solid(i, j)
                    || self.grid.is_solid(i - 1, j)
                    || self.grid.is_solid(i, j)
                {
                    continue;
                }
                let k = self.ui(i, j);
                let gidx = self.grid.idx(i, j);
                self.u[k] -= dt * (self.p[gidx] - self.p[gidx - 1]) / dx;
            }
            // outflow face: Dirichlet ghost p_ghost = -p[nx-1]
            if self.grid.is_fluid(nx - 1, j) {
                let k = self.ui(nx, j);
                let gidx = self.grid.idx(nx - 1, j);
                self.u[k] -= dt * (-2.0 * self.p[gidx]) / dx;
            }
        }
        for j in 1..ny {
            for i in 0..nx {
                if self.v_face_solid(i, j)
                    || self.grid.is_solid(i, j - 1)
                    || self.grid.is_solid(i, j)
                {
                    continue;
                }
                let k = self.vi(i, j);
                let gidx = self.grid.idx(i, j);
                self.v[k] -= dt * (self.p[gidx] - self.p[gidx - nx]) / dy;
            }
        }
        self.enforce_bcs();
        self.time += dt;
    }

    /// Max |∇·u| over fluid cells (projection quality diagnostic).
    pub fn max_divergence(&self) -> f64 {
        let g = &self.grid;
        let mut worst = 0.0f64;
        for j in 0..g.ny {
            for i in 0..g.nx {
                if g.is_solid(i, j) {
                    continue;
                }
                let div = (self.u[self.ui(i + 1, j)] - self.u[self.ui(i, j)]) / g.dx
                    + (self.v[self.vi(i, j + 1)] - self.v[self.vi(i, j)]) / g.dy;
                worst = worst.max(div.abs());
            }
        }
        worst
    }

    /// Cell-centered velocity sample: `(u_x, u_y)` matrices of shape
    /// `(ny, nx)` flattened row-major by j — the snapshot layout of the
    /// training dataset. Solid cells sample as 0.
    pub fn sample_cell_velocities(&self) -> (Vec<f64>, Vec<f64>) {
        let g = &self.grid;
        let mut ux = vec![0.0; g.cells()];
        let mut uy = vec![0.0; g.cells()];
        for j in 0..g.ny {
            for i in 0..g.nx {
                if g.is_solid(i, j) {
                    continue;
                }
                let k = g.idx(i, j);
                ux[k] = 0.5 * (self.u[self.ui(i, j)] + self.u[self.ui(i + 1, j)]);
                uy[k] = 0.5 * (self.v[self.vi(i, j)] + self.v[self.vi(i, j + 1)]);
            }
        }
        (ux, uy)
    }

    /// Snapshot as a 1-column matrix pair (test convenience).
    pub fn snapshot_matrices(&self) -> (Matrix, Matrix) {
        let (ux, uy) = self.sample_cell_velocities();
        let n = ux.len();
        (Matrix::from_vec(n, 1, ux), Matrix::from_vec(n, 1, uy))
    }

    /// Peak velocity magnitude over faces (stability diagnostic).
    pub fn max_speed(&self) -> f64 {
        self.u
            .iter()
            .chain(self.v.iter())
            .fold(0.0f64, |m, &x| m.max(x.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::grid::Geometry;

    fn channel(nx: usize, ny: usize) -> FlowSolver {
        FlowSolver::new(Grid::new(Geometry::Channel, nx, ny, 2.0, 1.0), 0.01, 1.0)
    }

    #[test]
    fn projection_kills_divergence() {
        let mut s = channel(32, 16);
        let dt = s.stable_dt();
        for _ in 0..5 {
            s.step(dt);
        }
        assert!(s.max_divergence() < 1e-4, "div {}", s.max_divergence());
    }

    #[test]
    fn stays_stable_and_bounded() {
        let mut s = channel(24, 12);
        for _ in 0..100 {
            let dt = s.stable_dt();
            s.step(dt);
        }
        let speed = s.max_speed();
        assert!(speed.is_finite());
        assert!(speed < 5.0 * 1.5, "runaway speed {speed}");
    }

    #[test]
    fn channel_converges_to_parabolic_profile() {
        // Poiseuille: steady profile should stay close to the parabolic
        // inflow (it is the exact steady solution of the channel).
        let mut s = channel(32, 16);
        for _ in 0..400 {
            let dt = s.stable_dt();
            s.step(dt);
        }
        let (ux, _) = s.sample_cell_velocities();
        let g = &s.grid;
        let i_mid = g.nx / 2;
        let mut worst = 0.0f64;
        for j in 0..g.ny {
            let want = s.inflow_profile(j);
            let got = ux[g.idx(i_mid, j)];
            worst = worst.max((got - want).abs() / 1.5);
        }
        assert!(worst < 0.08, "profile deviation {worst}");
    }

    #[test]
    fn cylinder_run_is_stable_and_divergence_free() {
        let mut s = FlowSolver::new(Grid::dfg_cylinder(66, 30), 0.001, 1.0);
        for _ in 0..30 {
            let dt = s.stable_dt();
            s.step(dt);
        }
        assert!(s.max_speed().is_finite());
        assert!(s.max_divergence() < 1e-3, "div {}", s.max_divergence());
    }

    #[test]
    fn cylinder_wake_develops_transverse_flow() {
        // flow past the cylinder must generate nonzero v (deflection),
        // the precursor of vortex shedding
        let mut s = FlowSolver::new(Grid::dfg_cylinder(66, 30), 0.001, 1.0);
        for _ in 0..60 {
            let dt = s.stable_dt();
            s.step(dt);
        }
        let (_, uy) = s.sample_cell_velocities();
        let max_v = uy.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
        assert!(max_v > 1e-3, "no transverse flow developed: {max_v}");
    }

    #[test]
    fn solid_cells_sample_zero() {
        let s = FlowSolver::new(Grid::dfg_cylinder(44, 20), 0.001, 1.0);
        let (ux, uy) = s.sample_cell_velocities();
        for j in 0..s.grid.ny {
            for i in 0..s.grid.nx {
                if s.grid.is_solid(i, j) {
                    assert_eq!(ux[s.grid.idx(i, j)], 0.0);
                    assert_eq!(uy[s.grid.idx(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn inflow_profile_is_parabolic() {
        let s = channel(16, 8);
        // peak at mid-height ≈ 1.5·u_mean
        let peak = (0..8).map(|j| s.inflow_profile(j)).fold(0.0f64, f64::max);
        assert!((peak - 1.5).abs() < 0.05, "peak {peak}");
        // symmetric
        assert!((s.inflow_profile(0) - s.inflow_profile(7)).abs() < 1e-12);
    }

    #[test]
    fn stable_dt_positive_and_reasonable() {
        let s = channel(32, 16);
        let dt = s.stable_dt();
        assert!(dt > 0.0 && dt < 1.0);
    }
}

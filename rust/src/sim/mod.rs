//! High-fidelity flow solver substrate.
//!
//! The paper's training data comes from a FEniCS finite-element solve of
//! the 2D incompressible Navier–Stokes equations (DFG 2D-3 cylinder
//! benchmark, Re=100, vortex shedding). FEniCS is not available here, so
//! this module implements the same physics from scratch (DESIGN.md §3):
//!
//! * [`grid`] — uniform MAC staggered grid with solid masks (cylinder /
//!   backward-facing step geometries) and probe-index extraction
//! * [`poisson`] — matrix-free conjugate-gradient pressure solver
//! * [`solver`] — Chorin projection scheme: explicit advection +
//!   diffusion, pressure projection, inflow/outflow/no-slip BCs
//! * [`synth`] — fast analytic traveling-wave datasets for tests and the
//!   quickstart (low-rank by construction)
//! * [`driver`] — time-integration loop producing SNAPD snapshot
//!   datasets (downsampled, like the paper's factor-20 downsampling) and
//!   reference probe trajectories

pub mod driver;
pub mod grid;
pub mod poisson;
pub mod solver;
pub mod synth;

pub use grid::{Geometry, Grid};
pub use solver::FlowSolver;

//! Analytic synthetic datasets: traveling-wave fields with a known low
//! rank and periodic dynamics.
//!
//! Used by the quickstart, unit tests, and the scaling bench so they do
//! not need a long Navier–Stokes run: the fields mimic the structure the
//! ROM pipeline exploits (fast singular-value decay, quasi-periodic
//! temporal dynamics), and the exact rank is known a priori so energy
//! thresholds can be asserted.

use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// Parameters of the synthetic traveling-wave dataset.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    /// spatial DoF per state variable
    pub nx: usize,
    /// number of state variables (the NS example has 2: u_x, u_y)
    pub ns: usize,
    /// number of snapshots
    pub nt: usize,
    /// number of traveling-wave modes (=> exact rank ≤ 2·modes + 1)
    pub modes: usize,
    /// time step between snapshots
    pub dt: f64,
    /// RNG seed for mode shapes/frequencies
    pub seed: u64,
    /// constant offset added per variable (exercises centering)
    pub offset: f64,
}

impl Default for SynthSpec {
    fn default() -> Self {
        SynthSpec { nx: 512, ns: 2, nt: 80, modes: 4, dt: 0.05, seed: 42, offset: 1.0 }
    }
}

/// Precomputed mode table for row-on-demand generation: the streaming
/// ingestion path ([`crate::io::SyntheticBlockReader`]) fills one row
/// at a time, so the synthetic state dimension is never bounded by RAM.
/// [`generate`] is a thin wrapper that fills every row.
pub struct SynthField {
    nx: usize,
    dt: f64,
    offset: f64,
    modes: Vec<Mode>,
}

impl SynthField {
    pub fn new(spec: &SynthSpec) -> SynthField {
        let mut rng = Rng::new(spec.seed);
        let modes: Vec<Mode> = (0..spec.modes)
            .map(|k| Mode {
                amp: 1.0 / (k as f64 + 1.0),
                omega: 0.7 + 0.9 * (k as f64) + 0.2 * rng.uniform(),
                kx: (k + 1) as f64 * std::f64::consts::PI,
                phase_x: rng.range(0.0, std::f64::consts::TAU),
                phase_per_var: (0..spec.ns)
                    .map(|_| rng.range(0.0, std::f64::consts::TAU))
                    .collect(),
            })
            .collect();
        SynthField { nx: spec.nx, dt: spec.dt, offset: spec.offset, modes }
    }

    /// Value of variable `var` at spatial row `row`, snapshot column
    /// `col` of the window starting at `t0_index`.
    pub fn value(&self, var: usize, row: usize, t0_index: usize, col: usize) -> f64 {
        let x = row as f64 / self.nx as f64;
        let t = (t0_index + col) as f64 * self.dt;
        let mut val = self.offset * (var as f64 + 1.0);
        for m in &self.modes {
            val += m.amp
                * (m.kx * x + m.phase_x).sin()
                * (m.omega * t + m.phase_per_var[var]).cos();
        }
        val
    }

    /// Fill one spatial row's full snapshot series (`out.len()`
    /// columns) — bitwise identical to the corresponding [`generate`]
    /// row.
    pub fn fill_row(&self, var: usize, row: usize, t0_index: usize, out: &mut [f64]) {
        for (col, v) in out.iter_mut().enumerate() {
            *v = self.value(var, row, t0_index, col);
        }
    }
}

/// Generate the snapshot matrix for `spec` over snapshots
/// `[t0_index, t0_index + nt)`: shape `(ns·nx, nt)` with the variables
/// stacked like the paper's tutorial (all u_x rows, then all u_y rows).
///
/// Each variable is `offset + Σ_k a_k sin(ω_k t + φ_{k,var}) g_k(x)`
/// with smooth spatial profiles `g_k` — a rank ≤ `2·modes`+constant
/// field whose temporal dynamics are exactly periodic, so an OpInf ROM
/// can predict beyond training.
pub fn generate(spec: &SynthSpec, t0_index: usize) -> Matrix {
    let field = SynthField::new(spec);
    let mut q = Matrix::zeros(spec.ns * spec.nx, spec.nt);
    for var in 0..spec.ns {
        for row in 0..spec.nx {
            field.fill_row(var, row, t0_index, q.row_mut(var * spec.nx + row));
        }
    }
    q
}

struct Mode {
    amp: f64,
    omega: f64,
    kx: f64,
    phase_x: f64,
    phase_per_var: Vec<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{eigh, syrk};

    #[test]
    fn shape_and_determinism() {
        let spec = SynthSpec { nx: 64, ns: 2, nt: 20, ..Default::default() };
        let a = generate(&spec, 0);
        let b = generate(&spec, 0);
        assert_eq!(a.rows(), 128);
        assert_eq!(a.cols(), 20);
        assert_eq!(a, b);
        // different window differs
        let c = generate(&spec, 5);
        assert!(a.max_abs_diff(&c) > 1e-6);
    }

    #[test]
    fn windows_are_consistent() {
        // columns [5..10) of window-0 == columns [0..5) of window-5
        let spec = SynthSpec { nx: 32, nt: 10, ..Default::default() };
        let full = generate(&spec, 0);
        let shifted = generate(&SynthSpec { nt: 5, ..spec.clone() }, 5);
        assert!(full.slice_cols(5, 10).max_abs_diff(&shifted) < 1e-12);
    }

    #[test]
    fn rank_is_bounded_by_modes() {
        let spec = SynthSpec { nx: 128, ns: 2, nt: 60, modes: 3, ..Default::default() };
        let q = generate(&spec, 0);
        // centered rank ≤ 2*modes (constant mode removed by centering)
        let mut centered = q.clone();
        for i in 0..centered.rows() {
            let mean: f64 = centered.row(i).iter().sum::<f64>() / centered.cols() as f64;
            for j in 0..centered.cols() {
                centered[(i, j)] -= mean;
            }
        }
        let eig = eigh(&syrk(&centered));
        let mut vals: Vec<f64> = eig.values.iter().rev().copied().collect();
        let total: f64 = vals.iter().sum();
        vals.truncate(2 * spec.modes);
        let energy: f64 = vals.iter().sum::<f64>() / total;
        assert!(energy > 0.999_999, "energy in 2·modes = {energy}");
    }

    #[test]
    fn offset_shifts_means_per_variable() {
        let spec = SynthSpec { nx: 64, ns: 2, nt: 40, offset: 2.0, ..Default::default() };
        let q = generate(&spec, 0);
        let mean_var0: f64 =
            (0..64).map(|i| q.row(i).iter().sum::<f64>() / 40.0).sum::<f64>() / 64.0;
        let mean_var1: f64 =
            (64..128).map(|i| q.row(i).iter().sum::<f64>() / 40.0).sum::<f64>() / 64.0;
        // finite window => temporal mode means don't vanish exactly;
        // modes have amplitude ≤ 1 so the offsets still dominate
        assert!((mean_var0 - 2.0).abs() < 0.75, "{mean_var0}");
        assert!((mean_var1 - 4.0).abs() < 0.75, "{mean_var1}");
    }
}

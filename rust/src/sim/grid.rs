//! Uniform MAC grid with solid-cell masks.
//!
//! Cell (i, j) spans `[i·dx, (i+1)·dx] × [j·dy, (j+1)·dy]`, i (column)
//! along the channel, j (row) across it. Pressure and sampled velocities
//! live at cell centers; face velocities are staggered (see
//! `sim::solver`). Solid cells (cylinder, step) are masked out of the
//! dynamics and the Poisson solve.

/// Benchmark geometry selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Geometry {
    /// DFG 2D-3 analogue: channel with a circular cylinder.
    Cylinder,
    /// Backward-facing step (the abstract's "flow over a step").
    Step,
    /// Plain channel (no obstacle) — used by solver unit tests.
    Channel,
}

/// Uniform Cartesian grid with a solid mask.
#[derive(Clone, Debug)]
pub struct Grid {
    pub nx: usize,
    pub ny: usize,
    pub lx: f64,
    pub ly: f64,
    pub dx: f64,
    pub dy: f64,
    /// `true` = solid cell (excluded from fluid dynamics), len nx*ny
    solid: Vec<bool>,
    pub geometry: Geometry,
}

impl Grid {
    /// Channel `[0,lx]×[0,ly]` with geometry-specific solids.
    pub fn new(geometry: Geometry, nx: usize, ny: usize, lx: f64, ly: f64) -> Grid {
        assert!(nx >= 4 && ny >= 4, "grid too small");
        let dx = lx / nx as f64;
        let dy = ly / ny as f64;
        let mut g = Grid { nx, ny, lx, ly, dx, dy, solid: vec![false; nx * ny], geometry };
        match geometry {
            Geometry::Cylinder => {
                // DFG 2D-3 proportions: cylinder of diameter ly/4.1*1.0,
                // centered at (0.2/2.2·lx, 0.2/0.41·ly) in DFG units.
                let cx = lx * (0.2 / 2.2);
                let cy = ly * (0.2 / 0.41);
                let radius = ly * (0.05 / 0.41);
                g.add_cylinder(cx, cy, radius);
            }
            Geometry::Step => {
                // backward-facing step: lower-left quarter blocked up to
                // x = ly (step length equal to channel height)
                let step_x = ly.min(lx * 0.25);
                let step_y = ly * 0.5;
                g.add_box(0.0, 0.0, step_x, step_y);
            }
            Geometry::Channel => {}
        }
        g
    }

    /// DFG-proportioned cylinder default used by the paper experiments.
    pub fn dfg_cylinder(nx: usize, ny: usize) -> Grid {
        Grid::new(Geometry::Cylinder, nx, ny, 2.2, 0.41)
    }

    /// Mark cells inside a circle as solid.
    pub fn add_cylinder(&mut self, cx: f64, cy: f64, radius: f64) {
        for j in 0..self.ny {
            for i in 0..self.nx {
                let (x, y) = self.cell_center(i, j);
                if (x - cx).powi(2) + (y - cy).powi(2) <= radius * radius {
                    let k = self.idx(i, j);
                    self.solid[k] = true;
                }
            }
        }
    }

    /// Mark cells inside an axis-aligned box as solid.
    pub fn add_box(&mut self, x0: f64, y0: f64, x1: f64, y1: f64) {
        for j in 0..self.ny {
            for i in 0..self.nx {
                let (x, y) = self.cell_center(i, j);
                if x >= x0 && x <= x1 && y >= y0 && y <= y1 {
                    let k = self.idx(i, j);
                    self.solid[k] = true;
                }
            }
        }
    }

    /// Flat index of cell (i, j); row-major by j.
    #[inline]
    pub fn idx(&self, i: usize, j: usize) -> usize {
        debug_assert!(i < self.nx && j < self.ny);
        j * self.nx + i
    }

    #[inline]
    pub fn cells(&self) -> usize {
        self.nx * self.ny
    }

    #[inline]
    pub fn is_solid(&self, i: usize, j: usize) -> bool {
        self.solid[self.idx(i, j)]
    }

    #[inline]
    pub fn is_fluid(&self, i: usize, j: usize) -> bool {
        !self.is_solid(i, j)
    }

    pub fn solid_count(&self) -> usize {
        self.solid.iter().filter(|&&s| s).count()
    }

    /// Physical center of cell (i, j).
    pub fn cell_center(&self, i: usize, j: usize) -> (f64, f64) {
        ((i as f64 + 0.5) * self.dx, (j as f64 + 0.5) * self.dy)
    }

    /// Nearest *fluid* cell index to physical point (x, y) — the probe
    /// extraction the paper ships as a repository script.
    pub fn probe_index(&self, x: f64, y: f64) -> usize {
        let ic = ((x / self.dx - 0.5).round().clamp(0.0, (self.nx - 1) as f64)) as usize;
        let jc = ((y / self.dy - 0.5).round().clamp(0.0, (self.ny - 1) as f64)) as usize;
        if self.is_fluid(ic, jc) {
            return self.idx(ic, jc);
        }
        // spiral out to the nearest fluid cell
        for radius in 1..self.nx.max(self.ny) {
            let mut best: Option<(f64, usize)> = None;
            let i0 = ic.saturating_sub(radius);
            let i1 = (ic + radius).min(self.nx - 1);
            let j0 = jc.saturating_sub(radius);
            let j1 = (jc + radius).min(self.ny - 1);
            for j in j0..=j1 {
                for i in i0..=i1 {
                    if self.is_fluid(i, j) {
                        let (cx, cy) = self.cell_center(i, j);
                        let d2 = (cx - x).powi(2) + (cy - y).powi(2);
                        if best.map_or(true, |(bd, _)| d2 < bd) {
                            best = Some((d2, self.idx(i, j)));
                        }
                    }
                }
            }
            if let Some((_, idx)) = best {
                return idx;
            }
        }
        panic!("no fluid cell in grid");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_channel_has_no_solids() {
        let g = Grid::new(Geometry::Channel, 16, 8, 2.0, 1.0);
        assert_eq!(g.solid_count(), 0);
        assert_eq!(g.cells(), 128);
        assert!((g.dx - 0.125).abs() < 1e-15);
    }

    #[test]
    fn cylinder_mask_is_plausible() {
        let g = Grid::dfg_cylinder(88, 41);
        let area = g.solid_count() as f64 * g.dx * g.dy;
        let expect = std::f64::consts::PI * 0.05 * 0.05;
        assert!(g.solid_count() > 0);
        assert!((area - expect).abs() / expect < 0.5, "area {area} vs {expect}");
        // cylinder is in the left part of the channel, off the walls
        assert!(g.is_fluid(0, 0));
        assert!(g.is_fluid(g.nx - 1, g.ny - 1));
    }

    #[test]
    fn step_blocks_lower_left() {
        let g = Grid::new(Geometry::Step, 64, 16, 4.0, 1.0);
        assert!(g.is_solid(0, 0));
        assert!(g.is_fluid(0, g.ny - 1));
        assert!(g.is_fluid(g.nx - 1, 0));
    }

    #[test]
    fn idx_roundtrip() {
        let g = Grid::new(Geometry::Channel, 10, 5, 1.0, 1.0);
        assert_eq!(g.idx(0, 0), 0);
        assert_eq!(g.idx(9, 4), 49);
        assert_eq!(g.idx(3, 2), 23);
    }

    #[test]
    fn probe_index_nearest_cell() {
        let g = Grid::new(Geometry::Channel, 10, 10, 1.0, 1.0);
        // point exactly at center of cell (2,7)
        let (x, y) = g.cell_center(2, 7);
        assert_eq!(g.probe_index(x, y), g.idx(2, 7));
        // clamped outside the domain
        assert_eq!(g.probe_index(-5.0, -5.0), g.idx(0, 0));
        assert_eq!(g.probe_index(9.0, 9.0), g.idx(9, 9));
    }

    #[test]
    fn probe_index_skips_solid() {
        let mut g = Grid::new(Geometry::Channel, 20, 20, 1.0, 1.0);
        g.add_cylinder(0.5, 0.5, 0.2);
        let idx = g.probe_index(0.5, 0.5);
        let (i, j) = (idx % 20, idx / 20);
        assert!(g.is_fluid(i, j));
    }

    #[test]
    fn paper_probe_fractions_map_into_grid() {
        let g = Grid::dfg_cylinder(88, 41);
        for (fx, fy) in crate::io::probes::ProbeSet::paper_fractions() {
            let idx = g.probe_index(fx * g.lx, fy * g.ly);
            assert!(idx < g.cells());
        }
    }
}

//! Simulation driver: time-integrate a flow and emit a SNAPD training
//! dataset (paper Sec. II.B).
//!
//! Mirrors the paper's data pipeline: integrate the high-fidelity model
//! over `[0, t_end]`, start sampling after the transient at `t_sample`,
//! sample every `sample_every` seconds (the paper downsamples by 20×),
//! and store the two velocity variables as `(cells, n_samples)`
//! datasets. Probe rows for the paper's three probe locations are
//! recorded in the metadata.

use std::path::Path;

use anyhow::Result;

use super::grid::{Geometry, Grid};
use super::solver::FlowSolver;
use crate::io::probes::ProbeSet;
use crate::io::snapd::SnapWriter;
use crate::linalg::Matrix;
use crate::util::json::Json;

/// Configuration of one data-generation run.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub geometry: Geometry,
    pub nx: usize,
    pub ny: usize,
    /// kinematic viscosity; DFG 2D-3 uses Re = Ū·D/ν = 100
    pub nu: f64,
    pub u_mean: f64,
    /// start sampling here (after the shedding transient)
    pub t_sample: f64,
    /// end of the simulated horizon
    pub t_end: f64,
    /// seconds between stored snapshots (downsampling)
    pub sample_every: f64,
    /// fixed time step; `None` = adaptive `stable_dt()` each step
    pub dt: Option<f64>,
}

impl SimConfig {
    /// The cylinder workload at a given resolution, DFG proportions:
    /// horizon [0, t_end] with sampling from `t_sample`.
    pub fn cylinder(nx: usize, ny: usize) -> SimConfig {
        SimConfig {
            geometry: Geometry::Cylinder,
            nx,
            ny,
            nu: 0.001,
            u_mean: 1.0,
            t_sample: 4.0,
            t_end: 10.0,
            sample_every: 0.005,
            dt: None,
        }
    }

    /// Backward-facing step workload.
    pub fn step(nx: usize, ny: usize) -> SimConfig {
        SimConfig {
            geometry: Geometry::Step,
            nx,
            ny,
            nu: 0.002,
            u_mean: 1.0,
            t_sample: 2.0,
            t_end: 8.0,
            sample_every: 0.01,
            dt: None,
        }
    }
}

/// Summary of a generated dataset.
#[derive(Clone, Debug)]
pub struct DatasetInfo {
    /// spatial DoF per variable (= grid cells)
    pub cells: usize,
    /// snapshots stored
    pub n_samples: usize,
    /// solver steps taken
    pub steps: usize,
    /// sample times (seconds)
    pub times: Vec<f64>,
    /// paper probe rows within one variable
    pub probe_rows: Vec<usize>,
}

/// Run the simulation and write `out_path` (SNAPD).
///
/// Dataset layout: variables `u_x`, `u_y`, each `(cells, n_samples)`;
/// metadata records grid shape, domain size, sample times, probe rows,
/// and the config. Progress lines go to stderr every simulated second.
pub fn run_to_dataset<P: AsRef<Path>>(cfg: &SimConfig, out_path: P) -> Result<DatasetInfo> {
    let grid = Grid::new(cfg.geometry, cfg.nx, cfg.ny, domain(cfg).0, domain(cfg).1);
    let probe_rows: Vec<usize> = ProbeSet::paper_fractions()
        .iter()
        .map(|(fx, fy)| grid.probe_index(fx * grid.lx, fy * grid.ly))
        .collect();
    let cells = grid.cells();
    let mut solver = FlowSolver::new(grid, cfg.nu, cfg.u_mean);

    let mut ux_cols: Vec<Vec<f64>> = Vec::new();
    let mut uy_cols: Vec<Vec<f64>> = Vec::new();
    let mut times: Vec<f64> = Vec::new();
    let mut next_sample = cfg.t_sample;
    let mut steps = 0usize;
    let mut last_report = 0.0f64;

    // half-open sampling [t_sample, t_end): (t_end - t_sample)/sample_every
    // snapshots exactly — the paper's horizon [4, 10) at 0.005 s = 1200
    while solver.time < cfg.t_end - 1e-12 && next_sample < cfg.t_end - 1e-9 {
        let dt = cfg.dt.unwrap_or_else(|| solver.stable_dt());
        // do not step over a sample instant
        let dt = dt.min(next_sample - solver.time).max(1e-9);
        solver.step(dt);
        steps += 1;
        if solver.time >= next_sample - 1e-9 {
            let (ux, uy) = solver.sample_cell_velocities();
            ux_cols.push(ux);
            uy_cols.push(uy);
            times.push(solver.time);
            next_sample += cfg.sample_every;
        }
        if solver.time - last_report >= 1.0 {
            last_report = solver.time;
            eprintln!(
                "  sim t={:.2}/{:.2}s steps={} samples={} cg_iters={}",
                solver.time,
                cfg.t_end,
                steps,
                times.len(),
                solver.last_poisson_iters
            );
        }
        anyhow::ensure!(
            solver.max_speed().is_finite(),
            "solver diverged at t={}",
            solver.time
        );
    }

    let n_samples = times.len();
    let meta = Json::obj(vec![
        ("geometry", Json::Str(format!("{:?}", cfg.geometry))),
        ("nx", Json::Num(cfg.nx as f64)),
        ("ny", Json::Num(cfg.ny as f64)),
        ("lx", Json::Num(domain(cfg).0)),
        ("ly", Json::Num(domain(cfg).1)),
        ("nu", Json::Num(cfg.nu)),
        ("u_mean", Json::Num(cfg.u_mean)),
        ("t_sample", Json::Num(cfg.t_sample)),
        ("t_end", Json::Num(cfg.t_end)),
        ("sample_every", Json::Num(cfg.sample_every)),
        ("times", Json::Arr(times.iter().map(|&t| Json::Num(t)).collect())),
        (
            "probe_rows",
            Json::Arr(probe_rows.iter().map(|&r| Json::Num(r as f64)).collect()),
        ),
    ]);

    let mut writer = SnapWriter::create(
        &out_path,
        &[("u_x", cells, n_samples), ("u_y", cells, n_samples)],
        meta,
    )?;
    write_columns_chunked(&mut writer, "u_x", cells, &ux_cols)?;
    drop(ux_cols);
    write_columns_chunked(&mut writer, "u_y", cells, &uy_cols)?;
    drop(uy_cols);
    writer.finish()?;

    Ok(DatasetInfo { cells, n_samples, steps, times, probe_rows })
}

/// Rows per streamed write chunk: 2048 rows × nt doubles keeps the
/// transpose buffer in the low MB range at any sampling length.
const WRITE_CHUNK_ROWS: usize = 2048;

/// Stream the sampled columns into the writer as row chunks, so the
/// full `(cells, n_samples)` field matrix is never materialized — the
/// write-side counterpart of the chunked [`crate::io::BlockReader`]
/// ingestion path.
fn write_columns_chunked(
    w: &mut SnapWriter,
    name: &str,
    cells: usize,
    cols: &[Vec<f64>],
) -> Result<()> {
    let nt = cols.len();
    if cells == 0 {
        return w.write_rows(name, &Matrix::zeros(0, nt));
    }
    let mut start = 0;
    while start < cells {
        let end = (start + WRITE_CHUNK_ROWS).min(cells);
        let mut chunk = Matrix::zeros(end - start, nt);
        for (t, col) in cols.iter().enumerate() {
            debug_assert_eq!(col.len(), cells);
            for row in start..end {
                chunk[(row - start, t)] = col[row];
            }
        }
        w.write_rows(name, &chunk)?;
        start = end;
    }
    Ok(())
}

fn domain(cfg: &SimConfig) -> (f64, f64) {
    match cfg.geometry {
        Geometry::Cylinder => (2.2, 0.41),
        Geometry::Step => (4.0, 1.0),
        Geometry::Channel => (2.0, 1.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::snapd::SnapReader;

    #[test]
    fn tiny_run_produces_dataset() {
        let cfg = SimConfig {
            geometry: Geometry::Channel,
            nx: 16,
            ny: 8,
            nu: 0.01,
            u_mean: 1.0,
            t_sample: 0.0,
            t_end: 0.2,
            sample_every: 0.05,
            dt: None,
        };
        let dir = std::env::temp_dir().join("dopinf_driver_test");
        let path = dir.join("tiny.snapd");
        let info = run_to_dataset(&cfg, &path).unwrap();
        assert_eq!(info.cells, 128);
        assert!(info.n_samples >= 4, "samples {}", info.n_samples);
        assert_eq!(info.probe_rows.len(), 3);

        let r = SnapReader::open(&path).unwrap();
        let ux = r.read_all("u_x").unwrap();
        assert_eq!(ux.rows(), 128);
        assert_eq!(ux.cols(), info.n_samples);
        // channel flow: u_x should be nonzero, bounded
        assert!(ux.fro_norm() > 0.1);
        assert!(ux.data().iter().all(|v| v.is_finite()));
        // meta roundtrip
        assert_eq!(r.meta().get("nx").unwrap().as_usize().unwrap(), 16);
        assert_eq!(
            r.meta().get("probe_rows").unwrap().as_arr().unwrap().len(),
            3
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sample_times_are_even() {
        let cfg = SimConfig {
            geometry: Geometry::Channel,
            nx: 12,
            ny: 6,
            nu: 0.02,
            u_mean: 1.0,
            t_sample: 0.1,
            t_end: 0.35,
            sample_every: 0.05,
            dt: None,
        };
        let dir = std::env::temp_dir().join("dopinf_driver_test2");
        let info = run_to_dataset(&cfg, dir.join("even.snapd")).unwrap();
        for (k, t) in info.times.iter().enumerate() {
            let want = 0.1 + k as f64 * 0.05;
            assert!((t - want).abs() < 1e-6, "sample {k} at {t}, want {want}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

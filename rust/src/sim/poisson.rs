//! Matrix-free conjugate-gradient pressure Poisson solver.
//!
//! The projection step needs `∇²p = rhs` on the fluid cells with Neumann
//! conditions at walls/solids/inflow (zero normal pressure gradient) and
//! Dirichlet `p = 0` at the outflow column — which pins the pressure
//! level and makes the (negated) operator symmetric positive definite,
//! so plain CG with Jacobi preconditioning converges. This mirrors the
//! paper's use of preconditioned Krylov solvers (BiCGstab/CG) in the
//! FEniCS reference implementation.

use super::grid::Grid;

/// Pressure-Poisson operator bound to a grid.
pub struct PoissonSolver<'g> {
    grid: &'g Grid,
    /// 1/dx², 1/dy²
    ax: f64,
    ay: f64,
    /// diagonal of the operator (for the Jacobi preconditioner)
    diag: Vec<f64>,
    pub tol: f64,
    pub max_iters: usize,
}

impl<'g> PoissonSolver<'g> {
    pub fn new(grid: &'g Grid) -> PoissonSolver<'g> {
        let ax = 1.0 / (grid.dx * grid.dx);
        let ay = 1.0 / (grid.dy * grid.dy);
        let mut solver =
            PoissonSolver { grid, ax, ay, diag: vec![1.0; grid.cells()], tol: 1e-8, max_iters: 2000 };
        solver.diag = solver.compute_diag();
        solver
    }

    /// Face coefficient between cell (i,j) and its neighbor: 0 across
    /// walls/solids (Neumann), ax/ay across fluid faces. The outflow
    /// boundary (i = nx-1 east face) uses a Dirichlet ghost (p_ghost =
    /// -p), contributing 2·ax to the diagonal.
    fn compute_diag(&self) -> Vec<f64> {
        let g = self.grid;
        let mut diag = vec![1.0; g.cells()];
        for j in 0..g.ny {
            for i in 0..g.nx {
                if g.is_solid(i, j) {
                    continue;
                }
                let mut d = 0.0;
                // west
                if i > 0 && g.is_fluid(i - 1, j) {
                    d += self.ax;
                }
                // east
                if i + 1 < g.nx {
                    if g.is_fluid(i + 1, j) {
                        d += self.ax;
                    }
                } else {
                    d += 2.0 * self.ax; // Dirichlet outflow ghost
                }
                // south
                if j > 0 && g.is_fluid(i, j - 1) {
                    d += self.ay;
                }
                // north
                if j + 1 < g.ny && g.is_fluid(i, j + 1) {
                    d += self.ay;
                }
                diag[g.idx(i, j)] = d.max(self.ax.min(self.ay)); // guard isolated cells
            }
        }
        diag
    }

    /// `out = A p` where `A = -∇²` with the boundary closure above.
    /// Solid cells are identity rows (p stays 0 there).
    pub fn apply(&self, p: &[f64], out: &mut [f64]) {
        let g = self.grid;
        assert_eq!(p.len(), g.cells());
        for j in 0..g.ny {
            for i in 0..g.nx {
                let k = g.idx(i, j);
                if g.is_solid(i, j) {
                    out[k] = p[k];
                    continue;
                }
                let mut acc = self.diag[k] * p[k];
                if i > 0 && g.is_fluid(i - 1, j) {
                    acc -= self.ax * p[k - 1];
                }
                if i + 1 < g.nx && g.is_fluid(i + 1, j) {
                    acc -= self.ax * p[k + 1];
                }
                if j > 0 && g.is_fluid(i, j - 1) {
                    acc -= self.ay * p[k - g.nx];
                }
                if j + 1 < g.ny && g.is_fluid(i, j + 1) {
                    acc -= self.ay * p[k + g.nx];
                }
                out[k] = acc;
            }
        }
    }

    /// Solve `-∇²p = rhs` by Jacobi-preconditioned CG. Returns the
    /// iteration count. `p` is the initial guess (warm-start with the
    /// previous step's pressure) and holds the solution on exit.
    pub fn solve(&self, rhs: &[f64], p: &mut [f64]) -> usize {
        let n = self.grid.cells();
        assert_eq!(rhs.len(), n);
        assert_eq!(p.len(), n);

        let mut r = vec![0.0; n];
        let mut z = vec![0.0; n];
        let mut q = vec![0.0; n];
        self.apply(p, &mut q);
        for k in 0..n {
            r[k] = rhs[k] - q[k];
        }
        let rhs_norm = rhs.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-300);
        for k in 0..n {
            z[k] = r[k] / self.diag[k];
        }
        let mut d = z.clone();
        let mut rz: f64 = r.iter().zip(&z).map(|(a, b)| a * b).sum();

        for iter in 0..self.max_iters {
            let rnorm = r.iter().map(|x| x * x).sum::<f64>().sqrt();
            if rnorm <= self.tol * rhs_norm {
                return iter;
            }
            self.apply(&d, &mut q);
            let dq: f64 = d.iter().zip(&q).map(|(a, b)| a * b).sum();
            if dq.abs() < 1e-300 {
                return iter;
            }
            let alpha = rz / dq;
            for k in 0..n {
                p[k] += alpha * d[k];
                r[k] -= alpha * q[k];
            }
            for k in 0..n {
                z[k] = r[k] / self.diag[k];
            }
            let rz_new: f64 = r.iter().zip(&z).map(|(a, b)| a * b).sum();
            let beta = rz_new / rz;
            rz = rz_new;
            for k in 0..n {
                d[k] = z[k] + beta * d[k];
            }
        }
        self.max_iters
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::grid::Geometry;

    #[test]
    fn operator_is_symmetric() {
        let g = Grid::new(Geometry::Cylinder, 22, 10, 2.2, 0.41);
        let s = PoissonSolver::new(&g);
        let n = g.cells();
        // <Ax, y> == <x, Ay> for random x, y
        let mut rng = crate::util::rng::Rng::new(3);
        let x = rng.normal_vec(n);
        let y = rng.normal_vec(n);
        let mut ax = vec![0.0; n];
        let mut ay = vec![0.0; n];
        s.apply(&x, &mut ax);
        s.apply(&y, &mut ay);
        let axy: f64 = ax.iter().zip(&y).map(|(a, b)| a * b).sum();
        let xay: f64 = x.iter().zip(&ay).map(|(a, b)| a * b).sum();
        assert!((axy - xay).abs() < 1e-8 * axy.abs().max(1.0));
    }

    #[test]
    fn solves_manufactured_problem() {
        // A p* = rhs for a random p*; CG must recover p*
        let g = Grid::new(Geometry::Channel, 24, 12, 2.0, 1.0);
        let s = PoissonSolver::new(&g);
        let n = g.cells();
        let mut rng = crate::util::rng::Rng::new(5);
        let p_star = rng.normal_vec(n);
        let mut rhs = vec![0.0; n];
        s.apply(&p_star, &mut rhs);
        let mut p = vec![0.0; n];
        let iters = s.solve(&rhs, &mut p);
        assert!(iters < s.max_iters, "CG did not converge");
        let err = p
            .iter()
            .zip(&p_star)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        assert!(err < 1e-5, "err {err}");
    }

    #[test]
    fn warm_start_converges_faster() {
        let g = Grid::new(Geometry::Cylinder, 44, 20, 2.2, 0.41);
        let s = PoissonSolver::new(&g);
        let n = g.cells();
        let mut rng = crate::util::rng::Rng::new(6);
        let target = rng.normal_vec(n);
        let mut rhs = vec![0.0; n];
        s.apply(&target, &mut rhs);
        let mut cold = vec![0.0; n];
        let iters_cold = s.solve(&rhs, &mut cold);
        // warm start from the solution: ~0 iterations
        let mut warm = cold.clone();
        let iters_warm = s.solve(&rhs, &mut warm);
        assert!(iters_warm <= iters_cold);
        assert!(iters_warm <= 1);
    }

    #[test]
    fn solid_rows_stay_identity() {
        let g = Grid::new(Geometry::Cylinder, 44, 20, 2.2, 0.41);
        let s = PoissonSolver::new(&g);
        let n = g.cells();
        let rhs = vec![0.0; n];
        let mut p = vec![0.0; n];
        s.solve(&rhs, &mut p);
        for j in 0..g.ny {
            for i in 0..g.nx {
                if g.is_solid(i, j) {
                    assert_eq!(p[g.idx(i, j)], 0.0);
                }
            }
        }
    }
}

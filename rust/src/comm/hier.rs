//! Hierarchical two-level transport: thread boards within a node,
//! a socket tree between per-node leader ranks.
//!
//! [`run_with_clocks_timeout`] spawns `p` rank threads grouped into
//! `nodes` contiguous node groups (node sizes differ by at most one).
//! Each node owns a poisonable rendezvous [`Board`] plus contribution /
//! reply slots; the first rank of each node is the node *leader* and
//! additionally holds TCP streams to its neighbours in a binary tree
//! over the node ids (`parent(n) = (n-1)/2`, children `2n+1`/`2n+2`,
//! node 0 — and therefore global rank 0 — at the root). The tree
//! replaces the flat socket transport's rank-0 star: no leader ever
//! talks to more than three peers, so the leader exchange scales with
//! `log2(nodes)` hops instead of a single hub fanning out to `p - 1`
//! streams.
//!
//! Every collective is the same three-phase decomposition:
//!
//! 1. **local fold** — all node ranks post `(header, payload, clock)`
//!    to their node's slots and pass the first board rendezvous;
//! 2. **leader exchange** — each leader bundles its node's *raw,
//!    rank-tagged* contributions with its children's bundles and ships
//!    them up the tree; the root assembles every rank's part **in
//!    global rank order** and computes all replies with the shared
//!    [`hub_replies`] kernel, then per-rank replies travel back down;
//! 3. **local broadcast** — leaders drop the replies into the node
//!    reply slots and a second rendezvous releases every rank.
//!
//! Bitwise identity with the flat transports is by construction, not by
//! accident: partial per-node reductions would re-associate the
//! floating-point fold, so the tree forwards *unreduced* parts and the
//! root folds exactly once, in rank order, through the same
//! [`fold`](super::communicator::fold) kernels every other transport
//! uses. The integration property sweeps assert this across
//! p × nodes shapes.
//!
//! Failure semantics:
//!
//! * [`Communicator::abort`] installs a **group-wide poison**
//!   (first abort wins) and poisons every node board, so ranks parked
//!   at either rendezvous wake immediately; leaders parked on tree
//!   sockets poll the poison between short read slices and also
//!   receive best-effort abort frames, so the whole group observes
//!   [`CommError::RemoteAbort`] promptly rather than in rank order.
//! * A leader failure (timeout, mismatched collective, wire error)
//!   aborts the group the same way — one rank's failure is every
//!   rank's typed error, never a hang.
//! * An optional deadline bounds both the board waits and every tree
//!   read/write; a peer that never arrives surfaces as
//!   [`CommError::Timeout`] on the waiting ranks.
//! * A panic in rank code poisons the group before propagating with
//!   its original payload (same contract as the thread transport).
//!
//! Virtual time: the root computes `max_entry` over every rank's clock
//! and ships it with the replies; all ranks then advance to
//! `max_entry + cost`, where `cost` comes from the [`TwoLevelModel`]
//! (intra α–β for the node hops, inter α–β for the leader tree). Each
//! rank closes an `"intra"`-tagged tracer comm record per collective;
//! leaders additionally record an `"inter"` hop when more than one
//! node exists, so traces show where the wire time went.

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::clock::{Category, Clock};
use super::communicator::{Communicator, Op};
use super::costmodel::TwoLevelModel;
use super::error::{CommError, CommResult};
use super::socket::{
    hub_replies, io_error, op_to_byte, push_comm_error, read_comm_error, OpCode, FRAME_ABORT,
    FRAME_COLLECTIVE,
};
use super::thread::Board;
use crate::obs::{CommStart, Tracer};
use crate::util::codec;
use crate::util::panic::panic_text;

/// Poll slice for leader tree sockets: reads and writes block at most
/// this long before re-checking the group poison and the deadline.
const POLL_SLICE: Duration = Duration::from_millis(25);

/// What one rank entered a collective with; every contribution carries
/// it so mismatched calls surface as [`CommError::ContractViolation`]
/// instead of corrupt folds.
#[derive(Clone, Copy, PartialEq, Eq)]
struct Header {
    code: OpCode,
    op: u8,
    root: usize,
}

/// One rank's posting for the collective in flight.
struct Entry {
    header: Header,
    provided: bool,
    time: f64,
    payload: Vec<f64>,
}

/// A rank-tagged raw contribution travelling up the leader tree.
struct Contribution {
    rank: usize,
    provided: bool,
    time: f64,
    payload: Vec<f64>,
}

/// A rank's reply parts travelling back down the leader tree.
struct ReplyEntry {
    rank: usize,
    parts: Vec<Vec<f64>>,
}

struct NodeShared {
    /// global rank of this node's leader (local index 0)
    first: usize,
    slots: Vec<Mutex<Option<Entry>>>,
    replies: Vec<Mutex<Option<(f64, Vec<Vec<f64>>)>>>,
    board: Board,
}

struct GroupShared {
    size: usize,
    nodes: Vec<NodeShared>,
    /// group-wide first-wins abort; leaders poll it between socket
    /// slices, boards are poisoned alongside it
    poison: Mutex<Option<CommError>>,
    model: TwoLevelModel,
    timeout: Option<Duration>,
    /// ranks-per-node figure used by the cost model (the largest node)
    rpn: usize,
}

fn group_poisoned(shared: &GroupShared) -> Option<CommError> {
    shared.poison.lock().unwrap().clone()
}

/// Install `err` as the group abort (first wins), poison every node
/// board, and return the canonical error.
fn group_abort(shared: &GroupShared, err: CommError) -> CommError {
    let canonical = shared.poison.lock().unwrap().get_or_insert(err).clone();
    for node in &shared.nodes {
        node.board.poison(canonical.clone());
    }
    canonical
}

struct ChildLink {
    node: usize,
    stream: TcpStream,
    /// global ranks in this child's subtree, recorded during the up
    /// phase of the collective in flight (the reply routing table)
    ranks: Vec<usize>,
}

/// The tree streams a node leader holds (`parent` is `None` at the
/// root).
struct LeaderLink {
    parent: Option<TcpStream>,
    children: Vec<ChildLink>,
}

// ------------------------------------------------------- polled stream I/O

/// `Read`/`Write` over a tree stream that wakes every [`POLL_SLICE`]
/// to check the group poison and the collective deadline, so a leader
/// parked on the wire observes an abort promptly instead of at its
/// full timeout. The stream's OS read/write timeouts are set to the
/// poll slice at creation ([`loopback_pair`]).
struct Polled<'a> {
    stream: &'a TcpStream,
    shared: &'a GroupShared,
    rank: usize,
    deadline: Option<Instant>,
    waiting_for: &'static str,
    /// typed failure behind the last `io::Error` this wrapper returned
    failure: Option<CommError>,
}

impl<'a> Polled<'a> {
    fn new(
        stream: &'a TcpStream,
        shared: &'a GroupShared,
        rank: usize,
        deadline: Option<Instant>,
        waiting_for: &'static str,
    ) -> Polled<'a> {
        Polled { stream, shared, rank, deadline, waiting_for, failure: None }
    }

    /// Between slices: a group poison or an elapsed deadline turns
    /// into an `io::Error` whose typed cause is stashed in `failure`.
    fn interrupted(&mut self) -> Option<io::Error> {
        if let Some(e) = group_poisoned(self.shared) {
            self.failure = Some(e);
            return Some(io::ErrorKind::ConnectionAborted.into());
        }
        if let Some(d) = self.deadline {
            if Instant::now() >= d {
                self.failure = Some(CommError::Timeout {
                    rank: self.rank,
                    seconds: self.shared.timeout.map_or(0.0, |t| t.as_secs_f64()),
                    waiting_for: self.waiting_for.to_string(),
                });
                return Some(io::ErrorKind::TimedOut.into());
            }
        }
        None
    }

    /// Map an `io::Error` out of this wrapper back to its typed cause.
    fn fail(mut self, e: io::Error) -> CommError {
        self.failure
            .take()
            .unwrap_or_else(|| io_error(self.rank, self.shared.timeout, self.waiting_for, e))
    }
}

impl Read for Polled<'_> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        loop {
            match (&mut &*self.stream).read(buf) {
                Ok(n) => return Ok(n),
                Err(e) if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
                ) =>
                {
                    if let Some(err) = self.interrupted() {
                        return Err(err);
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
    }
}

impl Write for Polled<'_> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        loop {
            match (&mut &*self.stream).write(buf) {
                Ok(n) => return Ok(n),
                Err(e) if matches!(
                    e.kind(),
                    io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut
                ) =>
                {
                    if let Some(err) = self.interrupted() {
                        return Err(err);
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        (&mut &*self.stream).flush()
    }
}

// ----------------------------------------------------------- tree framing

/// A bundle frame travelling toward the root:
/// `FRAME_COLLECTIVE | code u8 | op u8 | root u64 | n u64 |
/// n × (rank u64 | provided bool | time f64 | payload f64s)` —
/// or `FRAME_ABORT | comm_error`.
enum UpFrame {
    Abort(CommError),
    Bundle { header: Header, contributions: Vec<Contribution> },
}

fn write_up_frame(
    w: &mut impl Write,
    header: Header,
    contributions: &[Contribution],
) -> io::Result<()> {
    let mut buf = Vec::new();
    buf.push(FRAME_COLLECTIVE);
    buf.push(header.code.to_byte());
    buf.push(header.op);
    codec::write_u64(&mut buf, header.root as u64)?;
    codec::write_u64(&mut buf, contributions.len() as u64)?;
    for c in contributions {
        codec::write_usize(&mut buf, c.rank)?;
        codec::write_bool(&mut buf, c.provided)?;
        codec::write_f64(&mut buf, c.time)?;
        codec::write_f64s(&mut buf, &c.payload)?;
    }
    w.write_all(&buf)
}

fn read_up_frame(r: &mut impl Read) -> io::Result<UpFrame> {
    match codec::read_u8(r)? {
        FRAME_ABORT => Ok(UpFrame::Abort(read_comm_error(r)?)),
        FRAME_COLLECTIVE => {
            let code = OpCode::from_byte(codec::read_u8(r)?)?;
            let op = codec::read_u8(r)?;
            let root = codec::read_usize(r)?;
            let n = codec::read_usize(r)?;
            let mut contributions = Vec::with_capacity(n);
            for _ in 0..n {
                contributions.push(Contribution {
                    rank: codec::read_usize(r)?,
                    provided: codec::read_bool(r)?,
                    time: codec::read_f64(r)?,
                    payload: codec::read_f64s(r)?,
                });
            }
            Ok(UpFrame::Bundle { header: Header { code, op, root }, contributions })
        }
        other => Err(codec::corrupt(format!("unknown bundle frame {other}"))),
    }
}

/// A reply frame travelling away from the root:
/// `FRAME_COLLECTIVE | max_entry f64 | n u64 |
/// n × (rank u64 | n_parts u64 | n_parts × f64s)` —
/// or `FRAME_ABORT | comm_error`.
enum DownFrame {
    Abort(CommError),
    Replies { max_entry: f64, entries: Vec<ReplyEntry> },
}

fn write_down_frame(
    w: &mut impl Write,
    max_entry: f64,
    entries: &[ReplyEntry],
) -> io::Result<()> {
    let mut buf = Vec::new();
    buf.push(FRAME_COLLECTIVE);
    codec::write_f64(&mut buf, max_entry)?;
    codec::write_u64(&mut buf, entries.len() as u64)?;
    for e in entries {
        codec::write_usize(&mut buf, e.rank)?;
        codec::write_u64(&mut buf, e.parts.len() as u64)?;
        for part in &e.parts {
            codec::write_f64s(&mut buf, part)?;
        }
    }
    w.write_all(&buf)
}

fn read_down_frame(r: &mut impl Read) -> io::Result<DownFrame> {
    match codec::read_u8(r)? {
        FRAME_ABORT => Ok(DownFrame::Abort(read_comm_error(r)?)),
        FRAME_COLLECTIVE => {
            let max_entry = codec::read_f64(r)?;
            let n = codec::read_usize(r)?;
            let mut entries = Vec::with_capacity(n);
            for _ in 0..n {
                let rank = codec::read_usize(r)?;
                let n_parts = codec::read_usize(r)?;
                let mut parts = Vec::with_capacity(n_parts);
                for _ in 0..n_parts {
                    parts.push(codec::read_f64s(r)?);
                }
                entries.push(ReplyEntry { rank, parts });
            }
            Ok(DownFrame::Replies { max_entry, entries })
        }
        other => Err(codec::corrupt(format!("unknown reply frame {other}"))),
    }
}

fn mismatch(leader: usize, mine: Header, peer: usize, theirs: Header) -> CommError {
    CommError::ContractViolation {
        rank: leader,
        message: format!(
            "collective mismatch — rank {leader} entered {:?}(root {}), \
             rank {peer} entered {:?}(root {})",
            mine.code, mine.root, theirs.code, theirs.root
        ),
    }
}

fn transport_err(rank: usize, message: String) -> CommError {
    CommError::Transport { rank, message }
}

// ------------------------------------------------------------- the handle

/// Telemetry identity of one collective: the full two-level `cost`
/// charges the clock and prices the `"intra"` record; `inter_cost` is
/// the leader-tree share, priced on the leader's `"inter"` record.
struct Probe {
    primitive: &'static str,
    bytes: usize,
    cost: f64,
    inter_cost: f64,
}

/// Per-rank handle of the hierarchical transport.
pub struct HierCtx<'a> {
    rank: usize,
    size: usize,
    node: usize,
    local: usize,
    shared: &'a GroupShared,
    /// tree streams — `Some` on node leaders only
    link: Option<LeaderLink>,
    clock: Clock,
    /// first failure observed on this handle; subsequent collectives
    /// fail fast with it instead of touching desynced boards/streams
    failed: Option<CommError>,
    tracer: Tracer,
}

impl HierCtx<'_> {
    /// The node index this rank lives on (leaders are local index 0).
    pub fn node(&self) -> usize {
        self.node
    }

    /// Whether this rank is its node's leader (holds tree streams).
    pub fn is_leader(&self) -> bool {
        self.local == 0
    }

    fn exchange(
        &mut self,
        probe: Probe,
        header: Header,
        provided: bool,
        payload: Vec<f64>,
    ) -> CommResult<(f64, Vec<Vec<f64>>)> {
        if let Some(e) = &self.failed {
            return Err(e.clone());
        }
        let cs = self.tracer.comm_start();
        let mut wait_s = 0.0;
        let result = self.exchange_inner(cs, &probe, header, provided, payload, &mut wait_s);
        self.tracer.comm_record_link(
            cs,
            probe.primitive,
            "intra",
            probe.bytes,
            probe.cost,
            wait_s,
        );
        if let Err(e) = &result {
            self.failed = Some(e.clone());
        }
        result
    }

    /// The three-phase collective body. `wait_s` receives the time this
    /// rank spent parked: to the first rendezvous for leaders (waiting
    /// for node peers — the tree exchange is accounted separately by
    /// the `"inter"` record), to the release rendezvous for everyone
    /// else.
    fn exchange_inner(
        &mut self,
        cs: CommStart,
        probe: &Probe,
        header: Header,
        provided: bool,
        payload: Vec<f64>,
        wait_s: &mut f64,
    ) -> CommResult<(f64, Vec<Vec<f64>>)> {
        let shared = self.shared;
        let nshared = &shared.nodes[self.node];
        *nshared.slots[self.local].lock().unwrap() =
            Some(Entry { header, provided, time: self.clock.now(), payload });

        if let Err(e) = nshared.board.wait(self.rank, shared.timeout) {
            *wait_s = self.tracer.elapsed_since(cs);
            return Err(e);
        }
        if self.local == 0 {
            *wait_s = self.tracer.elapsed_since(cs);
            if let Err(e) = self.leader_exchange(probe, header) {
                let canonical = group_abort(shared, e);
                self.best_effort_abort(&canonical);
                return Err(canonical);
            }
        }
        let released = nshared.board.wait(self.rank, shared.timeout);
        if self.local != 0 {
            *wait_s = self.tracer.elapsed_since(cs);
        }
        released?;
        match nshared.replies[self.local].lock().unwrap().take() {
            Some(reply) => Ok(reply),
            None => Err(transport_err(
                self.rank,
                "reply slot empty after a completed exchange".to_string(),
            )),
        }
    }

    /// Leader phase: gather the node's raw contributions, exchange
    /// bundles through the tree (the root folds once, in global rank
    /// order), route replies back down, and fill the node reply slots.
    fn leader_exchange(&mut self, probe: &Probe, header: Header) -> CommResult<()> {
        let shared = self.shared;
        let nshared = &shared.nodes[self.node];
        let deadline = shared.timeout.map(|t| Instant::now() + t);
        let inter_cs = self.tracer.comm_start();

        // node-local gather, rank-tagged
        let mut contributions: Vec<Contribution> = Vec::new();
        for (local, slot) in nshared.slots.iter().enumerate() {
            let peer = nshared.first + local;
            let entry = slot.lock().unwrap().take().ok_or_else(|| {
                transport_err(self.rank, format!("rank {peer} posted no contribution"))
            })?;
            if entry.header != header {
                return Err(mismatch(self.rank, header, peer, entry.header));
            }
            contributions.push(Contribution {
                rank: peer,
                provided: entry.provided,
                time: entry.time,
                payload: entry.payload,
            });
        }

        // up phase: fold in each child subtree's bundle
        let link = self.link.as_mut().expect("leader rank holds the tree link");
        for child in link.children.iter_mut() {
            let mut pr = Polled::new(
                &child.stream,
                shared,
                self.rank,
                deadline,
                "bundle from a child node leader",
            );
            let frame = match read_up_frame(&mut pr) {
                Ok(f) => f,
                Err(e) => return Err(pr.fail(e)),
            };
            match frame {
                UpFrame::Abort(e) => return Err(e),
                UpFrame::Bundle { header: theirs, contributions: subtree } => {
                    if theirs != header {
                        let child_leader = shared.nodes[child.node].first;
                        return Err(mismatch(self.rank, header, child_leader, theirs));
                    }
                    child.ranks.clear();
                    for c in subtree {
                        if c.rank >= shared.size {
                            return Err(transport_err(
                                self.rank,
                                format!("bundle names rank {} of {}", c.rank, shared.size),
                            ));
                        }
                        child.ranks.push(c.rank);
                        contributions.push(c);
                    }
                }
            }
        }

        let (max_entry, mut reply_of) = match link.parent.as_ref() {
            None => root_replies(shared, self.rank, header, contributions)?,
            Some(parent) => {
                let mut pw = Polled::new(
                    parent,
                    shared,
                    self.rank,
                    deadline,
                    "sending the bundle to the parent node leader",
                );
                if let Err(e) = write_up_frame(&mut pw, header, &contributions) {
                    return Err(pw.fail(e));
                }
                let mut pr = Polled::new(
                    parent,
                    shared,
                    self.rank,
                    deadline,
                    "replies from the parent node leader",
                );
                let down = match read_down_frame(&mut pr) {
                    Ok(d) => d,
                    Err(e) => return Err(pr.fail(e)),
                };
                match down {
                    DownFrame::Abort(e) => return Err(e),
                    DownFrame::Replies { max_entry, entries } => {
                        let mut reply_of: Vec<Option<Vec<Vec<f64>>>> = Vec::new();
                        reply_of.resize_with(shared.size, || None);
                        for e in entries {
                            if e.rank >= shared.size {
                                return Err(transport_err(
                                    self.rank,
                                    format!("reply names rank {} of {}", e.rank, shared.size),
                                ));
                            }
                            reply_of[e.rank] = Some(e.parts);
                        }
                        (max_entry, reply_of)
                    }
                }
            }
        };

        // down phase: children first (deeper nodes wake sooner), then
        // this node's reply slots
        for child in &link.children {
            let mut entries = Vec::with_capacity(child.ranks.len());
            for &r in &child.ranks {
                let parts = reply_of[r].take().ok_or_else(|| {
                    transport_err(self.rank, format!("no reply for subtree rank {r}"))
                })?;
                entries.push(ReplyEntry { rank: r, parts });
            }
            let mut pw = Polled::new(
                &child.stream,
                shared,
                self.rank,
                deadline,
                "forwarding replies to a child node leader",
            );
            if let Err(e) = write_down_frame(&mut pw, max_entry, &entries) {
                return Err(pw.fail(e));
            }
        }
        for (local, slot) in nshared.replies.iter().enumerate() {
            let r = nshared.first + local;
            let parts = reply_of[r]
                .take()
                .ok_or_else(|| transport_err(self.rank, format!("no reply for rank {r}")))?;
            *slot.lock().unwrap() = Some((max_entry, parts));
        }

        if shared.nodes.len() > 1 {
            let inter_wait = self.tracer.elapsed_since(inter_cs);
            self.tracer.comm_record_link(
                inter_cs,
                probe.primitive,
                "inter",
                probe.bytes,
                probe.inter_cost,
                inter_wait,
            );
        }
        Ok(())
    }

    /// Wake leaders parked on tree sockets with explicit abort frames
    /// (the poison poll would get them within a slice anyway; frames
    /// make the fan-out immediate and are the carrier a cross-machine
    /// deployment of this tree would rely on). Writes are fire-and-
    /// forget under the streams' short OS write timeout.
    fn best_effort_abort(&mut self, err: &CommError) {
        let Some(link) = self.link.as_mut() else { return };
        let mut buf = vec![FRAME_ABORT];
        push_comm_error(&mut buf, err);
        for child in &link.children {
            let _ = (&mut &child.stream).write_all(&buf);
        }
        if let Some(parent) = link.parent.as_ref() {
            let _ = (&mut &*parent).write_all(&buf);
        }
    }
}

/// Root assembly: order every rank's contribution by global rank, fold
/// once through [`hub_replies`], and index the replies by rank.
#[allow(clippy::type_complexity)]
fn root_replies(
    shared: &GroupShared,
    leader: usize,
    header: Header,
    contributions: Vec<Contribution>,
) -> CommResult<(f64, Vec<Option<Vec<Vec<f64>>>>)> {
    if contributions.len() != shared.size {
        return Err(transport_err(
            leader,
            format!(
                "assembled {} contributions for {} ranks",
                contributions.len(),
                shared.size
            ),
        ));
    }
    let max_entry = contributions.iter().map(|c| c.time).fold(0.0f64, f64::max);
    let mut provided = vec![false; shared.size];
    let mut parts: Vec<Vec<f64>> = Vec::new();
    parts.resize_with(shared.size, Vec::new);
    let mut seen = vec![false; shared.size];
    for c in contributions {
        if seen[c.rank] {
            return Err(transport_err(leader, format!("rank {} contributed twice", c.rank)));
        }
        seen[c.rank] = true;
        provided[c.rank] = c.provided;
        parts[c.rank] = c.payload;
    }
    let replies = hub_replies(header.code, header.op, header.root, &provided, &parts, shared.size)?;
    Ok((max_entry, replies.into_iter().map(Some).collect()))
}

impl Communicator for HierCtx<'_> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn clock(&self) -> &Clock {
        &self.clock
    }

    fn charge(&mut self, category: Category, seconds: f64) {
        self.clock.add(category, seconds);
    }

    fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    fn tracer_mut(&mut self) -> &mut Tracer {
        &mut self.tracer
    }

    fn allreduce_inplace(&mut self, data: &mut [f64], op: Op) -> CommResult<()> {
        let bytes = data.len() * 8;
        let (nodes, rpn) = (self.shared.nodes.len(), self.shared.rpn);
        let cost = self.shared.model.allreduce(nodes, rpn, bytes);
        let inter_cost = self.shared.model.inter.allreduce(nodes, bytes);
        let (max_entry, mut parts) = self.exchange(
            Probe { primitive: "allreduce", bytes, cost, inter_cost },
            Header { code: OpCode::Allreduce, op: op_to_byte(op), root: 0 },
            true,
            data.to_vec(),
        )?;
        let reduced = parts.pop().ok_or_else(|| {
            transport_err(self.rank, "empty allreduce reply".to_string())
        })?;
        debug_assert_eq!(reduced.len(), data.len(), "root validated equal lengths");
        data.copy_from_slice(&reduced);
        self.clock.sync_to(max_entry + cost);
        Ok(())
    }

    fn broadcast(&mut self, root: usize, data: Option<Vec<f64>>) -> CommResult<Vec<f64>> {
        self.check_root("broadcast", root)?;
        let provided = data.is_some();
        let data_bytes = data.as_ref().map_or(0, |d| d.len() * 8);
        let (nodes, rpn) = (self.shared.nodes.len(), self.shared.rpn);
        let cost = self.shared.model.broadcast(nodes, rpn, data_bytes);
        let inter_cost = self.shared.model.inter.broadcast(nodes, data_bytes);
        let (max_entry, mut parts) = self.exchange(
            Probe { primitive: "broadcast", bytes: data_bytes, cost, inter_cost },
            Header { code: OpCode::Broadcast, op: 0, root },
            provided,
            data.unwrap_or_default(),
        )?;
        let out = parts.pop().ok_or_else(|| {
            transport_err(self.rank, "empty broadcast reply".to_string())
        })?;
        self.clock.sync_to(max_entry + cost);
        Ok(out)
    }

    fn allgather(&mut self, data: &[f64]) -> CommResult<Vec<Vec<f64>>> {
        let bytes = data.len() * 8 * self.size;
        let (nodes, rpn) = (self.shared.nodes.len(), self.shared.rpn);
        let cost = self.shared.model.allgather(nodes, rpn, bytes);
        let inter_cost = self.shared.model.inter.allgather(nodes, bytes);
        let (max_entry, parts) = self.exchange(
            Probe { primitive: "allgather", bytes, cost, inter_cost },
            Header { code: OpCode::Allgather, op: 0, root: 0 },
            true,
            data.to_vec(),
        )?;
        self.clock.sync_to(max_entry + cost);
        Ok(parts)
    }

    fn gather(&mut self, root: usize, data: &[f64]) -> CommResult<Option<Vec<Vec<f64>>>> {
        self.check_root("gather", root)?;
        let bytes = data.len() * 8 * self.size;
        let (nodes, rpn) = (self.shared.nodes.len(), self.shared.rpn);
        let cost = self.shared.model.gather(nodes, rpn, bytes);
        let inter_cost = self.shared.model.inter.gather(nodes, bytes);
        let (max_entry, parts) = self.exchange(
            Probe { primitive: "gather", bytes, cost, inter_cost },
            Header { code: OpCode::Gather, op: 0, root },
            true,
            data.to_vec(),
        )?;
        self.clock.sync_to(max_entry + cost);
        Ok((self.rank == root).then_some(parts))
    }

    fn reduce(&mut self, root: usize, data: &[f64], op: Op) -> CommResult<Option<Vec<f64>>> {
        self.check_root("reduce", root)?;
        let bytes = data.len() * 8;
        let (nodes, rpn) = (self.shared.nodes.len(), self.shared.rpn);
        let cost = self.shared.model.reduce(nodes, rpn, bytes);
        let inter_cost = self.shared.model.inter.reduce(nodes, bytes);
        let (max_entry, mut parts) = self.exchange(
            Probe { primitive: "reduce", bytes, cost, inter_cost },
            Header { code: OpCode::Reduce, op: op_to_byte(op), root },
            true,
            data.to_vec(),
        )?;
        self.clock.sync_to(max_entry + cost);
        if self.rank == root {
            match parts.pop() {
                Some(reduced) => Ok(Some(reduced)),
                None => Err(transport_err(
                    self.rank,
                    "empty reduce reply on root".to_string(),
                )),
            }
        } else {
            Ok(None)
        }
    }

    fn reduce_scatter_block(&mut self, data: &[f64], op: Op) -> CommResult<Vec<f64>> {
        // divisibility is validated at the root over every rank's
        // length, after the exchange (same rationale as the flat
        // transports: a local pre-check would park compliant peers)
        let bytes = data.len() * 8;
        let (nodes, rpn) = (self.shared.nodes.len(), self.shared.rpn);
        let cost = self.shared.model.reduce_scatter(nodes, rpn, bytes);
        let inter_cost = self.shared.model.inter.reduce_scatter(nodes, bytes);
        let (max_entry, mut parts) = self.exchange(
            Probe { primitive: "reduce_scatter", bytes, cost, inter_cost },
            Header { code: OpCode::ReduceScatter, op: op_to_byte(op), root: 0 },
            true,
            data.to_vec(),
        )?;
        self.clock.sync_to(max_entry + cost);
        parts.pop().ok_or_else(|| {
            transport_err(self.rank, "empty reduce_scatter_block reply".to_string())
        })
    }

    fn barrier(&mut self) -> CommResult<()> {
        let (nodes, rpn) = (self.shared.nodes.len(), self.shared.rpn);
        let cost = self.shared.model.barrier(nodes, rpn);
        let inter_cost = self.shared.model.inter.barrier(nodes);
        let (max_entry, _) = self.exchange(
            Probe { primitive: "barrier", bytes: 0, cost, inter_cost },
            Header { code: OpCode::Barrier, op: 0, root: 0 },
            true,
            Vec::new(),
        )?;
        self.clock.sync_to(max_entry + cost);
        Ok(())
    }

    fn abort(&mut self, message: &str) -> CommError {
        let canonical = group_abort(
            self.shared,
            CommError::RemoteAbort { origin_rank: self.rank, message: message.to_string() },
        );
        self.best_effort_abort(&canonical);
        canonical
    }
}

// -------------------------------------------------------------- the runner

/// Contiguous node layout: `(first_rank, size)` per node, sizes
/// differing by at most one (the first `p % nodes` nodes take the
/// extra rank).
fn node_layout(p: usize, nodes: usize) -> Vec<(usize, usize)> {
    let base = p / nodes;
    let extra = p % nodes;
    let mut layout = Vec::with_capacity(nodes);
    let mut first = 0;
    for i in 0..nodes {
        let size = base + usize::from(i < extra);
        layout.push((first, size));
        first += size;
    }
    layout
}

fn locate(layout: &[(usize, usize)], rank: usize) -> (usize, usize) {
    for (node, &(first, size)) in layout.iter().enumerate() {
        if rank >= first && rank < first + size {
            return (node, rank - first);
        }
    }
    unreachable!("rank {rank} outside the node layout");
}

/// A connected loopback stream pair for one tree edge, with nodelay on
/// and OS read/write timeouts set to the poll slice (see [`Polled`]).
fn loopback_pair() -> (TcpStream, TcpStream) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind a loopback leader-tree edge");
    let addr = listener.local_addr().expect("leader-tree listener address");
    let near = TcpStream::connect(addr).expect("connect a leader-tree edge");
    let (far, _) = listener.accept().expect("accept a leader-tree edge");
    for s in [&near, &far] {
        s.set_nodelay(true).ok();
        s.set_read_timeout(Some(POLL_SLICE)).expect("leader-tree read timeout");
        s.set_write_timeout(Some(POLL_SLICE)).expect("leader-tree write timeout");
    }
    (far, near)
}

/// Spawn `p` rank threads over `nodes` node groups and return the
/// per-rank results in rank order. Panics in rank code abort the group
/// (siblings wake with [`CommError::RemoteAbort`]) and then propagate
/// with their original payload.
pub fn run<R: Send>(
    p: usize,
    nodes: usize,
    model: TwoLevelModel,
    f: impl Fn(&mut HierCtx) -> R + Send + Sync,
) -> Vec<R> {
    run_with_clocks_timeout(p, nodes, model, None, f).into_iter().map(|(out, _)| out).collect()
}

/// Like [`run`], but also returns each rank's final [`Clock`], with an
/// optional deadline bounding every board wait and tree read/write.
pub fn run_with_clocks_timeout<R: Send>(
    p: usize,
    nodes: usize,
    model: TwoLevelModel,
    timeout: Option<Duration>,
    f: impl Fn(&mut HierCtx) -> R + Send + Sync,
) -> Vec<(R, Clock)> {
    assert!(p >= 1, "need at least one rank");
    assert!((1..=p).contains(&nodes), "need 1 ≤ nodes ≤ ranks, got {nodes} nodes for {p} ranks");
    let layout = node_layout(p, nodes);
    let shared = GroupShared {
        size: p,
        nodes: layout
            .iter()
            .map(|&(first, size)| NodeShared {
                first,
                slots: (0..size).map(|_| Mutex::new(None)).collect(),
                replies: (0..size).map(|_| Mutex::new(None)).collect(),
                board: Board::new(size),
            })
            .collect(),
        poison: Mutex::new(None),
        model,
        timeout,
        rpn: p.div_ceil(nodes),
    };
    // the leader tree: one loopback stream pair per edge, created
    // before any thread spawns so a rank function can never observe a
    // half-built topology
    let mut links: Vec<Option<LeaderLink>> =
        (0..nodes).map(|_| Some(LeaderLink { parent: None, children: Vec::new() })).collect();
    for child_node in 1..nodes {
        let parent_node = (child_node - 1) / 2;
        let (parent_end, child_end) = loopback_pair();
        links[parent_node].as_mut().unwrap().children.push(ChildLink {
            node: child_node,
            stream: parent_end,
            ranks: Vec::new(),
        });
        links[child_node].as_mut().unwrap().parent = Some(child_end);
    }
    let shared = &shared;
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..p)
            .map(|rank| {
                let f = &f;
                let (node, local) = locate(&layout, rank);
                let link = if local == 0 { links[node].take() } else { None };
                scope.spawn(move || {
                    let mut ctx = HierCtx {
                        rank,
                        size: p,
                        node,
                        local,
                        shared,
                        link,
                        clock: Clock::new(),
                        failed: None,
                        tracer: Tracer::new(rank),
                    };
                    // a genuine panic must poison the group before
                    // propagating: siblings parked at a collective
                    // would otherwise never be joinable
                    let out =
                        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut ctx)));
                    match out {
                        Ok(v) => (v, ctx.clock),
                        Err(payload) => {
                            ctx.abort(&format!(
                                "rank {rank} panicked: {}",
                                panic_text(&payload)
                            ));
                            std::panic::resume_unwind(payload);
                        }
                    }
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(v) => v,
                Err(e) => std::panic::resume_unwind(e),
            })
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::super::costmodel::CostModel;
    use super::super::thread;
    use super::*;

    /// A digest touching every primitive with rank-skewed magnitudes;
    /// any re-association of the folds changes the bits.
    fn digest<C: Communicator>(ctx: &mut C) -> Vec<f64> {
        let rank = ctx.rank() as f64;
        let size = ctx.size();
        let mut out = Vec::new();
        let mine: Vec<f64> =
            (0..6).map(|j| 1e12 * rank - j as f64 * 0.37 + 1.0 / (rank + 2.0)).collect();
        out.extend(ctx.allreduce(&mine, Op::Sum).unwrap());
        out.extend(ctx.allreduce(&mine, Op::Max).unwrap());
        let payload = (ctx.rank() == size - 1).then(|| vec![2.5, -1e9, 0.125]);
        out.extend(ctx.broadcast(size - 1, payload).unwrap());
        for part in ctx.allgather(&[rank * 3.25, -rank]).unwrap() {
            out.extend(part);
        }
        if let Some(parts) = ctx.gather(0, &vec![rank + 0.5; ctx.rank() + 1]).unwrap() {
            for part in parts {
                out.extend(part);
            }
        }
        if let Some(reduced) = ctx.reduce(size - 1, &mine, Op::Min).unwrap() {
            out.extend(reduced);
        }
        let long: Vec<f64> = (0..2 * size).map(|j| (j as f64 + 0.25) * (rank + 1.0)).collect();
        out.extend(ctx.reduce_scatter_block(&long, Op::Sum).unwrap());
        ctx.barrier().unwrap();
        out
    }

    #[test]
    fn matches_the_thread_backend_bitwise_across_node_shapes() {
        for (p, nodes) in [(1, 1), (2, 2), (4, 1), (4, 2), (4, 3), (4, 4), (5, 2), (8, 4)] {
            let flat = thread::run(p, CostModel::free(), |ctx| digest(ctx));
            let hier = run(p, nodes, TwoLevelModel::free(), |ctx| digest(ctx));
            for rank in 0..p {
                assert_eq!(
                    flat[rank].len(),
                    hier[rank].len(),
                    "digest length, p={p} nodes={nodes} rank={rank}"
                );
                for (i, (a, b)) in flat[rank].iter().zip(&hier[rank]).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "p={p} nodes={nodes} rank={rank} element {i}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn abort_wakes_every_rank_promptly() {
        // rank 3 (node 1) aborts immediately; rank 1 dawdles before its
        // collective. Ranks 0 and 2 — the leaders, one parked at a
        // board, one on the tree — must wake with the typed abort long
        // before any timeout, not in rank order behind the dawdler.
        let results = run_with_clocks_timeout(
            4,
            2,
            TwoLevelModel::free(),
            Some(Duration::from_secs(10)),
            |ctx| {
                let begin = Instant::now();
                let out = if ctx.rank() == 3 {
                    Err(ctx.abort("injected failure on the last rank"))
                } else {
                    if ctx.rank() == 1 {
                        std::thread::sleep(Duration::from_millis(300));
                    }
                    ctx.allreduce_scalar(1.0, Op::Sum).map(|_| ())
                };
                (out, begin.elapsed())
            },
        );
        for (rank, ((out, elapsed), _)) in results.iter().enumerate() {
            match out {
                Err(CommError::RemoteAbort { origin_rank: 3, message }) => {
                    assert!(message.contains("injected failure"), "{message}");
                }
                other => panic!("rank {rank}: expected RemoteAbort from 3, got {other:?}"),
            }
            if rank == 0 || rank == 2 {
                assert!(
                    *elapsed < Duration::from_millis(1000),
                    "rank {rank} woke after {elapsed:?}, not promptly"
                );
            }
        }
    }

    #[test]
    fn broadcast_contract_violation_fails_the_whole_group() {
        let results = run(4, 2, TwoLevelModel::free(), |ctx| {
            let payload = (ctx.rank() == 2 || ctx.rank() == 0).then(|| vec![1.0]);
            ctx.broadcast(0, payload)
        });
        for (rank, r) in results.iter().enumerate() {
            match r {
                Err(CommError::ContractViolation { message, .. }) => {
                    assert!(message.contains("non-root rank 2 passed Some"), "{message}");
                }
                other => panic!("rank {rank}: expected ContractViolation, got {other:?}"),
            }
        }
    }

    #[test]
    fn mismatched_collectives_are_a_typed_error_not_a_corrupt_fold() {
        let results = run(4, 2, TwoLevelModel::free(), |ctx| {
            if ctx.rank() == 3 {
                ctx.barrier().map(|()| Vec::new())
            } else {
                ctx.allreduce(&[1.0], Op::Sum)
            }
        });
        for (rank, r) in results.iter().enumerate() {
            match r {
                Err(CommError::ContractViolation { message, .. }) => {
                    assert!(message.contains("collective mismatch"), "{message}");
                }
                other => panic!("rank {rank}: expected ContractViolation, got {other:?}"),
            }
        }
    }

    #[test]
    fn missing_peer_times_out_instead_of_hanging() {
        // rank 3 never enters the collective; its node leader times out
        // at the board and aborts the group, so every other rank gets a
        // typed error bounded by the deadline
        let results = run_with_clocks_timeout(
            4,
            2,
            TwoLevelModel::free(),
            Some(Duration::from_millis(250)),
            |ctx| {
                if ctx.rank() == 3 {
                    Ok(0.0)
                } else {
                    ctx.allreduce_scalar(1.0, Op::Sum)
                }
            },
        );
        for (rank, (r, _)) in results.iter().enumerate().take(3) {
            assert!(
                matches!(r, Err(CommError::Timeout { .. }) | Err(CommError::RemoteAbort { .. })),
                "rank {rank}: expected Timeout/RemoteAbort, got {r:?}"
            );
        }
        assert!(results[3].0.is_ok());
    }

    #[test]
    fn poisoned_group_fails_every_subsequent_collective() {
        let results = run(4, 2, TwoLevelModel::free(), |ctx| {
            if ctx.rank() == 1 {
                ctx.abort("dead");
            }
            let a = ctx.allreduce_scalar(1.0, Op::Sum);
            let b = ctx.barrier();
            (a.is_err(), b.is_err())
        });
        for (a, b) in &results {
            assert!(a && b);
        }
    }

    #[test]
    fn traces_tag_intra_and_inter_hops() {
        let traces = run(4, 2, TwoLevelModel::hpc(), |ctx| {
            ctx.tracer_mut().set_enabled(true);
            ctx.allreduce_scalar(ctx.rank() as f64, Op::Sum).unwrap();
            ctx.barrier().unwrap();
            let leader = ctx.is_leader();
            (leader, ctx.tracer_mut().take())
        });
        for (rank, (leader, trace)) in traces.iter().enumerate() {
            assert_eq!(*leader, rank == 0 || rank == 2);
            let intra: Vec<_> = trace.comm.iter().filter(|c| c.link == "intra").collect();
            let inter: Vec<_> = trace.comm.iter().filter(|c| c.link == "inter").collect();
            assert_eq!(intra.len(), 2, "rank {rank}: one intra record per collective");
            assert_eq!(intra[0].primitive, "allreduce");
            assert_eq!(intra[1].primitive, "barrier");
            if *leader {
                assert_eq!(inter.len(), 2, "leaders record the tree hop");
                let expect = TwoLevelModel::hpc().inter.allreduce(2, 8);
                assert!((inter[0].predicted_s - expect).abs() < 1e-18);
            } else {
                assert!(inter.is_empty(), "rank {rank} is not a leader");
            }
            // the intra record is priced at the full two-level cost the
            // clock was charged with
            let full = TwoLevelModel::hpc().allreduce(2, 2, 8);
            assert!((intra[0].predicted_s - full).abs() < 1e-18);
        }
    }

    #[test]
    fn clocks_sync_to_the_two_level_cost() {
        let model = TwoLevelModel::hpc();
        let results = run_with_clocks_timeout(4, 2, model, None, |ctx| {
            ctx.charge(Category::Compute, ctx.rank() as f64);
            ctx.allreduce_scalar(1.0, Op::Sum).unwrap();
            ctx.clock().now()
        });
        let expect = 3.0 + model.allreduce(2, 2, 8);
        for (t, clock) in &results {
            assert!((t - expect).abs() < 1e-12, "{t} vs {expect}");
            assert!((clock.now() - expect).abs() < 1e-12);
        }
        // the laggard charged 3s of compute; everyone else waited
        assert!(results[0].1.in_category(Category::Comm) >= 3.0 - 1e-9);
    }

    #[test]
    fn single_rank_single_node_works() {
        let results = run(1, 1, TwoLevelModel::hpc(), |ctx| {
            ctx.barrier().unwrap();
            assert_eq!(ctx.gather(0, &[3.0]).unwrap().unwrap(), vec![vec![3.0]]);
            ctx.allreduce_scalar(5.0, Op::Sum).unwrap()
        });
        assert_eq!(results, vec![5.0]);
    }

    #[test]
    fn node_layout_is_contiguous_and_balanced() {
        assert_eq!(node_layout(4, 2), vec![(0, 2), (2, 2)]);
        assert_eq!(node_layout(5, 2), vec![(0, 3), (3, 2)]);
        assert_eq!(node_layout(4, 3), vec![(0, 2), (2, 1), (3, 1)]);
        assert_eq!(node_layout(8, 1), vec![(0, 8)]);
        for p in 1..=9 {
            for nodes in 1..=p {
                let layout = node_layout(p, nodes);
                assert_eq!(layout.iter().map(|&(_, s)| s).sum::<usize>(), p);
                assert!(layout.iter().all(|&(_, s)| s >= 1));
                for rank in 0..p {
                    let (node, local) = locate(&layout, rank);
                    assert_eq!(layout[node].0 + local, rank);
                }
            }
        }
    }

    #[test]
    fn rank_panic_poisons_the_group_then_propagates() {
        let observed = Mutex::new(None);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run(4, 2, TwoLevelModel::free(), |ctx| {
                if ctx.rank() == 3 {
                    panic!("boom in hier rank code");
                }
                let got = ctx.allreduce_scalar(1.0, Op::Sum);
                if ctx.rank() == 0 {
                    *observed.lock().unwrap() = Some(got);
                }
            })
        }));
        assert!(caught.is_err(), "the original panic must still propagate");
        match observed.into_inner().unwrap() {
            Some(Err(CommError::RemoteAbort { origin_rank: 3, message })) => {
                assert!(message.contains("boom in hier rank code"));
            }
            other => panic!("rank 0 should observe the panic as RemoteAbort, got {other:?}"),
        }
    }
}

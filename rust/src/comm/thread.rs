//! Shared-board thread transport: exact collectives between rank
//! threads of one process.
//!
//! [`run`] spawns `p` rank threads executing the same closure (the MPI
//! model of the paper, Sec. III.A). Ranks synchronize through
//! [`RankCtx`] collectives backed by a shared contribution board: each
//! rank posts its payload, waits at a barrier, combines all
//! contributions *in rank order* through the shared
//! [`fold`](super::communicator::fold) kernels (bitwise-deterministic
//! results), then passes a second barrier before slots are reused.
//!
//! Contract validation rides the board: `broadcast` exchanges a
//! provided-payload flag with the data, so a rank that breaks the
//! root-provides contract makes *every* rank panic with a rank-tagged
//! message — a local assert would leave the compliant ranks parked
//! forever at the barrier.

use std::sync::{Barrier, Mutex};

use super::clock::{Category, Clock};
use super::communicator::{fold, Communicator, Op};
use super::costmodel::CostModel;

struct Shared {
    /// per-rank contribution slots for the active collective
    slots: Vec<Mutex<Vec<f64>>>,
    /// per-rank virtual-time postings for clock synchronization
    times: Vec<Mutex<f64>>,
    barrier: Barrier,
    model: CostModel,
}

/// Per-rank handle of the shared-board thread transport.
pub struct RankCtx<'a> {
    rank: usize,
    size: usize,
    shared: &'a Shared,
    clock: Clock,
}

impl<'a> RankCtx<'a> {
    /// Post this rank's payload + clock, wait for all, then combine
    /// every rank's payload in rank order with `combine`. Advances
    /// clocks to max-entry + modeled cost.
    fn collective<T>(
        &mut self,
        payload: Vec<f64>,
        modeled_cost: f64,
        combine: impl FnOnce(&[Vec<f64>]) -> T,
    ) -> T {
        *self.shared.slots[self.rank].lock().unwrap() = payload;
        *self.shared.times[self.rank].lock().unwrap() = self.clock.now();
        self.shared.barrier.wait();

        // every rank reads all contributions; rank-ordered combine
        let contributions: Vec<Vec<f64>> = (0..self.size)
            .map(|i| self.shared.slots[i].lock().unwrap().clone())
            .collect();
        let max_entry = (0..self.size)
            .map(|i| *self.shared.times[i].lock().unwrap())
            .fold(0.0, f64::max);
        let out = combine(&contributions);

        // second barrier: nobody reuses slots until everyone has read
        self.shared.barrier.wait();
        self.clock.sync_to(max_entry + modeled_cost);
        out
    }
}

impl Communicator for RankCtx<'_> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn clock(&self) -> &Clock {
        &self.clock
    }

    fn charge(&mut self, category: Category, seconds: f64) {
        self.clock.add(category, seconds);
    }

    fn allreduce_inplace(&mut self, data: &mut [f64], op: Op) {
        let bytes = data.len() * 8;
        let cost = self.shared.model.allreduce(self.size, bytes);
        let payload = data.to_vec(); // the board keeps its own copy
        self.collective(payload, cost, |parts| fold::reduce_into(parts, data, op));
    }

    fn broadcast(&mut self, root: usize, data: Option<Vec<f64>>) -> Vec<f64> {
        assert!(root < self.size, "broadcast root {root} out of range (size {})", self.size);
        let rank = self.rank;
        // A provided-payload flag travels with the data so contract
        // violations surface as a panic on every rank after the
        // exchange, not as a deadlock at the barrier.
        let provided = data.is_some();
        let data_bytes = data.as_ref().map_or(0, |d| d.len() * 8);
        let mut payload = vec![if provided { 1.0 } else { 0.0 }];
        if let Some(d) = data {
            payload.extend_from_slice(&d);
        }
        let cost = self.shared.model.broadcast(self.size, data_bytes);
        self.collective(payload, cost, |parts| {
            for (i, part) in parts.iter().enumerate() {
                let flagged = part.first() == Some(&1.0);
                if i == root && !flagged {
                    panic!(
                        "rank {rank}: broadcast(root={root}) — root rank {root} provided no payload"
                    );
                }
                if i != root && flagged {
                    panic!(
                        "rank {rank}: broadcast(root={root}) — non-root rank {i} passed Some(..); \
                         only the root provides the payload"
                    );
                }
            }
            parts[root][1..].to_vec()
        })
    }

    fn allgather(&mut self, data: &[f64]) -> Vec<Vec<f64>> {
        let bytes = data.len() * 8 * self.size;
        let cost = self.shared.model.allgather(self.size, bytes);
        self.collective(data.to_vec(), cost, |parts| parts.to_vec())
    }

    fn gather(&mut self, root: usize, data: &[f64]) -> Option<Vec<Vec<f64>>> {
        assert!(root < self.size, "gather root {root} out of range (size {})", self.size);
        let bytes = data.len() * 8 * self.size;
        let cost = self.shared.model.gather(self.size, bytes);
        let rank = self.rank;
        self.collective(data.to_vec(), cost, |parts| (rank == root).then(|| parts.to_vec()))
    }

    fn reduce(&mut self, root: usize, data: &[f64], op: Op) -> Option<Vec<f64>> {
        assert!(root < self.size, "reduce root {root} out of range (size {})", self.size);
        let bytes = data.len() * 8;
        let cost = self.shared.model.reduce(self.size, bytes);
        let rank = self.rank;
        self.collective(data.to_vec(), cost, |parts| {
            (rank == root).then(|| fold::reduce_parts(parts, op))
        })
    }

    fn reduce_scatter_block(&mut self, data: &[f64], op: Op) -> Vec<f64> {
        let bytes = data.len() * 8;
        let cost = self.shared.model.reduce_scatter(self.size, bytes);
        let (rank, size) = (self.rank, self.size);
        // length validation happens after the exchange, over every
        // rank's part: a rank with a ragged (or indivisible) length
        // must panic the whole group, not park the compliant ranks
        // forever at the board barrier (same rationale as broadcast's
        // provided-payload flag)
        self.collective(data.to_vec(), cost, |parts| {
            for (i, part) in parts.iter().enumerate() {
                assert_eq!(
                    part.len() % size,
                    0,
                    "rank {rank}: reduce_scatter_block — rank {i}'s length {} not divisible by p = {size}",
                    part.len()
                );
            }
            let reduced = fold::reduce_parts(parts, op);
            fold::block(&reduced, rank, size)
        })
    }

    fn barrier(&mut self) {
        let cost = self.shared.model.barrier(self.size);
        self.collective(Vec::new(), cost, |_| ());
    }
}

fn new_shared(p: usize, model: CostModel) -> Shared {
    Shared {
        slots: (0..p).map(|_| Mutex::new(Vec::new())).collect(),
        times: (0..p).map(|_| Mutex::new(0.0)).collect(),
        barrier: Barrier::new(p),
        model,
    }
}

/// Spawn `p` rank threads running `f` and return the per-rank results in
/// rank order. Panics in any rank propagate with their original payload.
pub fn run<R: Send>(
    p: usize,
    model: CostModel,
    f: impl Fn(&mut RankCtx) -> R + Send + Sync,
) -> Vec<R> {
    run_with_clocks(p, model, f).into_iter().map(|(out, _)| out).collect()
}

/// Like [`run`], but also returns each rank's final [`Clock`].
pub fn run_with_clocks<R: Send>(
    p: usize,
    model: CostModel,
    f: impl Fn(&mut RankCtx) -> R + Send + Sync,
) -> Vec<(R, Clock)> {
    assert!(p >= 1, "need at least one rank");
    let shared = new_shared(p, model);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..p)
            .map(|rank| {
                let shared = &shared;
                let f = &f;
                scope.spawn(move || {
                    let mut ctx = RankCtx { rank, size: p, shared, clock: Clock::new() };
                    let out = f(&mut ctx);
                    (out, ctx.clock)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(v) => v,
                Err(e) => std::panic::resume_unwind(e),
            })
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_sum_exact() {
        let results = run(4, CostModel::free(), |ctx| {
            let mine = vec![ctx.rank() as f64, 1.0];
            ctx.allreduce(&mine, Op::Sum)
        });
        for r in &results {
            assert_eq!(r, &vec![0.0 + 1.0 + 2.0 + 3.0, 4.0]);
        }
    }

    #[test]
    fn allreduce_max_min() {
        let results = run(3, CostModel::free(), |ctx| {
            let x = (ctx.rank() as f64 - 1.0) * 2.5;
            (ctx.allreduce_scalar(x, Op::Max), ctx.allreduce_scalar(x, Op::Min))
        });
        for (mx, mn) in &results {
            assert_eq!(*mx, 2.5);
            assert_eq!(*mn, -2.5);
        }
    }

    #[test]
    fn allreduce_inplace_matches_allocating() {
        let results = run(4, CostModel::free(), |ctx| {
            let mine: Vec<f64> = (0..6).map(|j| (ctx.rank() * 10 + j) as f64).collect();
            let alloc = ctx.allreduce(&mine, Op::Sum);
            let mut inplace = mine;
            ctx.allreduce_inplace(&mut inplace, Op::Sum);
            (alloc, inplace)
        });
        for (alloc, inplace) in &results {
            assert_eq!(alloc, inplace);
        }
    }

    #[test]
    fn broadcast_from_nonzero_root() {
        let results = run(4, CostModel::free(), |ctx| {
            let payload = (ctx.rank() == 2).then(|| vec![7.0, 8.0, 9.0]);
            ctx.broadcast(2, payload)
        });
        for r in &results {
            assert_eq!(r, &vec![7.0, 8.0, 9.0]);
        }
    }

    #[test]
    #[should_panic(expected = "non-root rank 1 passed Some")]
    fn broadcast_nonroot_some_panics_everywhere() {
        // the ISSUE-2 bug: non-root Some + root None used to hang the
        // group; now every rank panics with a rank-tagged message
        run(3, CostModel::free(), |ctx| {
            let payload = (ctx.rank() == 1).then(|| vec![1.0]);
            ctx.broadcast(0, payload)
        });
    }

    #[test]
    #[should_panic(expected = "root rank 0 provided no payload")]
    fn broadcast_root_none_panics_everywhere() {
        run(3, CostModel::free(), |ctx| {
            let _ = ctx.rank();
            ctx.broadcast(0, None)
        });
    }

    #[test]
    fn allgather_preserves_rank_order() {
        let results = run(3, CostModel::free(), |ctx| ctx.allgather(&[ctx.rank() as f64]));
        for r in &results {
            assert_eq!(r, &vec![vec![0.0], vec![1.0], vec![2.0]]);
        }
    }

    #[test]
    fn gather_lands_on_root_only() {
        let results = run(4, CostModel::free(), |ctx| {
            let mine = vec![ctx.rank() as f64; ctx.rank() + 1]; // ragged parts
            ctx.gather(2, &mine)
        });
        for (rank, r) in results.iter().enumerate() {
            if rank == 2 {
                let parts = r.as_ref().expect("root receives");
                assert_eq!(parts.len(), 4);
                for (i, part) in parts.iter().enumerate() {
                    assert_eq!(part, &vec![i as f64; i + 1]);
                }
            } else {
                assert!(r.is_none(), "rank {rank} must not receive");
            }
        }
    }

    #[test]
    fn reduce_lands_on_root_only() {
        let results = run(4, CostModel::free(), |ctx| {
            ctx.reduce(1, &[ctx.rank() as f64, 1.0], Op::Sum)
        });
        for (rank, r) in results.iter().enumerate() {
            if rank == 1 {
                assert_eq!(r.as_ref().unwrap(), &vec![6.0, 4.0]);
            } else {
                assert!(r.is_none());
            }
        }
    }

    #[test]
    fn reduce_scatter_block_distributes_the_reduction() {
        let results = run(3, CostModel::free(), |ctx| {
            // rank r contributes [r, r, r, r, r, r]
            let mine = vec![ctx.rank() as f64; 6];
            ctx.reduce_scatter_block(&mine, Op::Sum)
        });
        // reduction is [3, 3, 3, 3, 3, 3]; each rank gets its 2-block
        for r in &results {
            assert_eq!(r, &vec![3.0, 3.0]);
        }
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn reduce_scatter_ragged_length_panics_without_deadlock() {
        // rank 0 misuses the collective; every rank must panic (the
        // validation rides the exchange) instead of rank 1 hanging
        run(2, CostModel::free(), |ctx| {
            let mine = vec![1.0; if ctx.rank() == 0 { 3 } else { 4 }];
            ctx.reduce_scatter_block(&mine, Op::Sum)
        });
    }

    #[test]
    fn barrier_and_slot_reuse() {
        // exercise slot reuse across many rounds and mixed primitives
        let results = run(4, CostModel::free(), |ctx| {
            let mut acc = 0.0;
            for round in 0..20 {
                acc += ctx.allreduce_scalar((ctx.rank() + round) as f64, Op::Sum);
                ctx.barrier();
            }
            acc
        });
        let expect: f64 = (0..20).map(|r| (0..4).map(|k| (k + r) as f64).sum::<f64>()).sum();
        for r in &results {
            assert_eq!(*r, expect);
        }
    }

    #[test]
    fn deterministic_sum_order() {
        // results must be identical across repeated runs (rank-ordered fold)
        let vals = [1e16, 1.0, -1e16, 3.0];
        let run_once = || {
            run(4, CostModel::free(), |ctx| ctx.allreduce_scalar(vals[ctx.rank()], Op::Sum))[0]
        };
        let first = run_once();
        for _ in 0..5 {
            assert_eq!(run_once(), first);
        }
    }

    #[test]
    fn clocks_sync_at_collectives() {
        let results = run_with_clocks(2, CostModel::shared_memory(), |ctx| {
            if ctx.rank() == 0 {
                ctx.charge(Category::Compute, 1.0);
            } else {
                ctx.charge(Category::Compute, 3.0);
            }
            ctx.allreduce_scalar(1.0, Op::Sum);
            ctx.clock().now()
        });
        // both ranks end at >= 3.0 (max entry) and equal virtual time
        let t0 = results[0].0;
        let t1 = results[1].0;
        assert!(t0 >= 3.0 && (t0 - t1).abs() < 1e-12, "{t0} vs {t1}");
        // rank 0 waited ~2s in comm
        assert!(results[0].1.in_category(Category::Comm) >= 2.0);
    }

    #[test]
    fn single_rank_works() {
        let results = run(1, CostModel::shared_memory(), |ctx| {
            ctx.barrier();
            assert_eq!(ctx.gather(0, &[3.0]).unwrap(), vec![vec![3.0]]);
            ctx.allreduce_scalar(5.0, Op::Sum)
        });
        assert_eq!(results, vec![5.0]);
    }

    #[test]
    fn timed_charges_cpu() {
        let results = run_with_clocks(2, CostModel::free(), |ctx| {
            ctx.timed(Category::Learn, || {
                let mut acc = 0u64;
                for i in 0..500_000u64 {
                    acc = acc.wrapping_add(i * i);
                }
                std::hint::black_box(acc)
            });
            ctx.clock().in_category(Category::Learn)
        });
        for (learn, _) in &results {
            assert!(*learn > 0.0);
        }
    }
}

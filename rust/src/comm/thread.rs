//! Shared-board thread transport: exact collectives between rank
//! threads of one process.
//!
//! [`run`] spawns `p` rank threads executing the same closure (the MPI
//! model of the paper, Sec. III.A). Ranks synchronize through
//! [`RankCtx`] collectives backed by a shared contribution board: each
//! rank posts its payload, waits at a poisonable rendezvous, combines
//! all contributions *in rank order* through the shared
//! [`fold`](super::communicator::fold) kernels (bitwise-deterministic
//! results), then passes a second rendezvous before slots are reused.
//!
//! Failure semantics ride the board:
//!
//! * [`Communicator::abort`] **poisons** the rendezvous — every rank
//!   parked at (or later entering) any collective wakes immediately
//!   with [`CommError::RemoteAbort`] carrying the origin rank, instead
//!   of waiting forever for a contribution that will never come.
//! * Contract validation happens *after* the exchange (`broadcast`'s
//!   provided-payload flag, `reduce_scatter_block`'s length check), so
//!   a rank that breaks the contract makes *every* rank return the
//!   same [`CommError::ContractViolation`] — a local assert would
//!   leave the compliant ranks parked at the rendezvous.
//! * An optional deadline ([`run_with_clocks_timeout`]) turns a peer
//!   that never arrives into [`CommError::Timeout`] rather than an
//!   indefinite block.
//! * A genuine panic in rank code poisons the board before propagating
//!   with its original payload, so sibling ranks fail fast instead of
//!   deadlocking the join.

use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::clock::{Category, Clock};
use super::communicator::{fold, Communicator, Op};
use super::costmodel::CostModel;
use super::error::{CommError, CommResult};
use crate::obs::Tracer;
use crate::util::panic::panic_text;

struct BoardState {
    /// ranks arrived at the current rendezvous generation
    arrived: usize,
    /// bumped when a full rendezvous completes
    generation: u64,
    /// first abort wins; once set, every wait returns it immediately
    poison: Option<CommError>,
}

/// Poisonable all-rank rendezvous (a `std::sync::Barrier` cannot be
/// woken early, which is exactly the hang this transport must avoid).
/// Also the node-local rendezvous of the hierarchical transport
/// ([`super::hier`]), which runs one board per node.
pub(crate) struct Board {
    state: Mutex<BoardState>,
    cv: Condvar,
    size: usize,
}

impl Board {
    pub(crate) fn new(size: usize) -> Board {
        Board {
            state: Mutex::new(BoardState { arrived: 0, generation: 0, poison: None }),
            cv: Condvar::new(),
            size,
        }
    }

    /// Rendezvous of all ranks. Fails fast if the board is (or becomes)
    /// poisoned, or when `timeout` elapses before every peer arrives.
    pub(crate) fn wait(&self, rank: usize, timeout: Option<Duration>) -> CommResult<()> {
        let deadline = timeout.map(|t| Instant::now() + t);
        let mut s = self.state.lock().unwrap();
        if let Some(e) = &s.poison {
            return Err(e.clone());
        }
        s.arrived += 1;
        if s.arrived == self.size {
            s.arrived = 0;
            s.generation = s.generation.wrapping_add(1);
            self.cv.notify_all();
            return Ok(());
        }
        let gen = s.generation;
        loop {
            s = match deadline {
                None => self.cv.wait(s).unwrap(),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        // withdraw this rank's arrival: a late peer must
                        // not be able to complete the rendezvous against
                        // a rank that has already given up on it (the
                        // generation is unchanged under this lock, so
                        // the increment is still ours to take back)
                        s.arrived -= 1;
                        return Err(self.timeout_error(rank, timeout));
                    }
                    self.cv.wait_timeout(s, d - now).unwrap().0
                }
            };
            if let Some(e) = &s.poison {
                return Err(e.clone());
            }
            if s.generation != gen {
                return Ok(());
            }
        }
    }

    fn timeout_error(&self, rank: usize, timeout: Option<Duration>) -> CommError {
        CommError::Timeout {
            rank,
            seconds: timeout.map_or(0.0, |t| t.as_secs_f64()),
            waiting_for: format!("{} peer rank(s) at the collective rendezvous", self.size - 1),
        }
    }

    /// Poison the board (first abort wins) and wake every waiter.
    /// Returns the canonical group abort.
    pub(crate) fn poison(&self, err: CommError) -> CommError {
        let mut s = self.state.lock().unwrap();
        let out = s.poison.get_or_insert(err).clone();
        self.cv.notify_all();
        out
    }
}

struct Shared {
    /// per-rank contribution slots for the active collective
    slots: Vec<Mutex<Vec<f64>>>,
    /// per-rank virtual-time postings for clock synchronization
    times: Vec<Mutex<f64>>,
    board: Board,
    model: CostModel,
    timeout: Option<Duration>,
}

/// Per-rank handle of the shared-board thread transport.
pub struct RankCtx<'a> {
    rank: usize,
    size: usize,
    shared: &'a Shared,
    clock: Clock,
    /// first failure observed on this handle; subsequent collectives
    /// fail fast with it instead of touching a board the rank has
    /// already fallen out of lockstep with
    failed: Option<CommError>,
    /// per-rank span recorder (default-off; see [`crate::obs`])
    tracer: Tracer,
}

impl<'a> RankCtx<'a> {
    /// Post this rank's payload + clock, rendezvous with all, then
    /// combine every rank's payload in rank order with `combine`.
    /// Advances clocks to max-entry + modeled cost. Fails with the
    /// group abort if the board is poisoned at either rendezvous, and
    /// fail-fast once this handle has observed any failure.
    ///
    /// Every exit that performed an exchange closes a tracer comm
    /// record (primitive, bytes, wait split, α–β prediction); only the
    /// fail-fast entry records nothing, because no exchange happened.
    /// The wait split is the time from entry to the first rendezvous
    /// completing (peers arriving); everything after is local
    /// combine + slot-reuse handshake.
    fn collective<T>(
        &mut self,
        primitive: &'static str,
        bytes: usize,
        payload: Vec<f64>,
        modeled_cost: f64,
        combine: impl FnOnce(&[Vec<f64>]) -> CommResult<T>,
    ) -> CommResult<T> {
        if let Some(e) = &self.failed {
            return Err(e.clone());
        }
        let cs = self.tracer.comm_start();
        *self.shared.slots[self.rank].lock().unwrap() = payload;
        *self.shared.times[self.rank].lock().unwrap() = self.clock.now();
        if let Err(e) = self.shared.board.wait(self.rank, self.shared.timeout) {
            let wait_s = self.tracer.elapsed_since(cs);
            self.tracer.comm_record(cs, primitive, bytes, modeled_cost, wait_s);
            self.failed = Some(e.clone());
            return Err(e);
        }
        let wait_s = self.tracer.elapsed_since(cs);

        // every rank reads all contributions; rank-ordered combine
        let contributions: Vec<Vec<f64>> = (0..self.size)
            .map(|i| self.shared.slots[i].lock().unwrap().clone())
            .collect();
        let max_entry = (0..self.size)
            .map(|i| *self.shared.times[i].lock().unwrap())
            .fold(0.0, f64::max);
        let out = combine(&contributions);

        // second rendezvous: nobody reuses slots until everyone has
        // read. A contract violation from `combine` is deterministic —
        // every rank derives the same error from the same board state —
        // so the group stays in lockstep either way; the combine error
        // takes display precedence over a racing poison.
        let wait2 = self.shared.board.wait(self.rank, self.shared.timeout);
        self.clock.sync_to(max_entry + modeled_cost);
        self.tracer.comm_record(cs, primitive, bytes, modeled_cost, wait_s);
        let result = match (out, wait2) {
            (Err(e), _) | (Ok(_), Err(e)) => Err(e),
            (Ok(v), Ok(())) => Ok(v),
        };
        if let Err(e) = &result {
            self.failed = Some(e.clone());
        }
        result
    }
}

impl Communicator for RankCtx<'_> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn clock(&self) -> &Clock {
        &self.clock
    }

    fn charge(&mut self, category: Category, seconds: f64) {
        self.clock.add(category, seconds);
    }

    fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    fn tracer_mut(&mut self) -> &mut Tracer {
        &mut self.tracer
    }

    fn allreduce_inplace(&mut self, data: &mut [f64], op: Op) -> CommResult<()> {
        let bytes = data.len() * 8;
        let cost = self.shared.model.allreduce(self.size, bytes);
        let rank = self.rank;
        let payload = data.to_vec(); // the board keeps its own copy
        self.collective("allreduce", bytes, payload, cost, |parts| {
            if let Some(e) = fold::length_violation("allreduce", rank, parts) {
                return Err(e);
            }
            fold::reduce_into(parts, data, op);
            Ok(())
        })
    }

    fn broadcast(&mut self, root: usize, data: Option<Vec<f64>>) -> CommResult<Vec<f64>> {
        self.check_root("broadcast", root)?;
        let rank = self.rank;
        // A provided-payload flag travels with the data so contract
        // violations surface as the same typed error on every rank
        // after the exchange, not as a deadlock at the rendezvous.
        let provided = data.is_some();
        let data_bytes = data.as_ref().map_or(0, |d| d.len() * 8);
        let mut payload = vec![if provided { 1.0 } else { 0.0 }];
        if let Some(d) = data {
            payload.extend_from_slice(&d);
        }
        let cost = self.shared.model.broadcast(self.size, data_bytes);
        self.collective("broadcast", data_bytes, payload, cost, |parts| {
            let flags: Vec<bool> = parts.iter().map(|p| p.first() == Some(&1.0)).collect();
            if let Some(e) = fold::broadcast_violation(root, &flags, rank) {
                return Err(e);
            }
            Ok(parts[root][1..].to_vec())
        })
    }

    fn allgather(&mut self, data: &[f64]) -> CommResult<Vec<Vec<f64>>> {
        let bytes = data.len() * 8 * self.size;
        let cost = self.shared.model.allgather(self.size, bytes);
        self.collective("allgather", bytes, data.to_vec(), cost, |parts| Ok(parts.to_vec()))
    }

    fn gather(&mut self, root: usize, data: &[f64]) -> CommResult<Option<Vec<Vec<f64>>>> {
        self.check_root("gather", root)?;
        let bytes = data.len() * 8 * self.size;
        let cost = self.shared.model.gather(self.size, bytes);
        let rank = self.rank;
        self.collective("gather", bytes, data.to_vec(), cost, |parts| {
            Ok((rank == root).then(|| parts.to_vec()))
        })
    }

    fn reduce(&mut self, root: usize, data: &[f64], op: Op) -> CommResult<Option<Vec<f64>>> {
        self.check_root("reduce", root)?;
        let bytes = data.len() * 8;
        let cost = self.shared.model.reduce(self.size, bytes);
        let rank = self.rank;
        self.collective("reduce", bytes, data.to_vec(), cost, |parts| {
            if let Some(e) = fold::length_violation("reduce", rank, parts) {
                return Err(e);
            }
            Ok((rank == root).then(|| fold::reduce_parts(parts, op)))
        })
    }

    fn reduce_scatter_block(&mut self, data: &[f64], op: Op) -> CommResult<Vec<f64>> {
        let bytes = data.len() * 8;
        let cost = self.shared.model.reduce_scatter(self.size, bytes);
        let (rank, size) = (self.rank, self.size);
        // length validation happens after the exchange, over every
        // rank's part: a rank with a ragged (or indivisible) length
        // must fail the whole group with the same typed error, not park
        // the compliant ranks forever at the rendezvous (same rationale
        // as broadcast's provided-payload flag)
        self.collective("reduce_scatter", bytes, data.to_vec(), cost, |parts| {
            if let Some(e) = fold::divisibility_violation(parts, size, rank) {
                return Err(e);
            }
            if let Some(e) = fold::length_violation("reduce_scatter_block", rank, parts) {
                return Err(e);
            }
            let reduced = fold::reduce_parts(parts, op);
            Ok(fold::block(&reduced, rank, size))
        })
    }

    fn barrier(&mut self) -> CommResult<()> {
        let cost = self.shared.model.barrier(self.size);
        self.collective("barrier", 0, Vec::new(), cost, |_| Ok(()))
    }

    fn abort(&mut self, message: &str) -> CommError {
        self.shared.board.poison(CommError::RemoteAbort {
            origin_rank: self.rank,
            message: message.to_string(),
        })
    }
}

fn new_shared(p: usize, model: CostModel, timeout: Option<Duration>) -> Shared {
    Shared {
        slots: (0..p).map(|_| Mutex::new(Vec::new())).collect(),
        times: (0..p).map(|_| Mutex::new(0.0)).collect(),
        board: Board::new(p),
        model,
        timeout,
    }
}

/// Spawn `p` rank threads running `f` and return the per-rank results in
/// rank order. Panics in any rank poison the board (siblings wake with
/// [`CommError::RemoteAbort`]) and then propagate with their original
/// payload.
pub fn run<R: Send>(
    p: usize,
    model: CostModel,
    f: impl Fn(&mut RankCtx) -> R + Send + Sync,
) -> Vec<R> {
    run_with_clocks(p, model, f).into_iter().map(|(out, _)| out).collect()
}

/// Like [`run`], but also returns each rank's final [`Clock`].
pub fn run_with_clocks<R: Send>(
    p: usize,
    model: CostModel,
    f: impl Fn(&mut RankCtx) -> R + Send + Sync,
) -> Vec<(R, Clock)> {
    run_with_clocks_timeout(p, model, None, f)
}

/// Like [`run_with_clocks`], with an optional per-rendezvous deadline:
/// a peer that never enters a collective yields [`CommError::Timeout`]
/// on the waiting ranks instead of blocking indefinitely.
pub fn run_with_clocks_timeout<R: Send>(
    p: usize,
    model: CostModel,
    timeout: Option<Duration>,
    f: impl Fn(&mut RankCtx) -> R + Send + Sync,
) -> Vec<(R, Clock)> {
    assert!(p >= 1, "need at least one rank");
    let shared = new_shared(p, model, timeout);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..p)
            .map(|rank| {
                let shared = &shared;
                let f = &f;
                scope.spawn(move || {
                    let mut ctx = RankCtx {
                        rank,
                        size: p,
                        shared,
                        clock: Clock::new(),
                        failed: None,
                        tracer: Tracer::new(rank),
                    };
                    // a genuine panic must poison the board before
                    // propagating: siblings parked at a collective would
                    // otherwise never be joinable
                    let out =
                        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut ctx)));
                    match out {
                        Ok(v) => (v, ctx.clock),
                        Err(payload) => {
                            ctx.abort(&format!("rank {rank} panicked: {}", panic_text(&payload)));
                            std::panic::resume_unwind(payload);
                        }
                    }
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(v) => v,
                Err(e) => std::panic::resume_unwind(e),
            })
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_sum_exact() {
        let results = run(4, CostModel::free(), |ctx| {
            let mine = vec![ctx.rank() as f64, 1.0];
            ctx.allreduce(&mine, Op::Sum).unwrap()
        });
        for r in &results {
            assert_eq!(r, &vec![0.0 + 1.0 + 2.0 + 3.0, 4.0]);
        }
    }

    #[test]
    fn allreduce_max_min() {
        let results = run(3, CostModel::free(), |ctx| {
            let x = (ctx.rank() as f64 - 1.0) * 2.5;
            (
                ctx.allreduce_scalar(x, Op::Max).unwrap(),
                ctx.allreduce_scalar(x, Op::Min).unwrap(),
            )
        });
        for (mx, mn) in &results {
            assert_eq!(*mx, 2.5);
            assert_eq!(*mn, -2.5);
        }
    }

    #[test]
    fn allreduce_inplace_matches_allocating() {
        let results = run(4, CostModel::free(), |ctx| {
            let mine: Vec<f64> = (0..6).map(|j| (ctx.rank() * 10 + j) as f64).collect();
            let alloc = ctx.allreduce(&mine, Op::Sum).unwrap();
            let mut inplace = mine;
            ctx.allreduce_inplace(&mut inplace, Op::Sum).unwrap();
            (alloc, inplace)
        });
        for (alloc, inplace) in &results {
            assert_eq!(alloc, inplace);
        }
    }

    #[test]
    fn broadcast_from_nonzero_root() {
        let results = run(4, CostModel::free(), |ctx| {
            let payload = (ctx.rank() == 2).then(|| vec![7.0, 8.0, 9.0]);
            ctx.broadcast(2, payload).unwrap()
        });
        for r in &results {
            assert_eq!(r, &vec![7.0, 8.0, 9.0]);
        }
    }

    #[test]
    fn broadcast_nonroot_some_errors_everywhere() {
        // the ISSUE-2 bug lineage: non-root Some + root None used to
        // hang the group, then panicked; now every rank returns the
        // same typed ContractViolation
        let results = run(3, CostModel::free(), |ctx| {
            let payload = (ctx.rank() == 1).then(|| vec![1.0]);
            ctx.broadcast(0, payload)
        });
        for r in &results {
            match r {
                Err(CommError::ContractViolation { message, .. }) => {
                    assert!(message.contains("non-root rank 1 passed Some"), "{message}");
                }
                other => panic!("expected ContractViolation, got {other:?}"),
            }
        }
    }

    #[test]
    fn broadcast_root_none_errors_everywhere() {
        let results = run(3, CostModel::free(), |ctx| ctx.broadcast(0, None));
        for r in &results {
            match r {
                Err(CommError::ContractViolation { message, .. }) => {
                    assert!(message.contains("root rank 0 provided no payload"), "{message}");
                }
                other => panic!("expected ContractViolation, got {other:?}"),
            }
        }
    }

    #[test]
    fn allgather_preserves_rank_order() {
        let results =
            run(3, CostModel::free(), |ctx| ctx.allgather(&[ctx.rank() as f64]).unwrap());
        for r in &results {
            assert_eq!(r, &vec![vec![0.0], vec![1.0], vec![2.0]]);
        }
    }

    #[test]
    fn gather_lands_on_root_only() {
        let results = run(4, CostModel::free(), |ctx| {
            let mine = vec![ctx.rank() as f64; ctx.rank() + 1]; // ragged parts
            ctx.gather(2, &mine).unwrap()
        });
        for (rank, r) in results.iter().enumerate() {
            if rank == 2 {
                let parts = r.as_ref().expect("root receives");
                assert_eq!(parts.len(), 4);
                for (i, part) in parts.iter().enumerate() {
                    assert_eq!(part, &vec![i as f64; i + 1]);
                }
            } else {
                assert!(r.is_none(), "rank {rank} must not receive");
            }
        }
    }

    #[test]
    fn reduce_lands_on_root_only() {
        let results = run(4, CostModel::free(), |ctx| {
            ctx.reduce(1, &[ctx.rank() as f64, 1.0], Op::Sum).unwrap()
        });
        for (rank, r) in results.iter().enumerate() {
            if rank == 1 {
                assert_eq!(r.as_ref().unwrap(), &vec![6.0, 4.0]);
            } else {
                assert!(r.is_none());
            }
        }
    }

    #[test]
    fn reduce_scatter_block_distributes_the_reduction() {
        let results = run(3, CostModel::free(), |ctx| {
            // rank r contributes [r, r, r, r, r, r]
            let mine = vec![ctx.rank() as f64; 6];
            ctx.reduce_scatter_block(&mine, Op::Sum).unwrap()
        });
        // reduction is [3, 3, 3, 3, 3, 3]; each rank gets its 2-block
        for r in &results {
            assert_eq!(r, &vec![3.0, 3.0]);
        }
    }

    #[test]
    fn reduce_scatter_ragged_length_errors_without_deadlock() {
        // rank 0 misuses the collective; every rank must observe the
        // violation (the validation rides the exchange) instead of
        // rank 1 hanging
        let results = run(2, CostModel::free(), |ctx| {
            let mine = vec![1.0; if ctx.rank() == 0 { 3 } else { 4 }];
            ctx.reduce_scatter_block(&mine, Op::Sum)
        });
        for r in &results {
            match r {
                Err(CommError::ContractViolation { message, .. }) => {
                    assert!(message.contains("not divisible"), "{message}");
                }
                other => panic!("expected ContractViolation, got {other:?}"),
            }
        }
    }

    #[test]
    fn abort_wakes_ranks_parked_at_a_collective() {
        // rank 1 fails locally and aborts; ranks 0 and 2 are parked at
        // an allreduce rendezvous and must wake with the rank-tagged
        // RemoteAbort — this is the hang the redesign exists to fix
        let results = run(3, CostModel::free(), |ctx| {
            if ctx.rank() == 1 {
                Err(ctx.abort("injected disk failure"))
            } else {
                // the group is poisoned: this must come back Err
                ctx.allreduce_scalar(1.0, Op::Sum).map(|_| ())
            }
        });
        for r in &results {
            match r {
                Err(CommError::RemoteAbort { origin_rank, message }) => {
                    assert_eq!(*origin_rank, 1);
                    assert!(message.contains("injected disk failure"));
                }
                other => panic!("expected RemoteAbort, got {other:?}"),
            }
        }
    }

    #[test]
    fn abort_is_idempotent_and_first_wins() {
        let results = run(2, CostModel::free(), |ctx| {
            if ctx.rank() == 0 {
                let first = ctx.abort("first failure");
                let second = ctx.abort("second failure");
                (first, second)
            } else {
                // rank 1 parks until the poison lands, then also aborts:
                // it must receive rank 0's original error back
                let woken = ctx.barrier().unwrap_err();
                let follow_up = ctx.abort("rank 1 follow-up");
                (woken, follow_up)
            }
        });
        for (a, b) in &results {
            assert_eq!(a, b, "abort must be idempotent");
            match a {
                CommError::RemoteAbort { origin_rank, message } => {
                    assert_eq!(*origin_rank, 0);
                    assert!(message.contains("first failure"));
                }
                other => panic!("expected RemoteAbort, got {other:?}"),
            }
        }
    }

    #[test]
    fn poisoned_board_fails_every_subsequent_collective() {
        let results = run(2, CostModel::free(), |ctx| {
            if ctx.rank() == 0 {
                ctx.abort("dead");
            }
            let a = ctx.allreduce_scalar(1.0, Op::Sum);
            let b = ctx.barrier();
            (a.is_err(), b.is_err())
        });
        for (a, b) in &results {
            assert!(a && b);
        }
    }

    #[test]
    fn deadline_turns_a_missing_peer_into_timeout() {
        // rank 1 returns without ever entering the collective; rank 0
        // must time out rather than block forever — and once timed out,
        // the handle is failed: later collectives fail fast with the
        // same error instead of touching the desynced board
        let results =
            run_with_clocks_timeout(2, CostModel::free(), Some(Duration::from_millis(150)), |ctx| {
                if ctx.rank() == 0 {
                    let first = ctx.allreduce_scalar(1.0, Op::Sum).unwrap_err();
                    let second = ctx.barrier().unwrap_err();
                    assert_eq!(first, second, "failed handle must fail fast");
                    Err(first)
                } else {
                    Ok(())
                }
            });
        match &results[0].0 {
            Err(CommError::Timeout { rank, seconds, .. }) => {
                assert_eq!(*rank, 0);
                assert!(*seconds > 0.0);
            }
            other => panic!("expected Timeout, got {other:?}"),
        }
        assert!(results[1].0.is_ok());
    }

    #[test]
    fn late_peer_cannot_complete_a_rendezvous_the_waiter_abandoned() {
        // rank 0 times out and *withdraws* its arrival; rank 1 enters
        // the collective only after that (gated on an explicit signal,
        // not wall-clock) and must not be able to complete the
        // rendezvous against the stale arrival (silently combining old
        // slot data) — it parks and times out too
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let (tx, rx) = (std::sync::Mutex::new(tx), std::sync::Mutex::new(rx));
        let results = run_with_clocks_timeout(
            2,
            CostModel::free(),
            Some(Duration::from_millis(120)),
            |ctx| {
                if ctx.rank() == 0 {
                    let out = ctx.allreduce_scalar(1.0, Op::Sum);
                    tx.lock().unwrap().send(()).ok();
                    out
                } else {
                    let _ = rx.lock().unwrap().recv();
                    ctx.allreduce_scalar(1.0, Op::Sum)
                }
            },
        );
        for (r, _) in &results {
            assert!(matches!(r, Err(CommError::Timeout { .. })), "{r:?}");
        }
    }

    #[test]
    fn rank_panic_poisons_siblings_then_propagates() {
        // rank 1 panics; rank 0 must wake from the collective with a
        // RemoteAbort (observed via a side channel, since run() itself
        // re-raises the original panic afterwards)
        let observed = std::sync::Mutex::new(None);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run(2, CostModel::free(), |ctx| {
                if ctx.rank() == 1 {
                    panic!("boom in rank code");
                }
                let got = ctx.allreduce_scalar(1.0, Op::Sum);
                *observed.lock().unwrap() = Some(got);
            })
        }));
        assert!(caught.is_err(), "the original panic must still propagate");
        match observed.into_inner().unwrap() {
            Some(Err(CommError::RemoteAbort { origin_rank: 1, message })) => {
                assert!(message.contains("boom in rank code"));
            }
            other => panic!("sibling should observe the panic as RemoteAbort, got {other:?}"),
        }
    }

    #[test]
    fn barrier_and_slot_reuse() {
        // exercise slot reuse across many rounds and mixed primitives
        let results = run(4, CostModel::free(), |ctx| {
            let mut acc = 0.0;
            for round in 0..20 {
                acc += ctx.allreduce_scalar((ctx.rank() + round) as f64, Op::Sum).unwrap();
                ctx.barrier().unwrap();
            }
            acc
        });
        let expect: f64 = (0..20).map(|r| (0..4).map(|k| (k + r) as f64).sum::<f64>()).sum();
        for r in &results {
            assert_eq!(*r, expect);
        }
    }

    #[test]
    fn deterministic_sum_order() {
        // results must be identical across repeated runs (rank-ordered fold)
        let vals = [1e16, 1.0, -1e16, 3.0];
        let run_once = || {
            run(4, CostModel::free(), |ctx| {
                ctx.allreduce_scalar(vals[ctx.rank()], Op::Sum).unwrap()
            })[0]
        };
        let first = run_once();
        for _ in 0..5 {
            assert_eq!(run_once(), first);
        }
    }

    #[test]
    fn clocks_sync_at_collectives() {
        let results = run_with_clocks(2, CostModel::shared_memory(), |ctx| {
            if ctx.rank() == 0 {
                ctx.charge(Category::Compute, 1.0);
            } else {
                ctx.charge(Category::Compute, 3.0);
            }
            ctx.allreduce_scalar(1.0, Op::Sum).unwrap();
            ctx.clock().now()
        });
        // both ranks end at >= 3.0 (max entry) and equal virtual time
        let t0 = results[0].0;
        let t1 = results[1].0;
        assert!(t0 >= 3.0 && (t0 - t1).abs() < 1e-12, "{t0} vs {t1}");
        // rank 0 waited ~2s in comm
        assert!(results[0].1.in_category(Category::Comm) >= 2.0);
    }

    #[test]
    fn single_rank_works() {
        let results = run(1, CostModel::shared_memory(), |ctx| {
            ctx.barrier().unwrap();
            assert_eq!(ctx.gather(0, &[3.0]).unwrap().unwrap(), vec![vec![3.0]]);
            ctx.allreduce_scalar(5.0, Op::Sum).unwrap()
        });
        assert_eq!(results, vec![5.0]);
    }

    #[test]
    fn root_out_of_range_is_a_local_contract_error() {
        let results = run(2, CostModel::free(), |ctx| {
            // no exchange happens: the error is local and identical on
            // every rank, so nobody parks
            let _ = ctx.rank();
            ctx.broadcast(7, None)
        });
        for r in &results {
            assert!(matches!(r, Err(CommError::ContractViolation { .. })), "{r:?}");
        }
    }

    #[test]
    fn traced_collectives_record_telemetry_per_rank() {
        let traces = run(2, CostModel::shared_memory(), |ctx| {
            ctx.tracer_mut().set_enabled(true);
            ctx.allreduce_scalar(ctx.rank() as f64, Op::Sum).unwrap();
            ctx.barrier().unwrap();
            ctx.tracer_mut().take()
        });
        for (rank, trace) in traces.iter().enumerate() {
            assert_eq!(trace.rank, rank);
            assert_eq!(trace.comm.len(), 2);
            let ar = &trace.comm[0];
            assert_eq!(ar.primitive, "allreduce");
            assert_eq!(ar.bytes, 8);
            // predicted cost is the α–β model the clock was charged with
            assert!((ar.predicted_s - CostModel::shared_memory().allreduce(2, 8)).abs() < 1e-18);
            assert!(ar.measured_s >= ar.wait_s);
            assert_eq!(trace.comm[1].primitive, "barrier");
        }
    }

    #[test]
    fn abort_closes_the_pending_collective_record() {
        // ranks parked at a collective when the abort lands must still
        // close their comm record — no open span in a failure trace
        let traces = run(2, CostModel::free(), |ctx| {
            ctx.tracer_mut().set_enabled(true);
            if ctx.rank() == 1 {
                ctx.abort("injected failure");
            } else {
                let _ = ctx.allreduce_scalar(1.0, Op::Sum);
            }
            ctx.tracer_mut().take()
        });
        assert_eq!(traces[0].comm.len(), 1, "rank 0's aborted allreduce must be recorded");
        assert!(traces[0].comm[0].measured_s >= 0.0);
        // fail-fast entries after the poison record nothing
        assert!(traces[1].comm.is_empty());
    }

    #[test]
    fn timed_charges_cpu() {
        let results = run_with_clocks(2, CostModel::free(), |ctx| {
            ctx.timed(Category::Learn, || {
                let mut acc = 0u64;
                for i in 0..500_000u64 {
                    acc = acc.wrapping_add(i * i);
                }
                std::hint::black_box(acc)
            });
            ctx.clock().in_category(Category::Learn)
        });
        for (learn, _) in &results {
            assert!(*learn > 0.0);
        }
    }
}

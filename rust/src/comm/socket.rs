//! Localhost socket transport: rank threads exchanging length-prefixed
//! frames over TCP, with rank 0 as the rendezvous hub.
//!
//! This backend proves the [`Communicator`] boundary is transport-real:
//! no shared memory crosses rank boundaries — every collective
//! round-trips through rank 0 as little-endian length-prefixed frames,
//! exactly the structure a multi-process / multi-node deployment needs
//! (swap `127.0.0.1` for a host list and the same protocol runs across
//! machines).
//!
//! ## Protocol
//!
//! Rank 0 binds an ephemeral listener; ranks 1..p connect and send a
//! 4-byte hello carrying their rank id. Each collective is one
//! request/reply round in strict lockstep:
//!
//! ```text
//! request (leaf → hub):  opcode u8 | op u8 | provided u8 | root u32 |
//!                        clock f64 | len u64 | payload f64 × len
//! reply   (hub → leaf):  max_entry f64 | n_parts u64 |
//!                        (len u64 | part f64 × len) × n_parts
//! ```
//!
//! The hub collects every rank's contribution **in rank order**,
//! validates that all ranks entered the same collective (mismatches
//! panic with both call sites named), reduces through the shared
//! [`fold`] kernels — so results are bitwise identical to the thread
//! backend — and replies with only what each rank needs: rooted
//! collectives (`gather`, `reduce`) ship data to the root alone, which
//! is precisely the traffic saving that motivates them over
//! allgather-then-discard.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};

use super::clock::{Category, Clock};
use super::communicator::{fold, Communicator, Op};
use super::costmodel::CostModel;

/// Collective opcode on the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum OpCode {
    Allreduce,
    Broadcast,
    Allgather,
    Gather,
    Reduce,
    ReduceScatter,
    Barrier,
}

impl OpCode {
    fn to_byte(self) -> u8 {
        match self {
            OpCode::Allreduce => 0,
            OpCode::Broadcast => 1,
            OpCode::Allgather => 2,
            OpCode::Gather => 3,
            OpCode::Reduce => 4,
            OpCode::ReduceScatter => 5,
            OpCode::Barrier => 6,
        }
    }

    fn from_byte(b: u8) -> OpCode {
        match b {
            0 => OpCode::Allreduce,
            1 => OpCode::Broadcast,
            2 => OpCode::Allgather,
            3 => OpCode::Gather,
            4 => OpCode::Reduce,
            5 => OpCode::ReduceScatter,
            6 => OpCode::Barrier,
            other => panic!("socket transport: corrupt frame (unknown opcode {other})"),
        }
    }
}

fn op_to_byte(op: Op) -> u8 {
    match op {
        Op::Sum => 0,
        Op::Max => 1,
        Op::Min => 2,
    }
}

fn op_from_byte(b: u8) -> Op {
    match b {
        0 => Op::Sum,
        1 => Op::Max,
        2 => Op::Min,
        other => panic!("socket transport: corrupt frame (unknown reduction op {other})"),
    }
}

// ---------------------------------------------------------------- frame I/O

fn read_bytes(stream: &mut TcpStream, buf: &mut [u8], from: &str) {
    stream
        .read_exact(buf)
        .unwrap_or_else(|e| panic!("socket transport: lost connection to {from}: {e}"));
}

fn read_u64(stream: &mut TcpStream, from: &str) -> u64 {
    let mut b = [0u8; 8];
    read_bytes(stream, &mut b, from);
    u64::from_le_bytes(b)
}

fn read_f64s(stream: &mut TcpStream, count: usize, from: &str) -> Vec<f64> {
    let mut raw = vec![0u8; count * 8];
    read_bytes(stream, &mut raw, from);
    raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect()
}

fn push_f64s(buf: &mut Vec<u8>, values: &[f64]) {
    for v in values {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

struct Request {
    code: OpCode,
    op: u8,
    provided: bool,
    root: usize,
    time: f64,
    payload: Vec<f64>,
}

fn write_request(
    stream: &mut TcpStream,
    code: OpCode,
    op: u8,
    provided: bool,
    root: usize,
    time: f64,
    payload: &[f64],
) {
    let mut buf = Vec::with_capacity(23 + payload.len() * 8);
    buf.push(code.to_byte());
    buf.push(op);
    buf.push(u8::from(provided));
    buf.extend_from_slice(&(root as u32).to_le_bytes());
    buf.extend_from_slice(&time.to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    push_f64s(&mut buf, payload);
    stream
        .write_all(&buf)
        .unwrap_or_else(|e| panic!("socket transport: lost connection to rank 0: {e}"));
}

fn read_request(stream: &mut TcpStream, from_rank: usize) -> Request {
    let from = format!("rank {from_rank}");
    let mut head = [0u8; 7];
    read_bytes(stream, &mut head, &from);
    let code = OpCode::from_byte(head[0]);
    let op = head[1];
    let provided = head[2] != 0;
    let root = u32::from_le_bytes(head[3..7].try_into().unwrap()) as usize;
    let mut t = [0u8; 8];
    read_bytes(stream, &mut t, &from);
    let time = f64::from_le_bytes(t);
    let len = read_u64(stream, &from) as usize;
    let payload = read_f64s(stream, len, &from);
    Request { code, op, provided, root, time, payload }
}

fn write_reply(stream: &mut TcpStream, max_entry: f64, parts: &[Vec<f64>], to_rank: usize) {
    let total: usize = parts.iter().map(|p| 8 + p.len() * 8).sum();
    let mut buf = Vec::with_capacity(16 + total);
    buf.extend_from_slice(&max_entry.to_le_bytes());
    buf.extend_from_slice(&(parts.len() as u64).to_le_bytes());
    for part in parts {
        buf.extend_from_slice(&(part.len() as u64).to_le_bytes());
        push_f64s(&mut buf, part);
    }
    stream
        .write_all(&buf)
        .unwrap_or_else(|e| panic!("socket transport: lost connection to rank {to_rank}: {e}"));
}

fn read_reply(stream: &mut TcpStream) -> (f64, Vec<Vec<f64>>) {
    let from = "rank 0 (did rank 0 abort?)";
    let mut t = [0u8; 8];
    read_bytes(stream, &mut t, from);
    let max_entry = f64::from_le_bytes(t);
    let n_parts = read_u64(stream, from) as usize;
    let parts = (0..n_parts)
        .map(|_| {
            let len = read_u64(stream, from) as usize;
            read_f64s(stream, len, from)
        })
        .collect();
    (max_entry, parts)
}

// ---------------------------------------------------------------- the hub

/// Compute every rank's reply parts for one collective. All reductions
/// go through [`fold`] in rank order — bitwise identical to the thread
/// backend by construction.
fn hub_replies(
    code: OpCode,
    op: u8,
    root: usize,
    provided: &[bool],
    parts: &[Vec<f64>],
    size: usize,
) -> Vec<Vec<Vec<f64>>> {
    match code {
        OpCode::Allreduce => {
            let reduced = fold::reduce_parts(parts, op_from_byte(op));
            (0..size).map(|_| vec![reduced.clone()]).collect()
        }
        OpCode::Broadcast => {
            for (i, &flag) in provided.iter().enumerate() {
                if i == root && !flag {
                    panic!("broadcast(root={root}) — root rank {root} provided no payload");
                }
                if i != root && flag {
                    panic!(
                        "broadcast(root={root}) — non-root rank {i} passed Some(..); \
                         only the root provides the payload"
                    );
                }
            }
            (0..size).map(|_| vec![parts[root].clone()]).collect()
        }
        OpCode::Allgather => (0..size).map(|_| parts.to_vec()).collect(),
        OpCode::Gather => (0..size)
            .map(|i| if i == root { parts.to_vec() } else { Vec::new() })
            .collect(),
        OpCode::Reduce => {
            let reduced = fold::reduce_parts(parts, op_from_byte(op));
            (0..size)
                .map(|i| if i == root { vec![reduced.clone()] } else { Vec::new() })
                .collect()
        }
        OpCode::ReduceScatter => {
            let reduced = fold::reduce_parts(parts, op_from_byte(op));
            (0..size).map(|i| vec![fold::block(&reduced, i, size)]).collect()
        }
        OpCode::Barrier => (0..size).map(|_| Vec::new()).collect(),
    }
}

enum Conn {
    /// rank 0: one stream per leaf, index i ↔ rank i + 1
    Hub { streams: Vec<TcpStream> },
    Leaf { stream: TcpStream },
}

/// Per-rank handle of the localhost socket transport.
pub struct SocketComm {
    rank: usize,
    size: usize,
    clock: Clock,
    model: CostModel,
    conn: Conn,
}

impl SocketComm {
    /// One collective round: contribute `payload`, receive this rank's
    /// reply parts plus the max clock entry time over all ranks.
    fn exchange(
        &mut self,
        code: OpCode,
        op: u8,
        provided: bool,
        root: usize,
        payload: Vec<f64>,
    ) -> (f64, Vec<Vec<f64>>) {
        let now = self.clock.now();
        match &mut self.conn {
            Conn::Leaf { stream } => {
                write_request(stream, code, op, provided, root, now, &payload);
                read_reply(stream)
            }
            Conn::Hub { streams } => {
                let mut times = vec![now];
                let mut provided_flags = vec![provided];
                let mut parts: Vec<Vec<f64>> = vec![payload];
                for (i, s) in streams.iter_mut().enumerate() {
                    let req = read_request(s, i + 1);
                    if req.code != code || req.root != root || req.op != op {
                        panic!(
                            "socket transport: collective mismatch — rank 0 entered \
                             {code:?}(root {root}), rank {} entered {:?}(root {})",
                            i + 1,
                            req.code,
                            req.root
                        );
                    }
                    times.push(req.time);
                    provided_flags.push(req.provided);
                    parts.push(req.payload);
                }
                let max_entry = times.iter().fold(0.0f64, |a, &b| a.max(b));
                let mut replies = hub_replies(code, op, root, &provided_flags, &parts, self.size);
                for (i, s) in streams.iter_mut().enumerate() {
                    write_reply(s, max_entry, &replies[i + 1], i + 1);
                }
                (max_entry, replies.swap_remove(0))
            }
        }
    }
}

impl Communicator for SocketComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn clock(&self) -> &Clock {
        &self.clock
    }

    fn charge(&mut self, category: Category, seconds: f64) {
        self.clock.add(category, seconds);
    }

    fn allreduce_inplace(&mut self, data: &mut [f64], op: Op) {
        let cost = self.model.allreduce(self.size, data.len() * 8);
        let (max_entry, mut parts) =
            self.exchange(OpCode::Allreduce, op_to_byte(op), true, 0, data.to_vec());
        let reduced = parts.pop().expect("allreduce reply");
        assert_eq!(reduced.len(), data.len(), "collective length mismatch across ranks");
        data.copy_from_slice(&reduced);
        self.clock.sync_to(max_entry + cost);
    }

    fn broadcast(&mut self, root: usize, data: Option<Vec<f64>>) -> Vec<f64> {
        assert!(root < self.size, "broadcast root {root} out of range (size {})", self.size);
        let provided = data.is_some();
        let data_bytes = data.as_ref().map_or(0, |d| d.len() * 8);
        let cost = self.model.broadcast(self.size, data_bytes);
        let (max_entry, mut parts) =
            self.exchange(OpCode::Broadcast, 0, provided, root, data.unwrap_or_default());
        let out = parts.pop().expect("broadcast reply");
        self.clock.sync_to(max_entry + cost);
        out
    }

    fn allgather(&mut self, data: &[f64]) -> Vec<Vec<f64>> {
        let cost = self.model.allgather(self.size, data.len() * 8 * self.size);
        let (max_entry, parts) = self.exchange(OpCode::Allgather, 0, true, 0, data.to_vec());
        self.clock.sync_to(max_entry + cost);
        parts
    }

    fn gather(&mut self, root: usize, data: &[f64]) -> Option<Vec<Vec<f64>>> {
        assert!(root < self.size, "gather root {root} out of range (size {})", self.size);
        let cost = self.model.gather(self.size, data.len() * 8 * self.size);
        let (max_entry, parts) = self.exchange(OpCode::Gather, 0, true, root, data.to_vec());
        self.clock.sync_to(max_entry + cost);
        (self.rank == root).then_some(parts)
    }

    fn reduce(&mut self, root: usize, data: &[f64], op: Op) -> Option<Vec<f64>> {
        assert!(root < self.size, "reduce root {root} out of range (size {})", self.size);
        let cost = self.model.reduce(self.size, data.len() * 8);
        let (max_entry, mut parts) =
            self.exchange(OpCode::Reduce, op_to_byte(op), true, root, data.to_vec());
        self.clock.sync_to(max_entry + cost);
        if self.rank == root {
            Some(parts.pop().expect("reduce reply"))
        } else {
            None
        }
    }

    fn reduce_scatter_block(&mut self, data: &[f64], op: Op) -> Vec<f64> {
        assert_eq!(
            data.len() % self.size,
            0,
            "rank {}: reduce_scatter_block length {} not divisible by p = {}",
            self.rank,
            data.len(),
            self.size
        );
        let cost = self.model.reduce_scatter(self.size, data.len() * 8);
        let (max_entry, mut parts) =
            self.exchange(OpCode::ReduceScatter, op_to_byte(op), true, 0, data.to_vec());
        self.clock.sync_to(max_entry + cost);
        parts.pop().expect("reduce_scatter_block reply")
    }

    fn barrier(&mut self) {
        let cost = self.model.barrier(self.size);
        let (max_entry, _) = self.exchange(OpCode::Barrier, 0, true, 0, Vec::new());
        self.clock.sync_to(max_entry + cost);
    }
}

// ---------------------------------------------------------------- runners

/// Spawn `p` rank threads connected over localhost TCP and return the
/// per-rank results in rank order. Panics in any rank propagate with
/// their original payload (a hub panic surfaces on rank 0; leaves then
/// fail their reads and abort too — no deadlock).
pub fn run<R: Send>(
    p: usize,
    model: CostModel,
    f: impl Fn(&mut SocketComm) -> R + Send + Sync,
) -> Vec<R> {
    run_with_clocks(p, model, f).into_iter().map(|(out, _)| out).collect()
}

/// Like [`run`], but also returns each rank's final [`Clock`].
pub fn run_with_clocks<R: Send>(
    p: usize,
    model: CostModel,
    f: impl Fn(&mut SocketComm) -> R + Send + Sync,
) -> Vec<(R, Clock)> {
    assert!(p >= 1, "need at least one rank");
    let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind rendezvous listener");
    let port = listener.local_addr().expect("listener addr").port();
    std::thread::scope(|scope| {
        let f = &f;
        let mut handles = Vec::with_capacity(p);
        handles.push(scope.spawn(move || {
            // rank 0: accept every leaf, slotting streams by rank id
            let mut slots: Vec<Option<TcpStream>> = (1..p).map(|_| None).collect();
            for _ in 1..p {
                let (mut s, _) = listener.accept().expect("accept leaf rank");
                s.set_nodelay(true).ok();
                let mut hello = [0u8; 4];
                read_bytes(&mut s, &mut hello, "connecting leaf");
                let peer = u32::from_le_bytes(hello) as usize;
                assert!(peer >= 1 && peer < p, "socket transport: bad hello rank {peer}");
                assert!(
                    slots[peer - 1].replace(s).is_none(),
                    "socket transport: duplicate hello from rank {peer}"
                );
            }
            let streams: Vec<TcpStream> = slots.into_iter().map(|s| s.unwrap()).collect();
            let mut ctx =
                SocketComm { rank: 0, size: p, clock: Clock::new(), model, conn: Conn::Hub { streams } };
            let out = f(&mut ctx);
            (out, ctx.clock)
        }));
        for rank in 1..p {
            handles.push(scope.spawn(move || {
                let mut stream =
                    TcpStream::connect(("127.0.0.1", port)).expect("connect to rank 0");
                stream.set_nodelay(true).ok();
                stream.write_all(&(rank as u32).to_le_bytes()).expect("send hello");
                let mut ctx = SocketComm {
                    rank,
                    size: p,
                    clock: Clock::new(),
                    model,
                    conn: Conn::Leaf { stream },
                };
                let out = f(&mut ctx);
                (out, ctx.clock)
            }));
        }
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(v) => v,
                Err(e) => std::panic::resume_unwind(e),
            })
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::thread;

    #[test]
    fn allreduce_sum_exact() {
        let results = run(4, CostModel::free(), |ctx| {
            ctx.allreduce(&[ctx.rank() as f64, 1.0], Op::Sum)
        });
        for r in &results {
            assert_eq!(r, &vec![6.0, 4.0]);
        }
    }

    #[test]
    fn broadcast_from_nonzero_root() {
        let results = run(4, CostModel::free(), |ctx| {
            let payload = (ctx.rank() == 2).then(|| vec![7.0, 8.0, 9.0]);
            ctx.broadcast(2, payload)
        });
        for r in &results {
            assert_eq!(r, &vec![7.0, 8.0, 9.0]);
        }
    }

    #[test]
    #[should_panic(expected = "non-root rank 2 passed Some")]
    fn broadcast_nonroot_some_panics() {
        run(3, CostModel::free(), |ctx| {
            let payload = (ctx.rank() == 2).then(|| vec![1.0]);
            ctx.broadcast(0, payload)
        });
    }

    #[test]
    fn allgather_and_gather_preserve_rank_order() {
        let results = run(3, CostModel::free(), |ctx| {
            let mine = vec![ctx.rank() as f64; ctx.rank() + 1];
            (ctx.allgather(&mine), ctx.gather(1, &mine))
        });
        for (rank, (all, rooted)) in results.iter().enumerate() {
            assert_eq!(all, &vec![vec![0.0], vec![1.0, 1.0], vec![2.0, 2.0, 2.0]]);
            if rank == 1 {
                assert_eq!(rooted.as_ref().unwrap(), all);
            } else {
                assert!(rooted.is_none());
            }
        }
    }

    #[test]
    fn reduce_and_reduce_scatter() {
        let results = run(4, CostModel::free(), |ctx| {
            let mine = vec![ctx.rank() as f64; 8];
            (ctx.reduce(3, &mine, Op::Max), ctx.reduce_scatter_block(&mine, Op::Sum))
        });
        for (rank, (reduced, scattered)) in results.iter().enumerate() {
            assert_eq!(scattered, &vec![6.0, 6.0]);
            if rank == 3 {
                assert_eq!(reduced.as_ref().unwrap(), &vec![3.0; 8]);
            } else {
                assert!(reduced.is_none());
            }
        }
    }

    #[test]
    fn sequence_of_collectives_stays_in_lockstep() {
        let results = run(4, CostModel::free(), |ctx| {
            let mut acc = 0.0;
            for round in 0..10 {
                acc += ctx.allreduce_scalar((ctx.rank() + round) as f64, Op::Sum);
                ctx.barrier();
            }
            acc
        });
        let expect: f64 = (0..10).map(|r| (0..4).map(|k| (k + r) as f64).sum::<f64>()).sum();
        for r in &results {
            assert_eq!(*r, expect);
        }
    }

    #[test]
    fn single_rank_is_a_lone_hub() {
        let results = run(1, CostModel::free(), |ctx| {
            ctx.barrier();
            assert_eq!(ctx.gather(0, &[2.5]).unwrap(), vec![vec![2.5]]);
            ctx.allreduce_scalar(5.0, Op::Sum)
        });
        assert_eq!(results, vec![5.0]);
    }

    #[test]
    fn bitwise_matches_thread_backend() {
        // non-associative payload: the rank-ordered fold must make the
        // two transports agree to the bit
        let payload = |rank: usize| {
            vec![1e16 * (rank as f64 - 1.5), 1.0 + rank as f64 * 1e-13, -0.75]
        };
        let via_threads =
            thread::run(4, CostModel::free(), |ctx| ctx.allreduce(&payload(ctx.rank()), Op::Sum));
        let via_sockets =
            run(4, CostModel::free(), |ctx| ctx.allreduce(&payload(ctx.rank()), Op::Sum));
        assert_eq!(via_threads, via_sockets);
    }

    #[test]
    fn clocks_sync_across_the_wire() {
        let results = run_with_clocks(2, CostModel::shared_memory(), |ctx| {
            ctx.charge(Category::Compute, if ctx.rank() == 0 { 1.0 } else { 3.0 });
            ctx.allreduce_scalar(1.0, Op::Sum);
            ctx.clock().now()
        });
        let (t0, t1) = (results[0].0, results[1].0);
        assert!(t0 >= 3.0 && (t0 - t1).abs() < 1e-12, "{t0} vs {t1}");
        assert!(results[0].1.in_category(Category::Comm) >= 2.0);
    }
}

//! Localhost socket transport: rank threads exchanging length-prefixed
//! frames over TCP, with rank 0 as the rendezvous hub.
//!
//! This backend proves the [`Communicator`] boundary is transport-real:
//! no shared memory crosses rank boundaries — every collective
//! round-trips through rank 0 as little-endian length-prefixed frames,
//! exactly the structure a multi-process / multi-node deployment needs
//! (swap `127.0.0.1` for a host list and the same protocol runs across
//! machines).
//!
//! ## Protocol
//!
//! Rank 0 binds an ephemeral listener; ranks 1..p connect and send a
//! 4-byte hello carrying their rank id. Each collective is one
//! request/reply round in strict lockstep:
//!
//! ```text
//! request (leaf → hub):  frame u8 (0 = collective | 1 = abort)
//!   collective: opcode u8 | op u8 | provided u8 | root u32 |
//!               clock f64 | len u64 | payload f64 × len
//!   abort:      encoded CommError (kind u8 | rank u64 | secs f64 |
//!               len u64 | message bytes)
//! reply   (hub → leaf):  status u8 (0 = ok | 1 = error)
//!   ok:    max_entry f64 | n_parts u64 | (len u64 | part f64 × len) × n_parts
//!   error: encoded CommError
//! ```
//!
//! The hub collects every rank's contribution **in rank order**,
//! validates that all ranks entered the same collective, reduces
//! through the shared [`fold`] kernels — so results are bitwise
//! identical to the thread backend — and replies with only what each
//! rank needs: rooted collectives (`gather`, `reduce`) ship data to the
//! root alone.
//!
//! ## Failure semantics
//!
//! * **Abort broadcast** ([`Communicator::abort`]): a failing leaf
//!   sends an abort frame in place of its next request; the hub's
//!   frame collection is a readiness *poll* over every pending leaf,
//!   so the abort is observed and relayed to every leaf the moment it
//!   arrives — not after lower-ranked requests trickle in — and ranks
//!   parked mid-collective wake with [`CommError::RemoteAbort`]. A
//!   failing hub writes the error reply to every leaf directly. After
//!   any failure the handle is poisoned — subsequent collectives fail
//!   fast without touching the (possibly desynced) wire.
//! * **Dead peers**: a leaf connection at EOF while the hub collects
//!   frames (its process died, or its thread returned early while the
//!   group is mid-collective) surfaces as [`CommError::RemoteAbort`]
//!   naming the dead rank, relayed to the survivors immediately.
//! * **Deadlines** ([`run_with_clocks_timeout`]): rendezvous
//!   (accept/connect/hello) and every frame read/write observe the
//!   configured timeout, so a worker that never connects or a peer that
//!   dies silently mid-collective yields [`CommError::Timeout`] instead
//!   of blocking indefinitely.
//! * **Contract misuse** (mismatched collectives, broadcast payload
//!   violations, ragged `reduce_scatter_block` lengths, corrupt frames)
//!   is detected at the hub and relayed to every rank as the same typed
//!   [`CommError::ContractViolation`] / [`CommError::Transport`].

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use super::clock::{Category, Clock};
use super::communicator::{fold, Communicator, Op};
use super::costmodel::CostModel;
use super::error::{CommError, CommResult};
use crate::obs::Tracer;
use crate::util::panic::panic_text;

/// Collective opcode on the wire (shared with the leader tree of
/// [`super::hier`], whose bundle frames carry the same opcode bytes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum OpCode {
    Allreduce,
    Broadcast,
    Allgather,
    Gather,
    Reduce,
    ReduceScatter,
    Barrier,
}

impl OpCode {
    pub(crate) fn to_byte(self) -> u8 {
        match self {
            OpCode::Allreduce => 0,
            OpCode::Broadcast => 1,
            OpCode::Allgather => 2,
            OpCode::Gather => 3,
            OpCode::Reduce => 4,
            OpCode::ReduceScatter => 5,
            OpCode::Barrier => 6,
        }
    }

    pub(crate) fn from_byte(b: u8) -> io::Result<OpCode> {
        Ok(match b {
            0 => OpCode::Allreduce,
            1 => OpCode::Broadcast,
            2 => OpCode::Allgather,
            3 => OpCode::Gather,
            4 => OpCode::Reduce,
            5 => OpCode::ReduceScatter,
            6 => OpCode::Barrier,
            other => return Err(corrupt(format!("unknown opcode {other}"))),
        })
    }
}

pub(crate) fn op_to_byte(op: Op) -> u8 {
    match op {
        Op::Sum => 0,
        Op::Max => 1,
        Op::Min => 2,
    }
}

pub(crate) fn op_from_byte(b: u8) -> io::Result<Op> {
    Ok(match b {
        0 => Op::Sum,
        1 => Op::Max,
        2 => Op::Min,
        other => return Err(corrupt(format!("unknown reduction op {other}"))),
    })
}

pub(crate) fn corrupt(detail: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("corrupt frame ({detail})"))
}

/// Map an I/O failure while `waiting_for` into the typed comm error:
/// an elapsed deadline is [`CommError::Timeout`], anything else is
/// [`CommError::Transport`].
pub(crate) fn io_error(
    rank: usize,
    timeout: Option<Duration>,
    waiting_for: &str,
    e: io::Error,
) -> CommError {
    if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) {
        CommError::Timeout {
            rank,
            seconds: timeout.map_or(0.0, |t| t.as_secs_f64()),
            waiting_for: waiting_for.to_string(),
        }
    } else {
        CommError::Transport { rank, message: format!("{waiting_for}: {e}") }
    }
}

// ---------------------------------------------------------------- frame I/O

pub(crate) fn read_u64(stream: &mut impl Read) -> io::Result<u64> {
    let mut b = [0u8; 8];
    stream.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

pub(crate) fn read_f64(stream: &mut impl Read) -> io::Result<f64> {
    let mut b = [0u8; 8];
    stream.read_exact(&mut b)?;
    Ok(f64::from_le_bytes(b))
}

pub(crate) fn read_f64s(stream: &mut impl Read, count: usize) -> io::Result<Vec<f64>> {
    let mut raw = vec![0u8; count * 8];
    stream.read_exact(&mut raw)?;
    Ok(raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
}

pub(crate) fn push_f64s(buf: &mut Vec<u8>, values: &[f64]) {
    for v in values {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Encode a [`CommError`] onto the wire:
/// `kind u8 | rank u64 | seconds f64 | len u64 | message bytes`.
pub(crate) fn push_comm_error(buf: &mut Vec<u8>, e: &CommError) {
    let (kind, rank, seconds, msg): (u8, usize, f64, &str) = match e {
        CommError::RemoteAbort { origin_rank, message } => (0, *origin_rank, 0.0, message),
        CommError::Timeout { rank, seconds, waiting_for } => (1, *rank, *seconds, waiting_for),
        CommError::ContractViolation { rank, message } => (2, *rank, 0.0, message),
        CommError::Transport { rank, message } => (3, *rank, 0.0, message),
    };
    buf.push(kind);
    buf.extend_from_slice(&(rank as u64).to_le_bytes());
    buf.extend_from_slice(&seconds.to_le_bytes());
    buf.extend_from_slice(&(msg.len() as u64).to_le_bytes());
    buf.extend_from_slice(msg.as_bytes());
}

pub(crate) fn read_comm_error(stream: &mut impl Read) -> io::Result<CommError> {
    let mut kind = [0u8; 1];
    stream.read_exact(&mut kind)?;
    let rank = read_u64(stream)? as usize;
    let seconds = read_f64(stream)?;
    let len = read_u64(stream)? as usize;
    let mut raw = vec![0u8; len];
    stream.read_exact(&mut raw)?;
    let msg = String::from_utf8_lossy(&raw).into_owned();
    Ok(match kind[0] {
        0 => CommError::RemoteAbort { origin_rank: rank, message: msg },
        1 => CommError::Timeout { rank, seconds, waiting_for: msg },
        2 => CommError::ContractViolation { rank, message: msg },
        3 => CommError::Transport { rank, message: msg },
        other => return Err(corrupt(format!("unknown error kind {other}"))),
    })
}

pub(crate) const FRAME_COLLECTIVE: u8 = 0;
pub(crate) const FRAME_ABORT: u8 = 1;
const STATUS_OK: u8 = 0;
const STATUS_ERROR: u8 = 1;

pub(crate) struct Request {
    pub(crate) code: OpCode,
    pub(crate) op: u8,
    pub(crate) provided: bool,
    pub(crate) root: usize,
    pub(crate) time: f64,
    pub(crate) payload: Vec<f64>,
}

/// A frame read by the hub from a leaf.
pub(crate) enum Frame {
    Request(Request),
    Abort(CommError),
}

pub(crate) fn write_request(
    stream: &mut TcpStream,
    code: OpCode,
    op: u8,
    provided: bool,
    root: usize,
    time: f64,
    payload: &[f64],
) -> io::Result<()> {
    let mut buf = Vec::with_capacity(24 + payload.len() * 8);
    buf.push(FRAME_COLLECTIVE);
    buf.push(code.to_byte());
    buf.push(op);
    buf.push(u8::from(provided));
    buf.extend_from_slice(&(root as u32).to_le_bytes());
    buf.extend_from_slice(&time.to_le_bytes());
    buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    push_f64s(&mut buf, payload);
    stream.write_all(&buf)
}

pub(crate) fn write_abort(stream: &mut TcpStream, e: &CommError) -> io::Result<()> {
    let mut buf = vec![FRAME_ABORT];
    push_comm_error(&mut buf, e);
    stream.write_all(&buf)
}

pub(crate) fn read_frame(stream: &mut TcpStream) -> io::Result<Frame> {
    let mut head = [0u8; 1];
    stream.read_exact(&mut head)?;
    match head[0] {
        FRAME_COLLECTIVE => {
            let mut fixed = [0u8; 7];
            stream.read_exact(&mut fixed)?;
            let code = OpCode::from_byte(fixed[0])?;
            let op = fixed[1];
            let provided = fixed[2] != 0;
            let root = u32::from_le_bytes(fixed[3..7].try_into().unwrap()) as usize;
            let time = read_f64(stream)?;
            let len = read_u64(stream)? as usize;
            let payload = read_f64s(stream, len)?;
            Ok(Frame::Request(Request { code, op, provided, root, time, payload }))
        }
        FRAME_ABORT => Ok(Frame::Abort(read_comm_error(stream)?)),
        other => Err(corrupt(format!("unknown request frame type {other}"))),
    }
}

pub(crate) fn write_reply(
    stream: &mut TcpStream,
    max_entry: f64,
    parts: &[Vec<f64>],
) -> io::Result<()> {
    let total: usize = parts.iter().map(|p| 8 + p.len() * 8).sum();
    let mut buf = Vec::with_capacity(17 + total);
    buf.push(STATUS_OK);
    buf.extend_from_slice(&max_entry.to_le_bytes());
    buf.extend_from_slice(&(parts.len() as u64).to_le_bytes());
    for part in parts {
        buf.extend_from_slice(&(part.len() as u64).to_le_bytes());
        push_f64s(&mut buf, part);
    }
    stream.write_all(&buf)
}

pub(crate) fn write_error_reply(stream: &mut TcpStream, e: &CommError) -> io::Result<()> {
    let mut buf = vec![STATUS_ERROR];
    push_comm_error(&mut buf, e);
    stream.write_all(&buf)
}

/// Best-effort error broadcast to every leaf. Write failures are
/// ignored: a leaf whose connection is already gone cannot be woken,
/// and the group is failing regardless.
pub(crate) fn send_error_to_all(streams: &mut [TcpStream], e: &CommError) {
    for s in streams.iter_mut() {
        let _ = write_error_reply(s, e);
    }
}

/// Readiness state of one leaf stream during the hub's frame poll.
enum Ready {
    /// at least one byte is buffered — a frame read won't park long
    Frame,
    /// the peer closed the connection (process death / early return)
    Eof,
    /// nothing buffered yet
    Idle,
}

/// Non-destructively probe a leaf stream for a buffered frame. The
/// stream is flipped to non-blocking only around the `peek`, so the
/// subsequent full-frame read stays a plain blocking read (with the
/// configured read timeout still in force).
fn frame_ready(stream: &TcpStream) -> io::Result<Ready> {
    stream.set_nonblocking(true)?;
    let mut probe = [0u8; 1];
    let peeked = stream.peek(&mut probe);
    let restored = stream.set_nonblocking(false);
    let ready = match peeked {
        Ok(0) => Ready::Eof,
        Ok(_) => Ready::Frame,
        Err(e) if e.kind() == io::ErrorKind::WouldBlock => Ready::Idle,
        Err(e) => return Err(e),
    };
    restored?;
    Ok(ready)
}

/// Collect one collective frame from every leaf — in *arrival* order,
/// not rank order: each sweep probes every still-pending stream, reads
/// whatever is ready, and sleeps briefly only when a full sweep made no
/// progress. Contributions are slotted by rank, so arrival order never
/// leaks into the (rank-ordered) reduction; the poll only changes when
/// failures are observed — an abort frame, a dead peer (EOF), or a
/// contract mismatch short-circuits the collection the moment it shows
/// up, no matter which rank it came from, so the caller can fan the
/// error out to every leaf immediately.
pub(crate) fn collect_frames(
    streams: &mut [TcpStream],
    code: OpCode,
    op: u8,
    root: usize,
    rank: usize,
    timeout: Option<Duration>,
) -> Result<Vec<Request>, CommError> {
    let deadline = timeout.map(|t| Instant::now() + t);
    let mut slots: Vec<Option<Request>> = streams.iter().map(|_| None).collect();
    let mut remaining = streams.len();
    while remaining > 0 {
        let mut progressed = false;
        for (i, s) in streams.iter_mut().enumerate() {
            if slots[i].is_some() {
                continue;
            }
            let peer = i + 1;
            let ready = frame_ready(s).map_err(|e| {
                io_error(rank, timeout, &format!("probing rank {peer} for a request"), e)
            })?;
            match ready {
                Ready::Idle => {}
                Ready::Eof => {
                    // in lockstep SPMD a leaf never legitimately closes
                    // its connection while the hub is inside a
                    // collective: the peer returned early or its
                    // process died — either way the group is over
                    return Err(CommError::RemoteAbort {
                        origin_rank: peer,
                        message: "connection closed mid-collective (rank exited early or its \
                                  process died)"
                            .to_string(),
                    });
                }
                Ready::Frame => {
                    let frame = read_frame(s).map_err(|e| {
                        io_error(rank, timeout, &format!("request from rank {peer}"), e)
                    })?;
                    match frame {
                        Frame::Abort(e) => return Err(e),
                        Frame::Request(req) => {
                            if req.code != code || req.root != root || req.op != op {
                                // detected on the hub (rank 0), like
                                // every other hub-side contract check
                                return Err(CommError::ContractViolation {
                                    rank: 0,
                                    message: format!(
                                        "collective mismatch — rank 0 entered {code:?}(root \
                                         {root}), rank {peer} entered {:?}(root {})",
                                        req.code, req.root
                                    ),
                                });
                            }
                            slots[i] = Some(req);
                            remaining -= 1;
                            progressed = true;
                        }
                    }
                }
            }
        }
        if remaining > 0 && !progressed {
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    return Err(CommError::Timeout {
                        rank,
                        seconds: timeout.map_or(0.0, |t| t.as_secs_f64()),
                        waiting_for: format!("requests from {remaining} rank(s)"),
                    });
                }
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    Ok(slots.into_iter().map(|s| s.expect("all slots filled")).collect())
}

pub(crate) enum Reply {
    Ok { max_entry: f64, parts: Vec<Vec<f64>> },
    Error(CommError),
}

pub(crate) fn read_reply(stream: &mut TcpStream) -> io::Result<Reply> {
    let mut status = [0u8; 1];
    stream.read_exact(&mut status)?;
    match status[0] {
        STATUS_OK => {
            let max_entry = read_f64(stream)?;
            let n_parts = read_u64(stream)? as usize;
            let mut parts = Vec::with_capacity(n_parts);
            for _ in 0..n_parts {
                let len = read_u64(stream)? as usize;
                parts.push(read_f64s(stream, len)?);
            }
            Ok(Reply::Ok { max_entry, parts })
        }
        STATUS_ERROR => Ok(Reply::Error(read_comm_error(stream)?)),
        other => Err(corrupt(format!("unknown reply status {other}"))),
    }
}

// ---------------------------------------------------------------- the hub

/// Compute every rank's reply parts for one collective, validating the
/// usage contract over every rank's contribution. All reductions go
/// through [`fold`] in rank order — bitwise identical to the thread
/// backend by construction.
pub(crate) fn hub_replies(
    code: OpCode,
    op: u8,
    root: usize,
    provided: &[bool],
    parts: &[Vec<f64>],
    size: usize,
) -> Result<Vec<Vec<Vec<f64>>>, CommError> {
    // the hub (rank 0) is where ragged contributions are detected
    let equal_lengths = |what: &str| -> Result<(), CommError> {
        match fold::length_violation(what, 0, parts) {
            Some(e) => Err(e),
            None => Ok(()),
        }
    };
    Ok(match code {
        OpCode::Allreduce => {
            equal_lengths("allreduce")?;
            let reduced = fold::reduce_parts(parts, op_from_byte(op).map_err(|e| {
                CommError::Transport { rank: 0, message: e.to_string() }
            })?);
            (0..size).map(|_| vec![reduced.clone()]).collect()
        }
        OpCode::Broadcast => {
            if let Some(e) = fold::broadcast_violation(root, provided, 0) {
                return Err(e);
            }
            (0..size).map(|_| vec![parts[root].clone()]).collect()
        }
        OpCode::Allgather => (0..size).map(|_| parts.to_vec()).collect(),
        OpCode::Gather => (0..size)
            .map(|i| if i == root { parts.to_vec() } else { Vec::new() })
            .collect(),
        OpCode::Reduce => {
            equal_lengths("reduce")?;
            let reduced = fold::reduce_parts(parts, op_from_byte(op).map_err(|e| {
                CommError::Transport { rank: 0, message: e.to_string() }
            })?);
            (0..size)
                .map(|i| if i == root { vec![reduced.clone()] } else { Vec::new() })
                .collect()
        }
        OpCode::ReduceScatter => {
            equal_lengths("reduce_scatter_block")?;
            if let Some(e) = fold::divisibility_violation(parts, size, 0) {
                return Err(e);
            }
            let reduced = fold::reduce_parts(parts, op_from_byte(op).map_err(|e| {
                CommError::Transport { rank: 0, message: e.to_string() }
            })?);
            (0..size).map(|i| vec![fold::block(&reduced, i, size)]).collect()
        }
        OpCode::Barrier => (0..size).map(|_| Vec::new()).collect(),
    })
}

enum Conn {
    /// rank 0: one stream per leaf, index i ↔ rank i + 1
    Hub { streams: Vec<TcpStream> },
    Leaf { stream: TcpStream },
}

/// Telemetry identity of one collective: what the tracer records when
/// the exchange closes (the α–β `cost` doubles as the predicted time).
struct Probe {
    primitive: &'static str,
    bytes: usize,
    cost: f64,
}

/// Per-rank handle of the localhost socket transport.
pub struct SocketComm {
    rank: usize,
    size: usize,
    clock: Clock,
    model: CostModel,
    conn: Conn,
    timeout: Option<Duration>,
    /// first failure observed on this handle; subsequent collectives
    /// fail fast with it instead of touching a desynced stream
    failed: Option<CommError>,
    /// per-rank span/telemetry recorder (default off; see [`crate::obs`])
    tracer: Tracer,
}

impl SocketComm {
    /// The hub handle (rank 0) over already-rendezvoused leaf streams,
    /// index i ↔ rank i + 1. Used by the in-process runner below and by
    /// the process launcher ([`super::proc`]), whose parent rank holds
    /// streams to spawned worker processes.
    pub(crate) fn hub_from_streams(
        size: usize,
        streams: Vec<TcpStream>,
        model: CostModel,
        timeout: Option<Duration>,
    ) -> SocketComm {
        debug_assert_eq!(streams.len() + 1, size);
        SocketComm {
            rank: 0,
            size,
            clock: Clock::new(),
            model,
            conn: Conn::Hub { streams },
            timeout,
            failed: None,
            tracer: Tracer::new(0),
        }
    }

    /// A leaf handle over an already-rendezvoused stream to the hub.
    pub(crate) fn leaf_from_stream(
        rank: usize,
        size: usize,
        stream: TcpStream,
        model: CostModel,
        timeout: Option<Duration>,
    ) -> SocketComm {
        SocketComm {
            rank,
            size,
            clock: Clock::new(),
            model,
            conn: Conn::Leaf { stream },
            timeout,
            failed: None,
            tracer: Tracer::new(rank),
        }
    }

    /// Tear the handle down into its final clock, tracer, and streams
    /// (the hub's leaf streams in rank order, or a leaf's single hub
    /// stream) — the process transport reuses the collective streams
    /// for its join frames after the rank function returns.
    pub(crate) fn into_parts(self) -> (Clock, Tracer, Vec<TcpStream>) {
        let streams = match self.conn {
            Conn::Hub { streams } => streams,
            Conn::Leaf { stream } => vec![stream],
        };
        (self.clock, self.tracer, streams)
    }

    /// One collective round: contribute `payload`, receive this rank's
    /// reply parts plus the max clock entry time over all ranks.
    ///
    /// Every exit below the fail-fast check closes exactly one tracer
    /// comm record (success or failure), so an aborted or timed-out run
    /// never leaves a collective span open. The wait split is the time
    /// parked on the wire: `read_reply` for a leaf, the frame-
    /// collection poll ([`collect_frames`]) for the hub.
    fn exchange(
        &mut self,
        probe: Probe,
        code: OpCode,
        op: u8,
        provided: bool,
        root: usize,
        payload: Vec<f64>,
    ) -> CommResult<(f64, Vec<Vec<f64>>)> {
        if let Some(e) = &self.failed {
            return Err(e.clone());
        }
        let cs = self.tracer.comm_start();
        let mut wait_s = 0.0;
        let now = self.clock.now();
        let (rank, size, timeout) = (self.rank, self.size, self.timeout);
        let result = match &mut self.conn {
            Conn::Leaf { stream } => {
                let sent = write_request(stream, code, op, provided, root, now, &payload)
                    .map_err(|e| io_error(rank, timeout, "sending request to the rank 0 hub", e));
                let reply = match sent {
                    Err(e) => Err(e),
                    Ok(()) => {
                        let parked = self.tracer.comm_start();
                        let reply = read_reply(stream)
                            .map_err(|e| io_error(rank, timeout, "reply from the rank 0 hub", e));
                        wait_s = self.tracer.elapsed_since(parked);
                        reply
                    }
                };
                match reply {
                    Ok(Reply::Ok { max_entry, parts }) => Ok((max_entry, parts)),
                    Ok(Reply::Error(e)) | Err(e) => Err(e),
                }
            }
            Conn::Hub { streams } => {
                let parked = self.tracer.comm_start();
                let collected = collect_frames(streams, code, op, root, rank, timeout);
                wait_s = self.tracer.elapsed_since(parked);
                let computed = collected.and_then(|requests| {
                    let mut times = vec![now];
                    let mut provided_flags = vec![provided];
                    let mut parts: Vec<Vec<f64>> = vec![payload];
                    for req in requests {
                        times.push(req.time);
                        provided_flags.push(req.provided);
                        parts.push(req.payload);
                    }
                    hub_replies(code, op, root, &provided_flags, &parts, size)
                        .map(|replies| (times, replies))
                });
                match computed {
                    Err(e) => {
                        // relay the failure so ranks parked in
                        // read_reply wake instead of hanging
                        send_error_to_all(streams, &e);
                        Err(e)
                    }
                    Ok((times, mut replies)) => {
                        let max_entry = times.iter().fold(0.0f64, |a, &b| a.max(b));
                        let mut write_err = None;
                        for (i, s) in streams.iter_mut().enumerate() {
                            if let Err(e) = write_reply(s, max_entry, &replies[i + 1]) {
                                write_err = Some(io_error(
                                    rank,
                                    timeout,
                                    &format!("sending reply to rank {}", i + 1),
                                    e,
                                ));
                                break;
                            }
                        }
                        match write_err {
                            Some(e) => {
                                send_error_to_all(streams, &e);
                                Err(e)
                            }
                            None => Ok((max_entry, replies.swap_remove(0))),
                        }
                    }
                }
            }
        };
        self.tracer.comm_record(cs, probe.primitive, probe.bytes, probe.cost, wait_s);
        if let Err(e) = &result {
            self.failed = Some(e.clone());
        }
        result
    }
}

impl Communicator for SocketComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn clock(&self) -> &Clock {
        &self.clock
    }

    fn charge(&mut self, category: Category, seconds: f64) {
        self.clock.add(category, seconds);
    }

    fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    fn tracer_mut(&mut self) -> &mut Tracer {
        &mut self.tracer
    }

    fn allreduce_inplace(&mut self, data: &mut [f64], op: Op) -> CommResult<()> {
        let bytes = data.len() * 8;
        let cost = self.model.allreduce(self.size, bytes);
        let (max_entry, mut parts) = self.exchange(
            Probe { primitive: "allreduce", bytes, cost },
            OpCode::Allreduce,
            op_to_byte(op),
            true,
            0,
            data.to_vec(),
        )?;
        let reduced = parts.pop().ok_or_else(|| CommError::Transport {
            rank: self.rank,
            message: "empty allreduce reply".to_string(),
        })?;
        debug_assert_eq!(reduced.len(), data.len(), "hub validated equal lengths");
        data.copy_from_slice(&reduced);
        self.clock.sync_to(max_entry + cost);
        Ok(())
    }

    fn broadcast(&mut self, root: usize, data: Option<Vec<f64>>) -> CommResult<Vec<f64>> {
        self.check_root("broadcast", root)?;
        let provided = data.is_some();
        let data_bytes = data.as_ref().map_or(0, |d| d.len() * 8);
        let cost = self.model.broadcast(self.size, data_bytes);
        let (max_entry, mut parts) = self.exchange(
            Probe { primitive: "broadcast", bytes: data_bytes, cost },
            OpCode::Broadcast,
            0,
            provided,
            root,
            data.unwrap_or_default(),
        )?;
        let out = parts.pop().ok_or_else(|| CommError::Transport {
            rank: self.rank,
            message: "empty broadcast reply".to_string(),
        })?;
        self.clock.sync_to(max_entry + cost);
        Ok(out)
    }

    fn allgather(&mut self, data: &[f64]) -> CommResult<Vec<Vec<f64>>> {
        let bytes = data.len() * 8 * self.size;
        let cost = self.model.allgather(self.size, bytes);
        let (max_entry, parts) = self.exchange(
            Probe { primitive: "allgather", bytes, cost },
            OpCode::Allgather,
            0,
            true,
            0,
            data.to_vec(),
        )?;
        self.clock.sync_to(max_entry + cost);
        Ok(parts)
    }

    fn gather(&mut self, root: usize, data: &[f64]) -> CommResult<Option<Vec<Vec<f64>>>> {
        self.check_root("gather", root)?;
        let bytes = data.len() * 8 * self.size;
        let cost = self.model.gather(self.size, bytes);
        let (max_entry, parts) = self.exchange(
            Probe { primitive: "gather", bytes, cost },
            OpCode::Gather,
            0,
            true,
            root,
            data.to_vec(),
        )?;
        self.clock.sync_to(max_entry + cost);
        Ok((self.rank == root).then_some(parts))
    }

    fn reduce(&mut self, root: usize, data: &[f64], op: Op) -> CommResult<Option<Vec<f64>>> {
        self.check_root("reduce", root)?;
        let bytes = data.len() * 8;
        let cost = self.model.reduce(self.size, bytes);
        let (max_entry, mut parts) = self.exchange(
            Probe { primitive: "reduce", bytes, cost },
            OpCode::Reduce,
            op_to_byte(op),
            true,
            root,
            data.to_vec(),
        )?;
        self.clock.sync_to(max_entry + cost);
        if self.rank == root {
            match parts.pop() {
                Some(reduced) => Ok(Some(reduced)),
                None => Err(CommError::Transport {
                    rank: self.rank,
                    message: "empty reduce reply on root".to_string(),
                }),
            }
        } else {
            Ok(None)
        }
    }

    fn reduce_scatter_block(&mut self, data: &[f64], op: Op) -> CommResult<Vec<f64>> {
        // divisibility is validated at the hub over *every* rank's
        // length, after the exchange: a local pre-check here would
        // leave this rank silent while its peers park in read_reply
        // (same rationale as the thread board's validation-rides-the-
        // exchange rule)
        let bytes = data.len() * 8;
        let cost = self.model.reduce_scatter(self.size, bytes);
        let (max_entry, mut parts) = self.exchange(
            Probe { primitive: "reduce_scatter", bytes, cost },
            OpCode::ReduceScatter,
            op_to_byte(op),
            true,
            0,
            data.to_vec(),
        )?;
        self.clock.sync_to(max_entry + cost);
        parts.pop().ok_or_else(|| CommError::Transport {
            rank: self.rank,
            message: "empty reduce_scatter_block reply".to_string(),
        })
    }

    fn barrier(&mut self) -> CommResult<()> {
        let cost = self.model.barrier(self.size);
        let (max_entry, _) = self.exchange(
            Probe { primitive: "barrier", bytes: 0, cost },
            OpCode::Barrier,
            0,
            true,
            0,
            Vec::new(),
        )?;
        self.clock.sync_to(max_entry + cost);
        Ok(())
    }

    fn abort(&mut self, message: &str) -> CommError {
        if let Some(e) = &self.failed {
            return e.clone();
        }
        let err =
            CommError::RemoteAbort { origin_rank: self.rank, message: message.to_string() };
        match &mut self.conn {
            // the leaf's abort frame rides the request channel; the hub
            // relays it to every peer as an error reply
            Conn::Leaf { stream } => {
                let _ = write_abort(stream, &err);
            }
            // the hub short-circuits: error replies go straight out
            Conn::Hub { streams } => send_error_to_all(streams, &err),
        }
        self.failed = Some(err.clone());
        err
    }
}

// ---------------------------------------------------------------- runners

pub(crate) fn accept_with_deadline(
    listener: &TcpListener,
    deadline: Option<Instant>,
) -> io::Result<TcpStream> {
    match deadline {
        None => listener.accept().map(|(s, _)| s),
        Some(d) => {
            listener.set_nonblocking(true)?;
            loop {
                match listener.accept() {
                    Ok((s, _)) => {
                        s.set_nonblocking(false)?;
                        return Ok(s);
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        if Instant::now() >= d {
                            return Err(io::Error::new(
                                io::ErrorKind::TimedOut,
                                "rendezvous accept deadline elapsed",
                            ));
                        }
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(e) => return Err(e),
                }
            }
        }
    }
}

pub(crate) fn apply_stream_timeouts(stream: &TcpStream, timeout: Option<Duration>) {
    stream.set_read_timeout(timeout).ok();
    stream.set_write_timeout(timeout).ok();
}

/// Rank 0 rendezvous: accept every leaf, slotting streams by rank id.
pub(crate) fn hub_rendezvous(
    listener: &TcpListener,
    p: usize,
    timeout: Option<Duration>,
) -> CommResult<Vec<TcpStream>> {
    let deadline = timeout.map(|t| Instant::now() + t);
    let mut slots: Vec<Option<TcpStream>> = (1..p).map(|_| None).collect();
    for _ in 1..p {
        let mut s = accept_with_deadline(listener, deadline)
            .map_err(|e| io_error(0, timeout, "a worker rank to connect", e))?;
        s.set_nodelay(true).ok();
        apply_stream_timeouts(&s, timeout);
        let mut hello = [0u8; 4];
        s.read_exact(&mut hello)
            .map_err(|e| io_error(0, timeout, "hello from a connecting worker", e))?;
        let peer = u32::from_le_bytes(hello) as usize;
        if !(1..p).contains(&peer) {
            return Err(CommError::Transport { rank: 0, message: format!("bad hello rank {peer}") });
        }
        if slots[peer - 1].replace(s).is_some() {
            return Err(CommError::Transport {
                rank: 0,
                message: format!("duplicate hello from rank {peer}"),
            });
        }
    }
    Ok(slots.into_iter().map(|s| s.expect("all slots filled")).collect())
}

/// Leaf rendezvous: connect to the hub at `addr` (a `host:port`
/// string — `127.0.0.1:<port>` for the in-process runner, the hub
/// address handed to a spawned worker for the process transport) and
/// send the hello.
pub(crate) fn leaf_rendezvous(
    rank: usize,
    addr: &str,
    timeout: Option<Duration>,
) -> CommResult<TcpStream> {
    let resolved: SocketAddr = addr
        .to_socket_addrs()
        .map_err(|e| io_error(rank, timeout, "resolving the rendezvous address", e))?
        .next()
        .ok_or_else(|| CommError::Transport {
            rank,
            message: format!("rendezvous address {addr:?} resolved to nothing"),
        })?;
    let mut stream = match timeout {
        Some(t) => TcpStream::connect_timeout(&resolved, t),
        None => TcpStream::connect(resolved),
    }
    .map_err(|e| io_error(rank, timeout, "connecting to the rank 0 rendezvous", e))?;
    stream.set_nodelay(true).ok();
    apply_stream_timeouts(&stream, timeout);
    stream
        .write_all(&(rank as u32).to_le_bytes())
        .map_err(|e| io_error(rank, timeout, "sending hello to rank 0", e))?;
    Ok(stream)
}

/// Run `f` on a constructed rank handle, converting a genuine panic
/// into an abort broadcast (so peers wake) before re-raising it.
fn run_rank<R>(mut ctx: SocketComm, f: impl Fn(&mut SocketComm) -> R) -> (R, Clock) {
    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut ctx)));
    match out {
        Ok(v) => (v, ctx.clock),
        Err(payload) => {
            let rank = ctx.rank;
            ctx.abort(&format!("rank {rank} panicked: {}", panic_text(&payload)));
            std::panic::resume_unwind(payload);
        }
    }
}

/// Spawn `p` rank threads connected over localhost TCP and return the
/// per-rank results in rank order. Returns `Err` when the rendezvous
/// itself fails (bind, connect, hello — with a deadline configured via
/// [`run_with_clocks_timeout`], a worker that never connects yields
/// [`CommError::Timeout`]). Failures *inside* collectives surface
/// through each rank's own closure result; genuine panics broadcast an
/// abort to the peers and then propagate with their original payload.
pub fn run<R: Send>(
    p: usize,
    model: CostModel,
    f: impl Fn(&mut SocketComm) -> R + Send + Sync,
) -> Result<Vec<R>, CommError> {
    Ok(run_with_clocks(p, model, f)?.into_iter().map(|(out, _)| out).collect())
}

/// Like [`run`], but also returns each rank's final [`Clock`].
pub fn run_with_clocks<R: Send>(
    p: usize,
    model: CostModel,
    f: impl Fn(&mut SocketComm) -> R + Send + Sync,
) -> Result<Vec<(R, Clock)>, CommError> {
    run_with_clocks_timeout(p, model, None, f)
}

/// Like [`run_with_clocks`], with an optional deadline applied to the
/// rendezvous and to every frame read/write of every rank.
pub fn run_with_clocks_timeout<R: Send>(
    p: usize,
    model: CostModel,
    timeout: Option<Duration>,
    f: impl Fn(&mut SocketComm) -> R + Send + Sync,
) -> Result<Vec<(R, Clock)>, CommError> {
    assert!(p >= 1, "need at least one rank");
    let listener = TcpListener::bind(("127.0.0.1", 0)).map_err(|e| CommError::Transport {
        rank: 0,
        message: format!("binding the rendezvous listener: {e}"),
    })?;
    let port = listener
        .local_addr()
        .map_err(|e| CommError::Transport {
            rank: 0,
            message: format!("reading the rendezvous listener address: {e}"),
        })?
        .port();
    let joined: Vec<Result<(R, Clock), CommError>> = std::thread::scope(|scope| {
        let f = &f;
        let mut handles = Vec::with_capacity(p);
        handles.push(scope.spawn(move || {
            let streams = hub_rendezvous(&listener, p, timeout)?;
            let ctx = SocketComm::hub_from_streams(p, streams, model, timeout);
            Ok(run_rank(ctx, f))
        }));
        for rank in 1..p {
            handles.push(scope.spawn(move || {
                let stream = leaf_rendezvous(rank, &format!("127.0.0.1:{port}"), timeout)?;
                let ctx = SocketComm::leaf_from_stream(rank, p, stream, model, timeout);
                Ok(run_rank(ctx, f))
            }));
        }
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(v) => v,
                Err(e) => std::panic::resume_unwind(e),
            })
            .collect()
    });
    joined.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::thread;

    #[test]
    fn allreduce_sum_exact() {
        let results = run(4, CostModel::free(), |ctx| {
            ctx.allreduce(&[ctx.rank() as f64, 1.0], Op::Sum).unwrap()
        })
        .unwrap();
        for r in &results {
            assert_eq!(r, &vec![6.0, 4.0]);
        }
    }

    #[test]
    fn broadcast_from_nonzero_root() {
        let results = run(4, CostModel::free(), |ctx| {
            let payload = (ctx.rank() == 2).then(|| vec![7.0, 8.0, 9.0]);
            ctx.broadcast(2, payload).unwrap()
        })
        .unwrap();
        for r in &results {
            assert_eq!(r, &vec![7.0, 8.0, 9.0]);
        }
    }

    #[test]
    fn broadcast_nonroot_some_errors_everywhere() {
        let results = run(3, CostModel::free(), |ctx| {
            let payload = (ctx.rank() == 2).then(|| vec![1.0]);
            ctx.broadcast(0, payload)
        })
        .unwrap();
        for r in &results {
            match r {
                Err(CommError::ContractViolation { message, .. }) => {
                    assert!(message.contains("non-root rank 2 passed Some"), "{message}");
                }
                other => panic!("expected ContractViolation, got {other:?}"),
            }
        }
    }

    #[test]
    fn abort_frame_wakes_every_parked_rank() {
        // rank 2 fails locally and aborts; the hub relays the abort to
        // ranks parked in read_reply — nobody hangs, everyone observes
        // the rank-tagged origin
        let results = run(4, CostModel::free(), |ctx| {
            if ctx.rank() == 2 {
                Err(ctx.abort("injected chunk-read failure"))
            } else {
                ctx.allreduce_scalar(1.0, Op::Sum).map(|_| ())
            }
        })
        .unwrap();
        for (rank, r) in results.iter().enumerate() {
            match r {
                Err(CommError::RemoteAbort { origin_rank, message }) => {
                    assert_eq!(*origin_rank, 2, "rank {rank}");
                    assert!(message.contains("injected chunk-read failure"));
                }
                other => panic!("rank {rank}: expected RemoteAbort, got {other:?}"),
            }
        }
    }

    #[test]
    fn hub_abort_wakes_the_leaves() {
        let results = run(3, CostModel::free(), |ctx| {
            if ctx.rank() == 0 {
                Err(ctx.abort("hub-side failure"))
            } else {
                ctx.barrier()
            }
        })
        .unwrap();
        for r in &results {
            match r {
                Err(CommError::RemoteAbort { origin_rank: 0, message }) => {
                    assert!(message.contains("hub-side failure"));
                }
                other => panic!("expected RemoteAbort from rank 0, got {other:?}"),
            }
        }
    }

    #[test]
    fn failed_handle_short_circuits_later_collectives() {
        let results = run(2, CostModel::free(), |ctx| {
            if ctx.rank() == 1 {
                let first = ctx.abort("dead");
                // subsequent collectives must fail fast with the same
                // error, without touching the wire
                let second = ctx.allreduce_scalar(1.0, Op::Sum).unwrap_err();
                let third = ctx.barrier().unwrap_err();
                (first == second, second == third)
            } else {
                let woken = ctx.allreduce_scalar(1.0, Op::Sum);
                (woken.is_err(), ctx.barrier().is_err())
            }
        })
        .unwrap();
        for (a, b) in &results {
            assert!(a && b);
        }
    }

    #[test]
    fn silent_peer_death_yields_typed_error_not_hang() {
        // rank 1 returns without entering the collective; its stream
        // closes, and the hub's poll must observe the dead peer (EOF ⇒
        // RemoteAbort naming rank 1) or the deadline (⇒ Timeout) —
        // never a hang
        let results = run_with_clocks_timeout(
            3,
            CostModel::free(),
            Some(Duration::from_millis(300)),
            |ctx| {
                if ctx.rank() == 1 {
                    Ok(())
                } else {
                    ctx.allreduce_scalar(1.0, Op::Sum).map(|_| ())
                }
            },
        )
        .unwrap();
        assert!(results[1].0.is_ok());
        for rank in [0usize, 2] {
            match &results[rank].0 {
                Err(CommError::RemoteAbort { origin_rank: 1, .. })
                | Err(CommError::Timeout { .. }) => {}
                other => panic!("rank {rank}: expected RemoteAbort(1)/Timeout, got {other:?}"),
            }
        }
    }

    #[test]
    fn abort_fan_out_is_prompt() {
        // rank 3 aborts immediately while rank 1 dawdles before
        // entering the collective: the readiness poll must relay the
        // abort to the hub and rank 2 well before rank 1's request
        // arrives (the old rank-ordered read loop sat on rank 1 first)
        let results = run(4, CostModel::free(), |ctx| {
            let begin = Instant::now();
            let out = match ctx.rank() {
                3 => Err(ctx.abort("early failure on the highest rank")),
                1 => {
                    std::thread::sleep(Duration::from_millis(1500));
                    ctx.allreduce_scalar(1.0, Op::Sum).map(|_| ())
                }
                _ => ctx.allreduce_scalar(1.0, Op::Sum).map(|_| ()),
            };
            (out, begin.elapsed())
        })
        .unwrap();
        for rank in [0usize, 1, 2] {
            match &results[rank].0 {
                Err(CommError::RemoteAbort { origin_rank: 3, message }) => {
                    assert!(message.contains("early failure"), "{message}");
                }
                other => panic!("rank {rank}: expected RemoteAbort from rank 3, got {other:?}"),
            }
        }
        for rank in [0usize, 2] {
            let took = results[rank].1;
            assert!(
                took < Duration::from_millis(1000),
                "rank {rank} woke only after {took:?} — abort fan-out is not prompt"
            );
        }
    }

    #[test]
    fn traced_collectives_record_telemetry_on_hub_and_leaf() {
        let traces = run(2, CostModel::shared_memory(), |ctx| {
            ctx.tracer_mut().set_enabled(true);
            ctx.allreduce_scalar(1.0, Op::Sum).unwrap();
            ctx.barrier().unwrap();
            ctx.tracer_mut().take()
        })
        .unwrap();
        let predicted = CostModel::shared_memory().allreduce(2, 8);
        for (rank, trace) in traces.iter().enumerate() {
            assert_eq!(trace.rank, rank);
            assert_eq!(trace.comm.len(), 2);
            assert_eq!(trace.comm[0].primitive, "allreduce");
            assert_eq!(trace.comm[0].bytes, 8);
            assert!((trace.comm[0].predicted_s - predicted).abs() < 1e-15);
            assert!(trace.comm[0].measured_s >= trace.comm[0].wait_s);
            assert_eq!(trace.comm[1].primitive, "barrier");
            assert_eq!(trace.comm[1].bytes, 0);
        }
    }

    #[test]
    fn abort_still_closes_the_pending_comm_record() {
        let traces = run(2, CostModel::free(), |ctx| {
            ctx.tracer_mut().set_enabled(true);
            if ctx.rank() == 1 {
                let _ = ctx.abort("injected failure");
            } else {
                assert!(ctx.allreduce_scalar(1.0, Op::Sum).is_err());
            }
            ctx.tracer_mut().take()
        })
        .unwrap();
        // the hub's failed allreduce is still one *closed* record …
        assert_eq!(traces[0].comm.len(), 1);
        assert_eq!(traces[0].comm[0].primitive, "allreduce");
        // … and the aborting rank never entered a collective
        assert!(traces[1].comm.is_empty());
    }

    #[test]
    fn comm_error_wire_roundtrip() {
        let cases = vec![
            CommError::RemoteAbort { origin_rank: 7, message: "EIO at chunk 3".into() },
            CommError::Timeout { rank: 2, seconds: 1.5, waiting_for: "reply".into() },
            CommError::ContractViolation { rank: 0, message: "ragged".into() },
            CommError::Transport { rank: 4, message: "lost connection".into() },
        ];
        // round-trip through a real socket pair
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let port = listener.local_addr().unwrap().port();
        let mut tx = TcpStream::connect(("127.0.0.1", port)).unwrap();
        let (mut rx, _) = listener.accept().unwrap();
        for e in &cases {
            let mut buf = Vec::new();
            push_comm_error(&mut buf, e);
            tx.write_all(&buf).unwrap();
            let got = read_comm_error(&mut rx).unwrap();
            assert_eq!(&got, e);
        }
    }

    #[test]
    fn allgather_and_gather_preserve_rank_order() {
        let results = run(3, CostModel::free(), |ctx| {
            let mine = vec![ctx.rank() as f64; ctx.rank() + 1];
            (ctx.allgather(&mine).unwrap(), ctx.gather(1, &mine).unwrap())
        })
        .unwrap();
        for (rank, (all, rooted)) in results.iter().enumerate() {
            assert_eq!(all, &vec![vec![0.0], vec![1.0, 1.0], vec![2.0, 2.0, 2.0]]);
            if rank == 1 {
                assert_eq!(rooted.as_ref().unwrap(), all);
            } else {
                assert!(rooted.is_none());
            }
        }
    }

    #[test]
    fn reduce_and_reduce_scatter() {
        let results = run(4, CostModel::free(), |ctx| {
            let mine = vec![ctx.rank() as f64; 8];
            (
                ctx.reduce(3, &mine, Op::Max).unwrap(),
                ctx.reduce_scatter_block(&mine, Op::Sum).unwrap(),
            )
        })
        .unwrap();
        for (rank, (reduced, scattered)) in results.iter().enumerate() {
            assert_eq!(scattered, &vec![6.0, 6.0]);
            if rank == 3 {
                assert_eq!(reduced.as_ref().unwrap(), &vec![3.0; 8]);
            } else {
                assert!(reduced.is_none());
            }
        }
    }

    #[test]
    fn sequence_of_collectives_stays_in_lockstep() {
        let results = run(4, CostModel::free(), |ctx| {
            let mut acc = 0.0;
            for round in 0..10 {
                acc += ctx.allreduce_scalar((ctx.rank() + round) as f64, Op::Sum).unwrap();
                ctx.barrier().unwrap();
            }
            acc
        })
        .unwrap();
        let expect: f64 = (0..10).map(|r| (0..4).map(|k| (k + r) as f64).sum::<f64>()).sum();
        for r in &results {
            assert_eq!(*r, expect);
        }
    }

    #[test]
    fn single_rank_is_a_lone_hub() {
        let results = run(1, CostModel::free(), |ctx| {
            ctx.barrier().unwrap();
            assert_eq!(ctx.gather(0, &[2.5]).unwrap().unwrap(), vec![vec![2.5]]);
            ctx.allreduce_scalar(5.0, Op::Sum).unwrap()
        })
        .unwrap();
        assert_eq!(results, vec![5.0]);
    }

    #[test]
    fn bitwise_matches_thread_backend() {
        // non-associative payload: the rank-ordered fold must make the
        // two transports agree to the bit
        let payload = |rank: usize| {
            vec![1e16 * (rank as f64 - 1.5), 1.0 + rank as f64 * 1e-13, -0.75]
        };
        let via_threads = thread::run(4, CostModel::free(), |ctx| {
            ctx.allreduce(&payload(ctx.rank()), Op::Sum).unwrap()
        });
        let via_sockets = run(4, CostModel::free(), |ctx| {
            ctx.allreduce(&payload(ctx.rank()), Op::Sum).unwrap()
        })
        .unwrap();
        assert_eq!(via_threads, via_sockets);
    }

    #[test]
    fn clocks_sync_across_the_wire() {
        let results = run_with_clocks(2, CostModel::shared_memory(), |ctx| {
            ctx.charge(Category::Compute, if ctx.rank() == 0 { 1.0 } else { 3.0 });
            ctx.allreduce_scalar(1.0, Op::Sum).unwrap();
            ctx.clock().now()
        })
        .unwrap();
        let (t0, t1) = (results[0].0, results[1].0);
        assert!(t0 >= 3.0 && (t0 - t1).abs() < 1e-12, "{t0} vs {t1}");
        assert!(results[0].1.in_category(Category::Comm) >= 2.0);
    }
}

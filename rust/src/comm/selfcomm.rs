//! Zero-overhead single-rank backend.
//!
//! [`SelfComm`] is MPI_COMM_SELF: a p = 1 communicator where every
//! collective is the identity — no threads spawned, no barriers, no
//! contribution board. `run_distributed` and `serve_ensemble` use it
//! for p = 1 runs so the serial case pays nothing for the SPMD
//! abstraction; it is also the reference backend for transport
//! property tests (any collective over one rank must return its own
//! contribution unchanged).
//!
//! The fallible contract short-circuits: [`Communicator::abort`]
//! records the abort, and every subsequent collective fails fast with
//! the same [`CommError::RemoteAbort`] — exactly the poisoned-group
//! semantics of the multi-rank transports, collapsed to one rank.

use super::clock::{Category, Clock};
use super::communicator::{Communicator, Op};
use super::error::{CommError, CommResult};
use crate::obs::Tracer;

/// The p = 1 communicator: every collective returns this rank's own
/// contribution. Carries a virtual [`Clock`] like every backend so
/// timing reports stay uniform, and a [`Tracer`] so traced p = 1 runs
/// still show their collective call pattern (predicted cost is 0 — the
/// α–β model is free at p = 1).
#[derive(Debug, Default)]
pub struct SelfComm {
    clock: Clock,
    aborted: Option<CommError>,
    tracer: Tracer,
}

impl SelfComm {
    pub fn new() -> SelfComm {
        SelfComm { clock: Clock::new(), aborted: None, tracer: Tracer::new(0) }
    }

    /// Final clock, for timing reports after the rank function returns.
    pub fn into_clock(self) -> Clock {
        self.clock
    }

    fn check(&self) -> CommResult<()> {
        match &self.aborted {
            Some(e) => Err(e.clone()),
            None => Ok(()),
        }
    }

    /// Record a collective identity op (no peers → zero wait, zero
    /// predicted cost; measured time is the local copy).
    fn record(&mut self, start: crate::obs::CommStart, primitive: &'static str, bytes: usize) {
        self.tracer.comm_record(start, primitive, bytes, 0.0, 0.0);
    }
}

impl Communicator for SelfComm {
    fn rank(&self) -> usize {
        0
    }

    fn size(&self) -> usize {
        1
    }

    fn clock(&self) -> &Clock {
        &self.clock
    }

    fn charge(&mut self, category: Category, seconds: f64) {
        self.clock.add(category, seconds);
    }

    fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    fn tracer_mut(&mut self) -> &mut Tracer {
        &mut self.tracer
    }

    fn allreduce_inplace(&mut self, data: &mut [f64], _op: Op) -> CommResult<()> {
        self.check()?;
        let cs = self.tracer.comm_start();
        self.record(cs, "allreduce", data.len() * 8);
        Ok(())
    }

    fn broadcast(&mut self, root: usize, data: Option<Vec<f64>>) -> CommResult<Vec<f64>> {
        self.check()?;
        self.check_root("broadcast", root)?;
        let cs = self.tracer.comm_start();
        let out = data.ok_or_else(|| CommError::ContractViolation {
            rank: 0,
            message: "broadcast(root=0) — root rank 0 provided no payload".to_string(),
        })?;
        self.record(cs, "broadcast", out.len() * 8);
        Ok(out)
    }

    fn allgather(&mut self, data: &[f64]) -> CommResult<Vec<Vec<f64>>> {
        self.check()?;
        let cs = self.tracer.comm_start();
        let out = vec![data.to_vec()];
        self.record(cs, "allgather", data.len() * 8);
        Ok(out)
    }

    fn gather(&mut self, root: usize, data: &[f64]) -> CommResult<Option<Vec<Vec<f64>>>> {
        self.check()?;
        self.check_root("gather", root)?;
        let cs = self.tracer.comm_start();
        let out = Some(vec![data.to_vec()]);
        self.record(cs, "gather", data.len() * 8);
        Ok(out)
    }

    fn reduce(&mut self, root: usize, data: &[f64], _op: Op) -> CommResult<Option<Vec<f64>>> {
        self.check()?;
        self.check_root("reduce", root)?;
        let cs = self.tracer.comm_start();
        let out = Some(data.to_vec());
        self.record(cs, "reduce", data.len() * 8);
        Ok(out)
    }

    fn reduce_scatter_block(&mut self, data: &[f64], _op: Op) -> CommResult<Vec<f64>> {
        self.check()?;
        let cs = self.tracer.comm_start();
        let out = data.to_vec();
        self.record(cs, "reduce_scatter", data.len() * 8);
        Ok(out)
    }

    fn barrier(&mut self) -> CommResult<()> {
        self.check()?;
        let cs = self.tracer.comm_start();
        self.record(cs, "barrier", 0);
        Ok(())
    }

    fn abort(&mut self, message: &str) -> CommError {
        self.aborted
            .get_or_insert(CommError::RemoteAbort { origin_rank: 0, message: message.to_string() })
            .clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collectives_are_identities() {
        let mut c = SelfComm::new();
        assert_eq!(c.rank(), 0);
        assert_eq!(c.size(), 1);
        let mut v = vec![1.5, -2.0];
        c.allreduce_inplace(&mut v, Op::Sum).unwrap();
        assert_eq!(v, vec![1.5, -2.0]);
        assert_eq!(c.allreduce_scalar(7.0, Op::Min).unwrap(), 7.0);
        assert_eq!(c.broadcast(0, Some(vec![3.0])).unwrap(), vec![3.0]);
        assert_eq!(c.allgather(&[4.0]).unwrap(), vec![vec![4.0]]);
        assert_eq!(c.gather(0, &[5.0]).unwrap().unwrap(), vec![vec![5.0]]);
        assert_eq!(c.reduce(0, &[6.0], Op::Max).unwrap().unwrap(), vec![6.0]);
        assert_eq!(c.reduce_scatter_block(&[1.0, 2.0], Op::Sum).unwrap(), vec![1.0, 2.0]);
        c.barrier().unwrap();
    }

    #[test]
    fn clock_accumulates() {
        let mut c = SelfComm::new();
        c.charge(Category::Compute, 1.25);
        let x = c.timed(Category::Learn, || 42);
        assert_eq!(x, 42);
        assert!((c.clock().in_category(Category::Compute) - 1.25).abs() < 1e-15);
        let clock = c.into_clock();
        assert!(clock.now() >= 1.25);
    }

    #[test]
    fn traced_collectives_record_per_primitive() {
        let mut c = SelfComm::new();
        c.tracer_mut().set_enabled(true);
        c.allreduce_scalar(1.0, Op::Sum).unwrap();
        c.barrier().unwrap();
        c.broadcast(0, Some(vec![1.0, 2.0])).unwrap();
        let trace = c.tracer_mut().take();
        assert_eq!(trace.comm.len(), 3);
        assert_eq!(trace.comm[0].primitive, "allreduce");
        assert_eq!(trace.comm[0].bytes, 8);
        assert_eq!(trace.comm[0].predicted_s, 0.0);
        assert_eq!(trace.comm[1].primitive, "barrier");
        assert_eq!(trace.comm[1].bytes, 0);
        assert_eq!(trace.comm[2].bytes, 16);
        // untraced by default: a fresh SelfComm records nothing
        let mut quiet = SelfComm::new();
        quiet.barrier().unwrap();
        assert!(quiet.tracer_mut().take().comm.is_empty());
    }

    #[test]
    fn broadcast_without_payload_is_a_contract_error() {
        let e = SelfComm::new().broadcast(0, None).unwrap_err();
        assert!(matches!(e, CommError::ContractViolation { .. }), "{e:?}");
    }

    #[test]
    fn abort_short_circuits_every_collective() {
        let mut c = SelfComm::new();
        let first = c.abort("p=1 local failure");
        match &first {
            CommError::RemoteAbort { origin_rank: 0, message } => {
                assert!(message.contains("p=1 local failure"));
            }
            other => panic!("expected RemoteAbort, got {other:?}"),
        }
        // idempotent: the first abort wins
        assert_eq!(c.abort("later"), first);
        assert_eq!(c.allreduce_scalar(1.0, Op::Sum).unwrap_err(), first);
        assert_eq!(c.barrier().unwrap_err(), first);
        assert_eq!(c.broadcast(0, Some(vec![1.0])).unwrap_err(), first);
    }
}

//! Zero-overhead single-rank backend.
//!
//! [`SelfComm`] is MPI_COMM_SELF: a p = 1 communicator where every
//! collective is the identity — no threads spawned, no barriers, no
//! contribution board. `run_distributed` and `serve_ensemble` use it
//! for p = 1 runs so the serial case pays nothing for the SPMD
//! abstraction; it is also the reference backend for transport
//! property tests (any collective over one rank must return its own
//! contribution unchanged).

use super::clock::{Category, Clock};
use super::communicator::{Communicator, Op};

/// The p = 1 communicator: every collective returns this rank's own
/// contribution. Carries a virtual [`Clock`] like every backend so
/// timing reports stay uniform.
#[derive(Debug, Default)]
pub struct SelfComm {
    clock: Clock,
}

impl SelfComm {
    pub fn new() -> SelfComm {
        SelfComm { clock: Clock::new() }
    }

    /// Final clock, for timing reports after the rank function returns.
    pub fn into_clock(self) -> Clock {
        self.clock
    }
}

impl Communicator for SelfComm {
    fn rank(&self) -> usize {
        0
    }

    fn size(&self) -> usize {
        1
    }

    fn clock(&self) -> &Clock {
        &self.clock
    }

    fn charge(&mut self, category: Category, seconds: f64) {
        self.clock.add(category, seconds);
    }

    fn allreduce_inplace(&mut self, _data: &mut [f64], _op: Op) {}

    fn broadcast(&mut self, root: usize, data: Option<Vec<f64>>) -> Vec<f64> {
        assert_eq!(root, 0, "broadcast root {root} out of range (size 1)");
        data.unwrap_or_else(|| {
            panic!("rank 0: broadcast(root=0) — root rank 0 provided no payload")
        })
    }

    fn allgather(&mut self, data: &[f64]) -> Vec<Vec<f64>> {
        vec![data.to_vec()]
    }

    fn gather(&mut self, root: usize, data: &[f64]) -> Option<Vec<Vec<f64>>> {
        assert_eq!(root, 0, "gather root {root} out of range (size 1)");
        Some(vec![data.to_vec()])
    }

    fn reduce(&mut self, root: usize, data: &[f64], _op: Op) -> Option<Vec<f64>> {
        assert_eq!(root, 0, "reduce root {root} out of range (size 1)");
        Some(data.to_vec())
    }

    fn reduce_scatter_block(&mut self, data: &[f64], _op: Op) -> Vec<f64> {
        data.to_vec()
    }

    fn barrier(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collectives_are_identities() {
        let mut c = SelfComm::new();
        assert_eq!(c.rank(), 0);
        assert_eq!(c.size(), 1);
        let mut v = vec![1.5, -2.0];
        c.allreduce_inplace(&mut v, Op::Sum);
        assert_eq!(v, vec![1.5, -2.0]);
        assert_eq!(c.allreduce_scalar(7.0, Op::Min), 7.0);
        assert_eq!(c.broadcast(0, Some(vec![3.0])), vec![3.0]);
        assert_eq!(c.allgather(&[4.0]), vec![vec![4.0]]);
        assert_eq!(c.gather(0, &[5.0]).unwrap(), vec![vec![5.0]]);
        assert_eq!(c.reduce(0, &[6.0], Op::Max).unwrap(), vec![6.0]);
        assert_eq!(c.reduce_scatter_block(&[1.0, 2.0], Op::Sum), vec![1.0, 2.0]);
        c.barrier();
    }

    #[test]
    fn clock_accumulates() {
        let mut c = SelfComm::new();
        c.charge(Category::Compute, 1.25);
        let x = c.timed(Category::Learn, || 42);
        assert_eq!(x, 42);
        assert!((c.clock().in_category(Category::Compute) - 1.25).abs() < 1e-15);
        let clock = c.into_clock();
        assert!(clock.now() >= 1.25);
    }

    #[test]
    #[should_panic(expected = "provided no payload")]
    fn broadcast_without_payload_panics() {
        SelfComm::new().broadcast(0, None);
    }
}

//! SPMD thread-rank communicator with exact collectives.
//!
//! [`run`] spawns `p` rank threads executing the same closure (the MPI
//! model of the paper, Sec. III.A). Ranks synchronize through
//! [`RankCtx`] collectives backed by a shared contribution board: each
//! rank posts its payload, waits at a barrier, reduces all contributions
//! *in rank order* (bitwise-deterministic results), then passes a second
//! barrier before slots are reused.

use std::sync::{Barrier, Mutex};

use super::clock::{Category, Clock};
use super::costmodel::CostModel;
use crate::util::timer::ThreadCpuTimer;

/// Reduction operator for Allreduce.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Sum,
    Max,
    Min,
}

struct Shared {
    /// per-rank contribution slots for the active collective
    slots: Vec<Mutex<Vec<f64>>>,
    /// per-rank virtual-time postings for clock synchronization
    times: Vec<Mutex<f64>>,
    barrier: Barrier,
    model: CostModel,
}

/// Per-rank handle: rank id, collectives, and the virtual clock.
pub struct RankCtx<'a> {
    rank: usize,
    size: usize,
    shared: &'a Shared,
    clock: Clock,
}

impl<'a> RankCtx<'a> {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    /// Charge `seconds` of `category` work to this rank's virtual clock.
    pub fn charge(&mut self, category: Category, seconds: f64) {
        self.clock.add(category, seconds);
    }

    /// Run `f`, measuring its *thread CPU time* and charging it to
    /// `category`. Returns `f`'s result.
    pub fn timed<R>(&mut self, category: Category, f: impl FnOnce() -> R) -> R {
        let t = ThreadCpuTimer::start();
        let out = f();
        self.clock.add(category, t.elapsed());
        out
    }

    /// Post this rank's payload + clock, wait for all, then fold every
    /// rank's payload in rank order with `fold`. Advances clocks to
    /// max-entry + modeled cost.
    fn collective<T>(
        &mut self,
        payload: Vec<f64>,
        modeled_cost: f64,
        fold: impl FnOnce(&[Vec<f64>]) -> T,
    ) -> T {
        *self.shared.slots[self.rank].lock().unwrap() = payload;
        *self.shared.times[self.rank].lock().unwrap() = self.clock.now();
        self.shared.barrier.wait();

        // every rank reads all contributions; rank-ordered fold
        let contributions: Vec<Vec<f64>> = (0..self.size)
            .map(|i| self.shared.slots[i].lock().unwrap().clone())
            .collect();
        let max_entry = (0..self.size)
            .map(|i| *self.shared.times[i].lock().unwrap())
            .fold(0.0, f64::max);
        let out = fold(&contributions);

        // second barrier: nobody reuses slots until everyone has read
        self.shared.barrier.wait();
        self.clock.sync_to(max_entry + modeled_cost);
        out
    }

    /// MPI_Allreduce over an f64 vector. All ranks receive the result.
    pub fn allreduce(&mut self, data: &[f64], op: Op) -> Vec<f64> {
        let bytes = data.len() * 8;
        let cost = self.shared.model.allreduce(self.size, bytes);
        let n = data.len();
        self.collective(data.to_vec(), cost, |parts| {
            let mut acc = vec![
                match op {
                    Op::Sum => 0.0,
                    Op::Max => f64::NEG_INFINITY,
                    Op::Min => f64::INFINITY,
                };
                n
            ];
            for part in parts {
                assert_eq!(part.len(), n, "allreduce length mismatch across ranks");
                for (a, &v) in acc.iter_mut().zip(part) {
                    match op {
                        Op::Sum => *a += v,
                        Op::Max => *a = a.max(v),
                        Op::Min => *a = a.min(v),
                    }
                }
            }
            acc
        })
    }

    /// Scalar Allreduce convenience.
    pub fn allreduce_scalar(&mut self, x: f64, op: Op) -> f64 {
        self.allreduce(&[x], op)[0]
    }

    /// MPI_Bcast: `root` provides `data`; everyone receives a copy.
    pub fn broadcast(&mut self, root: usize, data: Option<Vec<f64>>) -> Vec<f64> {
        assert!(root < self.size);
        if self.rank == root {
            assert!(data.is_some(), "root must provide broadcast payload");
        }
        let payload = if self.rank == root { data.unwrap() } else { Vec::new() };
        let bytes = payload.len() * 8;
        // non-roots do not know the size yet; cost is computed from the
        // root's payload length after exchange — approximate with own
        // knowledge (root's bytes dominate; non-root cost equalized by
        // the max-entry sync).
        let cost = self.shared.model.broadcast(self.size, bytes);
        self.collective(payload, cost, |parts| parts[root].clone())
    }

    /// MPI_Gather to every rank (Allgather of variable-length parts).
    pub fn allgather(&mut self, data: &[f64]) -> Vec<Vec<f64>> {
        let bytes = data.len() * 8 * self.size;
        let cost = self.shared.model.allreduce(self.size, bytes);
        self.collective(data.to_vec(), cost, |parts| parts.to_vec())
    }

    /// MPI_Barrier.
    pub fn barrier(&mut self) {
        let cost = self.shared.model.barrier(self.size);
        self.collective(Vec::new(), cost, |_| ());
    }
}

/// Spawn `p` rank threads running `f` and return the per-rank results in
/// rank order. Panics in any rank propagate.
pub fn run<R: Send>(
    p: usize,
    model: CostModel,
    f: impl Fn(&mut RankCtx) -> R + Send + Sync,
) -> Vec<R> {
    assert!(p >= 1, "need at least one rank");
    let shared = Shared {
        slots: (0..p).map(|_| Mutex::new(Vec::new())).collect(),
        times: (0..p).map(|_| Mutex::new(0.0)).collect(),
        barrier: Barrier::new(p),
        model,
    };
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..p)
            .map(|rank| {
                let shared = &shared;
                let f = &f;
                scope.spawn(move || {
                    let mut ctx = RankCtx { rank, size: p, shared, clock: Clock::new() };
                    let out = f(&mut ctx);
                    (out, ctx.clock)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("rank panicked").0).collect()
    })
}

/// Like [`run`], but also returns each rank's final [`Clock`].
pub fn run_with_clocks<R: Send>(
    p: usize,
    model: CostModel,
    f: impl Fn(&mut RankCtx) -> R + Send + Sync,
) -> Vec<(R, Clock)> {
    assert!(p >= 1, "need at least one rank");
    let shared = Shared {
        slots: (0..p).map(|_| Mutex::new(Vec::new())).collect(),
        times: (0..p).map(|_| Mutex::new(0.0)).collect(),
        barrier: Barrier::new(p),
        model,
    };
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..p)
            .map(|rank| {
                let shared = &shared;
                let f = &f;
                scope.spawn(move || {
                    let mut ctx = RankCtx { rank, size: p, shared, clock: Clock::new() };
                    let out = f(&mut ctx);
                    (out, ctx.clock)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("rank panicked")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_sum_exact() {
        let results = run(4, CostModel::free(), |ctx| {
            let mine = vec![ctx.rank() as f64, 1.0];
            ctx.allreduce(&mine, Op::Sum)
        });
        for r in &results {
            assert_eq!(r, &vec![0.0 + 1.0 + 2.0 + 3.0, 4.0]);
        }
    }

    #[test]
    fn allreduce_max_min() {
        let results = run(3, CostModel::free(), |ctx| {
            let x = (ctx.rank() as f64 - 1.0) * 2.5;
            (ctx.allreduce_scalar(x, Op::Max), ctx.allreduce_scalar(x, Op::Min))
        });
        for (mx, mn) in &results {
            assert_eq!(*mx, 2.5);
            assert_eq!(*mn, -2.5);
        }
    }

    #[test]
    fn broadcast_from_nonzero_root() {
        let results = run(4, CostModel::free(), |ctx| {
            let payload = (ctx.rank() == 2).then(|| vec![7.0, 8.0, 9.0]);
            ctx.broadcast(2, payload)
        });
        for r in &results {
            assert_eq!(r, &vec![7.0, 8.0, 9.0]);
        }
    }

    #[test]
    fn allgather_preserves_rank_order() {
        let results = run(3, CostModel::free(), |ctx| ctx.allgather(&[ctx.rank() as f64]));
        for r in &results {
            assert_eq!(r, &vec![vec![0.0], vec![1.0], vec![2.0]]);
        }
    }

    #[test]
    fn sequence_of_collectives() {
        // exercise slot reuse across many rounds
        let results = run(4, CostModel::free(), |ctx| {
            let mut acc = 0.0;
            for round in 0..20 {
                acc += ctx.allreduce_scalar((ctx.rank() + round) as f64, Op::Sum);
                ctx.barrier();
            }
            acc
        });
        let expect: f64 = (0..20).map(|r| (0..4).map(|k| (k + r) as f64).sum::<f64>()).sum();
        for r in &results {
            assert_eq!(*r, expect);
        }
    }

    #[test]
    fn deterministic_sum_order() {
        // results must be identical across repeated runs (rank-ordered fold)
        let vals = [1e16, 1.0, -1e16, 3.0];
        let run_once = || {
            run(4, CostModel::free(), |ctx| {
                ctx.allreduce_scalar(vals[ctx.rank()], Op::Sum)
            })[0]
        };
        let first = run_once();
        for _ in 0..5 {
            assert_eq!(run_once(), first);
        }
    }

    #[test]
    fn clocks_sync_at_collectives() {
        let results = super::run_with_clocks(2, CostModel::shared_memory(), |ctx| {
            if ctx.rank() == 0 {
                ctx.charge(Category::Compute, 1.0);
            } else {
                ctx.charge(Category::Compute, 3.0);
            }
            ctx.allreduce_scalar(1.0, Op::Sum);
            ctx.clock().now()
        });
        // both ranks end at >= 3.0 (max entry) and equal virtual time
        let t0 = results[0].0;
        let t1 = results[1].0;
        assert!(t0 >= 3.0 && (t0 - t1).abs() < 1e-12, "{t0} vs {t1}");
        // rank 0 waited ~2s in comm
        assert!(results[0].1.in_category(Category::Comm) >= 2.0);
    }

    #[test]
    fn single_rank_works() {
        let results = run(1, CostModel::shared_memory(), |ctx| {
            ctx.barrier();
            ctx.allreduce_scalar(5.0, Op::Sum)
        });
        assert_eq!(results, vec![5.0]);
    }

    #[test]
    fn timed_charges_cpu() {
        let results = super::run_with_clocks(2, CostModel::free(), |ctx| {
            ctx.timed(Category::Learn, || {
                let mut acc = 0u64;
                for i in 0..500_000u64 {
                    acc = acc.wrapping_add(i * i);
                }
                std::hint::black_box(acc)
            });
            ctx.clock().in_category(Category::Learn)
        });
        for (learn, _) in &results {
            assert!(*learn > 0.0);
        }
    }
}

//! The transport-abstracted collective vocabulary.
//!
//! [`Communicator`] is the SPMD contract the dOpInf pipeline (paper
//! Sec. III.A) is written against: one instance per rank, collective
//! methods called by every rank of the group in the same order. Three
//! backends implement it:
//!
//! * [`super::thread::RankCtx`] — shared-board thread transport (the
//!   default; exact collectives between rank threads of one process),
//! * [`super::selfcomm::SelfComm`] — zero-overhead p = 1 backend (no
//!   threads, no barriers; every collective is the identity),
//! * [`super::socket::SocketComm`] — localhost TCP transport
//!   (length-prefixed frames, rank 0 as rendezvous hub).
//!
//! All reductions funnel through [`fold`]: contributions are combined
//! in rank order, so every backend produces bitwise-identical results
//! regardless of thread scheduling or packet arrival order.
//!
//! Every collective returns `Result<T, CommError>` and every backend
//! supports **abort broadcast** ([`Communicator::abort`]): a rank that
//! fails mid-pipeline poisons the group, waking peers parked at any
//! collective with [`CommError::RemoteAbort`] instead of hanging.

use super::clock::{Category, Clock};
use super::error::{CommError, CommResult};
use crate::obs::Tracer;
use crate::util::timer::ThreadCpuTimer;

/// Reduction operator for reducing collectives (MPI_Op subset).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Sum,
    Max,
    Min,
}

/// Rank-ordered reduction kernels shared by every transport backend.
///
/// Keeping the fold in one place is what makes the "bitwise identical
/// across transports" guarantee hold by construction: the thread board,
/// the socket hub, and the single-rank backend all combine the same
/// rank-ordered contribution list through these functions.
pub mod fold {
    use super::super::error::CommError;
    use super::Op;

    /// Identity element of `op`.
    pub fn identity(op: Op) -> f64 {
        match op {
            Op::Sum => 0.0,
            Op::Max => f64::NEG_INFINITY,
            Op::Min => f64::INFINITY,
        }
    }

    /// Fold `part` into `acc` elementwise.
    pub fn accumulate(acc: &mut [f64], part: &[f64], op: Op) {
        assert_eq!(acc.len(), part.len(), "collective length mismatch across ranks");
        for (a, &v) in acc.iter_mut().zip(part) {
            match op {
                Op::Sum => *a += v,
                Op::Max => *a = a.max(v),
                Op::Min => *a = a.min(v),
            }
        }
    }

    /// Reduce rank-ordered contributions into `out` (rank 0 first —
    /// the fixed order that makes results deterministic).
    pub fn reduce_into(parts: &[Vec<f64>], out: &mut [f64], op: Op) {
        out.fill(identity(op));
        for part in parts {
            accumulate(out, part, op);
        }
    }

    /// Reduce rank-ordered contributions into a fresh vector.
    pub fn reduce_parts(parts: &[Vec<f64>], op: Op) -> Vec<f64> {
        let n = parts.first().map_or(0, Vec::len);
        let mut out = vec![identity(op); n];
        for part in parts {
            accumulate(&mut out, part, op);
        }
        out
    }

    /// The first contribution whose length differs from rank 0's, as
    /// `(rank, its_len, rank0_len)` — backends turn this into a typed
    /// `CommError::ContractViolation` on every rank *before* folding
    /// ([`accumulate`] itself asserts, which would poison the group
    /// with a panic instead of the typed error).
    pub fn mismatched_length(parts: &[Vec<f64>]) -> Option<(usize, usize, usize)> {
        let want = parts.first().map_or(0, Vec::len);
        parts
            .iter()
            .enumerate()
            .find(|(_, p)| p.len() != want)
            .map(|(i, p)| (i, p.len(), want))
    }

    /// [`mismatched_length`] as the typed error every backend reports:
    /// one shared construction keeps the wording identical across
    /// transports. `rank` is the rank the violation is detected on.
    pub fn length_violation(what: &str, rank: usize, parts: &[Vec<f64>]) -> Option<CommError> {
        mismatched_length(parts).map(|(i, got, want)| CommError::ContractViolation {
            rank,
            message: format!(
                "{what} length mismatch: rank {i} contributed {got} elements, rank 0 {want}"
            ),
        })
    }

    /// Broadcast payload-contract guard over every rank's
    /// provided-payload flag (the root provides `Some`, everyone else
    /// `None`). Shared by the backends so the wording — and which rank
    /// the error is tagged with (`rank`, the detecting rank) — cannot
    /// drift between transports.
    pub fn broadcast_violation(root: usize, provided: &[bool], rank: usize) -> Option<CommError> {
        for (i, &flag) in provided.iter().enumerate() {
            if i == root && !flag {
                return Some(CommError::ContractViolation {
                    rank,
                    message: format!(
                        "broadcast(root={root}) — root rank {root} provided no payload"
                    ),
                });
            }
            if i != root && flag {
                return Some(CommError::ContractViolation {
                    rank,
                    message: format!(
                        "broadcast(root={root}) — non-root rank {i} passed Some(..); \
                         only the root provides the payload"
                    ),
                });
            }
        }
        None
    }

    /// `reduce_scatter_block` divisibility guard over every rank's
    /// contribution length (validated after the exchange so the whole
    /// group observes the same typed error).
    pub fn divisibility_violation(
        parts: &[Vec<f64>],
        size: usize,
        rank: usize,
    ) -> Option<CommError> {
        parts.iter().enumerate().find(|(_, p)| p.len() % size != 0).map(|(i, p)| {
            CommError::ContractViolation {
                rank,
                message: format!(
                    "reduce_scatter_block — rank {i}'s length {} not divisible by p = {size}",
                    p.len()
                ),
            }
        })
    }

    /// Rank `rank`'s block of an evenly divided reduced vector
    /// (MPI_Reduce_scatter_block semantics: `reduced.len()` must be a
    /// multiple of `size`).
    pub fn block(reduced: &[f64], rank: usize, size: usize) -> Vec<f64> {
        assert_eq!(
            reduced.len() % size,
            0,
            "reduce_scatter_block length {} not divisible by p = {size}",
            reduced.len()
        );
        let chunk = reduced.len() / size;
        reduced[rank * chunk..(rank + 1) * chunk].to_vec()
    }
}

/// Transport-abstracted MPI-style communicator.
///
/// One instance per rank; every collective must be entered by all ranks
/// of the group in the same order (the usual MPI contract — detected
/// mismatches and misuse surface as [`CommError::ContractViolation`] on
/// every rank, never as a deadlock). Reductions are applied in rank
/// order on every backend, so results are bitwise deterministic and
/// transport-independent.
///
/// Every collective is fallible: a failing sibling rank that called
/// [`Communicator::abort`] wakes this rank out of any collective with
/// [`CommError::RemoteAbort`]; with a configured deadline, a silent
/// peer yields [`CommError::Timeout`]. After any failure the group is
/// poisoned — subsequent collectives fail fast with the same error.
///
/// The trait also carries the rank's virtual [`Clock`] (`clock` /
/// `charge` / `timed`) so pipeline code can bill compute and model
/// communication cost without knowing the transport.
pub trait Communicator {
    /// This rank's id in `0..size()`.
    fn rank(&self) -> usize;

    /// Number of ranks in the group (the paper's p).
    fn size(&self) -> usize;

    /// This rank's virtual clock.
    fn clock(&self) -> &Clock;

    /// Charge `seconds` of `category` work to this rank's virtual clock.
    fn charge(&mut self, category: Category, seconds: f64);

    /// This rank's span recorder (default-off; see [`crate::obs`]).
    /// Each backend owns one tracer per rank, so recording is
    /// lock-free; collectives record their telemetry internally, and
    /// pipeline code opens/closes phase spans through these accessors.
    fn tracer(&self) -> &Tracer;

    /// Mutable access to the rank's span recorder (for closing spans,
    /// recording gauges, enabling, and draining at join).
    fn tracer_mut(&mut self) -> &mut Tracer;

    /// Run `f`, measuring its *thread CPU time* and charging it to
    /// `category`. Returns `f`'s result.
    fn timed<R>(&mut self, category: Category, f: impl FnOnce() -> R) -> R
    where
        Self: Sized,
    {
        let t = ThreadCpuTimer::start();
        let out = f();
        self.charge(category, t.elapsed());
        out
    }

    /// MPI_Allreduce, in place: on return `data` holds the rank-ordered
    /// reduction of every rank's buffer. The in-place form is the
    /// primitive (the allocating [`Communicator::allreduce`] wraps it)
    /// so multi-megabyte payloads — Gram matrices, probe blocks — skip
    /// the `Vec` round-trip on the caller's side.
    fn allreduce_inplace(&mut self, data: &mut [f64], op: Op) -> CommResult<()>;

    /// MPI_Allreduce over an f64 vector. All ranks receive the result.
    fn allreduce(&mut self, data: &[f64], op: Op) -> CommResult<Vec<f64>> {
        let mut out = data.to_vec();
        self.allreduce_inplace(&mut out, op)?;
        Ok(out)
    }

    /// Scalar Allreduce convenience.
    fn allreduce_scalar(&mut self, x: f64, op: Op) -> CommResult<f64> {
        let mut out = [x];
        self.allreduce_inplace(&mut out, op)?;
        Ok(out[0])
    }

    /// MPI_Bcast: `root` passes `Some(data)`, every other rank `None`;
    /// everyone receives the root's payload. Contract violations (a
    /// non-root passing `Some`, the root passing `None`) yield
    /// [`CommError::ContractViolation`] on every rank instead of
    /// deadlocking.
    fn broadcast(&mut self, root: usize, data: Option<Vec<f64>>) -> CommResult<Vec<f64>>;

    /// MPI_Allgather of variable-length parts: every rank receives
    /// every rank's contribution, in rank order.
    fn allgather(&mut self, data: &[f64]) -> CommResult<Vec<Vec<f64>>>;

    /// MPI_Gather: contributions travel to `root` only, which receives
    /// them in rank order; every other rank gets `None`. On a real
    /// network transport this is ~p× cheaper than [`Communicator::allgather`]
    /// when only the root consumes the result.
    fn gather(&mut self, root: usize, data: &[f64]) -> CommResult<Option<Vec<Vec<f64>>>>;

    /// MPI_Reduce: the rank-ordered reduction lands on `root` only;
    /// every other rank gets `None`.
    fn reduce(&mut self, root: usize, data: &[f64], op: Op) -> CommResult<Option<Vec<f64>>>;

    /// MPI_Reduce_scatter_block: reduce, then scatter equal blocks —
    /// rank i receives elements `[i·n/p, (i+1)·n/p)` of the reduction.
    /// `data.len()` must be a multiple of `size()`.
    fn reduce_scatter_block(&mut self, data: &[f64], op: Op) -> CommResult<Vec<f64>>;

    /// MPI_Barrier.
    fn barrier(&mut self) -> CommResult<()>;

    /// Shared guard for the rooted collectives: an out-of-range `root`
    /// is a local, deterministic contract violation (no exchange has
    /// happened, so no peer is parked on this rank's contribution).
    fn check_root(&self, what: &str, root: usize) -> CommResult<()> {
        if root < self.size() {
            Ok(())
        } else {
            Err(CommError::ContractViolation {
                rank: self.rank(),
                message: format!("{what} root {root} out of range (size {})", self.size()),
            })
        }
    }

    /// Abort broadcast — the recoverable analogue of `MPI_Abort`: this
    /// rank failed, so poison the group and wake every peer parked at
    /// any collective with [`CommError::RemoteAbort`].
    ///
    /// Returns the canonical group abort for *this* rank to propagate:
    /// the first abort wins, so if a sibling already aborted (or this
    /// rank already observed a failure) the existing rank-tagged error
    /// is returned unchanged — `abort` is idempotent and never blocks.
    fn abort(&mut self, message: &str) -> CommError;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fold_identities() {
        assert_eq!(fold::identity(Op::Sum), 0.0);
        assert_eq!(fold::identity(Op::Max), f64::NEG_INFINITY);
        assert_eq!(fold::identity(Op::Min), f64::INFINITY);
    }

    #[test]
    fn reduce_parts_in_rank_order() {
        let parts = vec![vec![1.0, 10.0], vec![2.0, -3.0], vec![4.0, 0.5]];
        assert_eq!(fold::reduce_parts(&parts, Op::Sum), vec![7.0, 7.5]);
        assert_eq!(fold::reduce_parts(&parts, Op::Max), vec![4.0, 10.0]);
        assert_eq!(fold::reduce_parts(&parts, Op::Min), vec![1.0, -3.0]);
    }

    #[test]
    fn reduce_into_matches_reduce_parts() {
        let parts = vec![vec![1e16, 1.0], vec![-1e16, 3.0]];
        let mut out = vec![99.0, 99.0];
        fold::reduce_into(&parts, &mut out, Op::Sum);
        assert_eq!(out, fold::reduce_parts(&parts, Op::Sum));
    }

    #[test]
    fn block_slices_evenly() {
        let reduced = vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(fold::block(&reduced, 0, 3), vec![0.0, 1.0]);
        assert_eq!(fold::block(&reduced, 2, 3), vec![4.0, 5.0]);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn block_rejects_ragged_length() {
        fold::block(&[1.0, 2.0, 3.0], 0, 2);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn accumulate_rejects_mismatched_lengths() {
        let mut acc = vec![0.0; 2];
        fold::accumulate(&mut acc, &[1.0, 2.0, 3.0], Op::Sum);
    }

    #[test]
    fn mismatched_length_finds_the_first_ragged_rank() {
        assert_eq!(fold::mismatched_length(&[]), None);
        assert_eq!(fold::mismatched_length(&[vec![1.0], vec![2.0]]), None);
        assert_eq!(
            fold::mismatched_length(&[vec![1.0, 2.0], vec![3.0], vec![4.0]]),
            Some((1, 1, 2))
        );
    }
}

//! Process-rank transport: real OS worker processes behind the socket
//! wire protocol.
//!
//! The socket transport ([`super::socket`]) already speaks a fully
//! transport-real protocol — length-prefixed frames over TCP with rank
//! 0 as the hub — but runs every rank as a thread of one process. This
//! module is the missing launch layer: the parent process *is* rank 0,
//! and ranks 1..p are spawned `dopinf worker` processes that connect
//! back to the parent's rendezvous listener. Because both sides reuse
//! [`SocketComm`] unchanged, every collective is bitwise identical to
//! the thread and socket backends by construction.
//!
//! ## Lifecycle
//!
//! ```text
//! parent (rank 0)                      worker i (rank i, i = 1..p)
//! ─────────────────                    ───────────────────────────
//! bind 127.0.0.1:0
//! spawn p-1 workers  ────argv────────▶ dopinf worker --rank i --size p
//!                                          --hub 127.0.0.1:PORT ...
//! hub_rendezvous     ◀───hello(i)───── leaf_rendezvous
//! send job frame     ────tag|bytes───▶ decode job (exercise/pipeline)
//! run rank-0 fn      ◀──collectives──▶ run the same fn (SocketComm)
//! read join frames   ◀───join(i)────── clock parts | trace | outcome
//! reap children                        exit
//! ```
//!
//! The join frame rides the same stream the collectives used, after
//! the last collective: clock parts round-trip bitwise
//! ([`Clock::from_parts`]), the worker's [`RankTrace`] crosses the
//! boundary so `--trace` still shows one track per rank, and the
//! worker's result (or typed failure) is rank-tagged for the runner's
//! error aggregation.
//!
//! ## Failure semantics
//!
//! A worker that dies mid-collective (e.g. SIGKILL) closes its socket;
//! the hub's readiness poll observes EOF and fans
//! [`CommError::RemoteAbort`] out to every survivor immediately — the
//! group never hangs past the configured timeout. A worker that dies
//! *between* the last collective and the join frame surfaces the same
//! way when the parent reads its join. Stuck children are killed at
//! reap time (and on parent panic, via the reaper's `Drop`).

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::clock::{Clock, ALL_CATEGORIES};
use super::communicator::{Communicator, Op};
use super::costmodel::CostModel;
use super::error::{CommError, CommResult};
use super::socket::{self, SocketComm};
use crate::obs::{CommRecord, RankTrace, Span};
use crate::util::codec;
use crate::util::rng::Rng;

/// Job-frame tags (hub → worker, right after the hello).
pub(crate) const JOB_EXERCISE: u8 = 0;
pub(crate) const JOB_PIPELINE: u8 = 1;
/// First byte of a join frame (worker → hub, after the last
/// collective); distinct from the collective/abort frame markers so a
/// desynced stream is caught instead of misparsed.
const JOIN_MARKER: u8 = 9;

/// Resolve the binary worker ranks are spawned from: the
/// `DOPINF_WORKER_BIN` override (tests and benches set it to the
/// `dopinf` binary Cargo built, since their own executable has no
/// `worker` subcommand), else this executable.
pub fn worker_binary() -> Result<std::path::PathBuf, CommError> {
    if let Ok(p) = std::env::var("DOPINF_WORKER_BIN") {
        return Ok(std::path::PathBuf::from(p));
    }
    std::env::current_exe().map_err(|e| CommError::Transport {
        rank: 0,
        message: format!("resolving the worker binary: {e}"),
    })
}

/// Per-worker runtime knobs forwarded on the worker command line.
#[derive(Clone, Debug, Default)]
pub struct WorkerKnobs {
    /// `--threads N` (compute threads per rank)
    pub threads_per_rank: Option<usize>,
    /// `--simd TIER` (kernel dispatch tier)
    pub simd: Option<String>,
}

/// Everything [`launch`] needs to start a process group.
pub(crate) struct LaunchSpec {
    pub p: usize,
    pub model: CostModel,
    pub timeout: Option<Duration>,
    /// job frame: `tag u8 | len u64 | bytes`, identical for every
    /// worker (each worker already knows its rank from argv)
    pub job_tag: u8,
    pub job: Vec<u8>,
    pub knobs: WorkerKnobs,
}

/// A launched process group: the parent's rank-0 hub handle plus the
/// child processes. Run the rank-0 function against `hub`, then call
/// [`Launched::join`].
pub(crate) struct Launched {
    pub hub: SocketComm,
    reaper: Reaper,
    timeout: Option<Duration>,
}

/// Child processes with kill-on-drop: if the parent unwinds before
/// [`Launched::join`] reaps gracefully, the workers are not leaked.
struct Reaper {
    children: Vec<Child>,
}

impl Reaper {
    /// Graceful reap: poll `try_wait` until `grace` elapses, then kill
    /// whatever is left. Every child is waited on (no zombies).
    fn reap(&mut self, grace: Duration) {
        let deadline = Instant::now() + grace;
        for c in &mut self.children {
            loop {
                match c.try_wait() {
                    Ok(Some(_)) => break,
                    Err(_) => break,
                    Ok(None) => {
                        if Instant::now() >= deadline {
                            let _ = c.kill();
                            let _ = c.wait();
                            break;
                        }
                        std::thread::sleep(Duration::from_millis(2));
                    }
                }
            }
        }
        self.children.clear();
    }
}

impl Drop for Reaper {
    fn drop(&mut self) {
        for c in &mut self.children {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

impl Launched {
    /// OS process ids of the workers, in rank order (rank i ↔ index
    /// i - 1). Fault-injection tests SIGKILL one of these
    /// mid-collective.
    pub fn worker_pids(&self) -> Vec<u32> {
        self.reaper.children.iter().map(Child::id).collect()
    }

    /// After the rank-0 function has returned: recover the hub's
    /// clock/tracer, read every worker's join report (rank order, each
    /// read under the stream timeout), and reap the children.
    pub fn join(self) -> (Clock, crate::obs::Tracer, Vec<JoinReport>) {
        let Launched { hub, mut reaper, timeout } = self;
        let (clock, tracer, mut streams) = hub.into_parts();
        let reports: Vec<JoinReport> = streams
            .iter_mut()
            .enumerate()
            .map(|(i, s)| read_join(s, i + 1, timeout))
            .collect();
        drop(streams);
        reaper.reap(timeout.unwrap_or(Duration::from_secs(5)));
        (clock, tracer, reports)
    }
}

/// Spawn `p - 1` worker processes, rendezvous, and ship the job frame.
/// The returned [`Launched::hub`] is rank 0 of the group; `p == 1`
/// spawns nothing and degenerates to a lone hub.
pub(crate) fn launch(spec: LaunchSpec) -> Result<Launched, CommError> {
    assert!(spec.p >= 1, "need at least one rank");
    let listener = TcpListener::bind(("127.0.0.1", 0)).map_err(|e| CommError::Transport {
        rank: 0,
        message: format!("binding the rendezvous listener: {e}"),
    })?;
    let port = listener
        .local_addr()
        .map_err(|e| CommError::Transport {
            rank: 0,
            message: format!("reading the rendezvous listener address: {e}"),
        })?
        .port();
    let bin = worker_binary()?;
    let mut reaper = Reaper { children: Vec::with_capacity(spec.p.saturating_sub(1)) };
    for rank in 1..spec.p {
        let mut cmd = Command::new(&bin);
        cmd.arg("worker")
            .arg("--rank")
            .arg(rank.to_string())
            .arg("--size")
            .arg(spec.p.to_string())
            .arg("--hub")
            .arg(format!("127.0.0.1:{port}"))
            .stdin(Stdio::null());
        if let Some(t) = spec.timeout {
            cmd.arg("--comm-timeout").arg(format!("{}", t.as_secs_f64()));
        }
        if let Some(n) = spec.knobs.threads_per_rank {
            cmd.arg("--threads").arg(n.to_string());
        }
        if let Some(tier) = &spec.knobs.simd {
            cmd.arg("--simd").arg(tier);
        }
        let child = cmd.spawn().map_err(|e| CommError::Transport {
            rank: 0,
            message: format!("spawning worker rank {rank} from {}: {e}", bin.display()),
        })?;
        reaper.children.push(child);
    }
    let streams = socket::hub_rendezvous(&listener, spec.p, spec.timeout)?;
    let mut streams = streams;
    for (i, s) in streams.iter_mut().enumerate() {
        write_job(s, spec.job_tag, &spec.job).map_err(|e| {
            socket::io_error(0, spec.timeout, &format!("sending the job to rank {}", i + 1), e)
        })?;
    }
    let hub = SocketComm::hub_from_streams(spec.p, streams, spec.model, spec.timeout);
    Ok(Launched { hub, reaper, timeout: spec.timeout })
}

fn write_job(stream: &mut TcpStream, tag: u8, job: &[u8]) -> std::io::Result<()> {
    let mut buf = Vec::with_capacity(9 + job.len());
    codec::write_u8(&mut buf, tag).expect("vec write");
    codec::write_bytes(&mut buf, job).expect("vec write");
    stream.write_all(&buf)
}

// ---------------------------------------------------------------- worker side

/// argv-shipped identity of a spawned worker (`dopinf worker ...`).
#[derive(Clone, Debug)]
pub struct WorkerBoot {
    pub rank: usize,
    pub size: usize,
    /// hub rendezvous address, `host:port`
    pub hub: String,
    pub timeout: Option<Duration>,
}

/// Worker rendezvous: connect, send the hello, read the job frame.
/// Returns the raw stream (the job may carry the cost model the
/// [`SocketComm`] is then built with) plus the job tag and bytes.
pub(crate) fn worker_connect(boot: &WorkerBoot) -> Result<(TcpStream, u8, Vec<u8>), CommError> {
    let mut stream = socket::leaf_rendezvous(boot.rank, &boot.hub, boot.timeout)?;
    let tag = codec::read_u8(&mut stream)
        .map_err(|e| socket::io_error(boot.rank, boot.timeout, "job frame from the hub", e))?;
    let job = codec::read_bytes(&mut stream)
        .map_err(|e| socket::io_error(boot.rank, boot.timeout, "job frame from the hub", e))?;
    Ok((stream, tag, job))
}

/// A worker's rank-tagged failure, as shipped in the join frame.
#[derive(Clone, Debug)]
pub enum WorkerFailure {
    /// a typed collective failure — aggregated exactly like the thread
    /// transport's per-rank comm errors
    Comm(CommError),
    /// any other rank-local failure (I/O, setup, …), carried as text
    Other(String),
}

/// One worker's join report, read by the parent at group teardown.
#[derive(Debug)]
pub struct JoinReport {
    pub rank: usize,
    /// the worker's final virtual clock (bitwise-exact round-trip)
    pub clock: Clock,
    /// the worker's trace, when tracing was enabled on its rank
    pub trace: Option<RankTrace>,
    /// the job's f64 result payload, or the rank-tagged failure
    pub outcome: Result<Vec<f64>, WorkerFailure>,
}

/// Worker epilogue: tear the comm handle down and ship the join frame
/// (clock parts, trace if enabled, outcome) back to the parent on the
/// collective stream.
pub(crate) fn send_join(
    comm: SocketComm,
    timeout: Option<Duration>,
    outcome: &Result<Vec<f64>, WorkerFailure>,
) -> CommResult<()> {
    let rank = comm.rank();
    let (clock, mut tracer, mut streams) = comm.into_parts();
    let trace = tracer.is_enabled().then(|| tracer.take());
    let mut buf = Vec::new();
    codec::write_u8(&mut buf, JOIN_MARKER).expect("vec write");
    let (total, split) = clock.parts();
    codec::write_f64(&mut buf, total).expect("vec write");
    for s in split {
        codec::write_f64(&mut buf, s).expect("vec write");
    }
    codec::write_bool(&mut buf, trace.is_some()).expect("vec write");
    if let Some(t) = &trace {
        push_trace(&mut buf, t);
    }
    match outcome {
        Ok(v) => {
            codec::write_u8(&mut buf, 0).expect("vec write");
            codec::write_f64s(&mut buf, v).expect("vec write");
        }
        Err(WorkerFailure::Comm(e)) => {
            codec::write_u8(&mut buf, 1).expect("vec write");
            socket::push_comm_error(&mut buf, e);
        }
        Err(WorkerFailure::Other(msg)) => {
            codec::write_u8(&mut buf, 2).expect("vec write");
            codec::write_str(&mut buf, msg).expect("vec write");
        }
    }
    streams[0]
        .write_all(&buf)
        .map_err(|e| socket::io_error(rank, timeout, "sending the join report", e))
}

fn read_join(stream: &mut TcpStream, rank: usize, timeout: Option<Duration>) -> JoinReport {
    match try_read_join(stream, rank) {
        Ok(report) => report,
        Err(e) => {
            let failure = if e.kind() == std::io::ErrorKind::UnexpectedEof {
                // in lockstep SPMD the worker never closes its stream
                // before the join frame: its process died
                CommError::RemoteAbort {
                    origin_rank: rank,
                    message: "worker exited without a join report (process died)".to_string(),
                }
            } else {
                socket::io_error(rank, timeout, "join report", e)
            };
            JoinReport {
                rank,
                clock: Clock::new(),
                trace: None,
                outcome: Err(WorkerFailure::Comm(failure)),
            }
        }
    }
}

fn try_read_join(stream: &mut TcpStream, rank: usize) -> std::io::Result<JoinReport> {
    let marker = codec::read_u8(stream)?;
    if marker != JOIN_MARKER {
        return Err(codec::corrupt(format!("join marker {marker}")));
    }
    let total = codec::read_f64(stream)?;
    let mut split = [0.0f64; 5];
    for s in &mut split {
        *s = codec::read_f64(stream)?;
    }
    let clock = Clock::from_parts(total, split);
    let trace = if codec::read_bool(stream)? { Some(read_trace(stream)?) } else { None };
    let outcome = match codec::read_u8(stream)? {
        0 => Ok(codec::read_f64s(stream)?),
        1 => Err(WorkerFailure::Comm(socket::read_comm_error(stream)?)),
        2 => Err(WorkerFailure::Other(codec::read_str(stream)?)),
        other => return Err(codec::corrupt(format!("join outcome tag {other}"))),
    };
    Ok(JoinReport { rank, clock, trace, outcome })
}

// ------------------------------------------------------------- trace transfer

/// Intern a wire string into the `&'static str` the trace structs
/// carry. Trace labels come from a small fixed vocabulary ("pass1",
/// "allreduce", "intra", …), so the leak is bounded by that vocabulary,
/// not by the number of joins.
fn intern(s: String) -> &'static str {
    static CACHE: Mutex<Vec<&'static str>> = Mutex::new(Vec::new());
    let mut cache = CACHE.lock().unwrap();
    if let Some(hit) = cache.iter().find(|&&c| c == s) {
        return hit;
    }
    let leaked: &'static str = Box::leak(s.into_boxed_str());
    cache.push(leaked);
    leaked
}

fn category_byte(c: crate::comm::Category) -> u8 {
    ALL_CATEGORIES.iter().position(|&x| x == c).expect("category in ALL_CATEGORIES") as u8
}

fn push_trace(buf: &mut Vec<u8>, t: &RankTrace) {
    codec::write_usize(buf, t.rank).expect("vec write");
    codec::write_usize(buf, t.spans.len()).expect("vec write");
    for s in &t.spans {
        codec::write_str(buf, s.label).expect("vec write");
        codec::write_u8(buf, category_byte(s.category)).expect("vec write");
        codec::write_f64(buf, s.start_s).expect("vec write");
        codec::write_f64(buf, s.dur_s).expect("vec write");
    }
    codec::write_usize(buf, t.comm.len()).expect("vec write");
    for c in &t.comm {
        codec::write_str(buf, c.primitive).expect("vec write");
        codec::write_str(buf, c.link).expect("vec write");
        codec::write_usize(buf, c.bytes).expect("vec write");
        codec::write_f64(buf, c.predicted_s).expect("vec write");
        codec::write_f64(buf, c.measured_s).expect("vec write");
        codec::write_f64(buf, c.wait_s).expect("vec write");
        codec::write_f64(buf, c.start_s).expect("vec write");
    }
    codec::write_usize(buf, t.gauges.len()).expect("vec write");
    for (name, value) in &t.gauges {
        codec::write_str(buf, name).expect("vec write");
        codec::write_f64(buf, *value).expect("vec write");
    }
}

fn read_trace(r: &mut impl std::io::Read) -> std::io::Result<RankTrace> {
    let rank = codec::read_usize(r)?;
    let n_spans = codec::read_usize(r)?;
    let mut spans = Vec::with_capacity(n_spans);
    for _ in 0..n_spans {
        let label = intern(codec::read_str(r)?);
        let cat = codec::read_u8(r)?;
        let category = *ALL_CATEGORIES
            .get(cat as usize)
            .ok_or_else(|| codec::corrupt(format!("category byte {cat}")))?;
        let start_s = codec::read_f64(r)?;
        let dur_s = codec::read_f64(r)?;
        spans.push(Span { label, category, start_s, dur_s });
    }
    let n_comm = codec::read_usize(r)?;
    let mut comm = Vec::with_capacity(n_comm);
    for _ in 0..n_comm {
        comm.push(CommRecord {
            primitive: intern(codec::read_str(r)?),
            link: intern(codec::read_str(r)?),
            bytes: codec::read_usize(r)?,
            predicted_s: codec::read_f64(r)?,
            measured_s: codec::read_f64(r)?,
            wait_s: codec::read_f64(r)?,
            start_s: codec::read_f64(r)?,
        });
    }
    let n_gauges = codec::read_usize(r)?;
    let mut gauges = std::collections::BTreeMap::new();
    for _ in 0..n_gauges {
        let name = intern(codec::read_str(r)?);
        gauges.insert(name, codec::read_f64(r)?);
    }
    Ok(RankTrace { rank, enabled: true, spans, comm, gauges })
}

// ------------------------------------------------------------- the exercise

/// A deterministic collective workload every transport can run — the
/// cross-transport bitwise-identity probe for the process and
/// hierarchical backends (and the payload generator for their bench
/// rows). Same `(seed, rank, round)` always produces the same
/// contributions, with magnitudes spread over ~2⁹⁶ so any deviation
/// from the rank-ordered fold shows up in the bits.
#[derive(Clone, Debug)]
pub struct ExerciseSpec {
    /// one primitive name, or `"mixed"` for all of them per round
    pub prim: String,
    /// payload length per rank per collective
    pub len: usize,
    pub rounds: usize,
    pub seed: u64,
    /// per-round sleep (milliseconds) — lets fault-injection tests
    /// hold the group mid-exercise while a worker is killed; 0 in
    /// every numeric test
    pub pause_ms: u64,
}

impl ExerciseSpec {
    pub(crate) fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        codec::write_str(&mut buf, &self.prim).expect("vec write");
        codec::write_usize(&mut buf, self.len).expect("vec write");
        codec::write_usize(&mut buf, self.rounds).expect("vec write");
        codec::write_u64(&mut buf, self.seed).expect("vec write");
        codec::write_u64(&mut buf, self.pause_ms).expect("vec write");
        buf
    }

    pub(crate) fn decode(r: &mut impl std::io::Read) -> std::io::Result<ExerciseSpec> {
        Ok(ExerciseSpec {
            prim: codec::read_str(r)?,
            len: codec::read_usize(r)?,
            rounds: codec::read_usize(r)?,
            seed: codec::read_u64(r)?,
            pause_ms: codec::read_u64(r)?,
        })
    }
}

/// Run the exercise on one rank of any transport. The returned digest
/// vector is what the bitwise-identity tests compare across backends.
pub fn exercise_rank<C: Communicator>(ctx: &mut C, spec: &ExerciseSpec) -> CommResult<Vec<f64>> {
    let (rank, size) = (ctx.rank(), ctx.size());
    let mut out = Vec::new();
    for round in 0..spec.rounds {
        if spec.pause_ms > 0 {
            std::thread::sleep(Duration::from_millis(spec.pause_ms));
        }
        let mut rng = Rng::new(spec.seed ^ ((rank as u64) << 32) ^ round as u64);
        let data: Vec<f64> = (0..spec.len)
            .map(|_| {
                let mantissa = rng.range(-1.0, 1.0);
                let exponent = rng.below(33) as i32 - 16;
                mantissa * 2.0f64.powi(exponent * 3)
            })
            .collect();
        let root = round % size;
        let prims: &[&str] = if spec.prim == "mixed" {
            &["allreduce", "broadcast", "allgather", "gather", "reduce", "reduce_scatter",
              "barrier"]
        } else {
            &[]
        };
        let single = [spec.prim.as_str()];
        let prims = if prims.is_empty() { &single[..] } else { prims };
        for prim in prims {
            match *prim {
                "allreduce" => out.extend(ctx.allreduce(&data, Op::Sum)?),
                "broadcast" => {
                    let payload = (rank == root).then(|| data.clone());
                    out.extend(ctx.broadcast(root, payload)?);
                }
                "allgather" => {
                    for part in ctx.allgather(&data)? {
                        out.extend(part);
                    }
                }
                "gather" => match ctx.gather(root, &data)? {
                    Some(parts) => {
                        for part in parts {
                            out.extend(part);
                        }
                    }
                    None => out.push(-1.0),
                },
                "reduce" => match ctx.reduce(root, &data, Op::Max)? {
                    Some(reduced) => out.extend(reduced),
                    None => out.push(-2.0),
                },
                "reduce_scatter" => {
                    let n = spec.len.div_ceil(size).max(1) * size;
                    let block: Vec<f64> = data.iter().cycle().take(n).copied().collect();
                    out.extend(ctx.reduce_scatter_block(&block, Op::Sum)?);
                }
                "barrier" => {
                    ctx.barrier()?;
                    out.push(round as f64);
                }
                other => {
                    return Err(CommError::ContractViolation {
                        rank,
                        message: format!("unknown exercise primitive {other:?}"),
                    })
                }
            }
        }
    }
    Ok(out)
}

/// Launch a process group that runs [`exercise_rank`] on every rank
/// and return `(outcome, clock)` per rank, rank 0 first. `on_spawn`
/// sees the worker PIDs right after the spawn — fault-injection tests
/// use it to SIGKILL a worker mid-exercise.
pub fn run_exercise(
    p: usize,
    model: CostModel,
    timeout: Option<Duration>,
    spec: &ExerciseSpec,
    on_spawn: impl FnOnce(&[u32]),
) -> Result<Vec<(Result<Vec<f64>, WorkerFailure>, Clock)>, CommError> {
    let mut launched = launch(LaunchSpec {
        p,
        model,
        timeout,
        job_tag: JOB_EXERCISE,
        job: encode_exercise_job(spec, model),
        knobs: WorkerKnobs::default(),
    })?;
    on_spawn(&launched.worker_pids());
    let root = exercise_rank(&mut launched.hub, spec).map_err(WorkerFailure::Comm);
    let (clock, _tracer, reports) = launched.join();
    let mut results = vec![(root, clock)];
    results.extend(reports.into_iter().map(|r| (r.outcome, r.clock)));
    Ok(results)
}

/// The exercise job frame carries the spec plus the hub's cost model,
/// so worker virtual clocks advance identically to the parent's.
fn encode_exercise_job(spec: &ExerciseSpec, model: CostModel) -> Vec<u8> {
    let mut buf = spec.encode();
    let (alpha, beta, gamma) = model.parts();
    codec::write_f64(&mut buf, alpha).expect("vec write");
    codec::write_f64(&mut buf, beta).expect("vec write");
    codec::write_f64(&mut buf, gamma).expect("vec write");
    buf
}

/// Worker-side handler for [`JOB_EXERCISE`]: build the leaf comm, run
/// the exercise, ship the join frame.
pub(crate) fn run_exercise_worker(
    boot: &WorkerBoot,
    stream: TcpStream,
    job: &[u8],
) -> CommResult<()> {
    let mut r = std::io::Cursor::new(job);
    let spec = ExerciseSpec::decode(&mut r)
        .map_err(|e| socket::io_error(boot.rank, boot.timeout, "decoding the exercise job", e))?;
    let alpha = codec::read_f64(&mut r)
        .map_err(|e| socket::io_error(boot.rank, boot.timeout, "decoding the exercise job", e))?;
    let beta = codec::read_f64(&mut r)
        .map_err(|e| socket::io_error(boot.rank, boot.timeout, "decoding the exercise job", e))?;
    let gamma = codec::read_f64(&mut r)
        .map_err(|e| socket::io_error(boot.rank, boot.timeout, "decoding the exercise job", e))?;
    let model = CostModel::from_parts(alpha, beta, gamma);
    let mut comm =
        SocketComm::leaf_from_stream(boot.rank, boot.size, stream, model, boot.timeout);
    let outcome = exercise_rank(&mut comm, &spec).map_err(WorkerFailure::Comm);
    send_join(comm, boot.timeout, &outcome)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Category;
    use std::collections::BTreeMap;

    #[test]
    fn exercise_spec_roundtrips() {
        let spec = ExerciseSpec {
            prim: "mixed".into(),
            len: 48,
            rounds: 3,
            seed: 0xDEAD_BEEF,
            pause_ms: 0,
        };
        let buf = spec.encode();
        let got = ExerciseSpec::decode(&mut std::io::Cursor::new(buf)).unwrap();
        assert_eq!(got.prim, spec.prim);
        assert_eq!((got.len, got.rounds, got.seed, got.pause_ms), (48, 3, 0xDEAD_BEEF, 0));
    }

    #[test]
    fn trace_wire_roundtrip_is_exact() {
        let t = RankTrace {
            rank: 3,
            enabled: true,
            spans: vec![Span {
                label: "pass1",
                category: Category::Load,
                start_s: 0.25,
                dur_s: 1.0 / 3.0,
            }],
            comm: vec![CommRecord {
                primitive: "allreduce",
                link: "intra",
                bytes: 4096,
                predicted_s: 1.5e-6,
                measured_s: 2.5e-6,
                wait_s: 1.0e-6,
                start_s: 0.5,
            }],
            gauges: BTreeMap::from([("peak_bytes", 1.25e6)]),
        };
        let mut buf = Vec::new();
        push_trace(&mut buf, &t);
        let got = read_trace(&mut std::io::Cursor::new(buf)).unwrap();
        assert_eq!(got.rank, 3);
        assert!(got.enabled);
        assert_eq!(got.spans.len(), 1);
        assert_eq!(got.spans[0].label, "pass1");
        assert_eq!(got.spans[0].category, Category::Load);
        assert_eq!(got.spans[0].dur_s.to_bits(), (1.0f64 / 3.0).to_bits());
        assert_eq!(got.comm.len(), 1);
        assert_eq!(got.comm[0].primitive, "allreduce");
        assert_eq!(got.comm[0].link, "intra");
        assert_eq!(got.comm[0].bytes, 4096);
        assert_eq!(got.comm[0].predicted_s.to_bits(), 1.5e-6f64.to_bits());
        assert_eq!(got.gauges.get("peak_bytes"), Some(&1.25e6));
    }

    #[test]
    fn interning_reuses_known_labels() {
        let a = intern("label-a".to_string());
        let b = intern("label-a".to_string());
        assert!(std::ptr::eq(a, b));
        assert_eq!(intern("label-b".to_string()), "label-b");
    }

    #[test]
    fn exercise_is_deterministic_per_rank_and_transport_free() {
        // same spec, same rank → same digest (SelfComm, p = 1)
        let spec =
            ExerciseSpec { prim: "mixed".into(), len: 16, rounds: 2, seed: 7, pause_ms: 0 };
        let mut a = crate::comm::SelfComm::new();
        let mut b = crate::comm::SelfComm::new();
        let da = exercise_rank(&mut a, &spec).unwrap();
        let db = exercise_rank(&mut b, &spec).unwrap();
        assert!(!da.is_empty());
        assert_eq!(
            da.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            db.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }
}

//! α–β (Hockney) communication cost model for the virtual clocks.
//!
//! Collective costs use the standard binomial-tree / recursive-doubling
//! estimates (Thakur, Rabenseifner & Gropp, IJHPCA 2005):
//!
//! * Allreduce (recursive doubling): `log2(p) · (α + n·β + n·γ)`
//! * Broadcast (binomial tree):      `log2(p) · (α + n·β)`
//! * Barrier (dissemination):        `log2(p) · α`
//! * Reduce (binomial tree):         `log2(p) · (α + n·β + n·γ)`
//! * Gather / Allgather:             `log2(p) · α + (p-1)/p · N·β`
//! * Reduce_scatter (pairwise):      `log2(p) · α + (p-1)/p · N·(β+γ)`
//!
//! where `n` is the per-rank payload and `N` the total volume across
//! ranks. The rooted primitives matter once the transport is a real
//! network: `gather` moves `(p-1)/p · N` toward one root where
//! allgather-then-discard would move `N` to every rank.
//!
//! Defaults model a shared-memory node like the paper's 256-core EPYC
//! box (α ≈ 1 µs thread sync, β ≈ 1/12 GB/s effective per-pair memory
//! bandwidth); `CostModel::cluster()` models an HPC interconnect for the
//! p→2048 projection ablation (Ref. [1] of the paper).

/// Storage read-path model for the chunked Step I ingestion charges:
/// each [`crate::io::Chunk`] bills `reads · seek_latency +
/// bytes / bandwidth` to the `Load` category, so `fig4_scaling` stays
/// honest when chunking multiplies the number of discrete read
/// operations (a chunk touching v variables issues v seeks).
#[derive(Clone, Copy, Debug)]
pub struct DiskModel {
    /// sustained sequential read bandwidth (bytes/s)
    pub bandwidth: f64,
    /// per-read-operation latency: seek + request issue (seconds)
    pub seek_latency: f64,
}

impl DiskModel {
    /// Local NVMe-class storage (the default; bandwidth matches the
    /// previous scalar `disk_bandwidth` so whole-block charges are
    /// unchanged up to the single seek).
    pub fn nvme() -> DiskModel {
        DiskModel { bandwidth: 1.5e9, seek_latency: 8.0e-5 }
    }

    /// Parallel-filesystem-class storage (HPC burst buffer / Lustre
    /// stripe): higher bandwidth, but each independent read pays more
    /// request latency.
    pub fn parallel_fs() -> DiskModel {
        DiskModel { bandwidth: 5.0e9, seek_latency: 5.0e-4 }
    }

    /// Zero-cost model (pure-correctness runs / tests).
    pub fn free() -> DiskModel {
        DiskModel { bandwidth: f64::INFINITY, seek_latency: 0.0 }
    }

    /// Modeled wall time of `reads` discrete read operations moving
    /// `bytes` in total.
    pub fn read_time(&self, reads: usize, bytes: usize) -> f64 {
        reads as f64 * self.seek_latency + bytes as f64 / self.bandwidth
    }
}

/// Intra-rank compute-plane model for the node-level scaling
/// projections: with the deterministic worker pool
/// ([`crate::linalg::par`]) a rank's `Compute` segment shrinks by the
/// Amdahl factor below, while `Load`/`Comm`/`Learn` stay serial per
/// rank (ingestion is I/O-bound, the collectives are the transport's,
/// and the grid search is already sharded across ranks). `fig4_scaling`
/// uses this to extend the measured p-sweep into a p × T table — the
/// paper's 256-core EPYC box runs p ranks × T cores each, and modeling
/// that term is what lets the strong-scaling figure speak to node-level
/// speedup instead of rank count alone.
#[derive(Clone, Copy, Debug)]
pub struct CoreModel {
    /// physical cores available to one rank (T is clamped to this)
    pub cores_per_rank: usize,
    /// fraction of a rank's compute that stays serial at any T —
    /// band-partition epilogues (the syrk mirror), carry flushes, and
    /// the sub-threshold kernels the plane leaves inline
    pub serial_fraction: f64,
}

impl CoreModel {
    /// A node slice like the paper's testbed: 8 cores per rank, a few
    /// percent serial.
    pub fn node() -> CoreModel {
        CoreModel { cores_per_rank: 8, serial_fraction: 0.05 }
    }

    /// The degenerate single-core rank (speedup ≡ 1 at every T).
    pub fn single_core() -> CoreModel {
        CoreModel { cores_per_rank: 1, serial_fraction: 1.0 }
    }

    /// Amdahl speedup of the `Compute` category at `threads` pool
    /// workers: `1 / (s + (1-s)/min(T, cores))`.
    pub fn speedup(&self, threads: usize) -> f64 {
        let t = threads.max(1).min(self.cores_per_rank.max(1)) as f64;
        let s = self.serial_fraction.clamp(0.0, 1.0);
        1.0 / (s + (1.0 - s) / t)
    }

    /// Modeled wall seconds of a `Compute` segment measured serial.
    pub fn compute_time(&self, serial_seconds: f64, threads: usize) -> f64 {
        serial_seconds / self.speedup(threads)
    }
}

/// Latency/bandwidth/reduction-op cost model.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// per-message latency (seconds)
    pub alpha: f64,
    /// per-byte transfer time (seconds/byte)
    pub beta: f64,
    /// per-byte reduction compute time (seconds/byte)
    pub gamma: f64,
}

impl CostModel {
    /// Shared-memory node (the paper's Fig. 4 testbed).
    pub fn shared_memory() -> CostModel {
        CostModel { alpha: 1.0e-6, beta: 1.0 / 12.0e9, gamma: 1.0 / 8.0e9 }
    }

    /// HPC cluster interconnect (for the Ref. [1] scale projection).
    pub fn cluster() -> CostModel {
        CostModel { alpha: 2.0e-6, beta: 1.0 / 25.0e9, gamma: 1.0 / 8.0e9 }
    }

    /// Zero-cost model (pure-correctness runs / tests).
    pub fn free() -> CostModel {
        CostModel { alpha: 0.0, beta: 0.0, gamma: 0.0 }
    }

    fn log2p(p: usize) -> f64 {
        if p <= 1 {
            0.0
        } else {
            (p as f64).log2().ceil()
        }
    }

    /// Modeled Allreduce time for `bytes` payload over `p` ranks.
    pub fn allreduce(&self, p: usize, bytes: usize) -> f64 {
        Self::log2p(p) * (self.alpha + bytes as f64 * (self.beta + self.gamma))
    }

    /// Modeled broadcast time.
    pub fn broadcast(&self, p: usize, bytes: usize) -> f64 {
        Self::log2p(p) * (self.alpha + bytes as f64 * self.beta)
    }

    /// Modeled barrier time.
    pub fn barrier(&self, p: usize) -> f64 {
        Self::log2p(p) * self.alpha
    }

    /// Fraction of the total volume that crosses the wire in the
    /// gather/allgather/reduce-scatter estimates.
    fn ring_fraction(p: usize) -> f64 {
        if p <= 1 {
            0.0
        } else {
            (p - 1) as f64 / p as f64
        }
    }

    /// Modeled rooted Reduce time for a `bytes` per-rank payload.
    pub fn reduce(&self, p: usize, bytes: usize) -> f64 {
        Self::log2p(p) * (self.alpha + bytes as f64 * (self.beta + self.gamma))
    }

    /// Modeled rooted Gather time (`total_bytes` = p · per-rank bytes).
    pub fn gather(&self, p: usize, total_bytes: usize) -> f64 {
        Self::log2p(p) * self.alpha + Self::ring_fraction(p) * total_bytes as f64 * self.beta
    }

    /// Modeled Allgather time (`total_bytes` = p · per-rank bytes).
    pub fn allgather(&self, p: usize, total_bytes: usize) -> f64 {
        Self::log2p(p) * self.alpha + Self::ring_fraction(p) * total_bytes as f64 * self.beta
    }

    /// Modeled Reduce_scatter_block time (`total_bytes` reduced, each
    /// rank keeping a 1/p block).
    pub fn reduce_scatter(&self, p: usize, total_bytes: usize) -> f64 {
        Self::log2p(p) * self.alpha
            + Self::ring_fraction(p) * total_bytes as f64 * (self.beta + self.gamma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_is_free() {
        let m = CostModel::shared_memory();
        assert_eq!(m.allreduce(1, 1 << 20), 0.0);
        assert_eq!(m.broadcast(1, 1 << 20), 0.0);
        assert_eq!(m.barrier(1), 0.0);
    }

    #[test]
    fn cost_grows_with_p_and_bytes() {
        let m = CostModel::shared_memory();
        assert!(m.allreduce(8, 1024) > m.allreduce(2, 1024));
        assert!(m.allreduce(4, 1 << 20) > m.allreduce(4, 1024));
        assert!(m.broadcast(16, 0) > 0.0); // latency-only floor
    }

    #[test]
    fn log_scaling() {
        let m = CostModel { alpha: 1.0, beta: 0.0, gamma: 0.0 };
        assert_eq!(m.barrier(2), 1.0);
        assert_eq!(m.barrier(4), 2.0);
        assert_eq!(m.barrier(8), 3.0);
        assert_eq!(m.barrier(1024), 10.0);
        // non-power-of-two rounds up
        assert_eq!(m.barrier(5), 3.0);
    }

    #[test]
    fn free_model_is_zero() {
        let m = CostModel::free();
        assert_eq!(m.allreduce(1024, 1 << 30), 0.0);
        assert_eq!(m.gather(1024, 1 << 30), 0.0);
        assert_eq!(m.reduce_scatter(1024, 1 << 30), 0.0);
    }

    #[test]
    fn rooted_primitives_single_rank_free() {
        let m = CostModel::shared_memory();
        assert_eq!(m.reduce(1, 1 << 20), 0.0);
        assert_eq!(m.gather(1, 1 << 20), 0.0);
        assert_eq!(m.allgather(1, 1 << 20), 0.0);
        assert_eq!(m.reduce_scatter(1, 1 << 20), 0.0);
    }

    #[test]
    fn disk_model_charges_seek_per_read() {
        let d = DiskModel::nvme();
        // one big read beats many small reads at equal volume
        let big = d.read_time(1, 1 << 24);
        let small = d.read_time(256, 1 << 24);
        assert!(small > big);
        assert!((small - big - 255.0 * d.seek_latency).abs() < 1e-12);
        // free model is exactly zero
        assert_eq!(DiskModel::free().read_time(1000, 1 << 30), 0.0);
        // bandwidth term scales linearly
        assert!(d.read_time(1, 2 << 20) > d.read_time(1, 1 << 20));
    }

    #[test]
    fn core_model_speedup_shape() {
        let m = CoreModel::node();
        // T=1 is exactly 1, monotone up to the core count, then flat
        assert_eq!(m.speedup(1), 1.0);
        assert!(m.speedup(2) > m.speedup(1));
        assert!(m.speedup(4) > m.speedup(2));
        assert!(m.speedup(8) > m.speedup(4));
        assert_eq!(m.speedup(16), m.speedup(8), "clamped at cores_per_rank");
        // Amdahl ceiling: never beats 1/serial_fraction
        assert!(m.speedup(8) < 1.0 / m.serial_fraction);
        // sub-linear: T=4 yields less than 4x
        assert!(m.speedup(4) < 4.0);
        // compute_time divides through
        assert!((m.compute_time(10.0, 4) - 10.0 / m.speedup(4)).abs() < 1e-12);
        // the single-core degenerate model never speeds up
        let one = CoreModel::single_core();
        assert_eq!(one.speedup(1), 1.0);
        assert_eq!(one.speedup(64), 1.0);
    }

    #[test]
    fn rooted_costs_grow_with_p_and_bytes() {
        let m = CostModel::shared_memory();
        assert!(m.gather(8, 1 << 20) > m.gather(2, 1 << 20));
        assert!(m.reduce(4, 1 << 20) > m.reduce(4, 1 << 10));
        assert!(m.reduce_scatter(8, 1 << 20) > m.reduce_scatter(8, 1 << 10));
        // reduce pays the reduction term on top of the transfer
        assert!(m.reduce(4, 1 << 20) > m.broadcast(4, 1 << 20));
        // rooted gather never costs more than allgather at equal volume
        assert!(m.gather(16, 1 << 22) <= m.allgather(16, 1 << 22));
    }
}

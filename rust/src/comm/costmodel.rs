//! α–β (Hockney) communication cost model for the virtual clocks.
//!
//! Collective costs use the standard binomial-tree / recursive-doubling
//! estimates (Thakur, Rabenseifner & Gropp, IJHPCA 2005):
//!
//! * Allreduce (recursive doubling): `log2(p) · (α + n·β + n·γ)`
//! * Broadcast (binomial tree):      `log2(p) · (α + n·β)`
//! * Barrier (dissemination):        `log2(p) · α`
//!
//! Defaults model a shared-memory node like the paper's 256-core EPYC
//! box (α ≈ 1 µs thread sync, β ≈ 1/12 GB/s effective per-pair memory
//! bandwidth); `CostModel::cluster()` models an HPC interconnect for the
//! p→2048 projection ablation (Ref. [1] of the paper).

/// Latency/bandwidth/reduction-op cost model.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// per-message latency (seconds)
    pub alpha: f64,
    /// per-byte transfer time (seconds/byte)
    pub beta: f64,
    /// per-byte reduction compute time (seconds/byte)
    pub gamma: f64,
}

impl CostModel {
    /// Shared-memory node (the paper's Fig. 4 testbed).
    pub fn shared_memory() -> CostModel {
        CostModel { alpha: 1.0e-6, beta: 1.0 / 12.0e9, gamma: 1.0 / 8.0e9 }
    }

    /// HPC cluster interconnect (for the Ref. [1] scale projection).
    pub fn cluster() -> CostModel {
        CostModel { alpha: 2.0e-6, beta: 1.0 / 25.0e9, gamma: 1.0 / 8.0e9 }
    }

    /// Zero-cost model (pure-correctness runs / tests).
    pub fn free() -> CostModel {
        CostModel { alpha: 0.0, beta: 0.0, gamma: 0.0 }
    }

    fn log2p(p: usize) -> f64 {
        if p <= 1 {
            0.0
        } else {
            (p as f64).log2().ceil()
        }
    }

    /// Modeled Allreduce time for `bytes` payload over `p` ranks.
    pub fn allreduce(&self, p: usize, bytes: usize) -> f64 {
        Self::log2p(p) * (self.alpha + bytes as f64 * (self.beta + self.gamma))
    }

    /// Modeled broadcast time.
    pub fn broadcast(&self, p: usize, bytes: usize) -> f64 {
        Self::log2p(p) * (self.alpha + bytes as f64 * self.beta)
    }

    /// Modeled barrier time.
    pub fn barrier(&self, p: usize) -> f64 {
        Self::log2p(p) * self.alpha
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_is_free() {
        let m = CostModel::shared_memory();
        assert_eq!(m.allreduce(1, 1 << 20), 0.0);
        assert_eq!(m.broadcast(1, 1 << 20), 0.0);
        assert_eq!(m.barrier(1), 0.0);
    }

    #[test]
    fn cost_grows_with_p_and_bytes() {
        let m = CostModel::shared_memory();
        assert!(m.allreduce(8, 1024) > m.allreduce(2, 1024));
        assert!(m.allreduce(4, 1 << 20) > m.allreduce(4, 1024));
        assert!(m.broadcast(16, 0) > 0.0); // latency-only floor
    }

    #[test]
    fn log_scaling() {
        let m = CostModel { alpha: 1.0, beta: 0.0, gamma: 0.0 };
        assert_eq!(m.barrier(2), 1.0);
        assert_eq!(m.barrier(4), 2.0);
        assert_eq!(m.barrier(8), 3.0);
        assert_eq!(m.barrier(1024), 10.0);
        // non-power-of-two rounds up
        assert_eq!(m.barrier(5), 3.0);
    }

    #[test]
    fn free_model_is_zero() {
        let m = CostModel::free();
        assert_eq!(m.allreduce(1024, 1 << 30), 0.0);
    }
}

//! α–β (Hockney) communication cost model for the virtual clocks.
//!
//! Collective costs use the standard binomial-tree / recursive-doubling
//! estimates (Thakur, Rabenseifner & Gropp, IJHPCA 2005):
//!
//! * Allreduce (recursive doubling): `log2(p) · (α + n·β + n·γ)`
//! * Broadcast (binomial tree):      `log2(p) · (α + n·β)`
//! * Barrier (dissemination):        `log2(p) · α`
//! * Reduce (binomial tree):         `log2(p) · (α + n·β + n·γ)`
//! * Gather / Allgather:             `log2(p) · α + (p-1)/p · N·β`
//! * Reduce_scatter (pairwise):      `log2(p) · α + (p-1)/p · N·(β+γ)`
//!
//! where `n` is the per-rank payload and `N` the total volume across
//! ranks. The rooted primitives matter once the transport is a real
//! network: `gather` moves `(p-1)/p · N` toward one root where
//! allgather-then-discard would move `N` to every rank.
//!
//! Defaults model a shared-memory node like the paper's 256-core EPYC
//! box (α ≈ 1 µs thread sync, β ≈ 1/12 GB/s effective per-pair memory
//! bandwidth); `CostModel::cluster()` models an HPC interconnect for the
//! p→2048 projection ablation (Ref. [1] of the paper).

/// Storage read-path model for the chunked Step I ingestion charges:
/// each [`crate::io::Chunk`] bills `reads · seek_latency +
/// bytes / bandwidth` to the `Load` category, so `fig4_scaling` stays
/// honest when chunking multiplies the number of discrete read
/// operations (a chunk touching v variables issues v seeks).
#[derive(Clone, Copy, Debug)]
pub struct DiskModel {
    /// sustained sequential read bandwidth (bytes/s)
    pub bandwidth: f64,
    /// per-read-operation latency: seek + request issue (seconds)
    pub seek_latency: f64,
}

impl DiskModel {
    /// Local NVMe-class storage (the default; bandwidth matches the
    /// previous scalar `disk_bandwidth` so whole-block charges are
    /// unchanged up to the single seek).
    pub fn nvme() -> DiskModel {
        DiskModel { bandwidth: 1.5e9, seek_latency: 8.0e-5 }
    }

    /// Parallel-filesystem-class storage (HPC burst buffer / Lustre
    /// stripe): higher bandwidth, but each independent read pays more
    /// request latency.
    pub fn parallel_fs() -> DiskModel {
        DiskModel { bandwidth: 5.0e9, seek_latency: 5.0e-4 }
    }

    /// Zero-cost model (pure-correctness runs / tests).
    pub fn free() -> DiskModel {
        DiskModel { bandwidth: f64::INFINITY, seek_latency: 0.0 }
    }

    /// Modeled wall time of `reads` discrete read operations moving
    /// `bytes` in total.
    pub fn read_time(&self, reads: usize, bytes: usize) -> f64 {
        reads as f64 * self.seek_latency + bytes as f64 / self.bandwidth
    }

    /// Modeled wall time of `writes` discrete write operations moving
    /// `bytes` in total — the storage model is symmetric, so the
    /// checkpoint shards the resilience plane persists (`crate::ckpt`)
    /// bill `Load` with the same α–β shape as the ingestion reads and
    /// `fig4_scaling` prices the checkpoint overhead honestly.
    pub fn write_time(&self, writes: usize, bytes: usize) -> f64 {
        writes as f64 * self.seek_latency + bytes as f64 / self.bandwidth
    }
}

/// Intra-rank compute-plane model for the node-level scaling
/// projections: with the deterministic worker pool
/// ([`crate::linalg::par`]) a rank's `Compute` segment shrinks by the
/// Amdahl factor below, while `Load`/`Comm`/`Learn` stay serial per
/// rank (ingestion is I/O-bound, the collectives are the transport's,
/// and the grid search is already sharded across ranks). `fig4_scaling`
/// uses this to extend the measured p-sweep into a p × T table — the
/// paper's 256-core EPYC box runs p ranks × T cores each, and modeling
/// that term is what lets the strong-scaling figure speak to node-level
/// speedup instead of rank count alone.
#[derive(Clone, Copy, Debug)]
pub struct CoreModel {
    /// physical cores available to one rank (T is clamped to this)
    pub cores_per_rank: usize,
    /// fraction of a rank's compute that stays serial at any T —
    /// band-partition epilogues (the syrk mirror), carry flushes, and
    /// the sub-threshold kernels the plane leaves inline
    pub serial_fraction: f64,
}

impl CoreModel {
    /// A node slice like the paper's testbed: 8 cores per rank, a few
    /// percent serial.
    pub fn node() -> CoreModel {
        CoreModel { cores_per_rank: 8, serial_fraction: 0.05 }
    }

    /// The degenerate single-core rank (speedup ≡ 1 at every T).
    pub fn single_core() -> CoreModel {
        CoreModel { cores_per_rank: 1, serial_fraction: 1.0 }
    }

    /// Amdahl speedup of the `Compute` category at `threads` pool
    /// workers: `1 / (s + (1-s)/min(T, cores))`.
    pub fn speedup(&self, threads: usize) -> f64 {
        let t = threads.max(1).min(self.cores_per_rank.max(1)) as f64;
        let s = self.serial_fraction.clamp(0.0, 1.0);
        1.0 / (s + (1.0 - s) / t)
    }

    /// Modeled wall seconds of a `Compute` segment measured serial.
    pub fn compute_time(&self, serial_seconds: f64, threads: usize) -> f64 {
        serial_seconds / self.speedup(threads)
    }
}

/// Latency/bandwidth/reduction-op cost model.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// per-message latency (seconds)
    pub alpha: f64,
    /// per-byte transfer time (seconds/byte)
    pub beta: f64,
    /// per-byte reduction compute time (seconds/byte)
    pub gamma: f64,
}

impl CostModel {
    /// Shared-memory node (the paper's Fig. 4 testbed).
    pub fn shared_memory() -> CostModel {
        CostModel { alpha: 1.0e-6, beta: 1.0 / 12.0e9, gamma: 1.0 / 8.0e9 }
    }

    /// HPC cluster interconnect (for the Ref. [1] scale projection).
    pub fn cluster() -> CostModel {
        CostModel { alpha: 2.0e-6, beta: 1.0 / 25.0e9, gamma: 1.0 / 8.0e9 }
    }

    /// Zero-cost model (pure-correctness runs / tests).
    pub fn free() -> CostModel {
        CostModel { alpha: 0.0, beta: 0.0, gamma: 0.0 }
    }

    /// `(α, β, γ)` — the wire form the process transport ships so
    /// worker-rank clocks advance identically to the parent's.
    pub fn parts(&self) -> (f64, f64, f64) {
        (self.alpha, self.beta, self.gamma)
    }

    /// Rebuild from [`CostModel::parts`].
    pub fn from_parts(alpha: f64, beta: f64, gamma: f64) -> CostModel {
        CostModel { alpha, beta, gamma }
    }

    fn log2p(p: usize) -> f64 {
        if p <= 1 {
            0.0
        } else {
            (p as f64).log2().ceil()
        }
    }

    /// Modeled Allreduce time for `bytes` payload over `p` ranks.
    pub fn allreduce(&self, p: usize, bytes: usize) -> f64 {
        Self::log2p(p) * (self.alpha + bytes as f64 * (self.beta + self.gamma))
    }

    /// Modeled broadcast time.
    pub fn broadcast(&self, p: usize, bytes: usize) -> f64 {
        Self::log2p(p) * (self.alpha + bytes as f64 * self.beta)
    }

    /// Modeled barrier time.
    pub fn barrier(&self, p: usize) -> f64 {
        Self::log2p(p) * self.alpha
    }

    /// Fraction of the total volume that crosses the wire in the
    /// gather/allgather/reduce-scatter estimates.
    fn ring_fraction(p: usize) -> f64 {
        if p <= 1 {
            0.0
        } else {
            (p - 1) as f64 / p as f64
        }
    }

    /// Modeled rooted Reduce time for a `bytes` per-rank payload.
    pub fn reduce(&self, p: usize, bytes: usize) -> f64 {
        Self::log2p(p) * (self.alpha + bytes as f64 * (self.beta + self.gamma))
    }

    /// Modeled rooted Gather time (`total_bytes` = p · per-rank bytes).
    pub fn gather(&self, p: usize, total_bytes: usize) -> f64 {
        Self::log2p(p) * self.alpha + Self::ring_fraction(p) * total_bytes as f64 * self.beta
    }

    /// Modeled Allgather time (`total_bytes` = p · per-rank bytes).
    pub fn allgather(&self, p: usize, total_bytes: usize) -> f64 {
        Self::log2p(p) * self.alpha + Self::ring_fraction(p) * total_bytes as f64 * self.beta
    }

    /// Modeled Reduce_scatter_block time (`total_bytes` reduced, each
    /// rank keeping a 1/p block).
    pub fn reduce_scatter(&self, p: usize, total_bytes: usize) -> f64 {
        Self::log2p(p) * self.alpha
            + Self::ring_fraction(p) * total_bytes as f64 * (self.beta + self.gamma)
    }
}

/// Two-level α–β model for hierarchical collectives
/// ([`crate::comm::hier`]): node-local hops priced by `intra`
/// (shared-memory board), leader-tree hops priced by `inter` (network
/// between nodes). Each primitive follows the standard two-level
/// decomposition — local fold → leader exchange → local broadcast —
/// so a group of `nodes × ranks_per_node` ranks pays `log2` terms in
/// the *node-local* fan-in and the *node count* separately, instead of
/// the flat model's `log2(p)` at a single (α, β). This is what makes
/// the nodes × ranks-per-node projection in `fig4_scaling` say
/// something the flat p-sweep cannot: at fixed p, fewer/fatter nodes
/// trade cheap intra hops against the expensive inter tree.
#[derive(Clone, Copy, Debug)]
pub struct TwoLevelModel {
    /// node-local hop costs (thread board / shared memory)
    pub intra: CostModel,
    /// leader-tree hop costs (inter-node network)
    pub inter: CostModel,
}

impl TwoLevelModel {
    /// The paper-adjacent default: shared-memory α–β within a node,
    /// cluster interconnect between nodes.
    pub fn hpc() -> TwoLevelModel {
        TwoLevelModel { intra: CostModel::shared_memory(), inter: CostModel::cluster() }
    }

    /// Zero-cost model (pure-correctness runs / tests).
    pub fn free() -> TwoLevelModel {
        TwoLevelModel { intra: CostModel::free(), inter: CostModel::free() }
    }

    /// Both levels at the same (α, β, γ) — the degenerate check that a
    /// two-level decomposition over one node collapses to the flat
    /// model's regime.
    pub fn flat(m: CostModel) -> TwoLevelModel {
        TwoLevelModel { intra: m, inter: m }
    }

    /// Allreduce of a `bytes` per-rank payload: local reduce → leader
    /// allreduce → local broadcast.
    pub fn allreduce(&self, nodes: usize, ranks_per_node: usize, bytes: usize) -> f64 {
        self.intra.reduce(ranks_per_node, bytes)
            + self.inter.allreduce(nodes, bytes)
            + self.intra.broadcast(ranks_per_node, bytes)
    }

    /// Broadcast: leader tree → node-local fan-out.
    pub fn broadcast(&self, nodes: usize, ranks_per_node: usize, bytes: usize) -> f64 {
        self.inter.broadcast(nodes, bytes) + self.intra.broadcast(ranks_per_node, bytes)
    }

    /// Barrier: node-local arrive → leader barrier → node-local release.
    pub fn barrier(&self, nodes: usize, ranks_per_node: usize) -> f64 {
        2.0 * self.intra.barrier(ranks_per_node) + self.inter.barrier(nodes)
    }

    /// Rooted reduce: local reduce → leader reduce toward the root's
    /// node.
    pub fn reduce(&self, nodes: usize, ranks_per_node: usize, bytes: usize) -> f64 {
        self.intra.reduce(ranks_per_node, bytes) + self.inter.reduce(nodes, bytes)
    }

    /// Rooted gather of `total_bytes` across all ranks: node-local
    /// gather of each node's share, then the leader tree moves the
    /// full volume to the root's node.
    pub fn gather(&self, nodes: usize, ranks_per_node: usize, total_bytes: usize) -> f64 {
        let per_node = total_bytes / nodes.max(1);
        self.intra.gather(ranks_per_node, per_node) + self.inter.gather(nodes, total_bytes)
    }

    /// Allgather: node-local gather → leader allgather of the full
    /// volume → node-local broadcast of the assembled vector.
    pub fn allgather(&self, nodes: usize, ranks_per_node: usize, total_bytes: usize) -> f64 {
        let per_node = total_bytes / nodes.max(1);
        self.intra.gather(ranks_per_node, per_node)
            + self.inter.allgather(nodes, total_bytes)
            + self.intra.broadcast(ranks_per_node, total_bytes)
    }

    /// Reduce_scatter_block of a `total_bytes` vector: local reduce of
    /// the full vector → leader reduce-scatter → node-local scatter of
    /// the node's block.
    pub fn reduce_scatter(&self, nodes: usize, ranks_per_node: usize, total_bytes: usize) -> f64 {
        self.intra.reduce(ranks_per_node, total_bytes)
            + self.inter.reduce_scatter(nodes, total_bytes)
            + self.intra.broadcast(ranks_per_node, total_bytes / nodes.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_is_free() {
        let m = CostModel::shared_memory();
        assert_eq!(m.allreduce(1, 1 << 20), 0.0);
        assert_eq!(m.broadcast(1, 1 << 20), 0.0);
        assert_eq!(m.barrier(1), 0.0);
    }

    #[test]
    fn cost_grows_with_p_and_bytes() {
        let m = CostModel::shared_memory();
        assert!(m.allreduce(8, 1024) > m.allreduce(2, 1024));
        assert!(m.allreduce(4, 1 << 20) > m.allreduce(4, 1024));
        assert!(m.broadcast(16, 0) > 0.0); // latency-only floor
    }

    #[test]
    fn log_scaling() {
        let m = CostModel { alpha: 1.0, beta: 0.0, gamma: 0.0 };
        assert_eq!(m.barrier(2), 1.0);
        assert_eq!(m.barrier(4), 2.0);
        assert_eq!(m.barrier(8), 3.0);
        assert_eq!(m.barrier(1024), 10.0);
        // non-power-of-two rounds up
        assert_eq!(m.barrier(5), 3.0);
    }

    #[test]
    fn free_model_is_zero() {
        let m = CostModel::free();
        assert_eq!(m.allreduce(1024, 1 << 30), 0.0);
        assert_eq!(m.gather(1024, 1 << 30), 0.0);
        assert_eq!(m.reduce_scatter(1024, 1 << 30), 0.0);
    }

    #[test]
    fn rooted_primitives_single_rank_free() {
        let m = CostModel::shared_memory();
        assert_eq!(m.reduce(1, 1 << 20), 0.0);
        assert_eq!(m.gather(1, 1 << 20), 0.0);
        assert_eq!(m.allgather(1, 1 << 20), 0.0);
        assert_eq!(m.reduce_scatter(1, 1 << 20), 0.0);
    }

    #[test]
    fn disk_model_charges_seek_per_read() {
        let d = DiskModel::nvme();
        // one big read beats many small reads at equal volume
        let big = d.read_time(1, 1 << 24);
        let small = d.read_time(256, 1 << 24);
        assert!(small > big);
        assert!((small - big - 255.0 * d.seek_latency).abs() < 1e-12);
        // free model is exactly zero
        assert_eq!(DiskModel::free().read_time(1000, 1 << 30), 0.0);
        // bandwidth term scales linearly
        assert!(d.read_time(1, 2 << 20) > d.read_time(1, 1 << 20));
        // the write path mirrors the read path exactly
        assert_eq!(d.write_time(3, 1 << 20).to_bits(), d.read_time(3, 1 << 20).to_bits());
        assert_eq!(DiskModel::free().write_time(10, 1 << 20), 0.0);
    }

    #[test]
    fn core_model_speedup_shape() {
        let m = CoreModel::node();
        // T=1 is exactly 1, monotone up to the core count, then flat
        assert_eq!(m.speedup(1), 1.0);
        assert!(m.speedup(2) > m.speedup(1));
        assert!(m.speedup(4) > m.speedup(2));
        assert!(m.speedup(8) > m.speedup(4));
        assert_eq!(m.speedup(16), m.speedup(8), "clamped at cores_per_rank");
        // Amdahl ceiling: never beats 1/serial_fraction
        assert!(m.speedup(8) < 1.0 / m.serial_fraction);
        // sub-linear: T=4 yields less than 4x
        assert!(m.speedup(4) < 4.0);
        // compute_time divides through
        assert!((m.compute_time(10.0, 4) - 10.0 / m.speedup(4)).abs() < 1e-12);
        // the single-core degenerate model never speeds up
        let one = CoreModel::single_core();
        assert_eq!(one.speedup(1), 1.0);
        assert_eq!(one.speedup(64), 1.0);
    }

    #[test]
    fn two_level_shapes() {
        let m = TwoLevelModel::hpc();
        // single node, single rank: everything degenerates to zero
        assert_eq!(m.allreduce(1, 1, 1 << 20), 0.0);
        assert_eq!(m.barrier(1, 1), 0.0);
        // one fat node never touches the inter network: allreduce cost
        // is exactly the intra reduce + broadcast
        let one_node = m.allreduce(1, 8, 4096);
        assert_eq!(
            one_node,
            m.intra.reduce(8, 4096) + m.intra.broadcast(8, 4096),
            "nodes = 1 must not pay inter terms"
        );
        // at fixed p = 16, spreading over more nodes costs more (the
        // inter α–β dominates the saved intra hops)
        assert!(m.allreduce(4, 4, 1 << 20) > m.allreduce(2, 8, 1 << 20));
        assert!(m.allreduce(2, 8, 1 << 20) > m.allreduce(1, 16, 1 << 20));
        // the hierarchy beats the flat model run entirely at inter
        // costs (that is its point)
        let flat_inter = CostModel::cluster().allreduce(16, 1 << 20);
        assert!(m.allreduce(2, 8, 1 << 20) < flat_inter);
        // costs grow with volume on every primitive
        for (a, b) in [
            (m.broadcast(4, 4, 1 << 20), m.broadcast(4, 4, 1 << 10)),
            (m.gather(4, 4, 1 << 20), m.gather(4, 4, 1 << 10)),
            (m.allgather(4, 4, 1 << 20), m.allgather(4, 4, 1 << 10)),
            (m.reduce_scatter(4, 4, 1 << 20), m.reduce_scatter(4, 4, 1 << 10)),
            (m.reduce(4, 4, 1 << 20), m.reduce(4, 4, 1 << 10)),
        ] {
            assert!(a > b);
        }
        // free() is identically zero, flat() uses one regime twice
        assert_eq!(TwoLevelModel::free().allreduce(8, 8, 1 << 20), 0.0);
        let f = TwoLevelModel::flat(CostModel::shared_memory());
        assert_eq!(f.intra.alpha, f.inter.alpha);
    }

    #[test]
    fn cost_model_parts_roundtrip() {
        let m = CostModel::cluster();
        let (a, b, g) = m.parts();
        let r = CostModel::from_parts(a, b, g);
        assert_eq!(r.alpha.to_bits(), m.alpha.to_bits());
        assert_eq!(r.beta.to_bits(), m.beta.to_bits());
        assert_eq!(r.gamma.to_bits(), m.gamma.to_bits());
    }

    #[test]
    fn rooted_costs_grow_with_p_and_bytes() {
        let m = CostModel::shared_memory();
        assert!(m.gather(8, 1 << 20) > m.gather(2, 1 << 20));
        assert!(m.reduce(4, 1 << 20) > m.reduce(4, 1 << 10));
        assert!(m.reduce_scatter(8, 1 << 20) > m.reduce_scatter(8, 1 << 10));
        // reduce pays the reduction term on top of the transfer
        assert!(m.reduce(4, 1 << 20) > m.broadcast(4, 1 << 20));
        // rooted gather never costs more than allgather at equal volume
        assert!(m.gather(16, 1 << 22) <= m.allgather(16, 1 << 22));
    }
}

//! α–β (Hockney) communication cost model for the virtual clocks.
//!
//! Collective costs use the standard binomial-tree / recursive-doubling
//! estimates (Thakur, Rabenseifner & Gropp, IJHPCA 2005):
//!
//! * Allreduce (recursive doubling): `log2(p) · (α + n·β + n·γ)`
//! * Broadcast (binomial tree):      `log2(p) · (α + n·β)`
//! * Barrier (dissemination):        `log2(p) · α`
//! * Reduce (binomial tree):         `log2(p) · (α + n·β + n·γ)`
//! * Gather / Allgather:             `log2(p) · α + (p-1)/p · N·β`
//! * Reduce_scatter (pairwise):      `log2(p) · α + (p-1)/p · N·(β+γ)`
//!
//! where `n` is the per-rank payload and `N` the total volume across
//! ranks. The rooted primitives matter once the transport is a real
//! network: `gather` moves `(p-1)/p · N` toward one root where
//! allgather-then-discard would move `N` to every rank.
//!
//! Defaults model a shared-memory node like the paper's 256-core EPYC
//! box (α ≈ 1 µs thread sync, β ≈ 1/12 GB/s effective per-pair memory
//! bandwidth); `CostModel::cluster()` models an HPC interconnect for the
//! p→2048 projection ablation (Ref. [1] of the paper).

/// Latency/bandwidth/reduction-op cost model.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// per-message latency (seconds)
    pub alpha: f64,
    /// per-byte transfer time (seconds/byte)
    pub beta: f64,
    /// per-byte reduction compute time (seconds/byte)
    pub gamma: f64,
}

impl CostModel {
    /// Shared-memory node (the paper's Fig. 4 testbed).
    pub fn shared_memory() -> CostModel {
        CostModel { alpha: 1.0e-6, beta: 1.0 / 12.0e9, gamma: 1.0 / 8.0e9 }
    }

    /// HPC cluster interconnect (for the Ref. [1] scale projection).
    pub fn cluster() -> CostModel {
        CostModel { alpha: 2.0e-6, beta: 1.0 / 25.0e9, gamma: 1.0 / 8.0e9 }
    }

    /// Zero-cost model (pure-correctness runs / tests).
    pub fn free() -> CostModel {
        CostModel { alpha: 0.0, beta: 0.0, gamma: 0.0 }
    }

    fn log2p(p: usize) -> f64 {
        if p <= 1 {
            0.0
        } else {
            (p as f64).log2().ceil()
        }
    }

    /// Modeled Allreduce time for `bytes` payload over `p` ranks.
    pub fn allreduce(&self, p: usize, bytes: usize) -> f64 {
        Self::log2p(p) * (self.alpha + bytes as f64 * (self.beta + self.gamma))
    }

    /// Modeled broadcast time.
    pub fn broadcast(&self, p: usize, bytes: usize) -> f64 {
        Self::log2p(p) * (self.alpha + bytes as f64 * self.beta)
    }

    /// Modeled barrier time.
    pub fn barrier(&self, p: usize) -> f64 {
        Self::log2p(p) * self.alpha
    }

    /// Fraction of the total volume that crosses the wire in the
    /// gather/allgather/reduce-scatter estimates.
    fn ring_fraction(p: usize) -> f64 {
        if p <= 1 {
            0.0
        } else {
            (p - 1) as f64 / p as f64
        }
    }

    /// Modeled rooted Reduce time for a `bytes` per-rank payload.
    pub fn reduce(&self, p: usize, bytes: usize) -> f64 {
        Self::log2p(p) * (self.alpha + bytes as f64 * (self.beta + self.gamma))
    }

    /// Modeled rooted Gather time (`total_bytes` = p · per-rank bytes).
    pub fn gather(&self, p: usize, total_bytes: usize) -> f64 {
        Self::log2p(p) * self.alpha + Self::ring_fraction(p) * total_bytes as f64 * self.beta
    }

    /// Modeled Allgather time (`total_bytes` = p · per-rank bytes).
    pub fn allgather(&self, p: usize, total_bytes: usize) -> f64 {
        Self::log2p(p) * self.alpha + Self::ring_fraction(p) * total_bytes as f64 * self.beta
    }

    /// Modeled Reduce_scatter_block time (`total_bytes` reduced, each
    /// rank keeping a 1/p block).
    pub fn reduce_scatter(&self, p: usize, total_bytes: usize) -> f64 {
        Self::log2p(p) * self.alpha
            + Self::ring_fraction(p) * total_bytes as f64 * (self.beta + self.gamma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_is_free() {
        let m = CostModel::shared_memory();
        assert_eq!(m.allreduce(1, 1 << 20), 0.0);
        assert_eq!(m.broadcast(1, 1 << 20), 0.0);
        assert_eq!(m.barrier(1), 0.0);
    }

    #[test]
    fn cost_grows_with_p_and_bytes() {
        let m = CostModel::shared_memory();
        assert!(m.allreduce(8, 1024) > m.allreduce(2, 1024));
        assert!(m.allreduce(4, 1 << 20) > m.allreduce(4, 1024));
        assert!(m.broadcast(16, 0) > 0.0); // latency-only floor
    }

    #[test]
    fn log_scaling() {
        let m = CostModel { alpha: 1.0, beta: 0.0, gamma: 0.0 };
        assert_eq!(m.barrier(2), 1.0);
        assert_eq!(m.barrier(4), 2.0);
        assert_eq!(m.barrier(8), 3.0);
        assert_eq!(m.barrier(1024), 10.0);
        // non-power-of-two rounds up
        assert_eq!(m.barrier(5), 3.0);
    }

    #[test]
    fn free_model_is_zero() {
        let m = CostModel::free();
        assert_eq!(m.allreduce(1024, 1 << 30), 0.0);
        assert_eq!(m.gather(1024, 1 << 30), 0.0);
        assert_eq!(m.reduce_scatter(1024, 1 << 30), 0.0);
    }

    #[test]
    fn rooted_primitives_single_rank_free() {
        let m = CostModel::shared_memory();
        assert_eq!(m.reduce(1, 1 << 20), 0.0);
        assert_eq!(m.gather(1, 1 << 20), 0.0);
        assert_eq!(m.allgather(1, 1 << 20), 0.0);
        assert_eq!(m.reduce_scatter(1, 1 << 20), 0.0);
    }

    #[test]
    fn rooted_costs_grow_with_p_and_bytes() {
        let m = CostModel::shared_memory();
        assert!(m.gather(8, 1 << 20) > m.gather(2, 1 << 20));
        assert!(m.reduce(4, 1 << 20) > m.reduce(4, 1 << 10));
        assert!(m.reduce_scatter(8, 1 << 20) > m.reduce_scatter(8, 1 << 10));
        // reduce pays the reduction term on top of the transfer
        assert!(m.reduce(4, 1 << 20) > m.broadcast(4, 1 << 20));
        // rooted gather never costs more than allgather at equal volume
        assert!(m.gather(16, 1 << 22) <= m.allgather(16, 1 << 22));
    }
}

//! α–β (Hockney) communication cost model for the virtual clocks.
//!
//! Collective costs use the standard binomial-tree / recursive-doubling
//! estimates (Thakur, Rabenseifner & Gropp, IJHPCA 2005):
//!
//! * Allreduce (recursive doubling): `log2(p) · (α + n·β + n·γ)`
//! * Broadcast (binomial tree):      `log2(p) · (α + n·β)`
//! * Barrier (dissemination):        `log2(p) · α`
//! * Reduce (binomial tree):         `log2(p) · (α + n·β + n·γ)`
//! * Gather / Allgather:             `log2(p) · α + (p-1)/p · N·β`
//! * Reduce_scatter (pairwise):      `log2(p) · α + (p-1)/p · N·(β+γ)`
//!
//! where `n` is the per-rank payload and `N` the total volume across
//! ranks. The rooted primitives matter once the transport is a real
//! network: `gather` moves `(p-1)/p · N` toward one root where
//! allgather-then-discard would move `N` to every rank.
//!
//! Defaults model a shared-memory node like the paper's 256-core EPYC
//! box (α ≈ 1 µs thread sync, β ≈ 1/12 GB/s effective per-pair memory
//! bandwidth); `CostModel::cluster()` models an HPC interconnect for the
//! p→2048 projection ablation (Ref. [1] of the paper).

/// Storage read-path model for the chunked Step I ingestion charges:
/// each [`crate::io::Chunk`] bills `reads · seek_latency +
/// bytes / bandwidth` to the `Load` category, so `fig4_scaling` stays
/// honest when chunking multiplies the number of discrete read
/// operations (a chunk touching v variables issues v seeks).
#[derive(Clone, Copy, Debug)]
pub struct DiskModel {
    /// sustained sequential read bandwidth (bytes/s)
    pub bandwidth: f64,
    /// per-read-operation latency: seek + request issue (seconds)
    pub seek_latency: f64,
}

impl DiskModel {
    /// Local NVMe-class storage (the default; bandwidth matches the
    /// previous scalar `disk_bandwidth` so whole-block charges are
    /// unchanged up to the single seek).
    pub fn nvme() -> DiskModel {
        DiskModel { bandwidth: 1.5e9, seek_latency: 8.0e-5 }
    }

    /// Parallel-filesystem-class storage (HPC burst buffer / Lustre
    /// stripe): higher bandwidth, but each independent read pays more
    /// request latency.
    pub fn parallel_fs() -> DiskModel {
        DiskModel { bandwidth: 5.0e9, seek_latency: 5.0e-4 }
    }

    /// Zero-cost model (pure-correctness runs / tests).
    pub fn free() -> DiskModel {
        DiskModel { bandwidth: f64::INFINITY, seek_latency: 0.0 }
    }

    /// Modeled wall time of `reads` discrete read operations moving
    /// `bytes` in total.
    pub fn read_time(&self, reads: usize, bytes: usize) -> f64 {
        reads as f64 * self.seek_latency + bytes as f64 / self.bandwidth
    }
}

/// Latency/bandwidth/reduction-op cost model.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// per-message latency (seconds)
    pub alpha: f64,
    /// per-byte transfer time (seconds/byte)
    pub beta: f64,
    /// per-byte reduction compute time (seconds/byte)
    pub gamma: f64,
}

impl CostModel {
    /// Shared-memory node (the paper's Fig. 4 testbed).
    pub fn shared_memory() -> CostModel {
        CostModel { alpha: 1.0e-6, beta: 1.0 / 12.0e9, gamma: 1.0 / 8.0e9 }
    }

    /// HPC cluster interconnect (for the Ref. [1] scale projection).
    pub fn cluster() -> CostModel {
        CostModel { alpha: 2.0e-6, beta: 1.0 / 25.0e9, gamma: 1.0 / 8.0e9 }
    }

    /// Zero-cost model (pure-correctness runs / tests).
    pub fn free() -> CostModel {
        CostModel { alpha: 0.0, beta: 0.0, gamma: 0.0 }
    }

    fn log2p(p: usize) -> f64 {
        if p <= 1 {
            0.0
        } else {
            (p as f64).log2().ceil()
        }
    }

    /// Modeled Allreduce time for `bytes` payload over `p` ranks.
    pub fn allreduce(&self, p: usize, bytes: usize) -> f64 {
        Self::log2p(p) * (self.alpha + bytes as f64 * (self.beta + self.gamma))
    }

    /// Modeled broadcast time.
    pub fn broadcast(&self, p: usize, bytes: usize) -> f64 {
        Self::log2p(p) * (self.alpha + bytes as f64 * self.beta)
    }

    /// Modeled barrier time.
    pub fn barrier(&self, p: usize) -> f64 {
        Self::log2p(p) * self.alpha
    }

    /// Fraction of the total volume that crosses the wire in the
    /// gather/allgather/reduce-scatter estimates.
    fn ring_fraction(p: usize) -> f64 {
        if p <= 1 {
            0.0
        } else {
            (p - 1) as f64 / p as f64
        }
    }

    /// Modeled rooted Reduce time for a `bytes` per-rank payload.
    pub fn reduce(&self, p: usize, bytes: usize) -> f64 {
        Self::log2p(p) * (self.alpha + bytes as f64 * (self.beta + self.gamma))
    }

    /// Modeled rooted Gather time (`total_bytes` = p · per-rank bytes).
    pub fn gather(&self, p: usize, total_bytes: usize) -> f64 {
        Self::log2p(p) * self.alpha + Self::ring_fraction(p) * total_bytes as f64 * self.beta
    }

    /// Modeled Allgather time (`total_bytes` = p · per-rank bytes).
    pub fn allgather(&self, p: usize, total_bytes: usize) -> f64 {
        Self::log2p(p) * self.alpha + Self::ring_fraction(p) * total_bytes as f64 * self.beta
    }

    /// Modeled Reduce_scatter_block time (`total_bytes` reduced, each
    /// rank keeping a 1/p block).
    pub fn reduce_scatter(&self, p: usize, total_bytes: usize) -> f64 {
        Self::log2p(p) * self.alpha
            + Self::ring_fraction(p) * total_bytes as f64 * (self.beta + self.gamma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_is_free() {
        let m = CostModel::shared_memory();
        assert_eq!(m.allreduce(1, 1 << 20), 0.0);
        assert_eq!(m.broadcast(1, 1 << 20), 0.0);
        assert_eq!(m.barrier(1), 0.0);
    }

    #[test]
    fn cost_grows_with_p_and_bytes() {
        let m = CostModel::shared_memory();
        assert!(m.allreduce(8, 1024) > m.allreduce(2, 1024));
        assert!(m.allreduce(4, 1 << 20) > m.allreduce(4, 1024));
        assert!(m.broadcast(16, 0) > 0.0); // latency-only floor
    }

    #[test]
    fn log_scaling() {
        let m = CostModel { alpha: 1.0, beta: 0.0, gamma: 0.0 };
        assert_eq!(m.barrier(2), 1.0);
        assert_eq!(m.barrier(4), 2.0);
        assert_eq!(m.barrier(8), 3.0);
        assert_eq!(m.barrier(1024), 10.0);
        // non-power-of-two rounds up
        assert_eq!(m.barrier(5), 3.0);
    }

    #[test]
    fn free_model_is_zero() {
        let m = CostModel::free();
        assert_eq!(m.allreduce(1024, 1 << 30), 0.0);
        assert_eq!(m.gather(1024, 1 << 30), 0.0);
        assert_eq!(m.reduce_scatter(1024, 1 << 30), 0.0);
    }

    #[test]
    fn rooted_primitives_single_rank_free() {
        let m = CostModel::shared_memory();
        assert_eq!(m.reduce(1, 1 << 20), 0.0);
        assert_eq!(m.gather(1, 1 << 20), 0.0);
        assert_eq!(m.allgather(1, 1 << 20), 0.0);
        assert_eq!(m.reduce_scatter(1, 1 << 20), 0.0);
    }

    #[test]
    fn disk_model_charges_seek_per_read() {
        let d = DiskModel::nvme();
        // one big read beats many small reads at equal volume
        let big = d.read_time(1, 1 << 24);
        let small = d.read_time(256, 1 << 24);
        assert!(small > big);
        assert!((small - big - 255.0 * d.seek_latency).abs() < 1e-12);
        // free model is exactly zero
        assert_eq!(DiskModel::free().read_time(1000, 1 << 30), 0.0);
        // bandwidth term scales linearly
        assert!(d.read_time(1, 2 << 20) > d.read_time(1, 1 << 20));
    }

    #[test]
    fn rooted_costs_grow_with_p_and_bytes() {
        let m = CostModel::shared_memory();
        assert!(m.gather(8, 1 << 20) > m.gather(2, 1 << 20));
        assert!(m.reduce(4, 1 << 20) > m.reduce(4, 1 << 10));
        assert!(m.reduce_scatter(8, 1 << 20) > m.reduce_scatter(8, 1 << 10));
        // reduce pays the reduction term on top of the transfer
        assert!(m.reduce(4, 1 << 20) > m.broadcast(4, 1 << 20));
        // rooted gather never costs more than allgather at equal volume
        assert!(m.gather(16, 1 << 22) <= m.allgather(16, 1 << 22));
    }
}

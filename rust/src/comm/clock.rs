//! Per-rank virtual clocks with per-category accounting.
//!
//! Categories match the paper's Fig. 4 (right) breakdown: data loading,
//! data-processing computations, communication, and OpInf learning (plus
//! postprocessing, which the paper discusses but does not plot).

/// Cost category for the Fig. 4 breakdown.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Category {
    /// Step I: reading the rank's snapshot partition.
    Load,
    /// Steps II–III compute: transforms, Gram products, eigh, projection.
    Compute,
    /// Collective communication (Allreduce/Bcast/Barrier sync).
    Comm,
    /// Step IV: regularization search + operator solves + ROM trials.
    Learn,
    /// Step V: postprocessing / lifting.
    Post,
}

pub const ALL_CATEGORIES: [Category; 5] =
    [Category::Load, Category::Compute, Category::Comm, Category::Learn, Category::Post];

impl Category {
    pub fn name(&self) -> &'static str {
        match self {
            Category::Load => "load",
            Category::Compute => "compute",
            Category::Comm => "comm",
            Category::Learn => "learn",
            Category::Post => "post",
        }
    }
}

/// A rank's virtual clock: total virtual time plus per-category split.
#[derive(Clone, Debug, Default)]
pub struct Clock {
    total: f64,
    split: [f64; 5],
}

fn idx(c: Category) -> usize {
    match c {
        Category::Load => 0,
        Category::Compute => 1,
        Category::Comm => 2,
        Category::Learn => 3,
        Category::Post => 4,
    }
}

impl Clock {
    pub fn new() -> Clock {
        Clock::default()
    }

    /// Advance the clock by `seconds` of `category` work.
    pub fn add(&mut self, category: Category, seconds: f64) {
        debug_assert!(seconds >= 0.0, "negative time {seconds}");
        self.total += seconds;
        self.split[idx(category)] += seconds;
    }

    /// Synchronize to a collective's completion time: the clock jumps to
    /// `sync_point` (max entry time over ranks + modeled cost); the wait
    /// (idle + transfer) is charged to Comm.
    pub fn sync_to(&mut self, sync_point: f64) {
        if sync_point > self.total {
            let wait = sync_point - self.total;
            self.total = sync_point;
            self.split[idx(Category::Comm)] += wait;
        }
    }

    /// Current virtual time (seconds).
    pub fn now(&self) -> f64 {
        self.total
    }

    /// Time accumulated in one category.
    pub fn in_category(&self, category: Category) -> f64 {
        self.split[idx(category)]
    }

    /// (category, seconds) pairs for reporting.
    pub fn breakdown(&self) -> Vec<(Category, f64)> {
        ALL_CATEGORIES.iter().map(|&c| (c, self.in_category(c))).collect()
    }

    /// Rebuild a clock from its raw parts. Used by the process
    /// transport, whose worker ranks ship their final clocks back over
    /// the wire at join; carrying the total explicitly (instead of
    /// re-summing the split) makes the round-trip bitwise exact.
    pub(crate) fn from_parts(total: f64, split: [f64; 5]) -> Clock {
        Clock { total, split }
    }

    /// The raw `(total, per-category split)` parts (split in
    /// [`ALL_CATEGORIES`] order), the wire counterpart of
    /// [`Clock::from_parts`].
    pub(crate) fn parts(&self) -> (f64, [f64; 5]) {
        (self.total, self.split)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_by_category() {
        let mut c = Clock::new();
        c.add(Category::Load, 1.0);
        c.add(Category::Compute, 2.0);
        c.add(Category::Compute, 0.5);
        assert!((c.now() - 3.5).abs() < 1e-15);
        assert!((c.in_category(Category::Compute) - 2.5).abs() < 1e-15);
        assert_eq!(c.in_category(Category::Learn), 0.0);
    }

    #[test]
    fn sync_charges_comm_wait() {
        let mut c = Clock::new();
        c.add(Category::Compute, 1.0);
        c.sync_to(1.4);
        assert!((c.now() - 1.4).abs() < 1e-15);
        assert!((c.in_category(Category::Comm) - 0.4).abs() < 1e-15);
        // syncing backwards is a no-op
        c.sync_to(1.0);
        assert!((c.now() - 1.4).abs() < 1e-15);
    }

    #[test]
    fn breakdown_covers_total() {
        let mut c = Clock::new();
        c.add(Category::Load, 0.1);
        c.add(Category::Learn, 0.2);
        c.sync_to(0.5);
        let sum: f64 = c.breakdown().iter().map(|(_, s)| s).sum();
        assert!((sum - c.now()).abs() < 1e-12);
    }

    #[test]
    fn parts_roundtrip_exactly() {
        let mut c = Clock::new();
        c.add(Category::Load, 0.125);
        c.add(Category::Compute, 1.0 / 3.0);
        c.sync_to(1.7);
        let (total, split) = c.parts();
        let rebuilt = Clock::from_parts(total, split);
        assert_eq!(rebuilt.now().to_bits(), c.now().to_bits());
        for cat in ALL_CATEGORIES {
            assert_eq!(rebuilt.in_category(cat).to_bits(), c.in_category(cat).to_bits());
        }
    }
}

//! Typed errors of the collective layer.
//!
//! Every [`super::Communicator`] method returns
//! `Result<T, CommError>`: at scale, single-rank failures are routine,
//! and the old infallible contract (panic on misuse, hang on a dead
//! peer) is the wrong one for a pipeline that interleaves fallible I/O
//! between collectives. The variants map onto the ways a collective can
//! fail:
//!
//! * [`CommError::RemoteAbort`] — another rank failed and broadcast an
//!   abort (the recoverable analogue of `MPI_Abort`): a rank parked at
//!   any collective wakes with the origin rank and its error message
//!   instead of waiting forever.
//! * [`CommError::Timeout`] — a configured comm deadline elapsed while
//!   waiting for peers (a worker that never connects, a peer that dies
//!   silently mid-collective).
//! * [`CommError::ContractViolation`] — the MPI usage contract was
//!   broken (broadcast payload on a non-root, ragged
//!   `reduce_scatter_block` lengths, root out of range, mismatched
//!   collectives). Detected *after* the exchange wherever possible so
//!   every rank observes the same typed error instead of deadlocking.
//! * [`CommError::Transport`] — the transport substrate itself failed
//!   (lost socket, corrupt frame).
//!
//! `CommError` implements [`std::error::Error`], so `?` lifts it into
//! `anyhow::Result` call sites, and `anyhow::Error::downcast_ref::<CommError>()`
//! recovers the typed value at the `run_distributed` boundary.

use std::fmt;

use super::communicator::Communicator;

/// Result alias for collective operations.
pub type CommResult<T> = Result<T, CommError>;

/// Wrap one rank's closure result in the abort protocol (shared by the
/// training pipeline and the serving shard workers):
///
/// * a **rank-local** failure (I/O error, bad input) broadcasts an
///   abort so peers parked at any collective wake with
///   [`CommError::RemoteAbort`] carrying this rank as the origin, and
///   that canonical abort is what this rank propagates;
/// * [`CommError::RemoteAbort`] passes through untouched — the group
///   is already poisoned and the origin tag must be preserved;
/// * [`CommError::Timeout`] passes through **without** re-broadcast:
///   aborting here would mis-tag the timeout as a `RemoteAbort`
///   originated by an innocent waiting rank; peers resolve through
///   their own deadlines;
/// * other typed comm errors (contract violation, transport failure)
///   are returned as-is but still broadcast an abort first — they can
///   be detected locally before any exchange (an out-of-range root),
///   where peers would otherwise stay parked; when the group already
///   observed the error the extra abort is an idempotent no-op.
pub fn abort_on_local_failure<T>(
    ctx: &mut impl Communicator,
    result: anyhow::Result<T>,
) -> anyhow::Result<T> {
    match result {
        Ok(v) => Ok(v),
        Err(e) => match e.downcast_ref::<CommError>() {
            Some(CommError::RemoteAbort { .. } | CommError::Timeout { .. }) => Err(e),
            Some(_) => {
                ctx.abort(&format!("{e:#}"));
                Err(e)
            }
            None => Err(anyhow::Error::from(ctx.abort(&format!("{e:#}")))),
        },
    }
}

/// Why a collective (or the transport beneath it) failed.
#[derive(Clone, Debug, PartialEq)]
pub enum CommError {
    /// A rank called [`super::Communicator::abort`] (directly, or via
    /// the pipeline's failure wrapper): the abort was broadcast and this
    /// rank observed it. `origin_rank` is the first rank that aborted.
    RemoteAbort { origin_rank: usize, message: String },
    /// The configured communication deadline elapsed on `rank` while
    /// waiting for `waiting_for`.
    Timeout { rank: usize, seconds: f64, waiting_for: String },
    /// The collective-usage contract was broken; `rank` is the rank the
    /// error was detected on (every rank of the group observes it).
    ContractViolation { rank: usize, message: String },
    /// Transport-level failure observed by `rank` (lost connection,
    /// corrupt frame, bind/accept failure).
    Transport { rank: usize, message: String },
}

impl CommError {
    /// The rank this error instance was observed on (for `RemoteAbort`,
    /// the rank that originated the abort).
    pub fn rank(&self) -> usize {
        match self {
            CommError::RemoteAbort { origin_rank, .. } => *origin_rank,
            CommError::Timeout { rank, .. }
            | CommError::ContractViolation { rank, .. }
            | CommError::Transport { rank, .. } => *rank,
        }
    }
}

impl fmt::Display for CommError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CommError::RemoteAbort { origin_rank, message } => {
                write!(f, "aborted by rank {origin_rank}: {message}")
            }
            CommError::Timeout { rank, seconds, waiting_for } => {
                write!(f, "rank {rank}: timed out after {seconds:.1}s waiting for {waiting_for}")
            }
            CommError::ContractViolation { rank, message } => {
                write!(f, "rank {rank}: collective contract violation: {message}")
            }
            CommError::Transport { rank, message } => {
                write!(f, "rank {rank}: transport failure: {message}")
            }
        }
    }
}

impl std::error::Error for CommError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_rank_tagged() {
        let e = CommError::RemoteAbort { origin_rank: 3, message: "EIO".into() };
        assert_eq!(e.to_string(), "aborted by rank 3: EIO");
        assert_eq!(e.rank(), 3);
        let t =
            CommError::Timeout { rank: 1, seconds: 2.5, waiting_for: "reply from rank 0".into() };
        assert!(t.to_string().contains("rank 1") && t.to_string().contains("2.5"));
        assert_eq!(t.rank(), 1);
    }

    #[test]
    fn abort_on_local_failure_broadcasts_only_local_errors() {
        use super::super::SelfComm;
        // a rank-local failure broadcasts an abort and returns the
        // canonical origin-tagged error
        let mut ctx = SelfComm::new();
        let out: anyhow::Result<()> =
            abort_on_local_failure(&mut ctx, Err(anyhow::anyhow!("EIO at chunk 4")));
        match out.unwrap_err().downcast_ref::<CommError>() {
            Some(CommError::RemoteAbort { origin_rank: 0, message }) => {
                assert!(message.contains("EIO at chunk 4"));
            }
            other => panic!("expected RemoteAbort, got {other:?}"),
        }
        assert!(ctx.barrier().is_err(), "the group must be poisoned");

        // a timeout passes through typed and is NOT re-broadcast (a
        // timeout must stay a timeout, not become this rank's abort)
        let mut ctx = SelfComm::new();
        let timeout =
            CommError::Timeout { rank: 0, seconds: 1.0, waiting_for: "peers".to_string() };
        let out: anyhow::Result<()> =
            abort_on_local_failure(&mut ctx, Err(anyhow::Error::from(timeout.clone())));
        assert_eq!(out.unwrap_err().downcast_ref::<CommError>(), Some(&timeout));
        assert!(ctx.barrier().is_ok(), "timeout passthrough must not poison the group");

        // a contract violation stays typed but still broadcasts: it can
        // be detected locally before any exchange (root out of range),
        // where peers would otherwise stay parked
        let mut ctx = SelfComm::new();
        let cv = ctx.check_root("gather", 5).unwrap_err();
        let out: anyhow::Result<()> =
            abort_on_local_failure(&mut ctx, Err(anyhow::Error::from(cv.clone())));
        assert_eq!(out.unwrap_err().downcast_ref::<CommError>(), Some(&cv));
        assert!(ctx.barrier().is_err(), "local contract violation must poison the group");
    }

    #[test]
    fn lifts_into_anyhow_and_downcasts_back() {
        fn fails() -> anyhow::Result<()> {
            Err(CommError::ContractViolation { rank: 2, message: "root 9 out of range".into() })?;
            Ok(())
        }
        let e = fails().unwrap_err().context("step IV");
        assert!(format!("{e:#}").contains("root 9 out of range"));
        let ce = e.downcast_ref::<CommError>().expect("typed source survives");
        assert_eq!(ce.rank(), 2);
    }
}

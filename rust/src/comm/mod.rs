//! In-process MPI-like communicator substrate.
//!
//! The paper runs dOpInf as one MPI group with p ranks (Sec. III.A). We
//! reproduce the same SPMD programming model with p *threads*: each rank
//! executes the same pipeline function against its own data partition
//! and synchronizes through exact shared-memory collectives
//! ([`communicator::RankCtx`]): `Allreduce(SUM|MAX|MIN)`, `Bcast`,
//! `Barrier`, `Gather` — reductions applied in rank order, so results
//! are bitwise deterministic regardless of thread scheduling.
//!
//! **Timing model** (DESIGN.md §3): this testbed has one physical core,
//! so wall-clock cannot exhibit strong scaling. Each rank instead carries
//! a virtual clock ([`clock::Clock`]) fed by per-thread CPU time
//! (`CLOCK_THREAD_CPUTIME_ID`) for compute segments and by an α–β
//! binomial-tree model ([`costmodel::CostModel`]) for collectives;
//! collective entry synchronizes clocks to the max over ranks, exactly
//! like a real bulk-synchronous MPI program. Numerics are unaffected —
//! the collectives are exact.

pub mod clock;
pub mod communicator;
pub mod costmodel;

pub use clock::{Category, Clock};
pub use communicator::{run, run_with_clocks, Op, RankCtx};
pub use costmodel::CostModel;

//! Transport-abstracted MPI-like communicator substrate.
//!
//! The paper runs dOpInf as one MPI group with p ranks (Sec. III.A).
//! We reproduce the same SPMD programming model behind the
//! [`Communicator`] trait: pipeline code is written against the
//! collective vocabulary, never against a concrete transport, and
//! every backend combines contributions through the same rank-ordered
//! [`fold`] kernels — so results are **bitwise identical across
//! transports** regardless of thread scheduling or packet order.
//!
//! ## Collective vocabulary
//!
//! Every method returns `Result<T, CommError>` — at thousands of
//! ranks, single-rank failures are routine, not exceptional.
//!
//! | trait method                         | MPI counterpart          | pipeline use (paper Sec. III)              |
//! |--------------------------------------|--------------------------|--------------------------------------------|
//! | [`Communicator::allreduce`] / `_inplace` / `_scalar` | `MPI_Allreduce` | Step II maxabs, Step III Gram `D`, Step IV best-error vote |
//! | [`Communicator::broadcast`]          | `MPI_Bcast`              | Step IV winner ships `(β₁, β₂, Q̃)`        |
//! | [`Communicator::allgather`]          | `MPI_Allgather`          | replicated gathers where all ranks consume |
//! | [`Communicator::gather`]             | `MPI_Gather`             | serve/: probe-series aggregation on rank 0 |
//! | [`Communicator::reduce`]             | `MPI_Reduce`             | rooted reductions (root-only statistics)   |
//! | [`Communicator::reduce_scatter_block`] | `MPI_Reduce_scatter_block` | block-distributed reductions             |
//! | [`Communicator::barrier`]            | `MPI_Barrier`            | phase alignment in benches/tests           |
//! | [`Communicator::abort`]              | ≈ `MPI_Abort`            | rank failure → abort broadcast, recoverable at `run_distributed` |
//!
//! ## Error semantics ([`CommError`])
//!
//! | failure                                   | every rank observes          | old (infallible) behaviour |
//! |-------------------------------------------|------------------------------|----------------------------|
//! | a rank calls `abort` (local I/O error, …) | `RemoteAbort { origin_rank }`| siblings hang at the next collective |
//! | peer never arrives (deadline configured)  | `Timeout`                    | indefinite block           |
//! | contract misuse (bcast payload, ragged reduce_scatter, mismatched collectives) | `ContractViolation` | rank-tagged panic |
//! | lost connection / corrupt frame (sockets) | `Transport`                  | panic                      |
//!
//! `abort` is the recoverable analogue of `MPI_Abort`: it poisons the
//! thread board / relays error frames through the socket hub /
//! short-circuits [`SelfComm`], waking every peer parked at any
//! collective — but the process survives, and `run_distributed`
//! aggregates the per-rank errors into one typed
//! `DOpInfError::RemoteAbort` carrying the originating rank.
//!
//! ## Backends
//!
//! | module | handle | ranks are | reduction topology | reach |
//! |--------|--------|-----------|--------------------|-------|
//! | [`selfcomm`] | [`SelfComm`] | the calling thread (p = 1) | identity | in-process |
//! | [`thread`] | [`RankCtx`] (default) | threads of one process | shared contribution board, single rank-ordered fold | in-process |
//! | [`socket`] | [`socket::SocketComm`] | threads of one process over localhost TCP | rank-0 hub star, single rank-ordered fold at the hub | localhost wire |
//! | [`proc`] | [`socket::SocketComm`] per OS process | **spawned worker processes** (`dopinf worker`) | rank-0 hub star over real process boundaries | localhost processes; multi-machine documented |
//! | [`hier`] | [`hier::HierCtx`] | threads grouped into nodes | two-level: node boards + a binary leader tree; raw parts funnel to the root for one rank-ordered fold | models multi-node topology |
//!
//! * [`thread`] — p rank threads synchronizing through a poisonable
//!   contribution board; exact collectives, reductions in rank order.
//! * [`selfcomm`] — the zero-overhead p = 1 backend: no threads, no
//!   barriers; every collective is the identity.
//! * [`socket`] — length-prefixed frames with rank 0 as rendezvous
//!   hub, abort/error frames on the same channel, optional rendezvous
//!   + I/O deadlines; the hub collects requests with a readiness poll,
//!   so aborts and dead peers fan out the moment they are observed.
//! * [`proc`] — the socket wire protocol across real OS processes:
//!   rank 0 spawns `p - 1` copies of the `dopinf` binary via the
//!   hidden `worker` subcommand, ships each a job frame, runs the
//!   collectives over the same hub, and collects join reports (clock,
//!   trace, result) when the job ends. A SIGKILLed worker surfaces as
//!   a typed error on every survivor, never a hang.
//! * [`hier`] — hierarchical two-level collectives: thread boards
//!   within each node, TCP streams between per-node leader ranks in a
//!   binary tree (no rank-0 star). Costs come from the two-level
//!   [`costmodel::TwoLevelModel`]; results stay bitwise identical to
//!   the flat transports because leaders forward *unreduced* rank-
//!   tagged parts and the root folds exactly once, in rank order.
//!
//! ## Telemetry
//!
//! Every backend carries a per-rank [`crate::obs::Tracer`]
//! ([`Communicator::tracer`] / [`Communicator::tracer_mut`]), and every
//! collective — in all transports — closes exactly one
//! [`crate::obs::CommRecord`] per call: primitive name, payload bytes
//! (the same byte count handed to the cost model), measured wall time,
//! the wait share (time parked at the rendezvous: the thread board
//! wait, a socket leaf's `read_reply`, the hub's frame-read loop), and
//! the α–β *predicted* time next to it, plus a link tag (`"flat"` for
//! the single-level transports; the hierarchical backend tags node-
//! local hops `"intra"` and leader-tree hops `"inter"`). Failed
//! collectives record too — an aborted run never leaves a collective
//! span open — while the fail-fast path of an already-poisoned handle
//! records nothing. Tracing is off by default (one branch per probe
//! point) and wall readings never feed the virtual clocks, so numerics
//! and the timing model are unaffected either way.
//!
//! **Timing model** (DESIGN.md §3): this testbed has one physical core,
//! so wall-clock cannot exhibit strong scaling. Each rank instead
//! carries a virtual clock ([`clock::Clock`]) fed by per-thread CPU
//! time (`CLOCK_THREAD_CPUTIME_ID`) for compute segments and by an α–β
//! binomial-tree model ([`costmodel::CostModel`], with per-primitive
//! entries for the rooted collectives) for communication; collective
//! entry synchronizes clocks to the max over ranks, exactly like a
//! real bulk-synchronous MPI program. Numerics are unaffected — the
//! collectives are exact, and the happy path is bitwise identical to
//! the pre-fallible API.

pub mod clock;
pub mod communicator;
pub mod costmodel;
pub mod error;
pub mod hier;
pub mod proc;
pub mod selfcomm;
pub mod socket;
pub mod thread;

pub use clock::{Category, Clock};
pub use communicator::{fold, Communicator, Op};
pub use costmodel::{CoreModel, CostModel, DiskModel, TwoLevelModel};
pub use error::{abort_on_local_failure, CommError, CommResult};
pub use selfcomm::SelfComm;
pub use thread::{run, run_with_clocks, run_with_clocks_timeout, RankCtx};

//! Snapshot dataset I/O substrate — the streaming data plane's bottom
//! layer.
//!
//! The paper stores training snapshots in HDF5 and leans on independent
//! per-rank row-slice reads (Step I, Remark 1). HDF5 is an external C
//! library we do not link, so [`snapd`] defines an equivalent chunked
//! binary container: named per-variable datasets of shape
//! `(spatial_dof, n_snapshots)` stored row-major, which makes a rank's
//! contiguous row range `[start, end)` a single contiguous pread — the
//! same access pattern h5py hyperslab selection gives the tutorial.
//! `SnapReader::open` validates the declared payload spans against the
//! file before any data is served, and `SnapWriter` streams row chunks
//! so datasets far beyond RAM can be written as well as read.
//!
//! [`reader`] is the primary ingestion path: the [`BlockReader`] trait
//! yields bounded row [`reader::Chunk`]s of a rank's block (SNAPD,
//! in-memory, or synthetic backed), which the pass-structured pipeline
//! in `coordinator::pipeline` streams through the Step II–III kernels
//! without ever materializing a full `(n_s·n_x/p, n_t)` block.
//!
//! [`partition`] implements the tutorial's `distribute_nx` splitting
//! (equal blocks, remainder to the last rank) plus a balanced variant;
//! [`probes`] maps physical probe locations to dataset row indices.

pub mod partition;
pub mod probes;
pub mod reader;
pub mod snapd;

pub use partition::{distribute_balanced, distribute_tutorial, RowRange};
pub use reader::{
    BlockReader, Chunk, FaultKind, FaultPass, FaultSpec, FaultyBlockReader, InMemoryBlockReader,
    SnapdBlockReader, SyntheticBlockReader,
};
pub use snapd::{SnapReader, SnapWriter};

//! Snapshot dataset I/O substrate.
//!
//! The paper stores training snapshots in HDF5 and leans on independent
//! per-rank row-slice reads (Step I, Remark 1). HDF5 is an external C
//! library we do not link, so [`snapd`] defines an equivalent chunked
//! binary container: named per-variable datasets of shape
//! `(spatial_dof, n_snapshots)` stored row-major, which makes a rank's
//! contiguous row range `[start, end)` a single contiguous pread — the
//! same access pattern h5py hyperslab selection gives the tutorial.
//!
//! [`partition`] implements the tutorial's `distribute_nx` splitting
//! (equal blocks, remainder to the last rank) plus a balanced variant;
//! [`probes`] maps physical probe locations to dataset row indices.

pub mod partition;
pub mod probes;
pub mod snapd;

pub use partition::{distribute_balanced, distribute_tutorial, RowRange};
pub use snapd::{SnapReader, SnapWriter};

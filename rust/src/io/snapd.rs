//! SNAPD: the snapshot dataset container (HDF5 stand-in, DESIGN.md §3).
//!
//! Layout:
//! ```text
//! [0..8)    magic  b"SNAPD\x01\0\0"
//! [8..16)   header length H (u64 LE)
//! [16..16+H) JSON header:
//!     {"variables": [{"name": "u_x", "rows": R, "cols": C, "offset": O}, ...],
//!      "meta": {...}}
//! [..]      per-variable payload: rows*cols f64 LE, row-major
//! ```
//! Row-major `(spatial_dof, n_snapshots)` payout means a rank's row range
//! `[start, end)` is one contiguous byte range — the independent
//! per-rank reads of paper Step I with no shared state between readers.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::io::partition::RowRange;
use crate::linalg::Matrix;
use crate::util::json::{self, Json};

const MAGIC: &[u8; 8] = b"SNAPD\x01\0\0";

/// Dataset writer. Declares variables up-front, then streams each
/// variable's row-major payload — either whole ([`Self::write_variable`])
/// or in bounded row chunks ([`Self::write_rows`]), so fields far
/// beyond RAM can be written without ever materializing them.
///
/// The payload is staged into a same-directory temp sibling
/// ([`crate::util::atomic`]) and renamed onto the final path by
/// [`Self::finish`], so a crash mid-simulation never leaves a torn
/// dataset where a complete one is expected — only an orphaned
/// `.tmp.*` file later writers overwrite.
pub struct SnapWriter {
    out: BufWriter<File>,
    /// the staged temp sibling being written
    tmp: PathBuf,
    /// the final path [`Self::finish`] promotes onto
    path: PathBuf,
    vars: Vec<(String, usize, usize)>,
    written: usize,
    /// rows of the current (partially streamed) variable already written
    rows_in_flight: usize,
}

impl SnapWriter {
    /// Create the file and write the header. `vars` are
    /// `(name, rows, cols)` in payload order; `meta` is free-form JSON.
    pub fn create<P: AsRef<Path>>(
        path: P,
        vars: &[(&str, usize, usize)],
        meta: Json,
    ) -> Result<SnapWriter> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut offset = 0usize;
        let entries: Vec<Json> = vars
            .iter()
            .map(|(name, rows, cols)| {
                let e = Json::obj(vec![
                    ("name", Json::Str(name.to_string())),
                    ("rows", Json::Num(*rows as f64)),
                    ("cols", Json::Num(*cols as f64)),
                    ("offset", Json::Num(offset as f64)),
                ]);
                offset += rows * cols * 8;
                e
            })
            .collect();
        let header = json::emit(&Json::obj(vec![
            ("variables", Json::Arr(entries)),
            ("meta", meta),
        ]));
        let final_path = path.as_ref().to_path_buf();
        let tmp = crate::util::atomic::temp_sibling(&final_path);
        let mut out = BufWriter::new(File::create(&tmp)?);
        out.write_all(MAGIC)?;
        out.write_all(&(header.len() as u64).to_le_bytes())?;
        out.write_all(header.as_bytes())?;
        Ok(SnapWriter {
            out,
            tmp,
            path: final_path,
            vars: vars.iter().map(|(n, r, c)| (n.to_string(), *r, *c)).collect(),
            written: 0,
            rows_in_flight: 0,
        })
    }

    /// Stream the next rows of the current variable. Call repeatedly
    /// with consecutive row chunks; once the declared row count is
    /// reached the writer advances to the next declared variable.
    pub fn write_rows(&mut self, name: &str, chunk: &Matrix) -> Result<()> {
        let (want_name, rows, cols) = self
            .vars
            .get(self.written)
            .context("more variables written than declared")?
            .clone();
        if want_name != name {
            bail!("expected variable {want_name:?} next, got {name:?}");
        }
        if chunk.cols() != cols {
            bail!("variable {name}: declared {cols} cols, chunk has {}", chunk.cols());
        }
        if self.rows_in_flight + chunk.rows() > rows {
            bail!(
                "variable {name}: declared {rows} rows, writing {} would overrun",
                self.rows_in_flight + chunk.rows()
            );
        }
        for v in chunk.data() {
            self.out.write_all(&v.to_le_bytes())?;
        }
        self.rows_in_flight += chunk.rows();
        if self.rows_in_flight == rows {
            self.written += 1;
            self.rows_in_flight = 0;
        }
        Ok(())
    }

    /// Write the next variable's payload whole (must match declared
    /// order/shape exactly).
    pub fn write_variable(&mut self, name: &str, data: &Matrix) -> Result<()> {
        if self.rows_in_flight > 0 {
            bail!("variable {name}: mixing write_variable with a partially streamed variable");
        }
        if let Some((_, rows, cols)) = self.vars.get(self.written) {
            if data.rows() != *rows || data.cols() != *cols {
                bail!(
                    "variable {name}: declared {rows}x{cols}, got {}x{}",
                    data.rows(),
                    data.cols()
                );
            }
        }
        self.write_rows(name, data)
    }

    /// Flush, fsync, and atomically promote the staged file onto the
    /// final path; errors (removing the staged file) if any declared
    /// variable was not written or was only partially streamed.
    pub fn finish(mut self) -> Result<()> {
        if self.rows_in_flight > 0 {
            let (name, rows, _) = &self.vars[self.written];
            std::fs::remove_file(&self.tmp).ok();
            bail!("variable {name}: only {} of {rows} rows streamed", self.rows_in_flight);
        }
        if self.written != self.vars.len() {
            std::fs::remove_file(&self.tmp).ok();
            bail!("{} of {} variables written", self.written, self.vars.len());
        }
        self.out.flush()?;
        self.out.get_ref().sync_all()?;
        crate::util::atomic::promote(&self.tmp, &self.path)?;
        Ok(())
    }
}

/// Shape info for one stored variable.
#[derive(Clone, Debug)]
pub struct VarInfo {
    pub rows: usize,
    pub cols: usize,
    offset: u64,
}

/// Dataset reader with row-range (hyperslab) access.
pub struct SnapReader {
    path: PathBuf,
    payload_start: u64,
    vars: BTreeMap<String, VarInfo>,
    meta: Json,
}

impl SnapReader {
    /// Open a SNAPD file and parse the header.
    pub fn open<P: AsRef<Path>>(path: P) -> Result<SnapReader> {
        let mut f = File::open(&path)
            .with_context(|| format!("open {:?}", path.as_ref()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{:?} is not a SNAPD file", path.as_ref());
        }
        let mut len = [0u8; 8];
        f.read_exact(&mut len)?;
        let header_len = u64::from_le_bytes(len) as usize;
        let mut header = vec![0u8; header_len];
        f.read_exact(&mut header)?;
        let header: Json = json::parse(std::str::from_utf8(&header)?)
            .map_err(|e| anyhow::anyhow!("bad SNAPD header: {e}"))?;

        let mut vars = BTreeMap::new();
        for v in header.get("variables").context("no variables")?.as_arr().context("bad vars")? {
            let name = v.get("name").and_then(Json::as_str).context("var name")?;
            vars.insert(
                name.to_string(),
                VarInfo {
                    rows: v.get("rows").and_then(Json::as_usize).context("rows")?,
                    cols: v.get("cols").and_then(Json::as_usize).context("cols")?,
                    offset: v.get("offset").and_then(Json::as_f64).context("offset")? as u64,
                },
            );
        }

        // Fail fast on truncated or corrupt files: every declared
        // payload must fit inside the file and payloads must not
        // overlap. Without this, a short file surfaces as a confusing
        // short-read mid-pipeline (or silently serves another
        // variable's bytes).
        let payload_start = 16 + header_len as u64;
        let payload_len = f
            .metadata()?
            .len()
            .checked_sub(payload_start)
            .with_context(|| format!("{:?}: SNAPD header longer than file", path.as_ref()))?;
        let mut spans: Vec<(u64, u64, &str)> = Vec::with_capacity(vars.len());
        for (name, info) in &vars {
            let len = (info.rows as u64)
                .checked_mul(info.cols as u64)
                .and_then(|n| n.checked_mul(8))
                .with_context(|| {
                    format!("variable {name:?}: declared {}x{} payload overflows", info.rows, info.cols)
                })?;
            spans.push((info.offset, len, name.as_str()));
        }
        for &(off, len, name) in &spans {
            let end = off
                .checked_add(len)
                .with_context(|| format!("variable {name:?}: payload span overflows"))?;
            if end > payload_len {
                bail!(
                    "{:?} is truncated or corrupt: variable {name:?} declares payload \
                     bytes {off}..{end} but only {payload_len} payload bytes exist",
                    path.as_ref()
                );
            }
        }
        spans.sort_by_key(|&(off, _, _)| off);
        for w in spans.windows(2) {
            let (off_a, len_a, name_a) = w[0];
            let (off_b, _, name_b) = w[1];
            if off_a + len_a > off_b {
                bail!(
                    "{:?} header is corrupt: variables {name_a:?} (bytes {off_a}..{}) and \
                     {name_b:?} (from byte {off_b}) declare overlapping payloads",
                    path.as_ref(),
                    off_a + len_a
                );
            }
        }

        Ok(SnapReader {
            path: path.as_ref().to_path_buf(),
            payload_start: 16 + header_len as u64,
            vars,
            meta: header.get("meta").cloned().unwrap_or(Json::Null),
        })
    }

    pub fn meta(&self) -> &Json {
        &self.meta
    }

    pub fn variables(&self) -> Vec<&str> {
        self.vars.keys().map(|s| s.as_str()).collect()
    }

    pub fn var_info(&self, name: &str) -> Result<&VarInfo> {
        self.vars.get(name).with_context(|| format!("no variable {name:?}"))
    }

    /// Read rows `[range.start, range.end)` of `name` — one contiguous
    /// pread per call; safe to call concurrently from many ranks (each
    /// opens its own handle, mirroring MPI-IO independent reads).
    pub fn read_rows(&self, name: &str, range: RowRange) -> Result<Matrix> {
        let mut f = File::open(&self.path)?;
        self.read_rows_from(&mut f, name, range)
    }

    /// [`Self::read_rows`] through an existing open handle — streaming
    /// readers keep one handle per pass instead of reopening the file
    /// for every chunk segment. Seeks are absolute, so one handle can
    /// serve any sequence of segment reads.
    pub fn read_rows_from(&self, f: &mut File, name: &str, range: RowRange) -> Result<Matrix> {
        let info = self.var_info(name)?.clone();
        if range.end > info.rows || range.start > range.end {
            bail!(
                "row range {}..{} out of bounds for {name} ({} rows)",
                range.start,
                range.end,
                info.rows
            );
        }
        let byte_start =
            self.payload_start + info.offset + (range.start * info.cols * 8) as u64;
        f.seek(SeekFrom::Start(byte_start))?;
        let count = range.len() * info.cols;
        let mut bytes = vec![0u8; count * 8];
        f.read_exact(&mut bytes)?;
        let data: Vec<f64> = bytes
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Matrix::from_vec(range.len(), info.cols, data))
    }

    /// A fresh read handle on the underlying file, for
    /// [`Self::read_rows_from`].
    pub fn open_handle(&self) -> Result<File> {
        Ok(File::open(&self.path)?)
    }

    /// Read a whole variable.
    pub fn read_all(&self, name: &str) -> Result<Matrix> {
        let rows = self.var_info(name)?.rows;
        self.read_rows(name, RowRange { start: 0, end: rows })
    }

    /// Read a single row (probe extraction).
    pub fn read_row(&self, name: &str, row: usize) -> Result<Vec<f64>> {
        Ok(self
            .read_rows(name, RowRange { start: row, end: row + 1 })?
            .into_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::partition::distribute_balanced;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("dopinf_snapd_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn write_sample(path: &Path, rows: usize, cols: usize) -> (Matrix, Matrix) {
        let ux = Matrix::randn(rows, cols, 1);
        let uy = Matrix::randn(rows, cols, 2);
        let mut w = SnapWriter::create(
            path,
            &[("u_x", rows, cols), ("u_y", rows, cols)],
            Json::obj(vec![("dt", Json::Num(0.5))]),
        )
        .unwrap();
        w.write_variable("u_x", &ux).unwrap();
        w.write_variable("u_y", &uy).unwrap();
        w.finish().unwrap();
        (ux, uy)
    }

    #[test]
    fn roundtrip_full() {
        let path = tmp("roundtrip.snapd");
        let (ux, uy) = write_sample(&path, 37, 9);
        let r = SnapReader::open(&path).unwrap();
        assert_eq!(r.variables(), vec!["u_x", "u_y"]);
        assert_eq!(r.read_all("u_x").unwrap(), ux);
        assert_eq!(r.read_all("u_y").unwrap(), uy);
        assert_eq!(r.meta().get("dt").unwrap().as_f64().unwrap(), 0.5);
    }

    #[test]
    fn row_slices_reassemble() {
        let path = tmp("slices.snapd");
        let (ux, _) = write_sample(&path, 101, 7);
        let r = SnapReader::open(&path).unwrap();
        let mut rebuilt = Matrix::zeros(0, 7);
        for range in distribute_balanced(101, 5) {
            rebuilt = rebuilt.vstack(&r.read_rows("u_x", range).unwrap());
        }
        assert_eq!(rebuilt, ux);
    }

    #[test]
    fn concurrent_rank_reads() {
        let path = tmp("concurrent.snapd");
        let (ux, _) = write_sample(&path, 64, 6);
        let r = SnapReader::open(&path).unwrap();
        let ranges = distribute_balanced(64, 4);
        let parts: Vec<Matrix> = std::thread::scope(|s| {
            let handles: Vec<_> = ranges
                .iter()
                .map(|&range| {
                    let r = &r;
                    s.spawn(move || r.read_rows("u_x", range).unwrap())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut rebuilt = parts[0].clone();
        for p in &parts[1..] {
            rebuilt = rebuilt.vstack(p);
        }
        assert_eq!(rebuilt, ux);
    }

    #[test]
    fn single_row_read() {
        let path = tmp("row.snapd");
        let (ux, _) = write_sample(&path, 20, 5);
        let r = SnapReader::open(&path).unwrap();
        assert_eq!(r.read_row("u_x", 13).unwrap(), ux.row(13));
    }

    #[test]
    fn rejects_bad_access() {
        let path = tmp("bad.snapd");
        write_sample(&path, 10, 4);
        let r = SnapReader::open(&path).unwrap();
        assert!(r.read_rows("u_x", RowRange { start: 5, end: 11 }).is_err());
        assert!(r.read_all("nope").is_err());
    }

    #[test]
    fn writer_enforces_declaration() {
        let path = tmp("declare.snapd");
        let mut w =
            SnapWriter::create(&path, &[("a", 4, 3)], Json::Null).unwrap();
        // wrong name
        assert!(w.write_variable("b", &Matrix::zeros(4, 3)).is_err());
        // wrong shape
        assert!(w.write_variable("a", &Matrix::zeros(3, 3)).is_err());
        w.write_variable("a", &Matrix::zeros(4, 3)).unwrap();
        w.finish().unwrap();
        // missing variable
        let w2 = SnapWriter::create(&path, &[("a", 1, 1)], Json::Null).unwrap();
        assert!(w2.finish().is_err());
    }

    #[test]
    fn rejects_non_snapd_file() {
        let path = tmp("not.snapd");
        std::fs::write(&path, b"hello world, definitely not snapd").unwrap();
        assert!(SnapReader::open(&path).is_err());
    }

    #[test]
    fn chunked_row_writes_roundtrip() {
        let path = tmp("chunked.snapd");
        let ux = Matrix::randn(33, 5, 7);
        let uy = Matrix::randn(33, 5, 8);
        let mut w = SnapWriter::create(
            &path,
            &[("u_x", 33, 5), ("u_y", 33, 5)],
            Json::Null,
        )
        .unwrap();
        // ragged chunks, crossing into the next variable mid-stream
        for (s, e) in [(0, 10), (10, 11), (11, 33)] {
            w.write_rows("u_x", &ux.slice_rows(s, e)).unwrap();
        }
        for (s, e) in [(0, 32), (32, 33)] {
            w.write_rows("u_y", &uy.slice_rows(s, e)).unwrap();
        }
        w.finish().unwrap();
        let r = SnapReader::open(&path).unwrap();
        assert_eq!(r.read_all("u_x").unwrap(), ux);
        assert_eq!(r.read_all("u_y").unwrap(), uy);
    }

    #[test]
    fn chunked_writer_enforces_bounds() {
        let path = tmp("chunked_bounds.snapd");
        let mut w = SnapWriter::create(&path, &[("a", 4, 3), ("b", 2, 3)], Json::Null).unwrap();
        // row overrun
        assert!(w.write_rows("a", &Matrix::zeros(5, 3)).is_err());
        // wrong width
        assert!(w.write_rows("a", &Matrix::zeros(2, 4)).is_err());
        w.write_rows("a", &Matrix::zeros(2, 3)).unwrap();
        // not the current variable
        assert!(w.write_rows("b", &Matrix::zeros(1, 3)).is_err());
        // write_variable cannot interleave with a partial stream
        assert!(w.write_variable("a", &Matrix::zeros(4, 3)).is_err());
        w.write_rows("a", &Matrix::zeros(2, 3)).unwrap();
        // partial tail variable fails finish
        w.write_rows("b", &Matrix::zeros(1, 3)).unwrap();
        assert!(w.finish().is_err());
    }

    #[test]
    fn open_rejects_truncated_file() {
        let path = tmp("truncated.snapd");
        write_sample(&path, 16, 6);
        let full = std::fs::metadata(&path).unwrap().len();
        // chop half the second variable's payload off
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(full - (16 * 6 * 8) / 2).unwrap();
        drop(f);
        let err = SnapReader::open(&path).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        // the error names the variable whose payload is short
        assert!(err.contains("u_y"), "{err}");
    }

    #[test]
    fn open_rejects_header_payload_mismatch() {
        // header declares more rows than the payload holds
        let path = tmp("short_payload.snapd");
        let header = r#"{"variables": [{"name": "u_x", "rows": 100, "cols": 10, "offset": 0}], "meta": null}"#;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"SNAPD\x01\0\0");
        bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(&[0u8; 80]); // 10 doubles, not 1000
        std::fs::write(&path, &bytes).unwrap();
        let err = SnapReader::open(&path).unwrap_err().to_string();
        assert!(err.contains("u_x") && err.contains("truncated"), "{err}");
    }

    #[test]
    fn open_rejects_overlapping_offsets() {
        let path = tmp("overlap.snapd");
        let header = r#"{"variables": [{"name": "a", "rows": 2, "cols": 2, "offset": 0}, {"name": "b", "rows": 2, "cols": 2, "offset": 16}], "meta": null}"#;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"SNAPD\x01\0\0");
        bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(&[0u8; 48]); // enough for b's span, but a overlaps it
        std::fs::write(&path, &bytes).unwrap();
        let err = SnapReader::open(&path).unwrap_err().to_string();
        assert!(err.contains("overlapping"), "{err}");
        assert!(err.contains('a') && err.contains('b'), "{err}");
    }
}

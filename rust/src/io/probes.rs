//! Probe bookkeeping: named physical locations -> dataset row indices.
//!
//! The paper's Step V postprocesses the ROM solution at three probe
//! locations near the mid-channel (Sec. III.F); the repository ships a
//! script mapping probe coordinates to grid indices. Here the mapping is
//! provided by the solver grid (`sim::grid::Grid::probe_index`) and this
//! module carries the resulting `(name, position, row)` set through the
//! pipeline and postprocessing outputs.

/// One probe: a label, its physical position, and the spatial row index
/// within a single state variable (0 <= row < nx).
#[derive(Clone, Debug, PartialEq)]
pub struct Probe {
    pub name: String,
    pub x: f64,
    pub y: f64,
    /// row index within one variable's (nx, nt) dataset
    pub row: usize,
}

/// An ordered probe collection.
#[derive(Clone, Debug, Default)]
pub struct ProbeSet {
    pub probes: Vec<Probe>,
}

impl ProbeSet {
    pub fn new() -> ProbeSet {
        ProbeSet::default()
    }

    pub fn push(&mut self, name: impl Into<String>, x: f64, y: f64, row: usize) {
        self.probes.push(Probe { name: name.into(), x, y, row });
    }

    pub fn len(&self) -> usize {
        self.probes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.probes.is_empty()
    }

    /// Row indices in probe order.
    pub fn rows(&self) -> Vec<usize> {
        self.probes.iter().map(|p| p.row).collect()
    }

    /// The paper's three probe locations (Sec. III.F), scaled to an
    /// arbitrary channel: fractions of (length, height) =
    /// (0.40, 0.20)/(2.2, 0.41) etc. of the DFG geometry.
    pub fn paper_fractions() -> [(f64, f64); 3] {
        [
            (0.40 / 2.2, 0.20 / 0.41),
            (0.60 / 2.2, 0.20 / 0.41),
            (1.00 / 2.2, 0.20 / 0.41),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordered_rows() {
        let mut ps = ProbeSet::new();
        ps.push("p1", 0.4, 0.2, 100);
        ps.push("p2", 0.6, 0.2, 200);
        assert_eq!(ps.rows(), vec![100, 200]);
        assert_eq!(ps.len(), 2);
        assert!(!ps.is_empty());
    }

    #[test]
    fn paper_fractions_in_unit_square() {
        for (fx, fy) in ProbeSet::paper_fractions() {
            assert!((0.0..=1.0).contains(&fx));
            assert!((0.0..=1.0).contains(&fy));
        }
    }
}

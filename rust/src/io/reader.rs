//! Streaming row-chunk ingestion: the [`BlockReader`] trait and its
//! backends.
//!
//! Step I of the distributed pipeline no longer loads a rank's whole
//! `(n_s·n_x/p, n_t)` block — it opens a `BlockReader` over the rank's
//! row range and pulls bounded [`Chunk`]s of at most `chunk_rows` local
//! rows per call. Each pass over the data (`reset` + drain) yields the
//! identical chunk sequence, rows in var-major local order, every row
//! complete — the contract the streaming transform/Gram kernels in
//! [`crate::opinf::streaming`] rely on for bitwise-invariant results.
//!
//! Backends:
//!
//! * [`SnapdBlockReader`] — SNAPD-file-backed: each chunk is one
//!   contiguous pread per variable it touches (the independent
//!   hyperslab reads of paper Step I, Remark 1), with optional
//!   training-column truncation so `train` never materializes the
//!   prediction horizon.
//! * [`InMemoryBlockReader`] — copies chunk rows out of a shared
//!   snapshot matrix (tests, benches, examples).
//! * [`SyntheticBlockReader`] — generates rows on demand from a
//!   [`SynthSpec`] mode table; state dimension is limited only by
//!   virtual patience, never by RAM.

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::io::partition::RowRange;
use crate::io::snapd::SnapReader;
use crate::linalg::Matrix;
use crate::sim::synth::{SynthField, SynthSpec};

/// One streamed chunk of a rank's block.
#[derive(Clone, Debug)]
pub struct Chunk {
    /// first local row index (var-major within the rank's block)
    pub start_row: usize,
    /// `(rows, nt)` chunk, rows in local order
    pub data: Matrix,
    /// bytes notionally read from storage for this chunk
    pub bytes: usize,
    /// discrete storage read operations (seek + sequential read) issued
    pub reads: usize,
}

/// A resettable, bounded-memory source of one rank's row chunks.
pub trait BlockReader {
    /// Total local rows each pass yields (`n_s · |range|`).
    fn local_rows(&self) -> usize;

    /// Snapshot columns per yielded row.
    fn nt(&self) -> usize;

    /// The next chunk of at most `chunk_rows` local rows, or `None`
    /// when the pass is complete.
    fn next_chunk(&mut self) -> Result<Option<Chunk>>;

    /// Rewind for another pass; the chunk sequence repeats exactly.
    fn reset(&mut self) -> Result<()>;

    /// Position the cursor so the next chunk starts at local row `row`
    /// (checkpoint resume skips the already-folded prefix this way).
    /// Resume always seeks to a chunk boundary of the interrupted run,
    /// so the remaining chunk sequence is identical to the
    /// uninterrupted pass's tail.
    fn seek_row(&mut self, row: usize) -> Result<()>;
}

/// Map a local row interval `[lo, hi)` to per-variable file segments.
/// Each segment is `(var, file_row_lo, file_row_hi)`.
fn var_segments(lo: usize, hi: usize, per: usize, range_start: usize) -> Vec<(usize, usize, usize)> {
    let mut segs = Vec::new();
    let mut cur = lo;
    while cur < hi {
        let var = cur / per;
        let seg_hi = hi.min((var + 1) * per);
        segs.push((var, range_start + (cur - var * per), range_start + (seg_hi - var * per)));
        cur = seg_hi;
    }
    segs
}

// ------------------------------------------------------------- SNAPD

/// SNAPD-backed chunk reader (one contiguous pread per variable
/// segment a chunk touches).
pub struct SnapdBlockReader {
    reader: SnapReader,
    /// one long-lived read handle per reader — segment reads seek
    /// absolutely, so chunked passes never reopen the file
    file: std::fs::File,
    variables: Vec<String>,
    range: RowRange,
    chunk_rows: usize,
    /// keep only the first `nt_train` snapshot columns of each row
    /// (full rows still stream through, so `bytes` counts file bytes)
    nt_train: Option<usize>,
    nt_file: usize,
    cursor: usize,
}

impl SnapdBlockReader {
    pub fn open<P: AsRef<Path>>(
        path: P,
        variables: &[String],
        range: RowRange,
        chunk_rows: usize,
        nt_train: Option<usize>,
    ) -> Result<SnapdBlockReader> {
        anyhow::ensure!(!variables.is_empty(), "no variables configured");
        anyhow::ensure!(chunk_rows >= 1, "chunk_rows must be >= 1");
        let reader = SnapReader::open(path)?;
        let first = reader.var_info(&variables[0])?.clone();
        for v in variables {
            let info = reader.var_info(v)?;
            anyhow::ensure!(
                info.rows == first.rows && info.cols == first.cols,
                "variable {v:?} is {}x{}, expected {}x{}",
                info.rows,
                info.cols,
                first.rows,
                first.cols
            );
        }
        anyhow::ensure!(
            range.start <= range.end && range.end <= first.rows,
            "row range {}..{} out of bounds ({} rows per variable)",
            range.start,
            range.end,
            first.rows
        );
        if let Some(ntt) = nt_train {
            anyhow::ensure!(
                ntt >= 1 && ntt <= first.cols,
                "nt_train = {ntt} out of bounds ({} snapshots stored)",
                first.cols
            );
        }
        let file = reader.open_handle()?;
        Ok(SnapdBlockReader {
            reader,
            file,
            variables: variables.to_vec(),
            range,
            chunk_rows,
            nt_train,
            nt_file: first.cols,
            cursor: 0,
        })
    }
}

impl BlockReader for SnapdBlockReader {
    fn local_rows(&self) -> usize {
        self.variables.len() * self.range.len()
    }

    fn nt(&self) -> usize {
        self.nt_train.unwrap_or(self.nt_file)
    }

    fn next_chunk(&mut self) -> Result<Option<Chunk>> {
        let total = self.local_rows();
        if self.cursor >= total {
            return Ok(None);
        }
        let start = self.cursor;
        let end = (start + self.chunk_rows).min(total);
        let nt = self.nt();
        let segs = var_segments(start, end, self.range.len(), self.range.start);

        // common case — one variable segment, no column truncation: the
        // decoded segment *is* the chunk, move it instead of re-copying
        // every row (this is the ingest hot path)
        if segs.len() == 1 && nt == self.nt_file {
            let (var, flo, fhi) = segs[0];
            let data = self.reader.read_rows_from(
                &mut self.file,
                &self.variables[var],
                RowRange { start: flo, end: fhi },
            )?;
            let bytes = data.rows() * data.cols() * 8;
            self.cursor = end;
            return Ok(Some(Chunk { start_row: start, data, bytes, reads: 1 }));
        }

        let mut data = Matrix::zeros(end - start, nt);
        let mut bytes = 0;
        let mut reads = 0;
        let mut filled = 0;
        for (var, flo, fhi) in segs {
            let part = self.reader.read_rows_from(
                &mut self.file,
                &self.variables[var],
                RowRange { start: flo, end: fhi },
            )?;
            bytes += part.rows() * part.cols() * 8;
            reads += 1;
            for i in 0..part.rows() {
                data.row_mut(filled + i).copy_from_slice(&part.row(i)[..nt]);
            }
            filled += part.rows();
        }
        debug_assert_eq!(filled, end - start);
        self.cursor = end;
        Ok(Some(Chunk { start_row: start, data, bytes, reads }))
    }

    fn reset(&mut self) -> Result<()> {
        self.cursor = 0;
        Ok(())
    }

    fn seek_row(&mut self, row: usize) -> Result<()> {
        anyhow::ensure!(row <= self.local_rows(), "seek past end of block");
        self.cursor = row;
        Ok(())
    }
}

// --------------------------------------------------------- in-memory

/// Chunk reader over a shared in-memory snapshot matrix (variables
/// stacked var-major over the full `n_x`, as `DataSource::InMemory`
/// stores them).
pub struct InMemoryBlockReader {
    q: Arc<Matrix>,
    range: RowRange,
    nx: usize,
    ns: usize,
    chunk_rows: usize,
    cursor: usize,
}

impl InMemoryBlockReader {
    pub fn new(
        q: Arc<Matrix>,
        range: RowRange,
        nx: usize,
        ns: usize,
        chunk_rows: usize,
    ) -> Result<InMemoryBlockReader> {
        anyhow::ensure!(chunk_rows >= 1, "chunk_rows must be >= 1");
        anyhow::ensure!(
            q.rows() == ns * nx,
            "in-memory source has {} rows, expected ns*nx = {}",
            q.rows(),
            ns * nx
        );
        anyhow::ensure!(range.end <= nx, "row range end {} > nx {}", range.end, nx);
        Ok(InMemoryBlockReader { q, range, nx, ns, chunk_rows, cursor: 0 })
    }
}

impl BlockReader for InMemoryBlockReader {
    fn local_rows(&self) -> usize {
        self.ns * self.range.len()
    }

    fn nt(&self) -> usize {
        self.q.cols()
    }

    fn next_chunk(&mut self) -> Result<Option<Chunk>> {
        let total = self.local_rows();
        if self.cursor >= total {
            return Ok(None);
        }
        let start = self.cursor;
        let end = (start + self.chunk_rows).min(total);
        let per = self.range.len();
        let nt = self.nt();
        let mut data = Matrix::zeros(end - start, nt);
        for li in start..end {
            let var = li / per;
            let global = var * self.nx + self.range.start + (li - var * per);
            data.row_mut(li - start).copy_from_slice(self.q.row(global));
        }
        self.cursor = end;
        Ok(Some(Chunk {
            start_row: start,
            bytes: (end - start) * nt * 8,
            reads: 1,
            data,
        }))
    }

    fn reset(&mut self) -> Result<()> {
        self.cursor = 0;
        Ok(())
    }

    fn seek_row(&mut self, row: usize) -> Result<()> {
        anyhow::ensure!(row <= self.local_rows(), "seek past end of block");
        self.cursor = row;
        Ok(())
    }
}

// --------------------------------------------------------- synthetic

/// Chunk reader that *generates* its rows from a synthetic mode table —
/// no backing storage at all, so arbitrarily large state dimensions
/// stream through O(chunk_rows · n_t) memory.
pub struct SyntheticBlockReader {
    field: SynthField,
    ns: usize,
    nt: usize,
    range: RowRange,
    chunk_rows: usize,
    t0_index: usize,
    cursor: usize,
}

impl SyntheticBlockReader {
    pub fn new(spec: &SynthSpec, range: RowRange, chunk_rows: usize) -> Result<SyntheticBlockReader> {
        anyhow::ensure!(chunk_rows >= 1, "chunk_rows must be >= 1");
        anyhow::ensure!(range.end <= spec.nx, "row range end {} > nx {}", range.end, spec.nx);
        Ok(SyntheticBlockReader {
            field: SynthField::new(spec),
            ns: spec.ns,
            nt: spec.nt,
            range,
            chunk_rows,
            t0_index: 0,
            cursor: 0,
        })
    }
}

impl BlockReader for SyntheticBlockReader {
    fn local_rows(&self) -> usize {
        self.ns * self.range.len()
    }

    fn nt(&self) -> usize {
        self.nt
    }

    fn next_chunk(&mut self) -> Result<Option<Chunk>> {
        let total = self.local_rows();
        if self.cursor >= total {
            return Ok(None);
        }
        let start = self.cursor;
        let end = (start + self.chunk_rows).min(total);
        let per = self.range.len();
        let mut data = Matrix::zeros(end - start, self.nt);
        for li in start..end {
            let var = li / per;
            let row = self.range.start + (li - var * per);
            self.field.fill_row(var, row, self.t0_index, data.row_mut(li - start));
        }
        self.cursor = end;
        // generated, not read: no storage traffic to model
        Ok(Some(Chunk { start_row: start, data, bytes: 0, reads: 0 }))
    }

    fn reset(&mut self) -> Result<()> {
        self.cursor = 0;
        Ok(())
    }

    fn seek_row(&mut self, row: usize) -> Result<()> {
        anyhow::ensure!(row <= self.local_rows(), "seek past end of block");
        self.cursor = row;
        Ok(())
    }
}

// --------------------------------------------------- fault injection

/// Whether an injected fault heals after firing a bounded number of
/// times, or fires on every run that reaches its trigger.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Fire on the first `fail_count` readers (process-wide, keyed by
    /// the spec) that reach the trigger, then heal — models a transient
    /// storage hiccup that a retry survives. The trip registry lives in
    /// this process, so transient healing is observable with the
    /// in-process transports (threads/sockets/hier); spawned worker
    /// processes start with a fresh registry and see the fault as
    /// persistent.
    Transient { fail_count: usize },
    /// Fire every time — models dead storage; retries must exhaust.
    Persistent,
}

/// Which data pass the fault lands in. Pass placement matters for the
/// resilience suites: a pass-2 fault destroys accumulated Gram state
/// after the rank already joined the pass-1 collectives — the exact
/// scenario checkpoint/resume exists for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPass {
    One,
    Two,
}

/// A deterministic fault to inject into one rank's reader: after
/// `after_chunks` chunks of the selected pass have been yielded, the
/// next read fails with a simulated I/O error (subject to `kind`'s
/// trip accounting).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    /// the rank whose reader fails
    pub rank: usize,
    /// chunks of the selected pass yielded before the fault arms
    pub after_chunks: usize,
    pub kind: FaultKind,
    pub pass: FaultPass,
}

type FaultKey = (usize, usize, usize, u8);

fn fault_key(spec: &FaultSpec) -> FaultKey {
    let fc = match spec.kind {
        FaultKind::Transient { fail_count } => fail_count,
        FaultKind::Persistent => usize::MAX,
    };
    (spec.rank, spec.after_chunks, fc, matches!(spec.pass, FaultPass::Two) as u8)
}

fn fault_trip_registry() -> &'static std::sync::Mutex<std::collections::BTreeMap<FaultKey, usize>> {
    static TRIPS: std::sync::OnceLock<
        std::sync::Mutex<std::collections::BTreeMap<FaultKey, usize>>,
    > = std::sync::OnceLock::new();
    TRIPS.get_or_init(|| std::sync::Mutex::new(std::collections::BTreeMap::new()))
}

/// How many times `spec` has fired in this process (transient trip
/// accounting; persistent faults don't register).
pub fn fault_trips(spec: &FaultSpec) -> usize {
    fault_trip_registry().lock().unwrap().get(&fault_key(spec)).copied().unwrap_or(0)
}

/// Forget `spec`'s trip count — tests that reuse a spec call this
/// first so earlier runs in the same process don't pre-heal the fault.
pub fn clear_fault_trips(spec: &FaultSpec) {
    fault_trip_registry().lock().unwrap().remove(&fault_key(spec));
}

/// Deterministic fault injection for the error-propagation and
/// resilience suites: delegates to `inner`, but
/// [`BlockReader::next_chunk`] fails with a simulated I/O error once
/// `after_chunks` chunks of the spec's pass have been yielded.
///
/// Passes are counted by [`BlockReader::reset`] calls (the pipeline
/// resets exactly once, between pass 1 and pass 2), so a
/// [`FaultPass::Two`] fault lands **after** the rank has already
/// participated in the pass-1 collectives — the "sibling ranks park at
/// the next collective" hang the abort broadcast exists to prevent,
/// and the state loss checkpoint/resume exists to repair.
pub struct FaultyBlockReader {
    inner: Box<dyn BlockReader>,
    spec: FaultSpec,
    yielded_in_pass: usize,
    resets: usize,
}

impl FaultyBlockReader {
    pub fn new(inner: Box<dyn BlockReader>, spec: FaultSpec) -> FaultyBlockReader {
        FaultyBlockReader { inner, spec, yielded_in_pass: 0, resets: 0 }
    }

    fn in_fault_pass(&self) -> bool {
        match self.spec.pass {
            FaultPass::One => self.resets == 0,
            FaultPass::Two => self.resets >= 1,
        }
    }

    /// Trip accounting at the trigger point: persistent faults always
    /// fire; transient ones fire only while the process-wide trip count
    /// for this spec is below `fail_count`.
    fn should_fire(&self) -> bool {
        match self.spec.kind {
            FaultKind::Persistent => true,
            FaultKind::Transient { fail_count } => {
                let mut reg = fault_trip_registry().lock().unwrap();
                let trips = reg.entry(fault_key(&self.spec)).or_insert(0);
                if *trips < fail_count {
                    *trips += 1;
                    true
                } else {
                    false
                }
            }
        }
    }
}

impl BlockReader for FaultyBlockReader {
    fn local_rows(&self) -> usize {
        self.inner.local_rows()
    }

    fn nt(&self) -> usize {
        self.inner.nt()
    }

    fn next_chunk(&mut self) -> Result<Option<Chunk>> {
        if self.in_fault_pass() && self.yielded_in_pass >= self.spec.after_chunks && self.should_fire()
        {
            anyhow::bail!(
                "injected read fault after {} chunks (simulated EIO)",
                self.yielded_in_pass
            );
        }
        let chunk = self.inner.next_chunk()?;
        if chunk.is_some() {
            self.yielded_in_pass += 1;
        }
        Ok(chunk)
    }

    fn reset(&mut self) -> Result<()> {
        self.resets += 1;
        self.yielded_in_pass = 0;
        self.inner.reset()
    }

    fn seek_row(&mut self, row: usize) -> Result<()> {
        // resume skips chunks without yielding them; the in-pass count
        // deliberately stays at the post-reset value, so a healed
        // transient fault's accounting is irrelevant and a persistent
        // fault still fires `after_chunks` yields later
        self.inner.seek_row(row)
    }
}

/// Drain a whole pass into one stacked matrix (tests/benches; defeats
/// the memory bound on purpose).
pub fn read_all_chunks(reader: &mut dyn BlockReader) -> Result<Matrix> {
    let mut out = Matrix::zeros(reader.local_rows(), reader.nt());
    let mut filled = 0;
    while let Some(chunk) = reader.next_chunk()? {
        anyhow::ensure!(chunk.start_row == filled, "chunks arrived out of order");
        for i in 0..chunk.data.rows() {
            out.row_mut(filled + i).copy_from_slice(chunk.data.row(i));
        }
        filled += chunk.data.rows();
    }
    anyhow::ensure!(filled == reader.local_rows(), "short pass: {filled} rows");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::snapd::SnapWriter;
    use crate::sim::synth::generate;
    use crate::util::json::Json;
    use std::path::PathBuf;

    fn mem_reader(chunk_rows: usize) -> Box<dyn BlockReader> {
        let q = Arc::new(Matrix::randn(2 * 6, 5, 3));
        Box::new(InMemoryBlockReader::new(q, RowRange { start: 0, end: 6 }, 6, 2, chunk_rows).unwrap())
    }

    #[test]
    fn faulty_reader_fails_in_the_configured_pass() {
        // 12 local rows / 4 = 3 chunks per pass; pass Two, after 1 chunk
        // ⇒ the first pass completes, the second fails on its 2nd call
        let spec = FaultSpec {
            rank: 0,
            after_chunks: 1,
            kind: FaultKind::Persistent,
            pass: FaultPass::Two,
        };
        let mut r = FaultyBlockReader::new(mem_reader(4), spec);
        for _ in 0..3 {
            assert!(r.next_chunk().unwrap().is_some());
        }
        assert!(r.next_chunk().unwrap().is_none(), "pass 1 unaffected");
        r.reset().unwrap();
        assert!(r.next_chunk().unwrap().is_some(), "2nd-pass chunk 1 still yields");
        let e = r.next_chunk().unwrap_err();
        assert!(format!("{e}").contains("injected read fault"), "{e}");

        // pass One placement fires before the reset ever happens
        let spec1 = FaultSpec { pass: FaultPass::One, ..spec };
        let mut r = FaultyBlockReader::new(mem_reader(4), spec1);
        assert!(r.next_chunk().unwrap().is_some());
        assert!(r.next_chunk().is_err(), "pass-1 fault must fire mid-pass-1");
    }

    #[test]
    fn transient_fault_heals_after_its_trip_budget() {
        let spec = FaultSpec {
            rank: 3,
            after_chunks: 2,
            kind: FaultKind::Transient { fail_count: 1 },
            pass: FaultPass::One,
        };
        clear_fault_trips(&spec);
        let mut r = FaultyBlockReader::new(mem_reader(4), spec);
        assert!(r.next_chunk().unwrap().is_some());
        assert!(r.next_chunk().unwrap().is_some());
        assert!(r.next_chunk().is_err(), "first run must trip");
        assert_eq!(fault_trips(&spec), 1);
        // a fresh reader over the same spec — the retry — sails through
        let mut r = FaultyBlockReader::new(mem_reader(4), spec);
        let block = read_all_chunks(&mut r).unwrap();
        assert_eq!(block.rows(), 12, "healed fault must not fire again");
        assert_eq!(fault_trips(&spec), 1, "healed fault never re-registers");
        clear_fault_trips(&spec);
    }

    #[test]
    fn seek_row_resumes_the_identical_chunk_tail() {
        let q = Arc::new(Matrix::randn(2 * 6, 5, 3));
        let mk = || {
            InMemoryBlockReader::new(q.clone(), RowRange { start: 0, end: 6 }, 6, 2, 5).unwrap()
        };
        let mut full = mk();
        let mut chunks = Vec::new();
        while let Some(c) = full.next_chunk().unwrap() {
            chunks.push(c);
        }
        // seek to the second chunk boundary; the tail must replay exactly
        let mut r = mk();
        r.seek_row(chunks[0].data.rows()).unwrap();
        for want in &chunks[1..] {
            let got = r.next_chunk().unwrap().unwrap();
            assert_eq!(got.start_row, want.start_row);
            assert_eq!(got.data.data(), want.data.data(), "seeked tail chunk differs");
        }
        assert!(r.next_chunk().unwrap().is_none());
        assert!(mk().seek_row(13).is_err(), "seek past end must fail");
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("dopinf_reader_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample_file(name: &str, nx: usize, nt: usize) -> (PathBuf, Matrix, Matrix) {
        let path = tmp(name);
        let ux = Matrix::randn(nx, nt, 11);
        let uy = Matrix::randn(nx, nt, 12);
        let mut w =
            SnapWriter::create(&path, &[("u_x", nx, nt), ("u_y", nx, nt)], Json::Null).unwrap();
        w.write_variable("u_x", &ux).unwrap();
        w.write_variable("u_y", &uy).unwrap();
        w.finish().unwrap();
        (path, ux, uy)
    }

    #[test]
    fn snapd_chunks_reassemble_across_variable_boundary() {
        let (path, ux, uy) = sample_file("reassemble.snapd", 23, 6);
        let range = RowRange { start: 4, end: 17 };
        let vars = vec!["u_x".to_string(), "u_y".to_string()];
        // per = 13 local rows per var; chunk of 7 straddles the boundary
        for chunk_rows in [1, 7, 13, 26, 100] {
            let mut r =
                SnapdBlockReader::open(&path, &vars, range, chunk_rows, None).unwrap();
            assert_eq!(r.local_rows(), 26);
            assert_eq!(r.nt(), 6);
            let block = read_all_chunks(&mut r).unwrap();
            let want = ux.slice_rows(4, 17).vstack(&uy.slice_rows(4, 17));
            assert_eq!(block, want, "chunk_rows={chunk_rows}");
        }
    }

    #[test]
    fn snapd_reset_replays_identically() {
        let (path, _, _) = sample_file("reset.snapd", 15, 5);
        let vars = vec!["u_x".to_string(), "u_y".to_string()];
        let mut r = SnapdBlockReader::open(
            &path,
            &vars,
            RowRange { start: 0, end: 15 },
            4,
            None,
        )
        .unwrap();
        let first = read_all_chunks(&mut r).unwrap();
        r.reset().unwrap();
        let second = read_all_chunks(&mut r).unwrap();
        assert_eq!(first.data(), second.data());
    }

    #[test]
    fn snapd_byte_accounting_covers_the_block() {
        let (path, _, _) = sample_file("bytes.snapd", 20, 7);
        let vars = vec!["u_x".to_string(), "u_y".to_string()];
        let range = RowRange { start: 3, end: 18 };
        let mut r = SnapdBlockReader::open(&path, &vars, range, 6, None).unwrap();
        let (mut bytes, mut reads, mut chunks) = (0, 0, 0);
        while let Some(c) = r.next_chunk().unwrap() {
            assert!(c.data.rows() <= 6);
            bytes += c.bytes;
            reads += c.reads;
            chunks += 1;
        }
        assert_eq!(bytes, 2 * 15 * 7 * 8, "every block byte read exactly once");
        assert!(reads >= chunks, "each chunk issues at least one read");
    }

    #[test]
    fn snapd_nt_train_truncates_columns_but_counts_file_bytes() {
        let (path, ux, _) = sample_file("truncate.snapd", 10, 8);
        let vars = vec!["u_x".to_string(), "u_y".to_string()];
        let range = RowRange { start: 0, end: 10 };
        let mut r = SnapdBlockReader::open(&path, &vars, range, 4, Some(5)).unwrap();
        assert_eq!(r.nt(), 5);
        let mut bytes = 0;
        let mut first_chunk: Option<Chunk> = None;
        while let Some(c) = r.next_chunk().unwrap() {
            assert_eq!(c.data.cols(), 5);
            bytes += c.bytes;
            if first_chunk.is_none() {
                first_chunk = Some(c);
            }
        }
        // the truncated matrix matches a column slice of the stored one
        let c0 = first_chunk.unwrap();
        assert_eq!(c0.data, ux.slice_rows(0, 4).slice_cols(0, 5));
        // bytes model the full-row reads the storage actually serves
        assert_eq!(bytes, 2 * 10 * 8 * 8);
    }

    #[test]
    fn snapd_rejects_bad_ranges_and_vars() {
        let (path, _, _) = sample_file("badopen.snapd", 8, 3);
        let vars = vec!["u_x".to_string(), "nope".to_string()];
        assert!(SnapdBlockReader::open(&path, &vars, RowRange { start: 0, end: 8 }, 2, None)
            .is_err());
        let vars = vec!["u_x".to_string()];
        assert!(SnapdBlockReader::open(&path, &vars, RowRange { start: 0, end: 9 }, 2, None)
            .is_err());
        assert!(SnapdBlockReader::open(&path, &vars, RowRange { start: 0, end: 8 }, 2, Some(4))
            .is_err());
        assert!(SnapdBlockReader::open(&path, &vars, RowRange { start: 0, end: 8 }, 0, None)
            .is_err());
    }

    #[test]
    fn in_memory_matches_snapd_reader() {
        let (path, ux, uy) = sample_file("cross.snapd", 19, 4);
        let stacked = Arc::new(ux.vstack(&uy));
        let range = RowRange { start: 2, end: 19 };
        let vars = vec!["u_x".to_string(), "u_y".to_string()];
        let mut file_r = SnapdBlockReader::open(&path, &vars, range, 5, None).unwrap();
        let mut mem_r = InMemoryBlockReader::new(stacked, range, 19, 2, 5).unwrap();
        let a = read_all_chunks(&mut file_r).unwrap();
        let b = read_all_chunks(&mut mem_r).unwrap();
        assert_eq!(a.data(), b.data());
    }

    #[test]
    fn synthetic_matches_generate() {
        let spec = SynthSpec { nx: 37, ns: 2, nt: 9, modes: 3, ..Default::default() };
        let full = generate(&spec, 0);
        let range = RowRange { start: 5, end: 30 };
        let mut r = SyntheticBlockReader::new(&spec, range, 6).unwrap();
        let block = read_all_chunks(&mut r).unwrap();
        let want = full
            .slice_rows(5, 30)
            .vstack(&full.slice_rows(37 + 5, 37 + 30));
        assert_eq!(block.data(), want.data(), "generated rows must be bitwise generate()");
    }
}

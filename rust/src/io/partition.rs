//! Row-partitioning strategies for distributing the snapshot matrix.
//!
//! The splitting scheme decomposes the spatial domain into p
//! non-overlapping subdomains (paper Sec. III.B): each rank holds *all*
//! state variables over its row range, which is what lets Step II center
//! variables without communication (Remark 3).

/// A rank's row range `[start, end)` with `len = end - start`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowRange {
    pub start: usize,
    pub end: usize,
}

impl RowRange {
    pub fn len(&self) -> usize {
        self.end - self.start
    }
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// The tutorial's `distribute_nx` (paper lines 29–51): equal blocks of
/// `floor(n/p)` with the entire remainder appended to the last rank.
pub fn distribute_tutorial(n: usize, p: usize) -> Vec<RowRange> {
    assert!(p >= 1);
    let equal = n / p;
    (0..p)
        .map(|rank| {
            let start = rank * equal;
            let mut end = (rank + 1) * equal;
            if rank == p - 1 {
                end = n;
            }
            RowRange { start, end }
        })
        .collect()
}

/// Balanced variant: sizes differ by at most one row (the "further
/// distribute the remaining rows" strategy the paper describes in
/// Sec. III.B.1). Preferred default — the tutorial split can leave the
/// last rank with up to p-1 extra rows.
pub fn distribute_balanced(n: usize, p: usize) -> Vec<RowRange> {
    assert!(p >= 1);
    let base = n / p;
    let extra = n % p;
    let mut start = 0;
    (0..p)
        .map(|rank| {
            let len = base + usize::from(rank < extra);
            let r = RowRange { start, end: start + len };
            start += len;
            r
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::quick;
    use crate::util::rng::Rng;

    fn covers_exactly(ranges: &[RowRange], n: usize) -> Result<(), String> {
        let mut pos = 0;
        for r in ranges {
            if r.start != pos {
                return Err(format!("gap/overlap at {pos}: {r:?}"));
            }
            pos = r.end;
        }
        if pos == n {
            Ok(())
        } else {
            Err(format!("covers {pos}, want {n}"))
        }
    }

    #[test]
    fn tutorial_matches_paper_example() {
        // nx=146339 over p=4 — last rank absorbs the remainder
        let ranges = distribute_tutorial(146_339, 4);
        assert_eq!(ranges[0], RowRange { start: 0, end: 36_584 });
        assert_eq!(ranges[3], RowRange { start: 109_752, end: 146_339 });
        covers_exactly(&ranges, 146_339).unwrap();
    }

    #[test]
    fn tutorial_partition_property() {
        quick(
            |rng: &mut Rng| {
                let n = rng.below(10_000) as usize;
                let p = 1 + rng.below(64) as usize;
                (n, p)
            },
            |&(n, p)| covers_exactly(&distribute_tutorial(n, p), n),
        );
    }

    #[test]
    fn balanced_partition_property() {
        quick(
            |rng: &mut Rng| {
                let n = rng.below(10_000) as usize;
                let p = 1 + rng.below(64) as usize;
                (n, p)
            },
            |&(n, p)| {
                let ranges = distribute_balanced(n, p);
                covers_exactly(&ranges, n)?;
                let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
                let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                if mx - mn <= 1 {
                    Ok(())
                } else {
                    Err(format!("imbalance {} vs {}", mn, mx))
                }
            },
        );
    }

    #[test]
    fn single_rank_gets_everything() {
        assert_eq!(distribute_tutorial(100, 1), vec![RowRange { start: 0, end: 100 }]);
        assert_eq!(distribute_balanced(100, 1), vec![RowRange { start: 0, end: 100 }]);
    }

    #[test]
    fn more_ranks_than_rows() {
        let ranges = distribute_balanced(3, 5);
        covers_exactly(&ranges, 3).unwrap();
        assert_eq!(ranges.iter().filter(|r| !r.is_empty()).count(), 3);
    }
}

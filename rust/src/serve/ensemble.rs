//! Ensemble construction + streaming statistics — the UQ workloads the
//! paper builds ROMs *for* ("design space exploration, risk assessment,
//! and uncertainty quantification").
//!
//! Two ensemble families:
//!
//! * **Perturbed initial conditions** — B copies of the artifact's
//!   reference q̂₀ with Gaussian perturbations of relative magnitude σ
//!   (member 0 stays unperturbed, so the deterministic prediction is
//!   always a member). Deterministic per seed.
//! * **Regularization-pair ensembles** — one ROM per (β₁, β₂) candidate
//!   re-solved from a shared [`OpInfProblem`] (McQuarrie et al. 2020:
//!   the reg sweep *is* an ensemble of plausible models).
//!
//! Statistics are accumulated *streaming*, one step at a time, straight
//! off the batched rollout: per probe and step we keep mean, sample
//! variance, and the (5, 50, 95)-percentiles over the members still
//! finite at that step, plus per-member NaN-divergence accounting.
//! Memory is O(probes · steps), independent of B's trajectories.

use anyhow::Result;

use crate::linalg::Matrix;
use crate::opinf::learn::OpInfProblem;
use crate::opinf::postprocess::ProbeBasis;
use crate::rom::rollout::solve_discrete;
use crate::rom::RomOperators;
use crate::runtime::Engine;
use crate::util::rng::Rng;

use super::batch::rollout_batch_with;
use super::model::RomArtifact;

/// How to build and roll an ensemble.
#[derive(Clone, Debug)]
pub struct EnsembleSpec {
    /// ensemble size B
    pub members: usize,
    /// relative std-dev of the Gaussian IC perturbation
    pub sigma: f64,
    /// RNG seed (ensembles are reproducible)
    pub seed: u64,
    /// rollout horizon per member
    pub n_steps: usize,
}

impl Default for EnsembleSpec {
    fn default() -> Self {
        EnsembleSpec { members: 256, sigma: 0.01, seed: 7, n_steps: 600 }
    }
}

/// B perturbed copies of `q0` as a `(B, r)` matrix. Member 0 is the
/// unperturbed reference; member i ≥ 1 gets `q0_j · (1 + σ ξ)` with
/// ξ ~ N(0, 1) (relative perturbation, so dominant and near-zero
/// coordinates are disturbed proportionally).
pub fn perturbed_initial_conditions(q0: &[f64], members: usize, sigma: f64, seed: u64) -> Matrix {
    let r = q0.len();
    assert!(members >= 1);
    let mut out = Matrix::zeros(members, r);
    out.row_mut(0).copy_from_slice(q0);
    let mut rng = Rng::new(seed);
    for i in 1..members {
        for (j, &v) in q0.iter().enumerate() {
            out[(i, j)] = v * (1.0 + sigma * rng.normal());
        }
    }
    out
}

/// One ROM per regularization pair, re-solved from the shared training
/// problem. Pairs whose Cholesky solve fails are skipped (returned
/// alongside, for accounting).
pub fn reg_pair_ensemble(
    problem: &OpInfProblem,
    pairs: &[(f64, f64)],
) -> (Vec<RomOperators>, Vec<(f64, f64)>) {
    let mut models = Vec::with_capacity(pairs.len());
    let mut skipped = Vec::new();
    for &(b1, b2) in pairs {
        match problem.solve(b1, b2) {
            Ok(ops) => models.push(ops),
            Err(_) => skipped.push((b1, b2)),
        }
    }
    (models, skipped)
}

/// Time series of ensemble statistics at one probe.
#[derive(Clone, Debug)]
pub struct ProbeSeries {
    pub var: usize,
    pub row: usize,
    /// ensemble mean per step (over members finite at that step)
    pub mean: Vec<f64>,
    /// sample variance per step (0 when fewer than 2 members survive)
    pub variance: Vec<f64>,
    /// 5th / 50th / 95th percentiles per step
    pub q05: Vec<f64>,
    pub q50: Vec<f64>,
    pub q95: Vec<f64>,
    /// members contributing per step (surviving and finite-valued)
    pub count: Vec<usize>,
}

impl ProbeSeries {
    /// Empty series for one probe, pre-sized for `n_steps` — the single
    /// construction path for the local and sharded reductions.
    pub fn with_capacity(probe: &ProbeBasis, n_steps: usize) -> ProbeSeries {
        ProbeSeries {
            var: probe.var,
            row: probe.row,
            mean: Vec::with_capacity(n_steps),
            variance: Vec::with_capacity(n_steps),
            q05: Vec::with_capacity(n_steps),
            q50: Vec::with_capacity(n_steps),
            q95: Vec::with_capacity(n_steps),
            count: Vec::with_capacity(n_steps),
        }
    }
}

/// Aggregated result of one ensemble evaluation.
#[derive(Clone, Debug)]
pub struct EnsembleStats {
    pub probes: Vec<ProbeSeries>,
    /// ensemble size B
    pub members: usize,
    /// steps rolled out
    pub n_steps: usize,
    /// `Some(step)` per member that went non-finite
    pub diverged_at: Vec<Option<usize>>,
}

impl EnsembleStats {
    pub fn n_diverged(&self) -> usize {
        self.diverged_at.iter().filter(|d| d.is_some()).count()
    }
}

/// Linear-interpolation percentile of a **sorted** slice (numpy
/// `percentile(..., interpolation="linear")`), q ∈ [0, 1].
fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let h = q * (n - 1) as f64;
    let lo = h.floor() as usize;
    let hi = (lo + 1).min(n - 1);
    let frac = h - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// All B member values of one probe at one step: a contiguous B-wide
/// axpy over the transposed `(r, B)` state matrix, then the affine
/// un-centering. Shared by the local accumulator and the sharded
/// server so both produce bitwise-identical values.
pub(crate) fn probe_values(p: &ProbeBasis, states_t: &Matrix, out: &mut Vec<f64>) {
    let b = states_t.cols();
    debug_assert_eq!(states_t.rows(), p.phi.len());
    out.clear();
    out.resize(b, 0.0);
    for (j, &pj) in p.phi.iter().enumerate() {
        if pj == 0.0 {
            continue;
        }
        for (v, &x) in out.iter_mut().zip(states_t.row(j)) {
            *v += pj * x;
        }
    }
    for v in out.iter_mut() {
        *v = *v * p.scale + p.mean;
    }
}

/// Mean / sample-variance / percentiles of one step's member values.
/// Sorts `values` in place. Exposed to `serve::server` so sharded and
/// local evaluations reduce through the identical code path.
pub(crate) fn step_stats(values: &mut [f64]) -> (f64, f64, f64, f64, f64) {
    let n = values.len();
    assert!(n >= 1, "step_stats needs at least one surviving member");
    let mean = values.iter().sum::<f64>() / n as f64;
    let variance = if n >= 2 {
        values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    values.sort_by(f64::total_cmp);
    (
        mean,
        variance,
        percentile_sorted(values, 0.05),
        percentile_sorted(values, 0.50),
        percentile_sorted(values, 0.95),
    )
}

/// Reduce one step's surviving-member values into `series`: NaN
/// sentinels when no member survives, mean/variance/quantiles
/// otherwise. `scratch` is sorted in place. The single reduction path
/// shared by the local accumulator and the sharded server — keeping
/// their outputs bitwise identical by construction.
pub(crate) fn push_series_step(series: &mut ProbeSeries, scratch: &mut Vec<f64>) {
    if scratch.is_empty() {
        series.mean.push(f64::NAN);
        series.variance.push(f64::NAN);
        series.q05.push(f64::NAN);
        series.q50.push(f64::NAN);
        series.q95.push(f64::NAN);
        series.count.push(0);
    } else {
        let (mean, var, q05, q50, q95) = step_stats(scratch);
        series.mean.push(mean);
        series.variance.push(var);
        series.q05.push(q05);
        series.q50.push(q50);
        series.q95.push(q95);
        series.count.push(scratch.len());
    }
}

/// Reduce fully-materialized member values into per-probe series:
/// `value_at(probe, step, member)` supplies the value, members flagged
/// in `diverged_at` at or before a step are excluded there, and
/// non-finite values are filtered exactly like the streaming
/// accumulator. The single batch-reduction path shared by the sharded
/// server and the reg-pair ensemble — divergence/finiteness semantics
/// live here once.
pub(crate) fn reduce_member_series(
    probes: &[ProbeBasis],
    n_steps: usize,
    members: usize,
    diverged_at: &[Option<usize>],
    value_at: impl Fn(usize, usize, usize) -> f64,
) -> Vec<ProbeSeries> {
    debug_assert_eq!(diverged_at.len(), members);
    let mut out: Vec<ProbeSeries> =
        probes.iter().map(|p| ProbeSeries::with_capacity(p, n_steps)).collect();
    let mut scratch: Vec<f64> = Vec::with_capacity(members);
    for (p, series) in out.iter_mut().enumerate() {
        for k in 0..n_steps {
            scratch.clear();
            for i in 0..members {
                let excluded = matches!(diverged_at[i], Some(at) if at <= k);
                let v = value_at(p, k, i);
                // same value-finiteness filter as EnsembleAccumulator
                if !excluded && v.is_finite() {
                    scratch.push(v);
                }
            }
            push_series_step(series, &mut scratch);
        }
    }
    out
}

/// Streaming per-probe statistics accumulator fed one transposed
/// `(r, B)` state batch per step.
pub struct EnsembleAccumulator {
    probes: Vec<ProbeBasis>,
    series: Vec<ProbeSeries>,
    /// scratch: all member probe values at the current step
    vals: Vec<f64>,
    /// scratch: surviving members' values (what step_stats reduces)
    scratch: Vec<f64>,
}

impl EnsembleAccumulator {
    pub fn new(probes: &[ProbeBasis], n_steps: usize) -> EnsembleAccumulator {
        let series = probes.iter().map(|p| ProbeSeries::with_capacity(p, n_steps)).collect();
        EnsembleAccumulator {
            probes: probes.to_vec(),
            series,
            vals: Vec::new(),
            scratch: Vec::new(),
        }
    }

    /// Fold in one step: `states_t` is the transposed `(r, B)`
    /// member-state matrix (as the batched rollout streams it),
    /// `diverged_at` the batch's divergence record (members flagged at
    /// or before this step are excluded).
    pub fn push_step(&mut self, step: usize, states_t: &Matrix, diverged_at: &[Option<usize>]) {
        let b = states_t.cols();
        debug_assert_eq!(diverged_at.len(), b);
        for (p, series) in self.probes.iter().zip(&mut self.series) {
            probe_values(p, states_t, &mut self.vals);
            self.scratch.clear();
            for (i, &v) in self.vals.iter().enumerate() {
                let excluded = matches!(diverged_at[i], Some(at) if at <= step);
                // a member's last *state* can still be finite while its
                // probe dot product overflows (mixed-sign ±inf terms →
                // inf/NaN) — exclude by value too, or the step's
                // mean/variance would be poisoned
                if !excluded && v.is_finite() {
                    self.scratch.push(v);
                }
            }
            push_series_step(series, &mut self.scratch);
        }
    }

    pub fn finish(
        self,
        members: usize,
        n_steps: usize,
        diverged_at: Vec<Option<usize>>,
    ) -> EnsembleStats {
        EnsembleStats { probes: self.series, members, n_steps, diverged_at }
    }
}

/// Result of a regularization-pair ensemble evaluation.
#[derive(Clone, Debug)]
pub struct RegEnsemble {
    /// the shared probe statistics; "members" are reg pairs, in
    /// `pairs_used` order
    pub stats: EnsembleStats,
    /// pairs that produced a model (stats member order)
    pub pairs_used: Vec<(f64, f64)>,
    /// pairs whose regularized solve failed
    pub skipped: Vec<(f64, f64)>,
}

/// Evaluate a regularization-pair ensemble from an artifact's persisted
/// normal-equation blocks (v2 `.rom`): one ROM per solvable (β₁, β₂)
/// candidate, each rolled out from the artifact's reference initial
/// condition, reduced into the same per-probe mean/variance/quantile
/// series as the perturbed-IC path (McQuarrie et al. 2020: the reg
/// sweep *is* an ensemble of plausible models). Models whose rollout
/// goes non-finite are flagged in `diverged_at` and excluded from the
/// statistics beyond their divergence step.
pub fn run_reg_ensemble(
    artifact: &RomArtifact,
    pairs: &[(f64, f64)],
    n_steps: usize,
) -> Result<RegEnsemble> {
    anyhow::ensure!(n_steps >= 1, "ensemble needs at least one step");
    anyhow::ensure!(!pairs.is_empty(), "ensemble needs at least one regularization pair");
    let problem = artifact.reg_problem()?;
    let (models, skipped) = reg_pair_ensemble(&problem, pairs);
    anyhow::ensure!(
        !models.is_empty(),
        "no regularization pair was solvable ({} candidates)",
        pairs.len()
    );
    let pairs_used: Vec<(f64, f64)> =
        pairs.iter().copied().filter(|pair| !skipped.contains(pair)).collect();

    // roll every model, recording member-major probe values:
    // values[p][k * b + i]
    let b = models.len();
    let n_probes = artifact.probes.len();
    let mut diverged_at: Vec<Option<usize>> = Vec::with_capacity(b);
    let mut values = vec![vec![0.0; n_steps * b]; n_probes];
    for (i, ops) in models.iter().enumerate() {
        let (_, traj) = solve_discrete(ops, &artifact.qhat0, n_steps);
        let mut first_bad = None;
        for k in 0..n_steps {
            let state = traj.row(k);
            if first_bad.is_none() && state.iter().any(|x| !x.is_finite()) {
                first_bad = Some(k);
            }
            for (p, probe) in artifact.probes.iter().enumerate() {
                values[p][k * b + i] = probe.eval(state);
            }
        }
        diverged_at.push(first_bad);
    }

    // reduce through the shared per-step path — identical statistics
    // code to the perturbed-IC ensembles
    let probes_out = reduce_member_series(&artifact.probes, n_steps, b, &diverged_at, |p, k, i| {
        values[p][k * b + i]
    });

    Ok(RegEnsemble {
        stats: EnsembleStats { probes: probes_out, members: b, n_steps, diverged_at },
        pairs_used,
        skipped,
    })
}

/// Evaluate a perturbed-IC ensemble of `spec.members` members on one
/// artifact, streaming statistics per step. Single-threaded; see
/// [`super::server`] for the sharded multi-worker path.
pub fn run_ensemble(
    engine: &Engine,
    artifact: &RomArtifact,
    spec: &EnsembleSpec,
) -> Result<EnsembleStats> {
    anyhow::ensure!(spec.members >= 1, "ensemble needs at least one member");
    anyhow::ensure!(spec.n_steps >= 1, "ensemble needs at least one step");
    let q0s =
        perturbed_initial_conditions(&artifact.qhat0, spec.members, spec.sigma, spec.seed);
    let mut acc = EnsembleAccumulator::new(&artifact.probes, spec.n_steps);
    let diverged = rollout_batch_with(engine, &artifact.ops, &q0s, spec.n_steps, |k, states, d| {
        acc.push_step(k, states, d);
    });
    Ok(acc.finish(spec.members, spec.n_steps, diverged))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opinf::learn;
    use crate::rom::quadratic::s_dim;
    use crate::rom::rollout::solve_discrete;
    use std::collections::BTreeMap;

    fn artifact(r: usize) -> RomArtifact {
        let ops = RomOperators::stable_sample(r, 21);
        let probes = vec![
            ProbeBasis { var: 0, row: 4, phi: vec![1.0; r], mean: 2.0, scale: 1.5 },
            ProbeBasis {
                var: 1,
                row: 9,
                phi: (0..r).map(|j| 0.1 * (j as f64 + 1.0)).collect(),
                mean: -1.0,
                scale: 1.0,
            },
        ];
        RomArtifact {
            ops,
            qhat0: (0..r).map(|j| 0.4 - 0.05 * j as f64).collect(),
            probes,
            reg: None,
            meta: BTreeMap::new(),
        }
    }

    /// Artifact whose reg blocks come from a real assembled problem on
    /// a stable trajectory.
    fn artifact_with_reg(r: usize) -> RomArtifact {
        let mut art = artifact(r);
        let (nans, traj) = solve_discrete(&art.ops, &art.qhat0, 90);
        assert!(!nans);
        let problem = learn::assemble(&traj.transpose());
        art.reg = Some(crate::serve::model::RegBlocks::from_problem(&problem));
        art
    }

    #[test]
    fn perturbation_member_zero_is_reference() {
        let q0 = [1.0, -2.0, 0.5];
        let ics = perturbed_initial_conditions(&q0, 8, 0.1, 3);
        assert_eq!(ics.row(0), &q0);
        // deterministic per seed, differs across seeds
        let again = perturbed_initial_conditions(&q0, 8, 0.1, 3);
        assert_eq!(ics, again);
        let other = perturbed_initial_conditions(&q0, 8, 0.1, 4);
        assert!(ics.max_abs_diff(&other) > 0.0);
        // relative: zero coordinates stay zero
        let zics = perturbed_initial_conditions(&[0.0, 1.0], 5, 0.2, 1);
        for i in 0..5 {
            assert_eq!(zics[(i, 0)], 0.0);
        }
    }

    #[test]
    fn sigma_zero_collapses_the_ensemble() {
        let art = artifact(4);
        let spec = EnsembleSpec { members: 12, sigma: 0.0, seed: 1, n_steps: 30 };
        let stats = run_ensemble(&Engine::native(), &art, &spec).unwrap();
        assert_eq!(stats.n_diverged(), 0);
        for series in &stats.probes {
            // all members identical => zero variance, quantiles == mean
            for k in 0..30 {
                assert!(series.variance[k].abs() < 1e-24, "k={k}");
                assert!((series.q05[k] - series.mean[k]).abs() < 1e-12);
                assert!((series.q95[k] - series.mean[k]).abs() < 1e-12);
                assert_eq!(series.count[k], 12);
            }
        }
        // and the collapsed mean equals the deterministic probe series
        let (_, traj) = solve_discrete(&art.ops, &art.qhat0, 30);
        for k in 0..30 {
            let want = art.probes[0].eval(traj.row(k));
            assert!((stats.probes[0].mean[k] - want).abs() < 1e-10, "k={k}");
        }
    }

    #[test]
    fn variance_grows_with_sigma() {
        let art = artifact(5);
        let small = run_ensemble(
            &Engine::native(),
            &art,
            &EnsembleSpec { members: 64, sigma: 1e-4, seed: 2, n_steps: 20 },
        )
        .unwrap();
        let large = run_ensemble(
            &Engine::native(),
            &art,
            &EnsembleSpec { members: 64, sigma: 1e-1, seed: 2, n_steps: 20 },
        )
        .unwrap();
        let v_small: f64 = small.probes[0].variance.iter().sum();
        let v_large: f64 = large.probes[0].variance.iter().sum();
        assert!(v_large > 100.0 * v_small, "{v_large} vs {v_small}");
    }

    #[test]
    fn quantiles_bracket_the_median() {
        let art = artifact(3);
        let stats = run_ensemble(
            &Engine::native(),
            &art,
            &EnsembleSpec { members: 100, sigma: 0.05, seed: 5, n_steps: 15 },
        )
        .unwrap();
        for series in &stats.probes {
            for k in 0..15 {
                assert!(series.q05[k] <= series.q50[k] && series.q50[k] <= series.q95[k]);
                assert!(series.variance[k] >= 0.0);
            }
        }
    }

    #[test]
    fn percentile_matches_numpy_convention() {
        let mut v = vec![1.0, 2.0, 3.0, 4.0];
        let (_, _, q05, q50, q95) = step_stats(&mut v);
        // numpy: percentile([1,2,3,4], 50) = 2.5, 5 -> 1.15, 95 -> 3.85
        assert!((q50 - 2.5).abs() < 1e-12);
        assert!((q05 - 1.15).abs() < 1e-12);
        assert!((q95 - 3.85).abs() < 1e-12);
    }

    #[test]
    fn diverged_members_are_excluded_and_counted() {
        let r = 2;
        let mut art = artifact(r);
        art.ops.fhat[(0, 0)] = 4.0; // quadratic blow-up for big ICs
        art.qhat0 = vec![0.05, 0.05];
        // huge sigma: some members land on explosive ICs
        let spec = EnsembleSpec { members: 64, sigma: 400.0, seed: 11, n_steps: 40 };
        let stats = run_ensemble(&Engine::native(), &art, &spec).unwrap();
        assert!(stats.n_diverged() > 0, "expected some divergence");
        assert!(stats.n_diverged() < 64, "expected some survivors");
        let last = &stats.probes[0];
        let k_last = 39;
        assert_eq!(last.count[k_last], 64 - stats.n_diverged());
        assert!(last.mean[k_last].is_finite());
        assert!(last.q95[k_last].is_finite());
    }

    #[test]
    fn reg_ensemble_end_to_end_from_blocks() {
        let art = artifact_with_reg(3);
        let pairs = [(1e-8, 1e-8), (1e-5, 1e-3), (1e-2, 1e-1)];
        let ens = run_reg_ensemble(&art, &pairs, 40).unwrap();
        assert_eq!(ens.stats.members, ens.pairs_used.len());
        assert_eq!(ens.pairs_used.len() + ens.skipped.len(), 3);
        assert_eq!(ens.stats.n_steps, 40);
        assert_eq!(ens.stats.probes.len(), art.probes.len());
        for series in &ens.stats.probes {
            assert_eq!(series.mean.len(), 40);
            for k in 0..40 {
                if series.count[k] > 0 {
                    assert!(series.q05[k] <= series.q50[k] && series.q50[k] <= series.q95[k]);
                    assert!(series.variance[k] >= 0.0);
                }
            }
        }
        // every member starts from the same reference IC: step 0 is
        // degenerate — zero variance, quantiles collapsed onto the
        // generating model's probe value
        let want0 = art.probes[0].eval(&art.qhat0);
        let series = &ens.stats.probes[0];
        assert_eq!(series.count[0], ens.stats.members);
        assert!(series.variance[0].abs() < 1e-20);
        assert!((series.mean[0] - want0).abs() < 1e-9 * want0.abs().max(1.0));
        assert_eq!(series.q05[0], series.q95[0]);
    }

    #[test]
    fn reg_ensemble_survives_artifact_roundtrip() {
        let art = artifact_with_reg(3);
        let back = RomArtifact::from_bytes(&art.to_bytes()).unwrap();
        let pairs = [(1e-7, 1e-5), (1e-3, 1e-2)];
        let a = run_reg_ensemble(&art, &pairs, 25).unwrap();
        let b = run_reg_ensemble(&back, &pairs, 25).unwrap();
        // blocks round-trip bitwise, so the ensembles agree bitwise
        assert_eq!(a.pairs_used, b.pairs_used);
        for (pa, pb) in a.stats.probes.iter().zip(&b.stats.probes) {
            assert_eq!(pa.mean, pb.mean);
            assert_eq!(pa.variance, pb.variance);
            assert_eq!(pa.q05, pb.q05);
            assert_eq!(pa.q95, pb.q95);
        }
    }

    #[test]
    fn reg_ensemble_requires_blocks() {
        let art = artifact(3); // no reg blocks (v1-style)
        let err = run_reg_ensemble(&art, &[(1e-6, 1e-6)], 10).unwrap_err();
        assert!(format!("{err:#}").contains("no regularization blocks"), "{err:#}");
    }

    #[test]
    fn reg_pair_ensemble_builds_models() {
        // learn from a synthetic stable trajectory
        let ops = artifact(3).ops;
        let (nans, traj) = solve_discrete(&ops, &[0.4, 0.35, 0.3], 80);
        assert!(!nans);
        let problem = learn::assemble(&traj.transpose());
        let pairs = [(1e-8, 1e-8), (1e-4, 1e-2), (1.0, 1.0)];
        let (models, skipped) = reg_pair_ensemble(&problem, &pairs);
        assert_eq!(models.len() + skipped.len(), 3);
        assert!(!models.is_empty());
        for m in &models {
            assert_eq!(m.r, 3);
            assert_eq!(m.fhat.cols(), s_dim(3));
        }
    }
}

//! Route dispatch + the hand-rolled JSON request/response codecs.
//!
//! Every route returns a [`Response`]; the connection layer owns the
//! socket. Status mapping follows the scheduler's admission contract:
//! queue full / draining → 503 with `Retry-After`, deadline → 504,
//! evaluation failure (including a contained panic) → 500, unknown
//! model → 404, any body the codec refuses → 400 with a reason.
//!
//! `POST /v1/ensemble` accepts a flat JSON object — unknown fields are
//! rejected (a typo'd `"member"` silently running a 256-member default
//! would be worse than a 400):
//!
//! | field        | default              | range                  |
//! |--------------|----------------------|------------------------|
//! | `model`      | sole registered model| registered name        |
//! | `members`    | 256                  | `[1, max_members]`     |
//! | `steps`      | 600                  | `[1, max_steps]`       |
//! | `sigma`      | 0.01                 | finite, ≥ 0            |
//! | `seed`       | 7                    | non-negative integer   |
//! | `timeout_ms` | server default       | `[1, 86400000]`        |
//! | `coalesce`   | `true`               | boolean opt-out        |
//! | `series`     | `"full"`             | `"full"` or `"last"`   |
//!
//! Response floats ride the emitter's shortest-roundtrip `Display`, so
//! a parsed response reproduces the computed statistics bit for bit —
//! the end-to-end test leans on that to extend the coalescing contract
//! through the wire format. Non-finite values (a diverged probe's NaN
//! tail) emit as `null` instead of breaking the JSON.

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::serve::ensemble::{EnsembleSpec, EnsembleStats};
use crate::util::json::{parse, Json};

use super::protocol::{Request, Response};
use super::registry::{ModelEntry, ReloadError};
use super::scheduler::JobError;
use super::Ctx;

/// Dispatch one parsed request and account the response's status class.
pub(crate) fn handle(ctx: &Ctx, req: &Request) -> Response {
    let resp = route(ctx, req);
    ctx.metrics.note_response(resp.status);
    resp
}

fn route(ctx: &Ctx, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => healthz(ctx),
        ("GET", "/metrics") => Response::json(200, &super::metrics_document(ctx)),
        ("GET", "/v1/models") => models(ctx),
        ("POST", "/v1/ensemble") => ensemble(ctx, req),
        ("POST", "/admin/shutdown") if ctx.cfg.admin_shutdown => {
            // test-build escape hatch for SIGINT: close admission, tell
            // the acceptor to wind down, report what is still draining
            let depth = ctx.queue.depth();
            ctx.shutdown.store(true, Ordering::SeqCst);
            let mut resp = Response::json(
                200,
                &Json::obj(vec![
                    ("status", Json::Str("shutting down".into())),
                    ("draining", Json::Num(depth as f64)),
                ]),
            );
            resp.close = true;
            resp
        }
        ("POST", p) => match reload_target(p) {
            Some(name) => reload(ctx, name),
            None => method_or_not_found(ctx, req),
        },
        _ => method_or_not_found(ctx, req),
    }
}

/// `/v1/models/{name}/reload` → `{name}`; one path segment only.
fn reload_target(path: &str) -> Option<&str> {
    let name = path.strip_prefix("/v1/models/")?.strip_suffix("/reload")?;
    if name.is_empty() || name.contains('/') {
        None
    } else {
        Some(name)
    }
}

/// Known path with the wrong method → 405 + `Allow`; anything else 404.
fn method_or_not_found(ctx: &Ctx, req: &Request) -> Response {
    let allow = match req.path.as_str() {
        "/healthz" | "/metrics" | "/v1/models" => Some("GET"),
        "/v1/ensemble" => Some("POST"),
        "/admin/shutdown" if ctx.cfg.admin_shutdown => Some("POST"),
        p if reload_target(p).is_some() => Some("POST"),
        _ => None,
    };
    match allow {
        Some(methods) => {
            Response::error(405, "method not allowed").with_header("Allow", methods)
        }
        None => Response::error(404, &format!("no route for {}", req.path)),
    }
}

fn healthz(ctx: &Ctx) -> Response {
    let draining = ctx.shutdown.load(Ordering::SeqCst);
    Response::json(
        200,
        &Json::obj(vec![
            ("status", Json::Str(if draining { "draining" } else { "ok" }.into())),
            ("models", Json::Num(ctx.registry.len() as f64)),
            ("queue_depth", Json::Num(ctx.queue.depth() as f64)),
            ("uptime_s", Json::Num(ctx.started.elapsed().as_secs_f64())),
        ]),
    )
}

fn models(ctx: &Ctx) -> Response {
    let rows: Vec<Json> = ctx
        .registry
        .entries()
        .map(|e| {
            let art = e.artifact();
            Json::obj(vec![
                ("name", Json::Str(e.name().into())),
                ("r", Json::Num(art.r() as f64)),
                ("probes", Json::Num(art.probes.len() as f64)),
                ("generation", Json::Num(e.generation() as f64)),
                ("reloads", Json::Num(e.reloads() as f64)),
                ("requests", Json::Num(e.metrics().requests as f64)),
                (
                    "meta",
                    Json::Obj(
                        art.meta
                            .iter()
                            .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();
    Response::json(200, &Json::obj(vec![("models", Json::Arr(rows))]))
}

fn reload(ctx: &Ctx, name: &str) -> Response {
    match ctx.registry.reload(name) {
        Ok(rep) => Response::json(
            200,
            &Json::obj(vec![
                ("model", Json::Str(name.into())),
                ("generation", Json::Num(rep.generation as f64)),
                ("r", Json::Num(rep.r as f64)),
                ("probes", Json::Num(rep.n_probes as f64)),
            ]),
        ),
        Err(ReloadError::UnknownModel) => Response::error(404, &format!("unknown model {name:?}")),
        Err(ReloadError::NotFileBacked) => {
            Response::error(400, "model has no backing file to reload from")
        }
        Err(e @ ReloadError::Load(_)) => {
            Response::error(500, &format!("{e}; serving the previous artifact"))
        }
    }
}

struct EnsembleCall {
    entry: Arc<ModelEntry>,
    model: String,
    spec: EnsembleSpec,
    coalesce: bool,
    timeout: Option<Duration>,
    series_last: bool,
}

fn ensemble(ctx: &Ctx, req: &Request) -> Response {
    let call = match parse_ensemble(ctx, req) {
        Ok(c) => c,
        Err(resp) => return resp,
    };
    if ctx.shutdown.load(Ordering::SeqCst) {
        ctx.metrics.note_rejected();
        return Response::error(503, "server is draining").with_header("Retry-After", "1");
    }
    let deadline = call.timeout.map(|d| Instant::now() + d);
    let rx = match ctx.queue.submit(
        Arc::clone(&call.entry),
        call.spec.clone(),
        call.coalesce,
        deadline,
    ) {
        Ok(rx) => rx,
        Err(e) => {
            ctx.metrics.note_rejected();
            return Response::error(503, &e.to_string()).with_header("Retry-After", "1");
        }
    };
    // the worker refuses expired jobs itself; the recv grace keeps this
    // side from racing a reply that is already on its way
    let reply = match call.timeout {
        Some(d) => match rx.recv_timeout(d + Duration::from_millis(250)) {
            Ok(reply) => reply,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                ctx.metrics.note_deadline();
                return Response::error(504, "deadline exceeded waiting for the evaluation");
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                return Response::error(500, "evaluation worker dropped the request")
            }
        },
        None => match rx.recv() {
            Ok(reply) => reply,
            Err(_) => return Response::error(500, "evaluation worker dropped the request"),
        },
    };
    match reply {
        Ok(stats) => Response::json(200, &stats_document(&call.model, &stats, call.series_last)),
        Err(JobError::Deadline) => {
            ctx.metrics.note_deadline();
            Response::error(504, "deadline exceeded before evaluation started")
        }
        Err(JobError::Failed(msg)) => Response::error(500, &msg),
    }
}

fn parse_ensemble(ctx: &Ctx, req: &Request) -> Result<EnsembleCall, Response> {
    let text = std::str::from_utf8(&req.body)
        .map_err(|_| Response::error(400, "body is not valid UTF-8"))?;
    let doc = parse(text).map_err(|e| Response::error(400, &format!("invalid JSON body: {e}")))?;
    let obj = doc
        .as_obj()
        .ok_or_else(|| Response::error(400, "body must be a JSON object"))?;
    const KNOWN: [&str; 8] =
        ["model", "members", "sigma", "seed", "steps", "timeout_ms", "coalesce", "series"];
    for key in obj.keys() {
        if !KNOWN.contains(&key.as_str()) {
            return Err(Response::error(400, &format!("unknown field {key:?}")));
        }
    }
    let entry = match obj.get("model") {
        Some(Json::Str(name)) => ctx
            .registry
            .get(name)
            .ok_or_else(|| Response::error(404, &format!("unknown model {name:?}")))?,
        Some(_) => return Err(Response::error(400, "\"model\" must be a string")),
        None => ctx.registry.sole().ok_or_else(|| {
            Response::error(400, "several models are registered; name one via \"model\"")
        })?,
    };
    let members = field_usize(obj, "members", 256, 1, ctx.cfg.max_members)?;
    let steps = field_usize(obj, "steps", 600, 1, ctx.cfg.max_steps)?;
    let sigma = match obj.get("sigma") {
        None => 0.01,
        Some(Json::Num(v)) if v.is_finite() && *v >= 0.0 => *v,
        Some(_) => {
            return Err(Response::error(400, "\"sigma\" must be a finite non-negative number"))
        }
    };
    let seed = match obj.get("seed") {
        None => 7u64,
        Some(Json::Num(v)) if *v >= 0.0 && v.fract() == 0.0 && *v < u64::MAX as f64 => *v as u64,
        Some(_) => return Err(Response::error(400, "\"seed\" must be a non-negative integer")),
    };
    let timeout = match obj.get("timeout_ms") {
        None => ctx.cfg.request_timeout,
        Some(Json::Num(v)) if v.fract() == 0.0 && *v >= 1.0 && *v <= 86_400_000.0 => {
            Some(Duration::from_millis(*v as u64))
        }
        Some(_) => {
            return Err(Response::error(400, "\"timeout_ms\" must be an integer in [1, 86400000]"))
        }
    };
    let coalesce = match obj.get("coalesce") {
        None => true,
        Some(Json::Bool(b)) => *b,
        Some(_) => return Err(Response::error(400, "\"coalesce\" must be a boolean")),
    };
    let series_last = match obj.get("series") {
        None => false,
        Some(Json::Str(s)) if s == "full" => false,
        Some(Json::Str(s)) if s == "last" => true,
        Some(_) => return Err(Response::error(400, "\"series\" must be \"full\" or \"last\"")),
    };
    Ok(EnsembleCall {
        model: entry.name().to_string(),
        entry,
        spec: EnsembleSpec { members, sigma, seed, n_steps: steps },
        coalesce,
        timeout,
        series_last,
    })
}

fn field_usize(
    obj: &std::collections::BTreeMap<String, Json>,
    key: &str,
    default: usize,
    min: usize,
    max: usize,
) -> Result<usize, Response> {
    match obj.get(key) {
        None => Ok(default),
        Some(Json::Num(v)) if v.fract() == 0.0 && *v >= min as f64 && *v <= max as f64 => {
            Ok(*v as usize)
        }
        Some(_) => {
            Err(Response::error(400, &format!("{key:?} must be an integer in [{min}, {max}]")))
        }
    }
}

/// NaN/inf would emit as invalid JSON; diverged tails become `null`.
fn finite(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

fn series(values: &[f64], last: bool) -> Json {
    if last {
        values.last().copied().map_or(Json::Null, finite)
    } else {
        Json::Arr(values.iter().map(|&v| finite(v)).collect())
    }
}

fn stats_document(model: &str, stats: &EnsembleStats, last: bool) -> Json {
    let probes: Vec<Json> = stats
        .probes
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("var", Json::Num(p.var as f64)),
                ("row", Json::Num(p.row as f64)),
                ("mean", series(&p.mean, last)),
                ("variance", series(&p.variance, last)),
                ("q05", series(&p.q05, last)),
                ("q50", series(&p.q50, last)),
                ("q95", series(&p.q95, last)),
                (
                    "count",
                    if last {
                        p.count.last().map_or(Json::Null, |&c| Json::Num(c as f64))
                    } else {
                        Json::Arr(p.count.iter().map(|&c| Json::Num(c as f64)).collect())
                    },
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("model", Json::Str(model.into())),
        ("members", Json::Num(stats.members as f64)),
        ("steps", Json::Num(stats.n_steps as f64)),
        ("diverged", Json::Num(stats.n_diverged() as f64)),
        ("series", Json::Str(if last { "last" } else { "full" }.into())),
        ("probes", Json::Arr(probes)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reload_targets_are_single_segments() {
        assert_eq!(reload_target("/v1/models/heat2d/reload"), Some("heat2d"));
        assert_eq!(reload_target("/v1/models//reload"), None);
        assert_eq!(reload_target("/v1/models/a/b/reload"), None);
        assert_eq!(reload_target("/v1/models/a/relod"), None);
        assert_eq!(reload_target("/v1/ensemble"), None);
    }

    #[test]
    fn series_modes_and_nonfinite_guard() {
        let vals = [1.5, f64::NAN, 2.5];
        match series(&vals, false) {
            Json::Arr(a) => {
                assert_eq!(a[0], Json::Num(1.5));
                assert_eq!(a[1], Json::Null);
                assert_eq!(a[2], Json::Num(2.5));
            }
            other => panic!("expected array, got {other:?}"),
        }
        assert_eq!(series(&vals, true), Json::Num(2.5));
        assert_eq!(series(&[f64::INFINITY], true), Json::Null);
        assert_eq!(series(&[], true), Json::Null);
    }
}

//! Cross-request coalescing: many small ensemble requests against the
//! same artifact merged into **one** batched rollout, then
//! de-interleaved back into per-request statistics.
//!
//! This is the serving analogue of the batched-rollout win: the per-step
//! cost of the `(r, r+s+1) @ (r+s+1, B)` product is dominated by fixed
//! per-step work (augmented-state build, dispatch, probe pass) at small
//! B, so eight B=1 requests cost nearly eight full rollouts served
//! alone but barely more than one when fused into a B=8 batch.
//!
//! ## Results contract: coalescing is invisible
//!
//! The per-request [`EnsembleStats`] returned here are **bitwise
//! identical** to serving each request alone through [`run_ensemble`].
//! The argument is member-column independence, the same invariant the
//! compute plane's T-invariance rests on:
//!
//! * each request's perturbed ICs are built by its own
//!   [`perturbed_initial_conditions`] call (same seed, same σ, same B),
//!   then placed in a *contiguous* column segment of the merged batch;
//! * every per-step kernel is per-column arithmetic: the GEMM
//!   accumulates each output element over the shared dimension in an
//!   order independent of B, the quadratic expansion is elementwise per
//!   column, and divergence scan/freeze are member-local;
//! * the visitor copies each segment's columns into a `(r, B_i)` slab
//!   in segment order — the same values, in the same layout, as the
//!   solo rollout streams — and feeds the request's own
//!   [`EnsembleAccumulator`], so the statistics reduction is the
//!   identical code path on identical floats.
//!
//! The sweep in `tests/integration_http.rs` (N ∈ {1, 3, 8} requests ×
//! B ∈ {1, 64} members) asserts the equality bit for bit.

use anyhow::Result;

use crate::linalg::Matrix;
use crate::runtime::Engine;
use crate::serve::batch::rollout_batch_with;
use crate::serve::ensemble::{
    perturbed_initial_conditions, run_ensemble, EnsembleAccumulator, EnsembleSpec, EnsembleStats,
};
use crate::serve::model::RomArtifact;

/// Evaluate `specs` as one fused rollout on `artifact`. All specs must
/// share `n_steps` (the scheduler only coalesces compatible requests);
/// `members`/`sigma`/`seed` may differ freely. Returns one
/// [`EnsembleStats`] per spec, in order, each bitwise identical to a
/// solo [`run_ensemble`] of that spec.
pub fn run_coalesced(
    engine: &Engine,
    artifact: &RomArtifact,
    specs: &[EnsembleSpec],
) -> Result<Vec<EnsembleStats>> {
    anyhow::ensure!(!specs.is_empty(), "coalesced batch needs at least one request");
    let n_steps = specs[0].n_steps;
    anyhow::ensure!(
        specs.iter().all(|s| s.n_steps == n_steps),
        "coalesced requests must share n_steps"
    );
    anyhow::ensure!(n_steps >= 1, "ensemble needs at least one step");
    anyhow::ensure!(
        specs.iter().all(|s| s.members >= 1),
        "ensemble needs at least one member"
    );
    if specs.len() == 1 {
        // nothing to fuse — take the solo path outright
        return Ok(vec![run_ensemble(engine, artifact, &specs[0])?]);
    }

    let r = artifact.r();
    let total: usize = specs.iter().map(|s| s.members).sum();

    // each request's ICs, built exactly as its solo run would, stacked
    // into contiguous member-row segments of one (total, r) batch
    let mut q0s = Matrix::zeros(total, r);
    let mut segments = Vec::with_capacity(specs.len());
    let mut start = 0;
    for spec in specs {
        let ics =
            perturbed_initial_conditions(&artifact.qhat0, spec.members, spec.sigma, spec.seed);
        for i in 0..spec.members {
            q0s.row_mut(start + i).copy_from_slice(ics.row(i));
        }
        segments.push(start..start + spec.members);
        start += spec.members;
    }

    let mut accs: Vec<EnsembleAccumulator> =
        specs.iter().map(|_| EnsembleAccumulator::new(&artifact.probes, n_steps)).collect();
    // per-request (r, B_i) slabs the merged step states are
    // de-interleaved into before hitting each accumulator
    let mut slabs: Vec<Matrix> = segments.iter().map(|seg| Matrix::zeros(r, seg.len())).collect();

    let diverged = rollout_batch_with(engine, &artifact.ops, &q0s, n_steps, |k, states_t, div| {
        for ((seg, acc), slab) in segments.iter().zip(accs.iter_mut()).zip(slabs.iter_mut()) {
            for j in 0..r {
                slab.row_mut(j).copy_from_slice(&states_t.row(j)[seg.start..seg.end]);
            }
            acc.push_step(k, slab, &div[seg.start..seg.end]);
        }
    });

    Ok(segments
        .iter()
        .zip(accs)
        .zip(specs)
        .map(|((seg, acc), spec)| {
            acc.finish(spec.members, n_steps, diverged[seg.clone()].to_vec())
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opinf::postprocess::ProbeBasis;
    use crate::rom::RomOperators;
    use std::collections::BTreeMap;

    fn artifact(r: usize) -> RomArtifact {
        let probes = vec![
            ProbeBasis { var: 0, row: 3, phi: vec![1.0; r], mean: 0.5, scale: 2.0 },
            ProbeBasis {
                var: 1,
                row: 7,
                phi: (0..r).map(|j| 0.2 * (j as f64 - 1.0)).collect(),
                mean: -0.25,
                scale: 1.0,
            },
        ];
        RomArtifact {
            ops: RomOperators::stable_sample(r, 21),
            qhat0: (0..r).map(|j| 0.4 - 0.05 * j as f64).collect(),
            probes,
            reg: None,
            meta: BTreeMap::new(),
        }
    }

    fn assert_stats_bitwise(a: &EnsembleStats, b: &EnsembleStats) {
        assert_eq!(a.members, b.members);
        assert_eq!(a.n_steps, b.n_steps);
        assert_eq!(a.diverged_at, b.diverged_at);
        assert_eq!(a.probes.len(), b.probes.len());
        for (pa, pb) in a.probes.iter().zip(&b.probes) {
            assert_eq!((pa.var, pa.row), (pb.var, pb.row));
            assert_eq!(pa.mean, pb.mean, "mean differs at var{} row{}", pa.var, pa.row);
            assert_eq!(pa.variance, pb.variance);
            assert_eq!(pa.q05, pb.q05);
            assert_eq!(pa.q50, pb.q50);
            assert_eq!(pa.q95, pb.q95);
            assert_eq!(pa.count, pb.count);
        }
    }

    #[test]
    fn two_fused_requests_match_solo_bitwise() {
        // swept across both lane-order tiers: coalescing fuses requests
        // as extra member *columns*, and lanes run along columns, so
        // column independence is exactly what SIMD must not break
        let engine = Engine::native();
        let art = artifact(5);
        let specs = vec![
            EnsembleSpec { members: 3, sigma: 0.02, seed: 11, n_steps: 40 },
            EnsembleSpec { members: 5, sigma: 0.05, seed: 99, n_steps: 40 },
        ];
        for tier in [crate::linalg::SimdTier::Native, crate::linalg::SimdTier::Scalar] {
            crate::linalg::simd::set_tier(tier);
            let fused = run_coalesced(&engine, &art, &specs).unwrap();
            assert_eq!(fused.len(), 2);
            for (spec, got) in specs.iter().zip(&fused) {
                let solo = run_ensemble(&engine, &art, spec).unwrap();
                assert_stats_bitwise(got, &solo);
            }
        }
        crate::linalg::simd::set_tier(crate::linalg::SimdTier::Native);
    }

    #[test]
    fn single_request_degenerates_to_the_solo_path() {
        let engine = Engine::native();
        let art = artifact(4);
        let spec = EnsembleSpec { members: 6, sigma: 0.01, seed: 3, n_steps: 25 };
        let fused = run_coalesced(&engine, &art, std::slice::from_ref(&spec)).unwrap();
        let solo = run_ensemble(&engine, &art, &spec).unwrap();
        assert_stats_bitwise(&fused[0], &solo);
    }

    #[test]
    fn divergence_stays_request_local() {
        let engine = Engine::native();
        let mut art = artifact(2);
        art.ops.fhat[(0, 0)] = 4.0; // quadratic blow-up for big ICs
        art.qhat0 = vec![0.05, 0.05];
        // request 0 is tame, request 1 explodes some members
        let specs = vec![
            EnsembleSpec { members: 4, sigma: 0.01, seed: 1, n_steps: 40 },
            EnsembleSpec { members: 32, sigma: 400.0, seed: 11, n_steps: 40 },
        ];
        for tier in [crate::linalg::SimdTier::Native, crate::linalg::SimdTier::Scalar] {
            crate::linalg::simd::set_tier(tier);
            let fused = run_coalesced(&engine, &art, &specs).unwrap();
            assert_eq!(fused[0].n_diverged(), 0);
            assert!(fused[1].n_diverged() > 0);
            for (spec, got) in specs.iter().zip(&fused) {
                let solo = run_ensemble(&engine, &art, spec).unwrap();
                assert_stats_bitwise(got, &solo);
            }
        }
        crate::linalg::simd::set_tier(crate::linalg::SimdTier::Native);
    }

    #[test]
    fn mismatched_horizons_are_refused() {
        let engine = Engine::native();
        let art = artifact(3);
        let specs = vec![
            EnsembleSpec { members: 2, sigma: 0.01, seed: 1, n_steps: 10 },
            EnsembleSpec { members: 2, sigma: 0.01, seed: 2, n_steps: 20 },
        ];
        assert!(run_coalesced(&engine, &art, &specs).is_err());
        assert!(run_coalesced(&engine, &art, &[]).is_err());
    }
}

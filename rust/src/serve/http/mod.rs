//! Production HTTP/1.1 serving tier over the ensemble queue —
//! zero-dependency (std `TcpListener` + bounded thread pool, no async
//! runtime), keeping the vendored-offline build constraint.
//!
//! ## Endpoints
//!
//! | method · path                    | purpose                                    |
//! |----------------------------------|--------------------------------------------|
//! | `POST /v1/ensemble`              | run an ensemble; JSON body, see [`api`]    |
//! | `GET /v1/models`                 | registered models + provenance             |
//! | `POST /v1/models/{name}/reload`  | checksum-validated hot-reload, atomic swap |
//! | `GET /healthz`                   | liveness: `ok` serving / `draining`        |
//! | `GET /metrics`                   | tier + queue + per-model metrics JSON      |
//! | `POST /admin/shutdown`           | test builds only ([`HttpConfig::admin_shutdown`]) |
//!
//! ## Layers
//!
//! * [`protocol`] — hardened parser + response emission: every read is
//!   bounded before it happens; malformed input → 400/411/413/501,
//!   never a panic.
//! * [`coalesce`] — merges small concurrent same-model requests into
//!   one batched rollout; results **bitwise identical** to solo serving.
//! * [`scheduler`] — admission: bounded queue (503 + `Retry-After`),
//!   per-request deadlines (504), large-B splitting over rank workers.
//! * [`registry`] — multi-model map with hot-reload; in-flight requests
//!   finish on the artifact they were admitted against.
//!
//! The connection model is thread-per-connection with a hard cap
//! ([`HttpConfig::max_connections`]): beyond it the acceptor answers
//! 503 and closes rather than queueing unbounded sockets. Keep-alive
//! connections park in a short poll loop so shutdown is never blocked
//! behind an idle client.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use crate::obs::Histogram;
use crate::util::json::{emit, Json};

pub mod api;
pub mod coalesce;
pub mod protocol;
pub mod registry;
pub mod scheduler;

pub use protocol::Limits;
pub use registry::{ModelEntry, ModelRegistry, ReloadError, ReloadReport};
pub use scheduler::{EnsembleQueue, JobError, QueueConfig, SubmitError};

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// HTTP-tier counters, shared across the acceptor, connection handlers,
/// and scheduler workers. Everything is monotonic; `/metrics` snapshots
/// are therefore safe to diff across scrapes.
pub struct TierMetrics {
    connections: AtomicU64,
    responses_2xx: AtomicU64,
    responses_4xx: AtomicU64,
    responses_5xx: AtomicU64,
    /// admission refusals: queue full, draining, connection cap
    rejected_503: AtomicU64,
    /// deadline expiries, both queue-side and handler-side
    deadline_504: AtomicU64,
    /// large-B requests sharded over rank workers
    split_jobs: AtomicU64,
    batches: AtomicU64,
    requests_per_batch: Mutex<Histogram>,
    members_per_batch: Mutex<Histogram>,
}

impl TierMetrics {
    pub fn new() -> TierMetrics {
        TierMetrics {
            connections: AtomicU64::new(0),
            responses_2xx: AtomicU64::new(0),
            responses_4xx: AtomicU64::new(0),
            responses_5xx: AtomicU64::new(0),
            rejected_503: AtomicU64::new(0),
            deadline_504: AtomicU64::new(0),
            split_jobs: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            requests_per_batch: Mutex::new(Histogram::new(1.0)),
            members_per_batch: Mutex::new(Histogram::new(1.0)),
        }
    }

    pub(crate) fn note_connection(&self) {
        self.connections.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_response(&self, status: u16) {
        let class = match status {
            200..=299 => &self.responses_2xx,
            400..=499 => &self.responses_4xx,
            _ => &self.responses_5xx,
        };
        class.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_rejected(&self) {
        self.rejected_503.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_deadline(&self) {
        self.deadline_504.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_split(&self) {
        self.split_jobs.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_batch(&self, requests: usize, members: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        lock(&self.requests_per_batch).record(requests as f64);
        lock(&self.members_per_batch).record(members as f64);
    }

    /// Responses accounted so far, over all status classes.
    pub fn responses(&self) -> u64 {
        self.responses_2xx.load(Ordering::Relaxed)
            + self.responses_4xx.load(Ordering::Relaxed)
            + self.responses_5xx.load(Ordering::Relaxed)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("connections", Json::Num(self.connections.load(Ordering::Relaxed) as f64)),
            ("responses", Json::Num(self.responses() as f64)),
            ("responses_2xx", Json::Num(self.responses_2xx.load(Ordering::Relaxed) as f64)),
            ("responses_4xx", Json::Num(self.responses_4xx.load(Ordering::Relaxed) as f64)),
            ("responses_5xx", Json::Num(self.responses_5xx.load(Ordering::Relaxed) as f64)),
            ("rejected_503", Json::Num(self.rejected_503.load(Ordering::Relaxed) as f64)),
            ("deadline_504", Json::Num(self.deadline_504.load(Ordering::Relaxed) as f64)),
            ("split_jobs", Json::Num(self.split_jobs.load(Ordering::Relaxed) as f64)),
            ("coalesced_batches", Json::Num(self.batches.load(Ordering::Relaxed) as f64)),
            ("requests_per_batch", lock(&self.requests_per_batch).to_json()),
            ("members_per_batch", lock(&self.members_per_batch).to_json()),
        ])
    }
}

impl Default for TierMetrics {
    fn default() -> Self {
        TierMetrics::new()
    }
}

/// Everything the serving tier is configured by; the CLI `serve`
/// subcommand maps its flags 1:1 onto these fields.
#[derive(Clone, Debug)]
pub struct HttpConfig {
    /// bind address, e.g. `127.0.0.1:8080`; port 0 picks an ephemeral
    /// port (tests/benches read it back via [`HttpServer::local_addr`])
    pub addr: String,
    /// evaluation worker threads behind the queue
    pub workers: usize,
    /// pending requests admitted before 503 + `Retry-After`
    pub max_queue: usize,
    /// server-side default deadline; `None` disables (requests may
    /// still set `timeout_ms` per call)
    pub request_timeout: Option<Duration>,
    /// fuse compatible concurrent requests into one rollout
    pub coalesce: bool,
    /// cap on a fused batch's total members
    pub max_coalesce_members: usize,
    /// members at or above this shard over rank workers
    pub split_members: usize,
    /// most rank workers one split request may use
    pub split_workers: usize,
    /// concurrent connections before the acceptor answers 503
    pub max_connections: usize,
    /// largest accepted `members` per request
    pub max_members: usize,
    /// largest accepted `steps` per request
    pub max_steps: usize,
    /// protocol-level byte caps (line/header/body)
    pub limits: Limits,
    /// enable `POST /admin/shutdown` (tests and the CI smoke; SIGINT is
    /// the production path)
    pub admin_shutdown: bool,
    /// where to flush the final metrics snapshot on shutdown
    pub metrics_path: Option<PathBuf>,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            addr: "127.0.0.1:8080".to_string(),
            workers: 2,
            max_queue: 256,
            request_timeout: Some(Duration::from_secs(30)),
            coalesce: true,
            max_coalesce_members: 1024,
            split_members: 8192,
            split_workers: 4,
            max_connections: 64,
            max_members: 65_536,
            max_steps: 1_000_000,
            limits: Limits::default(),
            admin_shutdown: false,
            metrics_path: None,
        }
    }
}

impl HttpConfig {
    fn queue_config(&self) -> QueueConfig {
        QueueConfig {
            workers: self.workers,
            max_queue: self.max_queue,
            coalesce: self.coalesce,
            max_coalesce_members: self.max_coalesce_members,
            split_members: self.split_members,
            split_workers: self.split_workers,
        }
    }
}

/// Shared server state every connection handler sees.
pub(crate) struct Ctx {
    pub(crate) cfg: HttpConfig,
    pub(crate) registry: ModelRegistry,
    pub(crate) queue: EnsembleQueue,
    pub(crate) metrics: Arc<TierMetrics>,
    /// set by SIGINT / `POST /admin/shutdown`; acceptor and keep-alive
    /// loops poll it
    pub(crate) shutdown: AtomicBool,
    pub(crate) started: Instant,
}

/// A running serving tier: acceptor thread + connection threads +
/// scheduler workers. [`HttpServer::join`] (or drop) drains everything.
pub struct HttpServer {
    ctx: Arc<Ctx>,
    addr: SocketAddr,
    acceptor: Option<JoinHandle<()>>,
    active: Arc<AtomicUsize>,
}

impl HttpServer {
    /// Bind, spawn the scheduler workers and the acceptor, return
    /// immediately. The listener is non-blocking so the acceptor can
    /// poll the shutdown flag between accepts.
    pub fn start(registry: ModelRegistry, cfg: HttpConfig) -> Result<HttpServer> {
        anyhow::ensure!(!registry.is_empty(), "serving needs at least one model");
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        listener.set_nonblocking(true).context("setting the listener non-blocking")?;
        let addr = listener.local_addr().context("reading the bound address")?;

        let metrics = Arc::new(TierMetrics::new());
        let queue = EnsembleQueue::start(cfg.queue_config(), Arc::clone(&metrics));
        let ctx = Arc::new(Ctx {
            cfg,
            registry,
            queue,
            metrics,
            shutdown: AtomicBool::new(false),
            started: Instant::now(),
        });

        let active = Arc::new(AtomicUsize::new(0));
        let acceptor = {
            let ctx = Arc::clone(&ctx);
            let active = Arc::clone(&active);
            std::thread::Builder::new()
                .name("http-acceptor".to_string())
                .spawn(move || accept_loop(&listener, &ctx, &active))
                .context("spawning the acceptor thread")?
        };
        Ok(HttpServer { ctx, addr, acceptor: Some(acceptor), active })
    }

    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Snapshot of the full `/metrics` document.
    pub fn metrics_json(&self) -> Json {
        metrics_document(&self.ctx)
    }

    /// Ask the server to stop: the acceptor exits, keep-alive
    /// connections close after their in-flight request, the queue
    /// drains. Returns immediately; pair with [`HttpServer::join`].
    pub fn request_shutdown(&self) {
        self.ctx.shutdown.store(true, Ordering::SeqCst);
    }

    pub fn shutdown_requested(&self) -> bool {
        self.ctx.shutdown.load(Ordering::SeqCst)
    }

    /// Stop accepting, drain connections and the queue, flush the final
    /// metrics snapshot, and return it. No accepted request is dropped:
    /// connections finish their in-flight request and the queue answers
    /// everything it admitted.
    pub fn join(mut self) -> Result<Json> {
        self.finish()?;
        Ok(metrics_document(&self.ctx))
    }

    fn finish(&mut self) -> Result<()> {
        let Some(acceptor) = self.acceptor.take() else {
            return Ok(()); // already joined
        };
        self.ctx.shutdown.store(true, Ordering::SeqCst);
        let _ = acceptor.join();
        // connection handlers see the flag at their next poll tick
        // (≤ 200ms) and exit after any in-flight request completes
        let drain_deadline = Instant::now() + Duration::from_secs(30);
        while self.active.load(Ordering::SeqCst) > 0 && Instant::now() < drain_deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        // close admission and answer everything already accepted
        self.ctx.queue.shutdown();
        if let Some(path) = &self.ctx.cfg.metrics_path {
            if let Some(parent) = path.parent() {
                if !parent.as_os_str().is_empty() {
                    std::fs::create_dir_all(parent)
                        .with_context(|| format!("creating {}", parent.display()))?;
                }
            }
            let doc = emit(&metrics_document(&self.ctx)) + "\n";
            std::fs::write(path, doc)
                .with_context(|| format!("writing the final metrics snapshot to {}", path.display()))?;
        }
        let leaked = self.active.load(Ordering::SeqCst);
        anyhow::ensure!(leaked == 0, "{leaked} connection(s) still active after the drain window");
        Ok(())
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        let _ = self.finish();
    }
}

fn accept_loop(listener: &TcpListener, ctx: &Arc<Ctx>, active: &Arc<AtomicUsize>) {
    while !ctx.shutdown.load(Ordering::SeqCst) {
        let (stream, _peer) = match listener.accept() {
            Ok(conn) => conn,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
                continue;
            }
            Err(_) => {
                std::thread::sleep(Duration::from_millis(5));
                continue;
            }
        };
        // the listener's non-blocking flag is inherited per-platform;
        // connection I/O must block (with read timeouts)
        if stream.set_nonblocking(false).is_err() {
            continue;
        }
        let _ = stream.set_nodelay(true);

        if active.load(Ordering::SeqCst) >= ctx.cfg.max_connections {
            ctx.metrics.note_rejected();
            ctx.metrics.note_response(503);
            let resp = protocol::Response::error(503, "connection limit reached")
                .with_header("Retry-After", "1");
            let mut stream = stream;
            let _ = resp.write_to(&mut stream);
            continue;
        }

        active.fetch_add(1, Ordering::SeqCst);
        let ctx_conn = Arc::clone(ctx);
        let active_conn = Arc::clone(active);
        let spawned = std::thread::Builder::new().name("http-conn".to_string()).spawn(move || {
            handle_connection(stream, &ctx_conn);
            active_conn.fetch_sub(1, Ordering::SeqCst);
        });
        if spawned.is_err() {
            // the closure never ran; undo its count here
            active.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// Serve one keep-alive connection until the client closes, an error
/// forces a close, or shutdown is requested.
fn handle_connection(stream: TcpStream, ctx: &Arc<Ctx>) {
    ctx.metrics.note_connection();
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut stream = stream;
    loop {
        if !wait_for_request(&mut reader, &stream, ctx) {
            return;
        }
        // a request has started arriving: bound how long the rest may take
        let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
        match protocol::read_request(&mut reader, &ctx.cfg.limits) {
            Ok(None) => return, // clean close between requests
            Ok(Some(req)) => {
                let mut resp = api::handle(ctx, &req);
                let client_close =
                    req.header("connection").is_some_and(|v| v.eq_ignore_ascii_case("close"));
                if client_close || ctx.shutdown.load(Ordering::SeqCst) {
                    resp.close = true;
                }
                let close = resp.close;
                if resp.write_to(&mut stream).is_err() || close {
                    return;
                }
            }
            Err(e) => {
                if let Some(resp) = e.to_response() {
                    ctx.metrics.note_response(resp.status);
                    let _ = resp.write_to(&mut stream);
                }
                return;
            }
        }
    }
}

/// Park until the next request's first byte is available, the client
/// closes, or shutdown is requested. Short read-timeout slices keep the
/// wait responsive to the shutdown flag without busy-spinning.
fn wait_for_request(reader: &mut BufReader<TcpStream>, stream: &TcpStream, ctx: &Ctx) -> bool {
    loop {
        if !reader.buffer().is_empty() {
            return true; // a pipelined request is already buffered
        }
        if ctx.shutdown.load(Ordering::SeqCst) {
            return false;
        }
        if stream.set_read_timeout(Some(Duration::from_millis(200))).is_err() {
            return false;
        }
        let mut probe = [0u8; 1];
        match stream.peek(&mut probe) {
            Ok(0) => return false, // client closed
            Ok(_) => return true,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => return false,
        }
    }
}

/// The `/metrics` document: tier counters, queue state, per-model
/// serving histograms (with p50/p99 read off the log buckets).
pub(crate) fn metrics_document(ctx: &Ctx) -> Json {
    let models: Vec<(String, Json)> = ctx
        .registry
        .entries()
        .map(|e| {
            let m = e.metrics();
            let mut doc = match m.to_json() {
                Json::Obj(map) => map,
                _ => unreachable!("ServeMetrics::to_json emits an object"),
            };
            doc.insert("latency_p50_s".to_string(), Json::Num(m.latency.quantile(0.50)));
            doc.insert("latency_p99_s".to_string(), Json::Num(m.latency.quantile(0.99)));
            doc.insert("generation".to_string(), Json::Num(e.generation() as f64));
            doc.insert("reloads".to_string(), Json::Num(e.reloads() as f64));
            (e.name().to_string(), Json::Obj(doc))
        })
        .collect();
    Json::obj(vec![
        ("schema", Json::Str("dopinf-serve-http-v1".to_string())),
        ("uptime_s", Json::Num(ctx.started.elapsed().as_secs_f64())),
        ("http", ctx.metrics.to_json()),
        (
            "queue",
            Json::obj(vec![
                ("depth", Json::Num(ctx.queue.depth() as f64)),
                ("peak_depth", Json::Num(ctx.queue.peak_depth() as f64)),
                ("max_queue", Json::Num(ctx.cfg.max_queue as f64)),
                ("workers", Json::Num(ctx.cfg.workers as f64)),
            ]),
        ),
        ("models", Json::Obj(models.into_iter().collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_metrics_classify_statuses() {
        let m = TierMetrics::new();
        m.note_response(200);
        m.note_response(204);
        m.note_response(400);
        m.note_response(404);
        m.note_response(503);
        m.note_response(500);
        assert_eq!(m.responses(), 6);
        let j = m.to_json();
        assert_eq!(j.get("responses_2xx").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("responses_4xx").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("responses_5xx").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn tier_metrics_batch_histograms() {
        let m = TierMetrics::new();
        m.note_batch(3, 12);
        m.note_batch(1, 64);
        let j = m.to_json();
        assert_eq!(j.get("coalesced_batches").unwrap().as_usize().unwrap(), 2);
        assert_eq!(
            j.get("requests_per_batch").unwrap().get("sum").unwrap().as_usize().unwrap(),
            4
        );
        assert_eq!(
            j.get("members_per_batch").unwrap().get("sum").unwrap().as_usize().unwrap(),
            76
        );
    }

    #[test]
    fn config_defaults_are_consistent_with_the_queue() {
        let cfg = HttpConfig::default();
        let q = cfg.queue_config();
        assert_eq!(q.workers, cfg.workers);
        assert_eq!(q.max_queue, cfg.max_queue);
        assert!(q.coalesce);
        assert!(cfg.max_coalesce_members <= cfg.split_members);
    }
}

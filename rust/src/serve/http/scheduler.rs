//! Batch-size-aware admission + the coalescing worker pool behind the
//! HTTP endpoints.
//!
//! Unlike [`crate::serve::RomServer`]'s mpsc queue, the pending queue
//! here is an inspectable `VecDeque` under a mutex/condvar — a worker
//! popping the oldest request can *also* drain every compatible pending
//! request into one fused batch ([`super::coalesce`]). Admission rules:
//!
//! * **bounded depth** — `pending.len() == max_queue` refuses the job
//!   ([`SubmitError::Full`] → 503 + `Retry-After`), so a burst degrades
//!   into fast rejections instead of unbounded memory and latency;
//! * **deadlines** — each job may carry one; a worker dequeuing an
//!   already-expired job replies [`JobError::Deadline`] without burning
//!   an evaluation on it (→ 504), and the HTTP handler independently
//!   gives up at the same deadline, so one stuck evaluation cannot wedge
//!   the connection while the queue stays serviceable;
//! * **large-B splitting** — a request at or past `split_members`
//!   bypasses coalescing and fans its members out over
//!   [`serve_ensemble`]'s rank workers (bitwise identical to the solo
//!   path by that function's own contract).
//!
//! Coalescing compatibility is deliberately strict: same pinned
//! artifact **pointer** (`Arc::ptr_eq` — requests admitted across a
//! hot-reload must not fuse), same horizon, both opted in, fused size
//! capped. Workers `catch_unwind` evaluations like `RomServer` does:
//! a panicking batch answers every member with an error and the worker
//! lives on.
//!
//! Shutdown drains: `shutdown()` closes admission, then workers keep
//! popping until the queue is empty before exiting — no accepted
//! request is dropped.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::runtime::Engine;
use crate::serve::ensemble::{run_ensemble, EnsembleSpec, EnsembleStats};
use crate::serve::model::RomArtifact;
use crate::serve::server::serve_ensemble;
use crate::util::panic::panic_text;

use super::coalesce::run_coalesced;
use super::registry::ModelEntry;
use super::TierMetrics;

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Scheduler knobs; mirrored from [`super::HttpConfig`].
#[derive(Clone, Debug)]
pub struct QueueConfig {
    /// evaluation worker threads
    pub workers: usize,
    /// pending jobs admitted before [`SubmitError::Full`]
    pub max_queue: usize,
    /// fuse compatible concurrent requests into one rollout
    pub coalesce: bool,
    /// cap on the fused batch's total members
    pub max_coalesce_members: usize,
    /// members at or above this shard over rank workers instead
    pub split_members: usize,
    /// most rank workers one split request may spawn
    pub split_workers: usize,
}

impl Default for QueueConfig {
    fn default() -> Self {
        QueueConfig {
            workers: 2,
            max_queue: 256,
            coalesce: true,
            max_coalesce_members: 1024,
            split_members: 8192,
            split_workers: 4,
        }
    }
}

/// Why a job's reply is an error rather than statistics.
#[derive(Clone, Debug)]
pub enum JobError {
    /// the deadline passed before a worker could start it → 504
    Deadline,
    /// the evaluation failed or panicked → 500
    Failed(String),
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobError::Deadline => write!(f, "deadline exceeded before evaluation started"),
            JobError::Failed(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for JobError {}

/// Why admission refused a job.
#[derive(Debug)]
pub enum SubmitError {
    /// queue at `max_queue` → 503 + `Retry-After`
    Full { depth: usize },
    /// the queue is shutting down → 503
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Full { depth } => write!(f, "queue full ({depth} pending)"),
            SubmitError::Closed => write!(f, "queue is shutting down"),
        }
    }
}

impl std::error::Error for SubmitError {}

pub type JobReply = Result<EnsembleStats, JobError>;

/// One admitted request. The artifact `Arc` is pinned at admission —
/// the hot-reload guarantee that in-flight requests finish on the
/// artifact they were admitted against.
struct Job {
    entry: Arc<ModelEntry>,
    artifact: Arc<RomArtifact>,
    spec: EnsembleSpec,
    coalesce: bool,
    deadline: Option<Instant>,
    submitted: Instant,
    reply: mpsc::Sender<JobReply>,
}

struct QueueState {
    pending: VecDeque<Job>,
    open: bool,
}

struct Shared {
    state: Mutex<QueueState>,
    ready: Condvar,
    cfg: QueueConfig,
    metrics: Arc<TierMetrics>,
}

/// The coalescing request queue + its worker pool.
pub struct EnsembleQueue {
    shared: Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    /// peak observed depth, for /metrics
    peak_depth: AtomicU64,
}

impl EnsembleQueue {
    /// Queue with **no** workers yet — tests use this to stage several
    /// submissions and then spawn one worker, making the coalescing
    /// decision deterministic. Production goes through [`start`].
    ///
    /// [`start`]: EnsembleQueue::start
    pub fn new(cfg: QueueConfig, metrics: Arc<TierMetrics>) -> EnsembleQueue {
        EnsembleQueue {
            shared: Arc::new(Shared {
                state: Mutex::new(QueueState { pending: VecDeque::new(), open: true }),
                ready: Condvar::new(),
                cfg,
                metrics,
            }),
            workers: Mutex::new(Vec::new()),
            peak_depth: AtomicU64::new(0),
        }
    }

    /// Queue with `cfg.workers` workers already draining it.
    pub fn start(cfg: QueueConfig, metrics: Arc<TierMetrics>) -> EnsembleQueue {
        let q = EnsembleQueue::new(cfg, metrics);
        let n = q.shared.cfg.workers;
        q.spawn_workers(n);
        q
    }

    pub fn spawn_workers(&self, n: usize) {
        let mut workers = lock(&self.workers);
        for i in 0..n {
            let shared = Arc::clone(&self.shared);
            let handle = std::thread::Builder::new()
                .name(format!("ensemble-worker-{i}"))
                .spawn(move || worker_loop(&shared))
                .expect("spawning an evaluation worker");
            workers.push(handle);
        }
    }

    /// Admit one request. The artifact is pinned here; the returned
    /// channel yields the reply when a worker finishes (or refuses) the
    /// job.
    pub fn submit(
        &self,
        entry: Arc<ModelEntry>,
        spec: EnsembleSpec,
        coalesce: bool,
        deadline: Option<Instant>,
    ) -> Result<mpsc::Receiver<JobReply>, SubmitError> {
        let artifact = entry.artifact();
        let (reply, rx) = mpsc::channel();
        {
            let mut st = lock(&self.shared.state);
            if !st.open {
                return Err(SubmitError::Closed);
            }
            if st.pending.len() >= self.shared.cfg.max_queue {
                return Err(SubmitError::Full { depth: st.pending.len() });
            }
            st.pending.push_back(Job {
                entry,
                artifact,
                spec,
                coalesce,
                deadline,
                submitted: Instant::now(),
                reply,
            });
            self.peak_depth.fetch_max(st.pending.len() as u64, Ordering::Relaxed);
        }
        self.shared.ready.notify_one();
        Ok(rx)
    }

    /// Requests currently queued (not counting in-flight evaluations).
    pub fn depth(&self) -> usize {
        lock(&self.shared.state).pending.len()
    }

    pub fn peak_depth(&self) -> u64 {
        self.peak_depth.load(Ordering::Relaxed)
    }

    /// Close admission, drain everything already accepted, join the
    /// workers. Idempotent; new submits fail with [`SubmitError::Closed`].
    pub fn shutdown(&self) {
        lock(&self.shared.state).open = false;
        self.shared.ready.notify_all();
        let handles: Vec<JoinHandle<()>> = lock(&self.workers).drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for EnsembleQueue {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(shared: &Shared) {
    let engine = Engine::native();
    loop {
        let batch = {
            let mut st = lock(&shared.state);
            loop {
                if let Some(first) = st.pending.pop_front() {
                    break collect_batch(first, &mut st, &shared.cfg);
                }
                if !st.open {
                    return; // drained and closed
                }
                st = shared.ready.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
        };
        run_batch(&engine, batch, shared);
    }
}

/// Greedily drain pending requests compatible with `first` into one
/// batch. Called under the queue lock; O(pending) per dequeue.
fn collect_batch(first: Job, st: &mut QueueState, cfg: &QueueConfig) -> Vec<Job> {
    if !cfg.coalesce || !first.coalesce || first.spec.members >= cfg.split_members {
        return vec![first];
    }
    let mut total = first.spec.members;
    let mut batch = vec![first];
    let mut i = 0;
    while i < st.pending.len() {
        let c = &st.pending[i];
        let compatible = c.coalesce
            && Arc::ptr_eq(&c.artifact, &batch[0].artifact)
            && c.spec.n_steps == batch[0].spec.n_steps
            && c.spec.members < cfg.split_members
            && total + c.spec.members <= cfg.max_coalesce_members;
        if compatible {
            let job = st.pending.remove(i).expect("index in bounds");
            total += job.spec.members;
            batch.push(job);
        } else {
            i += 1;
        }
    }
    batch
}

fn run_batch(engine: &Engine, batch: Vec<Job>, shared: &Shared) {
    let dequeued = Instant::now();
    // expired jobs answer Deadline without costing an evaluation; the
    // rest share one fused run
    let (live, expired): (Vec<Job>, Vec<Job>) =
        batch.into_iter().partition(|j| j.deadline.is_none_or(|d| dequeued <= d));
    for j in expired {
        let _ = j.reply.send(Err(JobError::Deadline));
    }
    if live.is_empty() {
        return;
    }

    let total_members: usize = live.iter().map(|j| j.spec.members).sum();
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        evaluate(engine, &live, &shared.cfg, &shared.metrics)
    }))
    .unwrap_or_else(|p| Err(format!("ensemble evaluation panicked: {}", panic_text(&*p))));

    let latency_s = dequeued.elapsed().as_secs_f64();
    shared.metrics.note_batch(live.len(), total_members);
    match result {
        Ok(all) => {
            debug_assert_eq!(all.len(), live.len());
            for (j, stats) in live.into_iter().zip(all) {
                let wait = dequeued.duration_since(j.submitted).as_secs_f64();
                j.entry.record(j.spec.members, wait, latency_s);
                let _ = j.reply.send(Ok(stats));
            }
        }
        Err(msg) => {
            // error replies record too — burned worker time must show
            // in the latency histograms (same policy as RomServer)
            for j in live {
                let wait = dequeued.duration_since(j.submitted).as_secs_f64();
                j.entry.record(j.spec.members, wait, latency_s);
                let _ = j.reply.send(Err(JobError::Failed(msg.clone())));
            }
        }
    }
}

fn evaluate(
    engine: &Engine,
    jobs: &[Job],
    cfg: &QueueConfig,
    metrics: &TierMetrics,
) -> Result<Vec<EnsembleStats>, String> {
    if jobs.len() == 1 {
        let j = &jobs[0];
        let stats = if j.spec.members >= cfg.split_members && cfg.split_workers > 1 {
            // very large B: shard members over rank workers —
            // serve_ensemble's own contract keeps this bitwise equal to
            // the solo path
            metrics.note_split();
            let shards = j.spec.members.div_ceil(cfg.split_members);
            let w = shards.max(2).min(cfg.split_workers);
            serve_ensemble(engine, &j.artifact, &j.spec, w)
        } else {
            run_ensemble(engine, &j.artifact, &j.spec)
        }
        .map_err(|e| format!("{e:#}"))?;
        return Ok(vec![stats]);
    }
    let specs: Vec<EnsembleSpec> = jobs.iter().map(|j| j.spec.clone()).collect();
    run_coalesced(engine, &jobs[0].artifact, &specs).map_err(|e| format!("{e:#}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::http::registry::ModelRegistry;
    use crate::serve::model::RomArtifact;
    use crate::opinf::postprocess::ProbeBasis;
    use crate::rom::RomOperators;
    use std::collections::BTreeMap;
    use std::time::Duration;

    fn registry(r: usize) -> ModelRegistry {
        let art = RomArtifact {
            ops: RomOperators::stable_sample(r, 21),
            qhat0: (0..r).map(|j| 0.4 - 0.05 * j as f64).collect(),
            probes: vec![ProbeBasis { var: 0, row: 2, phi: vec![1.0; r], mean: 0.0, scale: 1.0 }],
            reg: None,
            meta: BTreeMap::new(),
        };
        ModelRegistry::from_artifacts(vec![("m", art)])
    }

    fn queue(cfg: QueueConfig) -> (EnsembleQueue, Arc<TierMetrics>) {
        let metrics = Arc::new(TierMetrics::new());
        (EnsembleQueue::new(cfg, Arc::clone(&metrics)), metrics)
    }

    #[test]
    fn staged_submissions_coalesce_into_one_batch() {
        let reg = registry(4);
        let entry = reg.get("m").unwrap();
        let (q, metrics) = queue(QueueConfig::default());
        let spec = |seed| EnsembleSpec { members: 2, sigma: 0.01, seed, n_steps: 20 };
        let rxs: Vec<_> =
            (0..5).map(|s| q.submit(Arc::clone(&entry), spec(s), true, None).unwrap()).collect();
        assert_eq!(q.depth(), 5);
        q.spawn_workers(1);
        for rx in rxs {
            let stats = rx.recv().unwrap().unwrap();
            assert_eq!(stats.members, 2);
            assert_eq!(stats.n_steps, 20);
        }
        // all five went through as one fused batch of 10 members
        let j = metrics.to_json();
        assert_eq!(j.get("coalesced_batches").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            j.get("requests_per_batch").unwrap().get("sum").unwrap().as_usize().unwrap(),
            5
        );
        assert_eq!(
            j.get("members_per_batch").unwrap().get("sum").unwrap().as_usize().unwrap(),
            10
        );
        q.shutdown();
    }

    #[test]
    fn coalescing_respects_opt_out_and_caps() {
        let reg = registry(3);
        let entry = reg.get("m").unwrap();
        let cfg = QueueConfig { max_coalesce_members: 4, ..QueueConfig::default() };
        let (q, metrics) = queue(cfg);
        let spec = |seed| EnsembleSpec { members: 2, sigma: 0.01, seed, n_steps: 10 };
        // 2 coalescable + 1 opted out + 1 past the member cap
        let rxs: Vec<_> = vec![
            q.submit(Arc::clone(&entry), spec(0), true, None).unwrap(),
            q.submit(Arc::clone(&entry), spec(1), true, None).unwrap(),
            q.submit(Arc::clone(&entry), spec(2), false, None).unwrap(),
            q.submit(Arc::clone(&entry), spec(3), true, None).unwrap(),
        ];
        q.spawn_workers(1);
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        // batch 1 = jobs {0, 1} (cap 4 members), batch 2 = job 2 (opted
        // out), batch 3 = job 3
        let j = metrics.to_json();
        assert_eq!(j.get("coalesced_batches").unwrap().as_usize().unwrap(), 3);
        assert_eq!(
            j.get("requests_per_batch").unwrap().get("max").unwrap().as_usize().unwrap(),
            2
        );
        q.shutdown();
    }

    #[test]
    fn bounded_queue_refuses_with_full() {
        let reg = registry(3);
        let entry = reg.get("m").unwrap();
        let cfg = QueueConfig { max_queue: 2, ..QueueConfig::default() };
        let (q, _) = queue(cfg); // no workers: nothing drains
        let spec = EnsembleSpec { members: 1, sigma: 0.01, seed: 0, n_steps: 5 };
        let _a = q.submit(Arc::clone(&entry), spec.clone(), true, None).unwrap();
        let _b = q.submit(Arc::clone(&entry), spec.clone(), true, None).unwrap();
        match q.submit(Arc::clone(&entry), spec.clone(), true, None) {
            Err(SubmitError::Full { depth }) => assert_eq!(depth, 2),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.peak_depth(), 2);
    }

    #[test]
    fn expired_jobs_reply_deadline_and_queue_stays_serviceable() {
        let reg = registry(3);
        let entry = reg.get("m").unwrap();
        let (q, _) = queue(QueueConfig::default());
        let spec = EnsembleSpec { members: 1, sigma: 0.01, seed: 0, n_steps: 5 };
        // a deadline already in the past, then a healthy job
        let past = Instant::now() - Duration::from_millis(1);
        let dead = q.submit(Arc::clone(&entry), spec.clone(), true, Some(past)).unwrap();
        let live = q
            .submit(Arc::clone(&entry), spec.clone(), false, Some(Instant::now() + Duration::from_secs(60)))
            .unwrap();
        q.spawn_workers(1);
        assert!(matches!(dead.recv().unwrap(), Err(JobError::Deadline)));
        assert!(live.recv().unwrap().is_ok());
        q.shutdown();
    }

    #[test]
    fn shutdown_drains_pending_jobs() {
        let reg = registry(3);
        let entry = reg.get("m").unwrap();
        let (q, _) = queue(QueueConfig::default());
        let spec = |seed| EnsembleSpec { members: 2, sigma: 0.01, seed, n_steps: 15 };
        let rxs: Vec<_> =
            (0..3).map(|s| q.submit(Arc::clone(&entry), spec(s), true, None).unwrap()).collect();
        q.spawn_workers(1);
        // close admission immediately: the three accepted jobs must
        // still be answered, the fourth refused
        q.shutdown();
        let spec4 = EnsembleSpec { members: 1, sigma: 0.01, seed: 9, n_steps: 5 };
        assert!(matches!(q.submit(entry, spec4, true, None), Err(SubmitError::Closed)));
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok(), "accepted job dropped during shutdown");
        }
    }
}
